//! Hot-path microbenchmarks (custom harness; criterion unavailable
//! offline). These are the perf-pass targets of EXPERIMENTS.md §Perf:
//!
//!   1. full-grid prediction through the batched host engine
//!      (the request-path bottleneck: 2 models x 4,368-18,096 modes),
//!      with the seed scalar path benched alongside as the baseline and
//!      the 8-lane kernel path isolated over prebuilt SoA features
//!      (`host_simd` — build with `--features simd` for the intrinsics
//!      variant);
//!   2. prediction through the AOT `predict` artifact (feature `xla`);
//!   3. Pareto construction over grid-sized point clouds;
//!   4. simulator + profiler throughput (corpus generation);
//!   5. one fused train step through PJRT (feature `xla`);
//!   6. grid enumeration + profiling-plan construction;
//!   7. coordinator serving over the full 18,096-mode Orin grid: the cold
//!      per-request pipeline (which now includes online profiling and a
//!      host transfer of both models) vs the grid-resident cache hit
//!      (requests/s) through a long-lived per-worker pipeline (the
//!      lock-free snapshot fast path), the same hit path under 8-thread
//!      reader concurrency (`serve_concurrent_readers_8x` — aggregate
//!      ns/item should track the single-reader number, not 8x it), plus
//!      the burst under a 10% transient-fault plan (`serve_faulty_10pct`:
//!      retry machinery + fault consultation on the hot path);
//!   8. host-native transfer learning of one model from a 50-mode corpus
//!      (items = epochs, so ns/item reads as ns/epoch; median_ns is the
//!      end-to-end fit time);
//!   9. a 512-request burst of one identical workload streamed through
//!      the full coordinator service (priority queue + singleflight +
//!      pre-warmed shared cache): ns/item measures the steady-state
//!      service overhead per request, directly comparable to bench 7's
//!      cache-hit number (acceptance: within 10%);
//!  10. fleet placement: a 256-request mixed-kind burst routed over a
//!      64-node indexed registry snapshot and hash-dispatched onto 4
//!      coordinator domains (`coordinator/fleet_route_4shards`) — the
//!      pure routing + dispatch overhead the fleet front-end adds per
//!      request;
//!  11. fleet placement at 10k nodes through the indexed engine: one
//!      single O(1)-peek decision (`fleet/route_decision_10k_nodes`,
//!      target < 1 µs), a 1024-item burst folded in place
//!      (`fleet/route_10k_nodes`, target single-digit ms total), and a
//!      full heartbeat's dirty-entry rebuild + dirty-gated `ArcCell`
//!      publication (`fleet/snapshot_publish_10k`, ns/item = per-node
//!      republication cost);
//!  12. load-test planning (`loadgen/schedule_poisson200_60s`): fixing a
//!      60 s, 200 req/s Poisson arrival schedule up front — gap draws,
//!      rounding, the FNV fingerprint and one standard-mix draw per
//!      event; ns/item is `pt-loadtest`'s per-request setup overhead.
//!
//! Results are also written to `BENCH_hotpaths.json` (per-bench ns/item)
//! so successive PRs can track the perf trajectory.

use std::sync::Arc;

use powertrain::coordinator::{
    self, Coordinator, CoordinatorConfig, Job, PlaneCache, ReferenceModels, Request, Scenario,
};
use powertrain::device::{DeviceKind, PowerModeGrid, ProfilingPlan};
use powertrain::nn::{checkpoint::Checkpoint, host_mlp, MlpParams};
use powertrain::pareto::{ParetoFront, Point};
use powertrain::predict::GridPredictor;
use powertrain::profiler::{Profiler, StandardScaler};
use powertrain::sim::TrainerSim;
use powertrain::util::bench::Bencher;
use powertrain::util::rng::Rng;
use powertrain::workload::Workload;

fn demo_ckpt(seed: u64) -> Checkpoint {
    let mut rng = Rng::new(seed);
    Checkpoint {
        params: MlpParams::init_he(&mut rng),
        feature_scaler: StandardScaler {
            mean: vec![6.0, 1200.0, 700.0, 1700.0],
            std: vec![3.5, 600.0, 350.0, 1100.0],
        },
        target_scaler: StandardScaler { mean: vec![100.0], std: vec![40.0] },
        target: "time".into(),
        provenance: "bench".into(),
        val_loss: 0.0,
    }
}

/// The seed scalar host path, reproduced verbatim as the perf baseline
/// the engine is compared against: per-mode `Vec` round-trips through the
/// scaler plus `forward_one`'s per-layer allocations and strided weights.
fn predict_modes_host_scalar(
    ckpt: &Checkpoint,
    modes: &[powertrain::device::PowerMode],
) -> Vec<f64> {
    modes
        .iter()
        .map(|pm| {
            let feats = pm.features();
            let raw: Vec<f64> = feats.iter().map(|&v| v as f64).collect();
            let z = ckpt.feature_scaler.transform_row(&raw);
            let zf = [z[0] as f32, z[1] as f32, z[2] as f32, z[3] as f32];
            let pred_std = host_mlp::forward_one(&ckpt.params, &zf) as f64;
            ckpt.target_scaler.inverse1(pred_std)
        })
        .collect()
}

fn main() {
    println!("== powertrain hot-path benchmarks ==\n");
    let mut b = Bencher::default();

    // -- grid + plan construction ----------------------------------------
    b.bench_items("grid/enumerate_orin_full_18096", 18_096.0, || {
        PowerModeGrid::full(DeviceKind::OrinAgx).len()
    });
    let subset = PowerModeGrid::paper_subset(DeviceKind::OrinAgx);
    b.bench_items("grid/profiling_plan_4368", 4_368.0, || {
        ProfilingPlan::build(&subset.modes).reboot_count()
    });

    // -- simulator + profiler ---------------------------------------------
    let spec = DeviceKind::OrinAgx.spec();
    let mut sim_rng = Rng::new(3);
    let sample_modes = subset.sample(32, &mut sim_rng);
    b.bench_items("sim/true_time_power_4368_modes", 4_368.0, || {
        let sim = TrainerSim::new(spec, Workload::resnet(), 1);
        let mut acc = 0.0;
        for pm in &subset.modes {
            acc += sim.true_minibatch_ms(pm) + sim.true_power_mw(pm);
        }
        acc
    });
    b.bench_items("profiler/profile_32_modes_with_telemetry", 32.0, || {
        let mut p = Profiler::new(TrainerSim::new(spec, Workload::resnet(), 2));
        p.profile_modes(&sample_modes).unwrap().len()
    });

    // -- pareto -------------------------------------------------------------
    let mut rng = Rng::new(5);
    let cloud: Vec<Point> = (0..18_096)
        .map(|_| Point {
            mode: subset.modes[rng.below(subset.len())],
            time: rng.uniform_range(10.0, 2_000.0),
            power_mw: rng.uniform_range(8_000.0, 55_000.0),
        })
        .collect();
    b.bench_items("pareto/build_18096_points", 18_096.0, || {
        ParetoFront::build(&cloud).len()
    });
    let front = ParetoFront::build(&cloud);
    b.bench_items("pareto/optimize_sweep_34_budgets", 34.0, || {
        let mut acc = 0.0;
        for bw in 17..=50 {
            if let Ok(p) = front.optimize(bw as f64 * 1000.0) {
                acc += p.time;
            }
        }
        acc
    });

    // -- host prediction: seed scalar baseline vs batched engine ----------
    let ckpt = demo_ckpt(7);
    let full = PowerModeGrid::full(DeviceKind::OrinAgx);
    b.bench_items("predict/host_scalar_4368_modes", 4_368.0, || {
        predict_modes_host_scalar(&ckpt, &subset.modes).len()
    });
    b.bench_items("predict/host_4368_modes", 4_368.0, || {
        powertrain::predict::predict_modes_host(&ckpt, &subset.modes).len()
    });
    // steady state: engine built once per checkpoint, output buffer reused
    let gp = GridPredictor::new(&ckpt);
    let mut out = Vec::new();
    b.bench_items("predict/host_engine_steady_4368_modes", 4_368.0, || {
        gp.predict_into(&subset.modes, &mut out);
        out.len()
    });
    b.bench_items("predict/host_18096_modes", 18_096.0, || {
        gp.predict_into(&full.modes, &mut out);
        out.len()
    });
    // the SIMD-width kernel path in isolation: SoA features prebuilt
    // (shared grid layout), scratch + output reused, so the measurement
    // is the 8-lane forward kernels and nothing else. Build with
    // `--features simd` to time the std::arch intrinsics variant of the
    // shared dot kernel against the autovectorized default.
    let features = full.feature_matrix();
    b.bench_items("predict/host_simd_18096_modes", 18_096.0, || {
        gp.predict_features_into(&features, &mut out);
        out.len()
    });

    // -- host-native transfer learning (the paper's core loop) ------------
    // profile a 50-mode corpus once (profiling cost is its own bench),
    // then measure the fit: 100 fine-tuning epochs of one model.
    // items = epochs, so ns/item is ns/epoch; median_ns is the
    // end-to-end 50-mode fit time.
    {
        use powertrain::train::transfer::{transfer_host, TransferConfig};
        use powertrain::train::{Target, TrainConfig};
        let mut rng = Rng::new(17);
        let modes = subset.sample(50, &mut rng);
        let mut profiler = Profiler::new(TrainerSim::new(spec, Workload::mobilenet(), 17));
        let corpus = profiler.profile_modes(&modes).unwrap();
        let reference = demo_ckpt(7);
        let tcfg = TransferConfig {
            base: TrainConfig { epochs: 100, seed: 17, ..Default::default() },
            ..Default::default()
        };
        b.bench_items("train/host_transfer_50modes_100epochs", 100.0, || {
            transfer_host(&reference, &corpus, Target::Time, &tcfg)
                .unwrap()
                .0
                .val_loss
        });
    }

    // -- coordinator serving: cold pipeline vs grid-resident cache hit ----
    // items = 1 request, so throughput reads directly as requests/sec.
    // The cold path now runs the full host-native paper loop per request
    // (profile 50 modes + transfer both models + predict + Pareto);
    // epochs are scaled down so the bench finishes in its time budget,
    // the dedicated train/ bench above measures fit cost at full epochs.
    {
        let reference = ReferenceModels { time: demo_ckpt(7), power: demo_ckpt(8) };
        let cfg = CoordinatorConfig {
            prediction_grid: Some(18_096),
            transfer_epochs: 30,
            ..Default::default()
        };
        let metrics = coordinator::Metrics::new();
        let req = Request {
            id: 0,
            device: DeviceKind::OrinAgx,
            workload: Workload::resnet(),
            power_budget_w: 1e6,
            scenario: Scenario::FederatedLearning,
            affinity: None,
            node: None,
            seed: 4,
        };
        // cold: every request pays 50-mode profiling, two host transfers,
        // grid enumeration, the shared feature build, two folded engine
        // builds + grid passes and a Pareto sort
        b.bench_items("coordinator/serve_cold_18096", 1.0, || {
            let cache = PlaneCache::new();
            coordinator::handle_request_host(&cache, &reference, &cfg, &metrics, &req)
                .unwrap()
                .id
        });
        // steady state: plane resident and one long-lived pipeline (the
        // service's per-worker shape — reference fingerprints hashed at
        // construction, never per request), so each iteration is the
        // pure hit path: one lock-free snapshot read, three hash
        // lookups, one partition_point over the cached front
        let cache = PlaneCache::new();
        coordinator::handle_request_host(&cache, &reference, &cfg, &metrics, &req).unwrap();
        let pipeline = coordinator::HostPipeline::new(&cache, &reference, &cfg, &metrics);
        b.bench_items("coordinator/serve_cachehit_18096", 1.0, || {
            pipeline.handle(&req).unwrap().id
        });

        // aggregate hit throughput under reader concurrency: 8 threads,
        // each its own pipeline (per-worker shape), all resolving against
        // one shared warm cache. With mutex-guarded maps this serialized;
        // with the lock-free snapshot the readers never contend, so
        // ns/item (items = total requests) should track the single-reader
        // hit number instead of 8x it.
        const HIT_READERS: usize = 8;
        const HITS_PER_READER: usize = 64;
        b.bench_items(
            "coordinator/serve_concurrent_readers_8x",
            (HIT_READERS * HITS_PER_READER) as f64,
            || {
                std::thread::scope(|s| {
                    let handles: Vec<_> = (0..HIT_READERS)
                        .map(|_| {
                            s.spawn(|| {
                                let p = coordinator::HostPipeline::new(
                                    &cache, &reference, &cfg, &metrics,
                                );
                                let mut acc = 0u64;
                                for _ in 0..HITS_PER_READER {
                                    acc += p.handle(&req).unwrap().id;
                                }
                                acc
                            })
                        })
                        .collect();
                    handles.into_iter().map(|h| h.join().unwrap()).sum::<u64>()
                })
            },
        );

        // burst of identical requests through the full streaming service:
        // the shared cache is pre-warmed (the fit itself is measured by
        // serve_cold/train benches), so every burst request is a
        // singleflight-coalesced cache hit and ns/item measures the
        // steady-state service overhead — queue, scheduling, channel,
        // worker dispatch — on top of the pure hit path. Acceptance:
        // throughput within 10% of serve_cachehit_18096 (items = 1
        // request in both, so ns/item is directly comparable).
        let burst_cfg = CoordinatorConfig { workers: 4, ..cfg.clone() };
        let shared = Arc::new(PlaneCache::new());
        coordinator::handle_request_host(&shared, &reference, &burst_cfg, &metrics, &req)
            .unwrap();
        const BURST: usize = 512;
        b.bench_items("coordinator/serve_burst_identical", BURST as f64, || {
            let (coordinator, submitter) =
                Coordinator::start_with_cache(&burst_cfg, &reference, Arc::clone(&shared))
                    .unwrap();
            for i in 0..BURST {
                submitter
                    .send(Job::immediate(Request { id: i as u64, ..req.clone() }))
                    .unwrap();
            }
            drop(submitter);
            let (responses, _) = coordinator.finish().unwrap();
            responses.len()
        });

        // resilient serving under a 10% transient-fault plan: the same
        // pre-warmed burst, but every 10th request takes an injected
        // transient failure on its first attempt and goes through the
        // retry loop (deterministic backoff included — retry latency IS
        // the cost of faults), and any cold build under this plan would
        // roll a 10% profiling failure. ns/item measures steady-state
        // service overhead at a 10% fault rate, directly comparable to
        // serve_burst_identical.
        {
            use powertrain::sim::{FaultInjector, FaultPlan};
            const FAULTY: usize = 128;
            let plan = FaultPlan {
                seed: 9,
                profiling_fail_pct: 0.1,
                profiling_streak: 1,
                panic_request_ids: (0..FAULTY as u64).step_by(10).collect(),
                ..FaultPlan::default()
            };
            let faulty_cfg = CoordinatorConfig {
                faults: Some(Arc::new(FaultInjector::new(plan))),
                ..burst_cfg.clone()
            };
            b.bench_items("coordinator/serve_faulty_10pct", FAULTY as f64, || {
                let (coordinator, submitter) =
                    Coordinator::start_with_cache(&faulty_cfg, &reference, Arc::clone(&shared))
                        .unwrap();
                for i in 0..FAULTY {
                    submitter
                        .send(Job::immediate(Request { id: i as u64, ..req.clone() }))
                        .unwrap();
                }
                drop(submitter);
                let (responses, _) = coordinator.finish().unwrap();
                responses.len()
            });
        }
    }

    // -- fleet routing: a mixed-kind burst across 4 coordinator domains --
    // Pure placement cost on the production (indexed) path: one
    // 256-request burst folded through the indexed snapshot (warmth +
    // load applied between decisions, exactly what the fleet layer does
    // between heartbeats), each placement then resolved to its owning
    // domain via the model-key hash partition. ns/item is the
    // per-request routing + dispatch overhead the fleet front-end adds
    // on top of a shard's serve path.
    {
        use powertrain::coordinator::{ModelKey, Strategy};
        use powertrain::fleet::{route_burst_indexed, FleetRegistry};
        const SHARDS: usize = 4;
        const FLEET_BURST: usize = 256;
        let reference = ReferenceModels { time: demo_ckpt(7), power: demo_ckpt(8) };
        let ref_fps = reference.fingerprints();
        let registry = FleetRegistry::synthesize(64, 1);
        let items: Vec<(Option<DeviceKind>, Workload)> = (0..FLEET_BURST)
            .map(|i| {
                (
                    Some(DeviceKind::ALL[i % DeviceKind::ALL.len()]),
                    Workload::default_five()[i % 5],
                )
            })
            .collect();
        b.bench_items("coordinator/fleet_route_4shards", FLEET_BURST as f64, || {
            let placements = route_burst_indexed(registry.indexed(), &items);
            placements
                .iter()
                .zip(&items)
                .filter_map(|(p, (_, wl))| p.map(|p| (p, wl)))
                .map(|(p, wl)| {
                    let req = Request {
                        id: 0,
                        device: p.kind,
                        workload: *wl,
                        power_budget_w: 1e6,
                        scenario: Scenario::FederatedLearning,
                        affinity: None,
                        node: Some(p.node),
                        seed: 1,
                    };
                    ModelKey::for_request(
                        &req,
                        Strategy::for_scenario(req.scenario),
                        None,
                        100,
                        ref_fps,
                    )
                    .shard_index(SHARDS)
                })
                .sum::<usize>()
        });
    }

    // -- fleet placement at 10k nodes: the indexed engine's scale claim --
    // route_decision: one single placement decision against a 10,000-node
    // indexed snapshot (the O(1)-peek path; target < 1 µs).
    // route_10k_nodes: a 1024-item mixed burst folded through a working
    // copy of the index (one clone + 1024 O(log k) updates; target
    // single-digit ms total, so ns/item stays in the microsecond band).
    // snapshot_publish: one full heartbeat over the 10k-node registry —
    // per-node sim advance, dirty-entry index rebuild, and the dirty-gated
    // clone-and-store publication through the ArcCell; items = nodes, so
    // ns/item is the per-node republication cost.
    {
        use powertrain::fleet::{route_indexed, route_burst_indexed, FleetRegistry};
        const FLEET_10K: usize = 10_000;
        const BURST_10K: usize = 1024;
        let mut registry = FleetRegistry::synthesize(FLEET_10K, 1);
        // a heartbeat of state so headrooms differ node-to-node
        registry.heartbeat(30.0, None);
        let wl = Workload::default_five()[0];
        b.bench_items("fleet/route_decision_10k_nodes", 1.0, || {
            route_indexed(registry.indexed(), Some(DeviceKind::OrinAgx), &wl)
        });
        let items: Vec<(Option<DeviceKind>, Workload)> = (0..BURST_10K)
            .map(|i| {
                (
                    Some(DeviceKind::ALL[i % DeviceKind::ALL.len()]),
                    Workload::default_five()[i % 5],
                )
            })
            .collect();
        b.bench_items("fleet/route_10k_nodes", BURST_10K as f64, || {
            route_burst_indexed(registry.indexed(), &items)
        });
        b.bench_items("fleet/snapshot_publish_10k", FLEET_10K as f64, || {
            registry.heartbeat(30.0, None);
            registry.last_dirty()
        });
    }

    // -- load generation: schedule + mix materialization ------------------
    // One pt-loadtest run fixes its whole arrival schedule and every mix
    // draw up front (that is the determinism contract), so this is the
    // engine's entire per-run setup cost: a 60 s Poisson schedule at
    // 200 req/s (~12k events), fingerprinted, with a standard-mix draw
    // per event. items = expected events, so ns/item is the per-request
    // planning overhead — it should stay far below any serving cost.
    {
        use powertrain::loadgen::arrival::{build_schedule, schedule_fingerprint, ArrivalSpec};
        use powertrain::loadgen::Mix;
        const LOAD_EVENTS: f64 = 12_000.0; // 200 req/s x 60 s
        let spec = ArrivalSpec::parse("poisson:200").unwrap();
        let mix = Mix::standard();
        b.bench_items("loadgen/schedule_poisson200_60s", LOAD_EVENTS, || {
            let mut rng = Rng::new(42);
            let mut model = spec.build();
            let schedule = build_schedule(model.as_mut(), &mut rng, 60_000).unwrap();
            let mut draws = 0usize;
            for _ in &schedule {
                draws += mix.draw(&mut rng).deadline_ms.is_none() as usize;
            }
            (schedule_fingerprint(&schedule), draws)
        });
    }

    #[cfg(feature = "xla")]
    artifact_benches(&mut b, &ckpt, &subset, &full);

    let report = std::path::Path::new("BENCH_hotpaths.json");
    match b.save_json(report) {
        Ok(()) => println!("\nwrote {}", report.display()),
        Err(e) => eprintln!("\ncould not write {}: {e}", report.display()),
    }
    println!("\n== done ==");
}

#[cfg(feature = "xla")]
fn artifact_benches(
    b: &mut Bencher,
    ckpt: &Checkpoint,
    subset: &PowerModeGrid,
    full: &PowerModeGrid,
) {
    use powertrain::nn::leaf_shape;
    use powertrain::runtime::{f32_literal, u32_literal, Runtime};

    match Runtime::new(std::path::Path::new("artifacts")) {
        Ok(rt) => {
            // warm the executable cache explicitly so the bench isolates
            // steady-state execution
            let _ = powertrain::predict::predict_modes(&rt, ckpt, &subset.modes[..512]);
            b.bench_items("predict/artifact_4368_modes", 4_368.0, || {
                powertrain::predict::predict_modes(&rt, ckpt, &subset.modes)
                    .unwrap()
                    .len()
            });
            b.bench_items("predict/artifact_18096_modes", 18_096.0, || {
                powertrain::predict::predict_modes(&rt, ckpt, &full.modes)
                    .unwrap()
                    .len()
            });

            // one fused Adam train step
            let bsz = rt.manifest.train_batch;
            let params = ckpt.params.clone();
            let zeros = MlpParams::zeros();
            let x = vec![0.1f32; bsz * 4];
            let y = vec![0.2f32; bsz];
            let mask = vec![1.0f32; bsz];
            let mut step_rng = Rng::new(11);
            b.bench("train/fused_adam_step_b64", || {
                let mut inputs = Vec::with_capacity(29);
                for (i, leaf) in params.leaves.iter().enumerate() {
                    inputs.push(f32_literal(leaf, &leaf_shape(i)).unwrap());
                }
                for state in [&zeros, &zeros] {
                    for (i, leaf) in state.leaves.iter().enumerate() {
                        inputs.push(f32_literal(leaf, &leaf_shape(i)).unwrap());
                    }
                }
                inputs.push(f32_literal(&[1.0], &[1]).unwrap());
                inputs.push(u32_literal(&step_rng.jax_key()));
                inputs.push(f32_literal(&x, &[bsz, 4]).unwrap());
                inputs.push(f32_literal(&y, &[bsz, 1]).unwrap());
                inputs.push(f32_literal(&mask, &[bsz]).unwrap());
                rt.execute("train_mse", &inputs).unwrap().len()
            });
        }
        Err(e) => println!("(skipping artifact benches: {e})"),
    }
}
