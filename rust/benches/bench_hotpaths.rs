//! Hot-path microbenchmarks (custom harness; criterion unavailable
//! offline). These are the perf-pass targets of EXPERIMENTS.md §Perf:
//!
//!   1. full-grid prediction through the AOT `predict` artifact
//!      (the request-path bottleneck: 2 models x 4,368-18,096 modes);
//!   2. host-side fallback prediction;
//!   3. Pareto construction over grid-sized point clouds;
//!   4. simulator + profiler throughput (corpus generation);
//!   5. one fused train step through PJRT;
//!   6. grid enumeration + profiling-plan construction.

use powertrain::device::{DeviceKind, PowerModeGrid, ProfilingPlan};
use powertrain::nn::{checkpoint::Checkpoint, leaf_shape, MlpParams};
use powertrain::pareto::{ParetoFront, Point};
use powertrain::profiler::{Profiler, StandardScaler};
use powertrain::runtime::{f32_literal, u32_literal, Runtime};
use powertrain::sim::TrainerSim;
use powertrain::util::bench::Bencher;
use powertrain::util::rng::Rng;
use powertrain::workload::Workload;

fn demo_ckpt(seed: u64) -> Checkpoint {
    let mut rng = Rng::new(seed);
    Checkpoint {
        params: MlpParams::init_he(&mut rng),
        feature_scaler: StandardScaler {
            mean: vec![6.0, 1200.0, 700.0, 1700.0],
            std: vec![3.5, 600.0, 350.0, 1100.0],
        },
        target_scaler: StandardScaler { mean: vec![100.0], std: vec![40.0] },
        target: "time".into(),
        provenance: "bench".into(),
        val_loss: 0.0,
    }
}

fn main() {
    println!("== powertrain hot-path benchmarks ==\n");
    let mut b = Bencher::default();

    // -- grid + plan construction ----------------------------------------
    b.bench_items("grid/enumerate_orin_full_18096", 18_096.0, || {
        PowerModeGrid::full(DeviceKind::OrinAgx).len()
    });
    let subset = PowerModeGrid::paper_subset(DeviceKind::OrinAgx);
    b.bench_items("grid/profiling_plan_4368", 4_368.0, || {
        ProfilingPlan::build(&subset.modes).reboot_count()
    });

    // -- simulator + profiler ---------------------------------------------
    let spec = DeviceKind::OrinAgx.spec();
    let mut sim_rng = Rng::new(3);
    let sample_modes = subset.sample(32, &mut sim_rng);
    b.bench_items("sim/true_time_power_4368_modes", 4_368.0, || {
        let sim = TrainerSim::new(spec, Workload::resnet(), 1);
        let mut acc = 0.0;
        for pm in &subset.modes {
            acc += sim.true_minibatch_ms(pm) + sim.true_power_mw(pm);
        }
        acc
    });
    b.bench_items("profiler/profile_32_modes_with_telemetry", 32.0, || {
        let mut p = Profiler::new(TrainerSim::new(spec, Workload::resnet(), 2));
        p.profile_modes(&sample_modes).unwrap().len()
    });

    // -- pareto -------------------------------------------------------------
    let mut rng = Rng::new(5);
    let cloud: Vec<Point> = (0..18_096)
        .map(|_| Point {
            mode: subset.modes[rng.below(subset.len())],
            time: rng.uniform_range(10.0, 2_000.0),
            power_mw: rng.uniform_range(8_000.0, 55_000.0),
        })
        .collect();
    b.bench_items("pareto/build_18096_points", 18_096.0, || {
        ParetoFront::build(&cloud).len()
    });
    let front = ParetoFront::build(&cloud);
    b.bench_items("pareto/optimize_sweep_34_budgets", 34.0, || {
        let mut acc = 0.0;
        for bw in 17..=50 {
            if let Ok(p) = front.optimize(bw as f64 * 1000.0) {
                acc += p.time;
            }
        }
        acc
    });

    // -- prediction ----------------------------------------------------------
    let ckpt = demo_ckpt(7);
    b.bench_items("predict/host_4368_modes", 4_368.0, || {
        powertrain::predict::predict_modes_host(&ckpt, &subset.modes).len()
    });

    match Runtime::new(std::path::Path::new("artifacts")) {
        Ok(rt) => {
            // warm the executable cache explicitly so the bench isolates
            // steady-state execution
            let _ = powertrain::predict::predict_modes(&rt, &ckpt, &subset.modes[..512]);
            b.bench_items("predict/artifact_4368_modes", 4_368.0, || {
                powertrain::predict::predict_modes(&rt, &ckpt, &subset.modes)
                    .unwrap()
                    .len()
            });
            let full = PowerModeGrid::full(DeviceKind::OrinAgx);
            b.bench_items("predict/artifact_18096_modes", 18_096.0, || {
                powertrain::predict::predict_modes(&rt, &ckpt, &full.modes)
                    .unwrap()
                    .len()
            });

            // one fused Adam train step
            let bsz = rt.manifest.train_batch;
            let params = ckpt.params.clone();
            let zeros = MlpParams::zeros();
            let x = vec![0.1f32; bsz * 4];
            let y = vec![0.2f32; bsz];
            let mask = vec![1.0f32; bsz];
            let mut step_rng = Rng::new(11);
            b.bench("train/fused_adam_step_b64", || {
                let mut inputs = Vec::with_capacity(29);
                for (i, leaf) in params.leaves.iter().enumerate() {
                    inputs.push(f32_literal(leaf, &leaf_shape(i)).unwrap());
                }
                for state in [&zeros, &zeros] {
                    for (i, leaf) in state.leaves.iter().enumerate() {
                        inputs.push(f32_literal(leaf, &leaf_shape(i)).unwrap());
                    }
                }
                inputs.push(f32_literal(&[1.0], &[1]).unwrap());
                inputs.push(u32_literal(&step_rng.jax_key()));
                inputs.push(f32_literal(&x, &[bsz, 4]).unwrap());
                inputs.push(f32_literal(&y, &[bsz, 1]).unwrap());
                inputs.push(f32_literal(&mask, &[bsz]).unwrap());
                rt.execute("train_mse", &inputs).unwrap().len()
            });
        }
        Err(e) => println!("(skipping artifact benches: {e})"),
    }

    println!("\n== done ==");
}
