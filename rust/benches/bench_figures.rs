//! `cargo bench` regenerator: one reduced-scale end-to-end run per paper
//! exhibit (the full-scale versions are `powertrain experiment <id>`;
//! DESIGN.md section 6 maps exhibits to modules). Runs every experiment in
//! quick mode against a temp output dir and reports wall-clock per
//! exhibit — a regression harness for the whole reproduction pipeline.

use powertrain::experiments::{self, common::ExpContext};

fn main() {
    let out = std::env::temp_dir().join("pt_bench_figures");
    let _ = std::fs::remove_dir_all(&out);
    let artifacts = powertrain::runtime::artifacts::default_artifacts_dir();
    let mut ctx = match ExpContext::new(&artifacts, &out, true, 4242) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("cannot initialize experiment context: {e}");
            eprintln!("(run `make artifacts` first)");
            std::process::exit(1);
        }
    };

    println!("== paper-exhibit regeneration bench (quick mode) ==\n");
    let mut rows: Vec<(String, f64)> = Vec::new();
    for id in experiments::ALL {
        let t0 = std::time::Instant::now();
        match experiments::run(id, &mut ctx) {
            Ok(()) => {
                let dt = t0.elapsed().as_secs_f64();
                rows.push((id.to_string(), dt));
            }
            Err(e) => {
                eprintln!("experiment {id} FAILED: {e}");
                std::process::exit(1);
            }
        }
    }

    println!("\n== summary: seconds per exhibit ==");
    let mut total = 0.0;
    for (id, dt) in &rows {
        println!("{id:<8} {dt:>8.1}s");
        total += dt;
    }
    println!("{:<8} {total:>8.1}s", "total");
}
