//! Integration: the fleet orchestrator end-to-end in the default
//! (no-`xla`) build — registry synthesis, affinity routing, sharded
//! coordinator domains and the once-fleet-wide transfer, all driven the
//! way `powertrain serve --fleet` drives them.
//!
//! The isolation tests lean on two properties the fleet guarantees by
//! construction: model keys are hash-partitioned onto domains
//! ([`ModelKey::shard_index`]), so a storm aimed at one domain's keys
//! can be built from the outside; and nothing but the fleet-level
//! metrics is shared between domains, so the storm must not perturb a
//! single bit of any sibling's answers.

use std::sync::atomic::Ordering;
use std::sync::Arc;

use powertrain::coordinator::{
    CoordinatorConfig, ModelKey, Provenance, ReferenceModels, Request, Response, Scenario,
    Strategy,
};
use powertrain::device::{DeviceKind, PowerModeGrid};
use powertrain::fleet::{Fleet, FleetConfig, NodeHealth, NodeId};
use powertrain::profiler::Profiler;
use powertrain::sim::{FaultInjector, FaultPlan, TrainerSim};
use powertrain::util::rng::Rng;
use powertrain::workload::Workload;

/// Shared, lazily-built host reference models (same recipe as the other
/// integration suites: in-process `OnceLock`, never a stale temp dir).
fn reference() -> ReferenceModels {
    static REF: std::sync::OnceLock<ReferenceModels> = std::sync::OnceLock::new();
    REF.get_or_init(|| {
        let mut rng = Rng::new(1);
        let modes = PowerModeGrid::paper_subset(DeviceKind::OrinAgx).sample(400, &mut rng);
        let mut profiler = Profiler::new(TrainerSim::new(
            DeviceKind::OrinAgx.spec(),
            Workload::resnet(),
            1,
        ));
        let corpus = profiler.profile_modes(&modes).unwrap();
        ReferenceModels::bootstrap_host(&corpus, 60, 1).unwrap()
    })
    .clone()
}

fn fleet_cfg(shards: usize, nodes: usize) -> FleetConfig {
    FleetConfig {
        shards,
        nodes,
        coordinator: CoordinatorConfig {
            transfer_epochs: 60,
            prediction_grid: Some(400),
            workers: 1,
            ..Default::default()
        },
        ..Default::default()
    }
}

/// A fleet request with an explicit device-kind affinity. The seed is a
/// junk value on purpose: `Fleet::submit` pins it to the canonical
/// fleet seed, which is exactly what the key arithmetic below relies on.
fn request(id: u64, kind: DeviceKind, workload: Workload) -> Request {
    Request {
        id,
        device: kind,
        workload,
        power_budget_w: 1e6,
        scenario: Scenario::FederatedLearning,
        affinity: Some(kind),
        node: None,
        seed: 777,
    }
}

fn assert_bit_identical(a: &Response, b: &Response) {
    // everything but wall-clock latency must match exactly
    assert_eq!(a.id, b.id);
    assert_eq!(a.node, b.node);
    assert_eq!(a.strategy, b.strategy);
    assert_eq!(a.provenance, b.provenance);
    assert_eq!(a.chosen_mode, b.chosen_mode);
    assert_eq!(a.predicted_time_ms.to_bits(), b.predicted_time_ms.to_bits());
    assert_eq!(a.predicted_power_w.to_bits(), b.predicted_power_w.to_bits());
    assert_eq!(a.observed_time_ms.to_bits(), b.observed_time_ms.to_bits());
    assert_eq!(a.observed_power_w.to_bits(), b.observed_power_w.to_bits());
}

/// Acceptance: two runs from the same fleet seed place every request on
/// the same node and answer with bit-identical responses and counters.
#[test]
fn same_seed_fleet_runs_place_and_answer_identically() {
    let reference = reference();
    let run = || {
        let fleet = Fleet::start(fleet_cfg(4, 12), &reference).unwrap();
        let mut placements = Vec::new();
        for i in 0..10u64 {
            let kind = DeviceKind::ALL[(i % 3) as usize];
            let wl = Workload::default_five()[(i % 2) as usize];
            placements.push(fleet.submit(request(i, kind, wl)).unwrap());
        }
        (placements, fleet.finish().unwrap())
    };
    let (pa, oa) = run();
    let (pb, ob) = run();
    assert_eq!(pa, pb, "same seed ⇒ identical placements");
    assert_eq!(oa.responses.len(), 10);
    assert_eq!(oa.responses.len(), ob.responses.len());
    for (a, b) in oa.responses.iter().zip(&ob.responses) {
        assert_bit_identical(a, b);
    }
    assert_eq!(oa.fleet.routed_total(), ob.fleet.routed_total());
    assert_eq!(
        oa.fleet.cross_shard_transfers_saved.load(Ordering::Relaxed),
        ob.fleet.cross_shard_transfers_saved.load(Ordering::Relaxed),
    );
    // 3 kinds × 2 workloads = 6 keys, each transferred exactly once
    assert_eq!(oa.fleet.host_fits.load(Ordering::Relaxed), 12);
    for m in &oa.shards {
        assert_eq!(m.host_fits.load(Ordering::Relaxed), 0);
    }
}

/// Shard isolation: aim a worker-panic storm at every key owned by ONE
/// domain. The stormed domain absorbs the panics (caught, retried), and
/// the sibling domains' responses are bit-identical to an unfaulted run.
#[test]
fn storming_one_shard_leaves_siblings_bit_identical() {
    let reference = reference();
    let ref_fps = reference.fingerprints();
    let shards = 4;
    let base = fleet_cfg(shards, 12);

    // 12 distinct (kind, workload) pairs ⇒ 12 distinct model keys
    let requests: Vec<Request> = (0..12u64)
        .map(|i| {
            request(
                i,
                DeviceKind::ALL[(i % 3) as usize],
                Workload::default_five()[(i % 4) as usize],
            )
        })
        .collect();

    // replicate the fleet's own key derivation to find each request's
    // owning domain from outside (affinity is honored below, so the
    // submitted device kind survives placement)
    let shard_of = |r: &Request| {
        let mut pinned = r.clone();
        pinned.seed = base.seed;
        ModelKey::for_request(
            &pinned,
            Strategy::for_scenario(pinned.scenario),
            base.coordinator.prediction_grid,
            base.coordinator.transfer_epochs,
            ref_fps,
        )
        .shard_index(shards)
    };
    let stormed_shard = shard_of(&requests[0]);
    let stormed: Vec<u64> =
        requests.iter().filter(|r| shard_of(r) == stormed_shard).map(|r| r.id).collect();
    let quiet: Vec<u64> =
        requests.iter().filter(|r| shard_of(r) != stormed_shard).map(|r| r.id).collect();
    assert!(!quiet.is_empty(), "need sibling-domain traffic to compare");

    let run = |panic_ids: Vec<u64>| {
        let mut cfg = fleet_cfg(shards, 12);
        if !panic_ids.is_empty() {
            let plan = FaultPlan { panic_request_ids: panic_ids, ..Default::default() };
            cfg.coordinator.faults = Some(Arc::new(FaultInjector::new(plan)));
        }
        let fleet = Fleet::start(cfg, &reference).unwrap();
        for r in &requests {
            fleet.submit(r.clone()).unwrap();
        }
        fleet.finish().unwrap()
    };
    let calm = run(Vec::new());
    let stormy = run(stormed.clone());

    assert_eq!(calm.responses.len(), 12);
    assert_eq!(stormy.responses.len(), 12, "panics are caught and retried, never dropped");
    // the storm really landed: each panicking request cost (at least)
    // one retry, all of it inside the stormed domain
    let retries: u64 =
        stormy.shards.iter().map(|m| m.retries.load(Ordering::Relaxed)).sum();
    assert!(
        retries >= stormed.len() as u64,
        "expected ≥{} retries from the storm, saw {retries}",
        stormed.len()
    );
    for (s, m) in stormy.shards.iter().enumerate() {
        if s != stormed_shard {
            assert_eq!(m.retries.load(Ordering::Relaxed), 0, "storm leaked into shard {s}");
        }
    }
    // sibling domains never noticed: every non-stormed answer is
    // bit-identical to the unfaulted run (and the stormed ones recover
    // to the same answers too — the panic costs a retry, not an output)
    for id in quiet.iter().chain(&stormed) {
        let a = calm.responses.iter().find(|r| r.id == *id).unwrap();
        let b = stormy.responses.iter().find(|r| r.id == *id).unwrap();
        assert_bit_identical(a, b);
    }
}

/// Fleet chaos: a scripted per-node fan failure degrades the node after
/// its warm-up placement, so later affinity traffic reroutes away from
/// it, is surfaced as `DegradedPlacement`, and the chaos does not
/// duplicate the once-fleet-wide transfer.
#[test]
fn node_fan_off_reroutes_traffic_and_keeps_the_transfer_single() {
    let reference = reference();
    let mut cfg = fleet_cfg(2, 24);
    // node 0 (an Orin AGX: synthesis covers every kind with nodes 0-2)
    // loses its fan from t=60 s on; the registry heartbeats 30 s per
    // placement, so request 0 lands before the episode, the rest after
    let plan = FaultPlan {
        node_fan_off: vec![(0, 60.0, 1_000_000.0)],
        ..Default::default()
    };
    cfg.coordinator.faults = Some(Arc::new(FaultInjector::new(plan)));
    let fleet = Fleet::start(cfg, &reference).unwrap();

    let wl = Workload::mobilenet();
    let mut placements = Vec::new();
    for i in 0..4u64 {
        placements.push(fleet.submit(request(i, DeviceKind::OrinAgx, wl)).unwrap());
    }
    // request 0 warmed n000; request 1 found it degraded and was
    // rerouted to a healthy Orin node (the warm first choice was
    // skipped). Requests 2-3 follow the new warm node — n000's fan-off
    // headroom keeps it from being the blind ideal, so they are clean
    // placements, not reroutes.
    assert_eq!(placements[0].node, NodeId(0));
    assert!(!placements[0].rerouted);
    assert!(placements[1].rerouted, "the warm first-choice node was skipped");
    for p in &placements[1..] {
        assert_ne!(p.node, NodeId(0), "fan-off node must not take traffic");
        assert!(!p.cross_kind, "other Orin nodes exist; affinity must hold");
    }
    assert_eq!(placements[2].node, placements[1].node, "warmth follows the reroute");
    let snapshot = fleet.registry_snapshot();
    let n0 = snapshot.nodes.iter().find(|n| n.id == NodeId(0)).unwrap();
    assert_eq!(n0.kind, DeviceKind::OrinAgx);
    assert_ne!(n0.health, NodeHealth::Healthy, "fan-off must show in the registry");
    // the lock-free published index carries the same health flip (health
    // only changes inside heartbeats, which are exactly what publishes)
    let indexed = fleet.indexed_snapshot();
    indexed.check_invariants();
    let e0 = indexed.entry(NodeId(0)).expect("node 0 is indexed");
    assert_eq!(e0.health, n0.health, "published index must agree with the registry");

    let outcome = fleet.finish().unwrap();
    assert_eq!(outcome.responses.len(), 4);
    assert_eq!(outcome.responses[0].provenance, Provenance::Primary);
    assert_eq!(
        outcome.responses[1].provenance,
        Provenance::DegradedPlacement,
        "the reroute must be visible in the response provenance"
    );
    // one (kind, workload) key ⇒ one transfer (2 fits), chaos or not;
    // the 3 rerouted requests are all saved transfers
    assert_eq!(outcome.fleet.host_fits.load(Ordering::Relaxed), 2);
    for m in &outcome.shards {
        assert_eq!(m.host_fits.load(Ordering::Relaxed), 0);
    }
    assert_eq!(outcome.fleet.cross_shard_transfers_saved.load(Ordering::Relaxed), 3);
    assert_eq!(outcome.fleet.placement_rejected.load(Ordering::Relaxed), 0);
}

/// CI chaos smoke at fleet scope: the committed `faults_smoke.json`
/// plan (sensor noise, fit failures, worker panics, fan-off episodes —
/// fleet-wide and per-node) must be survivable by a 4-domain fleet:
/// every request answered, zero failures recorded anywhere.
#[test]
fn committed_smoke_plan_is_survived_by_the_fleet() {
    let reference = reference();
    let path =
        std::path::Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/tests/faults_smoke.json"));
    let plan = FaultPlan::load(path).expect("committed smoke plan parses");
    assert!(!plan.node_fan_off.is_empty(), "smoke plan must script a per-node fan failure");
    let mut cfg = fleet_cfg(4, 12);
    cfg.coordinator.faults = Some(Arc::new(FaultInjector::new(plan)));
    let fleet = Fleet::start(cfg, &reference).unwrap();
    for i in 0..9u64 {
        let kind = DeviceKind::ALL[(i % 3) as usize];
        let wl = Workload::default_five()[(i % 2) as usize];
        fleet.submit(request(i, kind, wl)).unwrap();
    }
    let outcome = fleet.finish().unwrap();
    assert_eq!(outcome.responses.len(), 9, "every request must be answered under chaos");
    for (s, m) in outcome.shards.iter().enumerate() {
        assert_eq!(
            m.requests_failed.load(Ordering::Relaxed),
            0,
            "shard {s} failures: {:?}",
            m.failed_requests()
        );
    }
    assert_eq!(outcome.fleet.routed_total(), 9);
}
