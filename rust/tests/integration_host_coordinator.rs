//! Integration: the serving coordinator end-to-end in the *default*
//! (no-`xla`) build — the acceptance path of the host-native training
//! subsystem. Real host-bootstrapped reference models, then
//! `Strategy::PowerTrain(50)` served entirely on host: online profiling
//! → host transfer of both targets → grid prediction → in-budget Pareto
//! recommendation, with the transferred planes flowing through the
//! shared `PlaneCache`.
//!
//! Scales are reduced (hundreds of reference modes, tens of epochs) to
//! keep `cargo test` fast; the bench + examples run larger versions.

use std::sync::atomic::Ordering;

use powertrain::coordinator::{
    handle_request_host, serve, CoordinatorConfig, Metrics, PlaneCache, ReferenceModels,
    Request, Scenario,
};
use powertrain::device::{DeviceKind, PowerModeGrid};
use powertrain::profiler::Profiler;
use powertrain::sim::TrainerSim;
use powertrain::util::rng::Rng;
use powertrain::workload::Workload;

/// Shared, lazily-built host reference models: trained once per test
/// binary run via `OnceLock` (in-process, not a temp-dir cache, so a
/// numerics change in `HostTrainer` can never serve stale checkpoints
/// from an earlier run).
fn reference() -> ReferenceModels {
    static REF: std::sync::OnceLock<ReferenceModels> = std::sync::OnceLock::new();
    REF.get_or_init(|| {
        let mut rng = Rng::new(1);
        let modes = PowerModeGrid::paper_subset(DeviceKind::OrinAgx).sample(400, &mut rng);
        let mut profiler = Profiler::new(TrainerSim::new(
            DeviceKind::OrinAgx.spec(),
            Workload::resnet(),
            1,
        ));
        let corpus = profiler.profile_modes(&modes).unwrap();
        ReferenceModels::bootstrap_host(&corpus, 60, 1).unwrap()
    })
    .clone()
}

fn test_cfg() -> CoordinatorConfig {
    CoordinatorConfig {
        transfer_epochs: 60,
        prediction_grid: Some(400),
        workers: 1,
        ..Default::default()
    }
}

#[test]
fn powertrain_request_end_to_end_on_host() {
    let reference = reference();
    let metrics = Metrics::new();
    let cache = PlaneCache::new();
    let req = Request {
        id: 1,
        device: DeviceKind::OrinAgx,
        workload: Workload::mobilenet(),
        power_budget_w: 30.0,
        scenario: Scenario::FederatedLearning,
        affinity: None,
        node: None,
        seed: 11,
    };
    let resp = handle_request_host(&cache, &reference, &test_cfg(), &metrics, &req).unwrap();
    assert_eq!(resp.strategy, "powertrain-50(host)");
    assert!(resp.predicted_power_w <= 30.0 + 1e-9, "prediction violates budget");
    // a genuinely transfer-learned power model keeps the *observed*
    // power near the budget too, not wildly above it (tolerance a bit
    // looser than the artifact suite: reduced reference/transfer scales)
    assert!(
        resp.observed_power_w <= 30.0 * 1.35,
        "observed {:.1} W >> budget",
        resp.observed_power_w
    );
    assert!(resp.observed_time_ms > 0.0);
    assert!(resp.profiling_cost_s > 0.0, "transfer profiling must be accounted");
    assert_eq!(metrics.modes_profiled.load(Ordering::Relaxed), 50);
    assert_eq!(metrics.host_fits.load(Ordering::Relaxed), 2);
    assert_eq!(metrics.requests_completed.load(Ordering::Relaxed), 1);
}

#[test]
fn cross_device_host_request_uses_device_grid() {
    let reference = reference();
    let metrics = Metrics::new();
    let cache = PlaneCache::new();
    let req = Request {
        id: 2,
        device: DeviceKind::OrinNano,
        workload: Workload::mobilenet(),
        power_budget_w: 10.0,
        scenario: Scenario::ContinuousLearning,
        affinity: None,
        node: None,
        seed: 12,
    };
    let cfg = CoordinatorConfig { prediction_grid: None, ..test_cfg() };
    let resp = handle_request_host(&cache, &reference, &cfg, &metrics, &req).unwrap();
    // the chosen mode must be valid on the Nano
    resp.chosen_mode.validate(DeviceKind::OrinNano.spec()).unwrap();
    assert!(resp.observed_power_w < 15.0);
}

#[test]
fn infeasible_budget_reported_as_error_on_host() {
    let reference = reference();
    let metrics = Metrics::new();
    let cache = PlaneCache::new();
    let req = Request {
        id: 3,
        device: DeviceKind::OrinAgx,
        workload: Workload::bert(),
        power_budget_w: 2.0, // below idle power
        scenario: Scenario::FederatedLearning,
        affinity: None,
        node: None,
        seed: 13,
    };
    assert!(handle_request_host(&cache, &reference, &test_cfg(), &metrics, &req).is_err());
}

#[test]
fn host_serve_mixes_strategies_and_reports_metrics() {
    let reference = reference();
    let cfg = CoordinatorConfig { workers: 2, ..test_cfg() };
    let requests: Vec<Request> = (0..4)
        .map(|i| Request {
            id: i,
            device: DeviceKind::OrinAgx,
            workload: if i % 2 == 0 { Workload::mobilenet() } else { Workload::lstm() },
            power_budget_w: 30.0 + 5.0 * i as f64,
            scenario: if i == 3 { Scenario::FineTuning } else { Scenario::FederatedLearning },
            affinity: None,
            node: None,
            seed: 100 + (i % 2), // two distinct (workload, seed) pairs repeat
        })
        .collect();
    let (responses, metrics) = serve(&cfg, &reference, requests).unwrap();
    assert_eq!(responses.len(), 4);
    let mut ids: Vec<u64> = responses.iter().map(|r| r.id).collect();
    ids.sort_unstable();
    assert_eq!(ids, vec![0, 1, 2, 3]);
    assert_eq!(metrics.requests_completed.load(Ordering::Relaxed), 4);
    for r in &responses {
        let strat = &r.strategy;
        assert!(
            strat == "powertrain-50(host)" || strat == "nn-100(host)",
            "unexpected strategy {strat}"
        );
        assert!(r.predicted_power_w <= 30.0 + 5.0 * r.id as f64 + 1e-9);
    }
    let (p50, _, _) = metrics.latency_summary_ms();
    assert!(p50 > 0.0);
    // the render string surfaces the new counters
    let rendered = metrics.render();
    assert!(rendered.contains("host fits"), "{rendered}");
    assert!(rendered.contains("model cache"), "{rendered}");
}
