//! Integration: the rust PJRT runtime loads the real AOT artifacts and its
//! numerics agree with the pure-rust reference forward pass.
//!
//! Requires `make artifacts` (the Makefile test target guarantees this).

#![cfg(feature = "xla")]

use powertrain::nn::{checkpoint::Checkpoint, host_mlp, leaf_shape, MlpParams};
use powertrain::profiler::StandardScaler;
use powertrain::runtime::{f32_literal, to_f32_scalar, to_f32_vec, u32_literal, Runtime};
use powertrain::util::rng::Rng;

fn runtime() -> Runtime {
    Runtime::new(std::path::Path::new("artifacts")).expect("run `make artifacts` first")
}

fn demo_params(seed: u64) -> MlpParams {
    let mut rng = Rng::new(seed);
    MlpParams::init_he(&mut rng)
}

fn random_x(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.normal() as f32).collect()
}

#[test]
fn manifest_describes_all_four_artifacts() {
    let rt = runtime();
    for name in ["predict", "evaluate", "train_mse", "train_mape"] {
        assert!(rt.manifest.artifact(name).is_ok(), "missing {name}");
    }
    assert_eq!(rt.manifest.input_dim, 4);
    assert_eq!(rt.manifest.hidden, vec![256, 128, 64]);
    assert_eq!(rt.manifest.predict_batch, 512);
    assert_eq!(rt.manifest.train_batch, 64);
}

#[test]
fn predict_artifact_matches_host_forward() {
    let rt = runtime();
    let params = demo_params(1);
    let mut rng = Rng::new(2);
    let bsz = rt.manifest.predict_batch;
    let x = random_x(&mut rng, bsz * 4);
    let (y_mean, y_std) = (120.0f32, 35.0f32);

    let mut inputs = Vec::new();
    for (i, leaf) in params.leaves.iter().enumerate() {
        inputs.push(f32_literal(leaf, &leaf_shape(i)).unwrap());
    }
    inputs.push(f32_literal(&x, &[bsz, 4]).unwrap());
    inputs.push(f32_literal(&[y_mean], &[]).unwrap());
    inputs.push(f32_literal(&[y_std], &[]).unwrap());

    let outs = rt.execute("predict", &inputs).unwrap();
    assert_eq!(outs.len(), 1);
    let preds = to_f32_vec(&outs[0]).unwrap();
    assert_eq!(preds.len(), bsz);

    for row in (0..bsz).step_by(37) {
        let feats = [x[row * 4], x[row * 4 + 1], x[row * 4 + 2], x[row * 4 + 3]];
        let want = host_mlp::forward_one(&params, &feats) * y_std + y_mean;
        let got = preds[row];
        assert!(
            (got - want).abs() <= 1e-3 * want.abs().max(1.0),
            "row {row}: artifact {got} vs host {want}"
        );
    }
}

#[test]
fn executable_cache_compiles_once() {
    let rt = runtime();
    assert_eq!(rt.cached_executables(), 0);
    let params = demo_params(3);
    let bsz = rt.manifest.predict_batch;
    let x = vec![0.0f32; bsz * 4];
    let mk_inputs = |params: &MlpParams| {
        let mut v = Vec::new();
        for (i, leaf) in params.leaves.iter().enumerate() {
            v.push(f32_literal(leaf, &leaf_shape(i)).unwrap());
        }
        v.push(f32_literal(&x, &[bsz, 4]).unwrap());
        v.push(f32_literal(&[0.0f32], &[]).unwrap());
        v.push(f32_literal(&[1.0f32], &[]).unwrap());
        v
    };
    rt.execute("predict", &mk_inputs(&params)).unwrap();
    assert_eq!(rt.cached_executables(), 1);
    rt.execute("predict", &mk_inputs(&params)).unwrap();
    assert_eq!(rt.cached_executables(), 1);
}

#[test]
fn execute_validates_input_arity_and_shape() {
    let rt = runtime();
    // wrong arity
    assert!(rt.execute("predict", &[]).map(|_| ()).is_err());
    // wrong shape on one input
    let params = demo_params(4);
    let mut inputs = Vec::new();
    for (i, leaf) in params.leaves.iter().enumerate() {
        inputs.push(f32_literal(leaf, &leaf_shape(i)).unwrap());
    }
    inputs.push(f32_literal(&[0.0f32; 8], &[2, 4]).unwrap()); // batch 2 != 512
    inputs.push(f32_literal(&[0.0f32], &[]).unwrap());
    inputs.push(f32_literal(&[1.0f32], &[]).unwrap());
    let err = match rt.execute("predict", &inputs) {
        Err(e) => e,
        Ok(_) => panic!("shape mismatch accepted"),
    };
    assert!(err.to_string().contains("elements"));
}

#[test]
fn unknown_artifact_is_reported() {
    let rt = runtime();
    let err = match rt.execute("nonexistent", &[]) {
        Err(e) => e,
        Ok(_) => panic!("unknown artifact accepted"),
    };
    assert!(err.to_string().contains("nonexistent"));
}

#[test]
fn train_mse_step_descends_and_preserves_shapes() {
    let rt = runtime();
    let params = demo_params(5);
    let mut rng = Rng::new(6);
    let bsz = rt.manifest.train_batch;

    let x = random_x(&mut rng, bsz * 4);
    // learnable target: y = 0.3 * sum(x)
    let y: Vec<f32> = (0..bsz)
        .map(|r| 0.3 * (x[r * 4] + x[r * 4 + 1] + x[r * 4 + 2] + x[r * 4 + 3]))
        .collect();
    let mask = vec![1.0f32; bsz];

    let mut p = params;
    let mut m = MlpParams::zeros();
    let mut v = MlpParams::zeros();
    let mut first_loss = None;
    let mut last_loss = 0.0;
    for t in 1..=60 {
        let mut inputs = Vec::new();
        for (i, leaf) in p.leaves.iter().enumerate() {
            inputs.push(f32_literal(leaf, &leaf_shape(i)).unwrap());
        }
        for state in [&m, &v] {
            for (i, leaf) in state.leaves.iter().enumerate() {
                inputs.push(f32_literal(leaf, &leaf_shape(i)).unwrap());
            }
        }
        inputs.push(f32_literal(&[t as f32], &[1]).unwrap());
        inputs.push(u32_literal(&rng.jax_key()));
        inputs.push(f32_literal(&x, &[bsz, 4]).unwrap());
        inputs.push(f32_literal(&y, &[bsz, 1]).unwrap());
        inputs.push(f32_literal(&mask, &[bsz]).unwrap());

        let outs = rt.execute("train_mse", &inputs).unwrap();
        assert_eq!(outs.len(), 25);
        for i in 0..8 {
            p.leaves[i] = to_f32_vec(&outs[i]).unwrap();
            m.leaves[i] = to_f32_vec(&outs[8 + i]).unwrap();
            v.leaves[i] = to_f32_vec(&outs[16 + i]).unwrap();
        }
        let loss = to_f32_scalar(&outs[24]).unwrap();
        if first_loss.is_none() {
            first_loss = Some(loss);
        }
        last_loss = loss;
    }
    assert!(p.is_finite());
    assert!(
        last_loss < 0.6 * first_loss.unwrap(),
        "no descent: {first_loss:?} -> {last_loss}"
    );
}

#[test]
fn evaluate_artifact_matches_host_mse() {
    let rt = runtime();
    let params = demo_params(7);
    let mut rng = Rng::new(8);
    let bsz = rt.manifest.predict_batch;
    let x = random_x(&mut rng, bsz * 4);
    // targets = host predictions + 2.0 -> mse must be 4.0
    let y_std_t: Vec<f32> = (0..bsz)
        .map(|r| {
            let feats = [x[r * 4], x[r * 4 + 1], x[r * 4 + 2], x[r * 4 + 3]];
            host_mlp::forward_one(&params, &feats) + 2.0
        })
        .collect();
    let y_raw = vec![100.0f32; bsz];
    let mask = vec![1.0f32; bsz];

    let mut inputs = Vec::new();
    for (i, leaf) in params.leaves.iter().enumerate() {
        inputs.push(f32_literal(leaf, &leaf_shape(i)).unwrap());
    }
    inputs.push(f32_literal(&x, &[bsz, 4]).unwrap());
    let y_col: Vec<f32> = y_std_t.clone();
    inputs.push(f32_literal(&y_col, &[bsz, 1]).unwrap());
    inputs.push(f32_literal(&y_raw, &[bsz, 1]).unwrap());
    inputs.push(f32_literal(&mask, &[bsz]).unwrap());
    inputs.push(f32_literal(&[0.0f32], &[]).unwrap());
    inputs.push(f32_literal(&[1.0f32], &[]).unwrap());

    let outs = rt.execute("evaluate", &inputs).unwrap();
    let mse = to_f32_scalar(&outs[0]).unwrap();
    assert!((mse - 4.0).abs() < 1e-2, "mse={mse}");
}

#[test]
fn checkpointed_model_predicts_identically_through_artifact() {
    // save -> load -> predict via artifact == predict via host
    let rt = runtime();
    let mut rng = Rng::new(9);
    let ckpt = Checkpoint {
        params: MlpParams::init_he(&mut rng),
        feature_scaler: StandardScaler {
            mean: vec![6.0, 1000.0, 700.0, 2000.0],
            std: vec![3.5, 600.0, 350.0, 1100.0],
        },
        target_scaler: StandardScaler { mean: vec![80.0], std: vec![30.0] },
        target: "time".into(),
        provenance: "integration".into(),
        val_loss: 0.0,
    };
    let dir = std::env::temp_dir().join("pt_rt_ckpt");
    let path = dir.join("ck.json");
    ckpt.save(&path).unwrap();
    let loaded = Checkpoint::load(&path).unwrap();

    let grid = powertrain::device::PowerModeGrid::paper_subset(
        powertrain::device::DeviceKind::OrinAgx,
    );
    let modes = &grid.modes[..700];
    let via_artifact = powertrain::predict::predict_modes(&rt, &loaded, modes).unwrap();
    let via_host = powertrain::predict::predict_modes_host(&loaded, modes);
    assert_eq!(via_artifact.len(), 700);
    for i in (0..700).step_by(53) {
        assert!(
            (via_artifact[i] - via_host[i]).abs() < 1e-2 * via_host[i].abs().max(1.0),
            "i={i}: {} vs {}",
            via_artifact[i],
            via_host[i]
        );
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Regression test for the input-buffer leak in xla 0.1.6's `execute` C
/// wrapper (buffers were `release()`d and never freed; the runtime now
/// routes through `execute_b` with self-managed buffers). 600 train-step
/// executions move ~350 MB of inputs; RSS must stay nearly flat.
#[test]
fn executions_do_not_leak_input_buffers() {
    fn rss_kb() -> u64 {
        let status = std::fs::read_to_string("/proc/self/status").unwrap();
        status
            .lines()
            .find(|l| l.starts_with("VmRSS"))
            .and_then(|l| l.split_whitespace().nth(1))
            .and_then(|v| v.parse().ok())
            .unwrap_or(0)
    }

    let rt = runtime();
    let params = demo_params(21);
    let m = MlpParams::zeros();
    let v = MlpParams::zeros();
    let mut rng = Rng::new(22);
    let bsz = rt.manifest.train_batch;
    let x = vec![0.1f32; bsz * 4];
    let y = vec![0.2f32; bsz];
    let mask = vec![1.0f32; bsz];

    let run_step = |t: u64, rng: &mut Rng| {
        let mut inputs = Vec::with_capacity(29);
        for state in [&params, &m, &v] {
            for (i, leaf) in state.leaves.iter().enumerate() {
                inputs.push(f32_literal(leaf, &leaf_shape(i)).unwrap());
            }
        }
        inputs.push(f32_literal(&[t as f32], &[1]).unwrap());
        inputs.push(u32_literal(&rng.jax_key()));
        inputs.push(f32_literal(&x, &[bsz, 4]).unwrap());
        inputs.push(f32_literal(&y, &[bsz, 1]).unwrap());
        inputs.push(f32_literal(&mask, &[bsz]).unwrap());
        rt.execute("train_mse", &inputs).unwrap();
    };

    // warmup: compile + allocator steady state
    for t in 1..=50 {
        run_step(t, &mut rng);
    }
    let before = rss_kb();
    for t in 51..=650 {
        run_step(t, &mut rng);
    }
    let after = rss_kb();
    let grown_mb = (after.saturating_sub(before)) as f64 / 1024.0;
    // the old leak grew ~0.55 MB/step (~330 MB here); allow generous jitter
    assert!(
        grown_mb < 60.0,
        "RSS grew {grown_mb:.0} MB over 600 executions — input buffers leaking again?"
    );
}
