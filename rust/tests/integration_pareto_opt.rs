//! Integration: Pareto construction + optimization on real simulator
//! ground truth, including the baselines' qualitative behaviour.

use powertrain::baselines;
use powertrain::device::{DeviceKind, PowerModeGrid};
use powertrain::pareto::{ParetoFront, Point};
use powertrain::profiler::{Corpus, Record};
use powertrain::sim::TrainerSim;
use powertrain::util::rng::Rng;
use powertrain::workload::Workload;

fn truth_points(wl: Workload, seed: u64) -> (Vec<Point>, Corpus) {
    let spec = DeviceKind::OrinAgx.spec();
    let sim = TrainerSim::new(spec, wl, seed);
    let grid = PowerModeGrid::paper_subset(DeviceKind::OrinAgx);
    let mut corpus = Corpus::new(DeviceKind::OrinAgx, wl);
    let pts: Vec<Point> = grid
        .modes
        .iter()
        .map(|pm| {
            let t = sim.true_minibatch_ms(pm);
            let p = sim.true_power_mw(pm);
            corpus.push(Record { mode: *pm, time_ms: t, power_mw: p, cost_s: 1.0 });
            Point { mode: *pm, time: t, power_mw: p }
        })
        .collect();
    (pts, corpus)
}

#[test]
fn ground_truth_front_spans_budget_range() {
    let (pts, _) = truth_points(Workload::resnet(), 1);
    let front = ParetoFront::build(&pts);
    assert!(front.is_valid());
    assert!(front.len() > 10, "front too sparse: {}", front.len());
    // the paper sweeps 17..50 W: every budget in that range is feasible
    for b in 17..=50 {
        let sol = front.optimize(b as f64 * 1000.0).unwrap();
        assert!(sol.power_mw <= b as f64 * 1000.0);
    }
}

#[test]
fn optimal_time_decreases_with_budget() {
    let (pts, _) = truth_points(Workload::mobilenet(), 2);
    let front = ParetoFront::build(&pts);
    let mut last = f64::INFINITY;
    for b in 15..=50 {
        if let Ok(sol) = front.optimize(b as f64 * 1000.0) {
            assert!(sol.time <= last + 1e-9, "budget {b}: time went up");
            last = sol.time;
        }
    }
}

#[test]
fn maxn_fastest_but_over_budget() {
    let (pts, _) = truth_points(Workload::resnet(), 3);
    let front = ParetoFront::build(&pts);
    let spec = DeviceKind::OrinAgx.spec();
    let sim = TrainerSim::new(spec, Workload::resnet(), 3);
    let maxn = baselines::maxn_choice(spec);
    let maxn_time = sim.true_minibatch_ms(&maxn);
    let maxn_power = sim.true_power_mw(&maxn);
    // fastest overall...
    let opt30 = front.optimize(30_000.0).unwrap();
    assert!(maxn_time <= opt30.time);
    // ...but violates a 30 W budget (paper: 51.1 W at MAXN)
    assert!(maxn_power > 30_000.0);
}

#[test]
fn random_sampling_is_slower_than_true_optimum() {
    // RND-50's observed Pareto can't cover the grid: across budgets it
    // must be >= optimal, and noticeably slower on average (paper: 12-28%)
    let (pts, corpus) = truth_points(Workload::mobilenet(), 4);
    let truth = ParetoFront::build(&pts);
    let mut rng = Rng::new(4);
    let rnd = baselines::random_sampling_front(&corpus.sample(50, &mut rng));
    let mut penalties = Vec::new();
    for b in 17..=50 {
        let budget = b as f64 * 1000.0;
        let (Ok(opt), Ok(got)) = (truth.optimize(budget), rnd.optimize(budget)) else {
            continue;
        };
        assert!(got.time >= opt.time - 1e-9, "rnd beat the optimum?!");
        penalties.push(100.0 * (got.time - opt.time) / opt.time);
    }
    let mean_penalty = powertrain::util::stats::mean(&penalties);
    assert!(
        mean_penalty > 2.0,
        "random sampling suspiciously good: {mean_penalty:.1}%"
    );
}

#[test]
fn linreg_baseline_produces_finite_but_poor_fit() {
    let (_, corpus) = truth_points(Workload::resnet(), 5);
    let model = baselines::linreg::Ridge::fit(&corpus, powertrain::train::Target::Time, 1e-6);
    let mut apes = Vec::new();
    for r in corpus.records().iter().step_by(11) {
        let pred = model.predict(&r.mode.features());
        assert!(pred.is_finite());
        apes.push(((pred - r.time_ms) / r.time_ms).abs() * 100.0);
    }
    let mape = powertrain::util::stats::mean(&apes);
    // the paper's motivation for NNs: linear models are inadequate
    assert!(mape > 15.0, "linreg too good: {mape:.1}%");
}

#[test]
fn infeasible_budget_is_an_error_not_a_panic() {
    let (pts, _) = truth_points(Workload::bert(), 6);
    let front = ParetoFront::build(&pts);
    assert!(front.optimize(1_000.0).is_err()); // 1 W: nothing fits
}
