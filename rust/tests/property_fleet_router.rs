//! Differential property suite: the indexed placement engine vs the
//! linear reference oracle.
//!
//! The indexed router (`fleet::index`) must be **bit-identical** to the
//! retained linear scan (`fleet::reference`) — same snapshot, same
//! request ⇒ same `Placement`, including the `rerouted`/`cross_kind`
//! provenance flags. These tests storm randomized registries (mixed
//! kinds, organic health flips from scripted fan-off episodes, forced
//! health/saturation states, warm sets, placement churn) through both
//! implementations, asserting equal placement sequences and re-checking
//! the index's structural invariants after every mutation.

use powertrain::device::DeviceKind;
use powertrain::fleet::index::{route_burst_indexed, route_indexed, IndexedSnapshot};
use powertrain::fleet::reference;
use powertrain::fleet::registry::{FleetRegistry, NodeHealth, NodeId, RegistrySnapshot};
use powertrain::sim::{FaultInjector, FaultPlan};
use powertrain::util::rng::Rng;
use powertrain::workload::Workload;

const AFFINITIES: [Option<DeviceKind>; 4] = [
    None,
    Some(DeviceKind::OrinAgx),
    Some(DeviceKind::XavierAgx),
    Some(DeviceKind::OrinNano),
];

/// Every affinity × workload probe must agree between the two routers.
fn assert_routes_agree(legacy: &RegistrySnapshot, indexed: &IndexedSnapshot, ctx: &str) {
    for affinity in AFFINITIES {
        for wl in Workload::default_five() {
            let oracle = reference::route(legacy, affinity, &wl);
            let fast = route_indexed(indexed, affinity, &wl);
            assert_eq!(
                oracle,
                fast,
                "routers diverged ({ctx}) at affinity {affinity:?}, workload {}",
                wl.name()
            );
        }
    }
}

fn random_items(rng: &mut Rng, n: usize) -> Vec<(Option<DeviceKind>, Workload)> {
    (0..n)
        .map(|_| {
            (
                AFFINITIES[rng.below(AFFINITIES.len())],
                Workload::default_five()[rng.below(5)],
            )
        })
        .collect()
}

/// Storm live registries: random sizes and seeds, scripted fan-off
/// episodes flipping health organically, placement churn from the
/// routed decisions themselves. After every mutation the incremental
/// index must stay structurally sound and route exactly like the
/// oracle; periodic bursts must match end-to-end.
#[test]
fn storming_registries_keeps_routers_bit_identical() {
    for seed in 0..8u64 {
        let mut rng = Rng::new(0x90f7_0000 ^ seed);
        let n_nodes = 1 + rng.below(48);
        let mut reg = FleetRegistry::synthesize(n_nodes, seed);
        // a handful of scripted per-node fan-off episodes scattered
        // through the run makes heartbeats flip health organically
        let episodes: Vec<(u32, f64, f64)> = (0..rng.below(4))
            .map(|_| {
                let node = rng.below(n_nodes) as u32;
                let start = rng.uniform_range(0.0, 300.0);
                (node, start, start + rng.uniform_range(30.0, 200.0))
            })
            .collect();
        let inj = FaultInjector::new(FaultPlan { node_fan_off: episodes, ..Default::default() });

        for step in 0..60 {
            match rng.below(3) {
                0 => reg.heartbeat(rng.uniform_range(5.0, 60.0), Some(&inj)),
                1 => {
                    let node = NodeId(rng.below(n_nodes) as u32);
                    reg.note_placement(node, Workload::default_five()[rng.below(5)]);
                }
                _ => {
                    // route like the fleet does and account the decision
                    let affinity = AFFINITIES[rng.below(AFFINITIES.len())];
                    let wl = Workload::default_five()[rng.below(5)];
                    if let Some(p) = route_indexed(reg.indexed(), affinity, &wl) {
                        reg.note_placement(p.node, wl);
                    }
                }
            }
            reg.indexed().check_invariants();
            assert_routes_agree(&reg.snapshot(), reg.indexed(), &format!("seed {seed} step {step}"));
            if step % 20 == 19 {
                let items = random_items(&mut rng, 32);
                assert_eq!(
                    reference::route_burst(&reg.snapshot(), &items),
                    route_burst_indexed(reg.indexed(), &items),
                    "burst diverged at seed {seed} step {step}"
                );
            }
        }
    }
}

/// Force the states heartbeats only reach slowly: arbitrary health
/// mixes (including every node Down), saturated and over-saturated
/// loads, dense warm sets. The legacy snapshot is mutated directly and
/// the index mirrored through its mutation API, so this also exercises
/// `set_health`/`set_load`/`apply_placement` paths and their invariant
/// maintenance.
#[test]
fn forced_health_and_saturation_states_stay_bit_identical() {
    const HEALTHS: [NodeHealth; 3] = [NodeHealth::Healthy, NodeHealth::Degraded, NodeHealth::Down];
    for seed in 0..8u64 {
        let mut rng = Rng::new(0x90f7_1000 ^ seed);
        let n_nodes = 1 + rng.below(32);
        let reg = FleetRegistry::synthesize(n_nodes, seed);
        let mut legacy = reg.snapshot();
        let mut indexed = IndexedSnapshot::from_registry_snapshot(&legacy);
        // seed the interner with every workload so warm mutations below
        // never have to extend it mid-mirror
        for wl in Workload::default_five() {
            indexed.intern(wl);
        }

        for step in 0..80 {
            let i = rng.below(n_nodes);
            let id = NodeId(i as u32);
            match rng.below(3) {
                0 => {
                    let health = HEALTHS[rng.below(3)];
                    legacy.nodes[i].health = health;
                    indexed.set_health(id, health);
                }
                1 => {
                    // 0..=capacity+1 covers empty, partial, saturated and
                    // over-saturated (free_slots saturates at zero)
                    let load = rng.below(legacy.nodes[i].capacity as usize + 2) as u32;
                    legacy.nodes[i].load = load;
                    indexed.set_load(id, load);
                }
                _ => {
                    let wl = Workload::default_five()[rng.below(5)];
                    let node = &mut legacy.nodes[i];
                    node.load = node.load.saturating_add(1);
                    if !node.warm.contains(&wl) {
                        node.warm.push(wl);
                    }
                    indexed.apply_placement(id, wl);
                }
            }
            indexed.check_invariants();
            assert_routes_agree(&legacy, &indexed, &format!("forced seed {seed} step {step}"));
        }

        // the endgame: every node down ⇒ both refuse every request
        for i in 0..n_nodes {
            legacy.nodes[i].health = NodeHealth::Down;
            indexed.set_health(NodeId(i as u32), NodeHealth::Down);
        }
        indexed.check_invariants();
        for affinity in AFFINITIES {
            for wl in Workload::default_five() {
                assert_eq!(reference::route(&legacy, affinity, &wl), None);
                assert_eq!(route_indexed(&indexed, affinity, &wl), None);
            }
        }
    }
}

/// One fleet-scale spot check: a 2048-node registry and a 256-item
/// burst must fold identically through both implementations.
#[test]
fn large_fleet_burst_matches_oracle() {
    let mut rng = Rng::new(0x90f7_2048);
    let reg = FleetRegistry::synthesize(2048, 17);
    let items = random_items(&mut rng, 256);
    let oracle = reference::route_burst(&reg.snapshot(), &items);
    let fast = route_burst_indexed(reg.indexed(), &items);
    assert_eq!(oracle, fast);
    assert!(fast.iter().all(Option::is_some), "a healthy 2048-node fleet places everything");
}
