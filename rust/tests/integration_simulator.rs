//! Integration: cross-module simulator behaviour — the learning problem
//! the simulator poses must have the structure the paper describes.

use powertrain::device::{DeviceKind, PowerMode, PowerModeGrid};
use powertrain::sim::perf_model::{epoch_time_s, minibatch_time_ms};
use powertrain::sim::power_model::steady_power_mw;
use powertrain::sim::TrainerSim;
use powertrain::util::stats;
use powertrain::workload::Workload;

#[test]
fn pareto_tradeoff_exists_for_every_workload() {
    // lowering power must genuinely cost time: across the subset grid the
    // correlation between time and power is clearly negative
    let spec = DeviceKind::OrinAgx.spec();
    let grid = PowerModeGrid::paper_subset(DeviceKind::OrinAgx);
    for wl in Workload::default_five() {
        let mut times = Vec::new();
        let mut powers = Vec::new();
        for pm in grid.modes.iter().step_by(7) {
            times.push(minibatch_time_ms(spec, &wl, pm).total_ms);
            powers.push(steady_power_mw(spec, &wl, pm));
        }
        let corr = stats::pearson(&times, &powers);
        assert!(corr < -0.2, "{}: time/power corr {corr:.2}", wl.name());
    }
}

#[test]
fn workload_rankings_differ_across_modes() {
    // the non-transferable part the 50-sample fine-tune must learn: the
    // ratio between workloads' times is mode-dependent (bottleneck switch)
    let spec = DeviceKind::OrinAgx.spec();
    let fast = PowerMode::maxn(spec);
    let slow_cpu = PowerMode { cores: 2, cpu_khz: spec.cpu_khz[4], gpu_khz: spec.max_gpu_khz(), mem_khz: spec.max_mem_khz() };
    let r = |wl: &Workload, pm: &PowerMode| minibatch_time_ms(spec, wl, pm).total_ms;
    let ratio_fast = r(&Workload::mobilenet(), &fast) / r(&Workload::resnet(), &fast);
    let ratio_slow = r(&Workload::mobilenet(), &slow_cpu) / r(&Workload::resnet(), &slow_cpu);
    assert!(
        (ratio_fast - ratio_slow).abs() > 0.3,
        "ratios too similar: {ratio_fast:.2} vs {ratio_slow:.2}"
    );
}

#[test]
fn cross_device_epoch_ordering() {
    // Orin < Nano always; Xavier between; per the paper's device classes
    let maxn = |k: DeviceKind| PowerMode::maxn(k.spec());
    for wl in [Workload::resnet(), Workload::mobilenet()] {
        let orin = epoch_time_s(DeviceKind::OrinAgx.spec(), &wl, &maxn(DeviceKind::OrinAgx));
        let xavier = epoch_time_s(DeviceKind::XavierAgx.spec(), &wl, &maxn(DeviceKind::XavierAgx));
        let nano = epoch_time_s(DeviceKind::OrinNano.spec(), &wl, &maxn(DeviceKind::OrinNano));
        assert!(orin < xavier && xavier < nano, "{}: {orin:.0} {xavier:.0} {nano:.0}", wl.name());
    }
}

#[test]
fn telemetry_statistics_track_ground_truth_across_grid() {
    let spec = DeviceKind::OrinAgx.spec();
    let grid = PowerModeGrid::paper_subset(DeviceKind::OrinAgx);
    let mut sim = TrainerSim::new(spec, Workload::resnet(), 77);
    let mut worst_t: f64 = 0.0;
    for pm in grid.modes.iter().step_by(397) {
        let run = sim.profile_mode(pm, 41);
        let clean = &run.minibatch_ms[1..];
        let truth = sim.true_minibatch_ms(pm);
        let err = (stats::mean(clean) - truth).abs() / truth;
        worst_t = worst_t.max(err);
    }
    assert!(worst_t < 0.03, "worst clean-minibatch error {worst_t:.3}");
}

#[test]
fn throttling_fault_slows_minibatches() {
    use powertrain::sim::FaultConfig;
    let spec = DeviceKind::OrinAgx.spec();
    let pm = PowerMode { cores: 8, cpu_khz: spec.cpu_khz[20], gpu_khz: spec.gpu_khz[8], mem_khz: spec.mem_khz[3] };
    let clean = TrainerSim::new(spec, Workload::resnet(), 5).profile_mode(&pm, 100);
    let faulty = TrainerSim::new(spec, Workload::resnet(), 5)
        .with_faults(FaultConfig {
            throttle_factor: Some(0.5),
            throttle_after_s: 2.0,
            ..Default::default()
        })
        .profile_mode(&pm, 100);
    let late_clean = stats::mean(&clean.minibatch_ms[80..]);
    let late_faulty = stats::mean(&faulty.minibatch_ms[80..]);
    assert!(
        late_faulty > 1.7 * late_clean,
        "throttle had no effect: {late_clean:.1} vs {late_faulty:.1}"
    );
}

#[test]
fn energy_is_power_times_time() {
    // the paper's footnote 1: energy derives from the two predicted
    // quantities; sanity-check the derived metric is self-consistent
    let spec = DeviceKind::OrinAgx.spec();
    let wl = Workload::resnet();
    let maxn = PowerMode::maxn(spec);
    let low = PowerMode { cores: 4, cpu_khz: spec.cpu_khz[10], gpu_khz: spec.gpu_khz[3], mem_khz: spec.mem_khz[1] };
    let energy = |pm: &PowerMode| {
        steady_power_mw(spec, &wl, pm) / 1000.0 * epoch_time_s(spec, &wl, pm) / 3600.0
    };
    // slow low-power modes can still cost *more* energy than MAXN — the
    // non-obvious trade-off that motivates the Pareto analysis
    let e_maxn = energy(&maxn);
    let e_low = energy(&low);
    assert!(e_maxn > 0.0 && e_low > 0.0);
    assert!(e_low > e_maxn * 0.5, "low-power energy implausibly small");
}
