//! Integration: the serving coordinator end-to-end — request handling,
//! budget compliance, multi-request serving, failure paths.
//!
//! Uses a reduced prediction grid + transfer epochs so the suite stays
//! fast; the federated_fleet example runs the full-scale version.
//!
//! Gated on the `xla` feature: the host-native serving paths are
//! covered by `coordinator::tests` and `integration_host_coordinator`
//! and run in every build.

#![cfg(feature = "xla")]

use powertrain::coordinator::{
    handle_request, prediction_grid, serve, CoordinatorConfig, Metrics, ReferenceModels,
    Request, Scenario,
};
use powertrain::device::{DeviceKind, PowerModeGrid};
use powertrain::profiler::Profiler;
use powertrain::runtime::Runtime;
use powertrain::sim::TrainerSim;
use powertrain::workload::Workload;

fn artifacts() -> std::path::PathBuf {
    std::path::PathBuf::from("artifacts")
}

/// Shared, lazily-built reference models (training them once is enough).
fn reference(rt: &Runtime) -> ReferenceModels {
    let dir = std::env::temp_dir().join("pt_coord_ref_v1");
    if let Ok(r) = ReferenceModels::load(&dir) {
        return r;
    }
    let mut rng = powertrain::util::rng::Rng::new(1);
    let modes = PowerModeGrid::paper_subset(DeviceKind::OrinAgx).sample(800, &mut rng);
    let mut profiler = Profiler::new(TrainerSim::new(
        DeviceKind::OrinAgx.spec(),
        Workload::resnet(),
        1,
    ));
    let corpus = profiler.profile_modes(&modes).unwrap();
    let r = ReferenceModels::bootstrap(rt, &corpus, 100, 1).unwrap();
    std::fs::create_dir_all(&dir).unwrap();
    r.save(&dir).unwrap();
    r
}

fn test_cfg() -> CoordinatorConfig {
    CoordinatorConfig {
        artifacts_dir: artifacts(),
        transfer_epochs: 60,
        prediction_grid: Some(400),
        workers: 1,
        ..Default::default()
    }
}

#[test]
fn powertrain_request_end_to_end() {
    let rt = Runtime::new(&artifacts()).unwrap();
    let reference = reference(&rt);
    let metrics = Metrics::new();
    let req = Request {
        id: 1,
        device: DeviceKind::OrinAgx,
        workload: Workload::mobilenet(),
        power_budget_w: 30.0,
        scenario: Scenario::FederatedLearning,
        affinity: None,
        node: None,
        seed: 11,
    };
    let resp = handle_request(&rt, &reference, &test_cfg(), &metrics, &req).unwrap();
    assert!(resp.strategy.starts_with("powertrain"));
    assert!(resp.predicted_power_w <= 30.0 + 1e-9, "prediction violates budget");
    // observed power should land near the budget, not wildly above
    assert!(
        resp.observed_power_w <= 30.0 * 1.25,
        "observed {:.1} W >> budget",
        resp.observed_power_w
    );
    assert!(resp.observed_time_ms > 0.0);
    assert!(resp.profiling_cost_s > 0.0);
    assert_eq!(metrics.requests_completed.load(std::sync::atomic::Ordering::Relaxed), 1);
}

#[test]
fn cross_device_request_uses_device_grid() {
    let rt = Runtime::new(&artifacts()).unwrap();
    let reference = reference(&rt);
    let metrics = Metrics::new();
    let req = Request {
        id: 2,
        device: DeviceKind::OrinNano,
        workload: Workload::mobilenet(),
        power_budget_w: 10.0,
        scenario: Scenario::ContinuousLearning,
        affinity: None,
        node: None,
        seed: 12,
    };
    let cfg = CoordinatorConfig { prediction_grid: None, ..test_cfg() };
    let resp = handle_request(&rt, &reference, &cfg, &metrics, &req).unwrap();
    // the chosen mode must be valid on the Nano
    resp.chosen_mode.validate(DeviceKind::OrinNano.spec()).unwrap();
    assert!(resp.observed_power_w < 15.0);
}

#[test]
fn infeasible_budget_reported_as_error() {
    let rt = Runtime::new(&artifacts()).unwrap();
    let reference = reference(&rt);
    let metrics = Metrics::new();
    let req = Request {
        id: 3,
        device: DeviceKind::OrinAgx,
        workload: Workload::bert(),
        power_budget_w: 2.0, // below idle power
        scenario: Scenario::FederatedLearning,
        affinity: None,
        node: None,
        seed: 13,
    };
    let err = handle_request(&rt, &reference, &test_cfg(), &metrics, &req);
    assert!(err.is_err());
}

#[test]
fn serve_processes_all_requests_and_tracks_metrics() {
    let rt = Runtime::new(&artifacts()).unwrap();
    let reference = reference(&rt);
    drop(rt);
    let requests: Vec<Request> = (0..3)
        .map(|i| Request {
            id: i,
            device: DeviceKind::OrinAgx,
            workload: if i % 2 == 0 { Workload::mobilenet() } else { Workload::lstm() },
            power_budget_w: 25.0 + 5.0 * i as f64,
            scenario: Scenario::FederatedLearning,
            affinity: None,
            node: None,
            seed: 100 + i,
        })
        .collect();
    let (responses, metrics) = serve(&test_cfg(), &reference, requests).unwrap();
    assert_eq!(responses.len(), 3);
    let mut ids: Vec<u64> = responses.iter().map(|r| r.id).collect();
    ids.sort_unstable();
    assert_eq!(ids, vec![0, 1, 2]);
    assert_eq!(
        metrics.requests_completed.load(std::sync::atomic::Ordering::Relaxed),
        3
    );
    let (p50, _, _) = metrics.latency_summary_ms();
    assert!(p50 > 0.0);
}

#[test]
fn serve_with_two_workers_completes() {
    // two workers, each with its own PJRT runtime (not Send across threads)
    let rt = Runtime::new(&artifacts()).unwrap();
    let reference = reference(&rt);
    drop(rt);
    let cfg = CoordinatorConfig { workers: 2, ..test_cfg() };
    let requests: Vec<Request> = (0..4)
        .map(|i| Request {
            id: i,
            device: DeviceKind::OrinAgx,
            workload: Workload::lstm(),
            power_budget_w: 28.0,
            scenario: Scenario::FederatedLearning,
            affinity: None,
            node: None,
            seed: 200 + i,
        })
        .collect();
    let (responses, _) = serve(&cfg, &reference, requests).unwrap();
    assert_eq!(responses.len(), 4);
}

#[test]
fn prediction_grids_match_paper_corpus_sizes() {
    assert_eq!(prediction_grid(DeviceKind::OrinAgx, None, 0).len(), 4368);
    assert_eq!(prediction_grid(DeviceKind::XavierAgx, None, 0).len(), 1000);
    assert_eq!(prediction_grid(DeviceKind::OrinNano, None, 0).len(), 180);
}
