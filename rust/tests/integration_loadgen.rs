//! Integration: the open-world traffic engine end-to-end in the default
//! (no-`xla`) build — arrival schedules, scenario mixes, the warm-up /
//! measured split, fleet pacing, and the loadreport-v1 JSON — all driven
//! the way `pt-loadtest` drives them.
//!
//! The suite leans on the engine's determinism contract: the schedule
//! and every mix draw are fixed up front from the run seed, so with one
//! worker per domain two runs of one config must agree on every counter
//! — only wall-clock latencies differ.

use powertrain::coordinator::{CoordinatorConfig, ReferenceModels};
use powertrain::device::{DeviceKind, PowerModeGrid};
use powertrain::loadgen::engine::{run, EngineConfig, FleetShape};
use powertrain::loadgen::report::LoadReport;
use powertrain::loadgen::{ArrivalSpec, Mix};
use powertrain::profiler::Profiler;
use powertrain::sim::TrainerSim;
use powertrain::util::rng::Rng;
use powertrain::workload::Workload;

/// Shared, lazily-built host reference models (same recipe as the other
/// integration suites: in-process `OnceLock`, never a stale temp dir).
fn reference() -> ReferenceModels {
    static REF: std::sync::OnceLock<ReferenceModels> = std::sync::OnceLock::new();
    REF.get_or_init(|| {
        let mut rng = Rng::new(1);
        let modes = PowerModeGrid::paper_subset(DeviceKind::OrinAgx).sample(400, &mut rng);
        let mut profiler = Profiler::new(TrainerSim::new(
            DeviceKind::OrinAgx.spec(),
            Workload::resnet(),
            1,
        ));
        let corpus = profiler.profile_modes(&modes).unwrap();
        ReferenceModels::bootstrap_host(&corpus, 60, 1).unwrap()
    })
    .clone()
}

fn engine_cfg(arrivals: &str, fleet: Option<FleetShape>) -> EngineConfig {
    EngineConfig {
        arrivals: ArrivalSpec::parse(arrivals).unwrap(),
        mix: Mix::standard(),
        seed: 11,
        warmup_ms: 500,
        duration_ms: 2_000,
        fleet,
        coordinator: CoordinatorConfig {
            transfer_epochs: 60,
            prediction_grid: Some(400),
            workers: 1,
            ..Default::default()
        },
    }
}

/// Acceptance: a two-shard fleet run reconciles — submitted equals
/// completed + failed + unplaced, the per-shard routing grid sums back
/// to the total, and the report survives its own JSON round trip.
#[test]
fn fleet_report_counters_reconcile_and_round_trip() {
    let shape = FleetShape { shards: 2, nodes: 64 };
    let report = run(&engine_cfg("poisson:40", Some(shape)), &reference()).unwrap();
    report.validate().unwrap();

    assert_eq!(report.mode, "fleet");
    assert_eq!(report.shards, 2);
    assert!(report.measured.events > 0);
    assert_eq!(report.submitted, report.measured.events);
    // every submitted request is accounted for, exactly once
    assert_eq!(
        report.submitted,
        report.counters.requests_completed
            + report.counters.requests_failed
            + report.placement_failed,
    );
    // the routing grid reconciles: per-shard counts sum to the total,
    // and the total is every request that made it past placement
    let per_shard = report.counters.routed_per_shard();
    assert_eq!(
        per_shard.iter().sum::<u64>(),
        report.counters.routed_total()
    );
    assert_eq!(
        report.counters.routed_total(),
        report.submitted - report.placement_failed
    );
    // both shards actually took traffic at this scale
    assert!(per_shard[0] > 0 && per_shard[1] > 0, "{per_shard:?}");

    assert_eq!(report.latency.samples, report.counters.requests_completed);
    assert!(report.latency.p50 > 0.0);
    assert!(report.latency.p99 >= report.latency.p50);
    assert!(report.throughput_rps > 0.0);

    // the JSON the operator reads must carry the same facts
    let back = LoadReport::from_json(&report.to_json().to_string()).unwrap();
    back.validate().unwrap();
    assert_eq!(back.counters, report.counters);
    assert_eq!(back.schedule_fingerprint, report.schedule_fingerprint);
    assert_eq!(back.submitted, report.submitted);
}

/// Acceptance: same seed + same config ⇒ bit-identical arrival schedule
/// and identical measured counters across two fleet runs (workers = 1;
/// only wall-clock latency may differ).
#[test]
fn same_seed_fleet_runs_replay_identically() {
    let cfg = engine_cfg("poisson:25", Some(FleetShape { shards: 2, nodes: 32 }));
    let a = run(&cfg, &reference()).unwrap();
    let b = run(&cfg, &reference()).unwrap();
    assert_eq!(a.schedule_fingerprint, b.schedule_fingerprint);
    assert_eq!(a.counters, b.counters, "measured counters must replay");
    assert_eq!(a.submitted, b.submitted);
    assert_eq!(a.placement_failed, b.placement_failed);
    assert_eq!(a.latency.samples, b.latency.samples);
    assert_eq!(a.deadlines.with_deadline, b.deadlines.with_deadline);
    assert_eq!(a.deadlines.misses, b.deadlines.misses);
}

/// Every arrival family drives a single coordinator to a valid report
/// with non-degenerate latency stats.
#[test]
fn arrival_families_drive_a_single_coordinator() {
    for spec in ["poisson:30", "mmpp:10,60:2,1", "diurnal:30:0.8:2"] {
        let report = run(&engine_cfg(spec, None), &reference()).unwrap();
        report.validate().unwrap();
        assert_eq!(report.mode, "single", "{spec}");
        assert!(report.measured.events > 0, "{spec}: empty measured phase");
        assert!(
            report.counters.requests_completed > 0,
            "{spec}: nothing completed"
        );
        assert!(report.latency.p50 > 0.0, "{spec}: degenerate p50");
        assert!(report.throughput_rps > 0.0, "{spec}");
        // warm-up paid the fits; the measured window serves from cache
        assert!(
            report.counters.model_cache_hits > 0,
            "{spec}: warm-up did not warm the model cache"
        );
    }
}
