//! Integration: CLI smoke tests through the compiled binary.

use std::process::Command;

fn bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_powertrain"))
}

#[test]
fn help_lists_all_commands() {
    let out = bin().arg("help").output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    for cmd in ["info", "profile", "train-ref", "transfer", "optimize", "serve", "experiment"] {
        assert!(text.contains(cmd), "help missing '{cmd}'");
    }
}

#[test]
fn no_args_prints_help() {
    let out = bin().output().unwrap();
    assert!(out.status.success());
    assert!(String::from_utf8_lossy(&out.stdout).contains("USAGE"));
}

#[test]
fn unknown_command_fails_with_message() {
    let out = bin().arg("frobnicate").output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown command"));
}

#[test]
fn info_reports_devices_and_artifacts() {
    let out = bin().arg("info").output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("orin-agx"));
    assert!(text.contains("18096"));
    // artifact status depends on the build/provisioning: "OK" with the
    // xla feature + `make artifacts`, otherwise a host-engine notice
    assert!(text.contains("artifacts:"), "no artifact status line: {text}");
}

#[test]
fn profile_writes_corpus_csv() {
    let dir = std::env::temp_dir().join("pt_cli_profile");
    std::fs::create_dir_all(&dir).unwrap();
    let out_file = dir.join("c.csv");
    let out = bin()
        .args([
            "profile", "--workload", "lstm", "--modes", "8", "--seed", "5",
            "--out", out_file.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let csv = std::fs::read_to_string(&out_file).unwrap();
    assert_eq!(csv.lines().count(), 9); // header + 8 modes
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn bad_flag_values_are_usage_errors() {
    let out = bin()
        .args(["profile", "--modes", "not-a-number"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("expects an integer"));

    let out = bin().args(["profile", "--device", "tpu"]).output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown device"));
}

#[test]
fn experiment_requires_id() {
    let out = bin().arg("experiment").output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("requires an id"));
}

#[cfg(feature = "xla")]
#[test]
fn experiment_table2_runs_quickly() {
    let dir = std::env::temp_dir().join("pt_cli_table2");
    let out = bin()
        .args(["experiment", "table2", "--out", dir.to_str().unwrap(), "--quick"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(dir.join("table2_devices.csv").exists());
    std::fs::remove_dir_all(&dir).ok();
}
