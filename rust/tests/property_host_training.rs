//! Property + integration tests for the host-native training subsystem:
//!
//! * the hand-rolled backward pass agrees with central finite
//!   differences of an independent f64 reference implementation at
//!   ≤ 1e-3 relative error;
//! * `HostTrainer` fits decrease the loss, are bit-deterministic per
//!   seed, and support the MAPE loss variant;
//! * PowerTrain host transfer with 50 modes beats a from-scratch NN on
//!   the same 50 modes (the paper's Fig. 9 claim, tolerance-based).

use powertrain::device::{DeviceKind, PowerModeGrid};
use powertrain::nn::grad::{self, HostLoss, Tape, TransposedMlp};
use powertrain::nn::{MlpParams, DIMS};
use powertrain::predict::corpus_mape_host;
use powertrain::profiler::{Corpus, Record};
use powertrain::sim::TrainerSim;
use powertrain::train::transfer::{transfer_host, TransferConfig};
use powertrain::train::{HostTrainer, LossKind, Target, TrainConfig};
use powertrain::util::rng::Rng;
use powertrain::workload::Workload;

/// Fast ground-truth corpus (no telemetry noise), mirroring the xla
/// integration suite's helper.
fn truth_corpus(wl: Workload, n: usize, seed: u64) -> Corpus {
    let spec = DeviceKind::OrinAgx.spec();
    let sim = TrainerSim::new(spec, wl, seed);
    let mut rng = Rng::new(seed ^ 0xc0ffee);
    let modes = PowerModeGrid::paper_subset(DeviceKind::OrinAgx).sample(n, &mut rng);
    let mut c = Corpus::new(DeviceKind::OrinAgx, wl);
    for pm in modes {
        c.push(Record {
            mode: pm,
            time_ms: sim.true_minibatch_ms(&pm),
            power_mw: sim.true_power_mw(&pm),
            cost_s: 0.0,
        });
    }
    c
}

/// Independent f64 reference: mean-MSE loss of the canonical row-major
/// MLP, plus an FNV hash of every ReLU gate so the finite-difference
/// check can detect (and skip) perturbations that cross a kink — the
/// loss is not differentiable there, so FD is meaningless for those
/// coordinates.
fn f64_loss_and_gates(leaves: &[Vec<f64>], xs: &[[f32; 4]], ys: &[f32]) -> (f64, u64) {
    let mut total = 0.0f64;
    let mut gates = 0xcbf29ce484222325u64;
    for (x, &y) in xs.iter().zip(ys) {
        let mut act: Vec<f64> = x.iter().map(|&v| v as f64).collect();
        for layer in 0..4 {
            let (ins, outs) = (DIMS[layer], DIMS[layer + 1]);
            let w = &leaves[layer * 2];
            let b = &leaves[layer * 2 + 1];
            let mut next = vec![0.0f64; outs];
            for (o, nx) in next.iter_mut().enumerate() {
                let mut acc = b[o];
                for (i, &a) in act.iter().enumerate() {
                    acc += a * w[i * outs + o];
                }
                if layer < 3 {
                    let open = acc > 0.0;
                    gates = (gates ^ (1 + open as u64)).wrapping_mul(0x100000001b3);
                    *nx = if open { acc } else { 0.0 };
                } else {
                    *nx = acc;
                }
            }
            act = next;
        }
        let e = act[0] - y as f64;
        total += e * e;
    }
    (total / xs.len() as f64, gates)
}

#[test]
fn analytic_gradient_matches_central_finite_differences() {
    let mut rng = Rng::new(4242);
    let params = MlpParams::init_he(&mut rng);
    let n = 8usize;
    let xs: Vec<[f32; 4]> = (0..n)
        .map(|_| {
            [
                rng.normal() as f32,
                rng.normal() as f32,
                rng.normal() as f32,
                rng.normal() as f32,
            ]
        })
        .collect();
    let ys: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();

    // analytic gradient from the production backward pass, mapped back
    // to canonical layout
    let net = TransposedMlp::from_params(&params);
    let mut tape = Tape::new(n);
    let mut g = TransposedMlp::zeros();
    let flat: Vec<f32> = xs.iter().flatten().copied().collect();
    let loss_f32 = grad::loss_and_grad(&net, &flat, &ys, n, HostLoss::Mse, &mut tape, &mut g);
    let analytic = g.to_params();

    // f64 reference agrees with the f32 forward on the loss itself
    let leaves64: Vec<Vec<f64>> = params
        .leaves
        .iter()
        .map(|l| l.iter().map(|&v| v as f64).collect())
        .collect();
    let (loss_f64, _) = f64_loss_and_gates(&leaves64, &xs, &ys);
    assert!(
        (loss_f32 - loss_f64).abs() <= 1e-4 * loss_f64.abs().max(1.0),
        "loss mismatch: f32 path {loss_f32} vs f64 reference {loss_f64}"
    );

    // central finite differences on ~8 random coordinates per leaf
    let h = 1e-6f64;
    let mut checked = 0usize;
    let mut skipped = 0usize;
    let mut worst: f64 = 0.0;
    for leaf in 0..8 {
        for _ in 0..8 {
            let idx = rng.below(leaves64[leaf].len());
            let mut perturbed = leaves64.clone();
            perturbed[leaf][idx] += h;
            let (lp, gates_p) = f64_loss_and_gates(&perturbed, &xs, &ys);
            perturbed[leaf][idx] -= 2.0 * h;
            let (lm, gates_m) = f64_loss_and_gates(&perturbed, &xs, &ys);
            if gates_p != gates_m {
                skipped += 1; // kink crossed: FD undefined here
                continue;
            }
            let numeric = (lp - lm) / (2.0 * h);
            let a = analytic.leaves[leaf][idx] as f64;
            let denom = a.abs().max(numeric.abs());
            let err = (a - numeric).abs();
            assert!(
                err <= 1e-3 * denom + 1e-6,
                "leaf {leaf} idx {idx}: analytic {a} vs numeric {numeric} (err {err})"
            );
            if denom > 1e-6 {
                worst = worst.max(err / denom);
            }
            checked += 1;
        }
    }
    assert!(
        checked >= 48,
        "too few coordinates checked ({checked} checked, {skipped} kink-skipped)"
    );
    assert!(worst <= 1e-3, "worst relative error {worst}");
}

#[test]
fn mape_gradient_matches_finite_differences_on_the_loss_scale() {
    // same FD approach, MAPE loss in raw units: perturb the head layer
    // (w4/b4), where the raw-unit chain rule is easiest to get wrong
    let mut rng = Rng::new(77);
    let params = MlpParams::init_he(&mut rng);
    let (y_mean, y_std) = (120.0f64, 35.0f64);
    let n = 6usize;
    let xs: Vec<[f32; 4]> = (0..n)
        .map(|_| [rng.normal() as f32, rng.normal() as f32, rng.normal() as f32, rng.normal() as f32])
        .collect();
    let ys_raw: Vec<f32> = (0..n).map(|_| (y_mean + 30.0 * rng.normal()) as f32).collect();

    let net = TransposedMlp::from_params(&params);
    let mut tape = Tape::new(n);
    let mut g = TransposedMlp::zeros();
    let flat: Vec<f32> = xs.iter().flatten().copied().collect();
    grad::loss_and_grad(&net, &flat, &ys_raw, n, HostLoss::Mape { y_mean, y_std }, &mut tape, &mut g);
    let analytic = g.to_params();

    let leaves64: Vec<Vec<f64>> = params
        .leaves
        .iter()
        .map(|l| l.iter().map(|&v| v as f64).collect())
        .collect();
    let mape64 = |leaves: &[Vec<f64>]| -> f64 {
        let mut total = 0.0;
        for (x, &y) in xs.iter().zip(&ys_raw) {
            let mut act: Vec<f64> = x.iter().map(|&v| v as f64).collect();
            for layer in 0..4 {
                let (ins, outs) = (DIMS[layer], DIMS[layer + 1]);
                let w = &leaves[layer * 2];
                let b = &leaves[layer * 2 + 1];
                let mut next = vec![0.0f64; outs];
                for (o, nx) in next.iter_mut().enumerate() {
                    let mut acc = b[o];
                    for (i, &a) in act.iter().enumerate() {
                        acc += a * w[i * outs + o];
                    }
                    *nx = if layer < 3 { acc.max(0.0) } else { acc };
                }
                act = next;
            }
            let pred_raw = act[0] * y_std + y_mean;
            total += 100.0 * (pred_raw - y as f64).abs() / (y as f64).abs().max(1e-6);
        }
        total / n as f64
    };
    let h = 1e-6;
    for leaf in [6usize, 7] {
        for idx in 0..leaves64[leaf].len().min(8) {
            let mut p = leaves64.clone();
            p[leaf][idx] += h;
            let lp = mape64(&p);
            p[leaf][idx] -= 2.0 * h;
            let lm = mape64(&p);
            let numeric = (lp - lm) / (2.0 * h);
            let a = analytic.leaves[leaf][idx] as f64;
            assert!(
                (a - numeric).abs() <= 1e-3 * a.abs().max(numeric.abs()) + 1e-5,
                "leaf {leaf} idx {idx}: analytic {a} vs numeric {numeric}"
            );
        }
    }
}

#[test]
fn host_trainer_loss_decreases_and_tracks_best_epoch() {
    let corpus = truth_corpus(Workload::resnet(), 120, 20);
    let cfg = TrainConfig { epochs: 60, seed: 21, ..Default::default() };
    let (ckpt, log) = HostTrainer::new().train(&corpus, Target::Time, &cfg).unwrap();
    assert!(ckpt.params.is_finite());
    assert_eq!(log.train_loss.len(), 60);
    let first = log.train_loss[0];
    let last = *log.train_loss.last().unwrap();
    assert!(last < 0.7 * first, "train loss barely moved: {first:.4} -> {last:.4}");
    let best = log.val_mse.iter().cloned().fold(f64::INFINITY, f64::min);
    assert!(best < log.val_mse[0], "validation never improved");
    assert!((ckpt.val_loss - best).abs() < 1e-12, "checkpoint not the best epoch");
    assert!(log.val_mse[log.best_epoch] == best);
}

#[test]
fn host_training_is_deterministic_per_seed() {
    let corpus = truth_corpus(Workload::mobilenet(), 60, 30);
    let cfg = TrainConfig { epochs: 8, seed: 31, ..Default::default() };
    let (a, log_a) = HostTrainer::new().train(&corpus, Target::Power, &cfg).unwrap();
    let (b, log_b) = HostTrainer::new().train(&corpus, Target::Power, &cfg).unwrap();
    // bit-identical replay — the property the coordinator's model cache
    // soundness rests on
    assert_eq!(a.fingerprint(), b.fingerprint());
    assert_eq!(a.params, b.params);
    assert_eq!(log_a.train_loss, log_b.train_loss);
    assert_eq!(log_a.best_epoch, log_b.best_epoch);
    // a different seed takes a genuinely different trajectory
    let cfg2 = TrainConfig { seed: 32, ..cfg };
    let (c, _) = HostTrainer::new().train(&corpus, Target::Power, &cfg2).unwrap();
    assert_ne!(a.fingerprint(), c.fingerprint());
}

#[test]
fn mape_loss_variant_trains_host() {
    let corpus = truth_corpus(Workload::resnet(), 120, 20);
    let cfg = TrainConfig {
        epochs: 60,
        loss: LossKind::Mape,
        seed: 21,
        ..Default::default()
    };
    let (ckpt, log) = HostTrainer::new().train(&corpus, Target::Power, &cfg).unwrap();
    assert!(ckpt.params.is_finite());
    let first = log.train_loss[0];
    let last = *log.train_loss.last().unwrap();
    assert!(last < 0.8 * first, "MAPE loss {first:.1} -> {last:.1}");
}

#[test]
fn host_transfer_beats_scratch_at_50_modes() {
    // the paper's Fig. 9 claim, reproduced host-natively at reduced
    // scale: a reference model transferred with 50 profiled modes of a
    // *new* workload predicts held-out modes at least as well as a
    // from-scratch NN on the same 50 modes (tolerance-based: transfer
    // must not lose by more than 2 MAPE points, and must be usable in
    // absolute terms)
    let ref_corpus = truth_corpus(Workload::resnet(), 600, 10);
    let ref_cfg = TrainConfig { epochs: 60, seed: 11, ..Default::default() };
    let trainer = HostTrainer::new();
    let (ref_time, _) = trainer.train(&ref_corpus, Target::Time, &ref_cfg).unwrap();

    let small = truth_corpus(Workload::mobilenet(), 50, 12);
    let holdout = truth_corpus(Workload::mobilenet(), 200, 13);

    let t_cfg = TransferConfig {
        base: TrainConfig { epochs: 80, seed: 14, ..Default::default() },
        ..Default::default()
    };
    let (pt_ckpt, _) = transfer_host(&ref_time, &small, Target::Time, &t_cfg).unwrap();
    let pt_mape = corpus_mape_host(&pt_ckpt, &holdout, Target::Time);

    let nn_cfg = TrainConfig { epochs: 80, seed: 15, ..Default::default() };
    let (nn_ckpt, _) = trainer.train(&small, Target::Time, &nn_cfg).unwrap();
    let nn_mape = corpus_mape_host(&nn_ckpt, &holdout, Target::Time);

    assert!(
        pt_mape <= nn_mape + 2.0,
        "host transfer {pt_mape:.1}% worse than scratch {nn_mape:.1}%"
    );
    assert!(pt_mape < 40.0, "host transfer too weak: {pt_mape:.1}%");
}

#[test]
fn transfer_provenance_and_surgery_are_applied() {
    let ref_corpus = truth_corpus(Workload::resnet(), 80, 40);
    let trainer = HostTrainer::new();
    let ref_cfg = TrainConfig { epochs: 6, seed: 41, ..Default::default() };
    let (reference, _) = trainer.train(&ref_corpus, Target::Time, &ref_cfg).unwrap();
    let small = truth_corpus(Workload::lstm(), 30, 42);
    let cfg = TransferConfig {
        base: TrainConfig { epochs: 8, seed: 43, ..Default::default() },
        ..Default::default()
    };
    let (ck, log) = transfer_host(&reference, &small, Target::Time, &cfg).unwrap();
    assert!(ck.provenance.starts_with("powertrain-transfer-host(from nn-scratch-host"));
    assert!(ck.provenance.contains("lstm (30 modes)"));
    assert_eq!(log.train_loss.len(), 8);
    // the fine-tuned model differs from the reference
    assert_ne!(ck.fingerprint(), reference.fingerprint());
    // freeze-then-finetune schedule: freeze_epochs clamps to the budget
    let clamped = TransferConfig {
        base: TrainConfig { epochs: 3, seed: 43, ..Default::default() },
        freeze_epochs: 10,
        ..Default::default()
    };
    let (_, log2) = transfer_host(&reference, &small, Target::Time, &clamped).unwrap();
    assert_eq!(log2.train_loss.len(), 3);
}
