//! Property suite for the batched host inference engine: the engine must
//! be indistinguishable (within 1e-5) from the scalar oracle
//! `host_mlp::forward_one` across random parameters, random inputs and
//! ragged batch sizes — plus NaN/infinity robustness for the Pareto
//! construction that consumes its predictions.

use powertrain::device::{DeviceKind, PowerMode, PowerModeGrid};
use powertrain::nn::engine::{HostEngine, Scratch};
use powertrain::nn::checkpoint::Checkpoint;
use powertrain::nn::{host_mlp, MlpParams};
use powertrain::pareto::{ParetoFront, Point};
use powertrain::predict::GridPredictor;
use powertrain::profiler::StandardScaler;
use powertrain::util::prop::{forall, vec_of, Gen};
use powertrain::util::rng::Rng;

fn agree(got: f32, want: f32) -> bool {
    (got - want).abs() <= 1e-5 * want.abs().max(1.0)
}

/// The acceptance bar: batched engine == forward_one within 1e-5 across
/// random params/inputs and ragged batch sizes spanning tile boundaries.
#[test]
fn engine_matches_oracle_across_ragged_batch_sizes() {
    for (case, &n) in [1usize, 63, 64, 65, 4_368].iter().enumerate() {
        let mut rng = Rng::new(100 + case as u64);
        let params = MlpParams::init_he(&mut rng);
        let engine = HostEngine::new(&params);
        let xs: Vec<[f32; 4]> = (0..n)
            .map(|_| {
                [
                    rng.normal() as f32,
                    rng.normal() as f32,
                    rng.normal() as f32,
                    rng.normal() as f32,
                ]
            })
            .collect();
        let got = engine.forward_batch(&xs);
        assert_eq!(got.len(), n);
        for (i, x) in xs.iter().enumerate() {
            let want = host_mlp::forward_one(&params, x);
            assert!(
                agree(got[i], want),
                "batch {n} row {i}: engine {} vs oracle {want}",
                got[i]
            );
        }
    }
}

/// The 8-lane register blocks (dot4 in gemm_relu, the 8-row layer-1
/// sweeps) must be invisible at every ragged width: sizes straddling the
/// lane width (7/8/9), the tile (63/64/65) and a full grid (4368), for
/// both the row-major and SoA entry points, against the scalar oracle.
#[test]
fn eight_lane_kernels_match_oracle_at_every_ragged_width() {
    for (case, &n) in [1usize, 7, 8, 9, 63, 64, 65, 4_368].iter().enumerate() {
        let mut rng = Rng::new(300 + case as u64);
        let params = MlpParams::init_he(&mut rng);
        let engine = HostEngine::new(&params);
        let xs: Vec<[f32; 4]> = (0..n)
            .map(|_| {
                [
                    rng.normal() as f32,
                    rng.normal() as f32,
                    rng.normal() as f32,
                    rng.normal() as f32,
                ]
            })
            .collect();
        let via_rows = engine.forward_batch(&xs);
        let mut cols: [Vec<f32>; 4] = Default::default();
        for x in &xs {
            for d in 0..4 {
                cols[d].push(x[d]);
            }
        }
        let mut via_cols = vec![0.0f32; n];
        engine.forward_cols_into([&cols[0], &cols[1], &cols[2], &cols[3]], &mut via_cols);
        assert_eq!(via_rows, via_cols, "row/col paths diverged at n={n}");
        for (i, x) in xs.iter().enumerate() {
            let want = host_mlp::forward_one(&params, x);
            assert!(
                agree(via_rows[i], want),
                "n={n} row {i}: engine {} vs oracle {want}",
                via_rows[i]
            );
        }
    }
}

/// Subnormals and negative zero must flow through the lane kernels the
/// same way they flow through the scalar oracle — no flush-to-zero
/// surprises from the blocking, and `(-0.0).max(0.0)` relu gating
/// identical in both.
#[test]
fn subnormal_and_negative_zero_inputs_match_the_oracle() {
    let mut rng = Rng::new(320);
    let params = MlpParams::init_he(&mut rng);
    let engine = HostEngine::new(&params);
    let tiny = f32::MIN_POSITIVE / 8.0; // subnormal
    let n = 65; // spans lane and tile remainders
    let xs: Vec<[f32; 4]> = (0..n)
        .map(|i| {
            let mut x = [
                rng.normal() as f32,
                rng.normal() as f32,
                rng.normal() as f32,
                rng.normal() as f32,
            ];
            x[i % 4] = match i % 3 {
                0 => -0.0f32,
                1 => tiny,
                _ => -tiny,
            };
            x
        })
        .collect();
    let got = engine.forward_batch(&xs);
    for (i, x) in xs.iter().enumerate() {
        let want = host_mlp::forward_one(&params, x);
        assert!(
            agree(got[i], want),
            "row {i} ({x:?}): engine {} vs oracle {want}",
            got[i]
        );
        assert!(got[i].is_finite(), "row {i} produced non-finite output");
    }
    // all-subnormal and all-negative-zero batches, exercising the
    // remainder loops (n=9) as well
    for special in [[-0.0f32; 4], [tiny; 4], [-tiny; 4]] {
        let batch = vec![special; 9];
        let got = engine.forward_batch(&batch);
        let want = host_mlp::forward_one(&params, &special);
        for (i, g) in got.iter().enumerate() {
            assert!(agree(*g, want), "special {special:?} row {i}");
        }
    }
}

#[test]
fn engine_agrees_for_many_random_parameter_draws() {
    // smaller batches, many independent parameter draws (incl. extreme
    // scales) — transposition must be exact for every leaf layout
    for seed in 0..12u64 {
        let mut rng = Rng::new(1000 + seed);
        let mut params = MlpParams::init_he(&mut rng);
        if seed % 3 == 0 {
            // exercise non-trivial biases too (init_he zeroes them)
            for leaf in [1usize, 3, 5, 7] {
                for v in params.leaves[leaf].iter_mut() {
                    *v = (rng.normal() * 0.5) as f32;
                }
            }
        }
        let engine = HostEngine::new(&params);
        let xs: Vec<[f32; 4]> = (0..37)
            .map(|_| {
                [
                    (rng.normal() * 3.0) as f32,
                    rng.uniform_range(-5.0, 5.0) as f32,
                    rng.normal() as f32,
                    0.0,
                ]
            })
            .collect();
        let got = engine.forward_batch(&xs);
        for (i, x) in xs.iter().enumerate() {
            let want = host_mlp::forward_one(&params, x);
            assert!(agree(got[i], want), "seed {seed} row {i}");
        }
    }
}

#[test]
fn scratch_arena_is_stateless_between_calls() {
    let mut rng = Rng::new(55);
    let params = MlpParams::init_he(&mut rng);
    let engine = HostEngine::new(&params);
    let mut scratch = Scratch::new();
    // interleave differently-sized batches through one scratch; results
    // must match fresh-scratch runs exactly
    for n in [65usize, 1, 130, 64, 7] {
        let xs: Vec<f32> = (0..n * 4).map(|_| rng.normal() as f32).collect();
        let mut reused = vec![0.0f32; n];
        engine.forward_serial(&xs, &mut reused, &mut scratch);
        let mut fresh = vec![0.0f32; n];
        engine.forward_serial(&xs, &mut fresh, &mut Scratch::new());
        assert_eq!(reused, fresh, "n={n}");
    }
}

#[test]
fn grid_predictor_matches_seed_scalar_pipeline() {
    // end-to-end: standardize -> forward -> inverse-scale over real grid
    // modes equals the seed per-mode path within 1e-5 relative
    let mut rng = Rng::new(9);
    let ckpt = Checkpoint {
        params: MlpParams::init_he(&mut rng),
        feature_scaler: StandardScaler {
            mean: vec![6.0, 1200.0, 700.0, 1500.0],
            std: vec![3.0, 600.0, 350.0, 1000.0],
        },
        target_scaler: StandardScaler { mean: vec![100.0], std: vec![40.0] },
        target: "time".into(),
        provenance: "prop".into(),
        val_loss: 0.0,
    };
    let grid = PowerModeGrid::paper_subset(DeviceKind::OrinAgx);
    let gp = GridPredictor::new(&ckpt);
    let got = gp.predict(&grid.modes);
    assert_eq!(got.len(), grid.len());
    // tolerance floor = σ_y: after the affine output fold, raw values
    // near zero are differences of σ_y-sized terms (see predict tests)
    let y_scale = ckpt.target_scaler.std[0];
    for (i, pm) in grid.modes.iter().enumerate() {
        let feats = pm.features();
        let raw: Vec<f64> = feats.iter().map(|&v| v as f64).collect();
        let z = ckpt.feature_scaler.transform_row(&raw);
        let zf = [z[0] as f32, z[1] as f32, z[2] as f32, z[3] as f32];
        let want = ckpt
            .target_scaler
            .inverse1(host_mlp::forward_one(&ckpt.params, &zf) as f64);
        assert!(
            (got[i] - want).abs() <= 1e-5 * want.abs().max(y_scale),
            "mode {i}: engine {} vs oracle {want}",
            got[i]
        );
    }
}

#[test]
fn folded_engine_matches_unfused_oracle_across_ragged_batches() {
    // the affine-folded serve engine (GridPredictor: scalers folded into
    // the first/last layer weights, raw features in, raw units out) must
    // match the unfused oracle — standardize -> HostEngine::new forward ->
    // inverse target transform — within 1e-5 relative, across ragged
    // batch sizes spanning the tile and threading boundaries
    let mut rng = Rng::new(210);
    let ckpt = Checkpoint {
        params: MlpParams::init_he(&mut rng),
        feature_scaler: StandardScaler {
            mean: vec![6.0, 1400.0, 800.0, 2000.0],
            std: vec![3.5, 600.0, 350.0, 1100.0],
        },
        target_scaler: StandardScaler { mean: vec![30_000.0], std: vec![9_000.0] },
        target: "power".into(),
        provenance: "prop-folded".into(),
        val_loss: 0.0,
    };
    let grid = PowerModeGrid::paper_subset(DeviceKind::OrinAgx);
    let folded = GridPredictor::new(&ckpt);
    let unfused = HostEngine::new(&ckpt.params);
    for &n in &[1usize, 63, 64, 65, 4_368] {
        let modes = &grid.modes[..n];
        let got = folded.predict(modes);
        assert_eq!(got.len(), n);
        let zs: Vec<[f32; 4]> = modes
            .iter()
            .map(|pm| ckpt.feature_scaler.transform4(&pm.features()))
            .collect();
        let std_out = unfused.forward_batch(&zs);
        let y_scale = ckpt.target_scaler.std[0];
        for i in 0..n {
            let want = ckpt.target_scaler.inverse1(std_out[i] as f64);
            assert!(
                (got[i] - want).abs() <= 1e-5 * want.abs().max(y_scale),
                "n={n} row {i}: folded {} vs unfused {want}",
                got[i]
            );
        }
    }
}

#[test]
fn folded_predictions_are_identical_across_entry_points() {
    // predict / predict_into / predict_features_into are one computation:
    // outputs must be bitwise equal however the features are fed
    let mut rng = Rng::new(211);
    let ckpt = Checkpoint {
        params: MlpParams::init_he(&mut rng),
        feature_scaler: StandardScaler {
            mean: vec![6.0, 1200.0, 700.0, 1500.0],
            std: vec![3.0, 600.0, 350.0, 1000.0],
        },
        target_scaler: StandardScaler { mean: vec![100.0], std: vec![40.0] },
        target: "time".into(),
        provenance: "prop-folded".into(),
        val_loss: 0.0,
    };
    let grid = PowerModeGrid::paper_subset(DeviceKind::OrinAgx);
    let gp = GridPredictor::new(&ckpt);
    let via_modes = gp.predict(&grid.modes);
    let fm = grid.feature_matrix();
    let via_features = gp.predict_features(&fm);
    assert_eq!(via_modes, via_features);
    let mut reused = Vec::new();
    gp.predict_features_into(&fm, &mut reused);
    assert_eq!(via_modes, reused);
}

#[test]
fn prop_pareto_build_survives_nan_and_infinity() {
    // clouds with randomly injected NaN/±inf coordinates: build must not
    // panic, must exclude every non-finite candidate, and the front over
    // the finite ones must stay valid and non-dominated
    let point_gen = Gen::new(|r: &mut Rng| {
        let corrupt = r.below(5);
        let time = match corrupt {
            0 => f64::NAN,
            1 => f64::INFINITY,
            _ => r.uniform_range(1.0, 1000.0),
        };
        let power = match corrupt {
            2 => f64::NAN,
            3 => f64::NEG_INFINITY,
            _ => r.uniform_range(5_000.0, 60_000.0),
        };
        Point {
            mode: PowerMode::maxn(DeviceKind::OrinAgx.spec()),
            time,
            power_mw: power,
        }
    });
    let cloud_gen = vec_of(point_gen, 1, 150);
    forall(42, 300, &cloud_gen, |pts| {
        let front = ParetoFront::build(pts);
        let finite: Vec<&Point> = pts
            .iter()
            .filter(|p| p.time.is_finite() && p.power_mw.is_finite())
            .collect();
        front.is_valid()
            && front
                .points()
                .iter()
                .all(|fp| fp.time.is_finite() && fp.power_mw.is_finite())
            && front.len() <= finite.len()
            // every finite candidate is dominated-or-equal by a front point
            && finite.iter().all(|c| {
                front
                    .points()
                    .iter()
                    .any(|fp| fp.time <= c.time && fp.power_mw <= c.power_mw)
            })
    });
}
