//! Property-based invariants across subsystems, driven by the in-house
//! mini framework (`util::prop`) since proptest is unavailable offline.

use powertrain::device::{DeviceKind, PowerMode, PowerModeGrid, ProfilingPlan};
use powertrain::pareto::{ParetoFront, Point};
use powertrain::profiler::{stabilization_index, StandardScaler};
use powertrain::sim::perf_model::minibatch_time_ms;
use powertrain::sim::power_model::steady_power_mw;
use powertrain::util::json::Value;
use powertrain::util::prop::{f64_in, forall, one_of, usize_in, vec_of, Gen};
use powertrain::util::rng::Rng;
use powertrain::workload::Workload;

/// Generator of valid power modes on a device.
fn mode_gen(kind: DeviceKind) -> Gen<PowerMode> {
    let spec = kind.spec();
    Gen::new(move |r| PowerMode {
        cores: 1 + r.below(spec.max_cores as usize) as u32,
        cpu_khz: spec.cpu_khz[r.below(spec.cpu_khz.len())],
        gpu_khz: spec.gpu_khz[r.below(spec.gpu_khz.len())],
        mem_khz: spec.mem_khz[r.below(spec.mem_khz.len())],
    })
}

#[test]
fn prop_every_generated_mode_validates() {
    for kind in DeviceKind::ALL {
        forall(1, 500, &mode_gen(kind), |m| m.validate(kind.spec()).is_ok());
    }
}

#[test]
fn prop_sim_outputs_always_positive_finite() {
    let spec = DeviceKind::OrinAgx.spec();
    let workloads = Workload::default_five();
    let gen = mode_gen(DeviceKind::OrinAgx);
    forall(2, 400, &gen, |m| {
        workloads.iter().all(|wl| {
            let t = minibatch_time_ms(spec, wl, m);
            let p = steady_power_mw(spec, wl, m);
            t.total_ms > 0.0 && t.total_ms.is_finite() && p > 0.0 && p.is_finite()
        })
    });
}

#[test]
fn prop_more_resources_never_slower() {
    // raising any single knob (cores/cpu/gpu/mem) must not increase time
    let spec = DeviceKind::OrinAgx.spec();
    let gen = mode_gen(DeviceKind::OrinAgx);
    let wl = Workload::resnet();
    let idx = |tbl: &[u32], v: u32| tbl.iter().position(|&x| x == v).unwrap();
    forall(3, 300, &gen, |m| {
        let t0 = minibatch_time_ms(spec, &wl, m).total_ms;
        let mut ok = true;
        if m.cores < spec.max_cores {
            let up = PowerMode { cores: m.cores + 1, ..*m };
            ok &= minibatch_time_ms(spec, &wl, &up).total_ms <= t0 + 1e-9;
        }
        let ci = idx(spec.cpu_khz, m.cpu_khz);
        if ci + 1 < spec.cpu_khz.len() {
            let up = PowerMode { cpu_khz: spec.cpu_khz[ci + 1], ..*m };
            ok &= minibatch_time_ms(spec, &wl, &up).total_ms <= t0 + 1e-9;
        }
        let gi = idx(spec.gpu_khz, m.gpu_khz);
        if gi + 1 < spec.gpu_khz.len() {
            let up = PowerMode { gpu_khz: spec.gpu_khz[gi + 1], ..*m };
            ok &= minibatch_time_ms(spec, &wl, &up).total_ms <= t0 + 1e-9;
        }
        let mi = idx(spec.mem_khz, m.mem_khz);
        if mi + 1 < spec.mem_khz.len() {
            let up = PowerMode { mem_khz: spec.mem_khz[mi + 1], ..*m };
            ok &= minibatch_time_ms(spec, &wl, &up).total_ms <= t0 + 1e-9;
        }
        ok
    });
}

#[test]
fn prop_pareto_front_is_minimal_and_nondominated() {
    let point_gen = Gen::new(|r: &mut Rng| Point {
        mode: PowerMode::maxn(DeviceKind::OrinAgx.spec()),
        time: r.uniform_range(1.0, 1000.0),
        power_mw: r.uniform_range(5_000.0, 60_000.0),
    });
    let cloud_gen = vec_of(point_gen, 1, 200);
    forall(4, 200, &cloud_gen, |pts| {
        let front = ParetoFront::build(pts);
        // valid ordering + no candidate dominates a front point
        front.is_valid()
            && front.points().iter().all(|fp| {
                !pts.iter().any(|c| c.time < fp.time && c.power_mw < fp.power_mw)
            })
            // every candidate is dominated-or-equal by some front point
            && pts.iter().all(|c| {
                front
                    .points()
                    .iter()
                    .any(|fp| fp.time <= c.time && fp.power_mw <= c.power_mw)
            })
    });
}

#[test]
fn prop_optimize_respects_budget_and_is_tight() {
    let point_gen = Gen::new(|r: &mut Rng| Point {
        mode: PowerMode::maxn(DeviceKind::OrinAgx.spec()),
        time: r.uniform_range(1.0, 1000.0),
        power_mw: r.uniform_range(5_000.0, 60_000.0),
    });
    let case_gen = Gen::new(move |r: &mut Rng| {
        let pts: Vec<Point> = (0..(1 + r.below(100))).map(|_| point_gen.sample(r)).collect();
        let budget = r.uniform_range(4_000.0, 70_000.0);
        (pts, budget)
    });
    forall(5, 300, &case_gen, |(pts, budget)| {
        let front = ParetoFront::build(pts);
        match front.optimize(*budget) {
            Err(_) => pts.iter().all(|p| p.power_mw > *budget),
            Ok(sol) => {
                sol.power_mw <= *budget
                    && pts
                        .iter()
                        .filter(|p| p.power_mw <= *budget)
                        .all(|p| sol.time <= p.time + 1e-9)
            }
        }
    });
}

#[test]
fn prop_profiling_plan_is_permutation_with_safe_segments() {
    let gen = vec_of(mode_gen(DeviceKind::OrinAgx), 1, 120);
    forall(6, 150, &gen, |modes| {
        let plan = ProfilingPlan::build(modes);
        if plan.steps.len() != modes.len() {
            return false;
        }
        // permutation check via sorted copies
        let mut a: Vec<_> = modes.to_vec();
        let mut b: Vec<_> = plan.steps.iter().map(|s| s.mode).collect();
        let key = |m: &PowerMode| (m.cores, m.cpu_khz, m.gpu_khz, m.mem_khz);
        a.sort_by_key(key);
        b.sort_by_key(key);
        if a != b {
            return false;
        }
        // between reboots, cpu & gpu frequencies never rise
        plan.steps.windows(2).all(|w| {
            w[1].reboot
                || (w[1].mode.cpu_khz <= w[0].mode.cpu_khz
                    && w[1].mode.gpu_khz <= w[0].mode.gpu_khz)
        })
    });
}

#[test]
fn prop_scaler_inverse_identity() {
    let row_gen = vec_of(f64_in(-1e6, 1e6), 4, 4);
    let data_gen = vec_of(row_gen, 2, 50);
    forall(7, 200, &data_gen, |rows| {
        let sc = StandardScaler::fit(rows);
        rows.iter().all(|r| {
            sc.inverse_row(&sc.transform_row(r))
                .iter()
                .zip(r)
                .all(|(a, b)| (a - b).abs() <= 1e-6 * b.abs().max(1.0))
        })
    });
}

#[test]
fn prop_json_round_trip_fuzz() {
    // random JSON-ish trees survive serialize -> parse -> serialize
    fn value_gen(depth: usize) -> Gen<Value> {
        Gen::new(move |r: &mut Rng| rand_value(r, depth))
    }
    fn rand_value(r: &mut Rng, depth: usize) -> Value {
        match if depth == 0 { r.below(4) } else { r.below(6) } {
            0 => Value::Null,
            1 => Value::Bool(r.bernoulli(0.5)),
            2 => Value::Num((r.normal() * 1e3 * 256.0).round() / 256.0),
            3 => Value::Str(format!("s{}\"\\\n{}", r.next_u32(), r.below(10))),
            4 => Value::Arr((0..r.below(5)).map(|_| rand_value(r, depth - 1)).collect()),
            _ => Value::Obj(
                (0..r.below(5))
                    .map(|i| (format!("k{i}"), rand_value(r, depth - 1)))
                    .collect(),
            ),
        }
    }
    forall(8, 300, &value_gen(3), |v| {
        let text = v.to_string();
        match Value::parse(&text) {
            Ok(back) => back == *v && back.to_string() == text,
            Err(_) => false,
        }
    });
}

#[test]
fn prop_stabilization_index_is_sound() {
    // whatever index it returns, the window starting there is stable
    let gen = vec_of(usize_in(1_000, 60_000), 0, 40).map(|v| {
        v.into_iter().map(|x| x as u32).collect::<Vec<u32>>()
    });
    forall(9, 400, &gen, |samples: &Vec<u32>| {
        match stabilization_index(samples) {
            None => true,
            Some(idx) => {
                let w = &samples[idx..idx + 3];
                let lo = *w.iter().min().unwrap() as f64;
                let hi = *w.iter().max().unwrap() as f64;
                idx + 3 <= samples.len() && (hi - lo) / hi <= 0.04
            }
        }
    });
}

#[test]
fn prop_corpus_split_partitions() {
    use powertrain::profiler::{Corpus, Record};
    let frac_gen = one_of(vec![0.5, 0.8, 0.9, 1.0]);
    let case_gen = Gen::new(move |r: &mut Rng| {
        let n = 2 + r.below(200);
        (n, frac_gen.sample(r), r.next_u64())
    });
    forall(10, 200, &case_gen, |&(n, frac, seed)| {
        let _spec = DeviceKind::OrinAgx.spec();
        let grid = PowerModeGrid::paper_subset(DeviceKind::OrinAgx);
        let mut c = Corpus::new(DeviceKind::OrinAgx, Workload::resnet());
        for i in 0..n {
            c.push(Record {
                mode: grid.modes[i % grid.len()],
                time_ms: i as f64 + 1.0,
                power_mw: 1000.0 + i as f64,
                cost_s: 0.0,
            });
        }
        let mut rng = Rng::new(seed);
        let (train, val) = c.split(frac, &mut rng);
        train.len() + val.len() == n
            && (train.len() as f64 - n as f64 * frac).abs() <= 1.0
    });
}
