//! Integration: the profiling pipeline end-to-end — reboot-aware plans,
//! corpus persistence, fault tolerance.

use powertrain::device::{DeviceKind, PowerModeGrid, ProfilingPlan};
use powertrain::profiler::{Corpus, Profiler};
use powertrain::sim::{FaultConfig, TrainerSim};
use powertrain::util::rng::Rng;
use powertrain::workload::Workload;

#[test]
fn corpus_round_trips_through_csv_after_profiling() {
    let spec = DeviceKind::OrinAgx.spec();
    let mut rng = Rng::new(2);
    let modes = PowerModeGrid::paper_subset(DeviceKind::OrinAgx).sample(30, &mut rng);
    let mut profiler = Profiler::new(TrainerSim::new(spec, Workload::yolo(), 2));
    let corpus = profiler.profile_modes(&modes).unwrap();

    let dir = std::env::temp_dir().join("pt_integration_corpus");
    let path = dir.join("yolo.csv");
    corpus.save(&path).unwrap();
    let loaded = Corpus::load(&path).unwrap();
    assert_eq!(loaded.len(), corpus.len());
    assert_eq!(loaded.workload, corpus.workload);
    for (a, b) in loaded.records().iter().zip(corpus.records()) {
        assert_eq!(a.mode, b.mode);
        assert!((a.time_ms - b.time_ms).abs() < 0.01);
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn profiling_plan_cost_includes_reboots() {
    let spec = DeviceKind::OrinAgx.spec();
    let mut rng = Rng::new(3);
    let modes = PowerModeGrid::paper_subset(DeviceKind::OrinAgx).sample(60, &mut rng);
    let plan = ProfilingPlan::build(&modes);
    let reboots = plan.reboot_count();

    let mut profiler = Profiler::new(TrainerSim::new(spec, Workload::resnet(), 3));
    let corpus = profiler.profile_modes(&modes).unwrap();
    // total cost must include ~45 s per reboot on top of the training time
    let reboot_s = reboots as f64 * profiler.reboot_cost_s;
    assert!(
        corpus.total_cost_s() > reboot_s,
        "cost {:.0}s vs reboot share {reboot_s:.0}s",
        corpus.total_cost_s()
    );
}

#[test]
fn profiler_survives_sensor_dropouts() {
    let spec = DeviceKind::OrinAgx.spec();
    let sim = TrainerSim::new(spec, Workload::resnet(), 4).with_faults(FaultConfig {
        sensor_dropout_prob: 0.3,
        ..Default::default()
    });
    let mut profiler = Profiler::new(sim);
    let mut rng = Rng::new(4);
    let modes = PowerModeGrid::paper_subset(DeviceKind::OrinAgx).sample(15, &mut rng);
    let corpus = profiler.profile_modes(&modes).unwrap();
    assert_eq!(corpus.len(), 15);
    // power values still close to truth despite 30% dropped samples
    for r in corpus.records() {
        let truth = profiler.sim.true_power_mw(&r.mode);
        assert!(
            (r.power_mw - truth).abs() / truth < 0.08,
            "{}: {} vs {truth}",
            r.mode.label(),
            r.power_mw
        );
    }
}

#[test]
fn profiling_cost_scales_with_mode_slowness() {
    let spec = DeviceKind::OrinAgx.spec();
    let slow = powertrain::device::PowerMode {
        cores: 2,
        cpu_khz: spec.cpu_khz[2],
        gpu_khz: spec.gpu_khz[0],
        mem_khz: spec.mem_khz[0],
    };
    let fast = powertrain::device::PowerMode::maxn(spec);
    let mut profiler = Profiler::new(TrainerSim::new(spec, Workload::resnet(), 5));
    let slow_prof = profiler.profile_mode(&slow, false).unwrap();
    let fast_prof = profiler.profile_mode(&fast, false).unwrap();
    assert!(
        slow_prof.cost_s > 2.0 * fast_prof.cost_s,
        "slow {:.1}s fast {:.1}s",
        slow_prof.cost_s,
        fast_prof.cost_s
    );
}

#[test]
fn per_workload_profiling_costs_differ() {
    // data-collection overhead (Figs 7/8 right axis) is workload-specific:
    // BERT minibatches are ~90x LSTM's
    let spec = DeviceKind::OrinAgx.spec();
    let mut rng = Rng::new(6);
    let modes = PowerModeGrid::paper_subset(DeviceKind::OrinAgx).sample(10, &mut rng);
    let cost = |wl: Workload, seed: u64| {
        let mut p = Profiler::new(TrainerSim::new(spec, wl, seed));
        p.profile_modes(&modes).unwrap().total_cost_s()
    };
    let bert = cost(Workload::bert(), 7);
    let lstm = cost(Workload::lstm(), 8);
    assert!(bert > 5.0 * lstm, "bert {bert:.0}s vs lstm {lstm:.0}s");
}
