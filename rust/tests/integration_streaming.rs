//! Integration: the streaming coordinator service — singleflight
//! fitting under concurrent identical load, priority/deadline
//! scheduling, deterministic response ordering, the per-request
//! failure ledger, and resilient serving under scripted fault plans
//! (retries, circuit breaking, graceful degradation, thermal drift).
//!
//! Reference models are cheap untrained checkpoints (the fit dynamics
//! under test are the coordinator's, not the models'); scales are
//! reduced so `cargo test` stays fast.

use std::sync::atomic::Ordering;
use std::sync::Arc;

use powertrain::coordinator::{
    serve, Coordinator, CoordinatorConfig, Feedback, Job, LifecycleConfig, Metrics, ModelState,
    Provenance, ReferenceModels, Request, Scenario, ThermalConfig,
};
use powertrain::device::DeviceKind;
use powertrain::error::Error;
use powertrain::nn::{checkpoint::Checkpoint, MlpParams};
use powertrain::profiler::StandardScaler;
use powertrain::sim::thermal::ThermalModel;
use powertrain::sim::{FaultInjector, FaultPlan};
use powertrain::util::rng::Rng;
use powertrain::workload::Workload;

fn reference() -> ReferenceModels {
    let mut rng = Rng::new(17);
    let ck = |target: &str| Checkpoint {
        params: MlpParams::init_he(&mut rng),
        feature_scaler: StandardScaler {
            mean: vec![6.0, 1400.0, 800.0, 2000.0],
            std: vec![3.5, 600.0, 350.0, 1100.0],
        },
        target_scaler: StandardScaler { mean: vec![30_000.0], std: vec![9_000.0] },
        target: target.into(),
        provenance: "streaming-test".into(),
        val_loss: 0.0,
    };
    ReferenceModels { time: ck("time"), power: ck("power") }
}

fn cfg(grid: usize, workers: usize) -> CoordinatorConfig {
    CoordinatorConfig {
        prediction_grid: Some(grid),
        transfer_epochs: 6,
        workers,
        ..Default::default()
    }
}

fn request(id: u64, scenario: Scenario, seed: u64) -> Request {
    Request {
        id,
        device: DeviceKind::OrinAgx,
        workload: Workload::mobilenet(),
        power_budget_w: 1e6, // any front point qualifies
        scenario,
        affinity: None,
        node: None,
        seed,
    }
}

/// Acceptance: a burst of N identical concurrent requests performs
/// exactly ONE host fit pair (singleflight) and N−1 cache hits, with all
/// responses bit-identical and exactly one request charged the profiling
/// cost.
#[test]
fn burst_of_identical_requests_costs_exactly_one_fit() {
    const N: u64 = 8;
    let reference = reference();
    let c = cfg(300, N as usize); // one worker per request: maximal overlap
    let (coordinator, submitter) = Coordinator::start(&c, &reference).unwrap();
    for i in 0..N {
        submitter.send_request(request(i, Scenario::FederatedLearning, 5)).unwrap();
    }
    drop(submitter);
    let (responses, metrics) = coordinator.finish().unwrap();
    assert_eq!(responses.len(), N as usize);

    // exactly one model build: one miss, one 50-mode profiling run, one
    // transfer pair — no matter how the N workers interleaved
    assert_eq!(metrics.model_cache_misses.load(Ordering::Relaxed), 1);
    assert_eq!(metrics.model_cache_hits.load(Ordering::Relaxed), N - 1);
    assert_eq!(metrics.host_fits.load(Ordering::Relaxed), 2);
    assert_eq!(metrics.modes_profiled.load(Ordering::Relaxed), 50);
    assert_eq!(metrics.plane_cache_misses.load(Ordering::Relaxed), 1);
    assert_eq!(metrics.plane_cache_hits.load(Ordering::Relaxed), N - 1);

    // responses are sorted by id and bit-identical across the burst
    let ids: Vec<u64> = responses.iter().map(|r| r.id).collect();
    assert_eq!(ids, (0..N).collect::<Vec<_>>());
    for r in &responses[1..] {
        assert_eq!(r.chosen_mode, responses[0].chosen_mode);
        assert_eq!(r.predicted_time_ms.to_bits(), responses[0].predicted_time_ms.to_bits());
        assert_eq!(r.predicted_power_w.to_bits(), responses[0].predicted_power_w.to_bits());
    }
    // profiling cost is charged to exactly the request that led the fit
    let paid = responses.iter().filter(|r| r.profiling_cost_s > 0.0).count();
    assert_eq!(paid, 1, "exactly one request must be charged the profiling cost");
}

/// A short federated request submitted *after* a brute-force profiling
/// job overtakes it: both are parked with the same future arrival, so
/// the single worker sees them together and must pop by priority.
#[test]
fn federated_request_overtakes_queued_brute_force() {
    let reference = reference();
    let c = cfg(60, 1);
    let (coordinator, submitter) = Coordinator::start(&c, &reference).unwrap();
    // generous arrival margin: both jobs are enqueued (microseconds)
    // long before they become schedulable (400 ms)
    submitter
        .send(Job::arriving(request(0, Scenario::OneTimeTraining, 3), 400))
        .unwrap();
    submitter
        .send(Job::arriving(request(1, Scenario::FederatedLearning, 3), 400))
        .unwrap();
    drop(submitter);
    let (responses, metrics) = coordinator.finish().unwrap();
    assert_eq!(responses.len(), 2);
    // the scheduler's observable decision: federated completed first
    assert_eq!(metrics.completion_order(), vec![1, 0]);
    // but the returned batch is id-sorted regardless
    assert_eq!(responses[0].id, 0);
    assert_eq!(responses[0].strategy, "brute-force");
    assert_eq!(responses[1].id, 1);
    assert_eq!(responses[1].strategy, "powertrain-50(host)");
}

/// Satellite regression: per-request errors beyond the first used to be
/// dropped and a partially-failed batch still looked fully Ok. Every
/// failure id + message is now in the metrics ledger.
#[test]
fn partial_failures_are_all_reported() {
    let reference = reference();
    let c = cfg(200, 2);
    let requests = vec![
        request(0, Scenario::FederatedLearning, 7),
        // infeasible budget: fails at the Pareto query
        Request { power_budget_w: 2.0, ..request(1, Scenario::FederatedLearning, 7) },
        // malformed budget: rejected at admission
        Request { power_budget_w: -1.0, ..request(2, Scenario::FederatedLearning, 7) },
        request(3, Scenario::FederatedLearning, 8),
    ];
    let (responses, metrics) = serve(&c, &reference, requests).unwrap();
    let ids: Vec<u64> = responses.iter().map(|r| r.id).collect();
    assert_eq!(ids, vec![0, 3], "failed requests must not produce responses");
    // BOTH failures are recorded, id-ordered, with their messages
    assert_eq!(metrics.failed_ids(), vec![1, 2]);
    assert_eq!(metrics.requests_failed.load(Ordering::Relaxed), 2);
    assert_eq!(metrics.admission_rejected.load(Ordering::Relaxed), 1);
    let failed = metrics.failed_requests();
    assert!(failed.iter().all(|(_, msg)| !msg.is_empty()));
    // and the render line surfaces them for cmd_serve output
    assert!(metrics.render().contains("failed ids: [1, 2]"), "{}", metrics.render());
}

#[test]
fn all_failed_batch_is_an_error() {
    let reference = reference();
    let c = cfg(100, 1);
    let err = serve(
        &c,
        &reference,
        vec![Request { power_budget_w: f64::NAN, ..request(0, Scenario::FederatedLearning, 3) }],
    )
    .unwrap_err();
    assert!(matches!(err, Error::Usage(_)), "admission rejection expected: {err}");
}

/// Tentpole acceptance: the full serve → observe → refit loop. Drifted
/// feedback flips a served model Fresh→Stale; exactly ONE background
/// warm refit runs (the in-flight marker makes enqueueing singleflight,
/// however many drifted observations arrive); serving continues —
/// un-blocked — while the refit is deliberately held open (asserted via
/// completion order: the concurrent requests finish before the refit
/// publishes, still answered by the old version bit-for-bit); and once
/// the refit lands, responses come from the new model version with the
/// dependent plane invalidated and rebuilt (plane fingerprints change).
#[test]
fn drifted_feedback_triggers_one_warm_refit_without_blocking_serving() {
    let reference = reference();
    let c = CoordinatorConfig {
        lifecycle: Some(LifecycleConfig {
            trip_override_pct: Some(25.0),
            min_observations: 4,
            window: 8,
            refit_epochs: 50,
            // hold the refit open long enough that the concurrent
            // requests below *must* complete while it is in flight —
            // "serving never blocks on a refit" becomes deterministic
            refit_delay_ms: 400,
            ..Default::default()
        }),
        ..cfg(200, 2)
    };
    let (coordinator, submitter) = Coordinator::start(&c, &reference).unwrap();
    let lifecycle = coordinator.lifecycle().expect("lifecycle enabled");
    let metrics = coordinator.metrics();
    let req = |id: u64| request(id, Scenario::ContinuousLearning, 9);

    // round 1: the cold fit — version 1 serves
    submitter.send_request(req(0)).unwrap();
    let first = coordinator.recv_result().expect("worker alive").1.unwrap();
    assert_eq!(lifecycle.status(&req(0)).expect("model resident").version, 1);

    // drifted outcomes: observed values are 2× the predictions (guarded
    // positive — feedback validates its inputs), so every scored APE is
    // ≥50% — strictly above the 25% trip threshold
    for _ in 0..6 {
        submitter
            .report(Feedback {
                request: req(0),
                mode: first.chosen_mode,
                time_ms: (first.predicted_time_ms * 2.0).abs().max(1.0),
                power_mw: (first.predicted_power_w * 1000.0 * 2.0).abs().max(1.0),
            })
            .unwrap();
    }
    // exactly one trip despite 3 post-quorum breaching observations
    // (singleflight: the in-flight marker absorbs the rest)
    assert_eq!(metrics.drift_trips.load(Ordering::Relaxed), 1);
    let status = lifecycle.status(&req(0)).unwrap();
    assert_eq!(status.state, ModelState::Stale);
    assert_eq!(status.version, 1, "still the old version until the refit publishes");

    // serving continues while the (held) refit trains: these cache hits
    // must all complete first, answered by the old version bit-for-bit
    for id in 1..=4 {
        submitter.send_request(req(id)).unwrap();
    }
    let mut during = Vec::new();
    for _ in 0..4 {
        during.push(coordinator.recv_result().unwrap().1.unwrap());
    }
    assert_eq!(
        metrics.refits.load(Ordering::Relaxed),
        0,
        "completion order: all 4 requests finished before the held refit published"
    );
    for r in &during {
        assert_eq!(
            r.predicted_time_ms.to_bits(),
            first.predicted_time_ms.to_bits(),
            "pre-publish responses must come from the old version"
        );
    }
    // staleness exposure is accounted where it happened
    assert_eq!(metrics.stale_served.load(Ordering::Relaxed), 4);

    // let the background refit land
    lifecycle.wait_idle();
    assert_eq!(metrics.refits.load(Ordering::Relaxed), 1, "exactly one refit");
    let status = lifecycle.status(&req(0)).unwrap();
    assert_eq!(status.state, ModelState::Fresh, "published refit resets the monitor");
    assert_eq!(status.version, 2, "version is bumped monotonically");

    // post-refit: the same key now resolves the new version; its plane
    // key moved with the checkpoint fingerprints, so the old plane was
    // invalidated and a fresh one is built — and predictions change
    // (the refit trained toward the 2× observations)
    let planes_before = metrics.plane_cache_misses.load(Ordering::Relaxed);
    submitter.send_request(req(5)).unwrap();
    let after = coordinator.recv_result().unwrap().1.unwrap();
    assert_ne!(
        after.predicted_time_ms.to_bits(),
        first.predicted_time_ms.to_bits(),
        "post-refit predictions must come from the refitted model"
    );
    assert_eq!(
        metrics.plane_cache_misses.load(Ordering::Relaxed),
        planes_before + 1,
        "the dependent plane was invalidated atomically and rebuilt for the new version"
    );
    assert_eq!(metrics.model_cache_misses.load(Ordering::Relaxed), 1, "no re-fit on serve");
    assert_eq!(metrics.feedback_observations.load(Ordering::Relaxed), 6);

    drop(submitter);
    let (_, metrics) = coordinator.finish().unwrap();
    assert_eq!(metrics.requests_completed.load(Ordering::Relaxed), 6);
}

/// Deadline accounting: a cold fit cannot possibly finish within a 0 ms
/// deadline, while a best-effort job never counts as a miss.
#[test]
fn deadline_misses_are_counted() {
    let reference = reference();
    let c = CoordinatorConfig { transfer_epochs: 30, ..cfg(400, 1) };
    let (coordinator, submitter) = Coordinator::start(&c, &reference).unwrap();
    submitter
        .send(Job::immediate(request(0, Scenario::FederatedLearning, 21)).with_deadline(0))
        .unwrap();
    // best-effort control on the same (already warm) model key
    submitter.send(Job::immediate(request(1, Scenario::FederatedLearning, 21))).unwrap();
    // a generous deadline the warm cache-hit path easily meets
    submitter
        .send(Job::immediate(request(2, Scenario::FederatedLearning, 21)).with_deadline(60_000))
        .unwrap();
    drop(submitter);
    let (responses, metrics) = coordinator.finish().unwrap();
    assert_eq!(responses.len(), 3);
    assert_eq!(metrics.deadline_misses.load(Ordering::Relaxed), 1);
}

// ------------------------------------------------------------------
// fault injection + resilient serving

/// Every counter that must reproduce bit-identically run-to-run under
/// the same fault plan. Wall-clock-dependent metrics (latency, real
/// profiling seconds) are deliberately excluded — they are the only
/// nondeterministic ones.
fn counter_snapshot(m: &Metrics) -> Vec<u64> {
    [
        &m.requests_received,
        &m.requests_completed,
        &m.requests_failed,
        &m.admission_rejected,
        &m.modes_profiled,
        &m.plane_cache_hits,
        &m.plane_cache_misses,
        &m.model_cache_hits,
        &m.model_cache_misses,
        &m.host_fits,
        &m.deadline_misses,
        &m.feedback_observations,
        &m.drift_trips,
        &m.refits,
        &m.stale_served,
        &m.retries,
        &m.breaker_transitions,
        &m.degraded_served,
        &m.thermal_throttle_events,
    ]
    .iter()
    .map(|c| c.load(Ordering::Relaxed))
    .collect()
}

/// Tentpole acceptance: a no-op fault plan is bit-identical to serving
/// with no injector at all. The fault layer must add zero behavioral
/// footprint when it injects nothing — every response field and every
/// deterministic counter matches the uninjected run exactly.
#[test]
fn noop_fault_plan_is_bit_identical_to_an_uninjected_run() {
    let reference = reference();
    let stream = || {
        vec![
            request(0, Scenario::FederatedLearning, 5),
            request(1, Scenario::ContinuousLearning, 6),
            request(2, Scenario::FineTuning, 7),
            request(3, Scenario::OneTimeTraining, 8),
            request(4, Scenario::FederatedLearning, 5), // warm cache hit
        ]
    };
    let run = |faults: Option<Arc<FaultInjector>>| {
        let c = CoordinatorConfig { faults, ..cfg(120, 1) };
        serve(&c, &reference, stream()).unwrap()
    };
    let plan = FaultPlan::default();
    assert!(plan.is_noop(), "the default plan must be the no-op plan");
    let (base, base_m) = run(None);
    let (noop, noop_m) = run(Some(Arc::new(FaultInjector::new(plan))));
    assert_eq!(base.len(), 5);
    assert_eq!(noop.len(), 5);
    for (a, b) in base.iter().zip(&noop) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.strategy, b.strategy);
        assert_eq!(a.provenance, b.provenance);
        assert_eq!(a.chosen_mode, b.chosen_mode);
        assert_eq!(a.predicted_time_ms.to_bits(), b.predicted_time_ms.to_bits());
        assert_eq!(a.predicted_power_w.to_bits(), b.predicted_power_w.to_bits());
        assert_eq!(a.observed_time_ms.to_bits(), b.observed_time_ms.to_bits());
        assert_eq!(a.observed_power_w.to_bits(), b.observed_power_w.to_bits());
        assert_eq!(a.profiling_cost_s.to_bits(), b.profiling_cost_s.to_bits());
    }
    assert_eq!(counter_snapshot(&base_m), counter_snapshot(&noop_m));
    assert!(base.iter().all(|r| r.provenance == Provenance::Primary));
    assert_eq!(base_m.retries.load(Ordering::Relaxed), 0);
    assert_eq!(base_m.degraded_served.load(Ordering::Relaxed), 0);
}

/// Tentpole e2e acceptance: one serving run under a plan combining
/// transient fit failures, a permanently failing model, an injected
/// worker panic, a corrupted checkpoint, and a fan-off thermal episode.
/// The coordinator must
///
/// 1. answer EVERY request (degraded where necessary, no hangs),
/// 2. open exactly one circuit breaker — the permanent-failure key,
/// 3. trip the drift monitor organically from thermally dilated
///    observations and recover through one background warm refit,
/// 4. reproduce responses and counters bit-identically when the same
///    plan + seeds run a second time.
#[test]
fn chaos_plan_serves_everything_opens_one_breaker_and_recovers_from_thermal_drift() {
    let reference = reference();

    // Probe run — the phase-B model pair served clean (no faults, no
    // thermal). Serving is deterministic, so this reveals exactly which
    // APE a throttle-dilated observation of the same key will score
    // against the same predictions: the drift trip threshold can then be
    // placed strictly between the clean and the dilated score, making
    // the "thermal throttling trips drift" phase well-posed regardless
    // of how accurate the fitted pair happens to be.
    let nofan_ceiling_w =
        ThermalModel { fan_max: false, ..Default::default() }.max_sustainable_mw() / 1000.0;
    let probe_cfg = CoordinatorConfig { transfer_epochs: 40, ..cfg(200, 1) };
    let (probe, _) = serve(
        &probe_cfg,
        &reference,
        vec![
            request(0, Scenario::ContinuousLearning, 400),
            // feasibility under the fan-off ceiling: the clamped phase
            // below needs at least one front point this cheap
            Request {
                power_budget_w: nofan_ceiling_w,
                ..request(1, Scenario::ContinuousLearning, 400)
            },
        ],
    )
    .unwrap();
    assert_eq!(probe.len(), 2, "scenario precondition: fan-off ceiling must be feasible");
    let clean_resp = &probe[0];
    assert!(
        clean_resp.predicted_power_w > nofan_ceiling_w,
        "scenario precondition: the unclamped choice must exceed the fan-off ceiling \
         ({} W vs {nofan_ceiling_w} W), otherwise clamping is unobservable",
        clean_resp.predicted_power_w
    );
    // …but stay under the fan-ON ceiling, so the thermally guarded run
    // picks the identical mode while the fan still spins
    let fan_on_ceiling_w = ThermalModel::default().max_sustainable_mw() / 1000.0;
    assert!(
        clean_resp.predicted_power_w < fan_on_ceiling_w,
        "scenario precondition: the unclamped choice must fit the fan-on ceiling \
         ({} W vs {fan_on_ceiling_w} W)",
        clean_resp.predicted_power_w
    );
    let ape = |pred: f64, obs: f64| 100.0 * ((pred - obs) / obs).abs();
    // the monitor scores max(time APE, power APE); throttling dilates
    // observed time by 1/0.7 and observed power by 0.7
    let clean_score = ape(clean_resp.predicted_time_ms, clean_resp.observed_time_ms)
        .max(ape(clean_resp.predicted_power_w, clean_resp.observed_power_w));
    let dilated_score = ape(clean_resp.predicted_time_ms, clean_resp.observed_time_ms / 0.7)
        .max(ape(clean_resp.predicted_power_w, clean_resp.observed_power_w * 0.7));
    assert!(
        dilated_score > clean_score,
        "scenario precondition: throttle dilation must dominate the pair's own error \
         (clean {clean_score:.2}% vs dilated {dilated_score:.2}%)"
    );
    let trip_pct = (clean_score + dilated_score) / 2.0;

    let plan = FaultPlan {
        seed: 41,
        fit_fail_pct: 1.0, // every cold build fails once…
        fit_streak: 1,     // …and deterministically clears on retry
        permanent_fit_seeds: vec![777],
        corrupt_fit_seeds: vec![888],
        panic_request_ids: vec![13],
        // [1000 s, 1250 s): hits the phase-B window (7 phase-A responses
        // × 120 s slices put the clock at 840 s when phase B starts) and
        // ends before the post-refit request, which must serve unclamped
        fan_off_s: vec![(1000.0, 1250.0)],
        ..FaultPlan::default()
    };

    let run = |plan: &FaultPlan| -> (Vec<powertrain::coordinator::Response>, Vec<u64>) {
        let c = CoordinatorConfig {
            transfer_epochs: 40, // must match the probe: same ModelKey, same fit bits
            faults: Some(Arc::new(FaultInjector::new(plan.clone()))),
            thermal: Some(ThermalConfig { slice_s: 120.0 }), // 4× tau: slices park at steady state
            lifecycle: Some(LifecycleConfig {
                trip_override_pct: Some(trip_pct),
                min_observations: 2,
                window: 4,
                refit_epochs: 12,
                refit_delay_ms: 150, // hold the refit long enough to observe Stale
                ..Default::default()
            }),
            ..cfg(200, 1)
        };
        let (coordinator, submitter) = Coordinator::start(&c, &reference).unwrap();
        let lifecycle = coordinator.lifecycle().expect("lifecycle enabled");
        let metrics = coordinator.metrics();
        let mut responses = Vec::new();
        let mut ask = |req: Request| {
            submitter.send_request(req).unwrap();
            let resp = coordinator.recv_result().expect("worker alive").1.unwrap();
            responses.push(resp.clone());
            resp
        };

        // phase A — resilience. Three permanent fit failures on the same
        // key open its breaker; each is still answered by the ridge rung.
        for id in 1..=3u64 {
            let r = ask(request(id, Scenario::ContinuousLearning, 777));
            assert_eq!(r.provenance, Provenance::DegradedRidge, "id {id}");
            assert_eq!(r.strategy, "ridge(degraded)");
        }
        assert_eq!(metrics.breaker_transitions.load(Ordering::Relaxed), 1);
        // the fourth is shed by the open breaker — answered without a build
        let r4 = ask(request(4, Scenario::ContinuousLearning, 777));
        assert_eq!(r4.provenance, Provenance::DegradedRidge);
        // injected worker panic: retried transparently to a primary answer
        let r13 = ask(request(13, Scenario::FederatedLearning, 301));
        assert_eq!(r13.provenance, Provenance::Primary);
        // corrupted checkpoint: caught by the fingerprint check, degraded
        let r20 = ask(request(20, Scenario::ContinuousLearning, 888));
        assert_eq!(r20.provenance, Provenance::DegradedRidge);
        // plain transient fit failure: retried to a primary answer
        let r21 = ask(request(21, Scenario::FederatedLearning, 302));
        assert_eq!(r21.provenance, Provenance::Primary);

        let open = coordinator.cache().open_breakers();
        assert_eq!(open.len(), 1, "exactly one breaker must be open");
        assert_eq!(open[0].seed, 777, "…and it is the permanent-failure key");
        let thermal = coordinator.thermal().expect("thermal guard enabled");
        assert_eq!(metrics.thermal_throttle_events.load(Ordering::Relaxed), 0);

        // phase B — thermal. id 100 serves fan-on (clock 840 → 960 s) and
        // matches the probe bit-for-bit; id 101 queries the ceiling
        // against the (stale, one-slice-lagged) fan-on telemetry, runs
        // uncapped into the fan-off window (960 → 1080 s), trips the
        // throttle, and its observation comes back dilated by 1/0.7.
        let b = |id: u64| request(id, Scenario::ContinuousLearning, 400);
        let r100 = ask(b(100));
        assert_eq!(r100.provenance, Provenance::Primary);
        assert_eq!(r100.predicted_time_ms.to_bits(), clean_resp.predicted_time_ms.to_bits());
        assert_eq!(r100.observed_time_ms.to_bits(), clean_resp.observed_time_ms.to_bits());
        let r101 = ask(b(101));
        assert!(thermal.throttled(), "the uncapped hot slice must trip the throttle");
        assert_eq!(metrics.thermal_throttle_events.load(Ordering::Relaxed), 1);
        assert_eq!(r101.chosen_mode, r100.chosen_mode);
        assert!(
            (r101.observed_time_ms * 0.7 - r100.observed_time_ms).abs() < 1e-9,
            "throttled observation must be dilated by exactly 1/0.7"
        );
        // the guard's ceiling now reflects the fan loss: budgets clamp
        let ceiling_w = thermal.ceiling_mw() / 1000.0;
        assert!(ceiling_w < r100.predicted_power_w);
        let r102 = ask(b(102));
        assert!(r102.predicted_power_w <= ceiling_w + 1e-9, "clamped under the fan-off ceiling");
        assert!(r102.predicted_power_w < r100.predicted_power_w);
        let r103 = ask(b(103));
        assert!(r103.predicted_power_w <= ceiling_w + 1e-9);
        assert!(!thermal.throttled(), "shedding load must clear the throttle");

        // phase C — drift + recovery. The dilated outcome is reported as
        // executed-round feedback; two observations fill the quorum and
        // the rolling MAPE (== dilated score) strictly exceeds the trip
        // threshold parked below it.
        for _ in 0..2 {
            submitter
                .report(Feedback {
                    request: b(101),
                    mode: r101.chosen_mode,
                    time_ms: r101.observed_time_ms,
                    power_mw: r101.observed_power_w * 1000.0,
                })
                .unwrap();
        }
        assert_eq!(
            metrics.drift_trips.load(Ordering::Relaxed),
            1,
            "thermally dilated observations must trip the drift monitor"
        );
        assert_eq!(lifecycle.status(&b(101)).unwrap().state, ModelState::Stale);
        lifecycle.wait_idle();
        assert_eq!(metrics.refits.load(Ordering::Relaxed), 1, "exactly one warm refit");
        let status = lifecycle.status(&b(101)).unwrap();
        assert_eq!(status.state, ModelState::Fresh, "the published refit recovers the key");
        assert_eq!(status.version, 2);
        // recovered end-to-end: the key serves again (fan restored after
        // 1250 s, so the ceiling is back to the fan-on value)
        let r110 = ask(b(110));
        assert_eq!(r110.provenance, Provenance::Primary);

        assert_eq!(metrics.requests_failed.load(Ordering::Relaxed), 0);
        assert_eq!(metrics.degraded_served.load(Ordering::Relaxed), 5);
        assert_eq!(metrics.retries.load(Ordering::Relaxed), 4);
        drop(submitter);
        let (_, m) = coordinator.finish().unwrap();
        (responses, counter_snapshot(&m))
    };

    let (resp_a, counters_a) = run(&plan);
    let (resp_b, counters_b) = run(&plan);
    assert_eq!(resp_a.len(), 12, "every submitted request was answered");
    assert_eq!(counters_a, counters_b, "same plan + seeds ⇒ bit-identical counters");
    assert_eq!(resp_a.len(), resp_b.len());
    for (x, y) in resp_a.iter().zip(&resp_b) {
        assert_eq!(x.id, y.id);
        assert_eq!(x.strategy, y.strategy);
        assert_eq!(x.provenance, y.provenance);
        assert_eq!(x.chosen_mode, y.chosen_mode);
        assert_eq!(x.predicted_time_ms.to_bits(), y.predicted_time_ms.to_bits());
        assert_eq!(x.predicted_power_w.to_bits(), y.predicted_power_w.to_bits());
        assert_eq!(x.observed_time_ms.to_bits(), y.observed_time_ms.to_bits());
        assert_eq!(x.observed_power_w.to_bits(), y.observed_power_w.to_bits());
    }
}

/// CI chaos smoke: the committed `tests/faults_smoke.json` plan must
/// parse and be survivable — every request answered across three
/// request seeds and two workers, the retry and degradation machinery
/// both demonstrably exercised, and zero panics escaping the harness.
#[test]
fn committed_smoke_plan_is_survived_across_seeds() {
    let reference = reference();
    let path =
        std::path::Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/tests/faults_smoke.json"));
    let plan = FaultPlan::load(path).expect("committed smoke plan parses");
    assert!(!plan.is_noop(), "the smoke plan must actually inject faults");
    let c = CoordinatorConfig {
        faults: Some(Arc::new(FaultInjector::new(plan))),
        ..cfg(120, 2)
    };
    let mut requests = Vec::new();
    for id in 0..9u64 {
        let seed = [11, 12, 13][id as usize % 3];
        let scenario = [
            Scenario::FederatedLearning,
            Scenario::ContinuousLearning,
            Scenario::FineTuning,
        ][(id / 3) as usize];
        requests.push(request(id, scenario, seed));
    }
    let (responses, metrics) = serve(&c, &reference, requests).unwrap();
    assert_eq!(
        responses.len(),
        9,
        "every request must be answered; failures: {:?}",
        metrics.failed_requests()
    );
    assert_eq!(metrics.requests_failed.load(Ordering::Relaxed), 0);
    assert!(metrics.retries.load(Ordering::Relaxed) > 0, "smoke must exercise retries");
    assert!(
        metrics.degraded_served.load(Ordering::Relaxed) > 0,
        "smoke must exercise the degradation ladder"
    );
}
