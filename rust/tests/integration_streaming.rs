//! Integration: the streaming coordinator service — singleflight
//! fitting under concurrent identical load, priority/deadline
//! scheduling, deterministic response ordering, and the per-request
//! failure ledger.
//!
//! Reference models are cheap untrained checkpoints (the fit dynamics
//! under test are the coordinator's, not the models'); scales are
//! reduced so `cargo test` stays fast.

use std::sync::atomic::Ordering;

use powertrain::coordinator::{
    serve, Coordinator, CoordinatorConfig, Feedback, Job, LifecycleConfig, ModelState,
    ReferenceModels, Request, Scenario,
};
use powertrain::device::DeviceKind;
use powertrain::error::Error;
use powertrain::nn::{checkpoint::Checkpoint, MlpParams};
use powertrain::profiler::StandardScaler;
use powertrain::util::rng::Rng;
use powertrain::workload::Workload;

fn reference() -> ReferenceModels {
    let mut rng = Rng::new(17);
    let ck = |target: &str| Checkpoint {
        params: MlpParams::init_he(&mut rng),
        feature_scaler: StandardScaler {
            mean: vec![6.0, 1400.0, 800.0, 2000.0],
            std: vec![3.5, 600.0, 350.0, 1100.0],
        },
        target_scaler: StandardScaler { mean: vec![30_000.0], std: vec![9_000.0] },
        target: target.into(),
        provenance: "streaming-test".into(),
        val_loss: 0.0,
    };
    ReferenceModels { time: ck("time"), power: ck("power") }
}

fn cfg(grid: usize, workers: usize) -> CoordinatorConfig {
    CoordinatorConfig {
        prediction_grid: Some(grid),
        transfer_epochs: 6,
        workers,
        ..Default::default()
    }
}

fn request(id: u64, scenario: Scenario, seed: u64) -> Request {
    Request {
        id,
        device: DeviceKind::OrinAgx,
        workload: Workload::mobilenet(),
        power_budget_w: 1e6, // any front point qualifies
        scenario,
        seed,
    }
}

/// Acceptance: a burst of N identical concurrent requests performs
/// exactly ONE host fit pair (singleflight) and N−1 cache hits, with all
/// responses bit-identical and exactly one request charged the profiling
/// cost.
#[test]
fn burst_of_identical_requests_costs_exactly_one_fit() {
    const N: u64 = 8;
    let reference = reference();
    let c = cfg(300, N as usize); // one worker per request: maximal overlap
    let (coordinator, submitter) = Coordinator::start(&c, &reference).unwrap();
    for i in 0..N {
        submitter.send_request(request(i, Scenario::FederatedLearning, 5)).unwrap();
    }
    drop(submitter);
    let (responses, metrics) = coordinator.finish().unwrap();
    assert_eq!(responses.len(), N as usize);

    // exactly one model build: one miss, one 50-mode profiling run, one
    // transfer pair — no matter how the N workers interleaved
    assert_eq!(metrics.model_cache_misses.load(Ordering::Relaxed), 1);
    assert_eq!(metrics.model_cache_hits.load(Ordering::Relaxed), N - 1);
    assert_eq!(metrics.host_fits.load(Ordering::Relaxed), 2);
    assert_eq!(metrics.modes_profiled.load(Ordering::Relaxed), 50);
    assert_eq!(metrics.plane_cache_misses.load(Ordering::Relaxed), 1);
    assert_eq!(metrics.plane_cache_hits.load(Ordering::Relaxed), N - 1);

    // responses are sorted by id and bit-identical across the burst
    let ids: Vec<u64> = responses.iter().map(|r| r.id).collect();
    assert_eq!(ids, (0..N).collect::<Vec<_>>());
    for r in &responses[1..] {
        assert_eq!(r.chosen_mode, responses[0].chosen_mode);
        assert_eq!(r.predicted_time_ms.to_bits(), responses[0].predicted_time_ms.to_bits());
        assert_eq!(r.predicted_power_w.to_bits(), responses[0].predicted_power_w.to_bits());
    }
    // profiling cost is charged to exactly the request that led the fit
    let paid = responses.iter().filter(|r| r.profiling_cost_s > 0.0).count();
    assert_eq!(paid, 1, "exactly one request must be charged the profiling cost");
}

/// A short federated request submitted *after* a brute-force profiling
/// job overtakes it: both are parked with the same future arrival, so
/// the single worker sees them together and must pop by priority.
#[test]
fn federated_request_overtakes_queued_brute_force() {
    let reference = reference();
    let c = cfg(60, 1);
    let (coordinator, submitter) = Coordinator::start(&c, &reference).unwrap();
    // generous arrival margin: both jobs are enqueued (microseconds)
    // long before they become schedulable (400 ms)
    submitter
        .send(Job::arriving(request(0, Scenario::OneTimeTraining, 3), 400))
        .unwrap();
    submitter
        .send(Job::arriving(request(1, Scenario::FederatedLearning, 3), 400))
        .unwrap();
    drop(submitter);
    let (responses, metrics) = coordinator.finish().unwrap();
    assert_eq!(responses.len(), 2);
    // the scheduler's observable decision: federated completed first
    assert_eq!(metrics.completion_order(), vec![1, 0]);
    // but the returned batch is id-sorted regardless
    assert_eq!(responses[0].id, 0);
    assert_eq!(responses[0].strategy, "brute-force");
    assert_eq!(responses[1].id, 1);
    assert_eq!(responses[1].strategy, "powertrain-50(host)");
}

/// Satellite regression: per-request errors beyond the first used to be
/// dropped and a partially-failed batch still looked fully Ok. Every
/// failure id + message is now in the metrics ledger.
#[test]
fn partial_failures_are_all_reported() {
    let reference = reference();
    let c = cfg(200, 2);
    let requests = vec![
        request(0, Scenario::FederatedLearning, 7),
        // infeasible budget: fails at the Pareto query
        Request { power_budget_w: 2.0, ..request(1, Scenario::FederatedLearning, 7) },
        // malformed budget: rejected at admission
        Request { power_budget_w: -1.0, ..request(2, Scenario::FederatedLearning, 7) },
        request(3, Scenario::FederatedLearning, 8),
    ];
    let (responses, metrics) = serve(&c, &reference, requests).unwrap();
    let ids: Vec<u64> = responses.iter().map(|r| r.id).collect();
    assert_eq!(ids, vec![0, 3], "failed requests must not produce responses");
    // BOTH failures are recorded, id-ordered, with their messages
    assert_eq!(metrics.failed_ids(), vec![1, 2]);
    assert_eq!(metrics.requests_failed.load(Ordering::Relaxed), 2);
    assert_eq!(metrics.admission_rejected.load(Ordering::Relaxed), 1);
    let failed = metrics.failed_requests();
    assert!(failed.iter().all(|(_, msg)| !msg.is_empty()));
    // and the render line surfaces them for cmd_serve output
    assert!(metrics.render().contains("failed ids: [1, 2]"), "{}", metrics.render());
}

#[test]
fn all_failed_batch_is_an_error() {
    let reference = reference();
    let c = cfg(100, 1);
    let err = serve(
        &c,
        &reference,
        vec![Request { power_budget_w: f64::NAN, ..request(0, Scenario::FederatedLearning, 3) }],
    )
    .unwrap_err();
    assert!(matches!(err, Error::Usage(_)), "admission rejection expected: {err}");
}

/// Tentpole acceptance: the full serve → observe → refit loop. Drifted
/// feedback flips a served model Fresh→Stale; exactly ONE background
/// warm refit runs (the in-flight marker makes enqueueing singleflight,
/// however many drifted observations arrive); serving continues —
/// un-blocked — while the refit is deliberately held open (asserted via
/// completion order: the concurrent requests finish before the refit
/// publishes, still answered by the old version bit-for-bit); and once
/// the refit lands, responses come from the new model version with the
/// dependent plane invalidated and rebuilt (plane fingerprints change).
#[test]
fn drifted_feedback_triggers_one_warm_refit_without_blocking_serving() {
    let reference = reference();
    let c = CoordinatorConfig {
        lifecycle: Some(LifecycleConfig {
            trip_override_pct: Some(25.0),
            min_observations: 4,
            window: 8,
            refit_epochs: 50,
            // hold the refit open long enough that the concurrent
            // requests below *must* complete while it is in flight —
            // "serving never blocks on a refit" becomes deterministic
            refit_delay_ms: 400,
            ..Default::default()
        }),
        ..cfg(200, 2)
    };
    let (coordinator, submitter) = Coordinator::start(&c, &reference).unwrap();
    let lifecycle = coordinator.lifecycle().expect("lifecycle enabled");
    let metrics = coordinator.metrics();
    let req = |id: u64| request(id, Scenario::ContinuousLearning, 9);

    // round 1: the cold fit — version 1 serves
    submitter.send_request(req(0)).unwrap();
    let first = coordinator.recv_result().expect("worker alive").1.unwrap();
    assert_eq!(lifecycle.status(&req(0)).expect("model resident").version, 1);

    // drifted outcomes: observed values are 2× the predictions (guarded
    // positive — feedback validates its inputs), so every scored APE is
    // ≥50% — strictly above the 25% trip threshold
    for _ in 0..6 {
        submitter
            .report(Feedback {
                request: req(0),
                mode: first.chosen_mode,
                time_ms: (first.predicted_time_ms * 2.0).abs().max(1.0),
                power_mw: (first.predicted_power_w * 1000.0 * 2.0).abs().max(1.0),
            })
            .unwrap();
    }
    // exactly one trip despite 3 post-quorum breaching observations
    // (singleflight: the in-flight marker absorbs the rest)
    assert_eq!(metrics.drift_trips.load(Ordering::Relaxed), 1);
    let status = lifecycle.status(&req(0)).unwrap();
    assert_eq!(status.state, ModelState::Stale);
    assert_eq!(status.version, 1, "still the old version until the refit publishes");

    // serving continues while the (held) refit trains: these cache hits
    // must all complete first, answered by the old version bit-for-bit
    for id in 1..=4 {
        submitter.send_request(req(id)).unwrap();
    }
    let mut during = Vec::new();
    for _ in 0..4 {
        during.push(coordinator.recv_result().unwrap().1.unwrap());
    }
    assert_eq!(
        metrics.refits.load(Ordering::Relaxed),
        0,
        "completion order: all 4 requests finished before the held refit published"
    );
    for r in &during {
        assert_eq!(
            r.predicted_time_ms.to_bits(),
            first.predicted_time_ms.to_bits(),
            "pre-publish responses must come from the old version"
        );
    }
    // staleness exposure is accounted where it happened
    assert_eq!(metrics.stale_served.load(Ordering::Relaxed), 4);

    // let the background refit land
    lifecycle.wait_idle();
    assert_eq!(metrics.refits.load(Ordering::Relaxed), 1, "exactly one refit");
    let status = lifecycle.status(&req(0)).unwrap();
    assert_eq!(status.state, ModelState::Fresh, "published refit resets the monitor");
    assert_eq!(status.version, 2, "version is bumped monotonically");

    // post-refit: the same key now resolves the new version; its plane
    // key moved with the checkpoint fingerprints, so the old plane was
    // invalidated and a fresh one is built — and predictions change
    // (the refit trained toward the 2× observations)
    let planes_before = metrics.plane_cache_misses.load(Ordering::Relaxed);
    submitter.send_request(req(5)).unwrap();
    let after = coordinator.recv_result().unwrap().1.unwrap();
    assert_ne!(
        after.predicted_time_ms.to_bits(),
        first.predicted_time_ms.to_bits(),
        "post-refit predictions must come from the refitted model"
    );
    assert_eq!(
        metrics.plane_cache_misses.load(Ordering::Relaxed),
        planes_before + 1,
        "the dependent plane was invalidated atomically and rebuilt for the new version"
    );
    assert_eq!(metrics.model_cache_misses.load(Ordering::Relaxed), 1, "no re-fit on serve");
    assert_eq!(metrics.feedback_observations.load(Ordering::Relaxed), 6);

    drop(submitter);
    let (_, metrics) = coordinator.finish().unwrap();
    assert_eq!(metrics.requests_completed.load(Ordering::Relaxed), 6);
}

/// Deadline accounting: a cold fit cannot possibly finish within a 0 ms
/// deadline, while a best-effort job never counts as a miss.
#[test]
fn deadline_misses_are_counted() {
    let reference = reference();
    let c = CoordinatorConfig { transfer_epochs: 30, ..cfg(400, 1) };
    let (coordinator, submitter) = Coordinator::start(&c, &reference).unwrap();
    submitter
        .send(Job::immediate(request(0, Scenario::FederatedLearning, 21)).with_deadline(0))
        .unwrap();
    // best-effort control on the same (already warm) model key
    submitter.send(Job::immediate(request(1, Scenario::FederatedLearning, 21))).unwrap();
    // a generous deadline the warm cache-hit path easily meets
    submitter
        .send(Job::immediate(request(2, Scenario::FederatedLearning, 21)).with_deadline(60_000))
        .unwrap();
    drop(submitter);
    let (responses, metrics) = coordinator.finish().unwrap();
    assert_eq!(responses.len(), 3);
    assert_eq!(metrics.deadline_misses.load(Ordering::Relaxed), 1);
}
