//! Concurrency stress for the coordinator's lock-free snapshot read path:
//! N reader threads hammer [`PlaneCache::read_snapshot`] while a writer
//! storms `publish_models` republications. Every read must resolve a
//! *coherent* (models, plane) pair — the plane looked up by the resolved
//! models' own fingerprints must exist and carry that exact version's
//! payload — and each reader's observed publication version must be
//! monotonic (a reader can lag the newest snapshot, but can never travel
//! backwards). A torn ArcCell swap, a half-built snapshot, or a
//! use-after-free under the two-slot reclamation protocol would all
//! surface here as a mismatch, a panic, or a crash under the storm.

use std::sync::Arc;

use powertrain::coordinator::{
    GridEntry, GridKey, HostModels, Metrics, ModelKey, PlaneCache, PlaneKey, Strategy,
};
use powertrain::device::{DeviceKind, PowerModeGrid};
use powertrain::nn::checkpoint::Checkpoint;
use powertrain::nn::MlpParams;
use powertrain::pareto::{ParetoFront, Point};
use powertrain::profiler::StandardScaler;
use powertrain::workload::Workload;

const VERSIONS: usize = 8;
const PUBLICATIONS: usize = 600;
const READERS: usize = 6;

/// A model pair whose checkpoints (and therefore content fingerprints)
/// are unique to `tag`, with the tag recoverable from the parameters.
fn tagged_models(tag: usize) -> HostModels {
    let ck = |target: &str, salt: f32| {
        let mut params = MlpParams::zeros();
        params.leaves[0][0] = tag as f32 + salt;
        Checkpoint {
            params,
            feature_scaler: StandardScaler { mean: vec![0.0; 4], std: vec![1.0; 4] },
            target_scaler: StandardScaler { mean: vec![0.0], std: vec![1.0] },
            target: target.into(),
            provenance: format!("stress-v{tag}"),
            val_loss: 0.0,
        }
    };
    HostModels::new(ck("time", 0.25), ck("power", 0.5), 60.0)
}

fn tag_of(models: &HostModels) -> usize {
    (models.time.params.leaves[0][0] - 0.25) as usize
}

/// A plane whose `times[0]` encodes `tag`, so a reader can check that the
/// plane it resolved belongs to the model pair it resolved.
fn tagged_plane(grid: Arc<GridEntry>, tag: usize) -> powertrain::coordinator::ServePlane {
    let n = grid.grid.len();
    let times: Vec<f64> = (0..n).map(|i| tag as f64 * 1_000.0 + i as f64).collect();
    let powers: Vec<f64> = (0..n).map(|i| 10_000.0 + 10.0 * i as f64).collect();
    let points: Vec<Point> = grid
        .grid
        .modes
        .iter()
        .zip(times.iter().zip(&powers))
        .map(|(m, (&t, &p))| Point { mode: *m, time: t, power_mw: p })
        .collect();
    let front = ParetoFront::build(&points);
    powertrain::coordinator::ServePlane { grid, times, powers, front }
}

#[test]
fn concurrent_readers_never_see_a_torn_models_plane_pair() {
    let cache = PlaneCache::new();
    let metrics = Metrics::new();
    let gkey = GridKey::for_request(DeviceKind::OrinAgx, Some(40), 1);
    let key = ModelKey {
        grid: gkey,
        workload: Workload::mobilenet(),
        seed: 1,
        strategy: Strategy::PowerTrain(50),
        epochs: 100,
        ref_time_fp: 7,
        ref_power_fp: 8,
    };

    // resident grid + one pre-built plane per version, keyed by that
    // version's real checkpoint fingerprints (the refit flow builds the
    // plane after publishing the pair; pre-building keeps every read
    // resolvable so the test can demand full coherence on each one)
    let grid = cache.grid(gkey, || {
        let full = PowerModeGrid::paper_subset(DeviceKind::OrinAgx);
        GridEntry::new(PowerModeGrid {
            kind: DeviceKind::OrinAgx,
            modes: full.modes[..40].to_vec(),
        })
    });
    let fps: Vec<(u64, u64)> = (0..VERSIONS)
        .map(|tag| {
            let m = tagged_models(tag);
            let pkey = PlaneKey { grid: gkey, time_fp: m.time_fp, power_fp: m.power_fp };
            cache.plane(pkey, &metrics, || tagged_plane(Arc::clone(&grid), tag));
            (m.time_fp, m.power_fp)
        })
        .collect();
    assert_eq!(
        fps.iter().collect::<std::collections::HashSet<_>>().len(),
        VERSIONS,
        "version fingerprints must be distinct for the test to mean anything"
    );
    assert!(
        cache.publish_models(key, tagged_models(0)).is_some(),
        "initial publication must succeed"
    );

    std::thread::scope(|s| {
        // writer: a republication storm cycling the tagged versions
        s.spawn(|| {
            for i in 1..=PUBLICATIONS {
                let published = cache.publish_models(key, tagged_models(i % VERSIONS));
                assert!(published.is_some(), "republication {i} refused");
            }
        });
        for r in 0..READERS {
            s.spawn(move || {
                let mut last_version = 0u64;
                let mut resolved = 0usize;
                while resolved < 4 * PUBLICATIONS {
                    let snap = cache.read_snapshot();
                    let models = snap
                        .models(&key)
                        .unwrap_or_else(|| panic!("reader {r}: published pair missing"));
                    let tag = tag_of(models);
                    // the pair is coherent: the plane keyed by the
                    // resolved pair's own fingerprints exists and holds
                    // that version's payload
                    let pkey = PlaneKey {
                        grid: key.grid,
                        time_fp: models.time_fp,
                        power_fp: models.power_fp,
                    };
                    let plane = snap.plane(&pkey).unwrap_or_else(|| {
                        panic!("reader {r}: no plane for version {tag} fingerprints")
                    });
                    assert_eq!(
                        plane.times[0], tag as f64 * 1_000.0,
                        "reader {r}: plane payload does not match models version {tag}"
                    );
                    assert_eq!((models.time_fp, models.power_fp), fps[tag]);
                    // publication versions strictly increase writer-side,
                    // so each reader must observe them non-decreasing
                    assert!(
                        models.version >= last_version,
                        "reader {r}: version went backwards ({} after {last_version})",
                        models.version
                    );
                    last_version = models.version;
                    resolved += 1;
                }
            });
        }
    });

    // the storm settles on publication version PUBLICATIONS + 1
    let snap = cache.read_snapshot();
    assert_eq!(snap.models(&key).unwrap().version, PUBLICATIONS as u64 + 1);
}
