//! Integration: the full learning pipeline on simulator ground truth —
//! reference-NN training, PowerTrain transfer, and the paper's headline
//! qualitative claims at small scale:
//!
//! * an NN trained on a large corpus predicts the full grid accurately;
//! * PowerTrain transfer with 50 modes beats a from-scratch NN on 50 modes;
//! * power predictions are more accurate than time predictions.
//!
//! Scales are reduced (hundreds of modes, tens of epochs) to keep `cargo
//! test` fast; the experiment harness runs the paper-scale versions.

#![cfg(feature = "xla")]

use powertrain::device::{DeviceKind, PowerModeGrid};
use powertrain::profiler::{Corpus, Record};
use powertrain::runtime::Runtime;
use powertrain::sim::TrainerSim;
use powertrain::train::transfer::{transfer, TransferConfig};
use powertrain::train::{scale_features, Target, TrainConfig, Trainer};
use powertrain::util::rng::Rng;
use powertrain::util::stats;
use powertrain::workload::Workload;

fn runtime() -> Runtime {
    Runtime::new(std::path::Path::new("artifacts")).expect("run `make artifacts` first")
}

/// Fast ground-truth corpus (no telemetry noise) for training-logic tests.
fn truth_corpus(wl: Workload, n: usize, seed: u64) -> Corpus {
    let spec = DeviceKind::OrinAgx.spec();
    let sim = TrainerSim::new(spec, wl, seed);
    let mut rng = Rng::new(seed ^ 0xc0ffee);
    let modes = PowerModeGrid::paper_subset(DeviceKind::OrinAgx).sample(n, &mut rng);
    let mut c = Corpus::new(DeviceKind::OrinAgx, wl);
    for pm in modes {
        c.push(Record {
            mode: pm,
            time_ms: sim.true_minibatch_ms(&pm),
            power_mw: sim.true_power_mw(&pm),
            cost_s: 0.0,
        });
    }
    c
}

/// MAPE of a checkpoint against held-out ground truth.
fn holdout_mape(
    rt: &Runtime,
    ckpt: &powertrain::nn::checkpoint::Checkpoint,
    holdout: &Corpus,
    target: Target,
) -> f64 {
    let preds = powertrain::predict::predict_modes(
        rt,
        ckpt,
        &holdout.records().iter().map(|r| r.mode).collect::<Vec<_>>(),
    )
    .unwrap();
    let truth = target.values(holdout);
    stats::mape(&preds, &truth)
}

#[test]
fn nn_on_large_corpus_predicts_well() {
    let rt = runtime();
    let train_corpus = truth_corpus(Workload::resnet(), 1000, 1);
    let holdout = truth_corpus(Workload::resnet(), 300, 2);
    let cfg = TrainConfig { epochs: 100, seed: 3, ..Default::default() };
    let trainer = Trainer::new(&rt);

    let (time_ckpt, log) = trainer.train(&train_corpus, Target::Time, &cfg).unwrap();
    assert!(log.steps > 100);
    assert!(log.val_mse.iter().cloned().fold(f64::INFINITY, f64::min) < log.val_mse[0]);

    let time_mape = holdout_mape(&rt, &time_ckpt, &holdout, Target::Time);
    assert!(time_mape < 20.0, "time MAPE {time_mape:.1}% too high");

    let (power_ckpt, _) = trainer.train(&train_corpus, Target::Power, &cfg).unwrap();
    let power_mape = holdout_mape(&rt, &power_ckpt, &holdout, Target::Power);
    assert!(power_mape < 12.0, "power MAPE {power_mape:.1}% too high");

    // the paper's observation: power is easier to predict than time
    assert!(
        power_mape < time_mape,
        "power {power_mape:.1}% !< time {time_mape:.1}%"
    );
}

#[test]
fn powertrain_transfer_beats_nn_scratch_at_50_modes() {
    let rt = runtime();
    let trainer = Trainer::new(&rt);

    // reference: resnet, larger corpus + longer training (done offline once)
    let ref_corpus = truth_corpus(Workload::resnet(), 1000, 10);
    let ref_cfg = TrainConfig { epochs: 120, seed: 11, ..Default::default() };
    let (ref_time, _) = trainer.train(&ref_corpus, Target::Time, &ref_cfg).unwrap();

    // new workload: mobilenet with only 50 profiled modes
    let small = truth_corpus(Workload::mobilenet(), 50, 12);
    let holdout = truth_corpus(Workload::mobilenet(), 300, 13);

    let t_cfg = TransferConfig {
        base: TrainConfig { epochs: 100, seed: 14, ..Default::default() },
        ..Default::default()
    };
    let (pt_ckpt, _) = transfer(&rt, &ref_time, &small, Target::Time, &t_cfg).unwrap();
    let pt_mape = holdout_mape(&rt, &pt_ckpt, &holdout, Target::Time);

    let nn_cfg = TrainConfig { epochs: 100, seed: 15, ..Default::default() };
    let (nn_ckpt, _) = trainer.train(&small, Target::Time, &nn_cfg).unwrap();
    let nn_mape = holdout_mape(&rt, &nn_ckpt, &holdout, Target::Time);

    // the paper's headline: transfer is clearly better in the low-sample
    // regime (Fig 7: 26.7% vs 52.6% at 10 modes, <20% vs 35% at 30)
    assert!(
        pt_mape < nn_mape,
        "PT {pt_mape:.1}% not better than NN {nn_mape:.1}%"
    );
    assert!(pt_mape < 35.0, "PT transfer too weak: {pt_mape:.1}%");
}

#[test]
fn mape_loss_variant_trains() {
    let rt = runtime();
    let corpus = truth_corpus(Workload::resnet(), 120, 20);
    let cfg = TrainConfig {
        epochs: 30,
        loss: powertrain::train::LossKind::Mape,
        seed: 21,
        ..Default::default()
    };
    let trainer = Trainer::new(&rt);
    let (ckpt, log) = trainer.train(&corpus, Target::Power, &cfg).unwrap();
    assert!(ckpt.params.is_finite());
    // MAPE loss curve should come down substantially from its start
    let first = log.train_loss[0];
    let last = *log.train_loss.last().unwrap();
    assert!(last < 0.7 * first, "MAPE loss {first:.1} -> {last:.1}");
}

#[test]
fn training_rejects_degenerate_corpus() {
    let rt = runtime();
    let trainer = Trainer::new(&rt);
    let tiny = truth_corpus(Workload::resnet(), 1, 30);
    assert!(trainer.train(&tiny, Target::Time, &TrainConfig::default()).is_err());
}

#[test]
fn evaluate_consistent_with_predict() {
    // Trainer::evaluate's MAPE must agree with computing MAPE from
    // predict_modes outputs
    let rt = runtime();
    let corpus = truth_corpus(Workload::resnet(), 200, 40);
    let cfg = TrainConfig { epochs: 25, seed: 41, ..Default::default() };
    let trainer = Trainer::new(&rt);
    let (ckpt, _) = trainer.train(&corpus, Target::Time, &cfg).unwrap();

    let holdout = truth_corpus(Workload::resnet(), 150, 42);
    let xs = scale_features(&holdout, &ckpt.feature_scaler);
    let ys = Target::Time.values(&holdout);
    let (_, eval_mape) = trainer
        .evaluate(&ckpt.params, &xs, &ys, &ckpt.target_scaler)
        .unwrap();
    let direct = holdout_mape(&rt, &ckpt, &holdout, Target::Time);
    assert!(
        (eval_mape - direct).abs() < 1.0,
        "evaluate {eval_mape:.2}% vs predict-derived {direct:.2}%"
    );
}
