//! L3 coordinator: the edge power-mode recommendation service.
//!
//! Models the deployment the paper motivates (sections 1, 1.5): DNN
//! training workloads arrive dynamically at a fleet of Jetson devices; for
//! each request the coordinator profiles ~50 power modes on the target
//! device, transfer-learns the reference time/power models, predicts the
//! whole power-mode grid through the AOT artifacts, builds the Pareto
//! front, and returns the power mode that minimizes training time within
//! the request's power budget.
//!
//! Threading: PJRT clients are not `Send`, so each worker thread owns its
//! own `Runtime`; requests flow through a shared queue and responses are
//! collected on a channel. Python never runs here.
//!
//! Host-native serving: when the AOT artifacts are unavailable (built
//! without the `xla` feature, or `Runtime` construction fails at serve
//! time) [`handle_request_host`] runs the *same* per-scenario strategy
//! dispatch as the artifact path — `Strategy::PowerTrain(n)` profiles `n`
//! modes on the simulated device, transfer-learns both reference models
//! with the pure-rust trainer (`train::transfer::transfer_host` over
//! `nn::grad`), `Strategy::NnProfiled(n)` trains from scratch
//! (`train::HostTrainer`), and `Strategy::BruteForce` profiles the whole
//! grid. The default build therefore serves the paper's full loop —
//! profile → transfer → grid prediction → in-budget Pareto
//! recommendation — not a degraded reference-checkpoint approximation.
//!
//! Grid-resident serving: the host path keeps its expensive state — the
//! device grid, the shared SoA feature matrix, the per-workload
//! transferred model pairs, both raw-unit prediction planes and the
//! Pareto front — resident in a [`PlaneCache`] shared by all workers
//! (see [`cache`]). Host training is deterministic per [`ModelKey`], so
//! cached model pairs are provably what a rebuild would produce;
//! transferred checkpoints then key planes by content fingerprint
//! exactly like reference checkpoints do. Steady-state requests that
//! only vary the power budget answer with a binary search over the
//! cached front, O(log front) instead of profiling + fitting + O(grid ×
//! params).

pub mod cache;
pub mod metrics;
pub mod policy;

pub use cache::{GridEntry, GridKey, HostModels, ModelKey, PlaneCache, PlaneKey, ServePlane};
pub use metrics::Metrics;
pub use policy::{Scenario, Strategy};

use std::collections::VecDeque;
use std::path::PathBuf;
use std::sync::atomic::Ordering;
use std::sync::{mpsc, Arc, Mutex};
use std::time::Instant;

use crate::device::{DeviceKind, PowerMode, PowerModeGrid};
use crate::error::{Error, Result};
use crate::nn::checkpoint::Checkpoint;
use crate::pareto::{ParetoFront, Point};
use crate::predict::GridPredictor;
use crate::profiler::{Corpus, Profiler};
use crate::sim::TrainerSim;
use crate::train::transfer::{transfer_host, TransferConfig};
use crate::train::{HostTrainer, Target, TrainConfig};
use crate::util::rng::Rng;
use crate::workload::Workload;

#[cfg(feature = "xla")]
use crate::runtime::Runtime;
#[cfg(feature = "xla")]
use crate::train::{transfer::transfer, Trainer};

/// An arriving request: optimize this workload on this device under this
/// power budget.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub device: DeviceKind,
    pub workload: Workload,
    pub power_budget_w: f64,
    pub scenario: Scenario,
    /// Seed controlling the simulated device telemetry + sampling.
    pub seed: u64,
}

/// The coordinator's answer.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub strategy: String,
    pub chosen_mode: PowerMode,
    /// Predictions at the chosen mode.
    pub predicted_time_ms: f64,
    pub predicted_power_w: f64,
    /// Ground-truth values at the chosen mode (observable post-hoc).
    pub observed_time_ms: f64,
    pub observed_power_w: f64,
    /// Simulated device-seconds spent profiling for this request.
    pub profiling_cost_s: f64,
    /// Coordinator wall-clock latency (ms) for the decision.
    pub latency_ms: f64,
}

/// Reference models (time + power) the transfer bootstraps from.
#[derive(Debug, Clone)]
pub struct ReferenceModels {
    pub time: Checkpoint,
    pub power: Checkpoint,
}

impl ReferenceModels {
    /// Load from `<dir>/reference_time.json` + `<dir>/reference_power.json`.
    pub fn load(dir: &std::path::Path) -> Result<ReferenceModels> {
        Ok(ReferenceModels {
            time: Checkpoint::load(&dir.join("reference_time.json"))?,
            power: Checkpoint::load(&dir.join("reference_power.json"))?,
        })
    }

    pub fn save(&self, dir: &std::path::Path) -> Result<()> {
        self.time.save(&dir.join("reference_time.json"))?;
        self.power.save(&dir.join("reference_power.json"))?;
        Ok(())
    }

    /// Content fingerprints of (time, power) — the model half of the
    /// plane-cache key. O(params); compute once per worker/serve call
    /// (the models are immutable while serving) and pass to
    /// [`handle_request_host_keyed`] so cache hits don't re-hash 42k
    /// parameters per request.
    pub fn fingerprints(&self) -> (u64, u64) {
        (self.time.fingerprint(), self.power.fingerprint())
    }

    /// Train reference models from scratch on the reference workload's
    /// profiled corpus (the paper's one-time offline step).
    #[cfg(feature = "xla")]
    pub fn bootstrap(
        rt: &Runtime,
        corpus: &Corpus,
        epochs: usize,
        seed: u64,
    ) -> Result<ReferenceModels> {
        let trainer = Trainer::new(rt);
        let cfg = TrainConfig { epochs, seed, ..Default::default() };
        let (time, _) = trainer.train(corpus, Target::Time, &cfg)?;
        let (power, _) = trainer.train(corpus, Target::Power, &cfg)?;
        Ok(ReferenceModels { time, power })
    }

    /// Host-native [`ReferenceModels::bootstrap`]: the same one-time
    /// offline step through the pure-rust trainer, available in every
    /// build.
    pub fn bootstrap_host(corpus: &Corpus, epochs: usize, seed: u64) -> Result<ReferenceModels> {
        let trainer = HostTrainer::new();
        let cfg = TrainConfig { epochs, seed, ..Default::default() };
        let (time, _) = trainer.train(corpus, Target::Time, &cfg)?;
        let (power, _) = trainer.train(corpus, Target::Power, &cfg)?;
        Ok(ReferenceModels { time, power })
    }
}

/// Coordinator configuration.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    pub artifacts_dir: PathBuf,
    /// Transfer fine-tuning epochs.
    pub transfer_epochs: usize,
    /// Grid over which predictions + Pareto are computed. `None` = the
    /// device's paper subset (Orin) / a random subset of comparable size.
    pub prediction_grid: Option<usize>,
    pub workers: usize,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            artifacts_dir: crate::runtime::artifacts::default_artifacts_dir(),
            transfer_epochs: 300,
            prediction_grid: None,
            workers: 1,
        }
    }
}

/// Serve one request end-to-end on a given runtime. This is the heart of
/// the coordinator; the threaded service wraps it.
#[cfg(feature = "xla")]
pub fn handle_request(
    rt: &Runtime,
    reference: &ReferenceModels,
    cfg: &CoordinatorConfig,
    metrics: &Metrics,
    req: &Request,
) -> Result<Response> {
    let t0 = Instant::now();
    metrics.requests_received.fetch_add(1, Ordering::Relaxed);

    let spec = req.device.spec();
    let strategy = Strategy::for_scenario(req.scenario);

    // 1. online profiling of a small random mode sample on the target
    let grid = prediction_grid(req.device, cfg.prediction_grid, req.seed);
    let n_profile = strategy.profiling_modes(grid.len()).min(grid.len());
    let mut rng = Rng::new(req.seed);
    let sample = grid.sample(n_profile, &mut rng);
    let mut profiler = Profiler::new(TrainerSim::new(spec, req.workload, req.seed));
    let corpus = profiler.profile_modes(&sample)?;
    metrics.modes_profiled.fetch_add(corpus.len() as u64, Ordering::Relaxed);
    metrics.add_profiling_s(corpus.total_cost_s());

    // 2. obtain time/power prediction models per the scenario's strategy
    let (time_ckpt, power_ckpt, strat_name) = match strategy {
        Strategy::PowerTrain(_) => {
            let tcfg = TransferConfig {
                base: TrainConfig {
                    epochs: cfg.transfer_epochs,
                    seed: req.seed,
                    ..Default::default()
                },
                ..Default::default()
            };
            let (t, _) = transfer(rt, &reference.time, &corpus, Target::Time, &tcfg)?;
            let (p, _) = transfer(rt, &reference.power, &corpus, Target::Power, &tcfg)?;
            (t, p, strategy.to_string())
        }
        Strategy::NnProfiled(_) => {
            let trainer = Trainer::new(rt);
            let ncfg = TrainConfig {
                epochs: cfg.transfer_epochs,
                seed: req.seed,
                ..Default::default()
            };
            let (t, _) = trainer.train(&corpus, Target::Time, &ncfg)?;
            let (p, _) = trainer.train(&corpus, Target::Power, &ncfg)?;
            (t, p, strategy.to_string())
        }
        Strategy::BruteForce => {
            // observed Pareto over the full profiled grid; no models
            return finish_brute_force(req, &grid, profiler, metrics, t0);
        }
    };

    // 3. predict the full grid through the AOT artifacts and build the
    //    predicted Pareto front (paper Fig 10)
    let times = crate::predict::predict_modes(rt, &time_ckpt, &grid.modes)?;
    let powers = crate::predict::predict_modes(rt, &power_ckpt, &grid.modes)?;
    finish_predicted(
        req,
        &grid,
        &times,
        &powers,
        strat_name,
        corpus.total_cost_s(),
        metrics,
        t0,
    )
}

/// Serve one request end-to-end without the PJRT runtime — the default
/// build's native path, same strategy dispatch as [`handle_request`]:
///
/// * `Strategy::PowerTrain(n)` — profile `n` modes via the simulated
///   [`Profiler`], transfer-learn both reference models on host
///   (`transfer_host`), predict the grid, Pareto-optimize;
/// * `Strategy::NnProfiled(n)` — same, training from scratch
///   ([`HostTrainer`]) instead of transferring;
/// * `Strategy::BruteForce` — profile the whole grid, observed optimum.
///
/// Grid-resident: the per-workload model pair is cached under
/// [`ModelKey`] (host fits are deterministic per key), and everything
/// budget-independent — grid, shared SoA feature matrix, both prediction
/// planes, Pareto front — lives in `cache` keyed by grid identity plus
/// the content fingerprints of the *transferred* checkpoints, exactly as
/// reference planes are keyed. The first request per workload pays
/// profiling + two fits + the plane build; every later one answers via
/// [`ParetoFront::optimize`]'s binary search over the cached front.
pub fn handle_request_host(
    cache: &PlaneCache,
    reference: &ReferenceModels,
    cfg: &CoordinatorConfig,
    metrics: &Metrics,
    req: &Request,
) -> Result<Response> {
    handle_request_host_keyed(cache, reference, reference.fingerprints(), cfg, metrics, req)
}

/// [`handle_request_host`] with the reference fingerprints precomputed —
/// the steady-state entry `serve` workers use (models are immutable for
/// the whole call), so a cache hit is a map lookup plus a binary search
/// with no per-request O(params) hashing. `ref_fps` must be
/// `reference.fingerprints()` for the same models; a mismatched pair
/// would key models and planes under the wrong references.
pub fn handle_request_host_keyed(
    cache: &PlaneCache,
    reference: &ReferenceModels,
    ref_fps: (u64, u64),
    cfg: &CoordinatorConfig,
    metrics: &Metrics,
    req: &Request,
) -> Result<Response> {
    let t0 = Instant::now();
    metrics.requests_received.fetch_add(1, Ordering::Relaxed);

    let strategy = Strategy::for_scenario(req.scenario);
    if let Strategy::BruteForce = strategy {
        let grid = prediction_grid(req.device, cfg.prediction_grid, req.seed);
        let profiler = Profiler::new(TrainerSim::new(req.device.spec(), req.workload, req.seed));
        return finish_brute_force(req, &grid, profiler, metrics, t0);
    }

    let gkey = GridKey::for_request(req.device, cfg.prediction_grid, req.seed);
    let mkey = ModelKey {
        grid: gkey,
        workload: req.workload,
        seed: req.seed,
        strategy,
        epochs: cfg.transfer_epochs,
        ref_time_fp: ref_fps.0,
        ref_power_fp: ref_fps.1,
    };
    // one lazy grid resolver shared by both miss paths, so they can
    // never drift apart on how the grid is built
    let grid_entry = || {
        cache.grid(gkey, || {
            GridEntry::new(prediction_grid(req.device, cfg.prediction_grid, req.seed))
        })
    };
    let (models, built) = cache.models(mkey, metrics, || {
        train_host_models(&grid_entry().grid, reference, cfg, metrics, req, strategy)
    })?;

    let pkey = PlaneKey { grid: gkey, time_fp: models.time_fp, power_fp: models.power_fp };
    let plane = cache.plane(pkey, metrics, || {
        build_plane(grid_entry(), &models.time, &models.power)
    });

    // steady-state request cost: one binary search over the cached front.
    // Profiling cost is charged to the request that actually profiled;
    // model-cache hits spent zero device-seconds.
    let chosen = plane.front.optimize(req.power_budget_w * 1000.0)?;
    let profiling_cost_s = if built { models.profiling_cost_s } else { 0.0 };
    respond(req, chosen, format!("{strategy}(host)"), profiling_cost_s, metrics, t0)
}

/// The model-cache-miss work: online profiling of the strategy's mode
/// sample on the simulated target, then two host fits (transfer for
/// PowerTrain, from-scratch for NnProfiled). Deterministic in the
/// [`ModelKey`] inputs — same seed, workload, grid, references and
/// epochs reproduce bit-identical checkpoints.
fn train_host_models(
    grid: &PowerModeGrid,
    reference: &ReferenceModels,
    cfg: &CoordinatorConfig,
    metrics: &Metrics,
    req: &Request,
    strategy: Strategy,
) -> Result<HostModels> {
    let n_profile = strategy.profiling_modes(grid.len()).min(grid.len());
    let mut rng = Rng::new(req.seed);
    let sample = grid.sample(n_profile, &mut rng);
    let mut profiler = Profiler::new(TrainerSim::new(req.device.spec(), req.workload, req.seed));
    let corpus = profiler.profile_modes(&sample)?;
    metrics.modes_profiled.fetch_add(corpus.len() as u64, Ordering::Relaxed);
    metrics.add_profiling_s(corpus.total_cost_s());

    let base = TrainConfig { epochs: cfg.transfer_epochs, seed: req.seed, ..Default::default() };
    let (time, power) = match strategy {
        Strategy::PowerTrain(_) => {
            let tcfg = TransferConfig { base, ..Default::default() };
            let (t, _) = transfer_host(&reference.time, &corpus, Target::Time, &tcfg)?;
            let (p, _) = transfer_host(&reference.power, &corpus, Target::Power, &tcfg)?;
            (t, p)
        }
        Strategy::NnProfiled(_) => {
            let trainer = HostTrainer::new();
            let (t, _) = trainer.train(&corpus, Target::Time, &base)?;
            let (p, _) = trainer.train(&corpus, Target::Power, &base)?;
            (t, p)
        }
        Strategy::BruteForce => unreachable!("brute force never trains models"),
    };
    metrics.host_fits.fetch_add(2, Ordering::Relaxed);
    Ok(HostModels::new(time, power, corpus.total_cost_s()))
}

/// The cold-path work a plane-cache miss pays once per (grid, model-pair):
/// two affine-folded engine builds, two forward passes over the grid's
/// shared feature matrix, one Pareto sort. `time`/`power` are whichever
/// checkpoints the plane is keyed by — transferred per-workload models on
/// the host path, reference models elsewhere.
fn build_plane(grid: Arc<GridEntry>, time: &Checkpoint, power: &Checkpoint) -> ServePlane {
    let times = GridPredictor::new(time).predict_features(&grid.features);
    let powers = GridPredictor::new(power).predict_features(&grid.features);
    let points: Vec<Point> = grid
        .grid
        .modes
        .iter()
        .zip(times.iter().zip(&powers))
        .map(|(m, (&t, &p))| Point { mode: *m, time: t, power_mw: p })
        .collect();
    let front = ParetoFront::build(&points);
    ServePlane { grid, times, powers, front }
}

/// Shared tail of the per-request predicted path (xla transfer serving):
/// Pareto build, budget optimization, post-hoc observation, metrics.
/// The host path goes through the plane cache instead and only shares
/// [`respond`].
#[cfg(feature = "xla")]
#[allow(clippy::too_many_arguments)]
fn finish_predicted(
    req: &Request,
    grid: &PowerModeGrid,
    times: &[f64],
    powers: &[f64],
    strategy: String,
    profiling_cost_s: f64,
    metrics: &Metrics,
    t0: Instant,
) -> Result<Response> {
    let points: Vec<Point> = grid
        .modes
        .iter()
        .zip(times.iter().zip(powers))
        .map(|(m, (&t, &p))| Point { mode: *m, time: t, power_mw: p })
        .collect();
    let front = ParetoFront::build(&points);

    // optimize: fastest predicted mode within the budget
    let chosen = front.optimize(req.power_budget_w * 1000.0)?;
    respond(req, chosen, strategy, profiling_cost_s, metrics, t0)
}

/// Common response tail: observable ground truth at the chosen mode (for
/// reporting/validation), latency + completion metrics.
fn respond(
    req: &Request,
    chosen: Point,
    strategy: String,
    profiling_cost_s: f64,
    metrics: &Metrics,
    t0: Instant,
) -> Result<Response> {
    let sim = TrainerSim::new(req.device.spec(), req.workload, req.seed ^ 0xfeed);
    let obs_t = sim.true_minibatch_ms(&chosen.mode);
    let obs_p = sim.true_power_mw(&chosen.mode);

    let latency_ms = t0.elapsed().as_secs_f64() * 1000.0;
    metrics.observe_latency_ms(latency_ms);
    metrics.requests_completed.fetch_add(1, Ordering::Relaxed);

    Ok(Response {
        id: req.id,
        strategy,
        chosen_mode: chosen.mode,
        predicted_time_ms: chosen.time,
        predicted_power_w: chosen.power_mw / 1000.0,
        observed_time_ms: obs_t,
        observed_power_w: obs_p / 1000.0,
        profiling_cost_s,
        latency_ms,
    })
}

fn finish_brute_force(
    req: &Request,
    grid: &PowerModeGrid,
    mut profiler: Profiler,
    metrics: &Metrics,
    t0: Instant,
) -> Result<Response> {
    let corpus = profiler.profile_modes(&grid.modes)?;
    metrics.modes_profiled.fetch_add(corpus.len() as u64, Ordering::Relaxed);
    metrics.add_profiling_s(corpus.total_cost_s());
    let points: Vec<Point> = corpus
        .records()
        .iter()
        .map(|r| Point { mode: r.mode, time: r.time_ms, power_mw: r.power_mw })
        .collect();
    let front = ParetoFront::build(&points);
    let chosen = front.optimize(req.power_budget_w * 1000.0)?;
    let latency_ms = t0.elapsed().as_secs_f64() * 1000.0;
    metrics.observe_latency_ms(latency_ms);
    metrics.requests_completed.fetch_add(1, Ordering::Relaxed);
    Ok(Response {
        id: req.id,
        strategy: "brute-force".into(),
        chosen_mode: chosen.mode,
        predicted_time_ms: chosen.time,
        predicted_power_w: chosen.power_mw / 1000.0,
        observed_time_ms: chosen.time,
        observed_power_w: chosen.power_mw / 1000.0,
        profiling_cost_s: corpus.total_cost_s(),
        latency_ms,
    })
}

/// True when [`prediction_grid`] ignores `seed` for this (device,
/// override) pair — the single source of truth the cache's
/// [`GridKey`] canonicalization relies on. Keep in lockstep with
/// `prediction_grid` (it dispatches through this predicate).
pub fn prediction_grid_is_seed_independent(
    device: DeviceKind,
    override_n: Option<usize>,
) -> bool {
    // only the Orin default resolves to the deterministic paper subset;
    // every other combination draws a seeded random subset
    matches!((device, override_n), (DeviceKind::OrinAgx, None))
}

/// The grid predictions/Pareto are computed over for a device.
pub fn prediction_grid(device: DeviceKind, override_n: Option<usize>, seed: u64) -> PowerModeGrid {
    if prediction_grid_is_seed_independent(device, override_n) {
        return PowerModeGrid::paper_subset(device);
    }
    // Xavier/Nano defaults: the paper profiles random subsets (1,000 / 180)
    let n = override_n.unwrap_or_else(|| match device {
        DeviceKind::XavierAgx => 1000,
        DeviceKind::OrinNano => 180,
        DeviceKind::OrinAgx => unreachable!("orin default grid is seed-independent"),
    });
    let mut rng = Rng::new(seed ^ 0x9d1d);
    PowerModeGrid::random_subset(device, n, &mut rng)
}

/// Multi-worker serving: spawns `cfg.workers` threads, each with its own
/// PJRT runtime, pulling from a shared queue. Returns responses in
/// completion order together with the shared metrics. Workers whose
/// runtime cannot be constructed (or builds without the `xla` feature)
/// serve through the host-native path instead — the same profile →
/// transfer → predict loop, computed by the pure-rust trainer and the
/// batched host engine.
pub fn serve(
    cfg: &CoordinatorConfig,
    reference: &ReferenceModels,
    requests: Vec<Request>,
) -> Result<(Vec<Response>, Arc<Metrics>)> {
    let metrics = Arc::new(Metrics::new());
    // one plane cache for the whole serve call: workers share grids,
    // feature matrices, prediction planes and Pareto fronts
    let cache = Arc::new(PlaneCache::new());
    let queue: Arc<Mutex<VecDeque<Request>>> =
        Arc::new(Mutex::new(requests.into_iter().collect()));
    let (tx, rx) = mpsc::channel::<Result<Response>>();

    let mut handles = Vec::new();
    for worker_id in 0..cfg.workers.max(1) {
        let queue = Arc::clone(&queue);
        let metrics = Arc::clone(&metrics);
        let cache = Arc::clone(&cache);
        let tx = tx.clone();
        let cfg = cfg.clone();
        let reference = reference.clone();
        handles.push(
            std::thread::Builder::new()
                .name(format!("pt-worker-{worker_id}"))
                .spawn(move || {
                    // reference models are immutable for the whole serve
                    // call: hash them once, not per request
                    let ref_fps = reference.fingerprints();
                    // each worker owns its own non-Send PJRT runtime;
                    // without one it serves through the host engine
                    #[cfg(feature = "xla")]
                    let rt = match Runtime::new(&cfg.artifacts_dir) {
                        Ok(rt) => Some(rt),
                        Err(e) => {
                            // the switch must be visible, not silent: every
                            // request on this worker now profiles + transfers
                            // through the pure-rust trainer instead of the
                            // AOT artifacts
                            eprintln!(
                                "pt-worker-{worker_id}: artifacts unavailable ({e}); \
                                 serving via the host-native training path"
                            );
                            None
                        }
                    };
                    loop {
                        let req = { queue.lock().unwrap().pop_front() };
                        let Some(req) = req else { break };
                        #[cfg(feature = "xla")]
                        let res = match rt.as_ref() {
                            Some(rt) => handle_request(rt, &reference, &cfg, &metrics, &req),
                            None => handle_request_host_keyed(
                                &cache, &reference, ref_fps, &cfg, &metrics, &req,
                            ),
                        };
                        #[cfg(not(feature = "xla"))]
                        let res = handle_request_host_keyed(
                            &cache, &reference, ref_fps, &cfg, &metrics, &req,
                        );
                        if res.is_err() {
                            metrics.requests_failed.fetch_add(1, Ordering::Relaxed);
                        }
                        if tx.send(res).is_err() {
                            break;
                        }
                    }
                })
                .map_err(|e| Error::Coordinator(format!("spawn failed: {e}")))?,
        );
    }
    drop(tx);

    let mut responses = Vec::new();
    let mut first_err: Option<Error> = None;
    for res in rx {
        match res {
            Ok(r) => responses.push(r),
            Err(e) => {
                if first_err.is_none() {
                    first_err = Some(e);
                }
            }
        }
    }
    for h in handles {
        let _ = h.join();
    }
    if responses.is_empty() {
        if let Some(e) = first_err {
            return Err(e);
        }
    }
    Ok((responses, metrics))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::MlpParams;
    use crate::profiler::StandardScaler;

    #[test]
    fn prediction_grid_sizes() {
        assert_eq!(prediction_grid(DeviceKind::OrinAgx, None, 1).len(), 4368);
        assert_eq!(prediction_grid(DeviceKind::XavierAgx, None, 1).len(), 1000);
        assert_eq!(prediction_grid(DeviceKind::OrinNano, None, 1).len(), 180);
        assert_eq!(prediction_grid(DeviceKind::OrinAgx, Some(200), 1).len(), 200);
    }

    #[test]
    fn prediction_grid_deterministic_per_seed() {
        let a = prediction_grid(DeviceKind::XavierAgx, None, 7);
        let b = prediction_grid(DeviceKind::XavierAgx, None, 7);
        assert_eq!(a.modes, b.modes);
    }

    fn host_reference() -> ReferenceModels {
        let mut rng = Rng::new(17);
        let ck = |target: &str| Checkpoint {
            params: MlpParams::init_he(&mut rng),
            feature_scaler: StandardScaler {
                mean: vec![6.0, 1400.0, 800.0, 2000.0],
                std: vec![3.5, 600.0, 350.0, 1100.0],
            },
            target_scaler: StandardScaler { mean: vec![30_000.0], std: vec![9_000.0] },
            target: target.into(),
            provenance: "host-native-test".into(),
            val_loss: 0.0,
        };
        ReferenceModels { time: ck("time"), power: ck("power") }
    }

    /// Reduced fine-tuning epochs so the unit suite stays fast; the
    /// integration suite runs realistic scales.
    fn host_cfg(grid: usize) -> CoordinatorConfig {
        CoordinatorConfig {
            prediction_grid: Some(grid),
            transfer_epochs: 6,
            ..Default::default()
        }
    }

    #[test]
    fn host_powertrain_request_runs_the_full_loop() {
        let reference = host_reference();
        let cfg = host_cfg(300);
        let metrics = Metrics::new();
        let cache = PlaneCache::new();
        let req = Request {
            id: 9,
            device: DeviceKind::OrinAgx,
            workload: Workload::mobilenet(),
            power_budget_w: 1e6, // any front point qualifies
            scenario: Scenario::FederatedLearning,
            seed: 5,
        };
        let resp = handle_request_host(&cache, &reference, &cfg, &metrics, &req).unwrap();
        // the paper loop actually ran: 50 modes profiled, both targets
        // transfer-learned on host, cost accounted on the request
        assert_eq!(resp.strategy, "powertrain-50(host)");
        assert!(resp.profiling_cost_s > 0.0);
        assert_eq!(metrics.modes_profiled.load(Ordering::Relaxed), 50);
        assert_eq!(metrics.host_fits.load(Ordering::Relaxed), 2);
        assert_eq!(metrics.model_cache_misses.load(Ordering::Relaxed), 1);
        resp.chosen_mode.validate(DeviceKind::OrinAgx.spec()).unwrap();
        assert_eq!(metrics.requests_completed.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.plane_cache_misses.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.plane_cache_hits.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn nn_profiled_strategy_trains_from_scratch_on_host() {
        let reference = host_reference();
        let cfg = host_cfg(200);
        let metrics = Metrics::new();
        let cache = PlaneCache::new();
        let req = Request {
            id: 1,
            device: DeviceKind::OrinAgx,
            workload: Workload::lstm(),
            power_budget_w: 1e6,
            scenario: Scenario::FineTuning, // → NnProfiled(100)
            seed: 6,
        };
        let resp = handle_request_host(&cache, &reference, &cfg, &metrics, &req).unwrap();
        assert_eq!(resp.strategy, "nn-100(host)");
        assert_eq!(metrics.modes_profiled.load(Ordering::Relaxed), 100);
        assert_eq!(metrics.host_fits.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn cache_hit_is_bit_identical_and_counted() {
        let reference = host_reference();
        let cfg = host_cfg(300);
        let metrics = Metrics::new();
        let req = |id: u64| Request {
            id,
            device: DeviceKind::OrinAgx,
            workload: Workload::mobilenet(),
            power_budget_w: 1e6,
            scenario: Scenario::FederatedLearning,
            seed: 5,
        };
        // uncached baseline on its own fresh cache
        let fresh = PlaneCache::new();
        let uncached = handle_request_host(&fresh, &reference, &cfg, &metrics, &req(0)).unwrap();
        // cold miss then hit on a shared cache
        let cache = PlaneCache::new();
        let cold = handle_request_host(&cache, &reference, &cfg, &metrics, &req(1)).unwrap();
        let hit = handle_request_host(&cache, &reference, &cfg, &metrics, &req(2)).unwrap();
        assert_eq!(metrics.plane_cache_misses.load(Ordering::Relaxed), 2);
        assert_eq!(metrics.plane_cache_hits.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.model_cache_misses.load(Ordering::Relaxed), 2);
        assert_eq!(metrics.model_cache_hits.load(Ordering::Relaxed), 1);
        // host fits are deterministic per key, so a cached answer is
        // byte-identical to the uncached one in every model-derived field
        // (id and wall-clock latency are per-request by construction)
        for r in [&cold, &hit] {
            assert_eq!(r.chosen_mode, uncached.chosen_mode);
            assert_eq!(r.strategy, uncached.strategy);
            assert_eq!(r.predicted_time_ms.to_bits(), uncached.predicted_time_ms.to_bits());
            assert_eq!(r.predicted_power_w.to_bits(), uncached.predicted_power_w.to_bits());
            assert_eq!(r.observed_time_ms.to_bits(), uncached.observed_time_ms.to_bits());
            assert_eq!(r.observed_power_w.to_bits(), uncached.observed_power_w.to_bits());
        }
        // profiling happened exactly once per *fresh* model build; the
        // cache hit spent zero simulated device-seconds
        assert_eq!(cold.profiling_cost_s.to_bits(), uncached.profiling_cost_s.to_bits());
        assert!(cold.profiling_cost_s > 0.0);
        assert_eq!(hit.profiling_cost_s, 0.0);
    }

    #[test]
    fn budget_only_requests_share_one_plane_and_one_fit() {
        let reference = host_reference();
        let cfg = host_cfg(400);
        let metrics = Metrics::new();
        let cache = PlaneCache::new();
        for (i, budget_w) in [1e6, 40.0, 25.0, 60.0, 1e6].iter().enumerate() {
            let req = Request {
                id: i as u64,
                device: DeviceKind::OrinAgx,
                workload: Workload::lstm(),
                power_budget_w: *budget_w,
                scenario: Scenario::ContinuousLearning,
                seed: 8,
            };
            match handle_request_host(&cache, &reference, &cfg, &metrics, &req) {
                Ok(resp) => assert!(
                    resp.predicted_power_w <= budget_w + 1e-9,
                    "budget {budget_w} W violated: {}",
                    resp.predicted_power_w
                ),
                // an infeasible budget is still answered from the cached
                // plane (the lookup precedes the optimize)
                Err(Error::Optimization(_)) => {}
                Err(e) => panic!("unexpected error: {e}"),
            }
        }
        // one profiling run + one transfer pair + one plane build; four
        // O(log front) answers
        assert_eq!(metrics.model_cache_misses.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.model_cache_hits.load(Ordering::Relaxed), 4);
        assert_eq!(metrics.host_fits.load(Ordering::Relaxed), 2);
        assert_eq!(metrics.modes_profiled.load(Ordering::Relaxed), 50);
        assert_eq!(metrics.plane_cache_misses.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.plane_cache_hits.load(Ordering::Relaxed), 4);
        assert_eq!(cache.sizes(), (1, 1, 1));
    }

    #[test]
    fn distinct_workloads_get_distinct_transferred_planes() {
        // transferred checkpoints flow through the plane cache by content
        // fingerprint, so two workloads on the same grid coexist — planes
        // cache alongside each other instead of colliding
        let reference = host_reference();
        let cfg = host_cfg(250);
        let metrics = Metrics::new();
        let cache = PlaneCache::new();
        let req = |id: u64, wl: Workload| Request {
            id,
            device: DeviceKind::OrinAgx,
            workload: wl,
            power_budget_w: 1e6,
            scenario: Scenario::ContinuousLearning,
            seed: 12,
        };
        let a = handle_request_host(&cache, &reference, &cfg, &metrics, &req(0, Workload::lstm()))
            .unwrap();
        let b =
            handle_request_host(&cache, &reference, &cfg, &metrics, &req(1, Workload::bert()))
                .unwrap();
        // one shared grid, two model pairs, two planes
        assert_eq!(cache.sizes(), (1, 2, 2));
        assert_eq!(metrics.model_cache_misses.load(Ordering::Relaxed), 2);
        // per-workload models genuinely differ
        assert!(
            a.predicted_time_ms.to_bits() != b.predicted_time_ms.to_bits()
                || a.predicted_power_w.to_bits() != b.predicted_power_w.to_bits(),
            "two workloads produced identical planes"
        );
        // and re-asking workload A hits both caches
        handle_request_host(&cache, &reference, &cfg, &metrics, &req(2, Workload::lstm()))
            .unwrap();
        assert_eq!(metrics.model_cache_hits.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.plane_cache_hits.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn host_serve_processes_queue_without_artifacts() {
        let reference = host_reference();
        let cfg = CoordinatorConfig {
            artifacts_dir: PathBuf::from("definitely-missing-artifacts"),
            prediction_grid: Some(200),
            transfer_epochs: 4,
            workers: 2,
        };
        let requests: Vec<Request> = (0..4)
            .map(|i| Request {
                id: i,
                device: DeviceKind::OrinAgx,
                workload: Workload::lstm(),
                power_budget_w: 1e6,
                scenario: Scenario::ContinuousLearning,
                seed: 40 + i,
            })
            .collect();
        let (responses, metrics) = serve(&cfg, &reference, requests).unwrap();
        assert_eq!(responses.len(), 4);
        let mut ids: Vec<u64> = responses.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, vec![0, 1, 2, 3]);
        assert_eq!(metrics.requests_completed.load(Ordering::Relaxed), 4);
        // every distinct seed transfers its own model pair host-natively
        assert_eq!(metrics.host_fits.load(Ordering::Relaxed), 8);
        for r in &responses {
            assert_eq!(r.strategy, "powertrain-50(host)");
        }
    }
}
