//! L3 coordinator: the edge power-mode recommendation service.
//!
//! Models the deployment the paper motivates (sections 1, 1.5): DNN
//! training workloads *arrive dynamically* at a fleet of Jetson devices;
//! for each request the coordinator profiles ~50 power modes on the
//! target device, transfer-learns the reference time/power models,
//! predicts the whole power-mode grid, builds the Pareto front, and
//! returns the power mode that minimizes training time within the
//! request's power budget.
//!
//! The coordinator is a layered service, one module per concern:
//!
//! * [`queue`] — streaming ingress: [`Job`]s carry simulated arrival
//!   times, optional deadlines and scenario-derived priorities; workers
//!   pull in priority/EDF order, so short federated/continuous-learning
//!   rounds overtake queued brute-force profiling jobs;
//! * [`pipeline`] — the staged request pipeline (admission →
//!   grid/feature-matrix resolution → model acquisition → plane
//!   resolution → Pareto query → response), each stage with a narrow
//!   typed interface; [`HostPipeline`] bundles the per-worker context;
//! * [`cache`] — grid-resident serving state with *singleflight*
//!   acquisition: a burst of N identical workloads costs exactly one
//!   host fit, concurrent requesters coalesce onto the in-flight build;
//! * [`service`] — the long-lived [`Coordinator`]: worker pool,
//!   cloneable [`Submitter`] (channel-style streaming submission),
//!   deterministic id-sorted response collection, panic/poison
//!   containment; the batch [`serve`] wrapper rides on top;
//! * [`lifecycle`] — the serve → observe → refit loop: a feedback lane
//!   for executed-round outcomes ([`Submitter::report`]), per-model
//!   drift monitors (rolling raw-unit MAPE with hysteresis,
//!   `Fresh|Suspect|Stale`), and a background worker that warm-refits
//!   drifted models from their rolling feedback corpus and republishes
//!   them versioned — serving never blocks on a refit and never sees a
//!   torn model/plane pair;
//! * [`policy`] / [`metrics`] — paper-Table-1 strategy + priority
//!   mapping, and the shared counters (cache hits, singleflight waits,
//!   deadline misses, drift trips/refits, per-request failure ledger).
//!
//! Threading: PJRT clients are not `Send`, so each worker thread owns its
//! own `Runtime`; requests flow through the shared priority queue and
//! responses are collected on a channel. Python never runs here.
//!
//! Host-native serving: when the AOT artifacts are unavailable (built
//! without the `xla` feature, or `Runtime` construction fails at serve
//! time) workers run the *same* per-scenario strategy dispatch through
//! the pure-rust trainer — see [`pipeline`] — so the default build
//! serves the paper's full loop: profile → transfer → grid prediction →
//! in-budget Pareto recommendation.

pub mod cache;
pub mod lifecycle;
pub mod metrics;
pub mod pipeline;
pub mod policy;
pub mod queue;
pub mod service;

pub use cache::{
    BreakerConfig, BreakerState, GridEntry, GridKey, HostModels, ModelKey, PlaneCache, PlaneKey,
    ServePlane, ServeSnapshot,
};
pub use lifecycle::{
    DriftMonitor, Feedback, Lifecycle, LifecycleConfig, ModelState, ModelStatus,
};
pub use metrics::Metrics;
#[cfg(feature = "xla")]
pub use pipeline::handle_request;
pub use pipeline::{
    fit_models_for_request, handle_request_host, HostPipeline, ThermalConfig, ThermalGuard,
};
pub use policy::{RetryPolicy, Scenario, Strategy};
pub use queue::{Job, RequestQueue};
pub use service::{serve, Coordinator, Submitter};

use std::path::PathBuf;
use std::sync::Arc;

use crate::device::{DeviceKind, PowerMode, PowerModeGrid};
use crate::error::Result;
use crate::fleet::NodeId;
use crate::nn::checkpoint::Checkpoint;
use crate::profiler::Corpus;
use crate::sim::FaultInjector;
use crate::train::{HostTrainer, Target, TrainConfig};
use crate::util::rng::Rng;
use crate::workload::Workload;

#[cfg(feature = "xla")]
use crate::runtime::Runtime;
#[cfg(feature = "xla")]
use crate::train::Trainer;

/// An arriving request: optimize this workload on this device under this
/// power budget. Streaming metadata (arrival time, deadline, priority)
/// rides on [`Job`], which wraps a `Request` for the ingress queue.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub device: DeviceKind,
    pub workload: Workload,
    pub power_budget_w: f64,
    pub scenario: Scenario,
    /// Fleet placement affinity: prefer a node of this [`DeviceKind`].
    /// `None` outside fleet mode (the classic single implicit pool) and
    /// for callers that accept any kind.
    pub affinity: Option<DeviceKind>,
    /// The node the fleet router placed this request on. Stamped by the
    /// fleet layer before submission; `None` outside fleet mode.
    pub node: Option<NodeId>,
    /// Seed controlling the simulated device telemetry + sampling.
    pub seed: u64,
}

/// How a response was produced: by the primary NN model pair, or by a
/// rung of the graceful-degradation ladder after the primary path failed.
/// Degraded answers are still *answers* — a resilient coordinator never
/// leaves a trainable request without a power mode — but callers can see
/// exactly how much model quality backs each one.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Provenance {
    /// The scenario's primary strategy (transferred / scratch NN pair).
    Primary,
    /// Ridge linear fallback fit on a small freshly profiled subset.
    DegradedRidge,
    /// Analytic NPE power estimate + clock-monotone time proxy — no
    /// profiling at all (the last rung).
    DegradedNpe,
    /// The answer itself came from the primary model pair, but the fleet
    /// router had to place the request away from its first-choice node
    /// (e.g. a fan-off episode marked that node unhealthy). The serving
    /// quality is primary; the *placement* is degraded, and callers
    /// doing per-node accounting should treat the response accordingly.
    DegradedPlacement,
}

impl Provenance {
    pub fn is_degraded(&self) -> bool {
        !matches!(self, Provenance::Primary)
    }

    pub fn label(&self) -> &'static str {
        match self {
            Provenance::Primary => "primary",
            Provenance::DegradedRidge => "degraded-ridge",
            Provenance::DegradedNpe => "degraded-npe",
            Provenance::DegradedPlacement => "degraded-placement",
        }
    }
}

/// The coordinator's answer.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub strategy: String,
    /// Which serving path produced this answer (primary model pair vs a
    /// degradation-ladder rung).
    pub provenance: Provenance,
    pub chosen_mode: PowerMode,
    /// Predictions at the chosen mode.
    pub predicted_time_ms: f64,
    pub predicted_power_w: f64,
    /// Ground-truth values at the chosen mode (observable post-hoc).
    pub observed_time_ms: f64,
    pub observed_power_w: f64,
    /// Simulated device-seconds spent profiling for this request.
    pub profiling_cost_s: f64,
    /// Coordinator wall-clock latency (ms) for the decision.
    pub latency_ms: f64,
    /// The fleet node that served this request (echoed from
    /// [`Request::node`]; `None` outside fleet mode).
    pub node: Option<NodeId>,
}

/// Reference models (time + power) the transfer bootstraps from.
#[derive(Debug, Clone)]
pub struct ReferenceModels {
    pub time: Checkpoint,
    pub power: Checkpoint,
}

impl ReferenceModels {
    /// Load from `<dir>/reference_time.json` + `<dir>/reference_power.json`.
    pub fn load(dir: &std::path::Path) -> Result<ReferenceModels> {
        Ok(ReferenceModels {
            time: Checkpoint::load(&dir.join("reference_time.json"))?,
            power: Checkpoint::load(&dir.join("reference_power.json"))?,
        })
    }

    pub fn save(&self, dir: &std::path::Path) -> Result<()> {
        self.time.save(&dir.join("reference_time.json"))?;
        self.power.save(&dir.join("reference_power.json"))?;
        Ok(())
    }

    /// Content fingerprints of (time, power) — the model half of the
    /// plane-cache key. O(params); [`HostPipeline`] computes this once
    /// per worker (the models are immutable while serving) so cache hits
    /// don't re-hash 42k parameters per request.
    pub fn fingerprints(&self) -> (u64, u64) {
        (self.time.fingerprint(), self.power.fingerprint())
    }

    /// Train reference models from scratch on the reference workload's
    /// profiled corpus (the paper's one-time offline step).
    #[cfg(feature = "xla")]
    pub fn bootstrap(
        rt: &Runtime,
        corpus: &Corpus,
        epochs: usize,
        seed: u64,
    ) -> Result<ReferenceModels> {
        let trainer = Trainer::new(rt);
        let cfg = TrainConfig { epochs, seed, ..Default::default() };
        let (time, _) = trainer.train(corpus, Target::Time, &cfg)?;
        let (power, _) = trainer.train(corpus, Target::Power, &cfg)?;
        Ok(ReferenceModels { time, power })
    }

    /// Host-native [`ReferenceModels::bootstrap`]: the same one-time
    /// offline step through the pure-rust trainer, available in every
    /// build.
    pub fn bootstrap_host(corpus: &Corpus, epochs: usize, seed: u64) -> Result<ReferenceModels> {
        let trainer = HostTrainer::new();
        let cfg = TrainConfig { epochs, seed, ..Default::default() };
        let (time, _) = trainer.train(corpus, Target::Time, &cfg)?;
        let (power, _) = trainer.train(corpus, Target::Power, &cfg)?;
        Ok(ReferenceModels { time, power })
    }
}

/// Coordinator configuration.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    pub artifacts_dir: PathBuf,
    /// Transfer fine-tuning epochs.
    pub transfer_epochs: usize,
    /// Grid over which predictions + Pareto are computed. `None` = the
    /// device's paper subset (Orin) / a random subset of comparable size.
    pub prediction_grid: Option<usize>,
    pub workers: usize,
    /// Model-lifecycle management (feedback lane, drift monitoring,
    /// background warm refits). `None` (the default) disables the whole
    /// subsystem: no tracker state, no refit worker, and
    /// [`Submitter::report`] rejects feedback — exactly the pre-lifecycle
    /// behaviour.
    pub lifecycle: Option<lifecycle::LifecycleConfig>,
    /// Retry policy for transient pipeline-stage failures (always on;
    /// without an injector or real faults it simply never fires).
    pub retry: RetryPolicy,
    /// Deterministic fault injector for chaos runs (`serve --faults`).
    /// `None` (the default) injects nothing and leaves serving
    /// bit-identical to a build without the harness.
    pub faults: Option<Arc<FaultInjector>>,
    /// Thermal guard: when set, sustained serve load advances a shared
    /// [`ThermalModel`](crate::sim::thermal::ThermalModel), the Pareto
    /// query is capped at the current `max_sustainable_mw()`, and
    /// throttling shifts the simulated ground truth so the lifecycle's
    /// drift monitor sees the episode. `None` (the default) = the paper's
    /// fan-at-max configuration, no guard.
    pub thermal: Option<ThermalConfig>,
    /// Fleet shard index this coordinator domain serves, when it is one
    /// of several hash-partitioned domains under a
    /// [`Fleet`](crate::fleet::Fleet). Labels worker/refit threads
    /// (`pt-s{shard}-w{n}`, `pt-refit-s{shard}`) so chaos traces name the
    /// domain. `None` (the default) = the classic standalone coordinator.
    pub shard: Option<u32>,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            artifacts_dir: crate::runtime::artifacts::default_artifacts_dir(),
            transfer_epochs: 300,
            prediction_grid: None,
            workers: 1,
            lifecycle: None,
            retry: RetryPolicy::default(),
            faults: None,
            thermal: None,
            shard: None,
        }
    }
}

/// True when [`prediction_grid`] ignores `seed` for this (device,
/// override) pair — the single source of truth the cache's
/// [`GridKey`] canonicalization relies on. Keep in lockstep with
/// `prediction_grid` (it dispatches through this predicate).
pub fn prediction_grid_is_seed_independent(
    device: DeviceKind,
    override_n: Option<usize>,
) -> bool {
    // only the Orin default resolves to the deterministic paper subset;
    // every other combination draws a seeded random subset
    matches!((device, override_n), (DeviceKind::OrinAgx, None))
}

/// The grid predictions/Pareto are computed over for a device.
pub fn prediction_grid(device: DeviceKind, override_n: Option<usize>, seed: u64) -> PowerModeGrid {
    if prediction_grid_is_seed_independent(device, override_n) {
        return PowerModeGrid::paper_subset(device);
    }
    // Xavier/Nano defaults: the paper profiles random subsets (1,000 / 180)
    let n = override_n.unwrap_or_else(|| match device {
        DeviceKind::XavierAgx => 1000,
        DeviceKind::OrinNano => 180,
        DeviceKind::OrinAgx => unreachable!("orin default grid is seed-independent"),
    });
    let mut rng = Rng::new(seed ^ 0x9d1d);
    PowerModeGrid::random_subset(device, n, &mut rng)
}

/// Shared fixtures for the coordinator's unit suites (pipeline, service):
/// cheap untrained-but-plausible reference checkpoints and a reduced
/// config so `cargo test` stays fast; the integration suite runs
/// realistic scales.
#[cfg(test)]
pub(crate) mod test_support {
    use super::*;
    use crate::nn::MlpParams;
    use crate::profiler::StandardScaler;

    pub fn host_reference() -> ReferenceModels {
        let mut rng = Rng::new(17);
        let ck = |target: &str| Checkpoint {
            params: MlpParams::init_he(&mut rng),
            feature_scaler: StandardScaler {
                mean: vec![6.0, 1400.0, 800.0, 2000.0],
                std: vec![3.5, 600.0, 350.0, 1100.0],
            },
            target_scaler: StandardScaler { mean: vec![30_000.0], std: vec![9_000.0] },
            target: target.into(),
            provenance: "host-native-test".into(),
            val_loss: 0.0,
        };
        ReferenceModels { time: ck("time"), power: ck("power") }
    }

    /// Reduced fine-tuning epochs so the unit suite stays fast.
    pub fn host_cfg(grid: usize) -> CoordinatorConfig {
        CoordinatorConfig {
            prediction_grid: Some(grid),
            transfer_epochs: 6,
            ..Default::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prediction_grid_sizes() {
        assert_eq!(prediction_grid(DeviceKind::OrinAgx, None, 1).len(), 4368);
        assert_eq!(prediction_grid(DeviceKind::XavierAgx, None, 1).len(), 1000);
        assert_eq!(prediction_grid(DeviceKind::OrinNano, None, 1).len(), 180);
        assert_eq!(prediction_grid(DeviceKind::OrinAgx, Some(200), 1).len(), 200);
    }

    #[test]
    fn prediction_grid_deterministic_per_seed() {
        let a = prediction_grid(DeviceKind::XavierAgx, None, 7);
        let b = prediction_grid(DeviceKind::XavierAgx, None, 7);
        assert_eq!(a.modes, b.modes);
    }
}
