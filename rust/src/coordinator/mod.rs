//! L3 coordinator: the edge power-mode recommendation service.
//!
//! Models the deployment the paper motivates (sections 1, 1.5): DNN
//! training workloads arrive dynamically at a fleet of Jetson devices; for
//! each request the coordinator profiles ~50 power modes on the target
//! device, transfer-learns the reference time/power models, predicts the
//! whole power-mode grid through the AOT artifacts, builds the Pareto
//! front, and returns the power mode that minimizes training time within
//! the request's power budget.
//!
//! Threading: PJRT clients are not `Send`, so each worker thread owns its
//! own `Runtime`; requests flow through a shared queue and responses are
//! collected on a channel. Python never runs here.
//!
//! Degraded mode: when the AOT artifacts are unavailable (built without
//! the `xla` feature, or `Runtime` construction fails at serve time) the
//! coordinator falls back to [`handle_request_host`] — no transfer
//! fine-tuning, the reference checkpoints predict the grid directly
//! through the batched host engine (`nn::engine`). Requests still get an
//! in-budget recommendation instead of an error.

pub mod metrics;
pub mod policy;

pub use metrics::Metrics;
pub use policy::{Scenario, Strategy};

use std::collections::VecDeque;
use std::path::PathBuf;
use std::sync::atomic::Ordering;
use std::sync::{mpsc, Arc, Mutex};
use std::time::Instant;

use crate::device::{DeviceKind, PowerMode, PowerModeGrid};
use crate::error::{Error, Result};
use crate::nn::checkpoint::Checkpoint;
use crate::pareto::{ParetoFront, Point};
use crate::predict::GridPredictor;
use crate::profiler::Profiler;
use crate::sim::TrainerSim;
use crate::util::rng::Rng;
use crate::workload::Workload;

#[cfg(feature = "xla")]
use crate::profiler::Corpus;
#[cfg(feature = "xla")]
use crate::runtime::Runtime;
#[cfg(feature = "xla")]
use crate::train::transfer::{transfer, TransferConfig};
#[cfg(feature = "xla")]
use crate::train::{Target, TrainConfig, Trainer};

/// An arriving request: optimize this workload on this device under this
/// power budget.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub device: DeviceKind,
    pub workload: Workload,
    pub power_budget_w: f64,
    pub scenario: Scenario,
    /// Seed controlling the simulated device telemetry + sampling.
    pub seed: u64,
}

/// The coordinator's answer.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub strategy: String,
    pub chosen_mode: PowerMode,
    /// Predictions at the chosen mode.
    pub predicted_time_ms: f64,
    pub predicted_power_w: f64,
    /// Ground-truth values at the chosen mode (observable post-hoc).
    pub observed_time_ms: f64,
    pub observed_power_w: f64,
    /// Simulated device-seconds spent profiling for this request.
    pub profiling_cost_s: f64,
    /// Coordinator wall-clock latency (ms) for the decision.
    pub latency_ms: f64,
}

/// Reference models (time + power) the transfer bootstraps from.
#[derive(Debug, Clone)]
pub struct ReferenceModels {
    pub time: Checkpoint,
    pub power: Checkpoint,
}

impl ReferenceModels {
    /// Load from `<dir>/reference_time.json` + `<dir>/reference_power.json`.
    pub fn load(dir: &std::path::Path) -> Result<ReferenceModels> {
        Ok(ReferenceModels {
            time: Checkpoint::load(&dir.join("reference_time.json"))?,
            power: Checkpoint::load(&dir.join("reference_power.json"))?,
        })
    }

    pub fn save(&self, dir: &std::path::Path) -> Result<()> {
        self.time.save(&dir.join("reference_time.json"))?;
        self.power.save(&dir.join("reference_power.json"))?;
        Ok(())
    }

    /// Train reference models from scratch on the reference workload's
    /// profiled corpus (the paper's one-time offline step).
    #[cfg(feature = "xla")]
    pub fn bootstrap(
        rt: &Runtime,
        corpus: &Corpus,
        epochs: usize,
        seed: u64,
    ) -> Result<ReferenceModels> {
        let trainer = Trainer::new(rt);
        let cfg = TrainConfig { epochs, seed, ..Default::default() };
        let (time, _) = trainer.train(corpus, Target::Time, &cfg)?;
        let (power, _) = trainer.train(corpus, Target::Power, &cfg)?;
        Ok(ReferenceModels { time, power })
    }
}

/// Coordinator configuration.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    pub artifacts_dir: PathBuf,
    /// Transfer fine-tuning epochs.
    pub transfer_epochs: usize,
    /// Grid over which predictions + Pareto are computed. `None` = the
    /// device's paper subset (Orin) / a random subset of comparable size.
    pub prediction_grid: Option<usize>,
    pub workers: usize,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            artifacts_dir: crate::runtime::artifacts::default_artifacts_dir(),
            transfer_epochs: 300,
            prediction_grid: None,
            workers: 1,
        }
    }
}

/// Serve one request end-to-end on a given runtime. This is the heart of
/// the coordinator; the threaded service wraps it.
#[cfg(feature = "xla")]
pub fn handle_request(
    rt: &Runtime,
    reference: &ReferenceModels,
    cfg: &CoordinatorConfig,
    metrics: &Metrics,
    req: &Request,
) -> Result<Response> {
    let t0 = Instant::now();
    metrics.requests_received.fetch_add(1, Ordering::Relaxed);

    let spec = req.device.spec();
    let strategy = Strategy::for_scenario(req.scenario);

    // 1. online profiling of a small random mode sample on the target
    let grid = prediction_grid(req.device, cfg.prediction_grid, req.seed);
    let n_profile = strategy.profiling_modes(grid.len()).min(grid.len());
    let mut rng = Rng::new(req.seed);
    let sample = grid.sample(n_profile, &mut rng);
    let mut profiler = Profiler::new(TrainerSim::new(spec, req.workload, req.seed));
    let corpus = profiler.profile_modes(&sample)?;
    metrics.modes_profiled.fetch_add(corpus.len() as u64, Ordering::Relaxed);
    metrics.add_profiling_s(corpus.total_cost_s());

    // 2. obtain time/power prediction models per the scenario's strategy
    let (time_ckpt, power_ckpt, strat_name) = match strategy {
        Strategy::PowerTrain(_) => {
            let tcfg = TransferConfig {
                base: TrainConfig {
                    epochs: cfg.transfer_epochs,
                    seed: req.seed,
                    ..Default::default()
                },
                ..Default::default()
            };
            let (t, _) = transfer(rt, &reference.time, &corpus, Target::Time, &tcfg)?;
            let (p, _) = transfer(rt, &reference.power, &corpus, Target::Power, &tcfg)?;
            (t, p, strategy.to_string())
        }
        Strategy::NnProfiled(_) => {
            let trainer = Trainer::new(rt);
            let ncfg = TrainConfig {
                epochs: cfg.transfer_epochs,
                seed: req.seed,
                ..Default::default()
            };
            let (t, _) = trainer.train(&corpus, Target::Time, &ncfg)?;
            let (p, _) = trainer.train(&corpus, Target::Power, &ncfg)?;
            (t, p, strategy.to_string())
        }
        Strategy::BruteForce => {
            // observed Pareto over the full profiled grid; no models
            return finish_brute_force(req, &grid, profiler, metrics, t0);
        }
    };

    // 3. predict the full grid through the AOT artifacts and build the
    //    predicted Pareto front (paper Fig 10)
    let times = crate::predict::predict_modes(rt, &time_ckpt, &grid.modes)?;
    let powers = crate::predict::predict_modes(rt, &power_ckpt, &grid.modes)?;
    finish_predicted(
        req,
        &grid,
        &times,
        &powers,
        strat_name,
        corpus.total_cost_s(),
        metrics,
        t0,
    )
}

/// Serve one request without the PJRT runtime: the artifact-unavailable
/// fallback. Skips online profiling and transfer (both need the train
/// artifacts) and predicts the device grid directly with the *reference*
/// checkpoints through the batched host engine — a degraded but in-budget
/// answer with zero profiling cost. Brute force still works unchanged
/// (it never touches the models).
pub fn handle_request_host(
    reference: &ReferenceModels,
    cfg: &CoordinatorConfig,
    metrics: &Metrics,
    req: &Request,
) -> Result<Response> {
    let t0 = Instant::now();
    metrics.requests_received.fetch_add(1, Ordering::Relaxed);

    let spec = req.device.spec();
    let strategy = Strategy::for_scenario(req.scenario);
    let grid = prediction_grid(req.device, cfg.prediction_grid, req.seed);

    if let Strategy::BruteForce = strategy {
        let profiler = Profiler::new(TrainerSim::new(spec, req.workload, req.seed));
        return finish_brute_force(req, &grid, profiler, metrics, t0);
    }

    // engines are built once per request (weight transposition is O(params),
    // ~3 orders of magnitude cheaper than one grid prediction)
    let times = GridPredictor::new(&reference.time).predict(&grid.modes);
    let powers = GridPredictor::new(&reference.power).predict(&grid.modes);
    finish_predicted(
        req,
        &grid,
        &times,
        &powers,
        format!("host-fallback({strategy})"),
        0.0,
        metrics,
        t0,
    )
}

/// Shared tail of the predicted paths: Pareto build, budget optimization,
/// post-hoc observation, metrics.
#[allow(clippy::too_many_arguments)]
fn finish_predicted(
    req: &Request,
    grid: &PowerModeGrid,
    times: &[f64],
    powers: &[f64],
    strategy: String,
    profiling_cost_s: f64,
    metrics: &Metrics,
    t0: Instant,
) -> Result<Response> {
    let points: Vec<Point> = grid
        .modes
        .iter()
        .zip(times.iter().zip(powers))
        .map(|(m, (&t, &p))| Point { mode: *m, time: t, power_mw: p })
        .collect();
    let front = ParetoFront::build(&points);

    // optimize: fastest predicted mode within the budget
    let chosen = front.optimize(req.power_budget_w * 1000.0)?;

    // observable ground truth at the chosen mode (for reporting/validation)
    let sim = TrainerSim::new(req.device.spec(), req.workload, req.seed ^ 0xfeed);
    let obs_t = sim.true_minibatch_ms(&chosen.mode);
    let obs_p = sim.true_power_mw(&chosen.mode);

    let latency_ms = t0.elapsed().as_secs_f64() * 1000.0;
    metrics.observe_latency_ms(latency_ms);
    metrics.requests_completed.fetch_add(1, Ordering::Relaxed);

    Ok(Response {
        id: req.id,
        strategy,
        chosen_mode: chosen.mode,
        predicted_time_ms: chosen.time,
        predicted_power_w: chosen.power_mw / 1000.0,
        observed_time_ms: obs_t,
        observed_power_w: obs_p / 1000.0,
        profiling_cost_s,
        latency_ms,
    })
}

fn finish_brute_force(
    req: &Request,
    grid: &PowerModeGrid,
    mut profiler: Profiler,
    metrics: &Metrics,
    t0: Instant,
) -> Result<Response> {
    let corpus = profiler.profile_modes(&grid.modes)?;
    metrics.modes_profiled.fetch_add(corpus.len() as u64, Ordering::Relaxed);
    metrics.add_profiling_s(corpus.total_cost_s());
    let points: Vec<Point> = corpus
        .records()
        .iter()
        .map(|r| Point { mode: r.mode, time: r.time_ms, power_mw: r.power_mw })
        .collect();
    let front = ParetoFront::build(&points);
    let chosen = front.optimize(req.power_budget_w * 1000.0)?;
    let latency_ms = t0.elapsed().as_secs_f64() * 1000.0;
    metrics.observe_latency_ms(latency_ms);
    metrics.requests_completed.fetch_add(1, Ordering::Relaxed);
    Ok(Response {
        id: req.id,
        strategy: "brute-force".into(),
        chosen_mode: chosen.mode,
        predicted_time_ms: chosen.time,
        predicted_power_w: chosen.power_mw / 1000.0,
        observed_time_ms: chosen.time,
        observed_power_w: chosen.power_mw / 1000.0,
        profiling_cost_s: corpus.total_cost_s(),
        latency_ms,
    })
}

/// The grid predictions/Pareto are computed over for a device.
pub fn prediction_grid(device: DeviceKind, override_n: Option<usize>, seed: u64) -> PowerModeGrid {
    match (device, override_n) {
        (_, Some(n)) => {
            let mut rng = Rng::new(seed ^ 0x9d1d);
            PowerModeGrid::random_subset(device, n, &mut rng)
        }
        (DeviceKind::OrinAgx, None) => PowerModeGrid::paper_subset(DeviceKind::OrinAgx),
        (dev, None) => {
            // Xavier/Nano: the paper profiles random subsets (1,000 / 180)
            let n = match dev {
                DeviceKind::XavierAgx => 1000,
                DeviceKind::OrinNano => 180,
                DeviceKind::OrinAgx => unreachable!(),
            };
            let mut rng = Rng::new(seed ^ 0x9d1d);
            PowerModeGrid::random_subset(dev, n, &mut rng)
        }
    }
}

/// Multi-worker serving: spawns `cfg.workers` threads, each with its own
/// PJRT runtime, pulling from a shared queue. Returns responses in
/// completion order together with the shared metrics. Workers whose
/// runtime cannot be constructed (or builds without the `xla` feature)
/// degrade to the host-engine fallback instead of failing the request.
pub fn serve(
    cfg: &CoordinatorConfig,
    reference: &ReferenceModels,
    requests: Vec<Request>,
) -> Result<(Vec<Response>, Arc<Metrics>)> {
    let metrics = Arc::new(Metrics::new());
    let queue: Arc<Mutex<VecDeque<Request>>> =
        Arc::new(Mutex::new(requests.into_iter().collect()));
    let (tx, rx) = mpsc::channel::<Result<Response>>();

    let mut handles = Vec::new();
    for worker_id in 0..cfg.workers.max(1) {
        let queue = Arc::clone(&queue);
        let metrics = Arc::clone(&metrics);
        let tx = tx.clone();
        let cfg = cfg.clone();
        let reference = reference.clone();
        handles.push(
            std::thread::Builder::new()
                .name(format!("pt-worker-{worker_id}"))
                .spawn(move || {
                    // each worker owns its own non-Send PJRT runtime;
                    // without one it serves through the host engine
                    #[cfg(feature = "xla")]
                    let rt = match Runtime::new(&cfg.artifacts_dir) {
                        Ok(rt) => Some(rt),
                        Err(e) => {
                            // degradation must be visible, not silent: every
                            // request on this worker now skips transfer and
                            // answers from the untransferred reference models
                            eprintln!(
                                "pt-worker-{worker_id}: artifacts unavailable ({e}); \
                                 serving via host-engine fallback"
                            );
                            None
                        }
                    };
                    loop {
                        let req = { queue.lock().unwrap().pop_front() };
                        let Some(req) = req else { break };
                        #[cfg(feature = "xla")]
                        let res = match rt.as_ref() {
                            Some(rt) => handle_request(rt, &reference, &cfg, &metrics, &req),
                            None => handle_request_host(&reference, &cfg, &metrics, &req),
                        };
                        #[cfg(not(feature = "xla"))]
                        let res = handle_request_host(&reference, &cfg, &metrics, &req);
                        if res.is_err() {
                            metrics.requests_failed.fetch_add(1, Ordering::Relaxed);
                        }
                        if tx.send(res).is_err() {
                            break;
                        }
                    }
                })
                .map_err(|e| Error::Coordinator(format!("spawn failed: {e}")))?,
        );
    }
    drop(tx);

    let mut responses = Vec::new();
    let mut first_err: Option<Error> = None;
    for res in rx {
        match res {
            Ok(r) => responses.push(r),
            Err(e) => {
                if first_err.is_none() {
                    first_err = Some(e);
                }
            }
        }
    }
    for h in handles {
        let _ = h.join();
    }
    if responses.is_empty() {
        if let Some(e) = first_err {
            return Err(e);
        }
    }
    Ok((responses, metrics))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::MlpParams;
    use crate::profiler::StandardScaler;

    #[test]
    fn prediction_grid_sizes() {
        assert_eq!(prediction_grid(DeviceKind::OrinAgx, None, 1).len(), 4368);
        assert_eq!(prediction_grid(DeviceKind::XavierAgx, None, 1).len(), 1000);
        assert_eq!(prediction_grid(DeviceKind::OrinNano, None, 1).len(), 180);
        assert_eq!(prediction_grid(DeviceKind::OrinAgx, Some(200), 1).len(), 200);
    }

    #[test]
    fn prediction_grid_deterministic_per_seed() {
        let a = prediction_grid(DeviceKind::XavierAgx, None, 7);
        let b = prediction_grid(DeviceKind::XavierAgx, None, 7);
        assert_eq!(a.modes, b.modes);
    }

    fn host_reference() -> ReferenceModels {
        let mut rng = Rng::new(17);
        let ck = |target: &str| Checkpoint {
            params: MlpParams::init_he(&mut rng),
            feature_scaler: StandardScaler {
                mean: vec![6.0, 1400.0, 800.0, 2000.0],
                std: vec![3.5, 600.0, 350.0, 1100.0],
            },
            target_scaler: StandardScaler { mean: vec![30_000.0], std: vec![9_000.0] },
            target: target.into(),
            provenance: "host-fallback-test".into(),
            val_loss: 0.0,
        };
        ReferenceModels { time: ck("time"), power: ck("power") }
    }

    #[test]
    fn host_fallback_answers_without_artifacts() {
        let reference = host_reference();
        let cfg = CoordinatorConfig {
            prediction_grid: Some(300),
            ..Default::default()
        };
        let metrics = Metrics::new();
        let req = Request {
            id: 9,
            device: DeviceKind::OrinAgx,
            workload: Workload::mobilenet(),
            power_budget_w: 1e6, // any front point qualifies
            scenario: Scenario::FederatedLearning,
            seed: 5,
        };
        let resp = handle_request_host(&reference, &cfg, &metrics, &req).unwrap();
        assert!(resp.strategy.starts_with("host-fallback"));
        assert_eq!(resp.profiling_cost_s, 0.0);
        resp.chosen_mode.validate(DeviceKind::OrinAgx.spec()).unwrap();
        assert_eq!(metrics.requests_completed.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn host_serve_processes_queue_without_artifacts() {
        let reference = host_reference();
        let cfg = CoordinatorConfig {
            artifacts_dir: PathBuf::from("definitely-missing-artifacts"),
            prediction_grid: Some(200),
            workers: 2,
            ..Default::default()
        };
        let requests: Vec<Request> = (0..4)
            .map(|i| Request {
                id: i,
                device: DeviceKind::OrinAgx,
                workload: Workload::lstm(),
                power_budget_w: 1e6,
                scenario: Scenario::ContinuousLearning,
                seed: 40 + i,
            })
            .collect();
        let (responses, metrics) = serve(&cfg, &reference, requests).unwrap();
        assert_eq!(responses.len(), 4);
        let mut ids: Vec<u64> = responses.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, vec![0, 1, 2, 3]);
        assert_eq!(metrics.requests_completed.load(Ordering::Relaxed), 4);
    }
}
