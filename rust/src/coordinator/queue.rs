//! Streaming ingress: a deadline-aware priority queue of arriving jobs.
//!
//! The coordinator no longer consumes a pre-materialized `Vec<Request>`:
//! callers stream [`Job`]s — a request plus its *simulated arrival time*,
//! an optional latency deadline, and a scenario-derived scheduling
//! priority — and workers pull from this queue. Scheduling order among
//! the jobs whose arrival instant has passed is
//!
//! 1. **priority class** (see
//!    [`Scenario::priority`](crate::coordinator::Scenario::priority)):
//!    short federated /
//!    continuous-learning rounds overtake queued brute-force profiling
//!    jobs instead of head-of-line blocking behind them;
//! 2. **earliest absolute deadline** within a class (EDF; best-effort
//!    jobs order last);
//! 3. **submission order** as the final tie-break, so equal jobs stay
//!    FIFO and the schedule is deterministic.
//!
//! Jobs whose arrival lies in the future are parked in a separate
//! min-heap and promoted when their instant passes; a worker popping an
//! empty-but-alive queue blocks on a condvar (with a timeout at the next
//! pending arrival). [`RequestQueue::close`] ends the stream: workers
//! drain what remains, then `pop` returns `None`.
//!
//! All locking is poison-recovering (`util::sync`): a worker that panics
//! while holding the queue lock no longer wedges every other worker —
//! the survivors recover the guard and keep draining.

use std::cmp::Ordering as CmpOrdering;
use std::collections::BinaryHeap;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::coordinator::Request;
use crate::util::sync::{lock_unpoisoned, wait_timeout_unpoisoned, wait_unpoisoned};

/// One scheduled unit of work: a request plus its streaming metadata.
#[derive(Debug, Clone)]
pub struct Job {
    pub request: Request,
    /// Simulated arrival instant, in ms since the queue epoch (the
    /// coordinator's start). The queue holds the job back until then.
    pub arrival_ms: u64,
    /// Latency budget from arrival to response, in ms. `None` = best
    /// effort. Misses are counted in `Metrics::deadline_misses`.
    pub deadline_ms: Option<u64>,
    /// Scheduling class, derived from the request's scenario (higher
    /// pops first).
    pub priority: u8,
}

impl Job {
    /// A job that arrives now, best-effort, with the scenario's priority.
    pub fn immediate(request: Request) -> Job {
        Job::arriving(request, 0)
    }

    /// A job with a simulated arrival offset from the queue epoch.
    pub fn arriving(request: Request, arrival_ms: u64) -> Job {
        let priority = request.scenario.priority();
        Job { request, arrival_ms, deadline_ms: None, priority }
    }

    /// Attach an arrival-relative deadline.
    pub fn with_deadline(mut self, deadline_ms: u64) -> Job {
        self.deadline_ms = Some(deadline_ms);
        self
    }

    /// Absolute deadline on the queue clock (`u64::MAX` = best effort).
    pub fn absolute_deadline_ms(&self) -> u64 {
        self.deadline_ms
            .map_or(u64::MAX, |d| self.arrival_ms.saturating_add(d))
    }
}

/// Heap entry for an arrived job. Max-heap order = scheduling order:
/// priority desc, absolute deadline asc, submission sequence asc.
#[derive(Debug)]
struct Scheduled {
    priority: u8,
    deadline_abs_ms: u64,
    seq: u64,
    job: Job,
}

impl Scheduled {
    fn rank(&self) -> (u8, std::cmp::Reverse<u64>, std::cmp::Reverse<u64>) {
        (
            self.priority,
            std::cmp::Reverse(self.deadline_abs_ms),
            std::cmp::Reverse(self.seq),
        )
    }
}

impl PartialEq for Scheduled {
    fn eq(&self, other: &Self) -> bool {
        self.rank() == other.rank()
    }
}
impl Eq for Scheduled {}
impl PartialOrd for Scheduled {
    fn partial_cmp(&self, other: &Self) -> Option<CmpOrdering> {
        Some(self.cmp(other))
    }
}
impl Ord for Scheduled {
    fn cmp(&self, other: &Self) -> CmpOrdering {
        self.rank().cmp(&other.rank())
    }
}

/// Heap entry for a not-yet-arrived job. Max-heap inverted so the
/// *earliest* arrival pops first.
#[derive(Debug)]
struct Pending {
    arrival_ms: u64,
    seq: u64,
    job: Job,
}

impl Pending {
    fn rank(&self) -> (std::cmp::Reverse<u64>, std::cmp::Reverse<u64>) {
        (std::cmp::Reverse(self.arrival_ms), std::cmp::Reverse(self.seq))
    }
}

impl PartialEq for Pending {
    fn eq(&self, other: &Self) -> bool {
        self.rank() == other.rank()
    }
}
impl Eq for Pending {}
impl PartialOrd for Pending {
    fn partial_cmp(&self, other: &Self) -> Option<CmpOrdering> {
        Some(self.cmp(other))
    }
}
impl Ord for Pending {
    fn cmp(&self, other: &Self) -> CmpOrdering {
        self.rank().cmp(&other.rank())
    }
}

#[derive(Debug, Default)]
struct QueueState {
    ready: BinaryHeap<Scheduled>,
    pending: BinaryHeap<Pending>,
    closed: bool,
    seq: u64,
}

/// The shared ingress queue. Submitters push [`Job`]s (possibly with
/// future arrival instants); workers [`pop`](RequestQueue::pop) in
/// priority/deadline order.
#[derive(Debug)]
pub struct RequestQueue {
    state: Mutex<QueueState>,
    cv: Condvar,
    epoch: Instant,
}

impl Default for RequestQueue {
    fn default() -> Self {
        Self::new()
    }
}

impl RequestQueue {
    pub fn new() -> RequestQueue {
        RequestQueue {
            state: Mutex::new(QueueState::default()),
            cv: Condvar::new(),
            epoch: Instant::now(),
        }
    }

    /// Milliseconds since the queue epoch — the simulated arrival clock
    /// jobs are timed against.
    pub fn now_ms(&self) -> u64 {
        self.epoch.elapsed().as_millis() as u64
    }

    /// Enqueue one job. Returns `false` (dropping the job) if the queue
    /// has been closed.
    pub fn submit(&self, job: Job) -> bool {
        let mut st = lock_unpoisoned(&self.state);
        if st.closed {
            return false;
        }
        st.seq += 1;
        let seq = st.seq;
        if job.arrival_ms <= self.now_ms() {
            st.ready.push(Scheduled {
                priority: job.priority,
                deadline_abs_ms: job.absolute_deadline_ms(),
                seq,
                job,
            });
        } else {
            st.pending.push(Pending { arrival_ms: job.arrival_ms, seq, job });
        }
        drop(st);
        self.cv.notify_all();
        true
    }

    /// End the stream: no further submissions are accepted; workers
    /// drain what is already queued (including future arrivals), then
    /// `pop` returns `None`.
    pub fn close(&self) {
        lock_unpoisoned(&self.state).closed = true;
        self.cv.notify_all();
    }

    pub fn is_closed(&self) -> bool {
        lock_unpoisoned(&self.state).closed
    }

    /// Jobs currently queued (arrived + future).
    pub fn len(&self) -> usize {
        let st = lock_unpoisoned(&self.state);
        st.ready.len() + st.pending.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Blocking pop of the next schedulable job: the highest-priority
    /// *arrived* job, earliest deadline then FIFO within a class. Blocks
    /// while the queue is open but nothing has arrived yet; returns
    /// `None` once the queue is closed and fully drained.
    pub fn pop(&self) -> Option<Job> {
        let mut st = lock_unpoisoned(&self.state);
        loop {
            let now = self.now_ms();
            // promote every parked job whose simulated arrival has passed
            loop {
                match st.pending.peek() {
                    Some(p) if p.arrival_ms <= now => {}
                    _ => break,
                }
                let p = st.pending.pop().expect("peeked entry must pop");
                st.ready.push(Scheduled {
                    priority: p.job.priority,
                    deadline_abs_ms: p.job.absolute_deadline_ms(),
                    seq: p.seq,
                    job: p.job,
                });
            }
            if let Some(s) = st.ready.pop() {
                return Some(s.job);
            }
            if let Some(p) = st.pending.peek() {
                // nothing arrived yet: sleep until the next arrival (or a
                // submission/close wakes us earlier)
                let wait_ms = p.arrival_ms.saturating_sub(now).max(1);
                let (guard, _) =
                    wait_timeout_unpoisoned(&self.cv, st, Duration::from_millis(wait_ms));
                st = guard;
                continue;
            }
            if st.closed {
                return None;
            }
            st = wait_unpoisoned(&self.cv, st);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Scenario;
    use crate::device::DeviceKind;
    use crate::workload::Workload;

    fn req(id: u64, scenario: Scenario) -> Request {
        Request {
            id,
            device: DeviceKind::OrinAgx,
            workload: Workload::mobilenet(),
            power_budget_w: 30.0,
            scenario,
            affinity: None,
            node: None,
            seed: id,
        }
    }

    fn drain_ids(q: &RequestQueue) -> Vec<u64> {
        q.close();
        let mut ids = Vec::new();
        while let Some(j) = q.pop() {
            ids.push(j.request.id);
        }
        ids
    }

    #[test]
    fn short_jobs_overtake_queued_brute_force() {
        let q = RequestQueue::new();
        // a brute-force profiling job is queued first...
        assert!(q.submit(Job::immediate(req(0, Scenario::OneTimeTraining))));
        // ...then short jobs arrive behind it
        assert!(q.submit(Job::immediate(req(1, Scenario::FederatedLearning))));
        assert!(q.submit(Job::immediate(req(2, Scenario::ContinuousLearning))));
        assert!(q.submit(Job::immediate(req(3, Scenario::FineTuning))));
        assert_eq!(drain_ids(&q), vec![1, 2, 3, 0]);
    }

    #[test]
    fn earliest_deadline_first_within_a_class() {
        let q = RequestQueue::new();
        q.submit(Job::immediate(req(0, Scenario::FederatedLearning)).with_deadline(500));
        q.submit(Job::immediate(req(1, Scenario::FederatedLearning)).with_deadline(100));
        q.submit(Job::immediate(req(2, Scenario::FederatedLearning))); // best effort: last
        q.submit(Job::immediate(req(3, Scenario::FederatedLearning)).with_deadline(300));
        assert_eq!(drain_ids(&q), vec![1, 3, 0, 2]);
    }

    #[test]
    fn fifo_within_equal_priority_and_deadline() {
        let q = RequestQueue::new();
        for id in 0..5 {
            q.submit(Job::immediate(req(id, Scenario::ContinuousLearning)));
        }
        assert_eq!(drain_ids(&q), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn future_arrivals_are_held_back() {
        let q = RequestQueue::new();
        // high-priority job 80 ms in the future, low-priority job now:
        // the low-priority one must pop first — priority applies among
        // *arrived* jobs, not against jobs that do not exist yet
        q.submit(Job::arriving(req(0, Scenario::FederatedLearning), 80));
        q.submit(Job::immediate(req(1, Scenario::OneTimeTraining)));
        q.close();
        assert_eq!(q.pop().map(|j| j.request.id), Some(1));
        // the second pop blocks until the simulated arrival passes
        assert_eq!(q.pop().map(|j| j.request.id), Some(0));
        assert!(q.now_ms() >= 80, "popped before its simulated arrival");
        assert_eq!(q.pop().map(|j| j.request.id), None);
    }

    #[test]
    fn close_drains_then_returns_none() {
        let q = RequestQueue::new();
        q.submit(Job::immediate(req(0, Scenario::FineTuning)));
        q.close();
        // closed queues reject new work...
        assert!(!q.submit(Job::immediate(req(1, Scenario::FineTuning))));
        // ...but still drain what was queued
        assert_eq!(q.pop().map(|j| j.request.id), Some(0));
        assert_eq!(q.pop().map(|j| j.request.id), None);
        assert!(q.is_empty());
    }

    #[test]
    fn pop_blocks_until_submission() {
        let q = RequestQueue::new();
        std::thread::scope(|s| {
            let popper = s.spawn(|| q.pop().map(|j| j.request.id));
            std::thread::sleep(Duration::from_millis(30));
            q.submit(Job::immediate(req(7, Scenario::FederatedLearning)));
            assert_eq!(popper.join().unwrap(), Some(7));
        });
    }

    #[test]
    fn poisoned_queue_lock_is_recovered() {
        // satellite regression: a worker that panics while holding the
        // queue mutex used to poison it, and every later `.lock().unwrap()`
        // cascaded — wedging all surviving workers. The queue now recovers
        // the guard and keeps serving.
        let q = RequestQueue::new();
        assert!(q.submit(Job::immediate(req(1, Scenario::FederatedLearning))));
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _guard = q.state.lock().unwrap();
            panic!("worker died holding the queue lock");
        }));
        assert!(res.is_err());
        assert!(q.state.lock().is_err(), "lock must actually be poisoned");
        // survivors still submit, pop in priority order, and drain
        assert!(q.submit(Job::immediate(req(2, Scenario::OneTimeTraining))));
        q.close();
        assert_eq!(q.pop().map(|j| j.request.id), Some(1));
        assert_eq!(q.pop().map(|j| j.request.id), Some(2));
        assert_eq!(q.pop().map(|j| j.request.id), None);
    }
}
