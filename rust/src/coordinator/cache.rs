//! Grid-resident serving state: the coordinator's cache of prediction
//! planes and Pareto fronts.
//!
//! The paper's deployment query — "best power mode under budget B" — is
//! asked over a fixed grid with fixed reference models; only the budget
//! (and the workload bookkeeping) varies between most requests. The seed
//! serve path nevertheless re-ran the whole pipeline per request: grid
//! enumeration, two engine builds, two grid-sized forward passes and a
//! from-scratch Pareto sort. This module makes that state *resident*:
//!
//! * [`GridEntry`] — one device grid plus its shared SoA
//!   [`FeatureMatrix`], keyed by [`GridKey`] and reused by both the time
//!   and power models and by every model pair that predicts over the grid;
//! * [`ServePlane`] — the full prediction planes (raw-unit time and power
//!   per mode) and the [`ParetoFront`] over them, keyed by [`PlaneKey`]
//!   (grid identity + content fingerprints of both checkpoints, see
//!   `Checkpoint::fingerprint`);
//! * [`PlaneCache`] — the two bounded, thread-safe maps, shared by all
//!   workers of a [`serve`](crate::coordinator::serve) call.
//!
//! A cache-hit request therefore costs one fingerprint pass, one map
//! lookup and one `partition_point` binary search over the cached front —
//! O(log front) instead of O(grid × params). Builds run outside the lock:
//! two workers missing the same key concurrently each build (the build is
//! deterministic per key, so the results are identical) and first insert
//! wins. [`Metrics`] counts hits and misses so degraded cache behaviour
//! is visible in the serve report.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::coordinator::Metrics;
use crate::device::{DeviceKind, FeatureMatrix, PowerModeGrid};
use crate::pareto::ParetoFront;

/// Bound on resident planes/grids. Fleets have a handful of device kinds
/// and model pairs; the caps only guard pathological request streams
/// (e.g. a distinct grid seed per request on seed-dependent grids).
const MAX_GRIDS: usize = 64;
const MAX_PLANES: usize = 64;

/// Identity of the grid a request's predictions are computed over.
///
/// `grid_seed` is canonicalized to 0 for seed-independent grids (the
/// Orin paper subset) so every request shares one entry; seed-dependent
/// grids (random subsets) key on the seed they were drawn with, which
/// keeps caching *sound* — two requests share an entry only when they
/// resolve to the identical mode list.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct GridKey {
    pub device: DeviceKind,
    pub override_n: Option<usize>,
    pub grid_seed: u64,
}

impl GridKey {
    /// Key for the grid `prediction_grid(device, override_n, seed)`
    /// resolves to. Seed-(in)dependence is owned by
    /// [`prediction_grid_is_seed_independent`](crate::coordinator::prediction_grid_is_seed_independent)
    /// — `prediction_grid` dispatches through the same predicate, so the
    /// canonicalization cannot drift from the grid construction.
    pub fn for_request(device: DeviceKind, override_n: Option<usize>, seed: u64) -> GridKey {
        let canonical =
            crate::coordinator::prediction_grid_is_seed_independent(device, override_n);
        GridKey {
            device,
            override_n,
            grid_seed: if canonical { 0 } else { seed },
        }
    }
}

/// Identity of a full serve plane: the grid plus the two models that
/// predicted over it. Checkpoint fingerprints are content hashes, so
/// retrained/transferred reference models move the key and can never
/// serve stale planes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct PlaneKey {
    pub grid: GridKey,
    pub time_fp: u64,
    pub power_fp: u64,
}

/// Device-level grid state shared across model pairs: the mode list and
/// its SoA feature matrix, built once.
#[derive(Debug, Clone)]
pub struct GridEntry {
    pub grid: PowerModeGrid,
    pub features: FeatureMatrix,
}

impl GridEntry {
    pub fn new(grid: PowerModeGrid) -> GridEntry {
        let features = grid.feature_matrix();
        GridEntry { grid, features }
    }
}

/// Everything needed to answer budget queries over one (grid, model-pair):
/// the raw-unit prediction planes and the Pareto front over them.
///
/// The budget path reads only `front`; the full planes are retained
/// (bounded: ≤ 2 × grid × 8 bytes × `MAX_PLANES`) so plane-level
/// consumers — per-mode diagnostics, Fig-10-style exports, future
/// non-budget queries — answer from cache instead of re-predicting.
#[derive(Debug, Clone)]
pub struct ServePlane {
    pub grid: Arc<GridEntry>,
    /// Predicted training time per mode (ms), parallel to `grid.grid.modes`.
    pub times: Vec<f64>,
    /// Predicted power per mode (mW), parallel to `grid.grid.modes`.
    pub powers: Vec<f64>,
    pub front: ParetoFront,
}

/// The coordinator-level cache: grids shared across model pairs, planes
/// shared across requests. Cheap to share (`Arc`) across worker threads.
#[derive(Debug, Default)]
pub struct PlaneCache {
    grids: Mutex<HashMap<GridKey, Arc<GridEntry>>>,
    planes: Mutex<HashMap<PlaneKey, Arc<ServePlane>>>,
}

impl PlaneCache {
    pub fn new() -> PlaneCache {
        PlaneCache::default()
    }

    /// Grid + feature matrix for `key`, building (outside the lock) on
    /// miss. `build` must be deterministic for the key.
    pub fn grid(&self, key: GridKey, build: impl FnOnce() -> GridEntry) -> Arc<GridEntry> {
        if let Some(hit) = self.grids.lock().unwrap().get(&key) {
            return Arc::clone(hit);
        }
        let built = Arc::new(build());
        let mut map = self.grids.lock().unwrap();
        evict_if_full(&mut map, MAX_GRIDS, &key);
        Arc::clone(map.entry(key).or_insert(built))
    }

    /// Serve plane for `key`, building (outside the lock) on miss and
    /// recording the hit/miss in `metrics`.
    pub fn plane(
        &self,
        key: PlaneKey,
        metrics: &Metrics,
        build: impl FnOnce() -> ServePlane,
    ) -> Arc<ServePlane> {
        use std::sync::atomic::Ordering;
        if let Some(hit) = self.planes.lock().unwrap().get(&key) {
            metrics.plane_cache_hits.fetch_add(1, Ordering::Relaxed);
            return Arc::clone(hit);
        }
        metrics.plane_cache_misses.fetch_add(1, Ordering::Relaxed);
        let built = Arc::new(build());
        let mut map = self.planes.lock().unwrap();
        evict_if_full(&mut map, MAX_PLANES, &key);
        Arc::clone(map.entry(key).or_insert(built))
    }

    /// (resident grids, resident planes) — for reporting/tests.
    pub fn sizes(&self) -> (usize, usize) {
        (
            self.grids.lock().unwrap().len(),
            self.planes.lock().unwrap().len(),
        )
    }
}

/// Keep `map` bounded: if inserting a *new* key would exceed `cap`, drop
/// one resident entry (arbitrary — the maps are small and churn only on
/// pathological streams, so LRU bookkeeping isn't worth its lock time).
fn evict_if_full<K: Copy + Eq + std::hash::Hash, V>(
    map: &mut HashMap<K, V>,
    cap: usize,
    incoming: &K,
) {
    if map.len() >= cap && !map.contains_key(incoming) {
        if let Some(k) = map.keys().next().copied() {
            map.remove(&k);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::Ordering;

    fn entry(n: usize) -> GridEntry {
        let full = PowerModeGrid::paper_subset(DeviceKind::OrinAgx);
        GridEntry::new(PowerModeGrid {
            kind: DeviceKind::OrinAgx,
            modes: full.modes[..n].to_vec(),
        })
    }

    fn plane_over(grid: Arc<GridEntry>) -> ServePlane {
        let n = grid.grid.len();
        let times: Vec<f64> = (0..n).map(|i| 1000.0 - i as f64).collect();
        let powers: Vec<f64> = (0..n).map(|i| 10_000.0 + 10.0 * i as f64).collect();
        let points: Vec<crate::pareto::Point> = grid
            .grid
            .modes
            .iter()
            .zip(times.iter().zip(&powers))
            .map(|(m, (&t, &p))| crate::pareto::Point { mode: *m, time: t, power_mw: p })
            .collect();
        let front = ParetoFront::build(&points);
        ServePlane { grid, times, powers, front }
    }

    #[test]
    fn grid_key_canonicalizes_seed_independent_grids() {
        let a = GridKey::for_request(DeviceKind::OrinAgx, None, 7);
        let b = GridKey::for_request(DeviceKind::OrinAgx, None, 99);
        assert_eq!(a, b);
        // seed-dependent grids must NOT be conflated across seeds
        let c = GridKey::for_request(DeviceKind::XavierAgx, None, 7);
        let d = GridKey::for_request(DeviceKind::XavierAgx, None, 99);
        assert_ne!(c, d);
        let e = GridKey::for_request(DeviceKind::OrinAgx, Some(200), 7);
        let f = GridKey::for_request(DeviceKind::OrinAgx, Some(200), 99);
        assert_ne!(e, f);
    }

    #[test]
    fn plane_hits_share_the_arc_and_count() {
        let cache = PlaneCache::new();
        let metrics = Metrics::new();
        let gkey = GridKey::for_request(DeviceKind::OrinAgx, None, 1);
        let key = PlaneKey { grid: gkey, time_fp: 1, power_fp: 2 };
        let g = cache.grid(gkey, || entry(50));
        let p1 = cache.plane(key, &metrics, || plane_over(Arc::clone(&g)));
        let p2 = cache.plane(key, &metrics, || panic!("must not rebuild on hit"));
        assert!(Arc::ptr_eq(&p1, &p2));
        assert_eq!(metrics.plane_cache_misses.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.plane_cache_hits.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn grid_entry_is_shared_across_model_pairs() {
        let cache = PlaneCache::new();
        let metrics = Metrics::new();
        let gkey = GridKey::for_request(DeviceKind::OrinAgx, None, 1);
        let k1 = PlaneKey { grid: gkey, time_fp: 1, power_fp: 2 };
        let k2 = PlaneKey { grid: gkey, time_fp: 3, power_fp: 4 };
        let p1 = cache.plane(k1, &metrics, || {
            plane_over(cache.grid(gkey, || entry(40)))
        });
        let p2 = cache.plane(k2, &metrics, || {
            plane_over(cache.grid(gkey, || panic!("grid must be resident")))
        });
        assert!(Arc::ptr_eq(&p1.grid, &p2.grid));
        assert_eq!(cache.sizes(), (1, 2));
    }

    #[test]
    fn caches_stay_bounded() {
        let cache = PlaneCache::new();
        let metrics = Metrics::new();
        for seed in 0..(MAX_PLANES as u64 + 40) {
            let gkey = GridKey::for_request(DeviceKind::XavierAgx, Some(10), seed);
            let key = PlaneKey { grid: gkey, time_fp: seed, power_fp: seed };
            let g = cache.grid(gkey, || entry(10));
            cache.plane(key, &metrics, || plane_over(g));
        }
        let (grids, planes) = cache.sizes();
        assert!(grids <= MAX_GRIDS, "{grids} grids resident");
        assert!(planes <= MAX_PLANES, "{planes} planes resident");
        assert_eq!(
            metrics.plane_cache_misses.load(Ordering::Relaxed),
            MAX_PLANES as u64 + 40
        );
    }
}
