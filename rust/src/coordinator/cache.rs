//! Grid-resident serving state: the coordinator's cache of prediction
//! planes and Pareto fronts.
//!
//! The paper's deployment query — "best power mode under budget B" — is
//! asked over a fixed grid with fixed reference models; only the budget
//! (and the workload bookkeeping) varies between most requests. The seed
//! serve path nevertheless re-ran the whole pipeline per request: grid
//! enumeration, two engine builds, two grid-sized forward passes and a
//! from-scratch Pareto sort. This module makes that state *resident*:
//!
//! * [`GridEntry`] — one device grid plus its shared SoA
//!   [`FeatureMatrix`], keyed by [`GridKey`] and reused by both the time
//!   and power models and by every model pair that predicts over the grid;
//! * [`ServePlane`] — the full prediction planes (raw-unit time and power
//!   per mode) and the [`ParetoFront`] over them, keyed by [`PlaneKey`]
//!   (grid identity + content fingerprints of both checkpoints, see
//!   `Checkpoint::fingerprint`);
//! * [`HostModels`] — a per-workload pair of host-trained checkpoints
//!   (PowerTrain transfer or scratch NN), keyed by [`ModelKey`] — every
//!   input that determines the (deterministic) profiling corpus and fit,
//!   so a hit provably reproduces what a rebuild would compute. Planes
//!   for transferred models then flow through the ordinary [`PlaneKey`]
//!   path: the transferred checkpoints' fingerprints key them, so
//!   per-workload planes cache (and evict) alongside reference planes;
//! * [`PlaneCache`] — the bounded, thread-safe maps, shared by all
//!   workers of a [`serve`](crate::coordinator::serve) call.
//!
//! A cache-hit request therefore costs one fingerprint pass, one map
//! lookup and one `partition_point` binary search over the cached front —
//! O(log front) instead of O(grid × params). Builds run outside the lock:
//! two workers missing the same key concurrently each build (the build is
//! deterministic per key, so the results are identical) and first insert
//! wins. [`Metrics`] counts hits and misses so degraded cache behaviour
//! is visible in the serve report.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::coordinator::{Metrics, Strategy};
use crate::device::{DeviceKind, FeatureMatrix, PowerModeGrid};
use crate::error::Result;
use crate::nn::checkpoint::Checkpoint;
use crate::pareto::ParetoFront;
use crate::workload::Workload;

/// Bound on resident planes/grids/models. Fleets have a handful of device
/// kinds and model pairs; the caps only guard pathological request
/// streams (e.g. a distinct grid seed per request on seed-dependent
/// grids, or a distinct workload/seed per request on the model cache).
const MAX_GRIDS: usize = 64;
const MAX_PLANES: usize = 64;
const MAX_MODELS: usize = 64;

/// Identity of the grid a request's predictions are computed over.
///
/// `grid_seed` is canonicalized to 0 for seed-independent grids (the
/// Orin paper subset) so every request shares one entry; seed-dependent
/// grids (random subsets) key on the seed they were drawn with, which
/// keeps caching *sound* — two requests share an entry only when they
/// resolve to the identical mode list.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct GridKey {
    pub device: DeviceKind,
    pub override_n: Option<usize>,
    pub grid_seed: u64,
}

impl GridKey {
    /// Key for the grid `prediction_grid(device, override_n, seed)`
    /// resolves to. Seed-(in)dependence is owned by
    /// [`prediction_grid_is_seed_independent`](crate::coordinator::prediction_grid_is_seed_independent)
    /// — `prediction_grid` dispatches through the same predicate, so the
    /// canonicalization cannot drift from the grid construction.
    pub fn for_request(device: DeviceKind, override_n: Option<usize>, seed: u64) -> GridKey {
        let canonical =
            crate::coordinator::prediction_grid_is_seed_independent(device, override_n);
        GridKey {
            device,
            override_n,
            grid_seed: if canonical { 0 } else { seed },
        }
    }
}

/// Identity of a full serve plane: the grid plus the two models that
/// predicted over it. Checkpoint fingerprints are content hashes, so
/// retrained/transferred reference models move the key and can never
/// serve stale planes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct PlaneKey {
    pub grid: GridKey,
    pub time_fp: u64,
    pub power_fp: u64,
}

/// Identity of a per-workload host-trained model pair: every input that
/// determines the profiling corpus (the grid it was sampled from, the
/// workload simulated, the request seed driving sampling + telemetry)
/// and the fit (strategy, epochs, and — for transfer — the reference
/// models fine-tuned from, by content fingerprint). Host training is
/// deterministic in all of these, so equal keys provably yield
/// bit-identical checkpoints and caching is sound.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ModelKey {
    pub grid: GridKey,
    pub workload: Workload,
    /// Request seed (drives mode sampling and simulated telemetry).
    pub seed: u64,
    pub strategy: Strategy,
    /// Fine-tuning / training epochs (`CoordinatorConfig::transfer_epochs`).
    pub epochs: usize,
    /// Reference checkpoint fingerprints the transfer starts from (also
    /// kept in the key for scratch strategies: harmless, and it keeps
    /// entries from outliving a reference-model swap).
    pub ref_time_fp: u64,
    pub ref_power_fp: u64,
}

/// A host-trained (time, power) checkpoint pair plus the bookkeeping the
/// serve path reports: the checkpoints' content fingerprints (the plane
/// key halves) and what the one-time profiling cost to build them was.
#[derive(Debug, Clone)]
pub struct HostModels {
    pub time: Checkpoint,
    pub power: Checkpoint,
    pub time_fp: u64,
    pub power_fp: u64,
    /// Simulated device-seconds of online profiling this fit consumed.
    pub profiling_cost_s: f64,
}

impl HostModels {
    pub fn new(time: Checkpoint, power: Checkpoint, profiling_cost_s: f64) -> HostModels {
        let (time_fp, power_fp) = (time.fingerprint(), power.fingerprint());
        HostModels { time, power, time_fp, power_fp, profiling_cost_s }
    }
}

/// Device-level grid state shared across model pairs: the mode list and
/// its SoA feature matrix, built once.
#[derive(Debug, Clone)]
pub struct GridEntry {
    pub grid: PowerModeGrid,
    pub features: FeatureMatrix,
}

impl GridEntry {
    pub fn new(grid: PowerModeGrid) -> GridEntry {
        let features = grid.feature_matrix();
        GridEntry { grid, features }
    }
}

/// Everything needed to answer budget queries over one (grid, model-pair):
/// the raw-unit prediction planes and the Pareto front over them.
///
/// The budget path reads only `front`; the full planes are retained
/// (bounded: ≤ 2 × grid × 8 bytes × `MAX_PLANES`) so plane-level
/// consumers — per-mode diagnostics, Fig-10-style exports, future
/// non-budget queries — answer from cache instead of re-predicting.
#[derive(Debug, Clone)]
pub struct ServePlane {
    pub grid: Arc<GridEntry>,
    /// Predicted training time per mode (ms), parallel to `grid.grid.modes`.
    pub times: Vec<f64>,
    /// Predicted power per mode (mW), parallel to `grid.grid.modes`.
    pub powers: Vec<f64>,
    pub front: ParetoFront,
}

/// The coordinator-level cache: grids shared across model pairs, planes
/// shared across requests. Cheap to share (`Arc`) across worker threads.
#[derive(Debug, Default)]
pub struct PlaneCache {
    grids: Mutex<HashMap<GridKey, Arc<GridEntry>>>,
    planes: Mutex<HashMap<PlaneKey, Arc<ServePlane>>>,
    models: Mutex<HashMap<ModelKey, Arc<HostModels>>>,
}

impl PlaneCache {
    pub fn new() -> PlaneCache {
        PlaneCache::default()
    }

    /// Grid + feature matrix for `key`, building (outside the lock) on
    /// miss. `build` must be deterministic for the key.
    pub fn grid(&self, key: GridKey, build: impl FnOnce() -> GridEntry) -> Arc<GridEntry> {
        if let Some(hit) = self.grids.lock().unwrap().get(&key) {
            return Arc::clone(hit);
        }
        let built = Arc::new(build());
        let mut map = self.grids.lock().unwrap();
        evict_if_full(&mut map, MAX_GRIDS, &key);
        Arc::clone(map.entry(key).or_insert(built))
    }

    /// Serve plane for `key`, building (outside the lock) on miss and
    /// recording the hit/miss in `metrics`.
    pub fn plane(
        &self,
        key: PlaneKey,
        metrics: &Metrics,
        build: impl FnOnce() -> ServePlane,
    ) -> Arc<ServePlane> {
        use std::sync::atomic::Ordering;
        if let Some(hit) = self.planes.lock().unwrap().get(&key) {
            metrics.plane_cache_hits.fetch_add(1, Ordering::Relaxed);
            return Arc::clone(hit);
        }
        metrics.plane_cache_misses.fetch_add(1, Ordering::Relaxed);
        let built = Arc::new(build());
        let mut map = self.planes.lock().unwrap();
        evict_if_full(&mut map, MAX_PLANES, &key);
        Arc::clone(map.entry(key).or_insert(built))
    }

    /// Host-trained model pair for `key`, building (outside the lock, so
    /// concurrent misses on *different* keys profile/train in parallel)
    /// on miss. Returns the resident entry plus whether *this call* paid
    /// the build — callers report profiling cost only when they actually
    /// profiled. A fallible build is not cached: the error propagates and
    /// the next request retries.
    pub fn models(
        &self,
        key: ModelKey,
        metrics: &Metrics,
        build: impl FnOnce() -> Result<HostModels>,
    ) -> Result<(Arc<HostModels>, bool)> {
        use std::sync::atomic::Ordering;
        if let Some(hit) = self.models.lock().unwrap().get(&key) {
            metrics.model_cache_hits.fetch_add(1, Ordering::Relaxed);
            return Ok((Arc::clone(hit), false));
        }
        metrics.model_cache_misses.fetch_add(1, Ordering::Relaxed);
        let built = Arc::new(build()?);
        let mut map = self.models.lock().unwrap();
        evict_if_full(&mut map, MAX_MODELS, &key);
        // first insert wins; the build is deterministic per key, so a
        // racing worker's entry is bit-identical anyway
        Ok((Arc::clone(map.entry(key).or_insert(built)), true))
    }

    /// (resident grids, resident planes, resident model pairs) — for
    /// reporting/tests.
    pub fn sizes(&self) -> (usize, usize, usize) {
        (
            self.grids.lock().unwrap().len(),
            self.planes.lock().unwrap().len(),
            self.models.lock().unwrap().len(),
        )
    }
}

/// Keep `map` bounded: if inserting a *new* key would exceed `cap`, drop
/// one resident entry (arbitrary — the maps are small and churn only on
/// pathological streams, so LRU bookkeeping isn't worth its lock time).
fn evict_if_full<K: Copy + Eq + std::hash::Hash, V>(
    map: &mut HashMap<K, V>,
    cap: usize,
    incoming: &K,
) {
    if map.len() >= cap && !map.contains_key(incoming) {
        if let Some(k) = map.keys().next().copied() {
            map.remove(&k);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::Ordering;

    fn entry(n: usize) -> GridEntry {
        let full = PowerModeGrid::paper_subset(DeviceKind::OrinAgx);
        GridEntry::new(PowerModeGrid {
            kind: DeviceKind::OrinAgx,
            modes: full.modes[..n].to_vec(),
        })
    }

    fn plane_over(grid: Arc<GridEntry>) -> ServePlane {
        let n = grid.grid.len();
        let times: Vec<f64> = (0..n).map(|i| 1000.0 - i as f64).collect();
        let powers: Vec<f64> = (0..n).map(|i| 10_000.0 + 10.0 * i as f64).collect();
        let points: Vec<crate::pareto::Point> = grid
            .grid
            .modes
            .iter()
            .zip(times.iter().zip(&powers))
            .map(|(m, (&t, &p))| crate::pareto::Point { mode: *m, time: t, power_mw: p })
            .collect();
        let front = ParetoFront::build(&points);
        ServePlane { grid, times, powers, front }
    }

    #[test]
    fn grid_key_canonicalizes_seed_independent_grids() {
        let a = GridKey::for_request(DeviceKind::OrinAgx, None, 7);
        let b = GridKey::for_request(DeviceKind::OrinAgx, None, 99);
        assert_eq!(a, b);
        // seed-dependent grids must NOT be conflated across seeds
        let c = GridKey::for_request(DeviceKind::XavierAgx, None, 7);
        let d = GridKey::for_request(DeviceKind::XavierAgx, None, 99);
        assert_ne!(c, d);
        let e = GridKey::for_request(DeviceKind::OrinAgx, Some(200), 7);
        let f = GridKey::for_request(DeviceKind::OrinAgx, Some(200), 99);
        assert_ne!(e, f);
    }

    #[test]
    fn plane_hits_share_the_arc_and_count() {
        let cache = PlaneCache::new();
        let metrics = Metrics::new();
        let gkey = GridKey::for_request(DeviceKind::OrinAgx, None, 1);
        let key = PlaneKey { grid: gkey, time_fp: 1, power_fp: 2 };
        let g = cache.grid(gkey, || entry(50));
        let p1 = cache.plane(key, &metrics, || plane_over(Arc::clone(&g)));
        let p2 = cache.plane(key, &metrics, || panic!("must not rebuild on hit"));
        assert!(Arc::ptr_eq(&p1, &p2));
        assert_eq!(metrics.plane_cache_misses.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.plane_cache_hits.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn grid_entry_is_shared_across_model_pairs() {
        let cache = PlaneCache::new();
        let metrics = Metrics::new();
        let gkey = GridKey::for_request(DeviceKind::OrinAgx, None, 1);
        let k1 = PlaneKey { grid: gkey, time_fp: 1, power_fp: 2 };
        let k2 = PlaneKey { grid: gkey, time_fp: 3, power_fp: 4 };
        let p1 = cache.plane(k1, &metrics, || {
            plane_over(cache.grid(gkey, || entry(40)))
        });
        let p2 = cache.plane(k2, &metrics, || {
            plane_over(cache.grid(gkey, || panic!("grid must be resident")))
        });
        assert!(Arc::ptr_eq(&p1.grid, &p2.grid));
        assert_eq!(cache.sizes(), (1, 2, 0));
    }

    #[test]
    fn caches_stay_bounded() {
        let cache = PlaneCache::new();
        let metrics = Metrics::new();
        for seed in 0..(MAX_PLANES as u64 + 40) {
            let gkey = GridKey::for_request(DeviceKind::XavierAgx, Some(10), seed);
            let key = PlaneKey { grid: gkey, time_fp: seed, power_fp: seed };
            let g = cache.grid(gkey, || entry(10));
            cache.plane(key, &metrics, || plane_over(g));
        }
        let (grids, planes, _) = cache.sizes();
        assert!(grids <= MAX_GRIDS, "{grids} grids resident");
        assert!(planes <= MAX_PLANES, "{planes} planes resident");
        assert_eq!(
            metrics.plane_cache_misses.load(Ordering::Relaxed),
            MAX_PLANES as u64 + 40
        );
    }

    fn demo_models(tag: f32) -> HostModels {
        use crate::nn::MlpParams;
        use crate::profiler::StandardScaler;
        let ck = |target: &str| {
            let mut params = MlpParams::zeros();
            params.leaves[0][0] = tag;
            Checkpoint {
                params,
                feature_scaler: StandardScaler {
                    mean: vec![0.0; 4],
                    std: vec![1.0; 4],
                },
                target_scaler: StandardScaler { mean: vec![0.0], std: vec![1.0] },
                target: target.into(),
                provenance: "cache-test".into(),
                val_loss: 0.0,
            }
        };
        HostModels::new(ck("time"), ck("power"), 120.0)
    }

    fn model_key(seed: u64) -> ModelKey {
        ModelKey {
            grid: GridKey::for_request(DeviceKind::OrinAgx, Some(50), seed),
            workload: Workload::mobilenet(),
            seed,
            strategy: Strategy::PowerTrain(50),
            epochs: 100,
            ref_time_fp: 1,
            ref_power_fp: 2,
        }
    }

    #[test]
    fn model_hits_share_the_arc_count_and_report_no_build() {
        let cache = PlaneCache::new();
        let metrics = Metrics::new();
        let key = model_key(5);
        let (m1, built1) = cache.models(key, &metrics, || Ok(demo_models(1.0))).unwrap();
        let (m2, built2) = cache
            .models(key, &metrics, || panic!("must not rebuild on hit"))
            .unwrap();
        assert!(built1 && !built2);
        assert!(Arc::ptr_eq(&m1, &m2));
        assert_eq!(metrics.model_cache_misses.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.model_cache_hits.load(Ordering::Relaxed), 1);
        assert_eq!(cache.sizes(), (0, 0, 1));
    }

    #[test]
    fn failed_model_builds_are_not_cached() {
        let cache = PlaneCache::new();
        let metrics = Metrics::new();
        let key = model_key(6);
        let err = cache.models(key, &metrics, || {
            Err(crate::error::Error::Training("simulated divergence".into()))
        });
        assert!(err.is_err());
        assert_eq!(cache.sizes(), (0, 0, 0));
        // the next request retries the build instead of serving the error
        let (_, built) = cache.models(key, &metrics, || Ok(demo_models(2.0))).unwrap();
        assert!(built);
        assert_eq!(metrics.model_cache_misses.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn model_cache_stays_bounded() {
        let cache = PlaneCache::new();
        let metrics = Metrics::new();
        for seed in 0..(MAX_MODELS as u64 + 10) {
            cache
                .models(model_key(seed), &metrics, || Ok(demo_models(seed as f32)))
                .unwrap();
        }
        let (_, _, models) = cache.sizes();
        assert!(models <= MAX_MODELS, "{models} model pairs resident");
    }
}
