//! Grid-resident serving state: the coordinator's cache of prediction
//! planes and Pareto fronts, with *singleflight* acquisition.
//!
//! The paper's deployment query — "best power mode under budget B" — is
//! asked over a fixed grid with fixed reference models; only the budget
//! (and the workload bookkeeping) varies between most requests. The seed
//! serve path nevertheless re-ran the whole pipeline per request: grid
//! enumeration, two engine builds, two grid-sized forward passes and a
//! from-scratch Pareto sort. This module makes that state *resident*:
//!
//! * [`GridEntry`] — one device grid plus its shared SoA
//!   [`FeatureMatrix`], keyed by [`GridKey`] and reused by both the time
//!   and power models and by every model pair that predicts over the grid;
//! * [`ServePlane`] — the full prediction planes (raw-unit time and power
//!   per mode) and the [`ParetoFront`] over them, keyed by [`PlaneKey`]
//!   (grid identity + content fingerprints of both checkpoints, see
//!   `Checkpoint::fingerprint`);
//! * [`HostModels`] — a per-workload pair of host-trained checkpoints
//!   (PowerTrain transfer or scratch NN), keyed by [`ModelKey`] — every
//!   input that determines the (deterministic) profiling corpus and fit,
//!   so a hit provably reproduces what a rebuild would compute. Planes
//!   for transferred models then flow through the ordinary [`PlaneKey`]
//!   path: the transferred checkpoints' fingerprints key them, so
//!   per-workload planes cache (and evict) alongside reference planes;
//! * [`PlaneCache`] — the bounded, thread-safe maps, shared by all
//!   workers of a coordinator service
//!   ([`Coordinator`](crate::coordinator::Coordinator) / legacy
//!   [`serve`](crate::coordinator::serve) call).
//!
//! **Versioned publishes**: model slots additionally support atomic
//! *republication* ([`PlaneCache::publish_models`]) — the model
//! lifecycle's background warm refit swaps a Ready slot for a refreshed
//! pair stamped with the next version and drops the superseded planes
//! ([`PlaneCache::invalidate_planes`]), while
//! [`PlaneCache::peek_models`] lets the feedback lane read the resident
//! pair without ever building or blocking. Serving stays tear-free by
//! construction: planes are keyed by the checkpoint fingerprints of
//! whichever model pair a request resolved.
//!
//! **Singleflight**: each map slot is either `Ready` (the built value) or
//! `InFlight` (a condvar the leader signals on completion). The first
//! requester of a key becomes the *leader* and builds outside the map
//! lock — misses on different keys still profile/train in parallel —
//! while every concurrent requester of the *same* key blocks on the
//! flight instead of duplicating the work. A burst of N identical
//! workloads therefore costs exactly one host fit: one model-cache miss,
//! N−1 hits (of which the overlapping ones are also counted as
//! `singleflight_waits`). A failed build publishes its error to the
//! waiters (re-running a deterministic build would fail identically),
//! is removed from the map so a *later* request retries fresh, and a
//! *panicking* build is converted into a failed flight by a drop guard
//! so waiters never hang on a slot nobody owns.
//!
//! **Lock-free snapshot reads**: alongside the mutex-guarded maps the
//! cache maintains a [`ServeSnapshot`] — an immutable copy of the Ready
//! portion of all three maps, published through an atomically swapped
//! `Arc` ([`crate::util::arc_cell::ArcCell`]) after every mutation
//! (leader insert, [`PlaneCache::publish_models`],
//! [`PlaneCache::invalidate_planes`]). A cache-hit request resolves grid
//! → models → plane against [`PlaneCache::read_snapshot`] without
//! touching a single mutex, so hit throughput scales linearly with
//! reader threads even while fits are in flight; any snapshot miss falls
//! back to the singleflight slow path above, unchanged. Snapshots cannot
//! tear: planes are keyed by the checkpoint fingerprints of whichever
//! model pair a request resolved, so the plane a fast-path hit serves
//! was predicted by exactly that pair — a reader racing a republication
//! sees the old or the new (models, plane) pairing, never a mixture.
//!
//! A cache-hit request therefore costs one fingerprint pass, three hash
//! lookups and one `partition_point` binary search over the cached front —
//! O(log front) instead of O(grid × params), with zero lock traffic.
//! [`Metrics`] counts hits, misses and coalesced waits so degraded cache
//! behaviour is visible in the serve report.

use std::cell::Cell;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use crate::coordinator::{Metrics, Request, Strategy};
use crate::device::{DeviceKind, FeatureMatrix, PowerModeGrid};
use crate::error::{Error, Result};
use crate::nn::checkpoint::Checkpoint;
use crate::pareto::ParetoFront;
use crate::util::arc_cell::ArcCell;
use crate::util::sync::{lock_unpoisoned, wait_unpoisoned};
use crate::workload::Workload;

/// Bound on resident planes/grids/models. Fleets have a handful of device
/// kinds and model pairs; the caps only guard pathological request
/// streams (e.g. a distinct grid seed per request on seed-dependent
/// grids, or a distinct workload/seed per request on the model cache).
const MAX_GRIDS: usize = 64;
const MAX_PLANES: usize = 64;
const MAX_MODELS: usize = 64;

/// Bound on tracked circuit breakers. When full, healthy (`Closed` with
/// zero failures) entries are dropped; tripped breakers keep their state.
const MAX_BREAKERS: usize = 256;

/// Identity of the grid a request's predictions are computed over.
///
/// `grid_seed` is canonicalized to 0 for seed-independent grids (the
/// Orin paper subset) so every request shares one entry; seed-dependent
/// grids (random subsets) key on the seed they were drawn with, which
/// keeps caching *sound* — two requests share an entry only when they
/// resolve to the identical mode list.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct GridKey {
    pub device: DeviceKind,
    pub override_n: Option<usize>,
    pub grid_seed: u64,
}

impl GridKey {
    /// Key for the grid `prediction_grid(device, override_n, seed)`
    /// resolves to. Seed-(in)dependence is owned by
    /// [`prediction_grid_is_seed_independent`](crate::coordinator::prediction_grid_is_seed_independent)
    /// — `prediction_grid` dispatches through the same predicate, so the
    /// canonicalization cannot drift from the grid construction.
    pub fn for_request(device: DeviceKind, override_n: Option<usize>, seed: u64) -> GridKey {
        let canonical =
            crate::coordinator::prediction_grid_is_seed_independent(device, override_n);
        GridKey {
            device,
            override_n,
            grid_seed: if canonical { 0 } else { seed },
        }
    }
}

/// Identity of a full serve plane: the grid plus the two models that
/// predicted over it. Checkpoint fingerprints are content hashes, so
/// retrained/transferred reference models move the key and can never
/// serve stale planes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct PlaneKey {
    pub grid: GridKey,
    pub time_fp: u64,
    pub power_fp: u64,
}

/// Identity of a per-workload host-trained model pair: every input that
/// determines the profiling corpus (the grid it was sampled from, the
/// workload simulated, the request seed driving sampling + telemetry)
/// and the fit (strategy, epochs, and — for transfer — the reference
/// models fine-tuned from, by content fingerprint). Host training is
/// deterministic in all of these, so equal keys provably yield
/// bit-identical checkpoints and caching is sound.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ModelKey {
    pub grid: GridKey,
    pub workload: Workload,
    /// Request seed (drives mode sampling and simulated telemetry).
    pub seed: u64,
    pub strategy: Strategy,
    /// Fine-tuning / training epochs (`CoordinatorConfig::transfer_epochs`).
    pub epochs: usize,
    /// Reference checkpoint fingerprints the transfer starts from (also
    /// kept in the key for scratch strategies: harmless, and it keeps
    /// entries from outliving a reference-model swap).
    pub ref_time_fp: u64,
    pub ref_power_fp: u64,
}

impl ModelKey {
    /// The cache identity of the model pair serving `req` — the single
    /// derivation shared by the pipeline's model-acquisition stage and
    /// the lifecycle's feedback lane, so an observed outcome can never be
    /// attributed to a different entry than the one that served the
    /// request. `prediction_grid` / `epochs` come from the coordinator
    /// config; `ref_fps` are the reference checkpoints' content
    /// fingerprints.
    pub fn for_request(
        req: &Request,
        strategy: Strategy,
        prediction_grid: Option<usize>,
        epochs: usize,
        ref_fps: (u64, u64),
    ) -> ModelKey {
        ModelKey {
            grid: GridKey::for_request(req.device, prediction_grid, req.seed),
            workload: req.workload,
            seed: req.seed,
            strategy,
            epochs,
            ref_time_fp: ref_fps.0,
            ref_power_fp: ref_fps.1,
        }
    }

    /// The coordinator domain this key belongs to when the fleet runs
    /// `shards` independent domains. Hash-partitioning on the full key
    /// keeps singleflight and drift state strictly shard-local: two
    /// requests that would coalesce land on the same shard, and two
    /// that would not can never contend. `DefaultHasher` uses fixed
    /// SipHash keys, so the partition is stable within a build — which
    /// is all the fleet determinism tests require.
    pub fn shard_index(&self, shards: usize) -> usize {
        use std::hash::{Hash, Hasher};
        let mut hasher = std::collections::hash_map::DefaultHasher::new();
        self.hash(&mut hasher);
        (hasher.finish() % shards.max(1) as u64) as usize
    }
}

/// A host-trained (time, power) checkpoint pair plus the bookkeeping the
/// serve path reports: the checkpoints' content fingerprints (the plane
/// key halves), what the one-time profiling cost to build them was, the
/// fit-time validation MAPEs (the drift monitor's baseline) and the
/// publication version (1 = first fit; warm refits bump it via
/// [`PlaneCache::publish_models`]).
#[derive(Debug, Clone)]
pub struct HostModels {
    pub time: Checkpoint,
    pub power: Checkpoint,
    pub time_fp: u64,
    pub power_fp: u64,
    /// Simulated device-seconds of online profiling this fit consumed.
    pub profiling_cost_s: f64,
    /// Fit-time validation MAPE (%) per target at the best epoch — the
    /// accuracy this pair shipped with, and the baseline serving-time
    /// drift is measured against. `NaN` when unknown (the lifecycle then
    /// falls back to its absolute floor threshold).
    pub val_mape_time_pct: f64,
    pub val_mape_power_pct: f64,
    /// Monotonic publication version within a Ready slot's lifetime:
    /// fresh builds carry 1, each [`PlaneCache::publish_models`] stamps
    /// `previous + 1`. (Eviction forgets history — the lifecycle's
    /// per-model tracker owns cross-eviction monotonicity.)
    pub version: u64,
}

impl HostModels {
    pub fn new(time: Checkpoint, power: Checkpoint, profiling_cost_s: f64) -> HostModels {
        let (time_fp, power_fp) = (time.fingerprint(), power.fingerprint());
        HostModels {
            time,
            power,
            time_fp,
            power_fp,
            profiling_cost_s,
            val_mape_time_pct: f64::NAN,
            val_mape_power_pct: f64::NAN,
            version: 1,
        }
    }

    /// Attach the fit-time validation MAPEs (%) — the drift baseline.
    pub fn with_validation(mut self, time_pct: f64, power_pct: f64) -> HostModels {
        self.val_mape_time_pct = time_pct;
        self.val_mape_power_pct = power_pct;
        self
    }

    /// The worse of the pair's fit-time validation MAPEs, NaN-tolerant
    /// (`NaN` only when *both* are unknown): a recommendation is wrong if
    /// either model is wrong, so drift thresholds key off the weaker fit.
    pub fn baseline_mape_pct(&self) -> f64 {
        self.val_mape_time_pct.max(self.val_mape_power_pct)
    }

    /// Recompute both checkpoints' content fingerprints and compare with
    /// the stored ones. The serve path runs this before caching a freshly
    /// built pair whenever corruption is suspected: a mismatched
    /// fingerprint means the checkpoint bytes changed between fit and
    /// publish (bit-rot, a torn write), and serving it would attribute
    /// predictions to the wrong model identity.
    pub fn verify_integrity(&self) -> Result<()> {
        let (time_fp, power_fp) = (self.time.fingerprint(), self.power.fingerprint());
        if time_fp != self.time_fp || power_fp != self.power_fp {
            return Err(Error::Artifact(format!(
                "checkpoint fingerprint mismatch after fit: time {time_fp:#x} vs stored {:#x}, \
                 power {power_fp:#x} vs stored {:#x}",
                self.time_fp, self.power_fp
            )));
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------
// per-ModelKey circuit breaker

/// Circuit-breaker thresholds for the model-build path.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BreakerConfig {
    /// Consecutive failed *leader* builds that open the circuit.
    pub failure_threshold: u32,
    /// Acquisitions an `Open` breaker rejects before letting the next one
    /// through as a Half-Open probe. The cooldown is counted in rejected
    /// attempts, not wall time: the queue clock is wall-clock and thus
    /// nondeterministic, and chaos runs must replay bit-identically.
    pub cooldown_rejections: u32,
}

impl Default for BreakerConfig {
    fn default() -> Self {
        BreakerConfig { failure_threshold: 3, cooldown_rejections: 8 }
    }
}

/// Public view of a breaker's coarse state.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BreakerState {
    Closed,
    Open,
    HalfOpen,
}

/// Internal breaker state machine. `Closed` admits and counts consecutive
/// leader failures; `Open` rejects while counting down its cooldown;
/// `HalfOpen` means one probe build is in flight and everyone else is
/// rejected until it resolves.
#[derive(Debug, Clone, Copy)]
enum Breaker {
    Closed { failures: u32 },
    Open { rejected: u32 },
    HalfOpen,
}

/// Device-level grid state shared across model pairs: the mode list and
/// its SoA feature matrix, built once.
#[derive(Debug, Clone)]
pub struct GridEntry {
    pub grid: PowerModeGrid,
    pub features: FeatureMatrix,
}

impl GridEntry {
    pub fn new(grid: PowerModeGrid) -> GridEntry {
        let features = grid.feature_matrix();
        GridEntry { grid, features }
    }
}

/// Everything needed to answer budget queries over one (grid, model-pair):
/// the raw-unit prediction planes and the Pareto front over them.
///
/// The budget path reads only `front`; the full planes are retained
/// (bounded: ≤ 2 × grid × 8 bytes × `MAX_PLANES`) so plane-level
/// consumers — per-mode diagnostics, Fig-10-style exports, future
/// non-budget queries — answer from cache instead of re-predicting.
#[derive(Debug, Clone)]
pub struct ServePlane {
    pub grid: Arc<GridEntry>,
    /// Predicted training time per mode (ms), parallel to `grid.grid.modes`.
    pub times: Vec<f64>,
    /// Predicted power per mode (mW), parallel to `grid.grid.modes`.
    pub powers: Vec<f64>,
    pub front: ParetoFront,
}

/// An immutable view of the Ready portion of the cache's three maps,
/// rebuilt and atomically republished after every mutation. Readers get
/// it via [`PlaneCache::read_snapshot`] (lock-free) and resolve cache
/// hits against it without contending with writers; anything absent here
/// (a miss, an in-flight build, an entry newer than the snapshot) falls
/// back to the mutex-guarded singleflight path.
///
/// The snapshot may lag the maps by one publication (a reader can race a
/// republish) and may retain an entry the maps already evicted for
/// capacity until the next republish — both are benign: entries are
/// deterministic in their keys, so a stale hit serves exactly the bytes
/// a rebuild would, and planes are keyed by model-pair fingerprints so a
/// (models, plane) resolution can never mix generations.
#[derive(Debug, Default)]
pub struct ServeSnapshot {
    grids: HashMap<GridKey, Arc<GridEntry>>,
    models: HashMap<ModelKey, Arc<HostModels>>,
    planes: HashMap<PlaneKey, Arc<ServePlane>>,
}

impl ServeSnapshot {
    /// Resident grid entry for `key`, if the snapshot has one.
    pub fn grid(&self, key: &GridKey) -> Option<&Arc<GridEntry>> {
        self.grids.get(key)
    }

    /// Resident model pair for `key`, if the snapshot has one.
    pub fn models(&self, key: &ModelKey) -> Option<&Arc<HostModels>> {
        self.models.get(key)
    }

    /// Resident serve plane for `key`, if the snapshot has one.
    pub fn plane(&self, key: &PlaneKey) -> Option<&Arc<ServePlane>> {
        self.planes.get(key)
    }

    /// (grids, planes, model pairs) resident in this snapshot.
    pub fn sizes(&self) -> (usize, usize, usize) {
        (self.grids.len(), self.planes.len(), self.models.len())
    }
}

// ---------------------------------------------------------------------
// singleflight machinery

/// One in-flight build. The leader publishes exactly once; waiters block
/// on `cv` until then.
#[derive(Debug)]
struct Flight<V> {
    done: Mutex<Option<FlightResult<V>>>,
    cv: Condvar,
}

#[derive(Debug)]
enum FlightResult<V> {
    Ready(Arc<V>),
    /// The leader's build failed (or panicked). Waiters surface this
    /// message instead of hanging — or re-running a deterministic build
    /// that would fail identically.
    Failed(String),
}

impl<V> Clone for FlightResult<V> {
    fn clone(&self) -> Self {
        match self {
            FlightResult::Ready(v) => FlightResult::Ready(Arc::clone(v)),
            FlightResult::Failed(m) => FlightResult::Failed(m.clone()),
        }
    }
}

impl<V> Flight<V> {
    fn new() -> Arc<Flight<V>> {
        Arc::new(Flight { done: Mutex::new(None), cv: Condvar::new() })
    }

    fn publish(&self, result: FlightResult<V>) {
        *lock_unpoisoned(&self.done) = Some(result);
        self.cv.notify_all();
    }

    fn wait(&self) -> FlightResult<V> {
        let mut done = lock_unpoisoned(&self.done);
        loop {
            if let Some(r) = done.as_ref() {
                return r.clone();
            }
            done = wait_unpoisoned(&self.cv, done);
        }
    }
}

/// A map slot: the built value, or the flight concurrent requesters of
/// the same key coalesce onto.
#[derive(Debug)]
enum Slot<V> {
    Ready(Arc<V>),
    InFlight(Arc<Flight<V>>),
}

/// What the map lookup found for this requester.
enum Found<V> {
    Hit(Arc<V>),
    Wait(Arc<Flight<V>>),
    Lead(Arc<Flight<V>>),
}

/// Hit/miss/coalesce counters for one cache map.
struct CacheCounters<'a> {
    hits: &'a AtomicU64,
    misses: &'a AtomicU64,
    waits: &'a AtomicU64,
}

/// Removes the leader's `InFlight` slot and fails the flight if the build
/// panicked — waiters get an error instead of blocking forever, and the
/// key is free for a later request to retry.
struct FlightGuard<'a, K: Copy + Eq + std::hash::Hash, V> {
    map: &'a Mutex<HashMap<K, Slot<V>>>,
    key: K,
    flight: &'a Arc<Flight<V>>,
    armed: bool,
}

impl<K: Copy + Eq + std::hash::Hash, V> Drop for FlightGuard<'_, K, V> {
    fn drop(&mut self) {
        if !self.armed {
            return;
        }
        lock_unpoisoned(self.map).remove(&self.key);
        self.flight
            .publish(FlightResult::Failed("builder panicked".into()));
    }
}

/// The singleflight get-or-build at the heart of every [`PlaneCache`]
/// map. Returns the resident value plus whether *this call* led the
/// build (callers report one-time costs only when they actually paid
/// them). `build` must be deterministic for the key.
fn get_or_build<K, V>(
    map: &Mutex<HashMap<K, Slot<V>>>,
    cap: usize,
    key: K,
    counters: Option<CacheCounters<'_>>,
    build: impl FnOnce() -> Result<V>,
) -> Result<(Arc<V>, bool)>
where
    K: Copy + Eq + std::hash::Hash,
{
    let found = {
        let mut m = lock_unpoisoned(map);
        let existing = match m.get(&key) {
            Some(Slot::Ready(v)) => Some(Found::Hit(Arc::clone(v))),
            Some(Slot::InFlight(f)) => Some(Found::Wait(Arc::clone(f))),
            None => None,
        };
        match existing {
            Some(f) => f,
            None => {
                // every map-growing path (here and `publish_models`'s
                // re-insert arm) enforces the bound before inserting
                evict_if_full(&mut m, cap);
                let f = Flight::new();
                m.insert(key, Slot::InFlight(Arc::clone(&f)));
                Found::Lead(f)
            }
        }
    };

    let flight = match found {
        Found::Hit(v) => {
            if let Some(c) = &counters {
                c.hits.fetch_add(1, Ordering::Relaxed);
            }
            return Ok((v, false));
        }
        Found::Wait(f) => {
            // the wait is counted up front (the coalescing happened);
            // the hit only once the flight actually delivers a value —
            // a waiter on a failed build served nothing from cache
            if let Some(c) = &counters {
                c.waits.fetch_add(1, Ordering::Relaxed);
            }
            return match f.wait() {
                FlightResult::Ready(v) => {
                    if let Some(c) = &counters {
                        c.hits.fetch_add(1, Ordering::Relaxed);
                    }
                    Ok((v, false))
                }
                FlightResult::Failed(msg) => Err(Error::Coordinator(format!(
                    "coalesced onto an in-flight build that failed: {msg}"
                ))),
            };
        }
        Found::Lead(f) => {
            if let Some(c) = &counters {
                c.misses.fetch_add(1, Ordering::Relaxed);
            }
            f
        }
    };

    // leader: build outside the map lock so misses on *different* keys
    // profile/train in parallel; the guard converts a panic into a
    // failed flight
    let mut guard = FlightGuard { map, key, flight: &flight, armed: true };
    let result = build();
    guard.armed = false;
    drop(guard);
    match result {
        Ok(v) => {
            let v = Arc::new(v);
            lock_unpoisoned(map).insert(key, Slot::Ready(Arc::clone(&v)));
            flight.publish(FlightResult::Ready(Arc::clone(&v)));
            Ok((v, true))
        }
        Err(e) => {
            // not cached: a *later* request retries the build fresh
            lock_unpoisoned(map).remove(&key);
            flight.publish(FlightResult::Failed(e.to_string()));
            Err(e)
        }
    }
}

/// Keep `map` bounded: if inserting a new key would exceed `cap`, drop
/// one resident `Ready` entry (arbitrary — the maps are small and churn
/// only on pathological streams, so LRU bookkeeping isn't worth its lock
/// time). In-flight slots are never evicted: their waiters are blocked
/// on them and their leaders are mid-build.
fn evict_if_full<K: Copy + Eq + std::hash::Hash, V>(map: &mut HashMap<K, Slot<V>>, cap: usize) {
    if map.len() >= cap {
        let victim = map.iter().find_map(|(k, slot)| match slot {
            Slot::Ready(_) => Some(*k),
            Slot::InFlight(_) => None,
        });
        if let Some(k) = victim {
            map.remove(&k);
        }
    }
}

/// The coordinator-level cache: grids shared across model pairs, planes
/// shared across requests, all acquired singleflight. Cheap to share
/// (`Arc`) across worker threads.
#[derive(Debug, Default)]
pub struct PlaneCache {
    grids: Mutex<HashMap<GridKey, Slot<GridEntry>>>,
    planes: Mutex<HashMap<PlaneKey, Slot<ServePlane>>>,
    models: Mutex<HashMap<ModelKey, Slot<HostModels>>>,
    /// Per-ModelKey circuit breakers guarding the (expensive) model-build
    /// path: a key whose builds keep failing is rejected up front instead
    /// of re-paying profiling + fit for a deterministic failure.
    breakers: Mutex<HashMap<ModelKey, Breaker>>,
    breaker_cfg: BreakerConfig,
    /// The lock-free read path: an atomically swapped immutable copy of
    /// the Ready portion of the maps above (see [`ServeSnapshot`]).
    snapshot: ArcCell<ServeSnapshot>,
    /// Serializes snapshot rebuilds so two concurrent mutators can't
    /// install snapshots out of order: each rebuild reads the maps after
    /// its trigger's insert, and publication order follows rebuild order.
    snapshot_gate: Mutex<()>,
}

/// Records a breaker failure if the guarded build panics: without this, a
/// panicking probe would wedge its key `HalfOpen` forever (every later
/// caller rejected with "probe in flight" and no probe alive).
struct BreakerPanicGuard<'a> {
    cache: &'a PlaneCache,
    key: ModelKey,
    metrics: &'a Metrics,
    led: &'a Cell<bool>,
    armed: bool,
}

impl Drop for BreakerPanicGuard<'_> {
    fn drop(&mut self) {
        if self.armed && self.led.get() {
            self.cache.note_build_outcome(self.key, false, true, self.metrics);
        }
    }
}

impl PlaneCache {
    pub fn new() -> PlaneCache {
        PlaneCache::default()
    }

    /// Cache with custom circuit-breaker thresholds (tests, chaos tuning).
    pub fn with_breaker(cfg: BreakerConfig) -> PlaneCache {
        PlaneCache { breaker_cfg: cfg, ..Default::default() }
    }

    /// Grid + feature matrix for `key`, building (outside the lock,
    /// singleflight) on miss. `build` must be deterministic for the key.
    pub fn grid(&self, key: GridKey, build: impl FnOnce() -> GridEntry) -> Arc<GridEntry> {
        match get_or_build(&self.grids, MAX_GRIDS, key, None, || Ok(build())) {
            Ok((g, led)) => {
                if led {
                    self.republish();
                }
                g
            }
            // only reachable when a coalesced leader panicked mid-build;
            // propagate that as a panic here too (workers catch it)
            Err(e) => panic!("grid build failed: {e}"),
        }
    }

    /// Serve plane for `key`, building (outside the lock, singleflight)
    /// on miss and recording the hit/miss/wait in `metrics`.
    pub fn plane(
        &self,
        key: PlaneKey,
        metrics: &Metrics,
        build: impl FnOnce() -> ServePlane,
    ) -> Arc<ServePlane> {
        let counters = CacheCounters {
            hits: &metrics.plane_cache_hits,
            misses: &metrics.plane_cache_misses,
            waits: &metrics.singleflight_waits,
        };
        match get_or_build(&self.planes, MAX_PLANES, key, Some(counters), || Ok(build())) {
            Ok((p, led)) => {
                if led {
                    self.republish();
                }
                p
            }
            // only reachable when a coalesced leader panicked mid-build;
            // propagate that as a panic here too (workers catch it)
            Err(e) => panic!("plane build failed: {e}"),
        }
    }

    /// Host-trained model pair for `key`, singleflight: the first
    /// requester builds (outside the lock, so concurrent misses on
    /// *different* keys profile/train in parallel) while concurrent
    /// requesters of the same key block on the in-flight fit instead of
    /// duplicating it. Returns the resident entry plus whether *this
    /// call* paid the build — callers report profiling cost only when
    /// they actually profiled. A fallible build is not cached: the
    /// leader's error propagates as-is, waiters receive it re-wrapped as
    /// `Error::Coordinator` carrying the leader's rendered message
    /// (`Error` isn't `Clone`, so the variant cannot cross the flight;
    /// classify coalesced failures by message, not variant), and the
    /// next request retries fresh.
    /// Acquisition is additionally guarded by `key`'s circuit breaker:
    /// after [`BreakerConfig::failure_threshold`] consecutive failed
    /// leader builds the breaker opens and requests are rejected with
    /// [`Error::CircuitOpen`] *before* touching the flight machinery;
    /// after [`BreakerConfig::cooldown_rejections`] rejections one caller
    /// is let through as a Half-Open probe whose outcome closes or
    /// re-opens the circuit. Only leader failures count — a waiter
    /// surfacing its leader's failure is the same event, and counting it
    /// twice would open the breaker early.
    pub fn models(
        &self,
        key: ModelKey,
        metrics: &Metrics,
        build: impl FnOnce() -> Result<HostModels>,
    ) -> Result<(Arc<HostModels>, bool)> {
        if let Some(rejection) = self.breaker_admit(key, metrics) {
            return Err(rejection);
        }
        let counters = CacheCounters {
            hits: &metrics.model_cache_hits,
            misses: &metrics.model_cache_misses,
            waits: &metrics.singleflight_waits,
        };
        let led = Cell::new(false);
        let mut panic_guard =
            BreakerPanicGuard { cache: self, key, metrics, led: &led, armed: true };
        let result = get_or_build(&self.models, MAX_MODELS, key, Some(counters), || {
            led.set(true);
            build()
        });
        panic_guard.armed = false;
        drop(panic_guard);
        self.note_build_outcome(key, result.is_ok(), led.get(), metrics);
        if let Ok((_, true)) = &result {
            self.republish();
        }
        result
    }

    /// Consult `key`'s breaker before touching the model map. `Some(err)`
    /// = rejected without attempting the build; `None` = admitted (and,
    /// for a cooled-down `Open` breaker, this caller just became the
    /// Half-Open probe).
    fn breaker_admit(&self, key: ModelKey, metrics: &Metrics) -> Option<Error> {
        let mut breakers = lock_unpoisoned(&self.breakers);
        if !breakers.contains_key(&key) && breakers.len() >= MAX_BREAKERS {
            breakers.retain(|_, b| !matches!(b, Breaker::Closed { failures: 0 }));
        }
        let state = breakers.entry(key).or_insert(Breaker::Closed { failures: 0 });
        match state {
            Breaker::Closed { .. } => None,
            Breaker::HalfOpen => Some(Error::CircuitOpen(format!(
                "model build for workload '{}' (seed {}) is half-open with a probe in flight",
                key.workload.name(),
                key.seed
            ))),
            Breaker::Open { rejected } => {
                if *rejected >= self.breaker_cfg.cooldown_rejections {
                    *state = Breaker::HalfOpen;
                    metrics.breaker_transitions.fetch_add(1, Ordering::Relaxed);
                    None
                } else {
                    *rejected += 1;
                    Some(Error::CircuitOpen(format!(
                        "model build for workload '{}' (seed {}) failed {} consecutive times; \
                         cooling down ({}/{} rejections)",
                        key.workload.name(),
                        key.seed,
                        self.breaker_cfg.failure_threshold,
                        rejected,
                        self.breaker_cfg.cooldown_rejections
                    )))
                }
            }
        }
    }

    /// Fold one acquisition outcome into `key`'s breaker. `led` is
    /// whether this caller actually ran the build closure (leader) as
    /// opposed to hitting cache or coalescing onto another flight.
    fn note_build_outcome(&self, key: ModelKey, ok: bool, led: bool, metrics: &Metrics) {
        let mut breakers = lock_unpoisoned(&self.breakers);
        let Some(state) = breakers.get_mut(&key) else { return };
        if ok {
            match state {
                Breaker::Closed { failures: 0 } => {}
                Breaker::Closed { failures } => *failures = 0,
                // a successful probe — or a hit against a pair the
                // lifecycle published while the circuit was tripped —
                // proves the key healthy again
                Breaker::HalfOpen | Breaker::Open { .. } => {
                    *state = Breaker::Closed { failures: 0 };
                    metrics.breaker_transitions.fetch_add(1, Ordering::Relaxed);
                }
            }
        } else if led {
            match state {
                Breaker::Closed { failures } => {
                    *failures += 1;
                    if *failures >= self.breaker_cfg.failure_threshold {
                        *state = Breaker::Open { rejected: 0 };
                        metrics.breaker_transitions.fetch_add(1, Ordering::Relaxed);
                    }
                }
                Breaker::HalfOpen => {
                    *state = Breaker::Open { rejected: 0 };
                    metrics.breaker_transitions.fetch_add(1, Ordering::Relaxed);
                }
                Breaker::Open { .. } => {}
            }
        }
        // waiter failures (!led) don't count: the leader's failure
        // already did
    }

    /// Coarse state of `key`'s breaker, `None` if never consulted.
    pub fn breaker_state(&self, key: &ModelKey) -> Option<BreakerState> {
        lock_unpoisoned(&self.breakers).get(key).map(|b| match b {
            Breaker::Closed { .. } => BreakerState::Closed,
            Breaker::Open { .. } => BreakerState::Open,
            Breaker::HalfOpen => BreakerState::HalfOpen,
        })
    }

    /// Every key whose breaker is currently tripped (Open or Half-Open).
    pub fn open_breakers(&self) -> Vec<ModelKey> {
        lock_unpoisoned(&self.breakers)
            .iter()
            .filter_map(|(k, b)| match b {
                Breaker::Open { .. } | Breaker::HalfOpen => Some(*k),
                Breaker::Closed { .. } => None,
            })
            .collect()
    }

    /// Resident model pair for `key` **without** building or waiting:
    /// `None` when the key is absent or its build is still in flight.
    /// The lifecycle's feedback lane reads through this — an observation
    /// must never trigger (or block on) a fit.
    pub fn peek_models(&self, key: &ModelKey) -> Option<Arc<HostModels>> {
        match lock_unpoisoned(&self.models).get(key) {
            Some(Slot::Ready(v)) => Some(Arc::clone(v)),
            _ => None,
        }
    }

    /// Atomically publish a refreshed model pair (a warm refit) for
    /// `key`. The Ready slot is replaced under the map lock in one
    /// operation and the new entry is stamped with `previous version + 1`
    /// (1 if the slot was empty), so the slot's version sequence is
    /// monotonic and a concurrent request observes either the old pair or
    /// the new pair — never a torn state. (Planes are keyed by the pair's
    /// checkpoint fingerprints: whichever pair a request resolved, the
    /// plane it then resolves was predicted by exactly that pair.)
    ///
    /// Returns the resident entry, or `None` when the slot is currently
    /// `InFlight`: a fresh build owns the key, its waiters are parked on
    /// the flight, and clobbering the slot would orphan them — the
    /// caller treats the refit as superseded and may retry later.
    pub fn publish_models(&self, key: ModelKey, mut models: HostModels) -> Option<Arc<HostModels>> {
        let arc = {
            let mut m = lock_unpoisoned(&self.models);
            match m.get(&key) {
                Some(Slot::InFlight(_)) => return None,
                Some(Slot::Ready(prev)) => models.version = prev.version + 1,
                None => {
                    // evicted mid-refit: the publish re-inserts a fresh key,
                    // so it must honor the same bound as get_or_build
                    evict_if_full(&mut m, MAX_MODELS);
                    models.version = 1;
                }
            }
            let arc = Arc::new(models);
            m.insert(key, Slot::Ready(Arc::clone(&arc)));
            arc
        };
        // outside the map lock: the rebuild re-locks the maps itself
        self.republish();
        Some(arc)
    }

    /// Drop every resident plane predicted by the checkpoint pair
    /// `(time_fp, power_fp)` — the invalidation a model republish
    /// performs so superseded planes free their memory immediately
    /// instead of lingering until eviction. In-flight plane builds are
    /// left alone: each was keyed by whichever model pair its request
    /// resolved, so it stays self-consistent. Returns how many planes
    /// were dropped.
    pub fn invalidate_planes(&self, time_fp: u64, power_fp: u64) -> usize {
        let dropped = {
            let mut m = lock_unpoisoned(&self.planes);
            let victims: Vec<PlaneKey> = m
                .iter()
                .filter_map(|(k, slot)| match slot {
                    Slot::Ready(_) if k.time_fp == time_fp && k.power_fp == power_fp => Some(*k),
                    _ => None,
                })
                .collect();
            for k in &victims {
                m.remove(k);
            }
            victims.len()
        };
        if dropped > 0 {
            self.republish();
        }
        dropped
    }

    /// The current [`ServeSnapshot`], without taking any lock: four
    /// atomic operations, wait-free unless racing a concurrent
    /// republication. This is the serve pipeline's fast path — a warm
    /// request resolves grid → models → plane against the returned
    /// snapshot and never contends with in-flight builds or refits.
    pub fn read_snapshot(&self) -> Arc<ServeSnapshot> {
        self.snapshot.load()
    }

    /// Rebuild the immutable snapshot from the Ready slots of all three
    /// maps and atomically publish it. Called by every successful mutator
    /// (leader insert, model publish, plane invalidation) *after* its map
    /// insert; rebuilds are serialized by `snapshot_gate` so publication
    /// order follows rebuild order, and each map is locked briefly, one
    /// at a time — a rebuild never holds two locks and never blocks the
    /// lock-free readers.
    fn republish(&self) {
        let _gate = lock_unpoisoned(&self.snapshot_gate);
        let grids: HashMap<GridKey, Arc<GridEntry>> = lock_unpoisoned(&self.grids)
            .iter()
            .filter_map(|(k, slot)| match slot {
                Slot::Ready(v) => Some((*k, Arc::clone(v))),
                Slot::InFlight(_) => None,
            })
            .collect();
        let models: HashMap<ModelKey, Arc<HostModels>> = lock_unpoisoned(&self.models)
            .iter()
            .filter_map(|(k, slot)| match slot {
                Slot::Ready(v) => Some((*k, Arc::clone(v))),
                Slot::InFlight(_) => None,
            })
            .collect();
        let planes: HashMap<PlaneKey, Arc<ServePlane>> = lock_unpoisoned(&self.planes)
            .iter()
            .filter_map(|(k, slot)| match slot {
                Slot::Ready(v) => Some((*k, Arc::clone(v))),
                Slot::InFlight(_) => None,
            })
            .collect();
        self.snapshot.store(Arc::new(ServeSnapshot { grids, models, planes }));
    }

    /// (resident grids, resident planes, resident model pairs) — for
    /// reporting/tests.
    pub fn sizes(&self) -> (usize, usize, usize) {
        (
            lock_unpoisoned(&self.grids).len(),
            lock_unpoisoned(&self.planes).len(),
            lock_unpoisoned(&self.models).len(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
    use std::time::Duration;

    fn entry(n: usize) -> GridEntry {
        let full = PowerModeGrid::paper_subset(DeviceKind::OrinAgx);
        GridEntry::new(PowerModeGrid {
            kind: DeviceKind::OrinAgx,
            modes: full.modes[..n].to_vec(),
        })
    }

    fn plane_over(grid: Arc<GridEntry>) -> ServePlane {
        let n = grid.grid.len();
        let times: Vec<f64> = (0..n).map(|i| 1000.0 - i as f64).collect();
        let powers: Vec<f64> = (0..n).map(|i| 10_000.0 + 10.0 * i as f64).collect();
        let points: Vec<crate::pareto::Point> = grid
            .grid
            .modes
            .iter()
            .zip(times.iter().zip(&powers))
            .map(|(m, (&t, &p))| crate::pareto::Point { mode: *m, time: t, power_mw: p })
            .collect();
        let front = ParetoFront::build(&points);
        ServePlane { grid, times, powers, front }
    }

    #[test]
    fn grid_key_canonicalizes_seed_independent_grids() {
        let a = GridKey::for_request(DeviceKind::OrinAgx, None, 7);
        let b = GridKey::for_request(DeviceKind::OrinAgx, None, 99);
        assert_eq!(a, b);
        // seed-dependent grids must NOT be conflated across seeds
        let c = GridKey::for_request(DeviceKind::XavierAgx, None, 7);
        let d = GridKey::for_request(DeviceKind::XavierAgx, None, 99);
        assert_ne!(c, d);
        let e = GridKey::for_request(DeviceKind::OrinAgx, Some(200), 7);
        let f = GridKey::for_request(DeviceKind::OrinAgx, Some(200), 99);
        assert_ne!(e, f);
    }

    #[test]
    fn plane_hits_share_the_arc_and_count() {
        let cache = PlaneCache::new();
        let metrics = Metrics::new();
        let gkey = GridKey::for_request(DeviceKind::OrinAgx, None, 1);
        let key = PlaneKey { grid: gkey, time_fp: 1, power_fp: 2 };
        let g = cache.grid(gkey, || entry(50));
        let p1 = cache.plane(key, &metrics, || plane_over(Arc::clone(&g)));
        let p2 = cache.plane(key, &metrics, || panic!("must not rebuild on hit"));
        assert!(Arc::ptr_eq(&p1, &p2));
        assert_eq!(metrics.plane_cache_misses.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.plane_cache_hits.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn grid_entry_is_shared_across_model_pairs() {
        let cache = PlaneCache::new();
        let metrics = Metrics::new();
        let gkey = GridKey::for_request(DeviceKind::OrinAgx, None, 1);
        let k1 = PlaneKey { grid: gkey, time_fp: 1, power_fp: 2 };
        let k2 = PlaneKey { grid: gkey, time_fp: 3, power_fp: 4 };
        let p1 = cache.plane(k1, &metrics, || {
            plane_over(cache.grid(gkey, || entry(40)))
        });
        let p2 = cache.plane(k2, &metrics, || {
            plane_over(cache.grid(gkey, || panic!("grid must be resident")))
        });
        assert!(Arc::ptr_eq(&p1.grid, &p2.grid));
        assert_eq!(cache.sizes(), (1, 2, 0));
    }

    #[test]
    fn caches_stay_bounded() {
        let cache = PlaneCache::new();
        let metrics = Metrics::new();
        for seed in 0..(MAX_PLANES as u64 + 40) {
            let gkey = GridKey::for_request(DeviceKind::XavierAgx, Some(10), seed);
            let key = PlaneKey { grid: gkey, time_fp: seed, power_fp: seed };
            let g = cache.grid(gkey, || entry(10));
            cache.plane(key, &metrics, || plane_over(g));
        }
        let (grids, planes, _) = cache.sizes();
        assert!(grids <= MAX_GRIDS, "{grids} grids resident");
        assert!(planes <= MAX_PLANES, "{planes} planes resident");
        assert_eq!(
            metrics.plane_cache_misses.load(Ordering::Relaxed),
            MAX_PLANES as u64 + 40
        );
    }

    fn demo_models(tag: f32) -> HostModels {
        use crate::nn::MlpParams;
        use crate::profiler::StandardScaler;
        let ck = |target: &str| {
            let mut params = MlpParams::zeros();
            params.leaves[0][0] = tag;
            Checkpoint {
                params,
                feature_scaler: StandardScaler {
                    mean: vec![0.0; 4],
                    std: vec![1.0; 4],
                },
                target_scaler: StandardScaler { mean: vec![0.0], std: vec![1.0] },
                target: target.into(),
                provenance: "cache-test".into(),
                val_loss: 0.0,
            }
        };
        HostModels::new(ck("time"), ck("power"), 120.0)
    }

    fn model_key(seed: u64) -> ModelKey {
        ModelKey {
            grid: GridKey::for_request(DeviceKind::OrinAgx, Some(50), seed),
            workload: Workload::mobilenet(),
            seed,
            strategy: Strategy::PowerTrain(50),
            epochs: 100,
            ref_time_fp: 1,
            ref_power_fp: 2,
        }
    }

    #[test]
    fn shard_index_is_stable_and_spreads_keys() {
        let key = model_key(5);
        assert_eq!(key.shard_index(4), key.shard_index(4), "partition must be stable");
        assert_eq!(key.shard_index(0), 0, "degenerate shard count clamps to one domain");
        assert_eq!(key.shard_index(1), 0);
        // distinct seeds must not all collapse onto one domain
        let shards: std::collections::HashSet<usize> =
            (0..32).map(|s| model_key(s).shard_index(4)).collect();
        assert!(shards.len() > 1, "32 keys all landed on one of 4 shards");
        for s in shards {
            assert!(s < 4);
        }
    }

    #[test]
    fn model_hits_share_the_arc_count_and_report_no_build() {
        let cache = PlaneCache::new();
        let metrics = Metrics::new();
        let key = model_key(5);
        let (m1, built1) = cache.models(key, &metrics, || Ok(demo_models(1.0))).unwrap();
        let (m2, built2) = cache
            .models(key, &metrics, || panic!("must not rebuild on hit"))
            .unwrap();
        assert!(built1 && !built2);
        assert!(Arc::ptr_eq(&m1, &m2));
        assert_eq!(metrics.model_cache_misses.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.model_cache_hits.load(Ordering::Relaxed), 1);
        assert_eq!(cache.sizes(), (0, 0, 1));
    }

    #[test]
    fn failed_model_builds_are_not_cached() {
        let cache = PlaneCache::new();
        let metrics = Metrics::new();
        let key = model_key(6);
        let err = cache.models(key, &metrics, || {
            Err(crate::error::Error::Training("simulated divergence".into()))
        });
        assert!(err.is_err());
        assert_eq!(cache.sizes(), (0, 0, 0));
        // the next request retries the build instead of serving the error
        let (_, built) = cache.models(key, &metrics, || Ok(demo_models(2.0))).unwrap();
        assert!(built);
        assert_eq!(metrics.model_cache_misses.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn model_cache_stays_bounded() {
        let cache = PlaneCache::new();
        let metrics = Metrics::new();
        for seed in 0..(MAX_MODELS as u64 + 10) {
            cache
                .models(model_key(seed), &metrics, || Ok(demo_models(seed as f32)))
                .unwrap();
        }
        let (_, _, models) = cache.sizes();
        assert!(models <= MAX_MODELS, "{models} model pairs resident");
    }

    #[test]
    fn peek_never_builds_and_sees_only_ready_slots() {
        let cache = PlaneCache::new();
        let metrics = Metrics::new();
        let key = model_key(20);
        assert!(cache.peek_models(&key).is_none());
        let (built, _) = cache.models(key, &metrics, || Ok(demo_models(1.0))).unwrap();
        let peeked = cache.peek_models(&key).expect("ready slot is peekable");
        assert!(Arc::ptr_eq(&built, &peeked));
        // peeking is not a hit/miss event
        assert_eq!(metrics.model_cache_hits.load(Ordering::Relaxed), 0);
        assert_eq!(metrics.model_cache_misses.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn publish_stamps_monotonic_versions() {
        let cache = PlaneCache::new();
        let metrics = Metrics::new();
        let key = model_key(21);
        let (v1, _) = cache.models(key, &metrics, || Ok(demo_models(1.0))).unwrap();
        assert_eq!(v1.version, 1, "fresh builds are version 1");
        let v2 = cache.publish_models(key, demo_models(2.0)).unwrap();
        assert_eq!(v2.version, 2);
        let v3 = cache.publish_models(key, demo_models(3.0)).unwrap();
        assert_eq!(v3.version, 3);
        // the published pair is what later requests resolve, with no build
        let (resident, built) = cache
            .models(key, &metrics, || panic!("published slot must hit"))
            .unwrap();
        assert!(!built);
        assert!(Arc::ptr_eq(&resident, &v3));
        // publishing into an empty slot restarts the slot's sequence at 1
        let other = model_key(22);
        assert_eq!(cache.publish_models(other, demo_models(4.0)).unwrap().version, 1);
    }

    #[test]
    fn publish_never_clobbers_an_inflight_build() {
        let cache = PlaneCache::new();
        let metrics = Metrics::new();
        let key = model_key(23);
        let in_build = AtomicBool::new(false);
        std::thread::scope(|s| {
            let leader = s.spawn(|| {
                cache.models(key, &metrics, || {
                    in_build.store(true, Ordering::SeqCst);
                    std::thread::sleep(Duration::from_millis(120));
                    Ok(demo_models(5.0))
                })
            });
            while !in_build.load(Ordering::SeqCst) {
                std::thread::yield_now();
            }
            // a refit landing mid-build is refused: the flight's waiters
            // must receive the leader's publication, not be orphaned
            assert!(cache.publish_models(key, demo_models(6.0)).is_none());
            let (m, built) = leader.join().unwrap().unwrap();
            assert!(built);
            assert_eq!(m.version, 1, "the leader's build is the resident entry");
            assert!(Arc::ptr_eq(&cache.peek_models(&key).unwrap(), &m));
        });
    }

    #[test]
    fn invalidate_planes_drops_only_the_superseded_pair() {
        let cache = PlaneCache::new();
        let metrics = Metrics::new();
        let gkey = GridKey::for_request(DeviceKind::OrinAgx, None, 1);
        let g = cache.grid(gkey, || entry(30));
        let old = PlaneKey { grid: gkey, time_fp: 10, power_fp: 11 };
        let other = PlaneKey { grid: gkey, time_fp: 12, power_fp: 13 };
        cache.plane(old, &metrics, || plane_over(Arc::clone(&g)));
        cache.plane(other, &metrics, || plane_over(Arc::clone(&g)));
        assert_eq!(cache.invalidate_planes(10, 11), 1);
        let (_, planes, _) = cache.sizes();
        assert_eq!(planes, 1, "only the superseded pair's plane is dropped");
        // the surviving plane still hits
        cache.plane(other, &metrics, || panic!("must not rebuild"));
        // and the dropped key rebuilds on next touch
        cache.plane(old, &metrics, || plane_over(Arc::clone(&g)));
        assert_eq!(metrics.plane_cache_misses.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn concurrent_identical_keys_coalesce_to_exactly_one_build() {
        // the singleflight guarantee the coordinator's burst behaviour
        // rests on: N threads racing on one ModelKey perform ONE build
        let cache = PlaneCache::new();
        let metrics = Metrics::new();
        let key = model_key(9);
        let builds = AtomicUsize::new(0);
        const N: usize = 8;
        std::thread::scope(|s| {
            for _ in 0..N {
                s.spawn(|| {
                    let (m, _) = cache
                        .models(key, &metrics, || {
                            builds.fetch_add(1, Ordering::SeqCst);
                            // hold the flight open long enough that the
                            // other threads must coalesce, not rebuild
                            std::thread::sleep(Duration::from_millis(100));
                            Ok(demo_models(3.0))
                        })
                        .unwrap();
                    assert_eq!(m.profiling_cost_s, 120.0);
                });
            }
        });
        assert_eq!(builds.load(Ordering::SeqCst), 1, "burst must cost one build");
        assert_eq!(metrics.model_cache_misses.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.model_cache_hits.load(Ordering::Relaxed), N as u64 - 1);
        // waits ≤ hits: every waiter is a hit, late arrivals hit Ready
        assert!(metrics.singleflight_waits.load(Ordering::Relaxed) <= N as u64 - 1);
        assert_eq!(cache.sizes(), (0, 0, 1));
    }

    #[test]
    fn waiters_surface_leader_failure_without_rebuilding() {
        let cache = PlaneCache::new();
        let metrics = Metrics::new();
        let key = model_key(10);
        let in_build = AtomicBool::new(false);
        std::thread::scope(|s| {
            let leader = s.spawn(|| {
                cache.models(key, &metrics, || {
                    in_build.store(true, Ordering::SeqCst);
                    std::thread::sleep(Duration::from_millis(150));
                    Err(crate::error::Error::Training("diverged".into()))
                })
            });
            let waiter = s.spawn(|| {
                // enter only once the leader is provably mid-build
                while !in_build.load(Ordering::SeqCst) {
                    std::thread::yield_now();
                }
                cache.models(key, &metrics, || panic!("waiter must not build"))
            });
            assert!(leader.join().unwrap().is_err());
            let err = waiter.join().unwrap().unwrap_err();
            assert!(
                err.to_string().contains("coalesced"),
                "waiter should report the coalesced failure, got: {err}"
            );
        });
        // the failed key is gone; a later request retries fresh
        assert_eq!(cache.sizes(), (0, 0, 0));
        let (_, built) = cache.models(key, &metrics, || Ok(demo_models(4.0))).unwrap();
        assert!(built);
    }

    #[test]
    fn breaker_opens_after_consecutive_failures_then_probes_and_closes() {
        let cache = PlaneCache::new(); // thresholds: 3 failures, 8 rejections
        let metrics = Metrics::new();
        let key = model_key(30);
        for i in 0..3 {
            let err = cache
                .models(key, &metrics, || {
                    Err(crate::error::Error::Training(format!("injected failure {i}")))
                })
                .unwrap_err();
            assert!(matches!(err, crate::error::Error::Training(_)));
        }
        assert_eq!(cache.breaker_state(&key), Some(BreakerState::Open));
        assert_eq!(cache.open_breakers().len(), 1);
        // while open, acquisitions are rejected before the build runs
        for _ in 0..8 {
            let err = cache
                .models(key, &metrics, || unreachable!("breaker must reject before the build"))
                .unwrap_err();
            assert!(matches!(err, crate::error::Error::CircuitOpen(_)), "{err}");
        }
        // the cooled-down breaker lets the next caller probe; a successful
        // probe closes the circuit
        let (_, built) = cache.models(key, &metrics, || Ok(demo_models(7.0))).unwrap();
        assert!(built);
        assert_eq!(cache.breaker_state(&key), Some(BreakerState::Closed));
        assert!(cache.open_breakers().is_empty());
        // Closed -> Open, Open -> HalfOpen, HalfOpen -> Closed
        assert_eq!(metrics.breaker_transitions.load(Ordering::Relaxed), 3);
        assert_eq!(metrics.model_cache_misses.load(Ordering::Relaxed), 4);
    }

    #[test]
    fn failed_probe_reopens_the_breaker() {
        let cache =
            PlaneCache::with_breaker(BreakerConfig { failure_threshold: 2, cooldown_rejections: 1 });
        let metrics = Metrics::new();
        let key = model_key(31);
        for _ in 0..2 {
            let _ = cache.models(key, &metrics, || {
                Err(crate::error::Error::Training("still broken".into()))
            });
        }
        assert_eq!(cache.breaker_state(&key), Some(BreakerState::Open));
        let _ = cache
            .models(key, &metrics, || unreachable!("cooling down"))
            .unwrap_err();
        // probe fails -> straight back to Open, not Closed-with-one-failure
        let err = cache
            .models(key, &metrics, || Err(crate::error::Error::Training("probe fails".into())))
            .unwrap_err();
        assert!(matches!(err, crate::error::Error::Training(_)));
        assert_eq!(cache.breaker_state(&key), Some(BreakerState::Open));
        // Closed->Open, Open->HalfOpen, HalfOpen->Open
        assert_eq!(metrics.breaker_transitions.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn success_resets_the_consecutive_failure_count() {
        let cache = PlaneCache::new();
        let metrics = Metrics::new();
        let key = model_key(32);
        for round in 0..3 {
            // 2 failures (below the threshold of 3), then a success
            for _ in 0..2 {
                let _ = cache.models(key, &metrics, || {
                    Err(crate::error::Error::Training("flaky".into()))
                });
            }
            let (_, built) = cache
                .models(key, &metrics, || Ok(demo_models(round as f32)))
                .unwrap();
            assert!(built);
            assert_eq!(cache.breaker_state(&key), Some(BreakerState::Closed));
            // drop the cached pair so the next round rebuilds
            lock_unpoisoned(&cache.models).remove(&key);
        }
        assert_eq!(metrics.breaker_transitions.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn waiter_failures_do_not_count_toward_the_breaker() {
        let cache =
            PlaneCache::with_breaker(BreakerConfig { failure_threshold: 2, cooldown_rejections: 8 });
        let metrics = Metrics::new();
        let key = model_key(33);
        let in_build = AtomicBool::new(false);
        std::thread::scope(|s| {
            let leader = s.spawn(|| {
                cache.models(key, &metrics, || {
                    in_build.store(true, Ordering::SeqCst);
                    std::thread::sleep(Duration::from_millis(150));
                    Err(crate::error::Error::Training("diverged".into()))
                })
            });
            let waiter = s.spawn(|| {
                while !in_build.load(Ordering::SeqCst) {
                    std::thread::yield_now();
                }
                cache.models(key, &metrics, || unreachable!("waiter must coalesce"))
            });
            assert!(leader.join().unwrap().is_err());
            assert!(waiter.join().unwrap().is_err());
        });
        // one build failed once: the leader's failure counts, the waiter's
        // surfaced copy of it must not (else bursts double-count straight
        // past the threshold)
        assert_eq!(cache.breaker_state(&key), Some(BreakerState::Closed));
        assert!(cache.open_breakers().is_empty());
    }

    #[test]
    fn panicking_probe_reopens_instead_of_wedging_half_open() {
        let cache =
            PlaneCache::with_breaker(BreakerConfig { failure_threshold: 1, cooldown_rejections: 0 });
        let metrics = Metrics::new();
        let key = model_key(34);
        let _ = cache.models(key, &metrics, || {
            Err(crate::error::Error::Training("opens immediately".into()))
        });
        assert_eq!(cache.breaker_state(&key), Some(BreakerState::Open));
        // cooldown 0: the next caller probes right away — and panics
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            cache.models(key, &metrics, || -> Result<HostModels> {
                panic!("probe crashed")
            })
        }));
        assert!(res.is_err());
        // the panic guard recorded the failure: back to Open, not stuck
        // HalfOpen with no probe alive
        assert_eq!(cache.breaker_state(&key), Some(BreakerState::Open));
        // and the key still recovers once the fault clears
        let _ = cache.models(key, &metrics, || Ok(demo_models(8.0))).unwrap();
        assert_eq!(cache.breaker_state(&key), Some(BreakerState::Closed));
    }

    #[test]
    fn snapshot_tracks_ready_entries_through_publish_and_invalidate() {
        let cache = PlaneCache::new();
        let metrics = Metrics::new();
        assert_eq!(cache.read_snapshot().sizes(), (0, 0, 0));

        let gkey = GridKey::for_request(DeviceKind::OrinAgx, None, 1);
        let g = cache.grid(gkey, || entry(30));
        let key = model_key(40);
        let (m1, _) = cache.models(key, &metrics, || Ok(demo_models(1.0))).unwrap();
        let pkey = PlaneKey { grid: gkey, time_fp: m1.time_fp, power_fp: m1.power_fp };
        let p1 = cache.plane(pkey, &metrics, || plane_over(Arc::clone(&g)));

        // every leader insert republished: the snapshot resolves all three
        let snap = cache.read_snapshot();
        assert_eq!(snap.sizes(), (1, 1, 1));
        assert!(Arc::ptr_eq(snap.grid(&gkey).unwrap(), &g));
        assert!(Arc::ptr_eq(snap.models(&key).unwrap(), &m1));
        assert!(Arc::ptr_eq(snap.plane(&pkey).unwrap(), &p1));

        // a refit publish swaps the visible model pair atomically...
        let m2 = cache.publish_models(key, demo_models(2.0)).unwrap();
        let snap = cache.read_snapshot();
        assert!(Arc::ptr_eq(snap.models(&key).unwrap(), &m2));
        assert_eq!(snap.models(&key).unwrap().version, 2);
        // ...and invalidating the superseded planes drops them from the
        // snapshot too, so the fast path can't serve a stale pairing
        cache.invalidate_planes(m1.time_fp, m1.power_fp);
        let snap = cache.read_snapshot();
        assert!(snap.plane(&pkey).is_none());
        assert_eq!(snap.sizes(), (1, 0, 1));
    }

    #[test]
    fn snapshot_excludes_inflight_builds() {
        let cache = PlaneCache::new();
        let metrics = Metrics::new();
        let key = model_key(41);
        let in_build = AtomicBool::new(false);
        std::thread::scope(|s| {
            let leader = s.spawn(|| {
                cache.models(key, &metrics, || {
                    in_build.store(true, Ordering::SeqCst);
                    std::thread::sleep(Duration::from_millis(100));
                    Ok(demo_models(9.0))
                })
            });
            while !in_build.load(Ordering::SeqCst) {
                std::thread::yield_now();
            }
            // mid-build: the in-flight slot must not leak into a snapshot,
            // and a refused publish must not republish anything either
            assert!(cache.publish_models(key, demo_models(10.0)).is_none());
            assert!(cache.read_snapshot().models(&key).is_none());
            let (m, _) = leader.join().unwrap().unwrap();
            // the leader's completion republished
            assert!(Arc::ptr_eq(cache.read_snapshot().models(&key).unwrap(), &m));
        });
    }

    #[test]
    fn verify_integrity_detects_fingerprint_mismatch() {
        let good = demo_models(1.0);
        assert!(good.verify_integrity().is_ok());
        let mut corrupted = demo_models(1.0);
        corrupted.time_fp ^= 0xdead_beef;
        let err = corrupted.verify_integrity().unwrap_err();
        assert!(matches!(err, crate::error::Error::Artifact(_)));
        assert!(err.to_string().contains("fingerprint mismatch"), "{err}");
    }

    #[test]
    fn panicking_build_fails_the_flight_and_frees_the_key() {
        let cache = PlaneCache::new();
        let metrics = Metrics::new();
        let key = model_key(11);
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            cache.models(key, &metrics, || -> Result<HostModels> {
                panic!("simulated builder crash")
            })
        }));
        assert!(res.is_err(), "the panic must propagate to the leader");
        // the drop guard removed the in-flight slot: nothing resident,
        // and a later request becomes a fresh leader instead of hanging
        assert_eq!(cache.sizes(), (0, 0, 0));
        let (_, built) = cache.models(key, &metrics, || Ok(demo_models(5.0))).unwrap();
        assert!(built);
    }
}
