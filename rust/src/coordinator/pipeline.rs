//! The staged request pipeline: admission → grid/feature-matrix
//! resolution → model acquisition → plane resolution → Pareto query →
//! response.
//!
//! Each stage has a narrow typed interface — [`Admitted`] flows into
//! [`ResolvedGrid`], which feeds singleflight model acquisition
//! (`PlaneCache::models`), plane resolution (`PlaneCache::plane`) and
//! finally the O(log front) budget query — replacing the old monolithic
//! handler that threaded six loose arguments through one 200-line
//! function. [`HostPipeline`] bundles the per-worker serving context
//! (cache, reference models + their fingerprints, config, metrics) once;
//! workers of a long-lived [`Coordinator`](crate::coordinator::Coordinator)
//! construct it at startup so steady-state requests never re-hash the
//! reference parameters.
//!
//! Strategy routing (paper Table 1) is unchanged:
//!
//! * `Strategy::PowerTrain(n)` — profile `n` modes via the simulated
//!   [`Profiler`], transfer-learn both reference models on host
//!   (`transfer_host`), predict the grid, Pareto-optimize;
//! * `Strategy::NnProfiled(n)` — same, training from scratch
//!   ([`HostTrainer`]) instead of transferring;
//! * `Strategy::BruteForce` — profile the whole grid, observed optimum
//!   (skips the model/plane stages entirely).
//!
//! Grid-resident + singleflight: the per-workload model pair is cached
//! under [`ModelKey`] (host fits are deterministic per key) with
//! concurrent identical requests coalescing onto one in-flight fit, and
//! everything budget-independent — grid, shared SoA feature matrix, both
//! prediction planes, Pareto front — lives in the shared cache keyed by
//! grid identity plus the content fingerprints of the *transferred*
//! checkpoints. The first request per workload pays profiling + two fits
//! + the plane build; every later one answers via `ParetoFront::optimize`'s
//! binary search over the cached front.

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Instant;

use crate::coordinator::cache::{
    GridEntry, GridKey, HostModels, ModelKey, PlaneCache, PlaneKey, ServePlane,
};
use crate::coordinator::lifecycle::Lifecycle;
use crate::coordinator::{
    prediction_grid, CoordinatorConfig, Metrics, ReferenceModels, Request, Response, Strategy,
};
use crate::device::PowerMode;
use crate::error::{Error, Result};
use crate::nn::checkpoint::Checkpoint;
use crate::pareto::{ParetoFront, Point};
use crate::predict::PlanePredictor;
use crate::profiler::Profiler;
use crate::sim::TrainerSim;
use crate::train::transfer::{transfer_host, TransferConfig};
use crate::train::{HostTrainer, Target, TrainConfig};
use crate::util::rng::Rng;

#[cfg(feature = "xla")]
use crate::device::PowerModeGrid;
#[cfg(feature = "xla")]
use crate::runtime::Runtime;
#[cfg(feature = "xla")]
use crate::train::{transfer::transfer, Trainer};

/// Stage 1 output: a validated request with its resolved strategy and
/// the wall-clock the latency is measured from.
#[derive(Debug)]
struct Admitted<'r> {
    req: &'r Request,
    strategy: Strategy,
    t0: Instant,
}

/// Stage 2 output: the grid identity and the resident grid state (mode
/// list + shared SoA feature matrix) every later stage reads.
struct ResolvedGrid {
    key: GridKey,
    entry: Arc<GridEntry>,
}

/// The per-worker host serving context: everything a pipeline run needs,
/// bundled once instead of threaded as loose arguments. Construct one
/// per worker (or per one-shot call via [`handle_request_host`]); the
/// reference fingerprints are hashed exactly once per context, so a
/// steady-state cache hit never pays an O(params) hash.
pub struct HostPipeline<'a> {
    cache: &'a PlaneCache,
    reference: &'a ReferenceModels,
    ref_fps: (u64, u64),
    cfg: &'a CoordinatorConfig,
    metrics: &'a Metrics,
    /// Model-lifecycle manager, when the service runs with one: the
    /// pipeline reports which model pair served each request so
    /// staleness exposure (`stale_served`) is accounted where it
    /// happens.
    lifecycle: Option<&'a Lifecycle>,
}

impl<'a> HostPipeline<'a> {
    pub fn new(
        cache: &'a PlaneCache,
        reference: &'a ReferenceModels,
        cfg: &'a CoordinatorConfig,
        metrics: &'a Metrics,
    ) -> HostPipeline<'a> {
        HostPipeline {
            cache,
            reference,
            ref_fps: reference.fingerprints(),
            cfg,
            metrics,
            lifecycle: None,
        }
    }

    /// Attach the lifecycle manager (drift/staleness accounting).
    pub fn with_lifecycle(mut self, lifecycle: &'a Lifecycle) -> HostPipeline<'a> {
        self.lifecycle = Some(lifecycle);
        self
    }

    /// Run one request through every stage.
    pub fn handle(&self, req: &Request) -> Result<Response> {
        let admitted = self.admit(req)?;
        let grid = self.resolve_grid(&admitted);
        if let Strategy::BruteForce = admitted.strategy {
            return self.brute_force(&admitted, &grid);
        }
        // the single shared key derivation (`ModelKey::for_request`) is
        // also what the lifecycle's feedback lane resolves, so observed
        // outcomes are always attributed to the entry that served them
        let key = ModelKey::for_request(
            admitted.req,
            admitted.strategy,
            self.cfg.prediction_grid,
            self.cfg.transfer_epochs,
            self.ref_fps,
        );
        debug_assert_eq!(key.grid, grid.key, "model key must live on the resolved grid");
        let (models, built) = self.acquire_models(&admitted, &grid, key)?;
        let plane = self.resolve_plane(&grid, &models);
        let chosen = pareto_query(&plane.front, admitted.req.power_budget_w)?;
        // counted only once a response is certain (`respond` is
        // infallible): `stale_served` measures answers actually produced
        // from a condemned model, not failed attempts that touched one
        if let Some(lifecycle) = self.lifecycle {
            lifecycle.note_served(&key);
        }
        // profiling cost is charged to the request that actually led the
        // fit; coalesced/cached requests spent zero device-seconds
        let profiling_cost_s = if built { models.profiling_cost_s } else { 0.0 };
        Ok(respond(
            admitted.req,
            chosen,
            format!("{}(host)", admitted.strategy),
            profiling_cost_s,
            self.metrics,
            admitted.t0,
        ))
    }

    /// Stage 1 — admission: count the arrival, reject malformed requests
    /// before any profiling or fitting work is spent, resolve the
    /// scenario's strategy (paper Table 1).
    fn admit<'r>(&self, req: &'r Request) -> Result<Admitted<'r>> {
        let t0 = Instant::now();
        admit_request(req, self.metrics)?;
        Ok(Admitted { req, strategy: Strategy::for_scenario(req.scenario), t0 })
    }

    /// Stage 2 — grid resolution: the device grid + shared feature
    /// matrix, resident in the cache (singleflight on first touch).
    fn resolve_grid(&self, a: &Admitted<'_>) -> ResolvedGrid {
        let key = GridKey::for_request(a.req.device, self.cfg.prediction_grid, a.req.seed);
        let entry = self.cache.grid(key, || {
            GridEntry::new(prediction_grid(a.req.device, self.cfg.prediction_grid, a.req.seed))
        });
        ResolvedGrid { key, entry }
    }

    /// Stage 3 — model acquisition, singleflight: a burst of identical
    /// requests costs exactly one online-profiling run + host fit pair;
    /// concurrent requesters of the same [`ModelKey`] block on the
    /// in-flight fit instead of duplicating it.
    fn acquire_models(
        &self,
        a: &Admitted<'_>,
        g: &ResolvedGrid,
        key: ModelKey,
    ) -> Result<(Arc<HostModels>, bool)> {
        self.cache.models(key, self.metrics, || {
            train_host_models(
                &g.entry.grid, self.reference, self.cfg, self.metrics, a.req, a.strategy,
            )
        })
    }

    /// Stage 4 — plane resolution: both raw-unit prediction planes and
    /// the Pareto front over them, resident per (grid, model-pair).
    fn resolve_plane(&self, g: &ResolvedGrid, models: &HostModels) -> Arc<ServePlane> {
        let key = PlaneKey { grid: g.key, time_fp: models.time_fp, power_fp: models.power_fp };
        self.cache.plane(key, self.metrics, || {
            build_plane(Arc::clone(&g.entry), &models.time, &models.power)
        })
    }

    /// The brute-force lane (one-time training): skips the model/plane
    /// stages and profiles the whole grid for the observed optimum.
    fn brute_force(&self, a: &Admitted<'_>, g: &ResolvedGrid) -> Result<Response> {
        brute_force_response(a.req, &g.entry.grid.modes, self.metrics, a.t0)
    }
}

/// Stage 5 — the budget query: fastest predicted mode within the budget,
/// an O(log front) binary search over the cached front.
fn pareto_query(front: &ParetoFront, power_budget_w: f64) -> Result<Point> {
    front.optimize(power_budget_w * 1000.0)
}

/// The admission check shared by the host pipeline and the xla lane:
/// count the arrival, reject malformed budgets before any profiling or
/// fitting work is spent. Both lanes therefore classify and count
/// rejections identically.
fn admit_request(req: &Request, metrics: &Metrics) -> Result<()> {
    metrics.requests_received.fetch_add(1, Ordering::Relaxed);
    if !req.power_budget_w.is_finite() || req.power_budget_w <= 0.0 {
        metrics.admission_rejected.fetch_add(1, Ordering::Relaxed);
        return Err(Error::Usage(format!(
            "request {} rejected at admission: power budget must be positive and finite, got {}",
            req.id, req.power_budget_w
        )));
    }
    Ok(())
}

/// One-shot convenience wrapper over [`HostPipeline`]: serve a single
/// request end-to-end without the PJRT runtime — the default build's
/// native path. Long-lived services construct one [`HostPipeline`] per
/// worker instead so reference fingerprints hash once, not per call.
pub fn handle_request_host(
    cache: &PlaneCache,
    reference: &ReferenceModels,
    cfg: &CoordinatorConfig,
    metrics: &Metrics,
    req: &Request,
) -> Result<Response> {
    HostPipeline::new(cache, reference, cfg, metrics).handle(req)
}

/// The model-cache-miss work: online profiling of the strategy's mode
/// sample on the simulated target, then two host fits (transfer for
/// PowerTrain, from-scratch for NnProfiled). Deterministic in the
/// [`ModelKey`] inputs — same seed, workload, grid, references and
/// epochs reproduce bit-identical checkpoints.
fn train_host_models(
    grid: &crate::device::PowerModeGrid,
    reference: &ReferenceModels,
    cfg: &CoordinatorConfig,
    metrics: &Metrics,
    req: &Request,
    strategy: Strategy,
) -> Result<HostModels> {
    let n_profile = strategy.profiling_modes(grid.len()).min(grid.len());
    let mut rng = Rng::new(req.seed);
    let sample = grid.sample(n_profile, &mut rng);
    let mut profiler = Profiler::new(TrainerSim::new(req.device.spec(), req.workload, req.seed));
    let corpus = profiler.profile_modes(&sample)?;
    metrics.modes_profiled.fetch_add(corpus.len() as u64, Ordering::Relaxed);
    metrics.add_profiling_s(corpus.total_cost_s());

    let base = TrainConfig { epochs: cfg.transfer_epochs, seed: req.seed, ..Default::default() };
    let (time, tlog, power, plog) = match strategy {
        Strategy::PowerTrain(_) => {
            let tcfg = TransferConfig { base, ..Default::default() };
            let (t, tl) = transfer_host(&reference.time, &corpus, Target::Time, &tcfg)?;
            let (p, pl) = transfer_host(&reference.power, &corpus, Target::Power, &tcfg)?;
            (t, tl, p, pl)
        }
        Strategy::NnProfiled(_) => {
            let trainer = HostTrainer::new();
            let (t, tl) = trainer.train(&corpus, Target::Time, &base)?;
            let (p, pl) = trainer.train(&corpus, Target::Power, &base)?;
            (t, tl, p, pl)
        }
        Strategy::BruteForce => unreachable!("brute force never trains models"),
    };
    metrics.host_fits.fetch_add(2, Ordering::Relaxed);
    // the fit-time validation MAPEs ride along as the drift monitor's
    // baseline: serving-time feedback is judged against the accuracy the
    // pair actually shipped with
    Ok(HostModels::new(time, power, corpus.total_cost_s())
        .with_validation(tlog.best_val_mape(), plog.best_val_mape()))
}

/// The cold-path work a plane-cache miss pays once per (grid, model-pair):
/// two affine-folded engine builds, two forward passes over the grid's
/// shared feature matrix, one Pareto sort. `time`/`power` are whichever
/// checkpoints the plane is keyed by — transferred per-workload models on
/// the host path, reference models elsewhere.
fn build_plane(grid: Arc<GridEntry>, time: &Checkpoint, power: &Checkpoint) -> ServePlane {
    let (times, powers) = PlanePredictor::new(time, power).predict_features(&grid.features);
    let points: Vec<Point> = grid
        .grid
        .modes
        .iter()
        .zip(times.iter().zip(&powers))
        .map(|(m, (&t, &p))| Point { mode: *m, time: t, power_mw: p })
        .collect();
    let front = ParetoFront::build(&points);
    ServePlane { grid, times, powers, front }
}

/// Stage 6 — the response tail shared by every lane: observable ground
/// truth at the chosen mode (for reporting/validation), latency +
/// completion metrics.
fn respond(
    req: &Request,
    chosen: Point,
    strategy: String,
    profiling_cost_s: f64,
    metrics: &Metrics,
    t0: Instant,
) -> Response {
    let sim = TrainerSim::new(req.device.spec(), req.workload, req.seed ^ 0xfeed);
    let obs_t = sim.true_minibatch_ms(&chosen.mode);
    let obs_p = sim.true_power_mw(&chosen.mode);

    let latency_ms = t0.elapsed().as_secs_f64() * 1000.0;
    metrics.observe_latency_ms(latency_ms);
    metrics.record_completion(req.id);

    Response {
        id: req.id,
        strategy,
        chosen_mode: chosen.mode,
        predicted_time_ms: chosen.time,
        predicted_power_w: chosen.power_mw / 1000.0,
        observed_time_ms: obs_t,
        observed_power_w: obs_p / 1000.0,
        profiling_cost_s,
        latency_ms,
    }
}

/// Brute-force tail shared by the host lane and the xla path: profile
/// every mode, pick the observed in-budget optimum.
fn brute_force_response(
    req: &Request,
    modes: &[PowerMode],
    metrics: &Metrics,
    t0: Instant,
) -> Result<Response> {
    let mut profiler = Profiler::new(TrainerSim::new(req.device.spec(), req.workload, req.seed));
    let corpus = profiler.profile_modes(modes)?;
    metrics.modes_profiled.fetch_add(corpus.len() as u64, Ordering::Relaxed);
    metrics.add_profiling_s(corpus.total_cost_s());
    let points: Vec<Point> = corpus
        .records()
        .iter()
        .map(|r| Point { mode: r.mode, time: r.time_ms, power_mw: r.power_mw })
        .collect();
    let front = ParetoFront::build(&points);
    let chosen = front.optimize(req.power_budget_w * 1000.0)?;
    let latency_ms = t0.elapsed().as_secs_f64() * 1000.0;
    metrics.observe_latency_ms(latency_ms);
    metrics.record_completion(req.id);
    Ok(Response {
        id: req.id,
        strategy: "brute-force".into(),
        chosen_mode: chosen.mode,
        predicted_time_ms: chosen.time,
        predicted_power_w: chosen.power_mw / 1000.0,
        observed_time_ms: chosen.time,
        observed_power_w: chosen.power_mw / 1000.0,
        profiling_cost_s: corpus.total_cost_s(),
        latency_ms,
    })
}

/// Serve one request end-to-end on a given runtime — the xla lane the
/// artifact-backed workers run. Uses the same admission semantics as the
/// host pipeline but predicts through the AOT artifacts.
#[cfg(feature = "xla")]
pub fn handle_request(
    rt: &Runtime,
    reference: &ReferenceModels,
    cfg: &CoordinatorConfig,
    metrics: &Metrics,
    req: &Request,
) -> Result<Response> {
    let t0 = Instant::now();
    admit_request(req, metrics)?;

    let strategy = Strategy::for_scenario(req.scenario);

    // 1. online profiling of a small random mode sample on the target
    let grid = prediction_grid(req.device, cfg.prediction_grid, req.seed);
    if let Strategy::BruteForce = strategy {
        return brute_force_response(req, &grid.modes, metrics, t0);
    }
    let n_profile = strategy.profiling_modes(grid.len()).min(grid.len());
    let mut rng = Rng::new(req.seed);
    let sample = grid.sample(n_profile, &mut rng);
    let mut profiler = Profiler::new(TrainerSim::new(req.device.spec(), req.workload, req.seed));
    let corpus = profiler.profile_modes(&sample)?;
    metrics.modes_profiled.fetch_add(corpus.len() as u64, Ordering::Relaxed);
    metrics.add_profiling_s(corpus.total_cost_s());

    // 2. obtain time/power prediction models per the scenario's strategy
    let (time_ckpt, power_ckpt, strat_name) = match strategy {
        Strategy::PowerTrain(_) => {
            let tcfg = TransferConfig {
                base: TrainConfig {
                    epochs: cfg.transfer_epochs,
                    seed: req.seed,
                    ..Default::default()
                },
                ..Default::default()
            };
            let (t, _) = transfer(rt, &reference.time, &corpus, Target::Time, &tcfg)?;
            let (p, _) = transfer(rt, &reference.power, &corpus, Target::Power, &tcfg)?;
            (t, p, strategy.to_string())
        }
        Strategy::NnProfiled(_) => {
            let trainer = Trainer::new(rt);
            let ncfg = TrainConfig {
                epochs: cfg.transfer_epochs,
                seed: req.seed,
                ..Default::default()
            };
            let (t, _) = trainer.train(&corpus, Target::Time, &ncfg)?;
            let (p, _) = trainer.train(&corpus, Target::Power, &ncfg)?;
            (t, p, strategy.to_string())
        }
        Strategy::BruteForce => unreachable!("handled above"),
    };

    // 3. predict the full grid through the AOT artifacts and build the
    //    predicted Pareto front (paper Fig 10)
    let times = crate::predict::predict_modes(rt, &time_ckpt, &grid.modes)?;
    let powers = crate::predict::predict_modes(rt, &power_ckpt, &grid.modes)?;
    finish_predicted(req, &grid, &times, &powers, strat_name, corpus.total_cost_s(), metrics, t0)
}

/// Shared tail of the per-request predicted path (xla transfer serving):
/// Pareto build, budget optimization, post-hoc observation, metrics.
/// The host pipeline goes through the plane cache instead and only
/// shares [`respond`].
#[cfg(feature = "xla")]
#[allow(clippy::too_many_arguments)]
fn finish_predicted(
    req: &Request,
    grid: &PowerModeGrid,
    times: &[f64],
    powers: &[f64],
    strategy: String,
    profiling_cost_s: f64,
    metrics: &Metrics,
    t0: Instant,
) -> Result<Response> {
    let points: Vec<Point> = grid
        .modes
        .iter()
        .zip(times.iter().zip(powers))
        .map(|(m, (&t, &p))| Point { mode: *m, time: t, power_mw: p })
        .collect();
    let front = ParetoFront::build(&points);

    // optimize: fastest predicted mode within the budget
    let chosen = front.optimize(req.power_budget_w * 1000.0)?;
    Ok(respond(req, chosen, strategy, profiling_cost_s, metrics, t0))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::test_support::{host_cfg, host_reference};
    use crate::coordinator::Scenario;
    use crate::device::DeviceKind;
    use crate::workload::Workload;

    #[test]
    fn host_powertrain_request_runs_the_full_loop() {
        let reference = host_reference();
        let cfg = host_cfg(300);
        let metrics = Metrics::new();
        let cache = PlaneCache::new();
        let req = Request {
            id: 9,
            device: DeviceKind::OrinAgx,
            workload: Workload::mobilenet(),
            power_budget_w: 1e6, // any front point qualifies
            scenario: Scenario::FederatedLearning,
            seed: 5,
        };
        let resp = handle_request_host(&cache, &reference, &cfg, &metrics, &req).unwrap();
        // the paper loop actually ran: 50 modes profiled, both targets
        // transfer-learned on host, cost accounted on the request
        assert_eq!(resp.strategy, "powertrain-50(host)");
        assert!(resp.profiling_cost_s > 0.0);
        assert_eq!(metrics.modes_profiled.load(Ordering::Relaxed), 50);
        assert_eq!(metrics.host_fits.load(Ordering::Relaxed), 2);
        assert_eq!(metrics.model_cache_misses.load(Ordering::Relaxed), 1);
        resp.chosen_mode.validate(DeviceKind::OrinAgx.spec()).unwrap();
        assert_eq!(metrics.requests_completed.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.plane_cache_misses.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.plane_cache_hits.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn nn_profiled_strategy_trains_from_scratch_on_host() {
        let reference = host_reference();
        let cfg = host_cfg(200);
        let metrics = Metrics::new();
        let cache = PlaneCache::new();
        let req = Request {
            id: 1,
            device: DeviceKind::OrinAgx,
            workload: Workload::lstm(),
            power_budget_w: 1e6,
            scenario: Scenario::FineTuning, // → NnProfiled(100)
            seed: 6,
        };
        let resp = handle_request_host(&cache, &reference, &cfg, &metrics, &req).unwrap();
        assert_eq!(resp.strategy, "nn-100(host)");
        assert_eq!(metrics.modes_profiled.load(Ordering::Relaxed), 100);
        assert_eq!(metrics.host_fits.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn admission_rejects_malformed_budgets_before_any_work() {
        let reference = host_reference();
        let cfg = host_cfg(100);
        let metrics = Metrics::new();
        let cache = PlaneCache::new();
        for (id, bad_budget) in [(0u64, -5.0), (1, 0.0), (2, f64::NAN), (3, f64::INFINITY)] {
            let req = Request {
                id,
                device: DeviceKind::OrinAgx,
                workload: Workload::mobilenet(),
                power_budget_w: bad_budget,
                scenario: Scenario::FederatedLearning,
                seed: 5,
            };
            let err = handle_request_host(&cache, &reference, &cfg, &metrics, &req).unwrap_err();
            assert!(matches!(err, Error::Usage(_)), "budget {bad_budget}: {err}");
        }
        assert_eq!(metrics.admission_rejected.load(Ordering::Relaxed), 4);
        // rejected before profiling/fitting: no work was spent
        assert_eq!(metrics.modes_profiled.load(Ordering::Relaxed), 0);
        assert_eq!(metrics.host_fits.load(Ordering::Relaxed), 0);
        assert_eq!(cache.sizes(), (0, 0, 0));
    }

    #[test]
    fn cache_hit_is_bit_identical_and_counted() {
        let reference = host_reference();
        let cfg = host_cfg(300);
        let metrics = Metrics::new();
        let req = |id: u64| Request {
            id,
            device: DeviceKind::OrinAgx,
            workload: Workload::mobilenet(),
            power_budget_w: 1e6,
            scenario: Scenario::FederatedLearning,
            seed: 5,
        };
        // uncached baseline on its own fresh cache
        let fresh = PlaneCache::new();
        let uncached = handle_request_host(&fresh, &reference, &cfg, &metrics, &req(0)).unwrap();
        // cold miss then hit on a shared cache
        let cache = PlaneCache::new();
        let cold = handle_request_host(&cache, &reference, &cfg, &metrics, &req(1)).unwrap();
        let hit = handle_request_host(&cache, &reference, &cfg, &metrics, &req(2)).unwrap();
        assert_eq!(metrics.plane_cache_misses.load(Ordering::Relaxed), 2);
        assert_eq!(metrics.plane_cache_hits.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.model_cache_misses.load(Ordering::Relaxed), 2);
        assert_eq!(metrics.model_cache_hits.load(Ordering::Relaxed), 1);
        // host fits are deterministic per key, so a cached answer is
        // byte-identical to the uncached one in every model-derived field
        // (id and wall-clock latency are per-request by construction)
        for r in [&cold, &hit] {
            assert_eq!(r.chosen_mode, uncached.chosen_mode);
            assert_eq!(r.strategy, uncached.strategy);
            assert_eq!(r.predicted_time_ms.to_bits(), uncached.predicted_time_ms.to_bits());
            assert_eq!(r.predicted_power_w.to_bits(), uncached.predicted_power_w.to_bits());
            assert_eq!(r.observed_time_ms.to_bits(), uncached.observed_time_ms.to_bits());
            assert_eq!(r.observed_power_w.to_bits(), uncached.observed_power_w.to_bits());
        }
        // profiling happened exactly once per *fresh* model build; the
        // cache hit spent zero simulated device-seconds
        assert_eq!(cold.profiling_cost_s.to_bits(), uncached.profiling_cost_s.to_bits());
        assert!(cold.profiling_cost_s > 0.0);
        assert_eq!(hit.profiling_cost_s, 0.0);
    }

    #[test]
    fn budget_only_requests_share_one_plane_and_one_fit() {
        let reference = host_reference();
        let cfg = host_cfg(400);
        let metrics = Metrics::new();
        let cache = PlaneCache::new();
        for (i, budget_w) in [1e6, 40.0, 25.0, 60.0, 1e6].iter().enumerate() {
            let req = Request {
                id: i as u64,
                device: DeviceKind::OrinAgx,
                workload: Workload::lstm(),
                power_budget_w: *budget_w,
                scenario: Scenario::ContinuousLearning,
                seed: 8,
            };
            match handle_request_host(&cache, &reference, &cfg, &metrics, &req) {
                Ok(resp) => assert!(
                    resp.predicted_power_w <= budget_w + 1e-9,
                    "budget {budget_w} W violated: {}",
                    resp.predicted_power_w
                ),
                // an infeasible budget is still answered from the cached
                // plane (the lookup precedes the optimize)
                Err(Error::Optimization(_)) => {}
                Err(e) => panic!("unexpected error: {e}"),
            }
        }
        // one profiling run + one transfer pair + one plane build; four
        // O(log front) answers
        assert_eq!(metrics.model_cache_misses.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.model_cache_hits.load(Ordering::Relaxed), 4);
        assert_eq!(metrics.host_fits.load(Ordering::Relaxed), 2);
        assert_eq!(metrics.modes_profiled.load(Ordering::Relaxed), 50);
        assert_eq!(metrics.plane_cache_misses.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.plane_cache_hits.load(Ordering::Relaxed), 4);
        assert_eq!(cache.sizes(), (1, 1, 1));
    }

    #[test]
    fn distinct_workloads_get_distinct_transferred_planes() {
        // transferred checkpoints flow through the plane cache by content
        // fingerprint, so two workloads on the same grid coexist — planes
        // cache alongside each other instead of colliding
        let reference = host_reference();
        let cfg = host_cfg(250);
        let metrics = Metrics::new();
        let cache = PlaneCache::new();
        let req = |id: u64, wl: Workload| Request {
            id,
            device: DeviceKind::OrinAgx,
            workload: wl,
            power_budget_w: 1e6,
            scenario: Scenario::ContinuousLearning,
            seed: 12,
        };
        let a = handle_request_host(&cache, &reference, &cfg, &metrics, &req(0, Workload::lstm()))
            .unwrap();
        let b =
            handle_request_host(&cache, &reference, &cfg, &metrics, &req(1, Workload::bert()))
                .unwrap();
        // one shared grid, two model pairs, two planes
        assert_eq!(cache.sizes(), (1, 2, 2));
        assert_eq!(metrics.model_cache_misses.load(Ordering::Relaxed), 2);
        // per-workload models genuinely differ
        assert!(
            a.predicted_time_ms.to_bits() != b.predicted_time_ms.to_bits()
                || a.predicted_power_w.to_bits() != b.predicted_power_w.to_bits(),
            "two workloads produced identical planes"
        );
        // and re-asking workload A hits both caches
        handle_request_host(&cache, &reference, &cfg, &metrics, &req(2, Workload::lstm()))
            .unwrap();
        assert_eq!(metrics.model_cache_hits.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.plane_cache_hits.load(Ordering::Relaxed), 1);
    }
}
