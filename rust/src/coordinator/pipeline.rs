//! The staged request pipeline: admission → grid/feature-matrix
//! resolution → model acquisition → plane resolution → Pareto query →
//! response.
//!
//! Each stage has a narrow typed interface — [`Admitted`] flows into
//! [`ResolvedGrid`], which feeds singleflight model acquisition
//! (`PlaneCache::models`), plane resolution (`PlaneCache::plane`) and
//! finally the O(log front) budget query — replacing the old monolithic
//! handler that threaded six loose arguments through one 200-line
//! function. [`HostPipeline`] bundles the per-worker serving context
//! (cache, reference models + their fingerprints, config, metrics) once;
//! workers of a long-lived [`Coordinator`](crate::coordinator::Coordinator)
//! construct it at startup so steady-state requests never re-hash the
//! reference parameters.
//!
//! Strategy routing (paper Table 1) is unchanged:
//!
//! * `Strategy::PowerTrain(n)` — profile `n` modes via the simulated
//!   [`Profiler`], transfer-learn both reference models on host
//!   (`transfer_host`), predict the grid, Pareto-optimize;
//! * `Strategy::NnProfiled(n)` — same, training from scratch
//!   ([`HostTrainer`]) instead of transferring;
//! * `Strategy::BruteForce` — profile the whole grid, observed optimum
//!   (skips the model/plane stages entirely).
//!
//! Grid-resident + singleflight: the per-workload model pair is cached
//! under [`ModelKey`] (host fits are deterministic per key) with
//! concurrent identical requests coalescing onto one in-flight fit, and
//! everything budget-independent — grid, shared SoA feature matrix, both
//! prediction planes, Pareto front — lives in the shared cache keyed by
//! grid identity plus the content fingerprints of the *transferred*
//! checkpoints. The first request per workload pays profiling + two fits
//! + the plane build; every later one answers via `ParetoFront::optimize`'s
//! binary search over the cached front — and takes the *lock-free fast
//! path* ([`HostPipeline::handle_attempt`]): the whole hit resolves
//! against the cache's atomically-published immutable snapshot, so warm
//! requests never contend with each other or with in-flight builds.
//!
//! Resilience: scripted faults from a [`FaultInjector`] fire inside the
//! cache-miss build (transient profiling/fit failures, permanent per-key
//! failures, checkpoint corruption caught by the integrity check), the
//! serving loop retries transients against [`handle_attempt`]'s attempt
//! counter, and [`HostPipeline::degrade`] walks a Ridge-fallback → NPE
//! ladder so every request still gets *an* answer — tagged with its
//! [`Provenance`]. An optional [`ThermalGuard`] caps Pareto budgets at
//! the sustainable power envelope and shifts the observed ground truth
//! while the simulated die throttles.
//!
//! [`handle_attempt`]: HostPipeline::handle_attempt

use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::baselines::linreg::Ridge;
use crate::baselines::npe::npe_estimate_mw;
use crate::coordinator::cache::{
    GridEntry, GridKey, HostModels, ModelKey, PlaneCache, PlaneKey, ServePlane,
};
use crate::coordinator::lifecycle::Lifecycle;
use crate::coordinator::{
    prediction_grid, CoordinatorConfig, Metrics, Provenance, ReferenceModels, Request, Response,
    Strategy,
};
use crate::device::PowerMode;
use crate::error::{Error, Result};
use crate::nn::checkpoint::Checkpoint;
use crate::pareto::{ParetoFront, Point};
use crate::predict::PlanePredictor;
use crate::profiler::Profiler;
use crate::sim::thermal::ThermalModel;
use crate::sim::{FaultInjector, TrainerSim};
use crate::train::transfer::{transfer_host, TransferConfig};
use crate::train::{HostTrainer, Target, TrainConfig};
use crate::util::rng::Rng;
use crate::util::sync::lock_unpoisoned;

#[cfg(feature = "xla")]
use crate::device::PowerModeGrid;
#[cfg(feature = "xla")]
use crate::runtime::Runtime;
#[cfg(feature = "xla")]
use crate::train::{transfer::transfer, Trainer};

/// Stage 1 output: a validated request with its resolved strategy and
/// the wall-clock the latency is measured from.
#[derive(Debug)]
struct Admitted<'r> {
    req: &'r Request,
    strategy: Strategy,
    t0: Instant,
}

/// Stage 2 output: the grid identity and the resident grid state (mode
/// list + shared SoA feature matrix) every later stage reads.
struct ResolvedGrid {
    key: GridKey,
    entry: Arc<GridEntry>,
}

/// Clock-clamp factor while thermally throttled: minibatches stretch by
/// `1/THROTTLE_FACTOR` and draw drops by `THROTTLE_FACTOR` (the same
/// scaling the trainer sim's scripted throttle fault applies), which is
/// what lets the lifecycle drift monitor notice a throttling device
/// through ordinary serving feedback.
const THROTTLE_FACTOR: f64 = 0.7;

/// Throttle-recovery hysteresis (°C below the trip point): once tripped,
/// the guard holds the throttled state until the die cools this far below
/// `throttle_c`, like a real DVFS governor — no flapping at the limit.
const RECOVER_MARGIN_C: f64 = 10.0;

/// Modes the Ridge degradation rung profiles: enough for a stable
/// closed-form fit on 4 features, a fraction of the primary path's 50.
const RIDGE_FALLBACK_MODES: usize = 8;

/// Ridge regularizer for the degradation rung.
const RIDGE_FALLBACK_LAMBDA: f64 = 1e-6;

/// Seed salt separating the fallback's profiling stream from the primary
/// path's: a fault plan keyed on the request seed must not
/// deterministically replay against the rescue attempt.
const FALLBACK_SALT: u64 = 0x6465_6772_6164_6531; // "degrade1"

/// Thermal-guard tuning.
#[derive(Debug, Clone, Copy)]
pub struct ThermalConfig {
    /// Simulated seconds of sustained training each served response
    /// represents on the guard's clock (one "serve slice").
    pub slice_s: f64,
}

impl Default for ThermalConfig {
    fn default() -> Self {
        ThermalConfig { slice_s: 30.0 }
    }
}

/// Serving-side thermal state shared by all pipeline workers: a
/// [`ThermalModel`] advanced one slice per response at the chosen mode's
/// *true* draw, plus the throttle latch. Fault plans script fan-off
/// episodes through it; the Pareto query caps budgets at
/// [`ThermalGuard::ceiling_mw`].
#[derive(Debug)]
pub struct ThermalGuard {
    state: Mutex<GuardState>,
    slice_s: f64,
    faults: Option<Arc<FaultInjector>>,
}

#[derive(Debug)]
struct GuardState {
    model: ThermalModel,
    clock_s: f64,
    throttled: bool,
}

impl ThermalGuard {
    pub fn new(cfg: ThermalConfig, faults: Option<Arc<FaultInjector>>) -> ThermalGuard {
        ThermalGuard {
            state: Mutex::new(GuardState {
                model: ThermalModel::default(),
                clock_s: 0.0,
                throttled: false,
            }),
            slice_s: cfg.slice_s,
            faults,
        }
    }

    fn fan_off_at(&self, t_s: f64) -> bool {
        self.faults.as_ref().is_some_and(|inj| inj.fan_off_at(t_s))
    }

    /// Power ceiling (mW) the Pareto query must respect right now. Uses
    /// the fan state as of the *last* advance: the guard learns about a
    /// fan failure the way a real board does — from telemetry after it
    /// already ran a slice hot — so an episode's onset always slips one
    /// overdrawn slice past the clamp (which is what trips the throttle).
    pub fn ceiling_mw(&self) -> f64 {
        lock_unpoisoned(&self.state).model.max_sustainable_mw()
    }

    /// Advance the guard by one serve slice at `power_mw` sustained true
    /// draw. Returns whether the device is throttled for this slice;
    /// rising edges bump `thermal_throttle_events`.
    pub fn advance(&self, power_mw: f64, metrics: &Metrics) -> bool {
        let mut st = lock_unpoisoned(&self.state);
        // a throttled device really does draw less: clamped clocks cut
        // the integrated power, which is how it eventually cools
        let draw = if st.throttled { power_mw * THROTTLE_FACTOR } else { power_mw };
        st.clock_s += self.slice_s;
        let fan_on = !self.fan_off_at(st.clock_s);
        st.model.fan_max = fan_on;
        st.model.advance(draw, self.slice_s);
        let was = st.throttled;
        let now = st.model.would_throttle()
            || (was && st.model.temp_c() >= st.model.throttle_c - RECOVER_MARGIN_C);
        if now && !was {
            metrics.thermal_throttle_events.fetch_add(1, Ordering::Relaxed);
        }
        st.throttled = now;
        now
    }

    /// Current throttle latch (without advancing).
    pub fn throttled(&self) -> bool {
        lock_unpoisoned(&self.state).throttled
    }

    /// Current die temperature (°C).
    pub fn temp_c(&self) -> f64 {
        lock_unpoisoned(&self.state).model.temp_c()
    }

    /// Simulated sustained-serving clock (seconds).
    pub fn clock_s(&self) -> f64 {
        lock_unpoisoned(&self.state).clock_s
    }
}

/// The per-worker host serving context: everything a pipeline run needs,
/// bundled once instead of threaded as loose arguments. Construct one
/// per worker (or per one-shot call via [`handle_request_host`]); the
/// reference fingerprints are hashed exactly once per context, so a
/// steady-state cache hit never pays an O(params) hash.
pub struct HostPipeline<'a> {
    cache: &'a PlaneCache,
    reference: &'a ReferenceModels,
    ref_fps: (u64, u64),
    cfg: &'a CoordinatorConfig,
    metrics: &'a Metrics,
    /// Model-lifecycle manager, when the service runs with one: the
    /// pipeline reports which model pair served each request so
    /// staleness exposure (`stale_served`) is accounted where it
    /// happens.
    lifecycle: Option<&'a Lifecycle>,
    /// Thermal guard, when the service runs with one: caps the Pareto
    /// query at the sustainable ceiling and advances the die temperature
    /// one slice per response.
    thermal: Option<&'a ThermalGuard>,
}

impl<'a> HostPipeline<'a> {
    pub fn new(
        cache: &'a PlaneCache,
        reference: &'a ReferenceModels,
        cfg: &'a CoordinatorConfig,
        metrics: &'a Metrics,
    ) -> HostPipeline<'a> {
        HostPipeline {
            cache,
            reference,
            ref_fps: reference.fingerprints(),
            cfg,
            metrics,
            lifecycle: None,
            thermal: None,
        }
    }

    /// Attach the lifecycle manager (drift/staleness accounting).
    pub fn with_lifecycle(mut self, lifecycle: &'a Lifecycle) -> HostPipeline<'a> {
        self.lifecycle = Some(lifecycle);
        self
    }

    /// Attach the thermal guard (budget clamp + per-response advance).
    pub fn with_thermal(mut self, thermal: &'a ThermalGuard) -> HostPipeline<'a> {
        self.thermal = Some(thermal);
        self
    }

    /// Run one request through every stage (first attempt).
    pub fn handle(&self, req: &Request) -> Result<Response> {
        self.handle_attempt(req, 0)
    }

    /// Run one attempt of a request through every stage. `attempt` is
    /// the serving loop's retry counter: it selects which scripted
    /// transient faults fire (a retry outlasting a fault's streak
    /// deterministically clears it) and keeps `requests_received`
    /// counting requests, not attempts.
    ///
    /// Warm requests take the **lock-free fast path** first: grid →
    /// models → plane resolved against the cache's immutable
    /// [`ServeSnapshot`](crate::coordinator::cache::ServeSnapshot)
    /// without touching a mutex, so cache-hit throughput scales linearly
    /// with worker threads even while fits or refits are in flight. Any
    /// snapshot miss falls through to the staged slow path below,
    /// unchanged.
    pub fn handle_attempt(&self, req: &Request, attempt: u32) -> Result<Response> {
        let admitted = self.admit(req, attempt)?;
        if let Some(inj) = &self.cfg.faults {
            if inj.panics_on(req.id, attempt) {
                panic!("injected fault-plan panic while handling request {}", req.id);
            }
        }
        if let Some(result) = self.try_snapshot_serve(&admitted) {
            return result;
        }
        let grid = self.resolve_grid(&admitted);
        if let Strategy::BruteForce = admitted.strategy {
            return self.brute_force(&admitted, &grid, attempt);
        }
        // the single shared key derivation (`ModelKey::for_request`) is
        // also what the lifecycle's feedback lane resolves, so observed
        // outcomes are always attributed to the entry that served them
        let key = ModelKey::for_request(
            admitted.req,
            admitted.strategy,
            self.cfg.prediction_grid,
            self.cfg.transfer_epochs,
            self.ref_fps,
        );
        debug_assert_eq!(key.grid, grid.key, "model key must live on the resolved grid");
        let (models, built) = self.acquire_models(&admitted, &grid, key, attempt)?;
        let plane = self.resolve_plane(&grid, &models);
        let chosen = pareto_query(&plane.front, self.effective_budget_mw(admitted.req))?;
        // counted only once a response is certain (`finish` is
        // infallible): `stale_served` measures answers actually produced
        // from a condemned model, not failed attempts that touched one
        if let Some(lifecycle) = self.lifecycle {
            lifecycle.note_served(&key);
        }
        // profiling cost is charged to the request that actually led the
        // fit; coalesced/cached requests spent zero device-seconds
        let profiling_cost_s = if built { models.profiling_cost_s } else { 0.0 };
        Ok(self.finish(
            admitted.req,
            chosen,
            format!("{}(host)", admitted.strategy),
            profiling_cost_s,
            admitted.t0,
            Provenance::Primary,
        ))
    }

    /// The lock-free fast path: resolve the request entirely against the
    /// cache's immutable snapshot — two hash lookups (model pair by
    /// [`ModelKey`], plane by the pair's checkpoint fingerprints) and an
    /// O(log front) budget query, zero mutexes end to end. Returns
    /// `None` on any snapshot miss (cold key, in-flight build, snapshot
    /// lagging a just-published entry), in which case the caller runs
    /// the staged singleflight slow path; `Some(Err)` only for an
    /// infeasible budget, exactly the error the slow path would produce
    /// after the same lookups.
    ///
    /// Hit accounting matches the slow path — one model-cache hit and
    /// one plane-cache hit — so cache observability is path-independent.
    /// The model pair's circuit breaker is *not* consulted: a pair
    /// resident in the snapshot was, by construction, built or published
    /// successfully, which is the same evidence that closes a breaker on
    /// the slow path.
    fn try_snapshot_serve(&self, a: &Admitted<'_>) -> Option<Result<Response>> {
        if matches!(a.strategy, Strategy::BruteForce) {
            // brute force never touches the model/plane caches
            return None;
        }
        let snap = self.cache.read_snapshot();
        let key = ModelKey::for_request(
            a.req,
            a.strategy,
            self.cfg.prediction_grid,
            self.cfg.transfer_epochs,
            self.ref_fps,
        );
        let models = snap.models(&key)?;
        let pkey = PlaneKey { grid: key.grid, time_fp: models.time_fp, power_fp: models.power_fp };
        let plane = snap.plane(&pkey)?;
        self.metrics.model_cache_hits.fetch_add(1, Ordering::Relaxed);
        self.metrics.plane_cache_hits.fetch_add(1, Ordering::Relaxed);
        let chosen = match pareto_query(&plane.front, self.effective_budget_mw(a.req)) {
            Ok(p) => p,
            Err(e) => return Some(Err(e)),
        };
        if let Some(lifecycle) = self.lifecycle {
            lifecycle.note_served(&key);
        }
        // a snapshot hit spent zero simulated device-seconds profiling
        Some(Ok(self.finish(
            a.req,
            chosen,
            format!("{}(host)", a.strategy),
            0.0,
            a.t0,
            Provenance::Primary,
        )))
    }

    /// The graceful-degradation ladder, run by the serving loop once the
    /// primary path has failed for good (permanent error, or a transient
    /// one with the retry budget or deadline exhausted): a cheap Ridge
    /// fit over a freshly profiled mode handful, then a profiling-free
    /// NPE estimate. Failures that are the request's own fault —
    /// malformed budget, infeasible optimization — are *not* degraded:
    /// the error is the correct answer. If the whole ladder fails, the
    /// original (root-cause) error is returned, not the last rung's.
    pub fn degrade(&self, req: &Request, err: Error) -> Result<Response> {
        if matches!(err, Error::Usage(_) | Error::Optimization(_)) {
            return Err(err);
        }
        if let Ok(resp) = self.ridge_fallback(req) {
            return Ok(resp);
        }
        match self.npe_fallback(req) {
            Ok(resp) => Ok(resp),
            Err(_) => Err(err),
        }
    }

    /// Rung 1: profile a small mode handful under a salted seed stream
    /// and fit closed-form Ridge models for both targets — orders of
    /// magnitude cheaper than the NN path and immune to fit divergence,
    /// at the cost of linear-model accuracy.
    fn ridge_fallback(&self, req: &Request) -> Result<Response> {
        let t0 = Instant::now();
        let gkey = GridKey::for_request(req.device, self.cfg.prediction_grid, req.seed);
        let entry = self.cache.grid(gkey, || {
            GridEntry::new(prediction_grid(req.device, self.cfg.prediction_grid, req.seed))
        });
        let n = RIDGE_FALLBACK_MODES.min(entry.grid.len());
        let mut rng = Rng::new(req.seed ^ FALLBACK_SALT);
        let sample = entry.grid.sample(n, &mut rng);
        let mut sim = TrainerSim::new(req.device.spec(), req.workload, req.seed ^ FALLBACK_SALT);
        if let Some(inj) = &self.cfg.faults {
            // the rescue profiling run is a real device operation too —
            // it rolls its own (salted) fault key rather than replaying
            // or dodging the primary path's
            if inj.profiling_fails(req.seed ^ FALLBACK_SALT, 0) {
                return Err(Error::Profiling(format!(
                    "injected profiling failure during ridge fallback for request {}",
                    req.id
                )));
            }
            sim = sim.with_faults(inj.trainer_faults());
        }
        let mut profiler = Profiler::new(sim);
        let corpus = profiler.profile_modes(&sample)?;
        self.metrics.modes_profiled.fetch_add(corpus.len() as u64, Ordering::Relaxed);
        self.metrics.add_profiling_s(corpus.total_cost_s());
        let time = Ridge::fit(&corpus, Target::Time, RIDGE_FALLBACK_LAMBDA);
        let power = Ridge::fit(&corpus, Target::Power, RIDGE_FALLBACK_LAMBDA);
        let times = time.predict_modes(&entry.grid.modes);
        let powers = power.predict_modes(&entry.grid.modes);
        let points: Vec<Point> = entry
            .grid
            .modes
            .iter()
            .zip(times.iter().zip(&powers))
            .map(|(m, (&t, &p))| Point { mode: *m, time: t, power_mw: p })
            .collect();
        let chosen = ParetoFront::build(&points).optimize(self.effective_budget_mw(req))?;
        Ok(self.finish(
            req,
            chosen,
            "ridge(degraded)".into(),
            corpus.total_cost_s(),
            t0,
            Provenance::DegradedRidge,
        ))
    }

    /// Rung 2: no profiling at all — analytic NPE power estimates plus a
    /// clock-monotone time proxy. The proxy is not a calibrated time
    /// prediction (it only orders modes by effective compute rate), so
    /// `predicted_time_ms` is indicative; the power budget is still
    /// honored through the NPE axis.
    fn npe_fallback(&self, req: &Request) -> Result<Response> {
        let t0 = Instant::now();
        let gkey = GridKey::for_request(req.device, self.cfg.prediction_grid, req.seed);
        let entry = self.cache.grid(gkey, || {
            GridEntry::new(prediction_grid(req.device, self.cfg.prediction_grid, req.seed))
        });
        let spec = req.device.spec();
        let points: Vec<Point> = entry
            .grid
            .modes
            .iter()
            .map(|m| Point {
                mode: *m,
                time: npe_time_proxy_ms(m),
                power_mw: npe_estimate_mw(spec, m),
            })
            .collect();
        let chosen = ParetoFront::build(&points).optimize(self.effective_budget_mw(req))?;
        Ok(self.finish(req, chosen, "npe(degraded)".into(), 0.0, t0, Provenance::DegradedNpe))
    }

    /// The budget the Pareto query actually sees: the request's, capped
    /// at the thermal guard's sustainable ceiling.
    fn effective_budget_mw(&self, req: &Request) -> f64 {
        let budget_mw = req.power_budget_w * 1000.0;
        match self.thermal {
            Some(guard) => budget_mw.min(guard.ceiling_mw()),
            None => budget_mw,
        }
    }

    /// The response tail owning the cross-cutting serving concerns: the
    /// thermal guard advances one slice at the chosen mode's *true* draw
    /// (prediction error is exactly how a clamped budget can still
    /// overshoot the ceiling), and degraded provenance is counted.
    fn finish(
        &self,
        req: &Request,
        chosen: Point,
        strategy: String,
        profiling_cost_s: f64,
        t0: Instant,
        provenance: Provenance,
    ) -> Response {
        let throttled = match self.thermal {
            Some(guard) => {
                let sim = TrainerSim::new(req.device.spec(), req.workload, req.seed ^ 0xfeed);
                guard.advance(sim.true_power_mw(&chosen.mode), self.metrics)
            }
            None => false,
        };
        if provenance.is_degraded() {
            self.metrics.degraded_served.fetch_add(1, Ordering::Relaxed);
        }
        respond(req, chosen, strategy, profiling_cost_s, self.metrics, t0, provenance, throttled)
    }

    /// Stage 1 — admission: count the arrival (first attempts only —
    /// retries are not new requests), reject malformed requests before
    /// any profiling or fitting work is spent, resolve the scenario's
    /// strategy (paper Table 1).
    fn admit<'r>(&self, req: &'r Request, attempt: u32) -> Result<Admitted<'r>> {
        let t0 = Instant::now();
        admit_request(req, self.metrics, attempt == 0)?;
        Ok(Admitted { req, strategy: Strategy::for_scenario(req.scenario), t0 })
    }

    /// Stage 2 — grid resolution: the device grid + shared feature
    /// matrix, resident in the cache (singleflight on first touch).
    fn resolve_grid(&self, a: &Admitted<'_>) -> ResolvedGrid {
        let key = GridKey::for_request(a.req.device, self.cfg.prediction_grid, a.req.seed);
        let entry = self.cache.grid(key, || {
            GridEntry::new(prediction_grid(a.req.device, self.cfg.prediction_grid, a.req.seed))
        });
        ResolvedGrid { key, entry }
    }

    /// Stage 3 — model acquisition, singleflight: a burst of identical
    /// requests costs exactly one online-profiling run + host fit pair;
    /// concurrent requesters of the same [`ModelKey`] block on the
    /// in-flight fit instead of duplicating it.
    fn acquire_models(
        &self,
        a: &Admitted<'_>,
        g: &ResolvedGrid,
        key: ModelKey,
        attempt: u32,
    ) -> Result<(Arc<HostModels>, bool)> {
        self.cache.models(key, self.metrics, || {
            train_host_models(
                &g.entry.grid, self.reference, self.cfg, self.metrics, a.req, a.strategy, attempt,
            )
        })
    }

    /// Stage 4 — plane resolution: both raw-unit prediction planes and
    /// the Pareto front over them, resident per (grid, model-pair).
    fn resolve_plane(&self, g: &ResolvedGrid, models: &HostModels) -> Arc<ServePlane> {
        let key = PlaneKey { grid: g.key, time_fp: models.time_fp, power_fp: models.power_fp };
        self.cache.plane(key, self.metrics, || {
            build_plane(Arc::clone(&g.entry), &models.time, &models.power)
        })
    }

    /// The brute-force lane (one-time training): skips the model/plane
    /// stages and profiles the whole grid for the observed optimum. The
    /// responses it produces stay on the primary provenance, but its
    /// profiling run is fault-injectable and its budget thermally capped
    /// like any other lane's.
    fn brute_force(&self, a: &Admitted<'_>, g: &ResolvedGrid, attempt: u32) -> Result<Response> {
        let resp = brute_force_response(
            a.req,
            &g.entry.grid.modes,
            self.metrics,
            a.t0,
            self.effective_budget_mw(a.req),
            self.cfg.faults.as_deref(),
            attempt,
        )?;
        if let Some(guard) = self.thermal {
            let sim = TrainerSim::new(a.req.device.spec(), a.req.workload, a.req.seed ^ 0xfeed);
            guard.advance(sim.true_power_mw(&resp.chosen_mode), self.metrics);
        }
        Ok(resp)
    }
}

/// Stage 5 — the budget query: fastest predicted mode within the
/// (thermally capped) budget, an O(log front) binary search over the
/// cached front.
fn pareto_query(front: &ParetoFront, budget_mw: f64) -> Result<Point> {
    front.optimize(budget_mw)
}

/// NPE-rung time proxy: inverse effective compute rate over the three
/// clock domains, GPU-weighted like the training workloads themselves.
/// Deliberately uncalibrated — the Pareto front only needs it to *order*
/// modes so faster in-budget modes win.
fn npe_time_proxy_ms(pm: &PowerMode) -> f64 {
    let gpu = pm.gpu_khz as f64;
    let mem = pm.mem_khz as f64;
    let cpu = pm.cpu_khz as f64 * pm.cores as f64;
    1e9 * (0.6 / gpu + 0.25 / mem + 0.15 / cpu)
}

/// The admission check shared by the host pipeline and the xla lane:
/// count the arrival (when `count_arrival`; retry attempts pass false),
/// reject malformed budgets before any profiling or fitting work is
/// spent. Both lanes therefore classify and count rejections identically.
fn admit_request(req: &Request, metrics: &Metrics, count_arrival: bool) -> Result<()> {
    if count_arrival {
        metrics.requests_received.fetch_add(1, Ordering::Relaxed);
    }
    if !req.power_budget_w.is_finite() || req.power_budget_w <= 0.0 {
        metrics.admission_rejected.fetch_add(1, Ordering::Relaxed);
        return Err(Error::Usage(format!(
            "request {} rejected at admission: power budget must be positive and finite, got {}",
            req.id, req.power_budget_w
        )));
    }
    Ok(())
}

/// One-shot convenience wrapper over [`HostPipeline`]: serve a single
/// request end-to-end without the PJRT runtime — the default build's
/// native path. Long-lived services construct one [`HostPipeline`] per
/// worker instead so reference fingerprints hash once, not per call.
pub fn handle_request_host(
    cache: &PlaneCache,
    reference: &ReferenceModels,
    cfg: &CoordinatorConfig,
    metrics: &Metrics,
    req: &Request,
) -> Result<Response> {
    HostPipeline::new(cache, reference, cfg, metrics).handle(req)
}

/// Fleet hook — build the host model pair for `req` outside any
/// coordinator domain, returning the [`ModelKey`] it must be published
/// under. The fleet layer runs this **once per (device kind, workload)**
/// and pushes the result into the owning shard's versioned Ready slot
/// via [`PlaneCache::publish_models`], so no shard ever refits a pair
/// another shard (or the fleet itself) already paid for. Identical key
/// derivation and fit path as the in-domain cache-miss lane, so a pair
/// built here is bit-identical to one a shard would have built itself.
pub fn fit_models_for_request(
    reference: &ReferenceModels,
    cfg: &CoordinatorConfig,
    metrics: &Metrics,
    req: &Request,
) -> Result<(ModelKey, HostModels)> {
    let strategy = Strategy::for_scenario(req.scenario);
    if let Strategy::BruteForce = strategy {
        return Err(Error::Usage(format!(
            "request {}: brute force trains no models to pre-publish",
            req.id
        )));
    }
    let grid = prediction_grid(req.device, cfg.prediction_grid, req.seed);
    let key = ModelKey::for_request(
        req,
        strategy,
        cfg.prediction_grid,
        cfg.transfer_epochs,
        reference.fingerprints(),
    );
    let models = train_host_models(&grid, reference, cfg, metrics, req, strategy, 0)?;
    Ok((key, models))
}

/// The model-cache-miss work: online profiling of the strategy's mode
/// sample on the simulated target, then two host fits (transfer for
/// PowerTrain, from-scratch for NnProfiled). Deterministic in the
/// [`ModelKey`] inputs — same seed, workload, grid, references and
/// epochs reproduce bit-identical checkpoints. Scripted faults fire
/// here, in strict order: transient profiling failure, permanent fit
/// failure, transient fit failure, then (post-fit) checkpoint
/// corruption caught by the integrity check before anything is cached.
fn train_host_models(
    grid: &crate::device::PowerModeGrid,
    reference: &ReferenceModels,
    cfg: &CoordinatorConfig,
    metrics: &Metrics,
    req: &Request,
    strategy: Strategy,
    attempt: u32,
) -> Result<HostModels> {
    if let Some(inj) = &cfg.faults {
        if inj.profiling_fails(req.seed, attempt) {
            return Err(Error::Profiling(format!(
                "injected transient profiling failure for request {} (attempt {attempt})",
                req.id
            )));
        }
        if inj.fit_fails_permanently(req.seed) {
            return Err(Error::Artifact(format!(
                "injected permanent fit failure for model seed {}",
                req.seed
            )));
        }
        if inj.fit_fails(req.seed, attempt) {
            return Err(Error::Training(format!(
                "injected transient fit failure for request {} (attempt {attempt})",
                req.id
            )));
        }
    }
    let n_profile = strategy.profiling_modes(grid.len()).min(grid.len());
    let mut rng = Rng::new(req.seed);
    let mut sim = TrainerSim::new(req.device.spec(), req.workload, req.seed);
    if let Some(inj) = &cfg.faults {
        sim = sim.with_faults(inj.trainer_faults());
    }
    let sample = grid.sample(n_profile, &mut rng);
    let mut profiler = Profiler::new(sim);
    let corpus = profiler.profile_modes(&sample)?;
    metrics.modes_profiled.fetch_add(corpus.len() as u64, Ordering::Relaxed);
    metrics.add_profiling_s(corpus.total_cost_s());

    let base = TrainConfig { epochs: cfg.transfer_epochs, seed: req.seed, ..Default::default() };
    let (time, tlog, power, plog) = match strategy {
        Strategy::PowerTrain(_) => {
            let tcfg = TransferConfig { base, ..Default::default() };
            let (t, tl) = transfer_host(&reference.time, &corpus, Target::Time, &tcfg)?;
            let (p, pl) = transfer_host(&reference.power, &corpus, Target::Power, &tcfg)?;
            (t, tl, p, pl)
        }
        Strategy::NnProfiled(_) => {
            let trainer = HostTrainer::new();
            let (t, tl) = trainer.train(&corpus, Target::Time, &base)?;
            let (p, pl) = trainer.train(&corpus, Target::Power, &base)?;
            (t, tl, p, pl)
        }
        Strategy::BruteForce => unreachable!("brute force never trains models"),
    };
    metrics.host_fits.fetch_add(2, Ordering::Relaxed);
    // the fit-time validation MAPEs ride along as the drift monitor's
    // baseline: serving-time feedback is judged against the accuracy the
    // pair actually shipped with
    let mut models = HostModels::new(time, power, corpus.total_cost_s())
        .with_validation(tlog.best_val_mape(), plog.best_val_mape());
    if let Some(inj) = &cfg.faults {
        if inj.corrupts_checkpoint(req.seed) {
            // scripted bit-rot between fit and publish: the integrity
            // check must catch it here, before the pair can be cached
            models.time_fp ^= 0xbad_c0de;
            models.verify_integrity()?;
        }
    }
    Ok(models)
}

/// The cold-path work a plane-cache miss pays once per (grid, model-pair):
/// two affine-folded engine builds, two forward passes over the grid's
/// shared feature matrix, one Pareto sort. `time`/`power` are whichever
/// checkpoints the plane is keyed by — transferred per-workload models on
/// the host path, reference models elsewhere.
fn build_plane(grid: Arc<GridEntry>, time: &Checkpoint, power: &Checkpoint) -> ServePlane {
    let (times, powers) = PlanePredictor::new(time, power).predict_features(&grid.features);
    let points: Vec<Point> = grid
        .grid
        .modes
        .iter()
        .zip(times.iter().zip(&powers))
        .map(|(m, (&t, &p))| Point { mode: *m, time: t, power_mw: p })
        .collect();
    let front = ParetoFront::build(&points);
    ServePlane { grid, times, powers, front }
}

/// Stage 6 — the response tail shared by every lane: observable ground
/// truth at the chosen mode (for reporting/validation), latency +
/// completion metrics. While the device throttles, the ground truth
/// itself shifts — clamped clocks stretch minibatches by
/// `1/THROTTLE_FACTOR` and cut draw by `THROTTLE_FACTOR` — which the
/// lifecycle's feedback lane sees as drift.
#[allow(clippy::too_many_arguments)]
fn respond(
    req: &Request,
    chosen: Point,
    strategy: String,
    profiling_cost_s: f64,
    metrics: &Metrics,
    t0: Instant,
    provenance: Provenance,
    throttled: bool,
) -> Response {
    let sim = TrainerSim::new(req.device.spec(), req.workload, req.seed ^ 0xfeed);
    let mut obs_t = sim.true_minibatch_ms(&chosen.mode);
    let mut obs_p = sim.true_power_mw(&chosen.mode);
    if throttled {
        obs_t /= THROTTLE_FACTOR;
        obs_p *= THROTTLE_FACTOR;
    }

    let latency_ms = t0.elapsed().as_secs_f64() * 1000.0;
    metrics.observe_latency_ms(latency_ms);
    metrics.record_completion(req.id);

    Response {
        id: req.id,
        strategy,
        provenance,
        chosen_mode: chosen.mode,
        predicted_time_ms: chosen.time,
        predicted_power_w: chosen.power_mw / 1000.0,
        observed_time_ms: obs_t,
        observed_power_w: obs_p / 1000.0,
        profiling_cost_s,
        latency_ms,
        node: req.node,
    }
}

/// Brute-force tail shared by the host lane and the xla path: profile
/// every mode, pick the observed in-budget optimum. `budget_mw` is the
/// caller's effective (possibly thermally capped) budget.
fn brute_force_response(
    req: &Request,
    modes: &[PowerMode],
    metrics: &Metrics,
    t0: Instant,
    budget_mw: f64,
    faults: Option<&FaultInjector>,
    attempt: u32,
) -> Result<Response> {
    let mut sim = TrainerSim::new(req.device.spec(), req.workload, req.seed);
    if let Some(inj) = faults {
        if inj.profiling_fails(req.seed, attempt) {
            return Err(Error::Profiling(format!(
                "injected transient profiling failure for request {} (attempt {attempt})",
                req.id
            )));
        }
        sim = sim.with_faults(inj.trainer_faults());
    }
    let mut profiler = Profiler::new(sim);
    let corpus = profiler.profile_modes(modes)?;
    metrics.modes_profiled.fetch_add(corpus.len() as u64, Ordering::Relaxed);
    metrics.add_profiling_s(corpus.total_cost_s());
    let points: Vec<Point> = corpus
        .records()
        .iter()
        .map(|r| Point { mode: r.mode, time: r.time_ms, power_mw: r.power_mw })
        .collect();
    let front = ParetoFront::build(&points);
    let chosen = front.optimize(budget_mw)?;
    let latency_ms = t0.elapsed().as_secs_f64() * 1000.0;
    metrics.observe_latency_ms(latency_ms);
    metrics.record_completion(req.id);
    Ok(Response {
        id: req.id,
        strategy: "brute-force".into(),
        provenance: Provenance::Primary,
        chosen_mode: chosen.mode,
        predicted_time_ms: chosen.time,
        predicted_power_w: chosen.power_mw / 1000.0,
        observed_time_ms: chosen.time,
        observed_power_w: chosen.power_mw / 1000.0,
        profiling_cost_s: corpus.total_cost_s(),
        latency_ms,
        node: req.node,
    })
}

/// Serve one request end-to-end on a given runtime — the xla lane the
/// artifact-backed workers run. Uses the same admission semantics as the
/// host pipeline but predicts through the AOT artifacts.
#[cfg(feature = "xla")]
pub fn handle_request(
    rt: &Runtime,
    reference: &ReferenceModels,
    cfg: &CoordinatorConfig,
    metrics: &Metrics,
    req: &Request,
) -> Result<Response> {
    let t0 = Instant::now();
    admit_request(req, metrics, true)?;

    let strategy = Strategy::for_scenario(req.scenario);

    // 1. online profiling of a small random mode sample on the target
    let grid = prediction_grid(req.device, cfg.prediction_grid, req.seed);
    if let Strategy::BruteForce = strategy {
        return brute_force_response(
            req,
            &grid.modes,
            metrics,
            t0,
            req.power_budget_w * 1000.0,
            cfg.faults.as_deref(),
            0,
        );
    }
    let n_profile = strategy.profiling_modes(grid.len()).min(grid.len());
    let mut rng = Rng::new(req.seed);
    let sample = grid.sample(n_profile, &mut rng);
    let mut profiler = Profiler::new(TrainerSim::new(req.device.spec(), req.workload, req.seed));
    let corpus = profiler.profile_modes(&sample)?;
    metrics.modes_profiled.fetch_add(corpus.len() as u64, Ordering::Relaxed);
    metrics.add_profiling_s(corpus.total_cost_s());

    // 2. obtain time/power prediction models per the scenario's strategy
    let (time_ckpt, power_ckpt, strat_name) = match strategy {
        Strategy::PowerTrain(_) => {
            let tcfg = TransferConfig {
                base: TrainConfig {
                    epochs: cfg.transfer_epochs,
                    seed: req.seed,
                    ..Default::default()
                },
                ..Default::default()
            };
            let (t, _) = transfer(rt, &reference.time, &corpus, Target::Time, &tcfg)?;
            let (p, _) = transfer(rt, &reference.power, &corpus, Target::Power, &tcfg)?;
            (t, p, strategy.to_string())
        }
        Strategy::NnProfiled(_) => {
            let trainer = Trainer::new(rt);
            let ncfg = TrainConfig {
                epochs: cfg.transfer_epochs,
                seed: req.seed,
                ..Default::default()
            };
            let (t, _) = trainer.train(&corpus, Target::Time, &ncfg)?;
            let (p, _) = trainer.train(&corpus, Target::Power, &ncfg)?;
            (t, p, strategy.to_string())
        }
        Strategy::BruteForce => unreachable!("handled above"),
    };

    // 3. predict the full grid through the AOT artifacts and build the
    //    predicted Pareto front (paper Fig 10)
    let times = crate::predict::predict_modes(rt, &time_ckpt, &grid.modes)?;
    let powers = crate::predict::predict_modes(rt, &power_ckpt, &grid.modes)?;
    finish_predicted(req, &grid, &times, &powers, strat_name, corpus.total_cost_s(), metrics, t0)
}

/// Shared tail of the per-request predicted path (xla transfer serving):
/// Pareto build, budget optimization, post-hoc observation, metrics.
/// The host pipeline goes through the plane cache instead and only
/// shares [`respond`].
#[cfg(feature = "xla")]
#[allow(clippy::too_many_arguments)]
fn finish_predicted(
    req: &Request,
    grid: &PowerModeGrid,
    times: &[f64],
    powers: &[f64],
    strategy: String,
    profiling_cost_s: f64,
    metrics: &Metrics,
    t0: Instant,
) -> Result<Response> {
    let points: Vec<Point> = grid
        .modes
        .iter()
        .zip(times.iter().zip(powers))
        .map(|(m, (&t, &p))| Point { mode: *m, time: t, power_mw: p })
        .collect();
    let front = ParetoFront::build(&points);

    // optimize: fastest predicted mode within the budget
    let chosen = front.optimize(req.power_budget_w * 1000.0)?;
    Ok(respond(
        req,
        chosen,
        strategy,
        profiling_cost_s,
        metrics,
        t0,
        Provenance::Primary,
        false,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::test_support::{host_cfg, host_reference};
    use crate::coordinator::Scenario;
    use crate::device::DeviceKind;
    use crate::sim::FaultPlan;
    use crate::workload::Workload;

    fn chaos_cfg(grid: usize, plan: FaultPlan) -> CoordinatorConfig {
        let mut cfg = host_cfg(grid);
        cfg.faults = Some(Arc::new(FaultInjector::new(plan)));
        cfg
    }

    fn federated_req(id: u64, seed: u64) -> Request {
        Request {
            id,
            device: DeviceKind::OrinAgx,
            workload: Workload::mobilenet(),
            power_budget_w: 1e6,
            scenario: Scenario::FederatedLearning,
            affinity: None,
            node: None,
            seed,
        }
    }

    #[test]
    fn host_powertrain_request_runs_the_full_loop() {
        let reference = host_reference();
        let cfg = host_cfg(300);
        let metrics = Metrics::new();
        let cache = PlaneCache::new();
        let req = Request {
            id: 9,
            device: DeviceKind::OrinAgx,
            workload: Workload::mobilenet(),
            power_budget_w: 1e6, // any front point qualifies
            scenario: Scenario::FederatedLearning,
            affinity: None,
            node: None,
            seed: 5,
        };
        let resp = handle_request_host(&cache, &reference, &cfg, &metrics, &req).unwrap();
        // the paper loop actually ran: 50 modes profiled, both targets
        // transfer-learned on host, cost accounted on the request
        assert_eq!(resp.strategy, "powertrain-50(host)");
        assert!(resp.profiling_cost_s > 0.0);
        assert_eq!(metrics.modes_profiled.load(Ordering::Relaxed), 50);
        assert_eq!(metrics.host_fits.load(Ordering::Relaxed), 2);
        assert_eq!(metrics.model_cache_misses.load(Ordering::Relaxed), 1);
        resp.chosen_mode.validate(DeviceKind::OrinAgx.spec()).unwrap();
        assert_eq!(metrics.requests_completed.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.plane_cache_misses.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.plane_cache_hits.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn nn_profiled_strategy_trains_from_scratch_on_host() {
        let reference = host_reference();
        let cfg = host_cfg(200);
        let metrics = Metrics::new();
        let cache = PlaneCache::new();
        let req = Request {
            id: 1,
            device: DeviceKind::OrinAgx,
            workload: Workload::lstm(),
            power_budget_w: 1e6,
            scenario: Scenario::FineTuning, // → NnProfiled(100)
            affinity: None,
            node: None,
            seed: 6,
        };
        let resp = handle_request_host(&cache, &reference, &cfg, &metrics, &req).unwrap();
        assert_eq!(resp.strategy, "nn-100(host)");
        assert_eq!(metrics.modes_profiled.load(Ordering::Relaxed), 100);
        assert_eq!(metrics.host_fits.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn admission_rejects_malformed_budgets_before_any_work() {
        let reference = host_reference();
        let cfg = host_cfg(100);
        let metrics = Metrics::new();
        let cache = PlaneCache::new();
        for (id, bad_budget) in [(0u64, -5.0), (1, 0.0), (2, f64::NAN), (3, f64::INFINITY)] {
            let req = Request {
                id,
                device: DeviceKind::OrinAgx,
                workload: Workload::mobilenet(),
                power_budget_w: bad_budget,
                scenario: Scenario::FederatedLearning,
                affinity: None,
                node: None,
                seed: 5,
            };
            let err = handle_request_host(&cache, &reference, &cfg, &metrics, &req).unwrap_err();
            assert!(matches!(err, Error::Usage(_)), "budget {bad_budget}: {err}");
        }
        assert_eq!(metrics.admission_rejected.load(Ordering::Relaxed), 4);
        // rejected before profiling/fitting: no work was spent
        assert_eq!(metrics.modes_profiled.load(Ordering::Relaxed), 0);
        assert_eq!(metrics.host_fits.load(Ordering::Relaxed), 0);
        assert_eq!(cache.sizes(), (0, 0, 0));
    }

    #[test]
    fn cache_hit_is_bit_identical_and_counted() {
        let reference = host_reference();
        let cfg = host_cfg(300);
        let metrics = Metrics::new();
        let req = |id: u64| Request {
            id,
            device: DeviceKind::OrinAgx,
            workload: Workload::mobilenet(),
            power_budget_w: 1e6,
            scenario: Scenario::FederatedLearning,
            affinity: None,
            node: None,
            seed: 5,
        };
        // uncached baseline on its own fresh cache
        let fresh = PlaneCache::new();
        let uncached = handle_request_host(&fresh, &reference, &cfg, &metrics, &req(0)).unwrap();
        // cold miss then hit on a shared cache
        let cache = PlaneCache::new();
        let cold = handle_request_host(&cache, &reference, &cfg, &metrics, &req(1)).unwrap();
        let hit = handle_request_host(&cache, &reference, &cfg, &metrics, &req(2)).unwrap();
        assert_eq!(metrics.plane_cache_misses.load(Ordering::Relaxed), 2);
        assert_eq!(metrics.plane_cache_hits.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.model_cache_misses.load(Ordering::Relaxed), 2);
        assert_eq!(metrics.model_cache_hits.load(Ordering::Relaxed), 1);
        // host fits are deterministic per key, so a cached answer is
        // byte-identical to the uncached one in every model-derived field
        // (id and wall-clock latency are per-request by construction)
        for r in [&cold, &hit] {
            assert_eq!(r.chosen_mode, uncached.chosen_mode);
            assert_eq!(r.strategy, uncached.strategy);
            assert_eq!(r.predicted_time_ms.to_bits(), uncached.predicted_time_ms.to_bits());
            assert_eq!(r.predicted_power_w.to_bits(), uncached.predicted_power_w.to_bits());
            assert_eq!(r.observed_time_ms.to_bits(), uncached.observed_time_ms.to_bits());
            assert_eq!(r.observed_power_w.to_bits(), uncached.observed_power_w.to_bits());
        }
        // profiling happened exactly once per *fresh* model build; the
        // cache hit spent zero simulated device-seconds
        assert_eq!(cold.profiling_cost_s.to_bits(), uncached.profiling_cost_s.to_bits());
        assert!(cold.profiling_cost_s > 0.0);
        assert_eq!(hit.profiling_cost_s, 0.0);
    }

    #[test]
    fn budget_only_requests_share_one_plane_and_one_fit() {
        let reference = host_reference();
        let cfg = host_cfg(400);
        let metrics = Metrics::new();
        let cache = PlaneCache::new();
        for (i, budget_w) in [1e6, 40.0, 25.0, 60.0, 1e6].iter().enumerate() {
            let req = Request {
                id: i as u64,
                device: DeviceKind::OrinAgx,
                workload: Workload::lstm(),
                power_budget_w: *budget_w,
                scenario: Scenario::ContinuousLearning,
                affinity: None,
                node: None,
                seed: 8,
            };
            match handle_request_host(&cache, &reference, &cfg, &metrics, &req) {
                Ok(resp) => assert!(
                    resp.predicted_power_w <= budget_w + 1e-9,
                    "budget {budget_w} W violated: {}",
                    resp.predicted_power_w
                ),
                // an infeasible budget is still answered from the cached
                // plane (the lookup precedes the optimize)
                Err(Error::Optimization(_)) => {}
                Err(e) => panic!("unexpected error: {e}"),
            }
        }
        // one profiling run + one transfer pair + one plane build; four
        // O(log front) answers
        assert_eq!(metrics.model_cache_misses.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.model_cache_hits.load(Ordering::Relaxed), 4);
        assert_eq!(metrics.host_fits.load(Ordering::Relaxed), 2);
        assert_eq!(metrics.modes_profiled.load(Ordering::Relaxed), 50);
        assert_eq!(metrics.plane_cache_misses.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.plane_cache_hits.load(Ordering::Relaxed), 4);
        assert_eq!(cache.sizes(), (1, 1, 1));
    }

    #[test]
    fn distinct_workloads_get_distinct_transferred_planes() {
        // transferred checkpoints flow through the plane cache by content
        // fingerprint, so two workloads on the same grid coexist — planes
        // cache alongside each other instead of colliding
        let reference = host_reference();
        let cfg = host_cfg(250);
        let metrics = Metrics::new();
        let cache = PlaneCache::new();
        let req = |id: u64, wl: Workload| Request {
            id,
            device: DeviceKind::OrinAgx,
            workload: wl,
            power_budget_w: 1e6,
            scenario: Scenario::ContinuousLearning,
            affinity: None,
            node: None,
            seed: 12,
        };
        let a = handle_request_host(&cache, &reference, &cfg, &metrics, &req(0, Workload::lstm()))
            .unwrap();
        let b =
            handle_request_host(&cache, &reference, &cfg, &metrics, &req(1, Workload::bert()))
                .unwrap();
        // one shared grid, two model pairs, two planes
        assert_eq!(cache.sizes(), (1, 2, 2));
        assert_eq!(metrics.model_cache_misses.load(Ordering::Relaxed), 2);
        // per-workload models genuinely differ
        assert!(
            a.predicted_time_ms.to_bits() != b.predicted_time_ms.to_bits()
                || a.predicted_power_w.to_bits() != b.predicted_power_w.to_bits(),
            "two workloads produced identical planes"
        );
        // and re-asking workload A hits both caches
        handle_request_host(&cache, &reference, &cfg, &metrics, &req(2, Workload::lstm()))
            .unwrap();
        assert_eq!(metrics.model_cache_hits.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.plane_cache_hits.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn transient_fit_fault_clears_once_the_retry_outlasts_its_streak() {
        let reference = host_reference();
        let cfg = chaos_cfg(300, FaultPlan { fit_fail_pct: 1.0, fit_streak: 2, ..Default::default() });
        let metrics = Metrics::new();
        let cache = PlaneCache::new();
        let pipe = HostPipeline::new(&cache, &reference, &cfg, &metrics);
        let req = federated_req(1, 5);
        for attempt in 0..2 {
            let err = pipe.handle_attempt(&req, attempt).unwrap_err();
            assert!(matches!(err, Error::Training(_)), "attempt {attempt}: {err}");
            assert!(err.is_transient());
        }
        let resp = pipe.handle_attempt(&req, 2).unwrap();
        assert_eq!(resp.provenance, Provenance::Primary);
        assert_eq!(resp.strategy, "powertrain-50(host)");
        // retried attempts are the same request: one arrival, not three
        assert_eq!(metrics.requests_received.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn permanent_fit_failure_degrades_to_the_ridge_rung() {
        let reference = host_reference();
        let cfg = chaos_cfg(300, FaultPlan { permanent_fit_seeds: vec![5], ..Default::default() });
        let metrics = Metrics::new();
        let cache = PlaneCache::new();
        let pipe = HostPipeline::new(&cache, &reference, &cfg, &metrics);
        let req = federated_req(2, 5);
        // never clears, whatever the attempt
        for attempt in [0, 1, 7] {
            let err = pipe.handle_attempt(&req, attempt).unwrap_err();
            assert!(matches!(err, Error::Artifact(_)), "attempt {attempt}: {err}");
            assert!(!err.is_transient());
        }
        let err = pipe.handle(&req).unwrap_err();
        let resp = pipe.degrade(&req, err).unwrap();
        assert_eq!(resp.provenance, Provenance::DegradedRidge);
        assert_eq!(resp.strategy, "ridge(degraded)");
        assert!(resp.predicted_power_w <= req.power_budget_w);
        assert_eq!(metrics.degraded_served.load(Ordering::Relaxed), 1);
        // the ridge rung profiled its small handful, nothing NN-sized
        assert_eq!(metrics.modes_profiled.load(Ordering::Relaxed), RIDGE_FALLBACK_MODES as u64);
        assert_eq!(metrics.host_fits.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn unbroken_profiling_outage_falls_through_to_the_npe_rung() {
        let reference = host_reference();
        let cfg = chaos_cfg(
            300,
            FaultPlan { profiling_fail_pct: 1.0, profiling_streak: 1_000_000, ..Default::default() },
        );
        let metrics = Metrics::new();
        let cache = PlaneCache::new();
        let pipe = HostPipeline::new(&cache, &reference, &cfg, &metrics);
        let req = federated_req(3, 5);
        let err = pipe.handle(&req).unwrap_err();
        assert!(matches!(err, Error::Profiling(_)), "{err}");
        let resp = pipe.degrade(&req, err).unwrap();
        assert_eq!(resp.provenance, Provenance::DegradedNpe);
        assert_eq!(resp.strategy, "npe(degraded)");
        assert!(resp.predicted_power_w <= req.power_budget_w);
        // the analytic rung touched the device zero times
        assert_eq!(metrics.modes_profiled.load(Ordering::Relaxed), 0);
        assert_eq!(metrics.degraded_served.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn degrade_refuses_to_mask_usage_and_optimization_errors() {
        let reference = host_reference();
        let cfg = host_cfg(200);
        let metrics = Metrics::new();
        let cache = PlaneCache::new();
        let pipe = HostPipeline::new(&cache, &reference, &cfg, &metrics);
        let req = federated_req(4, 5);
        let err = pipe.degrade(&req, Error::Usage("bad budget".into())).unwrap_err();
        assert!(matches!(err, Error::Usage(_)));
        let err = pipe.degrade(&req, Error::Optimization("infeasible".into())).unwrap_err();
        assert!(matches!(err, Error::Optimization(_)));
        assert_eq!(metrics.degraded_served.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn corrupted_checkpoint_is_rejected_and_never_cached() {
        let reference = host_reference();
        let cfg = chaos_cfg(300, FaultPlan { corrupt_fit_seeds: vec![5], ..Default::default() });
        let metrics = Metrics::new();
        let cache = PlaneCache::new();
        let pipe = HostPipeline::new(&cache, &reference, &cfg, &metrics);
        let err = pipe.handle(&federated_req(5, 5)).unwrap_err();
        let msg = err.to_string();
        assert!(matches!(err, Error::Artifact(_)), "{err}");
        assert!(msg.contains("fingerprint mismatch"), "{msg}");
        // the fits ran, but the corrupted pair must not be published:
        // grid cached, model slot evicted, no plane
        assert_eq!(metrics.host_fits.load(Ordering::Relaxed), 2);
        assert_eq!(cache.sizes(), (1, 0, 0));
    }

    #[test]
    fn thermal_guard_caps_the_budget_one_slice_after_fan_loss() {
        let reference = host_reference();
        let cfg = host_cfg(300);
        let metrics = Metrics::new();
        let cache = PlaneCache::new();
        // fan dies at t=0 and never recovers; long slices park the die
        // near steady state so the physics is unambiguous
        let inj = Arc::new(FaultInjector::new(FaultPlan {
            fan_off_s: vec![(0.0, f64::MAX)],
            ..Default::default()
        }));
        let guard = ThermalGuard::new(ThermalConfig { slice_s: 120.0 }, Some(inj));
        let pipe = HostPipeline::new(&cache, &reference, &cfg, &metrics).with_thermal(&guard);

        // slice 1: the guard still believes the fan is running (it learns
        // from telemetry, i.e. at advance time), so the full-speed mode is
        // served — and overdraws the fan-off envelope
        let first = pipe.handle(&federated_req(6, 5)).unwrap();
        assert!(guard.throttled(), "full-speed slice with the fan off must trip the throttle");
        assert_eq!(metrics.thermal_throttle_events.load(Ordering::Relaxed), 1);
        // throttled ground truth is dilated relative to the clean sim
        let clean = TrainerSim::new(DeviceKind::OrinAgx.spec(), Workload::mobilenet(), 5 ^ 0xfeed)
            .true_minibatch_ms(&first.chosen_mode);
        assert!((first.observed_time_ms * THROTTLE_FACTOR - clean).abs() < 1e-9);

        // slice 2 onward: the ceiling is now the fan-off sustainable
        // envelope, and the Pareto query respects it
        let second = pipe.handle(&federated_req(7, 5)).unwrap();
        let ceiling_w = ThermalModel { fan_max: false, ..Default::default() }.max_sustainable_mw()
            / 1000.0;
        assert!(
            second.predicted_power_w <= ceiling_w + 1e-9,
            "{} W exceeds fan-off ceiling {} W",
            second.predicted_power_w,
            ceiling_w
        );
        assert!(first.predicted_power_w > second.predicted_power_w);
    }
}
