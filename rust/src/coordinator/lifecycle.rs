//! Model lifecycle: the serve → observe → refit loop.
//!
//! A transferred model pair is only as good as the workload it was fit
//! on stays representative. The paper's continuous-learning and
//! federated scenarios (Table 1) deliver a *stream* of training rounds
//! whose executed outcomes are exactly the ground truth needed to detect
//! when a cached model has drifted — minibatch time/power distributions
//! shift with thermal state and workload phase (Prashanthi et al.,
//! "Characterizing the Performance of Accelerated Jetson Edge Devices
//! for Training DNN Models"). This module closes that loop:
//!
//! 1. **Feedback lane** — callers report observed `(mode, time_ms,
//!    power_mw)` outcomes of executed rounds ([`Feedback`], surfaced as
//!    [`Submitter::report`](crate::coordinator::Submitter::report)).
//!    Each observation is attributed to the [`ModelKey`] that served the
//!    round (the same derivation the pipeline uses, so attribution can't
//!    drift), banked into a bounded per-model
//!    [`RollingCorpus`] (recency window + reservoir), and scored
//!    against the resident model's predictions.
//! 2. **Drift monitor** — a per-model [`DriftMonitor`] tracks the
//!    rolling raw-unit MAPE of cached predictions vs. observations with
//!    hysteresis: it trips `Fresh/Suspect → Stale` only when the rolling
//!    MAPE *strictly exceeds* the trip threshold (by default
//!    [`LifecycleConfig::drift_factor`] × the pair's fit-time validation
//!    MAPE, floored at [`LifecycleConfig::floor_mape_pct`]) over at
//!    least [`LifecycleConfig::min_observations`] observations; between
//!    the recover and trip thresholds it reports `Suspect` without
//!    tripping, so boundary MAPE cannot flap the state; and once `Stale`
//!    it stays `Stale` until a refit actually publishes — recovery
//!    without refreshing the weights would be wishful.
//! 3. **Non-blocking warm refit** — a trip enqueues the key to a
//!    background refit worker (one per lifecycle; the `refit_inflight`
//!    marker makes the enqueue singleflight — repeated drifted
//!    observations cost one refit, not one per observation). The worker
//!    warm-starts from the *current* checkpoints
//!    ([`refit_host`]: no surgery, no freeze, short epoch budget) on the
//!    rolling corpus, then atomically republishes the pair with the next
//!    version ([`PlaneCache::publish_models`]) and drops the superseded
//!    planes ([`PlaneCache::invalidate_planes`]). Serving never blocks
//!    on a refit — workers keep answering from the old version until the
//!    publish lands (counted as `stale_served`) — and never observes a
//!    torn model/plane pair, because planes are keyed by the checkpoint
//!    fingerprints of whichever pair a request resolved.
//!
//! Everything is deterministic given the observation stream: corpora
//! sample reservoir slots from a seeded [`Rng`](crate::util::rng::Rng),
//! refits derive their seed from the key and the outgoing version, and
//! `HostTrainer` fits are bit-deterministic per seed.

use std::collections::{HashMap, VecDeque};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::Ordering;
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::coordinator::cache::{HostModels, ModelKey, PlaneCache};
use crate::coordinator::{
    CoordinatorConfig, Metrics, ReferenceModels, Request, Response, Strategy,
};
use crate::device::PowerMode;
use crate::error::{Error, Result};
use crate::nn::checkpoint::Checkpoint;
use crate::nn::host_mlp;
use crate::profiler::{Record, RollingCorpus};
use crate::train::transfer::refit_host;
use crate::train::{Target, TrainConfig};
use crate::util::sync::{lock_unpoisoned, wait_unpoisoned};

/// A refit needs a train/validation split, so fewer resident
/// observations than this keeps a stale model waiting for more feedback.
const MIN_REFIT_ROWS: usize = 2;

/// Lifecycle tuning. The defaults suit the simulated fleet; `serve
/// --drift-mape` maps to [`LifecycleConfig::trip_override_pct`].
#[derive(Debug, Clone, Copy)]
pub struct LifecycleConfig {
    /// Trip when the rolling MAPE exceeds `drift_factor ×` the pair's
    /// fit-time validation MAPE (the accuracy it shipped with).
    pub drift_factor: f64,
    /// Absolute floor (percent) under the factor rule — small fit-time
    /// MAPEs must not make ordinary simulator noise look like drift.
    pub floor_mape_pct: f64,
    /// Absolute trip threshold override (percent); when set, the factor
    /// and floor are ignored.
    pub trip_override_pct: Option<f64>,
    /// Observations required in the rolling window before drift can trip.
    pub min_observations: usize,
    /// Hysteresis: the monitor reports `Fresh` again only below
    /// `recover_ratio × trip`; between the two it reports `Suspect`
    /// without tripping.
    pub recover_ratio: f64,
    /// Rolling APE window per model (observations).
    pub window: usize,
    /// Rolling feedback corpus: total capacity and the always-kept
    /// recency prefix (see [`RollingCorpus`]).
    pub corpus_cap: usize,
    pub corpus_recent: usize,
    /// Warm-refit epoch budget — short by design: the fit starts from
    /// the deployed weights.
    pub refit_epochs: usize,
    /// Artificial latency (ms) added to each background refit. 0 in
    /// production; tests and demos raise it so "serving never blocks on
    /// a refit" is deterministically observable.
    pub refit_delay_ms: u64,
}

impl Default for LifecycleConfig {
    fn default() -> Self {
        LifecycleConfig {
            drift_factor: 2.0,
            floor_mape_pct: 10.0,
            trip_override_pct: None,
            min_observations: 8,
            recover_ratio: 0.5,
            window: 64,
            corpus_cap: 128,
            corpus_recent: 64,
            refit_epochs: 40,
            refit_delay_ms: 0,
        }
    }
}

impl LifecycleConfig {
    /// Resolve the (trip, recover, min-observations) thresholds for a
    /// model with the given fit-time baseline MAPE (%). A `NaN` baseline
    /// (validation MAPE unknown) degrades to the absolute floor.
    pub fn thresholds(&self, baseline_mape_pct: f64) -> DriftThresholds {
        let trip_pct = match self.trip_override_pct {
            Some(t) => t,
            // f64::max ignores NaN, so an unknown baseline yields the floor
            None => (self.drift_factor * baseline_mape_pct).max(self.floor_mape_pct),
        };
        DriftThresholds {
            trip_pct,
            recover_pct: trip_pct * self.recover_ratio,
            min_observations: self.min_observations,
        }
    }
}

/// Resolved drift thresholds for one model (see
/// [`LifecycleConfig::thresholds`]).
#[derive(Debug, Clone, Copy)]
pub struct DriftThresholds {
    pub trip_pct: f64,
    pub recover_pct: f64,
    pub min_observations: usize,
}

/// Drift state of one served model.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ModelState {
    /// Rolling MAPE below the recover threshold (or not enough
    /// observations yet): the model explains what it serves.
    Fresh,
    /// Rolling MAPE between the recover and trip thresholds: degraded
    /// but within hysteresis — watched, not refit.
    Suspect,
    /// Rolling MAPE tripped the threshold: a warm refit is (or will be)
    /// in flight; served responses count as `stale_served` until the new
    /// version publishes.
    Stale,
}

impl ModelState {
    pub fn name(&self) -> &'static str {
        match self {
            ModelState::Fresh => "fresh",
            ModelState::Suspect => "suspect",
            ModelState::Stale => "stale",
        }
    }
}

/// The pure drift state machine: a bounded window of per-observation
/// APE samples (%) and the `Fresh|Suspect|Stale` state with hysteresis.
/// Kept free of locks, clocks and models so the transition rules are
/// directly unit-testable with exact inputs.
#[derive(Debug, Clone)]
pub struct DriftMonitor {
    state: ModelState,
    window: VecDeque<f64>,
    cap: usize,
}

impl DriftMonitor {
    pub fn new(window: usize) -> DriftMonitor {
        DriftMonitor {
            state: ModelState::Fresh,
            window: VecDeque::with_capacity(window.max(1) + 1),
            cap: window.max(1),
        }
    }

    pub fn state(&self) -> ModelState {
        self.state
    }

    /// Mean APE (%) over the rolling window; `NaN` when empty.
    pub fn rolling_mape_pct(&self) -> f64 {
        if self.window.is_empty() {
            return f64::NAN;
        }
        self.window.iter().sum::<f64>() / self.window.len() as f64
    }

    /// Record one observation's APE (%) and advance the state machine.
    /// Returns `true` exactly when this observation tripped
    /// `Fresh/Suspect → Stale`.
    ///
    /// Rules (all on the rolling mean, `m`):
    /// * fewer than `min_observations` samples → state unchanged. The
    ///   quorum is clamped to the window capacity: a window smaller than
    ///   `min_observations` can never fill past its cap, and an
    ///   unreachable quorum would silently disable drift detection
    ///   forever;
    /// * `Stale` is latched: it clears only via [`DriftMonitor::reset`]
    ///   (a refit published) — observations cannot talk a stale model
    ///   fresh again;
    /// * otherwise `m > trip` (strictly) → `Stale`; `m > recover` →
    ///   `Suspect`; else `Fresh`. Exactly-at-threshold is *not* a trip,
    ///   and the `(recover, trip]` band absorbs boundary oscillation
    ///   without flapping.
    pub fn observe_ape_pct(&mut self, ape_pct: f64, th: &DriftThresholds) -> bool {
        self.window.push_back(ape_pct);
        if self.window.len() > self.cap {
            self.window.pop_front();
        }
        if self.window.len() < th.min_observations.min(self.cap) {
            return false;
        }
        let m = self.rolling_mape_pct();
        match self.state {
            ModelState::Stale => false,
            ModelState::Fresh | ModelState::Suspect => {
                if m > th.trip_pct {
                    self.state = ModelState::Stale;
                    true
                } else {
                    self.state = if m > th.recover_pct {
                        ModelState::Suspect
                    } else {
                        ModelState::Fresh
                    };
                    false
                }
            }
        }
    }

    /// A refit published: back to `Fresh` with an empty window (old APEs
    /// were measured against the superseded weights).
    pub fn reset(&mut self) {
        self.state = ModelState::Fresh;
        self.window.clear();
    }

    /// Downgrade a latched `Stale` to `Suspect` (a refit was superseded
    /// rather than published) so a later threshold breach can re-trip.
    fn soften(&mut self) {
        if self.state == ModelState::Stale {
            self.state = ModelState::Suspect;
        }
    }
}

/// One observed outcome of an executed training round, reported back
/// through the feedback lane.
#[derive(Debug, Clone)]
pub struct Feedback {
    /// The request whose recommendation the round executed — its
    /// identity resolves the [`ModelKey`] the outcome is attributed to.
    pub request: Request,
    /// Power mode the round actually ran in.
    pub mode: PowerMode,
    /// Observed mean minibatch time (ms).
    pub time_ms: f64,
    /// Observed mean power (mW).
    pub power_mw: f64,
}

impl Feedback {
    /// Feedback echoing the coordinator's own post-hoc observation — the
    /// common case when the round executed as recommended.
    pub fn from_response(request: Request, resp: &Response) -> Feedback {
        Feedback {
            request,
            mode: resp.chosen_mode,
            time_ms: resp.observed_time_ms,
            power_mw: resp.observed_power_w * 1000.0,
        }
    }
}

/// Externally visible lifecycle status of one served model (reports,
/// examples, tests).
#[derive(Debug, Clone, Copy)]
pub struct ModelStatus {
    pub state: ModelState,
    /// Publication version (1 = first fit; bumped per published refit).
    /// 0 only when feedback arrived before any fit existed.
    pub version: u64,
    /// Rolling MAPE (%) over the feedback window; `NaN` before the first
    /// scored observation.
    pub rolling_mape_pct: f64,
    /// Feedback observations attributed to this model so far.
    pub observations: u64,
    /// The trip threshold (%) currently in force.
    pub trip_pct: f64,
}

/// Per-model lifecycle bookkeeping.
#[derive(Debug)]
struct Tracker {
    monitor: DriftMonitor,
    /// Authoritative monotonic version (survives cache eviction, unlike
    /// the slot's own counter).
    version: u64,
    /// Fit-time validation MAPE baseline (`NaN` until a model is seen).
    baseline_mape_pct: f64,
    corpus: RollingCorpus,
    observations: u64,
    /// Singleflight marker: at most one queued/running refit per model.
    refit_inflight: bool,
}

/// The lifecycle manager: per-model drift trackers, the feedback entry
/// point, and the background refit worker. One per
/// [`Coordinator`](crate::coordinator::Coordinator) (shared by its
/// workers and submitters via `Arc`), or embed one directly next to a
/// [`PlaneCache`] for library use.
#[derive(Debug)]
pub struct Lifecycle {
    cfg: LifecycleConfig,
    prediction_grid: Option<usize>,
    transfer_epochs: usize,
    ref_fps: (u64, u64),
    cache: Arc<PlaneCache>,
    metrics: Arc<Metrics>,
    trackers: Mutex<HashMap<ModelKey, Tracker>>,
    /// `None` once shut down (or if the worker failed to spawn).
    refit_tx: Mutex<Option<mpsc::Sender<ModelKey>>>,
    worker: Mutex<Option<JoinHandle<()>>>,
    /// Queued + running refits, for [`Lifecycle::wait_idle`].
    pending: Mutex<u64>,
    pending_cv: Condvar,
}

impl Lifecycle {
    /// Build the manager and spawn its background refit worker. `coord`
    /// supplies the model-key derivation inputs (prediction grid,
    /// transfer epochs); `reference` supplies the fingerprints.
    pub fn start(
        cfg: LifecycleConfig,
        coord: &CoordinatorConfig,
        reference: &ReferenceModels,
        cache: Arc<PlaneCache>,
        metrics: Arc<Metrics>,
    ) -> Arc<Lifecycle> {
        let (tx, rx) = mpsc::channel::<ModelKey>();
        let lifecycle = Arc::new(Lifecycle {
            cfg,
            prediction_grid: coord.prediction_grid,
            transfer_epochs: coord.transfer_epochs,
            ref_fps: reference.fingerprints(),
            cache,
            metrics,
            trackers: Mutex::new(HashMap::new()),
            refit_tx: Mutex::new(Some(tx)),
            worker: Mutex::new(None),
            pending: Mutex::new(0),
            pending_cv: Condvar::new(),
        });
        let for_worker = Arc::clone(&lifecycle);
        // one refit worker per coordinator domain: shard-labelled so a
        // multi-domain fleet's thread dumps stay attributable
        let refit_name = match coord.shard {
            Some(shard) => format!("pt-refit-s{shard}"),
            None => "pt-refit".into(),
        };
        let spawned = std::thread::Builder::new()
            .name(refit_name)
            .spawn(move || {
                for key in rx {
                    // a panicking refit must not kill the worker: clear
                    // the singleflight marker so a later trip retries
                    if catch_unwind(AssertUnwindSafe(|| for_worker.refit(key))).is_err() {
                        for_worker.clear_inflight(&key);
                    }
                    for_worker.finish_pending();
                }
            });
        match spawned {
            Ok(h) => *lock_unpoisoned(&lifecycle.worker) = Some(h),
            Err(e) => {
                // degraded but visible: drift is still tracked and
                // reported, refreshes just never run
                eprintln!("pt-refit: could not spawn the refit worker ({e}); warm refits disabled");
                *lock_unpoisoned(&lifecycle.refit_tx) = None;
            }
        }
        lifecycle
    }

    /// The [`ModelKey`] serving `req` — `None` for brute-force rounds,
    /// which carry no model to age.
    pub fn key_for(&self, req: &Request) -> Option<ModelKey> {
        let strategy = Strategy::for_scenario(req.scenario);
        if matches!(strategy, Strategy::BruteForce) {
            return None;
        }
        Some(ModelKey::for_request(
            req,
            strategy,
            self.prediction_grid,
            self.transfer_epochs,
            self.ref_fps,
        ))
    }

    /// Feed one executed round's observed outcome into the lifecycle:
    /// bank it in the model's rolling corpus, score it against the
    /// resident predictions, advance the drift monitor, and (on a trip)
    /// enqueue exactly one background warm refit. Cheap — two scalar
    /// forward passes plus map updates — and never builds or blocks on a
    /// fit, so callers may report from the serving path.
    pub fn observe(&self, fb: &Feedback) -> Result<()> {
        if !(fb.time_ms.is_finite() && fb.time_ms > 0.0)
            || !(fb.power_mw.is_finite() && fb.power_mw > 0.0)
        {
            return Err(Error::Coordinator(format!(
                "feedback for request {} rejected: observed time/power must be positive \
                 and finite, got {} ms / {} mW",
                fb.request.id, fb.time_ms, fb.power_mw
            )));
        }
        let Some(key) = self.key_for(&fb.request) else {
            return Ok(()); // brute-force: observed optimum, no model to age
        };
        // resolve the resident pair before taking the tracker lock (the
        // cache lock is never held together with the tracker lock)
        let models = self.cache.peek_models(&key);
        self.metrics.feedback_observations.fetch_add(1, Ordering::Relaxed);

        let mut trackers = lock_unpoisoned(&self.trackers);
        let tracker = trackers.entry(key).or_insert_with(|| Tracker {
            monitor: DriftMonitor::new(self.cfg.window),
            version: models.as_ref().map_or(0, |m| m.version),
            baseline_mape_pct: f64::NAN,
            corpus: RollingCorpus::new(
                fb.request.device,
                fb.request.workload,
                self.cfg.corpus_cap,
                self.cfg.corpus_recent,
                fb.request.seed,
            ),
            observations: 0,
            refit_inflight: false,
        });
        tracker.observations += 1;
        // ground truth banks even before a model exists — it's the
        // corpus a future refit trains on (feedback costs no profiling)
        tracker.corpus.push(Record {
            mode: fb.mode,
            time_ms: fb.time_ms,
            power_mw: fb.power_mw,
            cost_s: 0.0,
        });
        let Some(models) = models else {
            return Ok(());
        };
        if tracker.version < models.version {
            tracker.version = models.version;
        }
        if tracker.baseline_mape_pct.is_nan() {
            tracker.baseline_mape_pct = models.baseline_mape_pct();
        }

        let ape_t = ape_pct(predict_one(&models.time, &fb.mode), fb.time_ms);
        let ape_p = ape_pct(predict_one(&models.power, &fb.mode), fb.power_mw);
        let th = self.cfg.thresholds(tracker.baseline_mape_pct);
        // the pair drifts when either model does: score the worse APE
        if tracker.monitor.observe_ape_pct(ape_t.max(ape_p), &th) {
            self.metrics.drift_trips.fetch_add(1, Ordering::Relaxed);
        }
        if tracker.monitor.state() == ModelState::Stale
            && !tracker.refit_inflight
            && tracker.corpus.len() >= MIN_REFIT_ROWS
            && self.enqueue_refit(key)
        {
            tracker.refit_inflight = true;
        }
        Ok(())
    }

    /// Lifecycle status of the model serving `req` (brute-force → `None`;
    /// a model that was fit but never observed reports `Fresh` at its
    /// resident version).
    pub fn status(&self, req: &Request) -> Option<ModelStatus> {
        let key = self.key_for(req)?;
        {
            let trackers = lock_unpoisoned(&self.trackers);
            if let Some(t) = trackers.get(&key) {
                let th = self.cfg.thresholds(t.baseline_mape_pct);
                return Some(ModelStatus {
                    state: t.monitor.state(),
                    version: t.version,
                    rolling_mape_pct: t.monitor.rolling_mape_pct(),
                    observations: t.observations,
                    trip_pct: th.trip_pct,
                });
            }
        }
        self.cache.peek_models(&key).map(|m| ModelStatus {
            state: ModelState::Fresh,
            version: m.version,
            rolling_mape_pct: f64::NAN,
            observations: 0,
            trip_pct: self.cfg.thresholds(m.baseline_mape_pct()).trip_pct,
        })
    }

    /// Pipeline hook: a response was produced from `key`'s resident
    /// model; count it as `stale_served` if the monitor currently marks
    /// that model `Stale`.
    pub(crate) fn note_served(&self, key: &ModelKey) {
        let trackers = lock_unpoisoned(&self.trackers);
        if let Some(t) = trackers.get(key) {
            if t.monitor.state() == ModelState::Stale {
                self.metrics.stale_served.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    /// Block until no refit is queued or running — deterministic
    /// sequencing for tests, demos and shutdown.
    pub fn wait_idle(&self) {
        let mut p = lock_unpoisoned(&self.pending);
        while *p > 0 {
            p = wait_unpoisoned(&self.pending_cv, p);
        }
    }

    /// Close the refit queue, drain what's enqueued, and join the
    /// worker. Idempotent; called by
    /// [`Coordinator::finish`](crate::coordinator::Coordinator::finish).
    pub fn shutdown(&self) {
        drop(lock_unpoisoned(&self.refit_tx).take());
        let handle = lock_unpoisoned(&self.worker).take();
        if let Some(h) = handle {
            let _ = h.join();
        }
    }

    fn enqueue_refit(&self, key: ModelKey) -> bool {
        let tx = lock_unpoisoned(&self.refit_tx);
        let Some(tx) = tx.as_ref() else {
            return false;
        };
        if tx.send(key).is_err() {
            return false;
        }
        *lock_unpoisoned(&self.pending) += 1;
        true
    }

    fn finish_pending(&self) {
        let mut p = lock_unpoisoned(&self.pending);
        *p = p.saturating_sub(1);
        if *p == 0 {
            self.pending_cv.notify_all();
        }
    }

    fn clear_inflight(&self, key: &ModelKey) {
        if let Some(t) = lock_unpoisoned(&self.trackers).get_mut(key) {
            t.refit_inflight = false;
        }
    }

    /// The background refit of one model: snapshot the rolling corpus,
    /// fine-tune both targets from the *current* checkpoints at the
    /// short epoch budget (no locks held while training), then publish
    /// the new version atomically and invalidate the superseded planes.
    fn refit(&self, key: ModelKey) {
        let snapshot = {
            let trackers = lock_unpoisoned(&self.trackers);
            trackers.get(&key).map(|t| t.corpus.snapshot())
        };
        let current = self.cache.peek_models(&key);
        let (Some(corpus), Some(current)) = (snapshot, current) else {
            // evicted mid-flight (or tracker vanished): nothing to refresh
            self.clear_inflight(&key);
            return;
        };
        if self.cfg.refit_delay_ms > 0 {
            std::thread::sleep(Duration::from_millis(self.cfg.refit_delay_ms));
        }
        // version in the seed: successive refits of one key draw
        // independent shuffle/split streams, deterministically
        let base = TrainConfig {
            epochs: self.cfg.refit_epochs.max(1),
            seed: key.seed ^ current.version.rotate_left(32),
            ..Default::default()
        };
        let refreshed = refit_host(&current.time, &corpus, Target::Time, &base).and_then(
            |(time, tlog)| {
                refit_host(&current.power, &corpus, Target::Power, &base).map(|(power, plog)| {
                    HostModels::new(time, power, 0.0)
                        .with_validation(tlog.best_val_mape(), plog.best_val_mape())
                })
            },
        );
        match refreshed {
            Ok(models) => match self.cache.publish_models(key, models) {
                Some(published) => {
                    self.cache.invalidate_planes(current.time_fp, current.power_fp);
                    self.metrics.refits.fetch_add(1, Ordering::Relaxed);
                    let mut trackers = lock_unpoisoned(&self.trackers);
                    if let Some(t) = trackers.get_mut(&key) {
                        // max, not +1: a concurrent observe may already
                        // have adopted the published version
                        t.version = t.version.max(published.version);
                        t.baseline_mape_pct = published.baseline_mape_pct();
                        t.monitor.reset();
                        t.refit_inflight = false;
                    }
                }
                None => {
                    // a fresh build owns the slot (evicted and re-requested
                    // mid-refit): our refresh is superseded — soften to
                    // Suspect so a later breach re-trips against the new fit
                    let mut trackers = lock_unpoisoned(&self.trackers);
                    if let Some(t) = trackers.get_mut(&key) {
                        t.monitor.soften();
                        t.refit_inflight = false;
                    }
                }
            },
            Err(e) => {
                // stays Stale; the next observation re-enqueues a retry
                eprintln!(
                    "pt-refit: warm refit failed for workload {} (seed {}): {e}; \
                     model stays stale until retried",
                    key.workload.name(),
                    key.seed
                );
                self.clear_inflight(&key);
            }
        }
    }
}

/// Scalar raw-unit prediction of one checkpoint at one mode — the
/// feedback lane's per-observation path (~42k MACs; no engine build, so
/// observations are cheap enough to score inline).
fn predict_one(ckpt: &Checkpoint, mode: &PowerMode) -> f64 {
    let feats = mode.features();
    let raw = [feats[0] as f64, feats[1] as f64, feats[2] as f64, feats[3] as f64];
    let z = ckpt.feature_scaler.transform_row(&raw);
    let zf = [z[0] as f32, z[1] as f32, z[2] as f32, z[3] as f32];
    ckpt.target_scaler
        .inverse1(host_mlp::forward_one(&ckpt.params, &zf) as f64)
}

/// Absolute percentage error of a prediction against a (validated
/// non-zero) observation.
fn ape_pct(pred: f64, obs: f64) -> f64 {
    100.0 * ((pred - obs) / obs).abs()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn th(trip: f64, recover: f64, min_obs: usize) -> DriftThresholds {
        DriftThresholds { trip_pct: trip, recover_pct: recover, min_observations: min_obs }
    }

    #[test]
    fn no_trip_below_min_observations() {
        let mut m = DriftMonitor::new(8);
        let t = th(50.0, 25.0, 4);
        for _ in 0..3 {
            assert!(!m.observe_ape_pct(400.0, &t), "must not trip before min_observations");
            assert_eq!(m.state(), ModelState::Fresh);
        }
        // the 4th observation reaches the quorum and trips
        assert!(m.observe_ape_pct(400.0, &t));
        assert_eq!(m.state(), ModelState::Stale);
    }

    #[test]
    fn stale_trips_strictly_above_threshold() {
        // exactly-at-threshold is NOT a trip: with APE samples of exactly
        // 50 (mean exactly 50.0, binary-exact), a 50.0 trip stays un-tripped
        let mut m = DriftMonitor::new(8);
        let t = th(50.0, 25.0, 2);
        for _ in 0..6 {
            assert!(!m.observe_ape_pct(50.0, &t));
        }
        assert_eq!(m.state(), ModelState::Suspect, "at-threshold sits in the suspect band");
        // one sample above pushes the mean strictly past the trip
        assert!(m.observe_ape_pct(120.0, &t));
        assert_eq!(m.state(), ModelState::Stale);
    }

    #[test]
    fn boundary_mape_does_not_flap() {
        // oscillating inside the (recover, trip] hysteresis band must
        // never trip nor report Fresh — that's the flap the band absorbs
        let mut m = DriftMonitor::new(4);
        let t = th(50.0, 25.0, 2);
        let mut trips = 0;
        for i in 0..40 {
            let ape = if i % 2 == 0 { 30.0 } else { 48.0 };
            if m.observe_ape_pct(ape, &t) {
                trips += 1;
            }
            if i >= 1 {
                assert_eq!(m.state(), ModelState::Suspect, "sample {i}");
            }
        }
        assert_eq!(trips, 0, "boundary oscillation must not trip");
        // and dropping clearly below the recover threshold reports Fresh
        for _ in 0..8 {
            m.observe_ape_pct(5.0, &t);
        }
        assert_eq!(m.state(), ModelState::Fresh);
    }

    #[test]
    fn stale_is_latched_until_reset() {
        let mut m = DriftMonitor::new(4);
        let t = th(50.0, 25.0, 2);
        for _ in 0..4 {
            m.observe_ape_pct(90.0, &t);
        }
        assert_eq!(m.state(), ModelState::Stale);
        // perfect observations cannot talk a stale model fresh again
        for _ in 0..10 {
            assert!(!m.observe_ape_pct(0.0, &t), "latched stale must not re-trip");
        }
        assert_eq!(m.state(), ModelState::Stale);
        // only a published refit resets
        m.reset();
        assert_eq!(m.state(), ModelState::Fresh);
        assert!(m.rolling_mape_pct().is_nan(), "window cleared with the reset");
    }

    #[test]
    fn soften_downgrades_only_stale() {
        let mut m = DriftMonitor::new(4);
        let t = th(50.0, 25.0, 1);
        m.observe_ape_pct(90.0, &t);
        assert_eq!(m.state(), ModelState::Stale);
        m.soften();
        assert_eq!(m.state(), ModelState::Suspect);
        m.soften();
        assert_eq!(m.state(), ModelState::Suspect);
        // and a suspect model can re-trip
        for _ in 0..4 {
            m.observe_ape_pct(200.0, &t);
        }
        assert_eq!(m.state(), ModelState::Stale);
    }

    #[test]
    fn thresholds_resolve_factor_floor_and_override() {
        let cfg = LifecycleConfig {
            drift_factor: 2.0,
            floor_mape_pct: 10.0,
            trip_override_pct: None,
            recover_ratio: 0.5,
            ..Default::default()
        };
        // factor rule above the floor
        let t = cfg.thresholds(8.0);
        assert_eq!(t.trip_pct, 16.0);
        assert_eq!(t.recover_pct, 8.0);
        // floor wins over a tiny baseline
        assert_eq!(cfg.thresholds(1.0).trip_pct, 10.0);
        // NaN baseline (no fit-time validation) degrades to the floor
        assert_eq!(cfg.thresholds(f64::NAN).trip_pct, 10.0);
        // explicit override wins over everything
        let over = LifecycleConfig { trip_override_pct: Some(33.0), ..cfg };
        assert_eq!(over.thresholds(8.0).trip_pct, 33.0);
        assert_eq!(over.thresholds(f64::NAN).trip_pct, 33.0);
    }

    #[test]
    fn quorum_clamps_to_the_window_capacity() {
        // regression: window 4 < min_observations 8 used to make the
        // quorum unreachable — the monitor never evaluated and a wildly
        // drifted model stayed Fresh forever
        let mut m = DriftMonitor::new(4);
        let t = th(50.0, 25.0, 8);
        for _ in 0..3 {
            assert!(!m.observe_ape_pct(400.0, &t));
        }
        // the window fills at 4 samples: the clamped quorum is met, trips
        assert!(m.observe_ape_pct(400.0, &t));
        assert_eq!(m.state(), ModelState::Stale);
    }

    #[test]
    fn rolling_window_is_bounded() {
        let mut m = DriftMonitor::new(4);
        let t = th(1e9, 1e9, 1); // never trips
        for _ in 0..10 {
            m.observe_ape_pct(100.0, &t);
        }
        // four old samples of 100 must be fully displaced by four of 0
        for _ in 0..4 {
            m.observe_ape_pct(0.0, &t);
        }
        assert_eq!(m.rolling_mape_pct(), 0.0);
    }
}
