//! The long-lived coordinator service: worker pool + streaming ingress +
//! response collection.
//!
//! [`Coordinator::start`] spawns `cfg.workers` threads sharing one
//! [`PlaneCache`], one [`Metrics`] and one deadline-aware priority
//! [`RequestQueue`], and returns a cloneable [`Submitter`] — the
//! channel-style submission handle. Callers *stream* [`Job`]s (requests
//! with simulated arrival times, optional deadlines, scenario-derived
//! priorities) instead of collecting a `Vec<Request>` upfront; when the
//! last `Submitter` clone drops, the queue closes, workers drain what
//! remains and [`Coordinator::finish`] returns every response **sorted
//! by request id** (stable CLI/table output regardless of completion
//! order) plus the shared metrics.
//!
//! Failure semantics: a per-request error never aborts the batch and is
//! never silently dropped — each one is recorded in
//! `Metrics::failed_requests` (id + message) and counted; `finish`
//! returns `Err` only when *no* request succeeded. A request handler
//! that panics is caught (`catch_unwind`), converted into a failed
//! response, and the worker keeps serving; combined with the queue's
//! poison-recovering locks, one bad request can no longer wedge the
//! fleet.
//!
//! Resilient serving (host lane): transient failures — including caught
//! panics — are retried under [`CoordinatorConfig::retry`]'s capped,
//! deterministically jittered backoff, but never past the job's
//! deadline: a retry that could only land after `arrival + deadline`
//! is abandoned and the request goes straight to
//! [`HostPipeline::degrade`]'s Ridge → NPE ladder, so callers get a
//! provenance-tagged answer instead of a hang or a late error. When
//! [`CoordinatorConfig::thermal`] is set, all workers share one
//! [`ThermalGuard`] that caps Pareto budgets at the sustainable power
//! envelope.
//!
//! Workers whose PJRT runtime cannot be constructed (or builds without
//! the `xla` feature) serve through the host-native [`HostPipeline`] —
//! the same profile → transfer → predict loop, computed by the pure-rust
//! trainer and the batched host engine. A worker's warm cache hits never
//! contend with its siblings: the pipeline first resolves the request
//! against the cache's immutable, atomically-swapped
//! [`ServeSnapshot`](crate::coordinator::ServeSnapshot) (zero mutexes on
//! the hit path), and only a miss falls back to the singleflight
//! mutex+condvar slow path.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;

use crate::coordinator::lifecycle::{Feedback, Lifecycle};
use crate::coordinator::pipeline::{HostPipeline, ThermalGuard};
use crate::coordinator::queue::{Job, RequestQueue};
use crate::coordinator::{
    CoordinatorConfig, Metrics, PlaneCache, ReferenceModels, Request, Response,
};
use crate::error::{Error, Result};

#[cfg(feature = "xla")]
use crate::coordinator::pipeline::handle_request;
#[cfg(feature = "xla")]
use crate::runtime::Runtime;

/// The queue plus the live-submitter count that decides when it closes.
#[derive(Debug)]
struct Ingress {
    queue: RequestQueue,
    submitters: AtomicUsize,
}

/// Cloneable streaming submission handle. Clones share the coordinator's
/// ingress queue (hand them to producer threads); when the **last** clone
/// drops, the stream closes and workers drain what remains — the same
/// lifecycle as an `mpsc::Sender`. When the coordinator runs with model
/// lifecycle management, the submitter is also the *feedback* handle:
/// [`Submitter::report`] feeds executed-round outcomes back into the
/// drift monitors.
#[derive(Debug)]
pub struct Submitter {
    ingress: Arc<Ingress>,
    lifecycle: Option<Arc<Lifecycle>>,
}

impl Clone for Submitter {
    fn clone(&self) -> Submitter {
        self.ingress.submitters.fetch_add(1, Ordering::SeqCst);
        Submitter {
            ingress: Arc::clone(&self.ingress),
            lifecycle: self.lifecycle.clone(),
        }
    }
}

impl Drop for Submitter {
    fn drop(&mut self) {
        if self.ingress.submitters.fetch_sub(1, Ordering::SeqCst) == 1 {
            self.ingress.queue.close();
        }
    }
}

impl Submitter {
    /// Stream one job into the coordinator.
    pub fn send(&self, job: Job) -> Result<()> {
        if self.ingress.queue.submit(job) {
            Ok(())
        } else {
            Err(Error::Coordinator("coordinator ingress is closed".into()))
        }
    }

    /// Submit a request that arrives now, best-effort, with its
    /// scenario's priority.
    pub fn send_request(&self, request: Request) -> Result<()> {
        self.send(Job::immediate(request))
    }

    /// Milliseconds since the coordinator started — the clock job
    /// arrival offsets are interpreted against.
    pub fn now_ms(&self) -> u64 {
        self.ingress.queue.now_ms()
    }

    /// Report the observed outcome of an *executed* round back into the
    /// model lifecycle (drift monitoring + refit corpora). Processed
    /// synchronously — a couple of scalar forward passes plus map
    /// updates; never blocks on (or triggers) a model fit in the caller.
    /// Errors when the coordinator was started without
    /// [`CoordinatorConfig::lifecycle`] or when the observation itself is
    /// malformed.
    pub fn report(&self, feedback: Feedback) -> Result<()> {
        match &self.lifecycle {
            Some(l) => l.observe(&feedback),
            None => Err(Error::Coordinator(
                "feedback lane disabled: start the coordinator with \
                 CoordinatorConfig::lifecycle = Some(..)"
                    .into(),
            )),
        }
    }
}

/// A running coordinator service. Obtain one (plus its [`Submitter`])
/// from [`Coordinator::start`]; stream jobs; then call
/// [`Coordinator::finish`] to collect the responses.
pub struct Coordinator {
    metrics: Arc<Metrics>,
    cache: Arc<PlaneCache>,
    lifecycle: Option<Arc<Lifecycle>>,
    thermal: Option<Arc<ThermalGuard>>,
    handles: Vec<JoinHandle<()>>,
    rx: mpsc::Receiver<(u64, Result<Response>)>,
}

impl Coordinator {
    /// Spawn the worker pool with a fresh plane cache.
    pub fn start(
        cfg: &CoordinatorConfig,
        reference: &ReferenceModels,
    ) -> Result<(Coordinator, Submitter)> {
        Coordinator::start_with_cache(cfg, reference, Arc::new(PlaneCache::new()))
    }

    /// Spawn the worker pool over an externally owned cache — warm
    /// restarts and benches reuse resident grids/models/planes across
    /// coordinator lifetimes.
    pub fn start_with_cache(
        cfg: &CoordinatorConfig,
        reference: &ReferenceModels,
        cache: Arc<PlaneCache>,
    ) -> Result<(Coordinator, Submitter)> {
        let metrics = Arc::new(Metrics::new());
        // the lifecycle manager (and its refit worker) exists only when
        // configured; everything downstream treats None as "subsystem off"
        let lifecycle = cfg.lifecycle.map(|lcfg| {
            Lifecycle::start(lcfg, cfg, reference, Arc::clone(&cache), Arc::clone(&metrics))
        });
        // one thermal guard for the whole pool: the die heats from the
        // fleet's combined serving, not per worker
        let thermal = cfg
            .thermal
            .map(|tcfg| Arc::new(ThermalGuard::new(tcfg, cfg.faults.clone())));
        let ingress = Arc::new(Ingress {
            queue: RequestQueue::new(),
            submitters: AtomicUsize::new(1),
        });
        let (tx, rx) = mpsc::channel::<(u64, Result<Response>)>();
        let mut handles = Vec::new();
        for worker_id in 0..cfg.workers.max(1) {
            // per-worker clones under fresh names: the originals stay
            // usable in the spawn-failure arm below
            let w_ingress = Arc::clone(&ingress);
            let w_metrics = Arc::clone(&metrics);
            let w_cache = Arc::clone(&cache);
            let w_lifecycle = lifecycle.clone();
            let w_thermal = thermal.clone();
            let w_tx = tx.clone();
            let w_cfg = cfg.clone();
            let w_reference = reference.clone();
            // shard-labelled names (`pt-s2-w0`) keep thread dumps of a
            // multi-domain fleet attributable to their coordinator domain
            let thread_name = match cfg.shard {
                Some(shard) => format!("pt-s{shard}-w{worker_id}"),
                None => format!("pt-worker-{worker_id}"),
            };
            let spawned = std::thread::Builder::new()
                .name(thread_name)
                .spawn(move || {
                    worker_loop(
                        worker_id,
                        &w_ingress,
                        &w_cache,
                        w_lifecycle.as_deref(),
                        w_thermal.as_deref(),
                        &w_reference,
                        &w_cfg,
                        &w_metrics,
                        &w_tx,
                    )
                });
            match spawned {
                Ok(h) => handles.push(h),
                Err(e) => {
                    // close the stream so already-spawned workers exit
                    // instead of blocking on a queue nobody will close
                    ingress.queue.close();
                    if let Some(l) = &lifecycle {
                        l.shutdown();
                    }
                    return Err(Error::Coordinator(format!("spawn failed: {e}")));
                }
            }
        }
        let submitter = Submitter { ingress, lifecycle: lifecycle.clone() };
        Ok((Coordinator { metrics, cache, lifecycle, thermal, handles, rx }, submitter))
    }

    /// The shared metrics (live — counters advance while workers run).
    pub fn metrics(&self) -> Arc<Metrics> {
        Arc::clone(&self.metrics)
    }

    /// The shared plane cache.
    pub fn cache(&self) -> Arc<PlaneCache> {
        Arc::clone(&self.cache)
    }

    /// The model-lifecycle manager, when the coordinator runs with one
    /// (status inspection, `wait_idle` sequencing in tests/demos).
    pub fn lifecycle(&self) -> Option<Arc<Lifecycle>> {
        self.lifecycle.clone()
    }

    /// The shared thermal guard, when the coordinator runs with one
    /// (die-temperature/throttle inspection in tests/demos).
    pub fn thermal(&self) -> Option<Arc<ThermalGuard>> {
        self.thermal.clone()
    }

    /// Receive the next completed result (blocking), *before*
    /// [`Coordinator::finish`]: interactive callers — `serve --feedback`,
    /// the examples' round loops — observe each response while the
    /// stream is still open so they can execute the round and
    /// [`Submitter::report`] its outcome. Returns `None` once every
    /// worker has exited. Results consumed here are not returned again
    /// by `finish`.
    pub fn recv_result(&self) -> Option<(u64, Result<Response>)> {
        self.rx.recv().ok()
    }

    /// Non-blocking variant of [`Coordinator::recv_result`]: drain
    /// whatever has completed *right now* and return to the caller —
    /// `None` means "nothing ready yet" as well as "stream ended", so
    /// this is for paced submitters (the load engine) that interleave
    /// submission with draining and do a final blocking drain (or
    /// [`Coordinator::finish`]) at the end. Results consumed here are
    /// not returned again by `finish`.
    pub fn try_recv_result(&self) -> Option<(u64, Result<Response>)> {
        self.rx.try_recv().ok()
    }

    /// Wait for the stream to end and every in-flight request to finish,
    /// then return all responses **sorted by request id** plus the shared
    /// metrics. Per-request failures are recorded in
    /// `Metrics::failed_requests`; `Err` is returned only when no request
    /// succeeded (the lowest-id failure, deterministically).
    ///
    /// Drop every [`Submitter`] clone before (or while) calling this —
    /// the stream only ends when the last one drops.
    pub fn finish(self) -> Result<(Vec<Response>, Arc<Metrics>)> {
        let Coordinator { metrics, handles, rx, lifecycle, .. } = self;
        let mut responses = Vec::new();
        let mut failures: Vec<(u64, Error)> = Vec::new();
        for (id, res) in rx {
            match res {
                Ok(r) => responses.push(r),
                Err(e) => failures.push((id, e)),
            }
        }
        for h in handles {
            let _ = h.join();
        }
        // drain + join the background refit worker so in-flight refits
        // land (and count) before the final metrics are reported
        if let Some(l) = &lifecycle {
            l.shutdown();
        }
        // deterministic output: order by request id, not completion order
        responses.sort_by_key(|r| r.id);
        if responses.is_empty() {
            failures.sort_by_key(|(id, _)| *id);
            if let Some((_, e)) = failures.into_iter().next() {
                return Err(e);
            }
        }
        Ok((responses, metrics))
    }
}

/// One worker: pull jobs in priority/deadline order, run the pipeline
/// (artifact-backed when a runtime is available, host-native otherwise),
/// convert panics into failed responses, account deadline misses. Host
/// jobs go through [`serve_host_job`]'s retry + degradation stack.
#[allow(clippy::too_many_arguments)]
fn worker_loop(
    worker_id: usize,
    ingress: &Ingress,
    cache: &PlaneCache,
    lifecycle: Option<&Lifecycle>,
    thermal: Option<&ThermalGuard>,
    reference: &ReferenceModels,
    cfg: &CoordinatorConfig,
    metrics: &Metrics,
    tx: &mpsc::Sender<(u64, Result<Response>)>,
) {
    // per-worker context: reference fingerprints hash once, not per request
    let mut pipeline = HostPipeline::new(cache, reference, cfg, metrics);
    if let Some(l) = lifecycle {
        pipeline = pipeline.with_lifecycle(l);
    }
    if let Some(t) = thermal {
        pipeline = pipeline.with_thermal(t);
    }
    // each worker owns its own non-Send PJRT runtime; without one it
    // serves through the host engine
    #[cfg(feature = "xla")]
    let rt = match Runtime::new(&cfg.artifacts_dir) {
        Ok(rt) => Some(rt),
        Err(e) => {
            // the switch must be visible, not silent: every request on
            // this worker now profiles + transfers through the pure-rust
            // trainer instead of the AOT artifacts
            eprintln!(
                "pt-worker-{worker_id}: artifacts unavailable ({e}); \
                 serving via the host-native training path"
            );
            None
        }
    };
    while let Some(job) = ingress.queue.pop() {
        let req = &job.request;
        #[cfg(feature = "xla")]
        let res = match rt.as_ref() {
            Some(rt) => catch_unwind(AssertUnwindSafe(|| {
                handle_request(rt, reference, cfg, metrics, req)
            }))
            .unwrap_or_else(|p| Err(panic_error(worker_id, &*p))),
            None => serve_host_job(&pipeline, worker_id, ingress, cfg, metrics, &job),
        };
        #[cfg(not(feature = "xla"))]
        let res = serve_host_job(&pipeline, worker_id, ingress, cfg, metrics, &job);
        // deadline accounting on the simulated arrival clock: a response
        // produced after `arrival + deadline` is a miss (best-effort jobs
        // have an unreachable u64::MAX absolute deadline)
        if res.is_ok() && ingress.queue.now_ms() > job.absolute_deadline_ms() {
            metrics.deadline_misses.fetch_add(1, Ordering::Relaxed);
        }
        if let Err(e) = &res {
            metrics.record_failure(req.id, e);
        }
        if tx.send((req.id, res)).is_err() {
            break;
        }
    }
}

/// Serve one job through the host pipeline with the full resilience
/// stack: per-attempt panic isolation, transient-failure retries under
/// the deterministic backoff policy (never scheduled past the job's
/// deadline), then the graceful-degradation ladder once the primary path
/// has failed for good. Every injected chaos scenario lands here, which
/// is why each attempt — and the rescue itself — runs under its own
/// `catch_unwind`.
fn serve_host_job(
    pipeline: &HostPipeline<'_>,
    worker_id: usize,
    ingress: &Ingress,
    cfg: &CoordinatorConfig,
    metrics: &Metrics,
    job: &Job,
) -> Result<Response> {
    let req = &job.request;
    let mut attempt: u32 = 0;
    let err = loop {
        let res = catch_unwind(AssertUnwindSafe(|| pipeline.handle_attempt(req, attempt)))
            .unwrap_or_else(|p| Err(panic_error(worker_id, &*p)));
        match res {
            Ok(resp) => return Ok(resp),
            Err(e) if e.is_transient() && attempt < cfg.retry.max_retries => {
                let delay = cfg.retry.backoff_ms(req.seed ^ req.id, attempt);
                // a retry that could only land after the deadline would
                // burn device time to produce a guaranteed miss — stop
                // retrying and let the degradation ladder answer now
                if ingress.queue.now_ms().saturating_add(delay) > job.absolute_deadline_ms() {
                    break e;
                }
                metrics.retries.fetch_add(1, Ordering::Relaxed);
                std::thread::sleep(std::time::Duration::from_millis(delay));
                attempt += 1;
            }
            Err(e) => break e,
        }
    };
    catch_unwind(AssertUnwindSafe(|| pipeline.degrade(req, err)))
        .unwrap_or_else(|p| Err(panic_error(worker_id, &*p)))
}

/// Render a caught panic payload as a coordinator error.
fn panic_error(worker_id: usize, payload: &(dyn std::any::Any + Send)) -> Error {
    let msg = payload
        .downcast_ref::<&str>()
        .map(|s| (*s).to_string())
        .or_else(|| payload.downcast_ref::<String>().cloned())
        .unwrap_or_else(|| "unknown panic".into());
    Error::Coordinator(format!("pt-worker-{worker_id}: request handler panicked: {msg}"))
}

/// Batch compatibility wrapper over the streaming service: submit every
/// request as an immediately-arriving, best-effort job, close the
/// stream, and collect. Responses come back sorted by request id; every
/// per-request failure is recorded in `Metrics` (ids + messages) rather
/// than silently dropped, and `Err` is returned only when no request
/// succeeded.
pub fn serve(
    cfg: &CoordinatorConfig,
    reference: &ReferenceModels,
    requests: Vec<Request>,
) -> Result<(Vec<Response>, Arc<Metrics>)> {
    let (coordinator, submitter) = Coordinator::start(cfg, reference)?;
    for req in requests {
        submitter.send_request(req)?;
    }
    drop(submitter);
    coordinator.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::test_support::{host_cfg, host_reference};
    use crate::coordinator::Scenario;
    use crate::device::DeviceKind;
    use crate::workload::Workload;
    use std::path::PathBuf;
    use std::sync::atomic::Ordering;

    #[test]
    fn host_serve_processes_queue_without_artifacts() {
        let reference = host_reference();
        let cfg = CoordinatorConfig {
            artifacts_dir: PathBuf::from("definitely-missing-artifacts"),
            prediction_grid: Some(200),
            transfer_epochs: 4,
            workers: 2,
            ..Default::default()
        };
        let requests: Vec<Request> = (0..4)
            .map(|i| Request {
                id: i,
                device: DeviceKind::OrinAgx,
                workload: Workload::lstm(),
                power_budget_w: 1e6,
                scenario: Scenario::ContinuousLearning,
                affinity: None,
                node: None,
                seed: 40 + i,
            })
            .collect();
        let (responses, metrics) = serve(&cfg, &reference, requests).unwrap();
        assert_eq!(responses.len(), 4);
        let ids: Vec<u64> = responses.iter().map(|r| r.id).collect();
        // responses are sorted by id regardless of completion order
        assert_eq!(ids, vec![0, 1, 2, 3]);
        assert_eq!(metrics.requests_completed.load(Ordering::Relaxed), 4);
        // every distinct seed transfers its own model pair host-natively
        assert_eq!(metrics.host_fits.load(Ordering::Relaxed), 8);
        for r in &responses {
            assert_eq!(r.strategy, "powertrain-50(host)");
        }
    }

    #[test]
    fn streaming_submitters_can_be_cloned_across_threads() {
        let reference = host_reference();
        let cfg = host_cfg(150);
        let (coordinator, submitter) = Coordinator::start(&cfg, &reference).unwrap();
        std::thread::scope(|s| {
            for t in 0..2u64 {
                let sub = submitter.clone();
                s.spawn(move || {
                    for i in 0..3u64 {
                        sub.send_request(Request {
                            id: t * 3 + i,
                            device: DeviceKind::OrinAgx,
                            workload: Workload::mobilenet(),
                            power_budget_w: 1e6,
                            scenario: Scenario::FederatedLearning,
                            affinity: None,
                            node: None,
                            seed: 60 + t, // one fit per producer thread
                        })
                        .unwrap();
                    }
                });
            }
        });
        drop(submitter); // last live handle: closes the stream
        let (responses, metrics) = coordinator.finish().unwrap();
        assert_eq!(responses.len(), 6);
        let ids: Vec<u64> = responses.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3, 4, 5]);
        // two distinct (workload, seed) keys → two fits, four cache hits
        assert_eq!(metrics.model_cache_misses.load(Ordering::Relaxed), 2);
        assert_eq!(metrics.model_cache_hits.load(Ordering::Relaxed), 4);
    }

    #[test]
    fn try_recv_drains_incrementally_and_finish_returns_the_rest() {
        let reference = host_reference();
        let cfg = host_cfg(150);
        let (coordinator, submitter) = Coordinator::start(&cfg, &reference).unwrap();
        let req = |id: u64| Request {
            id,
            device: DeviceKind::OrinAgx,
            workload: Workload::mobilenet(),
            power_budget_w: 1e6,
            scenario: Scenario::FederatedLearning,
            affinity: None,
            node: None,
            seed: 77,
        };
        submitter.send_request(req(0)).unwrap();
        // blocking recv observes the first result, then try_recv on the
        // empty channel must return None without hanging
        let (id0, res0) = coordinator.recv_result().unwrap();
        assert_eq!(id0, 0);
        assert!(res0.is_ok());
        assert!(coordinator.try_recv_result().is_none());
        submitter.send_request(req(1)).unwrap();
        // poll-drain the second result the way the load engine does
        let mut drained = None;
        for _ in 0..20_000 {
            if let Some(r) = coordinator.try_recv_result() {
                drained = Some(r);
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        let (id1, res1) = drained.expect("second result never arrived");
        assert_eq!(id1, 1);
        assert!(res1.is_ok());
        drop(submitter);
        // both results were consumed pre-finish; finish has nothing left
        let (responses, metrics) = coordinator.finish().unwrap();
        assert!(responses.is_empty());
        assert_eq!(metrics.requests_completed.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn all_failed_batch_returns_lowest_id_error() {
        let reference = host_reference();
        let cfg = host_cfg(100);
        let bad = |id: u64| Request {
            id,
            device: DeviceKind::OrinAgx,
            workload: Workload::mobilenet(),
            power_budget_w: -1.0, // admission-rejected
            scenario: Scenario::FederatedLearning,
            affinity: None,
            node: None,
            seed: 9,
        };
        let err = serve(&cfg, &reference, vec![bad(4), bad(2)]).unwrap_err();
        assert!(
            err.to_string().contains("request 2"),
            "expected the lowest-id failure, got: {err}"
        );
    }

    #[test]
    fn feedback_requires_a_lifecycle() {
        let reference = host_reference();
        let cfg = host_cfg(100); // lifecycle: None
        let (coordinator, submitter) = Coordinator::start(&cfg, &reference).unwrap();
        assert!(coordinator.lifecycle().is_none());
        let req = Request {
            id: 0,
            device: DeviceKind::OrinAgx,
            workload: Workload::mobilenet(),
            power_budget_w: 30.0,
            scenario: Scenario::FederatedLearning,
            affinity: None,
            node: None,
            seed: 1,
        };
        let fb = crate::coordinator::Feedback {
            request: req,
            mode: crate::device::PowerMode::maxn(DeviceKind::OrinAgx.spec()),
            time_ms: 100.0,
            power_mw: 20_000.0,
        };
        let err = submitter.report(fb).unwrap_err();
        assert!(err.to_string().contains("feedback lane disabled"), "{err}");
        drop(submitter);
        coordinator.finish().unwrap();
    }

    #[test]
    fn feedback_flows_into_the_lifecycle() {
        let reference = host_reference();
        let cfg = CoordinatorConfig {
            lifecycle: Some(crate::coordinator::LifecycleConfig::default()),
            ..host_cfg(150)
        };
        let (coordinator, submitter) = Coordinator::start(&cfg, &reference).unwrap();
        let lifecycle = coordinator.lifecycle().expect("lifecycle enabled");
        let metrics = coordinator.metrics();
        let req = Request {
            id: 0,
            device: DeviceKind::OrinAgx,
            workload: Workload::mobilenet(),
            power_budget_w: 1e6,
            scenario: Scenario::ContinuousLearning,
            affinity: None,
            node: None,
            seed: 77,
        };
        submitter.send_request(req.clone()).unwrap();
        let (_, res) = coordinator.recv_result().expect("one response");
        let resp = res.unwrap();
        // echo the coordinator's own observation back as feedback
        submitter
            .report(crate::coordinator::Feedback::from_response(req.clone(), &resp))
            .unwrap();
        assert_eq!(metrics.feedback_observations.load(Ordering::Relaxed), 1);
        let status = lifecycle.status(&req).expect("tracked model");
        assert_eq!(status.version, 1);
        assert_eq!(status.observations, 1);
        assert!(status.rolling_mape_pct.is_finite());
        // malformed observations are rejected loudly
        let bad = crate::coordinator::Feedback {
            time_ms: f64::NAN,
            ..crate::coordinator::Feedback::from_response(req.clone(), &resp)
        };
        assert!(submitter.report(bad).is_err());
        assert_eq!(metrics.feedback_observations.load(Ordering::Relaxed), 1);
        drop(submitter);
        coordinator.finish().unwrap();
    }

    #[test]
    fn empty_request_stream_is_ok() {
        let reference = host_reference();
        let cfg = host_cfg(100);
        let (responses, metrics) = serve(&cfg, &reference, Vec::new()).unwrap();
        assert!(responses.is_empty());
        assert_eq!(metrics.requests_received.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn transient_fit_failures_are_retried_to_a_primary_answer() {
        let reference = host_reference();
        let mut cfg = host_cfg(200);
        cfg.workers = 1;
        cfg.faults = Some(Arc::new(crate::sim::FaultInjector::new(crate::sim::FaultPlan {
            fit_fail_pct: 1.0,
            fit_streak: 2,
            ..Default::default()
        })));
        let req = Request {
            id: 0,
            device: DeviceKind::OrinAgx,
            workload: Workload::mobilenet(),
            power_budget_w: 1e6,
            scenario: Scenario::FederatedLearning,
            affinity: None,
            node: None,
            seed: 5,
        };
        let (responses, metrics) = serve(&cfg, &reference, vec![req]).unwrap();
        assert_eq!(responses.len(), 1);
        // two scripted failures, then the third attempt lands the real thing
        assert_eq!(responses[0].provenance, crate::coordinator::Provenance::Primary);
        assert_eq!(responses[0].strategy, "powertrain-50(host)");
        assert_eq!(metrics.retries.load(Ordering::Relaxed), 2);
        assert_eq!(metrics.requests_received.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.degraded_served.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn deadline_exhausted_transients_skip_retries_and_degrade() {
        let reference = host_reference();
        let mut cfg = host_cfg(200);
        cfg.workers = 1;
        // an outage no retry budget can outlast
        cfg.faults = Some(Arc::new(crate::sim::FaultInjector::new(crate::sim::FaultPlan {
            fit_fail_pct: 1.0,
            fit_streak: 1_000_000,
            ..Default::default()
        })));
        let req = Request {
            id: 0,
            device: DeviceKind::OrinAgx,
            workload: Workload::mobilenet(),
            power_budget_w: 1e6,
            scenario: Scenario::FederatedLearning,
            affinity: None,
            node: None,
            seed: 5,
        };
        let (coordinator, submitter) = Coordinator::start(&cfg, &reference).unwrap();
        // deadline 0: any backoff delay would already overshoot it, so
        // the worker must not burn a single retry before degrading
        submitter.send(Job::immediate(req).with_deadline(0)).unwrap();
        drop(submitter);
        let (responses, metrics) = coordinator.finish().unwrap();
        assert_eq!(responses.len(), 1);
        assert_eq!(responses[0].provenance, crate::coordinator::Provenance::DegradedRidge);
        assert_eq!(responses[0].strategy, "ridge(degraded)");
        assert_eq!(metrics.retries.load(Ordering::Relaxed), 0);
        assert_eq!(metrics.degraded_served.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn injected_worker_panics_are_retried_transparently() {
        let reference = host_reference();
        let mut cfg = host_cfg(200);
        cfg.workers = 1;
        cfg.faults = Some(Arc::new(crate::sim::FaultInjector::new(crate::sim::FaultPlan {
            panic_request_ids: vec![3],
            ..Default::default()
        })));
        let requests: Vec<Request> = (1..=4)
            .map(|id| Request {
                id,
                device: DeviceKind::OrinAgx,
                workload: Workload::mobilenet(),
                power_budget_w: 1e6,
                scenario: Scenario::FederatedLearning,
                affinity: None,
                node: None,
                seed: 5,
            })
            .collect();
        let (responses, metrics) = serve(&cfg, &reference, requests).unwrap();
        // the panicking request is retried (panics classify as transient
        // coordinator faults) and every request still gets a primary answer
        assert_eq!(responses.len(), 4);
        for r in &responses {
            assert_eq!(r.provenance, crate::coordinator::Provenance::Primary);
        }
        assert_eq!(metrics.retries.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.failed_requests().len(), 0);
    }
}
