//! Coordinator metrics: thread-safe counters and latency histograms for
//! the serving loop (throughput / latency reporting of the e2e driver),
//! plus the per-request failure ledger the streaming service reports —
//! a partially-failed batch is never silent: every failed request id and
//! its error message are recorded here and surfaced by `cmd_serve`.
//!
//! Everything the serve hot path records is lock-free: the counters are
//! relaxed `AtomicU64`s, and the latency/completion ledgers are
//! fixed-capacity [`AtomicLedger`]s (one `fetch_add` to claim a slot,
//! one store to fill it) — so metrics recording never serializes
//! concurrent responses. Only the *failure* ledger keeps a mutex: it
//! stores heap strings and sits firmly on the cold path.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::device::DeviceKind;
use crate::error::Error;
use crate::util::json::Value;
use crate::util::sync::lock_unpoisoned;

/// Cap on the completion-order ledger (diagnostics/tests observable).
/// Long-lived services complete unboundedly many requests; the ledger
/// keeps only the first window while the counters keep counting.
const MAX_COMPLETION_LEDGER: usize = 4096;

/// Cap on retained latency samples. Like the completion ledger, the
/// first window is kept for quantile reporting while a long-lived
/// service keeps serving; 16k × 8 bytes = 128 KiB per `Metrics`.
const MAX_LATENCY_SAMPLES: usize = 16_384;

/// Most coordinator domains a fleet-level `Metrics` tracks per-shard
/// counters for. Routing beyond this still works — the overflow shards
/// simply aggregate into the last slot.
pub const MAX_FLEET_SHARDS: usize = 16;

/// A lock-free, fixed-capacity, append-only ledger of `u64` records.
///
/// Writers claim a slot with one relaxed `fetch_add` and fill it with
/// one release store — no mutex, no retry loop, so recording on the
/// serve hot path never serializes concurrent responses. Once the
/// capacity is exhausted further records are dropped (the companion
/// monotonic counters keep counting). Slots are pre-initialized to a
/// `sentinel` value that no legitimate record uses; a reader that races
/// a claimed-but-not-yet-filled slot sees the sentinel and skips it, so
/// [`AtomicLedger::snapshot`] returns exactly the records whose writes
/// completed, in claim order.
#[derive(Debug)]
struct AtomicLedger {
    slots: Box<[AtomicU64]>,
    /// Total records ever claimed (may exceed capacity; the excess were
    /// dropped).
    claimed: AtomicU64,
    sentinel: u64,
}

impl AtomicLedger {
    fn new(cap: usize, sentinel: u64) -> AtomicLedger {
        let slots: Box<[AtomicU64]> = (0..cap).map(|_| AtomicU64::new(sentinel)).collect();
        AtomicLedger { slots, claimed: AtomicU64::new(0), sentinel }
    }

    /// Lock-free append; silently drops once the ledger is full.
    fn push(&self, value: u64) {
        let i = self.claimed.fetch_add(1, Ordering::Relaxed) as usize;
        if i < self.slots.len() {
            self.slots[i].store(value, Ordering::Release);
        }
    }

    /// Completed records in claim order (first window only).
    fn snapshot(&self) -> Vec<u64> {
        let n = (self.claimed.load(Ordering::Acquire) as usize).min(self.slots.len());
        self.slots[..n]
            .iter()
            .map(|s| s.load(Ordering::Acquire))
            .filter(|&v| v != self.sentinel)
            .collect()
    }
}

/// Latency samples as bit-stored `f64`s. The sentinel is the canonical
/// NaN bit pattern — a wall-clock latency is never NaN, so no sample can
/// collide with it.
#[derive(Debug)]
struct LatencySamples(AtomicLedger);

impl Default for LatencySamples {
    fn default() -> Self {
        LatencySamples(AtomicLedger::new(MAX_LATENCY_SAMPLES, f64::NAN.to_bits()))
    }
}

/// Completion-order ledger of request ids. `u64::MAX` is the sentinel
/// (never issued as a request id by any driver in this codebase).
#[derive(Debug)]
struct CompletionLedger(AtomicLedger);

impl Default for CompletionLedger {
    fn default() -> Self {
        CompletionLedger(AtomicLedger::new(MAX_COMPLETION_LEDGER, u64::MAX))
    }
}

/// Per-(device kind, shard) routed-placement counters — a dense
/// `kinds × MAX_FLEET_SHARDS` grid of relaxed `AtomicU64`s, so the fleet
/// submit path records a placement with exactly one `fetch_add` and no
/// lock, same discipline as every other hot-path counter here.
#[derive(Debug)]
struct RoutedLedger(Box<[AtomicU64]>);

impl Default for RoutedLedger {
    fn default() -> Self {
        RoutedLedger(
            (0..DeviceKind::ALL.len() * MAX_FLEET_SHARDS)
                .map(|_| AtomicU64::new(0))
                .collect(),
        )
    }
}

impl RoutedLedger {
    fn slot(kind: DeviceKind, shard: usize) -> usize {
        let k = DeviceKind::ALL
            .iter()
            .position(|c| *c == kind)
            .expect("DeviceKind::ALL covers every kind");
        k * MAX_FLEET_SHARDS + shard.min(MAX_FLEET_SHARDS - 1)
    }
}

/// Monotonic counters + latency samples. Shared across workers via `Arc`.
#[derive(Debug, Default)]
pub struct Metrics {
    pub requests_received: AtomicU64,
    pub requests_completed: AtomicU64,
    pub requests_failed: AtomicU64,
    /// Requests rejected by the pipeline's admission stage (malformed
    /// budget etc.) before any profiling or fitting work was spent.
    pub admission_rejected: AtomicU64,
    pub modes_profiled: AtomicU64,
    pub reboots: AtomicU64,
    /// Grid-resident serve-plane cache hits/misses (host path): a hit
    /// answers from the cached Pareto front in O(log front); a miss pays
    /// the full grid prediction + front build.
    pub plane_cache_hits: AtomicU64,
    pub plane_cache_misses: AtomicU64,
    /// Per-workload model cache hits/misses (host path): a hit reuses the
    /// transferred/scratch-trained checkpoints; a miss pays online
    /// profiling plus two host fits.
    pub model_cache_hits: AtomicU64,
    pub model_cache_misses: AtomicU64,
    /// Requests that found their key's build already in flight and
    /// blocked on it instead of duplicating the work (singleflight).
    /// Counted when the coalescing happens; the matching cache *hit* is
    /// only counted if the awaited build actually delivers a value.
    pub singleflight_waits: AtomicU64,
    /// Host-native model fits performed (transfer or scratch; two per
    /// model-cache miss — one per prediction target).
    pub host_fits: AtomicU64,
    /// Requests whose response was produced after their (simulated)
    /// arrival-relative deadline had already passed.
    pub deadline_misses: AtomicU64,
    /// Observed round outcomes accepted by the model-lifecycle feedback
    /// lane (brute-force rounds carry no model to age and are not
    /// counted).
    pub feedback_observations: AtomicU64,
    /// Fresh/Suspect → Stale transitions of the drift monitor: a cached
    /// model's rolling raw-unit MAPE against observed outcomes crossed
    /// its trip threshold.
    pub drift_trips: AtomicU64,
    /// Background warm refits that completed and published a new model
    /// version (and invalidated the superseded planes).
    pub refits: AtomicU64,
    /// Requests answered from a model the drift monitor currently marks
    /// `Stale` — the staleness exposure while a warm refit is in flight.
    pub stale_served: AtomicU64,
    /// Transient pipeline-stage failures that were retried (each backoff
    /// sleep counts once).
    pub retries: AtomicU64,
    /// Circuit-breaker coarse-state changes (Closed -> Open,
    /// Open -> Half-Open probe, Half-Open -> Closed / back to Open).
    pub breaker_transitions: AtomicU64,
    /// Responses served by the graceful-degradation ladder (Ridge or NPE
    /// fallback) instead of the primary NN model pair.
    pub degraded_served: AtomicU64,
    /// Rising edges of the thermal guard's throttle state (the device
    /// crossed its trip temperature under sustained serve load).
    pub thermal_throttle_events: AtomicU64,
    /// Fleet requests the router could not place on a healthy node of
    /// the requested kind (cross-kind fallback or no capacity at all).
    pub placement_rejected: AtomicU64,
    /// Fleet submissions whose model pair was already transferred for
    /// another shard (or an earlier request) — host fits the once-
    /// fleet-wide transfer discipline avoided.
    pub cross_shard_transfers_saved: AtomicU64,
    /// Simulated device-seconds spent profiling.
    profiling_ms: AtomicU64,
    /// Wall-clock request latencies (ms), recorded lock-free. Bounded:
    /// the first [`MAX_LATENCY_SAMPLES`] samples feed the quantile
    /// report; a long-lived service keeps serving without growing it.
    latencies_ms: LatencySamples,
    /// Request ids in the order their responses were produced (the
    /// scheduler's observable behaviour: priority tests and diagnostics
    /// read this), recorded lock-free. Bounded: recording stops at
    /// [`MAX_COMPLETION_LEDGER`] so a long-lived service doesn't grow
    /// one u64 per request forever; `requests_completed` keeps counting.
    completed_ids: CompletionLedger,
    /// Every failed request: (id, rendered error). The streaming service
    /// records each failure here so a partially-failed batch reports all
    /// of them, not just the first. Bounded like `completed_ids`
    /// (first [`MAX_COMPLETION_LEDGER`] failures); `requests_failed`
    /// keeps counting.
    failures: Mutex<Vec<(u64, String)>>,
    /// Placements routed per (device kind, shard) — only the fleet
    /// layer's `Metrics` writes here; a plain coordinator's stays zero.
    routed: RoutedLedger,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    /// Accumulate simulated profiling seconds. Rounds to the nearest
    /// millisecond — truncation made many sub-millisecond additions
    /// undercount to zero — and rejects negative durations loudly in
    /// debug builds (saturating to zero in release instead of wrapping
    /// a negative cast through u64).
    pub fn add_profiling_s(&self, s: f64) {
        debug_assert!(s >= 0.0, "negative profiling duration: {s}");
        self.profiling_ms
            .fetch_add((s.max(0.0) * 1000.0).round() as u64, Ordering::Relaxed);
    }

    pub fn profiling_s(&self) -> f64 {
        self.profiling_ms.load(Ordering::Relaxed) as f64 / 1000.0
    }

    /// Record one response latency — lock-free (one `fetch_add`, one
    /// store), so concurrent workers never serialize here.
    pub fn observe_latency_ms(&self, ms: f64) {
        self.latencies_ms.0.push(ms.to_bits());
    }

    /// Record a produced response: bumps `requests_completed` and appends
    /// the id to the (bounded) completion-order ledger. Lock-free.
    pub fn record_completion(&self, id: u64) {
        self.requests_completed.fetch_add(1, Ordering::Relaxed);
        self.completed_ids.0.push(id);
    }

    /// Request ids in the order their responses were produced (first
    /// [`MAX_COMPLETION_LEDGER`] completions only).
    pub fn completion_order(&self) -> Vec<u64> {
        self.completed_ids.0.snapshot()
    }

    /// Record a failed request: bumps `requests_failed` and remembers the
    /// id + a `[class kind]`-prefixed message so the batch report can
    /// surface every failure and chaos runs can grep by error kind. Like
    /// the completion ledger, the detail list is bounded at
    /// [`MAX_COMPLETION_LEDGER`] entries — a long-lived service under a
    /// failing stream must not grow one `String` per failure forever —
    /// while the counter keeps counting.
    pub fn record_failure(&self, id: u64, err: &Error) {
        self.requests_failed.fetch_add(1, Ordering::Relaxed);
        let mut failures = lock_unpoisoned(&self.failures);
        if failures.len() < MAX_COMPLETION_LEDGER {
            failures.push((id, format!("[{} {}] {}", err.class(), err.kind(), err)));
        }
    }

    /// Every recorded failure as (request id, error message), ordered by
    /// request id.
    pub fn failed_requests(&self) -> Vec<(u64, String)> {
        let mut v = lock_unpoisoned(&self.failures).clone();
        v.sort_by_key(|(id, _)| *id);
        v
    }

    /// Ids of every failed request, ascending.
    pub fn failed_ids(&self) -> Vec<u64> {
        self.failed_requests().into_iter().map(|(id, _)| id).collect()
    }

    /// Record a fleet placement routed to `shard` on a node of `kind`.
    /// Lock-free: one relaxed `fetch_add` into the dense ledger.
    pub fn note_routed(&self, kind: DeviceKind, shard: usize) {
        self.routed.0[RoutedLedger::slot(kind, shard)].fetch_add(1, Ordering::Relaxed);
    }

    /// Placements routed to `shard` on nodes of `kind`.
    pub fn routed(&self, kind: DeviceKind, shard: usize) -> u64 {
        self.routed.0[RoutedLedger::slot(kind, shard)].load(Ordering::Relaxed)
    }

    /// Total placements routed fleet-wide.
    pub fn routed_total(&self) -> u64 {
        self.routed.0.iter().map(|c| c.load(Ordering::Relaxed)).sum()
    }

    /// The retained latency samples (ms), in claim order — the load
    /// report computes its quantiles over these (optionally offset past
    /// a warm-up prefix) instead of re-deriving them per percentile.
    pub fn latencies_ms(&self) -> Vec<f64> {
        self.latencies_ms.0.snapshot().into_iter().map(f64::from_bits).collect()
    }

    /// A point-in-time copy of every monotonic counter (latency /
    /// completion / failure ledgers excluded — those have their own
    /// snapshot accessors). Two snapshots subtract
    /// ([`CounterSnapshot::delta`]) to scope a measurement window, e.g.
    /// the load engine's warm-up exclusion.
    pub fn counters(&self) -> CounterSnapshot {
        let mut routed = [0u64; ROUTED_SLOTS];
        for (slot, counter) in routed.iter_mut().zip(self.routed.0.iter()) {
            *slot = counter.load(Ordering::Relaxed);
        }
        CounterSnapshot {
            requests_received: self.requests_received.load(Ordering::Relaxed),
            requests_completed: self.requests_completed.load(Ordering::Relaxed),
            requests_failed: self.requests_failed.load(Ordering::Relaxed),
            admission_rejected: self.admission_rejected.load(Ordering::Relaxed),
            modes_profiled: self.modes_profiled.load(Ordering::Relaxed),
            reboots: self.reboots.load(Ordering::Relaxed),
            plane_cache_hits: self.plane_cache_hits.load(Ordering::Relaxed),
            plane_cache_misses: self.plane_cache_misses.load(Ordering::Relaxed),
            model_cache_hits: self.model_cache_hits.load(Ordering::Relaxed),
            model_cache_misses: self.model_cache_misses.load(Ordering::Relaxed),
            singleflight_waits: self.singleflight_waits.load(Ordering::Relaxed),
            host_fits: self.host_fits.load(Ordering::Relaxed),
            deadline_misses: self.deadline_misses.load(Ordering::Relaxed),
            feedback_observations: self.feedback_observations.load(Ordering::Relaxed),
            drift_trips: self.drift_trips.load(Ordering::Relaxed),
            refits: self.refits.load(Ordering::Relaxed),
            stale_served: self.stale_served.load(Ordering::Relaxed),
            retries: self.retries.load(Ordering::Relaxed),
            breaker_transitions: self.breaker_transitions.load(Ordering::Relaxed),
            degraded_served: self.degraded_served.load(Ordering::Relaxed),
            thermal_throttle_events: self.thermal_throttle_events.load(Ordering::Relaxed),
            placement_rejected: self.placement_rejected.load(Ordering::Relaxed),
            cross_shard_transfers_saved: self.cross_shard_transfers_saved.load(Ordering::Relaxed),
            profiling_ms: self.profiling_ms.load(Ordering::Relaxed),
            routed,
        }
    }

    /// (p50, p95, max) latency in ms, over the retained sample window.
    pub fn latency_summary_ms(&self) -> (f64, f64, f64) {
        let lat: Vec<f64> =
            self.latencies_ms.0.snapshot().into_iter().map(f64::from_bits).collect();
        if lat.is_empty() {
            return (0.0, 0.0, 0.0);
        }
        let p50 = crate::util::stats::quantile(&lat, 0.5);
        let p95 = crate::util::stats::quantile(&lat, 0.95);
        let max = lat.iter().cloned().fold(0.0, f64::max);
        (p50, p95, max)
    }

    pub fn render(&self) -> String {
        let (p50, p95, max) = self.latency_summary_ms();
        let mut out = format!(
            "requests: {} received, {} completed, {} failed, {} rejected | modes profiled: {} | reboots: {} | plane cache: {} hits / {} misses | model cache: {} hits / {} misses | singleflight waits: {} | host fits: {} | deadline misses: {} | lifecycle: {} observations, {} drift trips, {} refits, {} stale-served | resilience: {} retries, {} breaker transitions, {} degraded served, {} thermal throttles | simulated profiling: {:.1} min | latency ms (p50/p95/max): {:.0}/{:.0}/{:.0}",
            self.requests_received.load(Ordering::Relaxed),
            self.requests_completed.load(Ordering::Relaxed),
            self.requests_failed.load(Ordering::Relaxed),
            self.admission_rejected.load(Ordering::Relaxed),
            self.modes_profiled.load(Ordering::Relaxed),
            self.reboots.load(Ordering::Relaxed),
            self.plane_cache_hits.load(Ordering::Relaxed),
            self.plane_cache_misses.load(Ordering::Relaxed),
            self.model_cache_hits.load(Ordering::Relaxed),
            self.model_cache_misses.load(Ordering::Relaxed),
            self.singleflight_waits.load(Ordering::Relaxed),
            self.host_fits.load(Ordering::Relaxed),
            self.deadline_misses.load(Ordering::Relaxed),
            self.feedback_observations.load(Ordering::Relaxed),
            self.drift_trips.load(Ordering::Relaxed),
            self.refits.load(Ordering::Relaxed),
            self.stale_served.load(Ordering::Relaxed),
            self.retries.load(Ordering::Relaxed),
            self.breaker_transitions.load(Ordering::Relaxed),
            self.degraded_served.load(Ordering::Relaxed),
            self.thermal_throttle_events.load(Ordering::Relaxed),
            self.profiling_s() / 60.0,
            p50,
            p95,
            max,
        );
        // the fleet segment only appears when fleet counters moved, so a
        // plain coordinator's serve table is unchanged
        let routed_total = self.routed_total();
        let rejected = self.placement_rejected.load(Ordering::Relaxed);
        let saved = self.cross_shard_transfers_saved.load(Ordering::Relaxed);
        if routed_total > 0 || rejected > 0 || saved > 0 {
            let per_kind: Vec<String> = DeviceKind::ALL
                .iter()
                .map(|&kind| {
                    let by_shard: Vec<String> = (0..MAX_FLEET_SHARDS)
                        .map(|s| (s, self.routed(kind, s)))
                        .filter(|(_, n)| *n > 0)
                        .map(|(s, n)| format!("s{s}:{n}"))
                        .collect();
                    format!(
                        "{} {} [{}]",
                        kind.name(),
                        (0..MAX_FLEET_SHARDS).map(|s| self.routed(kind, s)).sum::<u64>(),
                        by_shard.join(" ")
                    )
                })
                .collect();
            out.push_str(&format!(
                " | fleet: {} routed ({}), {} placement rejected, {} cross-shard transfers saved",
                routed_total,
                per_kind.join("; "),
                rejected,
                saved,
            ));
        }
        let failed = self.failed_requests();
        if !failed.is_empty() {
            let ids: Vec<String> = failed.iter().map(|(id, _)| id.to_string()).collect();
            out.push_str(&format!(" | failed ids: [{}]", ids.join(", ")));
        }
        out
    }
}

/// Dense size of the per-(kind, shard) routed grid.
const ROUTED_SLOTS: usize = 3 * MAX_FLEET_SHARDS;
// the grid is indexed by DeviceKind::ALL position; keep the constant in
// lockstep with the kind roster
const _: () = assert!(ROUTED_SLOTS == DeviceKind::ALL.len() * MAX_FLEET_SHARDS);

/// A point-in-time copy of every [`Metrics`] monotonic counter.
///
/// Plain `Copy` data: subtract two snapshots with
/// [`CounterSnapshot::delta`] to scope a window (the load engine scopes
/// its measured phase this way — counters keep their absolute meaning on
/// the live `Metrics` while the report shows only the window), and
/// serialize with [`CounterSnapshot::to_json`] (deterministic key order
/// via the JSON object's `BTreeMap`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CounterSnapshot {
    pub requests_received: u64,
    pub requests_completed: u64,
    pub requests_failed: u64,
    pub admission_rejected: u64,
    pub modes_profiled: u64,
    pub reboots: u64,
    pub plane_cache_hits: u64,
    pub plane_cache_misses: u64,
    pub model_cache_hits: u64,
    pub model_cache_misses: u64,
    pub singleflight_waits: u64,
    pub host_fits: u64,
    pub deadline_misses: u64,
    pub feedback_observations: u64,
    pub drift_trips: u64,
    pub refits: u64,
    pub stale_served: u64,
    pub retries: u64,
    pub breaker_transitions: u64,
    pub degraded_served: u64,
    pub thermal_throttle_events: u64,
    pub placement_rejected: u64,
    pub cross_shard_transfers_saved: u64,
    /// Simulated profiling milliseconds (the private accumulator behind
    /// [`Metrics::profiling_s`]).
    pub profiling_ms: u64,
    /// The per-(device kind, shard) routed grid, flattened exactly like
    /// the live ledger: `kind_index * MAX_FLEET_SHARDS + shard`.
    pub routed: [u64; ROUTED_SLOTS],
}

impl Default for CounterSnapshot {
    // not derivable: std only provides `Default` for arrays up to 32
    // elements, and the routed grid has 3 × MAX_FLEET_SHARDS slots
    fn default() -> CounterSnapshot {
        CounterSnapshot {
            requests_received: 0,
            requests_completed: 0,
            requests_failed: 0,
            admission_rejected: 0,
            modes_profiled: 0,
            reboots: 0,
            plane_cache_hits: 0,
            plane_cache_misses: 0,
            model_cache_hits: 0,
            model_cache_misses: 0,
            singleflight_waits: 0,
            host_fits: 0,
            deadline_misses: 0,
            feedback_observations: 0,
            drift_trips: 0,
            refits: 0,
            stale_served: 0,
            retries: 0,
            breaker_transitions: 0,
            degraded_served: 0,
            thermal_throttle_events: 0,
            placement_rejected: 0,
            cross_shard_transfers_saved: 0,
            profiling_ms: 0,
            routed: [0; ROUTED_SLOTS],
        }
    }
}

impl CounterSnapshot {
    /// Element-wise `self − earlier` (saturating — a live counter can
    /// only grow, so a negative delta would mean mismatched snapshots;
    /// saturate rather than wrap).
    pub fn delta(&self, earlier: &CounterSnapshot) -> CounterSnapshot {
        let mut routed = [0u64; ROUTED_SLOTS];
        for (i, slot) in routed.iter_mut().enumerate() {
            *slot = self.routed[i].saturating_sub(earlier.routed[i]);
        }
        CounterSnapshot {
            requests_received: self.requests_received.saturating_sub(earlier.requests_received),
            requests_completed: self
                .requests_completed
                .saturating_sub(earlier.requests_completed),
            requests_failed: self.requests_failed.saturating_sub(earlier.requests_failed),
            admission_rejected: self
                .admission_rejected
                .saturating_sub(earlier.admission_rejected),
            modes_profiled: self.modes_profiled.saturating_sub(earlier.modes_profiled),
            reboots: self.reboots.saturating_sub(earlier.reboots),
            plane_cache_hits: self.plane_cache_hits.saturating_sub(earlier.plane_cache_hits),
            plane_cache_misses: self
                .plane_cache_misses
                .saturating_sub(earlier.plane_cache_misses),
            model_cache_hits: self.model_cache_hits.saturating_sub(earlier.model_cache_hits),
            model_cache_misses: self
                .model_cache_misses
                .saturating_sub(earlier.model_cache_misses),
            singleflight_waits: self
                .singleflight_waits
                .saturating_sub(earlier.singleflight_waits),
            host_fits: self.host_fits.saturating_sub(earlier.host_fits),
            deadline_misses: self.deadline_misses.saturating_sub(earlier.deadline_misses),
            feedback_observations: self
                .feedback_observations
                .saturating_sub(earlier.feedback_observations),
            drift_trips: self.drift_trips.saturating_sub(earlier.drift_trips),
            refits: self.refits.saturating_sub(earlier.refits),
            stale_served: self.stale_served.saturating_sub(earlier.stale_served),
            retries: self.retries.saturating_sub(earlier.retries),
            breaker_transitions: self
                .breaker_transitions
                .saturating_sub(earlier.breaker_transitions),
            degraded_served: self.degraded_served.saturating_sub(earlier.degraded_served),
            thermal_throttle_events: self
                .thermal_throttle_events
                .saturating_sub(earlier.thermal_throttle_events),
            placement_rejected: self
                .placement_rejected
                .saturating_sub(earlier.placement_rejected),
            cross_shard_transfers_saved: self
                .cross_shard_transfers_saved
                .saturating_sub(earlier.cross_shard_transfers_saved),
            profiling_ms: self.profiling_ms.saturating_sub(earlier.profiling_ms),
            routed,
        }
    }

    /// Element-wise sum — merges per-shard snapshots into a fleet total.
    pub fn merge(&self, other: &CounterSnapshot) -> CounterSnapshot {
        // delta with the zero snapshot inverts nothing; add field-wise
        // via the same exhaustive pattern to stay in lockstep with the
        // field roster
        let mut routed = [0u64; ROUTED_SLOTS];
        for (i, slot) in routed.iter_mut().enumerate() {
            *slot = self.routed[i] + other.routed[i];
        }
        CounterSnapshot {
            requests_received: self.requests_received + other.requests_received,
            requests_completed: self.requests_completed + other.requests_completed,
            requests_failed: self.requests_failed + other.requests_failed,
            admission_rejected: self.admission_rejected + other.admission_rejected,
            modes_profiled: self.modes_profiled + other.modes_profiled,
            reboots: self.reboots + other.reboots,
            plane_cache_hits: self.plane_cache_hits + other.plane_cache_hits,
            plane_cache_misses: self.plane_cache_misses + other.plane_cache_misses,
            model_cache_hits: self.model_cache_hits + other.model_cache_hits,
            model_cache_misses: self.model_cache_misses + other.model_cache_misses,
            singleflight_waits: self.singleflight_waits + other.singleflight_waits,
            host_fits: self.host_fits + other.host_fits,
            deadline_misses: self.deadline_misses + other.deadline_misses,
            feedback_observations: self.feedback_observations + other.feedback_observations,
            drift_trips: self.drift_trips + other.drift_trips,
            refits: self.refits + other.refits,
            stale_served: self.stale_served + other.stale_served,
            retries: self.retries + other.retries,
            breaker_transitions: self.breaker_transitions + other.breaker_transitions,
            degraded_served: self.degraded_served + other.degraded_served,
            thermal_throttle_events: self.thermal_throttle_events + other.thermal_throttle_events,
            placement_rejected: self.placement_rejected + other.placement_rejected,
            cross_shard_transfers_saved: self.cross_shard_transfers_saved
                + other.cross_shard_transfers_saved,
            profiling_ms: self.profiling_ms + other.profiling_ms,
            routed,
        }
    }

    /// Placements routed to `shard` on nodes of `kind`, mirroring
    /// [`Metrics::routed`].
    pub fn routed(&self, kind: DeviceKind, shard: usize) -> u64 {
        self.routed[RoutedLedger::slot(kind, shard)]
    }

    /// Total placements per shard (summed over device kinds).
    pub fn routed_per_shard(&self) -> [u64; MAX_FLEET_SHARDS] {
        let mut per_shard = [0u64; MAX_FLEET_SHARDS];
        for (i, &n) in self.routed.iter().enumerate() {
            per_shard[i % MAX_FLEET_SHARDS] += n;
        }
        per_shard
    }

    /// Total placements routed, mirroring [`Metrics::routed_total`].
    pub fn routed_total(&self) -> u64 {
        self.routed.iter().sum()
    }

    /// Deterministic JSON form: every scalar counter under its field
    /// name, plus the routed grid as `routed.<kind>` arrays trimmed to
    /// the highest shard that actually received a placement (kinds with
    /// zero placements are omitted; an empty fleet emits `routed: {}`).
    pub fn to_json(&self) -> Value {
        let num = |v: u64| Value::Num(v as f64);
        let mut routed_entries: Vec<(&str, Value)> = Vec::new();
        for (k, kind) in DeviceKind::ALL.iter().enumerate() {
            let row = &self.routed[k * MAX_FLEET_SHARDS..(k + 1) * MAX_FLEET_SHARDS];
            let used = row.iter().rposition(|&n| n > 0).map_or(0, |i| i + 1);
            if used > 0 {
                routed_entries.push((
                    kind.name(),
                    Value::Arr(row[..used].iter().map(|&n| num(n)).collect()),
                ));
            }
        }
        Value::obj(vec![
            ("requests_received", num(self.requests_received)),
            ("requests_completed", num(self.requests_completed)),
            ("requests_failed", num(self.requests_failed)),
            ("admission_rejected", num(self.admission_rejected)),
            ("modes_profiled", num(self.modes_profiled)),
            ("reboots", num(self.reboots)),
            ("plane_cache_hits", num(self.plane_cache_hits)),
            ("plane_cache_misses", num(self.plane_cache_misses)),
            ("model_cache_hits", num(self.model_cache_hits)),
            ("model_cache_misses", num(self.model_cache_misses)),
            ("singleflight_waits", num(self.singleflight_waits)),
            ("host_fits", num(self.host_fits)),
            ("deadline_misses", num(self.deadline_misses)),
            ("feedback_observations", num(self.feedback_observations)),
            ("drift_trips", num(self.drift_trips)),
            ("refits", num(self.refits)),
            ("stale_served", num(self.stale_served)),
            ("retries", num(self.retries)),
            ("breaker_transitions", num(self.breaker_transitions)),
            ("degraded_served", num(self.degraded_served)),
            ("thermal_throttle_events", num(self.thermal_throttle_events)),
            ("placement_rejected", num(self.placement_rejected)),
            ("cross_shard_transfers_saved", num(self.cross_shard_transfers_saved)),
            ("profiling_ms", num(self.profiling_ms)),
            ("routed", Value::obj(routed_entries)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_latency() {
        let m = Metrics::new();
        m.requests_received.fetch_add(3, Ordering::Relaxed);
        m.record_completion(0);
        m.record_completion(1);
        m.add_profiling_s(90.0);
        m.observe_latency_ms(10.0);
        m.observe_latency_ms(20.0);
        m.observe_latency_ms(120.0);
        let (p50, p95, max) = m.latency_summary_ms();
        assert_eq!(p50, 20.0);
        assert!(p95 > 20.0 && p95 <= 120.0);
        assert_eq!(max, 120.0);
        assert!((m.profiling_s() - 90.0).abs() < 0.01);
        assert_eq!(m.requests_completed.load(Ordering::Relaxed), 2);
        let r = m.render();
        assert!(r.contains("3 received"));
    }

    #[test]
    fn empty_latencies_are_zero() {
        let m = Metrics::new();
        assert_eq!(m.latency_summary_ms(), (0.0, 0.0, 0.0));
    }

    #[test]
    fn sub_millisecond_profiling_rounds_instead_of_truncating() {
        // regression: `(s * 1000.0) as u64` truncated 0.6 ms to 0 per
        // call, so streams of short profiling runs never accumulated
        let m = Metrics::new();
        for _ in 0..5 {
            m.add_profiling_s(0.0006);
        }
        assert!((m.profiling_s() - 0.005).abs() < 1e-9, "{}", m.profiling_s());
        // exact values stay exact
        let m2 = Metrics::new();
        m2.add_profiling_s(90.0);
        assert!((m2.profiling_s() - 90.0).abs() < 1e-9);
    }

    #[test]
    fn failures_are_all_reported_in_id_order() {
        let m = Metrics::new();
        m.record_failure(9, &Error::Optimization("no feasible mode".into()));
        m.record_failure(2, &Error::Usage("bad budget".into()));
        assert_eq!(m.requests_failed.load(Ordering::Relaxed), 2);
        assert_eq!(m.failed_ids(), vec![2, 9]);
        let failed = m.failed_requests();
        assert!(failed[0].1.contains("bad budget"));
        assert!(failed[1].1.contains("no feasible mode"));
        // the render string surfaces every failed id, not just the first
        let r = m.render();
        assert!(r.contains("failed ids: [2, 9]"), "{r}");
    }

    #[test]
    fn completion_order_is_recorded() {
        let m = Metrics::new();
        m.record_completion(5);
        m.record_completion(1);
        m.record_completion(3);
        assert_eq!(m.completion_order(), vec![5, 1, 3]);
    }

    #[test]
    fn failure_ledger_is_bounded_but_counter_keeps_counting() {
        let m = Metrics::new();
        for id in 0..(MAX_COMPLETION_LEDGER as u64 + 3) {
            m.record_failure(id, &Error::Optimization("infeasible".into()));
        }
        assert_eq!(m.failed_requests().len(), MAX_COMPLETION_LEDGER);
        assert_eq!(
            m.requests_failed.load(Ordering::Relaxed),
            MAX_COMPLETION_LEDGER as u64 + 3
        );
    }

    #[test]
    fn completion_ledger_is_bounded_but_counter_keeps_counting() {
        let m = Metrics::new();
        for id in 0..(MAX_COMPLETION_LEDGER as u64 + 5) {
            m.record_completion(id);
        }
        assert_eq!(m.completion_order().len(), MAX_COMPLETION_LEDGER);
        assert_eq!(
            m.requests_completed.load(Ordering::Relaxed),
            MAX_COMPLETION_LEDGER as u64 + 5
        );
    }

    #[test]
    fn latency_window_is_bounded_but_quantiles_stay_exact() {
        let m = Metrics::new();
        for i in 0..(MAX_LATENCY_SAMPLES + 10) {
            m.observe_latency_ms(i as f64);
        }
        // only the first window is retained; overflow is dropped, not
        // wrapped or torn
        let (p50, _, max) = m.latency_summary_ms();
        assert_eq!(max, (MAX_LATENCY_SAMPLES - 1) as f64);
        assert!(p50 < max);
    }

    #[test]
    fn concurrent_recording_loses_nothing_within_the_window() {
        // the lock-free ledgers must capture every completed record when
        // many workers record at once (claim slots race-free, no torn or
        // dropped slots below capacity)
        let m = Metrics::new();
        const THREADS: u64 = 8;
        const PER: u64 = 500;
        std::thread::scope(|s| {
            for t in 0..THREADS {
                let m = &m;
                s.spawn(move || {
                    for i in 0..PER {
                        m.record_completion(t * PER + i);
                        m.observe_latency_ms((t * PER + i) as f64 + 0.5);
                    }
                });
            }
        });
        let mut ids = m.completion_order();
        assert_eq!(ids.len(), (THREADS * PER) as usize);
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), (THREADS * PER) as usize, "duplicated or torn ids");
        assert_eq!(m.requests_completed.load(Ordering::Relaxed), THREADS * PER);
        let (_, _, max) = m.latency_summary_ms();
        assert_eq!(max, (THREADS * PER - 1) as f64 + 0.5);
    }

    #[test]
    fn lifecycle_counters_are_rendered() {
        let m = Metrics::new();
        m.feedback_observations.fetch_add(12, Ordering::Relaxed);
        m.drift_trips.fetch_add(1, Ordering::Relaxed);
        m.refits.fetch_add(1, Ordering::Relaxed);
        m.stale_served.fetch_add(3, Ordering::Relaxed);
        let r = m.render();
        assert!(
            r.contains("lifecycle: 12 observations, 1 drift trips, 1 refits, 3 stale-served"),
            "{r}"
        );
    }

    #[test]
    fn failure_ledger_tags_error_class_and_kind() {
        let m = Metrics::new();
        m.record_failure(4, &Error::Training("fit diverged".into()));
        m.record_failure(7, &Error::CircuitOpen("model build cooling down".into()));
        let failed = m.failed_requests();
        assert!(failed[0].1.starts_with("[transient training]"), "{}", failed[0].1);
        assert!(failed[0].1.contains("fit diverged"));
        assert!(failed[1].1.starts_with("[permanent circuit-open]"), "{}", failed[1].1);
    }

    #[test]
    fn resilience_counters_are_rendered() {
        let m = Metrics::new();
        m.retries.fetch_add(5, Ordering::Relaxed);
        m.breaker_transitions.fetch_add(3, Ordering::Relaxed);
        m.degraded_served.fetch_add(2, Ordering::Relaxed);
        m.thermal_throttle_events.fetch_add(1, Ordering::Relaxed);
        let r = m.render();
        assert!(
            r.contains("resilience: 5 retries, 3 breaker transitions, 2 degraded served, 1 thermal throttles"),
            "{r}"
        );
    }

    #[test]
    fn fleet_counters_are_ledgered_per_kind_and_shard_and_rendered() {
        let m = Metrics::new();
        // a plain coordinator never shows the fleet segment
        assert!(!m.render().contains("fleet:"), "{}", m.render());
        m.note_routed(DeviceKind::OrinAgx, 0);
        m.note_routed(DeviceKind::OrinAgx, 0);
        m.note_routed(DeviceKind::OrinAgx, 3);
        m.note_routed(DeviceKind::XavierAgx, 1);
        m.placement_rejected.fetch_add(1, Ordering::Relaxed);
        m.cross_shard_transfers_saved.fetch_add(4, Ordering::Relaxed);
        assert_eq!(m.routed(DeviceKind::OrinAgx, 0), 2);
        assert_eq!(m.routed(DeviceKind::OrinAgx, 3), 1);
        assert_eq!(m.routed(DeviceKind::XavierAgx, 1), 1);
        assert_eq!(m.routed(DeviceKind::OrinNano, 0), 0);
        assert_eq!(m.routed_total(), 4);
        // shards beyond the ledger aggregate into the last slot instead
        // of panicking
        m.note_routed(DeviceKind::OrinNano, MAX_FLEET_SHARDS + 7);
        assert_eq!(m.routed(DeviceKind::OrinNano, MAX_FLEET_SHARDS - 1), 1);
        let r = m.render();
        assert!(r.contains("fleet: 5 routed"), "{r}");
        assert!(r.contains("orin-agx 3 [s0:2 s3:1]"), "{r}");
        assert!(r.contains("1 placement rejected"), "{r}");
        assert!(r.contains("4 cross-shard transfers saved"), "{r}");
    }

    #[test]
    fn counter_snapshots_delta_merge_and_serialize() {
        let m = Metrics::new();
        m.requests_received.fetch_add(3, Ordering::Relaxed);
        m.record_completion(0);
        m.note_routed(DeviceKind::OrinAgx, 1);
        m.add_profiling_s(2.0);
        let warmup = m.counters();
        // ... the measured phase moves some counters further ...
        m.requests_received.fetch_add(5, Ordering::Relaxed);
        m.record_completion(1);
        m.record_completion(2);
        m.plane_cache_hits.fetch_add(4, Ordering::Relaxed);
        m.note_routed(DeviceKind::OrinAgx, 1);
        m.note_routed(DeviceKind::OrinNano, 0);
        let measured = m.counters().delta(&warmup);
        assert_eq!(measured.requests_received, 5);
        assert_eq!(measured.requests_completed, 2);
        assert_eq!(measured.plane_cache_hits, 4);
        assert_eq!(measured.profiling_ms, 0, "warm-up profiling must not leak");
        assert_eq!(measured.routed(DeviceKind::OrinAgx, 1), 1);
        assert_eq!(measured.routed(DeviceKind::OrinNano, 0), 1);
        assert_eq!(measured.routed_total(), 2);
        let per_shard = measured.routed_per_shard();
        assert_eq!(per_shard[0], 1);
        assert_eq!(per_shard[1], 1);
        // merge is element-wise: delta(warmup) + warmup == live
        let merged = measured.merge(&warmup);
        assert_eq!(merged, m.counters());
        // deterministic JSON: scalar counters by field name, routed grid
        // trimmed per kind, zero kinds omitted
        let json = measured.to_json().to_string();
        assert!(json.contains("\"requests_received\":5"), "{json}");
        assert!(json.contains("\"orin-agx\":[0,1]"), "{json}");
        assert!(json.contains("\"orin-nano\":[1]"), "{json}");
        assert!(!json.contains("xavier"), "{json}");
        assert_eq!(json, measured.to_json().to_string());
    }

    #[test]
    fn latency_samples_are_exposed_in_claim_order() {
        let m = Metrics::new();
        m.observe_latency_ms(3.5);
        m.observe_latency_ms(1.25);
        assert_eq!(m.latencies_ms(), vec![3.5, 1.25]);
    }

    #[test]
    fn no_failures_means_no_failed_ids_in_render() {
        let m = Metrics::new();
        assert!(m.failed_ids().is_empty());
        assert!(!m.render().contains("failed ids"));
    }
}
