//! Coordinator metrics: thread-safe counters and latency histograms for
//! the serving loop (throughput / latency reporting of the e2e driver).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Monotonic counters + latency samples. Shared across workers via `Arc`.
#[derive(Debug, Default)]
pub struct Metrics {
    pub requests_received: AtomicU64,
    pub requests_completed: AtomicU64,
    pub requests_failed: AtomicU64,
    pub modes_profiled: AtomicU64,
    pub reboots: AtomicU64,
    /// Grid-resident serve-plane cache hits/misses (host path): a hit
    /// answers from the cached Pareto front in O(log front); a miss pays
    /// the full grid prediction + front build.
    pub plane_cache_hits: AtomicU64,
    pub plane_cache_misses: AtomicU64,
    /// Per-workload model cache hits/misses (host path): a hit reuses the
    /// transferred/scratch-trained checkpoints; a miss pays online
    /// profiling plus two host fits.
    pub model_cache_hits: AtomicU64,
    pub model_cache_misses: AtomicU64,
    /// Host-native model fits performed (transfer or scratch; two per
    /// model-cache miss — one per prediction target).
    pub host_fits: AtomicU64,
    /// Simulated device-seconds spent profiling.
    profiling_ms: AtomicU64,
    /// Wall-clock request latencies (ms).
    latencies_ms: Mutex<Vec<f64>>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    /// Accumulate simulated profiling seconds. Rounds to the nearest
    /// millisecond — truncation made many sub-millisecond additions
    /// undercount to zero — and rejects negative durations loudly in
    /// debug builds (saturating to zero in release instead of wrapping
    /// a negative cast through u64).
    pub fn add_profiling_s(&self, s: f64) {
        debug_assert!(s >= 0.0, "negative profiling duration: {s}");
        self.profiling_ms
            .fetch_add((s.max(0.0) * 1000.0).round() as u64, Ordering::Relaxed);
    }

    pub fn profiling_s(&self) -> f64 {
        self.profiling_ms.load(Ordering::Relaxed) as f64 / 1000.0
    }

    pub fn observe_latency_ms(&self, ms: f64) {
        self.latencies_ms.lock().unwrap().push(ms);
    }

    /// (p50, p95, max) latency in ms.
    pub fn latency_summary_ms(&self) -> (f64, f64, f64) {
        let lat = self.latencies_ms.lock().unwrap();
        if lat.is_empty() {
            return (0.0, 0.0, 0.0);
        }
        let p50 = crate::util::stats::quantile(&lat, 0.5);
        let p95 = crate::util::stats::quantile(&lat, 0.95);
        let max = lat.iter().cloned().fold(0.0, f64::max);
        (p50, p95, max)
    }

    pub fn render(&self) -> String {
        let (p50, p95, max) = self.latency_summary_ms();
        format!(
            "requests: {} received, {} completed, {} failed | modes profiled: {} | reboots: {} | plane cache: {} hits / {} misses | model cache: {} hits / {} misses | host fits: {} | simulated profiling: {:.1} min | latency ms (p50/p95/max): {:.0}/{:.0}/{:.0}",
            self.requests_received.load(Ordering::Relaxed),
            self.requests_completed.load(Ordering::Relaxed),
            self.requests_failed.load(Ordering::Relaxed),
            self.modes_profiled.load(Ordering::Relaxed),
            self.reboots.load(Ordering::Relaxed),
            self.plane_cache_hits.load(Ordering::Relaxed),
            self.plane_cache_misses.load(Ordering::Relaxed),
            self.model_cache_hits.load(Ordering::Relaxed),
            self.model_cache_misses.load(Ordering::Relaxed),
            self.host_fits.load(Ordering::Relaxed),
            self.profiling_s() / 60.0,
            p50,
            p95,
            max,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_latency() {
        let m = Metrics::new();
        m.requests_received.fetch_add(3, Ordering::Relaxed);
        m.requests_completed.fetch_add(2, Ordering::Relaxed);
        m.add_profiling_s(90.0);
        m.observe_latency_ms(10.0);
        m.observe_latency_ms(20.0);
        m.observe_latency_ms(120.0);
        let (p50, p95, max) = m.latency_summary_ms();
        assert_eq!(p50, 20.0);
        assert!(p95 > 20.0 && p95 <= 120.0);
        assert_eq!(max, 120.0);
        assert!((m.profiling_s() - 90.0).abs() < 0.01);
        let r = m.render();
        assert!(r.contains("3 received"));
    }

    #[test]
    fn empty_latencies_are_zero() {
        let m = Metrics::new();
        assert_eq!(m.latency_summary_ms(), (0.0, 0.0, 0.0));
    }

    #[test]
    fn sub_millisecond_profiling_rounds_instead_of_truncating() {
        // regression: `(s * 1000.0) as u64` truncated 0.6 ms to 0 per
        // call, so streams of short profiling runs never accumulated
        let m = Metrics::new();
        for _ in 0..5 {
            m.add_profiling_s(0.0006);
        }
        assert!((m.profiling_s() - 0.005).abs() < 1e-9, "{}", m.profiling_s());
        // exact values stay exact
        let m2 = Metrics::new();
        m2.add_profiling_s(90.0);
        assert!((m2.profiling_s() - 90.0).abs() < 1e-9);
    }
}
