//! Scenario policies (paper Table 1): which solution approach fits which
//! deployment scenario, based on training duration and workload churn —
//! plus the retry policy the resilient serving loop applies to transient
//! pipeline-stage failures.

use std::fmt;

use crate::util::rng::Rng;

/// Deployment scenario for an arriving training request (paper Table 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scenario {
    /// One-time training of a large model over days.
    OneTimeTraining,
    /// Occasional fine-tuning of a pre-trained DNN (a few hours).
    FineTuning,
    /// Periodic continuous learning (< 1 hour per round).
    ContinuousLearning,
    /// Federated learning on a shared edge cloud: frequent, unknown
    /// workloads and durations.
    FederatedLearning,
}

impl Scenario {
    /// Every scenario, in paper Table 1 order.
    pub const ALL: [Scenario; 4] = [
        Scenario::OneTimeTraining,
        Scenario::FineTuning,
        Scenario::ContinuousLearning,
        Scenario::FederatedLearning,
    ];

    /// Scheduling class for the coordinator's ingress queue (higher pops
    /// first). Short, frequent jobs — federated rounds, continuous-
    /// learning updates, whose whole point is a fast turnaround on a
    /// 50-mode profile + transfer — overtake the long tail: a queued
    /// brute-force profiling job (one-time training, 1200–1800 min of
    /// data collection per paper Table 1) must never head-of-line-block
    /// them on a busy fleet.
    pub fn priority(self) -> u8 {
        match self {
            Scenario::FederatedLearning => 3,
            Scenario::ContinuousLearning => 2,
            Scenario::FineTuning => 1,
            Scenario::OneTimeTraining => 0,
        }
    }

    pub fn parse(s: &str) -> Option<Scenario> {
        match s {
            "one-time" => Some(Scenario::OneTimeTraining),
            "fine-tuning" => Some(Scenario::FineTuning),
            "continuous" => Some(Scenario::ContinuousLearning),
            "federated" => Some(Scenario::FederatedLearning),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Scenario::OneTimeTraining => "one-time",
            Scenario::FineTuning => "fine-tuning",
            Scenario::ContinuousLearning => "continuous",
            Scenario::FederatedLearning => "federated",
        }
    }
}

/// How the coordinator solves an optimization request.
/// `Hash` because the strategy is part of the host model-cache key
/// (`coordinator::cache::ModelKey`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Strategy {
    /// Profile every mode of the (subset) grid, pick the ground-truth
    /// optimum. 1200–1800 min of data collection (paper Table 1).
    BruteForce,
    /// Train an NN from scratch on `n` profiled modes (fine-tuning
    /// scenario: >= 100 modes are affordable).
    NnProfiled(usize),
    /// PowerTrain: transfer the reference models using `n` profiled modes.
    PowerTrain(usize),
}

impl Strategy {
    /// Paper Table 1's recommendation per scenario.
    pub fn for_scenario(s: Scenario) -> Strategy {
        match s {
            Scenario::OneTimeTraining => Strategy::BruteForce,
            Scenario::FineTuning => Strategy::NnProfiled(100),
            Scenario::ContinuousLearning => Strategy::PowerTrain(50),
            Scenario::FederatedLearning => Strategy::PowerTrain(50),
        }
    }

    /// Number of modes this strategy profiles online.
    pub fn profiling_modes(&self, grid_size: usize) -> usize {
        match self {
            Strategy::BruteForce => grid_size,
            Strategy::NnProfiled(n) | Strategy::PowerTrain(n) => *n,
        }
    }
}

/// Retry policy for transient pipeline-stage failures: capped exponential
/// backoff with deterministic jitter. The jitter is a pure hash of
/// `(seed, attempt)` — not a shared RNG stream — so a chaos run replays
/// the exact same delays under the same fault plan regardless of worker
/// scheduling.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RetryPolicy {
    /// Retries after the first attempt (so up to `max_retries + 1`
    /// attempts total).
    pub max_retries: u32,
    /// Backoff before retry 1 (doubles per retry).
    pub base_ms: u64,
    /// Backoff ceiling.
    pub cap_ms: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy { max_retries: 3, base_ms: 5, cap_ms: 80 }
    }
}

impl RetryPolicy {
    /// Backoff before retrying `attempt` (0-based: the delay between
    /// attempt N and attempt N+1). Deterministic in `(seed, attempt)`;
    /// jittered within `[ceil(capped/2), capped]` where
    /// `capped = min(base * 2^attempt, cap)`.
    pub fn backoff_ms(&self, seed: u64, attempt: u32) -> u64 {
        let exp = self.base_ms.saturating_mul(1u64 << attempt.min(20));
        let capped = exp.min(self.cap_ms).max(1);
        let low = capped - capped / 2;
        let span = (capped / 2 + 1) as usize;
        let jitter = Rng::new(seed ^ 0x6263_6b6f_6666) // "bckoff"
            .split(attempt as u64)
            .below(span) as u64;
        low + jitter
    }
}

impl fmt::Display for Strategy {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Strategy::BruteForce => write!(f, "brute-force"),
            Strategy::NnProfiled(n) => write!(f, "nn-{n}"),
            Strategy::PowerTrain(n) => write!(f, "powertrain-{n}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_mapping() {
        assert_eq!(Strategy::for_scenario(Scenario::OneTimeTraining), Strategy::BruteForce);
        assert_eq!(Strategy::for_scenario(Scenario::FineTuning), Strategy::NnProfiled(100));
        assert_eq!(Strategy::for_scenario(Scenario::ContinuousLearning), Strategy::PowerTrain(50));
        assert_eq!(Strategy::for_scenario(Scenario::FederatedLearning), Strategy::PowerTrain(50));
    }

    #[test]
    fn profiling_mode_counts() {
        assert_eq!(Strategy::BruteForce.profiling_modes(4368), 4368);
        assert_eq!(Strategy::PowerTrain(50).profiling_modes(4368), 50);
        assert_eq!(Strategy::NnProfiled(100).profiling_modes(4368), 100);
    }

    #[test]
    fn scenario_names_round_trip() {
        for s in Scenario::ALL {
            assert_eq!(Scenario::parse(s.name()), Some(s));
        }
    }

    #[test]
    fn backoff_is_deterministic_and_bounded() {
        let p = RetryPolicy::default();
        for attempt in 0..6 {
            let a = p.backoff_ms(42, attempt);
            let b = p.backoff_ms(42, attempt);
            assert_eq!(a, b, "jitter must be a pure function of (seed, attempt)");
            let capped = (p.base_ms << attempt.min(20)).min(p.cap_ms);
            assert!(a >= capped - capped / 2 && a <= capped, "attempt {attempt}: {a}");
        }
        // different seeds decorrelate the jitter (not all identical)
        let delays: Vec<u64> = (0..32).map(|s| p.backoff_ms(s, 3)).collect();
        assert!(delays.iter().any(|&d| d != delays[0]));
    }

    #[test]
    fn backoff_grows_then_saturates_at_cap() {
        let p = RetryPolicy { max_retries: 10, base_ms: 5, cap_ms: 80 };
        // lower bound of the jitter window doubles until the cap
        assert!(p.backoff_ms(7, 0) <= 5);
        assert!(p.backoff_ms(7, 4) <= 80);
        for attempt in 4..12 {
            let d = p.backoff_ms(7, attempt);
            assert!(d >= 40 && d <= 80, "attempt {attempt}: {d}");
        }
        // huge attempt numbers must not overflow the shift
        let _ = p.backoff_ms(7, u32::MAX);
    }

    #[test]
    fn short_scenarios_outrank_long_ones() {
        // the scheduling invariant the streaming queue relies on: both
        // PowerTrain short-job scenarios strictly outrank fine-tuning,
        // which strictly outranks brute-force one-time training
        assert!(Scenario::FederatedLearning.priority() > Scenario::FineTuning.priority());
        assert!(Scenario::ContinuousLearning.priority() > Scenario::FineTuning.priority());
        assert!(Scenario::FineTuning.priority() > Scenario::OneTimeTraining.priority());
    }
}
