//! Scenario policies (paper Table 1): which solution approach fits which
//! deployment scenario, based on training duration and workload churn.

use std::fmt;

/// Deployment scenario for an arriving training request (paper Table 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scenario {
    /// One-time training of a large model over days.
    OneTimeTraining,
    /// Occasional fine-tuning of a pre-trained DNN (a few hours).
    FineTuning,
    /// Periodic continuous learning (< 1 hour per round).
    ContinuousLearning,
    /// Federated learning on a shared edge cloud: frequent, unknown
    /// workloads and durations.
    FederatedLearning,
}

impl Scenario {
    /// Every scenario, in paper Table 1 order.
    pub const ALL: [Scenario; 4] = [
        Scenario::OneTimeTraining,
        Scenario::FineTuning,
        Scenario::ContinuousLearning,
        Scenario::FederatedLearning,
    ];

    /// Scheduling class for the coordinator's ingress queue (higher pops
    /// first). Short, frequent jobs — federated rounds, continuous-
    /// learning updates, whose whole point is a fast turnaround on a
    /// 50-mode profile + transfer — overtake the long tail: a queued
    /// brute-force profiling job (one-time training, 1200–1800 min of
    /// data collection per paper Table 1) must never head-of-line-block
    /// them on a busy fleet.
    pub fn priority(self) -> u8 {
        match self {
            Scenario::FederatedLearning => 3,
            Scenario::ContinuousLearning => 2,
            Scenario::FineTuning => 1,
            Scenario::OneTimeTraining => 0,
        }
    }

    pub fn parse(s: &str) -> Option<Scenario> {
        match s {
            "one-time" => Some(Scenario::OneTimeTraining),
            "fine-tuning" => Some(Scenario::FineTuning),
            "continuous" => Some(Scenario::ContinuousLearning),
            "federated" => Some(Scenario::FederatedLearning),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Scenario::OneTimeTraining => "one-time",
            Scenario::FineTuning => "fine-tuning",
            Scenario::ContinuousLearning => "continuous",
            Scenario::FederatedLearning => "federated",
        }
    }
}

/// How the coordinator solves an optimization request.
/// `Hash` because the strategy is part of the host model-cache key
/// (`coordinator::cache::ModelKey`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Strategy {
    /// Profile every mode of the (subset) grid, pick the ground-truth
    /// optimum. 1200–1800 min of data collection (paper Table 1).
    BruteForce,
    /// Train an NN from scratch on `n` profiled modes (fine-tuning
    /// scenario: >= 100 modes are affordable).
    NnProfiled(usize),
    /// PowerTrain: transfer the reference models using `n` profiled modes.
    PowerTrain(usize),
}

impl Strategy {
    /// Paper Table 1's recommendation per scenario.
    pub fn for_scenario(s: Scenario) -> Strategy {
        match s {
            Scenario::OneTimeTraining => Strategy::BruteForce,
            Scenario::FineTuning => Strategy::NnProfiled(100),
            Scenario::ContinuousLearning => Strategy::PowerTrain(50),
            Scenario::FederatedLearning => Strategy::PowerTrain(50),
        }
    }

    /// Number of modes this strategy profiles online.
    pub fn profiling_modes(&self, grid_size: usize) -> usize {
        match self {
            Strategy::BruteForce => grid_size,
            Strategy::NnProfiled(n) | Strategy::PowerTrain(n) => *n,
        }
    }
}

impl fmt::Display for Strategy {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Strategy::BruteForce => write!(f, "brute-force"),
            Strategy::NnProfiled(n) => write!(f, "nn-{n}"),
            Strategy::PowerTrain(n) => write!(f, "powertrain-{n}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_mapping() {
        assert_eq!(Strategy::for_scenario(Scenario::OneTimeTraining), Strategy::BruteForce);
        assert_eq!(Strategy::for_scenario(Scenario::FineTuning), Strategy::NnProfiled(100));
        assert_eq!(Strategy::for_scenario(Scenario::ContinuousLearning), Strategy::PowerTrain(50));
        assert_eq!(Strategy::for_scenario(Scenario::FederatedLearning), Strategy::PowerTrain(50));
    }

    #[test]
    fn profiling_mode_counts() {
        assert_eq!(Strategy::BruteForce.profiling_modes(4368), 4368);
        assert_eq!(Strategy::PowerTrain(50).profiling_modes(4368), 50);
        assert_eq!(Strategy::NnProfiled(100).profiling_modes(4368), 100);
    }

    #[test]
    fn scenario_names_round_trip() {
        for s in Scenario::ALL {
            assert_eq!(Scenario::parse(s.name()), Some(s));
        }
    }

    #[test]
    fn short_scenarios_outrank_long_ones() {
        // the scheduling invariant the streaming queue relies on: both
        // PowerTrain short-job scenarios strictly outrank fine-tuning,
        // which strictly outranks brute-force one-time training
        assert!(Scenario::FederatedLearning.priority() > Scenario::FineTuning.priority());
        assert!(Scenario::ContinuousLearning.priority() > Scenario::FineTuning.priority());
        assert!(Scenario::FineTuning.priority() > Scenario::OneTimeTraining.priority());
    }
}
