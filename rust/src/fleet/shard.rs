//! Sharded coordinator domains: N independent [`Coordinator`]s behind
//! one fleet front-end.
//!
//! Each shard owns its own [`PlaneCache`], ingress queue, worker pool
//! and (when configured) refit worker — nothing but the fleet-level
//! metrics is shared, so singleflight and drift state never cross
//! shards and a poisoned domain cannot wedge its siblings. [`ModelKey`]s
//! are hash-partitioned ([`ModelKey::shard_index`]) so identical keys
//! always land on the same domain and distinct keys never contend.
//!
//! **Once-fleet-wide transfer.** [`Fleet::submit`] pins every request to
//! the fleet's canonical seed, so all requests for one (device kind,
//! workload, strategy) share one [`ModelKey`]. The first such submission
//! runs the host transfer exactly once — through the same
//! [`fit_models_for_request`] path a shard's cache-miss lane would — and
//! publishes the pair into the owning shard's versioned Ready slot
//! ([`PlaneCache::publish_models`]); every later submission, whatever
//! shard or node it routes to, is a snapshot cache hit. The fleet
//! metrics carry the profiling/fit cost; the shards' own `host_fits`
//! stay zero, which is precisely the acceptance assert.

use std::collections::HashSet;
use std::sync::{Arc, Mutex};

use crate::coordinator::pipeline::fit_models_for_request;
use crate::coordinator::{
    Coordinator, CoordinatorConfig, Job, Metrics, ModelKey, Provenance, ReferenceModels, Request,
    Response, Strategy, Submitter,
};
use crate::error::{Error, Result};
use crate::fleet::index::{route_indexed, IndexedSnapshot};
use crate::fleet::registry::FleetRegistry;
use crate::fleet::router::Placement;
use crate::util::arc_cell::ArcCell;
use crate::util::sync::lock_unpoisoned;

/// Fleet configuration: how many coordinator domains, how many nodes,
/// and the canonical seed every fleet request is pinned to (the pin is
/// what lets per-kind model keys coalesce fleet-wide).
#[derive(Debug, Clone)]
pub struct FleetConfig {
    /// Independent coordinator domains (each with its own cache/queue/
    /// refit worker).
    pub shards: usize,
    /// Simulated nodes synthesized into the registry.
    pub nodes: usize,
    /// Canonical model seed + registry synthesis seed. Same seed ⇒
    /// bit-identical registry, placements and model pairs.
    pub seed: u64,
    /// Simulated seconds each heartbeat advances the fleet (one
    /// heartbeat runs before every placement decision).
    pub heartbeat_slice_s: f64,
    /// Per-shard coordinator configuration (shard labels are stamped on
    /// top of this per domain).
    pub coordinator: CoordinatorConfig,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            shards: 4,
            nodes: 64,
            seed: 1,
            heartbeat_slice_s: 30.0,
            coordinator: CoordinatorConfig::default(),
        }
    }
}

/// One coordinator domain plus its live submission handle.
struct ShardHandle {
    coordinator: Coordinator,
    /// `Some` while the fleet accepts submissions; taken (dropped) at
    /// [`Fleet::finish`] so the domain's queue closes.
    submitter: Option<Submitter>,
}

/// What [`Fleet::finish`] returns: the merged responses plus every
/// metrics handle, fleet-level and per-shard.
pub struct FleetOutcome {
    /// All responses across all shards, sorted by request id.
    pub responses: Vec<Response>,
    /// Fleet-level metrics: routing ledgers, placement rejections,
    /// transfers saved, and the once-fleet-wide profiling/fit cost.
    pub fleet: Arc<Metrics>,
    /// Per-shard serving metrics, indexed by shard.
    pub shards: Vec<Arc<Metrics>>,
}

/// The fleet front-end: routes requests onto registry nodes and
/// dispatches them to hash-partitioned coordinator domains.
pub struct Fleet {
    cfg: FleetConfig,
    reference: ReferenceModels,
    ref_fps: (u64, u64),
    registry: Mutex<FleetRegistry>,
    /// The registry's lock-free publication handle: heartbeat-granular
    /// indexed snapshots readable without the registry mutex.
    published: Arc<ArcCell<IndexedSnapshot>>,
    shards: Vec<ShardHandle>,
    metrics: Arc<Metrics>,
    /// Model keys whose pair has already been transferred fleet-wide.
    /// Guards the once-per-key fit; held across the fit so concurrent
    /// submitters of a new key cannot race a duplicate transfer.
    transferred: Mutex<HashSet<ModelKey>>,
    /// Requests the router placed away from their first-choice node;
    /// their primary responses are re-stamped `DegradedPlacement`.
    rerouted_ids: Mutex<Vec<u64>>,
    /// Queue-clock instant of the first paced submission. Paced arrivals
    /// advance the registry clock relative to this base, so the
    /// simulated fleet ages with the load schedule instead of by the
    /// fixed per-placement heartbeat slice.
    paced_base_ms: Mutex<Option<u64>>,
}

impl Fleet {
    /// Synthesize the registry and spawn every coordinator domain.
    pub fn start(cfg: FleetConfig, reference: &ReferenceModels) -> Result<Fleet> {
        if cfg.shards == 0 {
            return Err(Error::Usage("fleet needs at least one shard".into()));
        }
        if cfg.nodes == 0 {
            return Err(Error::Usage("fleet needs at least one node".into()));
        }
        let registry = FleetRegistry::synthesize(cfg.nodes, cfg.seed);
        let published = registry.publication();
        let mut shards = Vec::with_capacity(cfg.shards);
        for s in 0..cfg.shards {
            let mut shard_cfg = cfg.coordinator.clone();
            shard_cfg.shard = Some(s as u32);
            let (coordinator, submitter) = Coordinator::start(&shard_cfg, reference)?;
            shards.push(ShardHandle { coordinator, submitter: Some(submitter) });
        }
        Ok(Fleet {
            ref_fps: reference.fingerprints(),
            reference: reference.clone(),
            cfg,
            registry: Mutex::new(registry),
            published,
            shards,
            metrics: Arc::new(Metrics::new()),
            transferred: Mutex::new(HashSet::new()),
            rerouted_ids: Mutex::new(Vec::new()),
            paced_base_ms: Mutex::new(None),
        })
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Fleet-level metrics (live).
    pub fn metrics(&self) -> Arc<Metrics> {
        Arc::clone(&self.metrics)
    }

    /// Current registry snapshot (heartbeat state as of the last
    /// placement).
    pub fn registry_snapshot(&self) -> crate::fleet::registry::RegistrySnapshot {
        lock_unpoisoned(&self.registry).snapshot()
    }

    /// The newest *published* indexed snapshot, read lock-free from the
    /// registry's `ArcCell` — external monitors call this from any
    /// thread without contending with placement. Heartbeat-granular:
    /// placements since the last heartbeat are not yet visible here.
    pub fn indexed_snapshot(&self) -> Arc<IndexedSnapshot> {
        self.published.load()
    }

    /// Route and dispatch one request. The request's `seed` is pinned to
    /// the fleet's canonical seed (model identity is per (kind,
    /// workload, strategy) fleet-wide, not per caller), its `device` is
    /// rewritten to the placed node's kind, and its `node` is stamped
    /// before the owning shard sees it. Returns the placement so callers
    /// can account affinity/reroute decisions; `Err` only when no
    /// healthy capacity exists anywhere or the fleet is shut down.
    pub fn submit(&self, req: Request) -> Result<Placement> {
        self.submit_inner(req, None)
    }

    /// Paced submission for the load engine: route exactly like
    /// [`Fleet::submit`], but enter the owning shard's ingress with
    /// [`Job::arriving`] at `arrival_ms` (queue-clock absolute — rebase
    /// a schedule offset onto [`Fleet::now_ms`]) and an optional
    /// arrival-relative deadline, so the shard's queue holds the job
    /// until its arrival instant and deadline misses are accounted.
    /// Paced submissions also advance the registry clock to the
    /// schedule's simulated time (measured from the first paced arrival)
    /// instead of by the fixed `heartbeat_slice_s`, so node
    /// thermal/health state ages with the offered load.
    pub fn submit_paced(
        &self,
        req: Request,
        arrival_ms: u64,
        deadline_ms: Option<u64>,
    ) -> Result<Placement> {
        self.submit_inner(req, Some((arrival_ms, deadline_ms)))
    }

    /// Milliseconds on the fleet's queue clock (shard 0's queue epoch) —
    /// the base callers rebase paced arrival schedules onto. Shard
    /// epochs differ only by their sequential start instants, so a
    /// schedule rebased here is at worst that skew early on its owning
    /// shard's clock; past arrivals dispatch immediately, in order.
    pub fn now_ms(&self) -> Result<u64> {
        self.shards[0]
            .submitter
            .as_ref()
            .map(|s| s.now_ms())
            .ok_or_else(|| Error::Coordinator("fleet is shut down".into()))
    }

    /// Live per-shard serving metrics, indexed by shard — the load
    /// engine polls these to scope warm-up out of a measured run without
    /// tearing the fleet down between phases.
    pub fn shard_metrics(&self) -> Vec<Arc<Metrics>> {
        self.shards.iter().map(|s| s.coordinator.metrics()).collect()
    }

    fn submit_inner(
        &self,
        mut req: Request,
        paced: Option<(u64, Option<u64>)>,
    ) -> Result<Placement> {
        req.seed = self.cfg.seed;
        let affinity = req.affinity.or(Some(req.device));
        req.affinity = affinity;

        let placement = {
            let mut registry = lock_unpoisoned(&self.registry);
            let dt_s = match paced {
                None => self.cfg.heartbeat_slice_s,
                Some((arrival_ms, _)) => {
                    let mut base = lock_unpoisoned(&self.paced_base_ms);
                    let base_ms = *base.get_or_insert(arrival_ms);
                    let sim_s = arrival_ms.saturating_sub(base_ms) as f64 / 1000.0;
                    (sim_s - registry.clock_s()).max(0.0)
                }
            };
            registry.heartbeat(dt_s, self.cfg.coordinator.faults.as_deref());
            let placement = match route_indexed(registry.indexed(), affinity, &req.workload) {
                Some(p) => p,
                None => {
                    self.metrics
                        .placement_rejected
                        .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    return Err(Error::Coordinator(format!(
                        "request {}: no healthy fleet capacity for affinity {:?}",
                        req.id,
                        affinity.map(|k| k.name())
                    )));
                }
            };
            if placement.cross_kind {
                // the affinity could not be honored at all — count it,
                // but still serve on the fallback kind
                self.metrics
                    .placement_rejected
                    .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            }
            registry.note_placement(placement.node, req.workload);
            placement
        };

        req.device = placement.kind;
        req.node = Some(placement.node);
        if placement.rerouted {
            lock_unpoisoned(&self.rerouted_ids).push(req.id);
        }

        let strategy = Strategy::for_scenario(req.scenario);
        let key = ModelKey::for_request(
            &req,
            strategy,
            self.cfg.coordinator.prediction_grid,
            self.cfg.coordinator.transfer_epochs,
            self.ref_fps,
        );
        let shard_i = key.shard_index(self.shards.len());

        if !matches!(strategy, Strategy::BruteForce) {
            self.ensure_transferred(key, shard_i, &req);
        }

        self.metrics.note_routed(req.device, shard_i);
        let submitter = self.shards[shard_i]
            .submitter
            .as_ref()
            .ok_or_else(|| Error::Coordinator("fleet is shut down".into()))?;
        match paced {
            None => submitter.send_request(req)?,
            Some((arrival_ms, deadline_ms)) => {
                let mut job = Job::arriving(req, arrival_ms);
                if let Some(d) = deadline_ms {
                    job = job.with_deadline(d);
                }
                submitter.send(job)?;
            }
        }
        Ok(placement)
    }

    /// The once-fleet-wide transfer: the first submission of `key` fits
    /// the pair (on the *fleet* metrics — no shard pays for it) and
    /// publishes it into shard `shard_i`'s Ready slot; every later
    /// submission of the same key, from any node, is a saved transfer.
    /// A failed fit is forgotten so the owning shard's resilient lane
    /// (retry → breaker → degradation ladder) handles the request and a
    /// later submission may try the pre-publish again.
    fn ensure_transferred(&self, key: ModelKey, shard_i: usize, req: &Request) {
        let mut transferred = lock_unpoisoned(&self.transferred);
        if transferred.contains(&key) {
            self.metrics
                .cross_shard_transfers_saved
                .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            return;
        }
        match fit_models_for_request(&self.reference, &self.cfg.coordinator, &self.metrics, req) {
            Ok((fit_key, models)) => {
                debug_assert_eq!(fit_key, key, "fleet and pipeline key derivations diverged");
                let _ = self.shards[shard_i].coordinator.cache().publish_models(key, models);
                transferred.insert(key);
            }
            Err(_) => {
                // leave the key unmarked: the shard's own pipeline will
                // surface (and retry/degrade) the failure per request
            }
        }
    }

    /// Close every domain's ingress, drain them all, stamp rerouted
    /// responses, and return the merged outcome. Responses are sorted by
    /// request id across the whole fleet; `Err` is returned only when
    /// *no* request anywhere succeeded.
    pub fn finish(mut self) -> Result<FleetOutcome> {
        let rerouted: HashSet<u64> =
            lock_unpoisoned(&self.rerouted_ids).iter().copied().collect();
        let mut responses = Vec::new();
        let mut shard_metrics = Vec::with_capacity(self.shards.len());
        let mut first_err: Option<Error> = None;
        for shard in &mut self.shards {
            drop(shard.submitter.take());
        }
        for shard in self.shards {
            shard_metrics.push(shard.coordinator.metrics());
            match shard.coordinator.finish() {
                Ok((mut rs, _)) => responses.append(&mut rs),
                Err(e) => {
                    if first_err.is_none() {
                        first_err = Some(e);
                    }
                }
            }
        }
        if responses.is_empty() {
            if let Some(e) = first_err {
                return Err(e);
            }
        }
        for r in &mut responses {
            // placement degradation only overrides a *primary* answer —
            // a Ridge/NPE ladder response already reports worse quality
            if rerouted.contains(&r.id) && r.provenance == Provenance::Primary {
                r.provenance = Provenance::DegradedPlacement;
            }
        }
        responses.sort_by_key(|r| r.id);
        Ok(FleetOutcome { responses, fleet: self.metrics, shards: shard_metrics })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::test_support::{host_cfg, host_reference};
    use crate::coordinator::Scenario;
    use crate::device::DeviceKind;
    use crate::workload::Workload;
    use std::sync::atomic::Ordering;

    fn fleet_cfg(shards: usize, nodes: usize) -> FleetConfig {
        FleetConfig { shards, nodes, coordinator: host_cfg(120), ..Default::default() }
    }

    fn req(id: u64, kind: DeviceKind, workload: Workload) -> Request {
        Request {
            id,
            device: kind,
            workload,
            power_budget_w: 1e6,
            scenario: Scenario::FederatedLearning,
            affinity: Some(kind),
            node: None,
            seed: 999, // overwritten by the canonical fleet seed
        }
    }

    #[test]
    fn mixed_kind_burst_fits_once_per_kind_and_honors_affinity() {
        let reference = host_reference();
        let fleet = Fleet::start(fleet_cfg(4, 12), &reference).unwrap();
        let wl = Workload::mobilenet();
        let mut placements = Vec::new();
        for i in 0..9u64 {
            let kind = DeviceKind::ALL[(i % 3) as usize];
            placements.push(fleet.submit(req(i, kind, wl)).unwrap());
        }
        let snapshot = fleet.registry_snapshot();
        // the lock-free published index tracks the same fleet at
        // heartbeat granularity and is internally consistent
        let indexed = fleet.indexed_snapshot();
        indexed.check_invariants();
        assert_eq!(indexed.len(), snapshot.nodes.len());
        // publication is dirty-gated, so the published clock may lag the
        // live one by quiescent heartbeats but never lead it
        assert!(indexed.clock_s > 0.0 && indexed.clock_s <= snapshot.clock_s);
        let outcome = fleet.finish().unwrap();
        assert_eq!(outcome.responses.len(), 9);
        // every response served on a node of its requested kind
        for (r, p) in outcome.responses.iter().zip(&placements) {
            let node = r.node.expect("fleet responses carry their node");
            assert_eq!(node, p.node);
            let view = snapshot.nodes.iter().find(|n| n.id == node).unwrap();
            assert_eq!(view.kind, DeviceKind::ALL[(r.id % 3) as usize]);
            assert!(!p.cross_kind);
        }
        // exactly one transfer per (kind, workload): 3 keys × 2 fits,
        // all charged to the fleet, none to any shard
        assert_eq!(outcome.fleet.host_fits.load(Ordering::Relaxed), 6);
        for m in &outcome.shards {
            assert_eq!(m.host_fits.load(Ordering::Relaxed), 0);
        }
        // 9 routed, 6 of them saved transfers (first of each kind pays)
        assert_eq!(outcome.fleet.routed_total(), 9);
        assert_eq!(outcome.fleet.cross_shard_transfers_saved.load(Ordering::Relaxed), 6);
        assert_eq!(outcome.fleet.placement_rejected.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn fleet_runs_are_bit_reproducible() {
        let reference = host_reference();
        let run = || {
            let fleet = Fleet::start(fleet_cfg(4, 16), &reference).unwrap();
            let mut placements = Vec::new();
            for i in 0..12u64 {
                let kind = DeviceKind::ALL[(i % 3) as usize];
                let wl = Workload::default_five()[(i % 5) as usize];
                placements.push(fleet.submit(req(i, kind, wl)).unwrap());
            }
            let outcome = fleet.finish().unwrap();
            (placements, outcome)
        };
        let (pa, oa) = run();
        let (pb, ob) = run();
        assert_eq!(pa, pb, "same seed ⇒ identical placements");
        assert_eq!(oa.responses.len(), ob.responses.len());
        for (a, b) in oa.responses.iter().zip(&ob.responses) {
            // everything but wall-clock latency must be bit-identical
            assert_eq!(a.id, b.id);
            assert_eq!(a.node, b.node);
            assert_eq!(a.provenance, b.provenance);
            assert_eq!(a.chosen_mode, b.chosen_mode);
            assert_eq!(a.predicted_time_ms.to_bits(), b.predicted_time_ms.to_bits());
            assert_eq!(a.predicted_power_w.to_bits(), b.predicted_power_w.to_bits());
            assert_eq!(a.observed_time_ms.to_bits(), b.observed_time_ms.to_bits());
            assert_eq!(a.observed_power_w.to_bits(), b.observed_power_w.to_bits());
        }
    }

    #[test]
    fn paced_submission_ages_the_registry_with_the_schedule() {
        let reference = host_reference();
        let fleet = Fleet::start(fleet_cfg(2, 8), &reference).unwrap();
        let base = fleet.now_ms().unwrap();
        // 3 arrivals spread over 4 simulated seconds, generous deadlines
        for (i, offset) in [0u64, 1_500, 4_000].into_iter().enumerate() {
            fleet
                .submit_paced(
                    req(i as u64, DeviceKind::OrinAgx, Workload::mobilenet()),
                    base + offset,
                    Some(120_000),
                )
                .unwrap();
        }
        // the registry clock tracked the schedule (4 s), not the default
        // 30 s-per-placement heartbeat slice (which would read 90 s)
        let clock = fleet.registry_snapshot().clock_s;
        assert!((clock - 4.0).abs() < 1e-9, "registry clock {clock} s");
        let per_shard = fleet.shard_metrics();
        assert_eq!(per_shard.len(), 2);
        let outcome = fleet.finish().unwrap();
        assert_eq!(outcome.responses.len(), 3);
        // same key throughout: one fleet-paid fit, two saved transfers,
        // and the paced path reaches the queue with zero deadline misses
        assert_eq!(outcome.fleet.host_fits.load(Ordering::Relaxed), 2);
        assert_eq!(outcome.fleet.cross_shard_transfers_saved.load(Ordering::Relaxed), 2);
        let misses: u64 = outcome
            .shards
            .iter()
            .map(|m| m.deadline_misses.load(Ordering::Relaxed))
            .sum();
        assert_eq!(misses, 0);
    }

    #[test]
    fn zero_shards_or_nodes_is_a_usage_error() {
        let reference = host_reference();
        assert!(Fleet::start(fleet_cfg(0, 8), &reference).is_err());
        assert!(Fleet::start(fleet_cfg(2, 0), &reference).is_err());
    }
}
