//! Fleet orchestration: the layer above the streaming coordinator that
//! turns the single implicit device pool into a routed, sharded fleet of
//! simulated Jetson nodes (ROADMAP item 1).
//!
//! Concerns, one module each:
//!
//! * [`registry`] — the node registry: thousands of simulated nodes,
//!   each carrying its [`DeviceKind`](crate::device::DeviceKind),
//!   capacity, health, and per-node
//!   [`ThermalModel`](crate::sim::thermal::ThermalModel) /
//!   [`PowerSensor`](crate::sim::PowerSensor) state, with deterministic
//!   registration/heartbeats, a pluggable [`FleetObserver`] proxy for
//!   external observability planes, and an incrementally maintained
//!   [`IndexedSnapshot`] published lock-free through
//!   [`ArcCell`](crate::util::arc_cell::ArcCell);
//! * [`router`] — the placement scoring contract (kind match >
//!   warm-model locality > least-loaded > thermal headroom, node id as
//!   the final tie-break) and the shared [`Placement`] type;
//! * [`index`] — the production placement engine: per-kind candidate
//!   queues + inverted warm-locality bitsets, O(1) peek / O(log k)
//!   update, bit-identical to the reference scan;
//! * [`reference`] — the original linear O(nodes) router, retained as
//!   the executable oracle for the differential property suite;
//! * [`shard`] — N independent [`Coordinator`](crate::coordinator::Coordinator)
//!   domains, [`ModelKey`](crate::coordinator::ModelKey)s
//!   hash-partitioned across them so singleflight and drift state never
//!   cross shards, with the per-device-kind transfer performed **once
//!   fleet-wide** and published into the owning shard's versioned Ready
//!   slots.

pub mod index;
pub mod reference;
pub mod registry;
pub mod router;
pub mod shard;

pub use index::{
    route_burst_indexed, route_indexed, IndexedNode, IndexedSnapshot, WarmSet, WorkloadInterner,
};
pub use reference::{route, route_burst};
pub use registry::{
    FleetObserver, FleetRegistry, NodeHealth, NodeId, NodeView, NoopObserver, RecordingObserver,
    RegistrySnapshot,
};
pub use router::Placement;
pub use shard::{Fleet, FleetConfig, FleetOutcome};
