//! Fleet orchestration: the layer above the streaming coordinator that
//! turns the single implicit device pool into a routed, sharded fleet of
//! simulated Jetson nodes (ROADMAP item 1).
//!
//! Three concerns, one module each:
//!
//! * [`registry`] — the node registry: thousands of simulated nodes,
//!   each carrying its [`DeviceKind`](crate::device::DeviceKind),
//!   capacity, health, and per-node
//!   [`ThermalModel`](crate::sim::thermal::ThermalModel) /
//!   [`PowerSensor`](crate::sim::PowerSensor) state, with deterministic
//!   registration/heartbeats and a pluggable [`FleetObserver`] proxy for
//!   external observability planes;
//! * [`router`] — placement: a **pure** scoring function over an
//!   immutable [`RegistrySnapshot`] (kind match > warm-model locality >
//!   least-loaded > thermal headroom, node id as the final tie-break),
//!   so the same seed and snapshot always produce the same placement;
//! * [`shard`] — N independent [`Coordinator`](crate::coordinator::Coordinator)
//!   domains, [`ModelKey`](crate::coordinator::ModelKey)s
//!   hash-partitioned across them so singleflight and drift state never
//!   cross shards, with the per-device-kind transfer performed **once
//!   fleet-wide** and published into the owning shard's versioned Ready
//!   slots.

pub mod registry;
pub mod router;
pub mod shard;

pub use registry::{
    FleetObserver, FleetRegistry, NodeHealth, NodeId, NodeView, NoopObserver, RecordingObserver,
    RegistrySnapshot,
};
pub use router::{route, route_burst, Placement};
pub use shard::{Fleet, FleetConfig, FleetOutcome};
