//! Indexed placement engine: O(log k) routing decisions at 10,000 nodes.
//!
//! The reference router (`fleet::reference`) scores every node on every
//! decision — O(nodes) per placement, a quadratic wall for bursts at
//! fleet scale. This module keeps the same scoring contract (see
//! [`router`](crate::fleet::router)) pre-ordered in an index so the best
//! candidate is an O(1) peek and folding a placement back in is an
//! O(log k) bucket move:
//!
//! * **Per-kind candidate queues.** Nodes are grouped by [`DeviceKind`];
//!   each kind keeps two `BTreeSet` orderings keyed by
//!   `(load, ¬headroom_bits, id)` — one over *all* nodes of the kind
//!   (the health/saturation-blind "ideal" domain) and one over the
//!   *eligible* (healthy, non-saturated) placement candidates. The key
//!   encodes `f64::total_cmp` on headroom via the IEEE-754 total-order
//!   bit trick, so `BTreeSet` order reproduces the reference
//!   `better()` comparator bit-for-bit: least-loaded first, then
//!   largest headroom, then lowest id.
//! * **Inverted warm-locality map.** Warm-model locality outranks load,
//!   so each `(kind, workload)` keeps its own warm sub-queues. The
//!   workload set is small and fixed, so workloads are interned to
//!   dense `u8` indices ([`WorkloadInterner`]) and each node's warm set
//!   is a [`WarmSet`] — one `u64` bitset, `Copy`, no heap — which is
//!   what makes snapshot entries memcpy-cheap and the warm probe a bit
//!   test instead of a per-node `Vec::contains`.
//!
//! A routing decision peeks at most a handful of queue heads; a
//! placement update touches `2 + 2·|warm|` sets at O(log k) each. The
//! ordering domains are exactly the reference router's candidate
//! filters, so [`route_indexed`] is **bit-identical** to
//! [`reference::route`](crate::fleet::reference::route) — the
//! differential property suite (`tests/property_fleet_router.rs`) storms
//! randomized registries through both and asserts equal [`Placement`]
//! sequences.

use std::collections::BTreeSet;

use crate::device::DeviceKind;
use crate::fleet::registry::{NodeHealth, NodeId, RegistrySnapshot};
use crate::fleet::router::Placement;
use crate::workload::Workload;

/// Number of device kinds (one candidate-queue group per kind).
pub(crate) const KINDS: usize = DeviceKind::ALL.len();

/// Dense slot for a kind's queue group.
fn kind_slot(kind: DeviceKind) -> usize {
    match kind {
        DeviceKind::OrinAgx => 0,
        DeviceKind::XavierAgx => 1,
        DeviceKind::OrinNano => 2,
    }
}

/// Interns [`Workload`]s to dense `u8` indices in first-seen order.
///
/// The fleet's workload set is a small fixed family (the paper's five
/// plus variants), far below [`WarmSet::CAPACITY`]; interning it makes
/// per-node warm sets a single `u64` and the inverted warm map a dense
/// `Vec` lookup instead of a hash of `Workload` structs.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct WorkloadInterner {
    workloads: Vec<Workload>,
}

impl WorkloadInterner {
    /// Index of `workload`, allocating the next dense index on first
    /// sight. Panics past [`WarmSet::CAPACITY`] distinct workloads —
    /// the bitset cannot represent more.
    pub fn intern(&mut self, workload: Workload) -> u8 {
        if let Some(idx) = self.get(&workload) {
            return idx;
        }
        assert!(
            self.workloads.len() < WarmSet::CAPACITY,
            "fleet warm-set index supports at most {} distinct workloads",
            WarmSet::CAPACITY
        );
        self.workloads.push(workload);
        (self.workloads.len() - 1) as u8
    }

    /// Index of `workload` if it has ever been interned. A miss means no
    /// node anywhere can be warm for it.
    pub fn get(&self, workload: &Workload) -> Option<u8> {
        self.workloads.iter().position(|w| w == workload).map(|i| i as u8)
    }

    pub fn len(&self) -> usize {
        self.workloads.len()
    }

    pub fn is_empty(&self) -> bool {
        self.workloads.is_empty()
    }

    /// The workload behind a dense index (inverse of [`intern`](Self::intern)).
    pub fn workload(&self, idx: u8) -> Workload {
        self.workloads[idx as usize]
    }
}

/// Compact per-node warm set: bit `i` set ⇔ the node is warm for the
/// workload interned at index `i`. `Copy`, no heap — cloning a snapshot
/// entry is a memcpy, and the warm probe is one bit test.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct WarmSet(u64);

impl WarmSet {
    /// Maximum distinct workloads one fleet can track warmth for.
    pub const CAPACITY: usize = 64;

    pub fn contains(self, idx: u8) -> bool {
        debug_assert!((idx as usize) < Self::CAPACITY);
        (self.0 >> idx) & 1 == 1
    }

    pub fn insert(&mut self, idx: u8) {
        debug_assert!((idx as usize) < Self::CAPACITY);
        self.0 |= 1 << idx;
    }

    pub fn len(self) -> usize {
        self.0.count_ones() as usize
    }

    pub fn is_empty(self) -> bool {
        self.0 == 0
    }

    /// Iterate the set workload indices in ascending order.
    pub fn iter(self) -> WarmIter {
        WarmIter(self.0)
    }
}

/// Iterator over a [`WarmSet`]'s set bits.
#[derive(Debug, Clone)]
pub struct WarmIter(u64);

impl Iterator for WarmIter {
    type Item = u8;

    fn next(&mut self) -> Option<u8> {
        if self.0 == 0 {
            return None;
        }
        let idx = self.0.trailing_zeros() as u8;
        self.0 &= self.0 - 1;
        Some(idx)
    }
}

/// Map headroom to a key that sorts *best-first* under `u64` order:
/// the IEEE-754 total-order bit trick (monotone with `f64::total_cmp`),
/// then complemented so larger headroom yields a smaller key.
fn headroom_rank(headroom_mw: f64) -> u64 {
    let bits = headroom_mw.to_bits();
    let ascending = if (bits >> 63) == 1 { !bits } else { bits | (1 << 63) };
    !ascending
}

/// Candidate ordering key. Derived `Ord` reproduces the reference
/// router's `better()` exactly: load ascending, headroom descending
/// (by `total_cmp`), id ascending — `id` is unique, so the order is
/// total and ties cannot exist.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
struct OrderKey {
    load: u32,
    headroom_rank: u64,
    id: u32,
}

/// Compact, `Copy` per-node index entry. The `id`-is-index invariant
/// holds throughout: entry `i` of the snapshot has `id == NodeId(i)`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct IndexedNode {
    pub id: NodeId,
    pub kind: DeviceKind,
    pub health: NodeHealth,
    pub capacity: u32,
    pub load: u32,
    pub warm: WarmSet,
    pub headroom_mw: f64,
}

impl IndexedNode {
    pub fn free_slots(&self) -> u32 {
        self.capacity.saturating_sub(self.load)
    }

    fn eligible(&self) -> bool {
        self.health.placeable() && self.free_slots() > 0
    }

    fn key(&self) -> OrderKey {
        OrderKey {
            load: self.load,
            headroom_rank: headroom_rank(self.headroom_mw),
            id: self.id.0,
        }
    }

    /// Bitwise equality (NaN-safe, unlike `PartialEq` on the `f64`):
    /// the registry's dirty-entry filter, so an entry whose derived
    /// state did not change is never rebuilt or republished.
    pub fn bits_eq(&self, other: &IndexedNode) -> bool {
        self.id == other.id
            && self.kind == other.kind
            && self.health == other.health
            && self.capacity == other.capacity
            && self.load == other.load
            && self.warm == other.warm
            && self.headroom_mw.to_bits() == other.headroom_mw.to_bits()
    }
}

/// One kind's candidate queues: the blind "ideal" domain, the eligible
/// placement domain, and their per-workload warm sub-queues.
#[derive(Debug, Clone, Default, PartialEq)]
struct KindIndex {
    /// Every node of this kind, health/saturation-blind (the domain the
    /// reference router's `require_healthy = false` ideal pick scans).
    all: BTreeSet<OrderKey>,
    /// Healthy, non-saturated nodes — the placement candidates.
    eligible: BTreeSet<OrderKey>,
    /// `warm_all[w]` ⊆ `all`: nodes warm for interned workload `w`.
    warm_all: Vec<BTreeSet<OrderKey>>,
    /// `warm_eligible[w]` ⊆ `eligible`: the warm placement candidates.
    warm_eligible: Vec<BTreeSet<OrderKey>>,
}

impl KindIndex {
    fn grow(&mut self, n_workloads: usize) {
        if self.warm_all.len() < n_workloads {
            self.warm_all.resize_with(n_workloads, BTreeSet::new);
            self.warm_eligible.resize_with(n_workloads, BTreeSet::new);
        }
    }

    fn insert(&mut self, node: &IndexedNode) {
        let key = node.key();
        let fresh = self.all.insert(key);
        debug_assert!(fresh, "duplicate index key for {}", node.id);
        let eligible = node.eligible();
        if eligible {
            self.eligible.insert(key);
        }
        for w in node.warm.iter() {
            self.warm_all[w as usize].insert(key);
            if eligible {
                self.warm_eligible[w as usize].insert(key);
            }
        }
    }

    fn remove(&mut self, node: &IndexedNode) {
        let key = node.key();
        let present = self.all.remove(&key);
        debug_assert!(present, "index key for {} vanished", node.id);
        self.eligible.remove(&key);
        for w in node.warm.iter() {
            self.warm_all[w as usize].remove(&key);
            self.warm_eligible[w as usize].remove(&key);
        }
    }

    /// Best candidate of this kind: the warm queue's head when the
    /// workload is interned and a warm candidate exists (warm-model
    /// locality outranks load), else the plain queue's head.
    fn best(&self, workload: Option<u8>, eligible_only: bool) -> Option<OrderKey> {
        let (plain, warm) = if eligible_only {
            (&self.eligible, &self.warm_eligible)
        } else {
            (&self.all, &self.warm_all)
        };
        if let Some(w) = workload {
            if let Some(key) = warm.get(w as usize).and_then(|set| set.first()) {
                return Some(*key);
            }
        }
        plain.first().copied()
    }
}

/// An immutable indexed registry snapshot: the structure placement
/// decisions read, and the structure the registry publishes through its
/// `ArcCell` after every heartbeat that dirtied an entry.
///
/// Cloning is cheap by construction — entries are `Copy` (the warm set
/// is a bitset, not a `Vec`), so a clone is one memcpy plus the queue
/// node copies; there is no per-node heap allocation to deep-clone.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct IndexedSnapshot {
    /// Simulated seconds of fleet uptime at snapshot time.
    pub clock_s: f64,
    entries: Vec<IndexedNode>,
    kinds: [KindIndex; KINDS],
    interner: WorkloadInterner,
}

impl IndexedSnapshot {
    /// Bulk-build from entries (ids must already be dense and ordered).
    pub fn build(
        clock_s: f64,
        entries: Vec<IndexedNode>,
        interner: WorkloadInterner,
    ) -> IndexedSnapshot {
        let mut snap = IndexedSnapshot {
            clock_s,
            entries: Vec::with_capacity(entries.len()),
            kinds: Default::default(),
            interner: WorkloadInterner::default(),
        };
        // install the interner first so warm queues size correctly
        snap.interner = interner;
        let n = snap.interner.len();
        for ki in &mut snap.kinds {
            ki.grow(n);
        }
        for entry in entries {
            snap.push_entry(entry);
        }
        snap
    }

    /// Derive from a legacy [`RegistrySnapshot`] (interning every warm
    /// workload it mentions). Mainly for tests and the differential
    /// oracle; the registry maintains its index incrementally.
    pub fn from_registry_snapshot(snap: &RegistrySnapshot) -> IndexedSnapshot {
        let mut interner = WorkloadInterner::default();
        let entries: Vec<IndexedNode> = snap
            .nodes
            .iter()
            .map(|n| {
                let mut warm = WarmSet::default();
                for w in &n.warm {
                    warm.insert(interner.intern(*w));
                }
                IndexedNode {
                    id: n.id,
                    kind: n.kind,
                    health: n.health,
                    capacity: n.capacity,
                    load: n.load,
                    warm,
                    headroom_mw: n.headroom_mw,
                }
            })
            .collect();
        IndexedSnapshot::build(snap.clock_s, entries, interner)
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn entries(&self) -> &[IndexedNode] {
        &self.entries
    }

    pub fn entry(&self, id: NodeId) -> Option<&IndexedNode> {
        self.entries.get(id.0 as usize)
    }

    pub fn interner(&self) -> &WorkloadInterner {
        &self.interner
    }

    /// Is `id` warm for `workload`? One interner probe + one bit test.
    pub fn is_warm(&self, id: NodeId, workload: &Workload) -> bool {
        match (self.interner.get(workload), self.entry(id)) {
            (Some(w), Some(entry)) => entry.warm.contains(w),
            _ => false,
        }
    }

    /// Healthy placement candidates of `kind` (queue size, O(1)).
    pub fn eligible_of_kind(&self, kind: DeviceKind) -> usize {
        self.kinds[kind_slot(kind)].eligible.len()
    }

    /// Append a newly registered node. The id-is-index invariant is
    /// enforced here: the entry's id must be the next dense index.
    pub fn push_entry(&mut self, entry: IndexedNode) {
        debug_assert_eq!(
            entry.id.0 as usize,
            self.entries.len(),
            "id-is-index invariant: node ids are dense registration indices"
        );
        self.kinds[kind_slot(entry.kind)].insert(&entry);
        self.entries.push(entry);
    }

    /// Replace node `entry.id`'s entry, moving only its queue keys —
    /// the O(log k) bucket move (2 + 2·|warm| set operations).
    pub fn update_entry(&mut self, entry: IndexedNode) {
        let old = self.entries[entry.id.0 as usize];
        debug_assert_eq!(old.id, entry.id, "id-is-index invariant");
        debug_assert_eq!(old.kind, entry.kind, "a node never changes kind");
        let ki = &mut self.kinds[kind_slot(entry.kind)];
        ki.remove(&old);
        ki.insert(&entry);
        self.entries[entry.id.0 as usize] = entry;
    }

    /// Intern `workload`, growing every kind's warm queues to fit.
    pub fn intern(&mut self, workload: Workload) -> u8 {
        let idx = self.interner.intern(workload);
        let n = self.interner.len();
        for ki in &mut self.kinds {
            ki.grow(n);
        }
        idx
    }

    /// Fold one placement into the index in place: bump the node's load
    /// and mark the workload warm there — the O(log k) equivalent of
    /// the reference burst's working-copy scan-and-mutate.
    pub fn apply_placement(&mut self, id: NodeId, workload: Workload) {
        let w = self.intern(workload);
        let mut entry = self.entries[id.0 as usize];
        debug_assert_eq!(entry.id, id, "id-is-index invariant");
        entry.load = entry.load.saturating_add(1);
        entry.warm.insert(w);
        self.update_entry(entry);
    }

    /// Override one node's health (test/differential harness API —
    /// production health flows in through registry heartbeats).
    pub fn set_health(&mut self, id: NodeId, health: NodeHealth) {
        let mut entry = self.entries[id.0 as usize];
        entry.health = health;
        self.update_entry(entry);
    }

    /// Override one node's load (test/differential harness API).
    pub fn set_load(&mut self, id: NodeId, load: u32) {
        let mut entry = self.entries[id.0 as usize];
        entry.load = load;
        self.update_entry(entry);
    }

    /// Best eligible candidate across every kind: warm candidates first
    /// (warm outranks load in the reference comparator, kind is not a
    /// discriminator once the affinity filter is gone), then the global
    /// queue-head minimum.
    fn best_any_kind(&self, workload: Option<u8>) -> Option<OrderKey> {
        if let Some(w) = workload {
            let warm_best = self
                .kinds
                .iter()
                .filter_map(|ki| ki.warm_eligible.get(w as usize).and_then(|set| set.first()))
                .min()
                .copied();
            if warm_best.is_some() {
                return warm_best;
            }
        }
        self.kinds.iter().filter_map(|ki| ki.eligible.first()).min().copied()
    }

    /// Exhaustively verify index consistency: id-is-index, every entry
    /// in exactly the queues its state implies, no phantom keys.
    /// O(nodes × workloads) — test/debug harness only.
    pub fn check_invariants(&self) {
        let n_wl = self.interner.len();
        let mut all_counts = [0usize; KINDS];
        let mut eligible_counts = [0usize; KINDS];
        let mut warm_all_counts = vec![[0usize; KINDS]; n_wl];
        let mut warm_eligible_counts = vec![[0usize; KINDS]; n_wl];
        for (i, entry) in self.entries.iter().enumerate() {
            assert_eq!(entry.id.0 as usize, i, "id-is-index invariant broken at {i}");
            let slot = kind_slot(entry.kind);
            let ki = &self.kinds[slot];
            let key = entry.key();
            assert!(ki.all.contains(&key), "{} missing from its all-queue", entry.id);
            assert_eq!(
                ki.eligible.contains(&key),
                entry.eligible(),
                "{} eligibility out of sync",
                entry.id
            );
            all_counts[slot] += 1;
            if entry.eligible() {
                eligible_counts[slot] += 1;
            }
            for w in 0..n_wl as u8 {
                let warm = entry.warm.contains(w);
                assert_eq!(
                    ki.warm_all[w as usize].contains(&key),
                    warm,
                    "{} warm-all[{w}] out of sync",
                    entry.id
                );
                assert_eq!(
                    ki.warm_eligible[w as usize].contains(&key),
                    warm && entry.eligible(),
                    "{} warm-eligible[{w}] out of sync",
                    entry.id
                );
                if warm {
                    warm_all_counts[w as usize][slot] += 1;
                    if entry.eligible() {
                        warm_eligible_counts[w as usize][slot] += 1;
                    }
                }
            }
        }
        for (slot, ki) in self.kinds.iter().enumerate() {
            assert_eq!(ki.all.len(), all_counts[slot], "phantom keys in all-queue {slot}");
            assert_eq!(
                ki.eligible.len(),
                eligible_counts[slot],
                "phantom keys in eligible-queue {slot}"
            );
            assert!(ki.warm_all.len() >= n_wl, "warm queues lag the interner");
            assert_eq!(ki.warm_all.len(), ki.warm_eligible.len());
            for w in 0..n_wl {
                assert_eq!(
                    ki.warm_all[w].len(),
                    warm_all_counts[w][slot],
                    "phantom keys in warm-all[{w}] of kind {slot}"
                );
                assert_eq!(
                    ki.warm_eligible[w].len(),
                    warm_eligible_counts[w][slot],
                    "phantom keys in warm-eligible[{w}] of kind {slot}"
                );
            }
        }
    }
}

/// Route one request against the index. Bit-identical to
/// [`reference::route`](crate::fleet::reference::route) over the same
/// state, but every probe is a queue-head peek instead of a fleet scan.
pub fn route_indexed(
    snap: &IndexedSnapshot,
    affinity: Option<DeviceKind>,
    workload: &Workload,
) -> Option<Placement> {
    let wl = snap.interner.get(workload);
    if let Some(kind) = affinity {
        let ki = &snap.kinds[kind_slot(kind)];
        // the health/saturation-blind ideal: a chosen node differing
        // from it means health or saturation forced a reroute
        let ideal = ki.best(wl, false);
        if let Some(chosen) = ki.best(wl, true) {
            return Some(Placement {
                node: NodeId(chosen.id),
                kind,
                rerouted: ideal.is_some_and(|i| i.id != chosen.id),
                cross_kind: false,
            });
        }
        // no healthy in-kind capacity: fall back across kinds rather
        // than fail the request outright
        return snap.best_any_kind(wl).map(|key| {
            let node = &snap.entries[key.id as usize];
            Placement {
                node: node.id,
                kind: node.kind,
                rerouted: true,
                cross_kind: kind != node.kind,
            }
        });
    }
    snap.best_any_kind(wl).map(|key| {
        let node = &snap.entries[key.id as usize];
        Placement { node: node.id, kind: node.kind, rerouted: false, cross_kind: false }
    })
}

/// Route a burst against one snapshot, folding each placement into a
/// working copy of the index in place — one clone up front, then
/// O(log k) per decision, where the reference burst re-scans O(nodes)
/// per item.
pub fn route_burst_indexed(
    snap: &IndexedSnapshot,
    items: &[(Option<DeviceKind>, Workload)],
) -> Vec<Option<Placement>> {
    let mut working = snap.clone();
    items
        .iter()
        .map(|(affinity, workload)| {
            let placement = route_indexed(&working, *affinity, workload);
            if let Some(p) = placement {
                working.apply_placement(p.node, *workload);
            }
            placement
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fleet::reference;
    use crate::fleet::registry::FleetRegistry;

    #[test]
    fn warm_set_inserts_probes_and_iterates() {
        let mut set = WarmSet::default();
        assert!(set.is_empty());
        set.insert(0);
        set.insert(5);
        set.insert(63);
        assert!(set.contains(0) && set.contains(5) && set.contains(63));
        assert!(!set.contains(1));
        assert_eq!(set.len(), 3);
        assert_eq!(set.iter().collect::<Vec<_>>(), vec![0, 5, 63]);
    }

    #[test]
    fn interner_assigns_dense_first_seen_indices() {
        let mut interner = WorkloadInterner::default();
        let a = interner.intern(Workload::resnet());
        let b = interner.intern(Workload::bert());
        assert_eq!((a, b), (0, 1));
        assert_eq!(interner.intern(Workload::resnet()), 0, "re-intern is idempotent");
        assert_eq!(interner.get(&Workload::yolo()), None);
        assert_eq!(interner.workload(1), Workload::bert());
        assert_eq!(interner.len(), 2);
    }

    /// The ordering key must reproduce `f64::total_cmp` on headroom —
    /// descending — across the whole messy float landscape.
    #[test]
    fn headroom_rank_matches_total_cmp_descending() {
        let samples = [
            f64::NEG_INFINITY,
            -1e12,
            -1.0,
            -f64::MIN_POSITIVE / 2.0, // negative subnormal
            -0.0,
            0.0,
            f64::MIN_POSITIVE / 2.0, // positive subnormal
            1.0,
            1e12,
            f64::INFINITY,
            f64::NAN,
            -f64::NAN,
        ];
        for &a in &samples {
            for &b in &samples {
                let by_cmp = a.total_cmp(&b);
                let by_rank = headroom_rank(b).cmp(&headroom_rank(a)); // descending ⇒ flipped
                assert_eq!(by_cmp, by_rank, "rank order diverged at ({a}, {b})");
            }
        }
    }

    fn indexed(n: usize, seed: u64) -> IndexedSnapshot {
        FleetRegistry::synthesize(n, seed).indexed().clone()
    }

    #[test]
    fn indexed_route_matches_reference_on_a_fresh_registry() {
        let reg = FleetRegistry::synthesize(32, 9);
        let legacy = reg.snapshot();
        let snap = reg.indexed();
        for affinity in
            [None, Some(DeviceKind::OrinAgx), Some(DeviceKind::XavierAgx), Some(DeviceKind::OrinNano)]
        {
            for wl in Workload::default_five() {
                assert_eq!(
                    reference::route(&legacy, affinity, &wl),
                    route_indexed(snap, affinity, &wl),
                    "diverged at {affinity:?} / {}",
                    wl.name()
                );
            }
        }
    }

    #[test]
    fn placement_update_is_an_index_move_not_a_rebuild() {
        let mut snap = indexed(16, 3);
        let wl = Workload::yolo();
        let first = route_indexed(&snap, Some(DeviceKind::OrinAgx), &wl).unwrap();
        snap.apply_placement(first.node, wl);
        snap.check_invariants();
        let entry = snap.entry(first.node).unwrap();
        assert_eq!(entry.load, 1);
        assert!(snap.is_warm(first.node, &wl));
        // the warm node keeps attracting its workload despite the load
        let again = route_indexed(&snap, Some(DeviceKind::OrinAgx), &wl).unwrap();
        assert_eq!(again.node, first.node);
        // a different workload prefers an idle sibling
        let other = route_indexed(&snap, Some(DeviceKind::OrinAgx), &Workload::bert()).unwrap();
        assert_ne!(other.node, first.node);
    }

    #[test]
    fn saturation_and_health_move_candidates_out_of_the_eligible_queues() {
        let mut snap = indexed(9, 5);
        let wl = Workload::lstm();
        let first = route_indexed(&snap, Some(DeviceKind::OrinNano), &wl).unwrap();
        let cap = snap.entry(first.node).unwrap().capacity;
        for _ in 0..cap {
            snap.apply_placement(first.node, wl);
        }
        snap.check_invariants();
        let next = route_indexed(&snap, Some(DeviceKind::OrinNano), &wl).unwrap();
        assert_ne!(next.node, first.node);
        assert!(next.rerouted, "placement away from the ideal node must be flagged");
        assert!(!next.cross_kind);
        // knock out every nano: the fallback crosses kinds
        for i in 0..snap.len() {
            if snap.entries()[i].kind == DeviceKind::OrinNano {
                snap.set_health(NodeId(i as u32), NodeHealth::Down);
            }
        }
        snap.check_invariants();
        let p = route_indexed(&snap, Some(DeviceKind::OrinNano), &wl).unwrap();
        assert!(p.cross_kind && p.rerouted);
        assert_ne!(p.kind, DeviceKind::OrinNano);
        // whole fleet down ⇒ no placement at all
        for i in 0..snap.len() {
            snap.set_health(NodeId(i as u32), NodeHealth::Down);
        }
        assert_eq!(route_indexed(&snap, Some(DeviceKind::OrinAgx), &wl), None);
        assert_eq!(route_indexed(&snap, None, &wl), None);
    }

    #[test]
    fn burst_fold_matches_reference_burst() {
        let reg = FleetRegistry::synthesize(16, 11);
        let items: Vec<(Option<DeviceKind>, Workload)> = (0..24)
            .map(|i| {
                (
                    Some(DeviceKind::ALL[i % DeviceKind::ALL.len()]),
                    Workload::default_five()[i % 5],
                )
            })
            .collect();
        let oracle = reference::route_burst(&reg.snapshot(), &items);
        let fast = route_burst_indexed(reg.indexed(), &items);
        assert_eq!(oracle, fast);
        assert!(fast.iter().all(Option::is_some));
    }

    #[test]
    fn from_registry_snapshot_round_trips_membership() {
        let mut reg = FleetRegistry::synthesize(8, 2);
        reg.note_placement(NodeId(1), Workload::bert());
        reg.note_placement(NodeId(4), Workload::resnet());
        let derived = IndexedSnapshot::from_registry_snapshot(&reg.snapshot());
        derived.check_invariants();
        assert_eq!(derived.len(), 8);
        assert!(derived.is_warm(NodeId(1), &Workload::bert()));
        assert!(derived.is_warm(NodeId(4), &Workload::resnet()));
        assert!(!derived.is_warm(NodeId(1), &Workload::resnet()));
        // and it routes exactly like the registry's own incremental index
        for wl in Workload::default_five() {
            assert_eq!(
                route_indexed(&derived, None, &wl),
                route_indexed(reg.indexed(), None, &wl)
            );
        }
    }
}
