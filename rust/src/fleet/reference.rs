//! The linear reference router: the placement oracle.
//!
//! This is the original O(nodes)-per-decision scan, retained verbatim as
//! the executable specification of the scoring contract documented in
//! [`router`](crate::fleet::router). The production path is the indexed
//! engine ([`fleet::index`](crate::fleet::index)); the differential
//! property suite (`tests/property_fleet_router.rs`) storms randomized
//! registries through both and asserts bit-identical [`Placement`]
//! sequences, so any drift between implementation and specification
//! fails loudly.
//!
//! [`route`] takes only immutable inputs and allocates nothing on the
//! happy path, so the same snapshot + request always yields the same
//! [`Placement`] — the property the fleet determinism tests pin.

use crate::device::DeviceKind;
use crate::fleet::registry::{NodeView, RegistrySnapshot};
use crate::fleet::router::Placement;
use crate::workload::Workload;

/// `true` when `a` scores strictly better than `b` for `workload`.
fn better(a: &NodeView, b: &NodeView, workload: &Workload) -> bool {
    let warm = (a.is_warm(workload), b.is_warm(workload));
    if warm.0 != warm.1 {
        return warm.0;
    }
    if a.load != b.load {
        return a.load < b.load;
    }
    match a.headroom_mw.total_cmp(&b.headroom_mw) {
        std::cmp::Ordering::Greater => true,
        std::cmp::Ordering::Less => false,
        std::cmp::Ordering::Equal => a.id < b.id,
    }
}

/// Best node among `nodes` for `workload`, restricted to `kind` (when
/// given) and to healthy, non-saturated nodes (when `require_healthy`).
fn best<'a>(
    nodes: &'a [NodeView],
    kind: Option<DeviceKind>,
    workload: &Workload,
    require_healthy: bool,
) -> Option<&'a NodeView> {
    nodes
        .iter()
        .filter(|n| kind.map_or(true, |k| n.kind == k))
        .filter(|n| !require_healthy || (n.health.placeable() && n.free_slots() > 0))
        .fold(None, |acc: Option<&NodeView>, n| match acc {
            Some(cur) if !better(n, cur, workload) => Some(cur),
            _ => Some(n),
        })
}

/// Route one request by scanning every node. Pure: depends only on the
/// snapshot, the affinity and the workload. Returns `None` when no
/// healthy capacity exists anywhere in the fleet.
pub fn route(
    snapshot: &RegistrySnapshot,
    affinity: Option<DeviceKind>,
    workload: &Workload,
) -> Option<Placement> {
    // What would win if every node were healthy and empty-handed? A
    // chosen node differing from this means the fleet degraded the
    // placement (health or saturation forced a reroute).
    let ideal = affinity.and_then(|k| best(&snapshot.nodes, Some(k), workload, false));

    if let Some(node) = best(&snapshot.nodes, affinity, workload, true) {
        return Some(Placement {
            node: node.id,
            kind: node.kind,
            rerouted: ideal.is_some_and(|i| i.id != node.id),
            cross_kind: false,
        });
    }
    // No healthy in-kind capacity: fall back across kinds rather than
    // fail the request outright.
    best(&snapshot.nodes, None, workload, true).map(|node| Placement {
        node: node.id,
        kind: node.kind,
        rerouted: true,
        cross_kind: affinity.is_some_and(|k| k != node.kind),
    })
}

/// Route a burst of `(affinity, workload)` items against one snapshot,
/// applying each placement (load + warmth) to a working copy before the
/// next decision. The working-copy update indexes `nodes[p.node.0]`
/// directly — node ids are dense registration indices (the id-is-index
/// invariant, debug-asserted at registration and here).
pub fn route_burst(
    snapshot: &RegistrySnapshot,
    items: &[(Option<DeviceKind>, Workload)],
) -> Vec<Option<Placement>> {
    let mut working = snapshot.clone();
    items
        .iter()
        .map(|(affinity, workload)| {
            let placement = route(&working, *affinity, workload);
            if let Some(p) = placement {
                let node = &mut working.nodes[p.node.0 as usize];
                debug_assert_eq!(node.id, p.node, "id-is-index invariant");
                node.load += 1;
                if !node.warm.contains(workload) {
                    node.warm.push(*workload);
                }
            }
            placement
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fleet::registry::{FleetRegistry, NodeHealth, NodeId};

    fn snapshot(n: usize, seed: u64) -> RegistrySnapshot {
        FleetRegistry::synthesize(n, seed).snapshot()
    }

    #[test]
    fn routing_is_pure_and_deterministic() {
        let snap = snapshot(32, 9);
        let wl = Workload::resnet();
        let a = route(&snap, Some(DeviceKind::XavierAgx), &wl);
        let b = route(&snap, Some(DeviceKind::XavierAgx), &wl);
        assert_eq!(a, b);
        let p = a.expect("healthy fleet must place");
        assert_eq!(p.kind, DeviceKind::XavierAgx);
        assert!(!p.rerouted);
        assert!(!p.cross_kind);
    }

    /// A hand-built registry with `per_kind` nodes of every kind, so
    /// tests don't depend on the seeded tail mix.
    fn uniform_registry(per_kind: usize) -> FleetRegistry {
        let mut reg = FleetRegistry::synthesize(0, 0);
        for _ in 0..per_kind {
            for kind in DeviceKind::ALL {
                reg.register(kind);
            }
        }
        reg
    }

    #[test]
    fn warm_locality_beats_less_loaded_cold_node() {
        let mut reg = uniform_registry(3);
        let wl = Workload::yolo();
        let first = route(&reg.snapshot(), Some(DeviceKind::OrinAgx), &wl).unwrap();
        // warm the chosen node and give it one unit of load
        reg.note_placement(first.node, wl);
        let again = route(&reg.snapshot(), Some(DeviceKind::OrinAgx), &wl).unwrap();
        assert_eq!(again.node, first.node, "warm node should keep attracting its workload");
        // a different workload prefers an idle sibling over the loaded warm node
        let other = route(&reg.snapshot(), Some(DeviceKind::OrinAgx), &Workload::bert()).unwrap();
        assert_ne!(other.node, first.node);
    }

    #[test]
    fn saturated_or_unhealthy_nodes_are_skipped_and_flagged_rerouted() {
        let mut reg = uniform_registry(2);
        let wl = Workload::lstm();
        let first = route(&reg.snapshot(), Some(DeviceKind::OrinNano), &wl).unwrap();
        // saturate the first-choice node
        let cap = reg
            .snapshot()
            .nodes
            .iter()
            .find(|n| n.id == first.node)
            .unwrap()
            .capacity;
        for _ in 0..cap {
            reg.note_placement(first.node, wl);
        }
        let next = route(&reg.snapshot(), Some(DeviceKind::OrinNano), &wl).unwrap();
        assert_ne!(next.node, first.node);
        assert!(next.rerouted, "placement away from the ideal node must be flagged");
        assert!(!next.cross_kind);
    }

    #[test]
    fn cross_kind_fallback_only_when_no_healthy_in_kind_capacity() {
        let reg = FleetRegistry::synthesize(3, 6); // exactly one node per kind
        let wl = Workload::mobilenet();
        let mut snap = reg.snapshot();
        for node in &mut snap.nodes {
            if node.kind == DeviceKind::OrinNano {
                node.health = NodeHealth::Down;
            }
        }
        let p = route(&snap, Some(DeviceKind::OrinNano), &wl).unwrap();
        assert!(p.cross_kind);
        assert!(p.rerouted);
        assert_ne!(p.kind, DeviceKind::OrinNano);
        // whole fleet down ⇒ no placement at all
        for node in &mut snap.nodes {
            node.health = NodeHealth::Down;
        }
        assert_eq!(route(&snap, Some(DeviceKind::OrinAgx), &wl), None);
        // and the registry untouched by any of this still places in-kind
        let q = route(&reg.snapshot(), Some(DeviceKind::OrinNano), &wl).unwrap();
        assert!(!q.cross_kind);
    }

    #[test]
    fn route_burst_spreads_load_and_is_reproducible() {
        let snap = snapshot(16, 11);
        let items: Vec<(Option<DeviceKind>, Workload)> = (0..12)
            .map(|i| {
                (
                    Some(DeviceKind::ALL[i % DeviceKind::ALL.len()]),
                    Workload::default_five()[i % 5],
                )
            })
            .collect();
        let a = route_burst(&snap, &items);
        let b = route_burst(&snap, &items);
        assert_eq!(a, b, "same snapshot + items ⇒ identical burst placements");
        assert!(a.iter().all(Option::is_some));
        // burst accounting must spread same-kind requests across nodes
        // once the leader picks up load
        let orin: Vec<NodeId> = a
            .iter()
            .flatten()
            .filter(|p| p.kind == DeviceKind::OrinAgx)
            .map(|p| p.node)
            .collect();
        assert!(orin.len() >= 4);
    }
}
