//! Node registry: deterministic synthesis, heartbeats and observability
//! for a fleet of simulated Jetson nodes.
//!
//! Each [`Node`] owns the per-device state the placement layer scores
//! against: its [`DeviceKind`], request capacity, outstanding load, the
//! set of workloads it has already served (warm-model locality), and a
//! live [`ThermalModel`] + [`PowerSensor`] pair from `sim/` that
//! heartbeats advance deterministically. Health is derived, never set by
//! hand: a scripted per-node fan-off episode
//! ([`FaultPlan::node_fan_off`](crate::sim::FaultPlan)) marks the node
//! `Degraded`, and a die that would throttle marks it `Down`.
//!
//! Everything is a pure function of `(seed, heartbeat count, fault
//! plan)` — two registries built with the same inputs produce
//! bit-identical [`RegistrySnapshot`]s, which is what makes fleet
//! routing reproducible end-to-end.
//!
//! # Incremental snapshot publication
//!
//! Alongside its mutable [`Node`]s the registry maintains a live
//! [`IndexedSnapshot`] *incrementally*: registration appends one entry,
//! a placement is one O(log k) index move, and a heartbeat rebuilds only
//! the entries whose derived state actually changed (a bitwise
//! [`IndexedNode::bits_eq`] filter — NaN-safe, so a stuck sensor can't
//! force perpetual republication). The index is the structure routing
//! decisions read; a heartbeat that dirtied at least one entry also
//! publishes a clone through an
//! [`ArcCell`](crate::util::arc_cell::ArcCell), so external monitors
//! read fleet state lock-free at heartbeat granularity without ever
//! touching the registry mutex. The legacy O(nodes) deep-clone
//! [`snapshot()`](FleetRegistry::snapshot) projection remains for the
//! reference oracle and tests.

use std::collections::VecDeque;
use std::fmt;
use std::sync::{Arc, Mutex};

use crate::device::DeviceKind;
use crate::fleet::index::{IndexedNode, IndexedSnapshot, WarmSet};
use crate::sim::thermal::ThermalModel;
use crate::sim::{FaultInjector, PowerSensor};
use crate::util::arc_cell::ArcCell;
use crate::util::rng::Rng;
use crate::util::sync::lock_unpoisoned;
use crate::workload::Workload;

/// Fleet-unique node identity.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct NodeId(pub u32);

impl fmt::Display for NodeId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "n{:03}", self.0)
    }
}

/// Derived node health. Only `Healthy` nodes are placement candidates;
/// the router treats `Degraded` and `Down` identically (avoid), the
/// distinction exists for operators reading fleet state.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NodeHealth {
    Healthy,
    /// The cooling envelope is compromised (scripted fan-off episode):
    /// the node still runs but must not take new placements.
    Degraded,
    /// The die is at (or past) its throttle trip point.
    Down,
}

impl NodeHealth {
    pub fn placeable(&self) -> bool {
        matches!(self, NodeHealth::Healthy)
    }

    pub fn label(&self) -> &'static str {
        match self {
            NodeHealth::Healthy => "healthy",
            NodeHealth::Degraded => "degraded",
            NodeHealth::Down => "down",
        }
    }
}

/// One registered node. Mutable state lives behind the registry; the
/// router only ever sees the immutable [`NodeView`] projection.
#[derive(Debug)]
pub struct Node {
    pub id: NodeId,
    pub kind: DeviceKind,
    /// Concurrent request slots this node advertises.
    pub capacity: u32,
    pub health: NodeHealth,
    /// Outstanding placements; heartbeats drain one slot's worth each
    /// tick (a deterministic stand-in for round completions).
    pub load: u32,
    /// Workloads this node has served — the warm-model locality signal.
    warm: Vec<Workload>,
    thermal: ThermalModel,
    sensor: PowerSensor,
}

impl Node {
    fn new(id: NodeId, kind: DeviceKind) -> Node {
        let spec = kind.spec();
        // capacity scales with the module class: the AGX boards take more
        // concurrent training rounds than a Nano
        let capacity = match kind {
            DeviceKind::OrinAgx => 4,
            DeviceKind::XavierAgx => 3,
            DeviceKind::OrinNano => 2,
        };
        Node {
            id,
            kind,
            capacity,
            health: NodeHealth::Healthy,
            load: 0,
            warm: Vec::new(),
            thermal: ThermalModel::default(),
            sensor: PowerSensor::new(spec.p_base_mw),
        }
    }

    /// Sustainable-power headroom (mW) at the current die state.
    fn headroom_mw(&self) -> f64 {
        self.thermal.max_sustainable_mw() - self.sensor.instantaneous()
    }

    fn view(&self) -> NodeView {
        NodeView {
            id: self.id,
            kind: self.kind,
            health: self.health,
            capacity: self.capacity,
            load: self.load,
            warm: self.warm.clone(),
            headroom_mw: self.headroom_mw(),
        }
    }

    /// The compact index projection; `warm` bits carry over from the
    /// node's existing entry (warmth only changes via placements, which
    /// maintain the index themselves).
    fn indexed_entry(&self, warm: WarmSet) -> IndexedNode {
        IndexedNode {
            id: self.id,
            kind: self.kind,
            health: self.health,
            capacity: self.capacity,
            load: self.load,
            warm,
            headroom_mw: self.headroom_mw(),
        }
    }
}

/// Immutable per-node projection the router scores. `warm` keeps
/// registration order (deterministic), membership is what matters.
#[derive(Debug, Clone, PartialEq)]
pub struct NodeView {
    pub id: NodeId,
    pub kind: DeviceKind,
    pub health: NodeHealth,
    pub capacity: u32,
    pub load: u32,
    pub warm: Vec<Workload>,
    pub headroom_mw: f64,
}

impl NodeView {
    pub fn free_slots(&self) -> u32 {
        self.capacity.saturating_sub(self.load)
    }

    pub fn is_warm(&self, workload: &Workload) -> bool {
        self.warm.contains(workload)
    }
}

/// Immutable registry snapshot: what the reference router routes
/// against. Deep-clones every node's warm vector — O(nodes) to build;
/// the production path reads the incrementally maintained
/// [`IndexedSnapshot`] instead.
#[derive(Debug, Clone, PartialEq)]
pub struct RegistrySnapshot {
    /// Simulated seconds of fleet uptime at snapshot time.
    pub clock_s: f64,
    pub nodes: Vec<NodeView>,
}

impl RegistrySnapshot {
    pub fn healthy_of_kind(&self, kind: DeviceKind) -> usize {
        self.nodes
            .iter()
            .filter(|n| n.kind == kind && n.health.placeable())
            .count()
    }
}

/// Pluggable observability proxy: an external control/observability
/// plane subscribes to registry events (registration, heartbeats,
/// health flips, placements) without the registry knowing anything about
/// it. Default methods are no-ops so observers implement only what they
/// watch.
pub trait FleetObserver: Send + Sync + fmt::Debug {
    fn on_register(&self, _node: &NodeView) {}
    fn on_heartbeat(&self, _clock_s: f64) {}
    fn on_health_change(&self, _node: NodeId, _from: NodeHealth, _to: NodeHealth) {}
    fn on_placement(&self, _node: NodeId, _workload: &Workload) {}
}

/// The default observer: drops everything.
#[derive(Debug, Default)]
pub struct NoopObserver;

impl FleetObserver for NoopObserver {}

/// Retained events in a [`RecordingObserver`] unless overridden with
/// [`RecordingObserver::with_capacity`].
const RECORDING_DEFAULT_CAP: usize = 1024;

/// A test/demo observer that records events as rendered lines in a
/// **capped ring**: a 10k-node registration storm keeps the newest
/// `capacity` lines and counts the rest as dropped instead of growing an
/// unbounded `Vec` before the first heartbeat.
#[derive(Debug)]
pub struct RecordingObserver {
    log: Mutex<RecordingLog>,
    capacity: usize,
}

#[derive(Debug)]
struct RecordingLog {
    events: VecDeque<String>,
    dropped: u64,
}

impl Default for RecordingObserver {
    fn default() -> Self {
        RecordingObserver::with_capacity(RECORDING_DEFAULT_CAP)
    }
}

impl RecordingObserver {
    /// An observer retaining at most `capacity` newest events (min 1).
    pub fn with_capacity(capacity: usize) -> RecordingObserver {
        RecordingObserver {
            log: Mutex::new(RecordingLog { events: VecDeque::new(), dropped: 0 }),
            capacity: capacity.max(1),
        }
    }

    /// The retained (newest) events, oldest first.
    pub fn events(&self) -> Vec<String> {
        lock_unpoisoned(&self.log).events.iter().cloned().collect()
    }

    /// Events evicted from the ring since construction.
    pub fn dropped(&self) -> u64 {
        lock_unpoisoned(&self.log).dropped
    }

    fn push(&self, line: String) {
        let mut log = lock_unpoisoned(&self.log);
        if log.events.len() == self.capacity {
            log.events.pop_front();
            log.dropped += 1;
        }
        log.events.push_back(line);
    }
}

impl FleetObserver for RecordingObserver {
    fn on_register(&self, node: &NodeView) {
        self.push(format!("register {} {}", node.id, node.kind.name()));
    }
    fn on_heartbeat(&self, clock_s: f64) {
        self.push(format!("heartbeat {clock_s:.0}s"));
    }
    fn on_health_change(&self, node: NodeId, from: NodeHealth, to: NodeHealth) {
        self.push(format!("health {} {} -> {}", node, from.label(), to.label()));
    }
    fn on_placement(&self, node: NodeId, workload: &Workload) {
        self.push(format!("place {} {}", node, workload.name()));
    }
}

/// The registry proper. Not internally synchronized — the fleet layer
/// owns it behind one mutex; everything placement-facing goes through
/// immutable snapshots (the live [`IndexedSnapshot`] under that mutex,
/// or the lock-free published copy for external readers).
#[derive(Debug)]
pub struct FleetRegistry {
    nodes: Vec<Node>,
    clock_s: f64,
    observer: Arc<dyn FleetObserver>,
    /// The incrementally maintained index routing decisions read.
    index: IndexedSnapshot,
    /// Lock-free publication handle (heartbeat-granular copies).
    published: Arc<ArcCell<IndexedSnapshot>>,
    /// Entries the last heartbeat found changed (and hence republished).
    last_dirty: usize,
}

/// Registry synthesis salt (kept apart from every other consumer of the
/// fleet seed).
const REGISTRY_SALT: u64 = 0xf1ee_7001;

impl FleetRegistry {
    /// Deterministically synthesize `n_nodes` nodes. The first three
    /// cover every [`DeviceKind`] (a fleet of any useful size can always
    /// satisfy any affinity); the rest follow a seeded 50/30/20
    /// Orin/Xavier/Nano mix. Same `(n_nodes, seed)` ⇒ bit-identical
    /// registry. Publishes the built index once at the end (per-node
    /// publication during a registration storm would be quadratic).
    pub fn synthesize(n_nodes: usize, seed: u64) -> FleetRegistry {
        let mut rng = Rng::new(seed ^ REGISTRY_SALT);
        let mut registry = FleetRegistry {
            nodes: Vec::with_capacity(n_nodes),
            clock_s: 0.0,
            observer: Arc::new(NoopObserver),
            index: IndexedSnapshot::default(),
            published: Arc::new(ArcCell::default()),
            last_dirty: 0,
        };
        for i in 0..n_nodes {
            let kind = if i < DeviceKind::ALL.len() {
                DeviceKind::ALL[i]
            } else {
                match rng.below(10) {
                    0..=4 => DeviceKind::OrinAgx,
                    5..=7 => DeviceKind::XavierAgx,
                    _ => DeviceKind::OrinNano,
                }
            };
            registry.register(kind);
        }
        registry.publish();
        registry
    }

    /// Attach an observability proxy; replays registration for the
    /// already-resident nodes so late subscribers see the full fleet.
    pub fn with_observer(mut self, observer: Arc<dyn FleetObserver>) -> FleetRegistry {
        self.observer = observer;
        for node in &self.nodes {
            self.observer.on_register(&node.view());
        }
        self
    }

    /// Register one node of `kind`; ids are assigned densely in
    /// registration order — the id-is-index invariant every indexed
    /// lookup relies on. Appends the node's index entry; does **not**
    /// publish (call [`publish`](Self::publish) after a manual
    /// registration batch, as `synthesize` does).
    pub fn register(&mut self, kind: DeviceKind) -> NodeId {
        let id = NodeId(self.nodes.len() as u32);
        debug_assert_eq!(
            id.0 as usize,
            self.index.len(),
            "id-is-index invariant: node ids are dense registration indices"
        );
        let node = Node::new(id, kind);
        self.observer.on_register(&node.view());
        self.index.push_entry(node.indexed_entry(WarmSet::default()));
        self.nodes.push(node);
        id
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    pub fn clock_s(&self) -> f64 {
        self.clock_s
    }

    /// One deterministic heartbeat: advance the fleet clock by `dt_s`,
    /// drain one slot of load per node, advance every node's sensor +
    /// die state under its current utilization, apply any scripted
    /// per-node fan-off episode from `faults`, and re-derive health.
    ///
    /// Index maintenance is incremental: only entries whose derived
    /// state actually changed (bitwise compare) are rebuilt, and a clone
    /// of the index is published to the lock-free cell only when at
    /// least one entry was dirty.
    pub fn heartbeat(&mut self, dt_s: f64, faults: Option<&FaultInjector>) {
        self.clock_s += dt_s.max(0.0);
        let mut dirty = 0usize;
        for node in &mut self.nodes {
            node.load = node.load.saturating_sub(1);
            let spec = node.kind.spec();
            // utilization drives the simulated draw between idle and peak
            let busy = f64::from(node.load) / f64::from(node.capacity.max(1));
            let draw_mw = spec.p_base_mw + busy * (spec.peak_power_w * 1000.0 - spec.p_base_mw);
            node.sensor.change_mode(draw_mw);
            node.sensor.advance(dt_s);
            node.thermal.fan_max = !faults
                .map(|inj| inj.node_fan_off_at(node.id.0, self.clock_s))
                .unwrap_or(false);
            node.thermal.advance(node.sensor.instantaneous(), dt_s);
            let health = if node.thermal.would_throttle() {
                NodeHealth::Down
            } else if !node.thermal.fan_max {
                NodeHealth::Degraded
            } else {
                NodeHealth::Healthy
            };
            if health != node.health {
                self.observer.on_health_change(node.id, node.health, health);
                node.health = health;
            }
            let old = self.index.entries()[node.id.0 as usize];
            let entry = node.indexed_entry(old.warm);
            if !entry.bits_eq(&old) {
                self.index.update_entry(entry);
                dirty += 1;
            }
        }
        self.index.clock_s = self.clock_s;
        self.last_dirty = dirty;
        if dirty > 0 {
            self.publish();
        }
        self.observer.on_heartbeat(self.clock_s);
    }

    /// Account a placement decided by the router: bump the node's load
    /// and mark the workload warm there — an O(log k) index move, no
    /// publication (the next heartbeat's copy carries it to external
    /// readers).
    pub fn note_placement(&mut self, id: NodeId, workload: Workload) {
        if let Some(node) = self.nodes.get_mut(id.0 as usize) {
            debug_assert_eq!(node.id, id, "id-is-index invariant");
            node.load = node.load.saturating_add(1);
            if !node.warm.contains(&workload) {
                node.warm.push(workload);
            }
            self.index.apply_placement(id, workload);
            self.observer.on_placement(id, &workload);
        }
    }

    /// Immutable projection for the reference router (O(nodes) deep
    /// clone — tests and oracle only; production routes the index).
    pub fn snapshot(&self) -> RegistrySnapshot {
        RegistrySnapshot {
            clock_s: self.clock_s,
            nodes: self.nodes.iter().map(Node::view).collect(),
        }
    }

    /// The live indexed snapshot routing decisions read (callers hold
    /// the fleet's registry lock, so this is always current).
    pub fn indexed(&self) -> &IndexedSnapshot {
        &self.index
    }

    /// The lock-free publication handle: external monitors `load()` the
    /// newest heartbeat-granular copy without touching the registry
    /// mutex. Clone the `Arc` out and read from any thread.
    pub fn publication(&self) -> Arc<ArcCell<IndexedSnapshot>> {
        Arc::clone(&self.published)
    }

    /// Publish a clone of the live index to the lock-free cell now.
    pub fn publish(&mut self) {
        self.published.store(Arc::new(self.index.clone()));
    }

    /// Entries the last heartbeat found changed (bitwise compare); the
    /// heartbeat republished iff this is non-zero.
    pub fn last_dirty(&self) -> usize {
        self.last_dirty
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fleet::index::route_indexed;
    use crate::sim::FaultPlan;

    #[test]
    fn synthesis_is_deterministic_and_covers_every_kind() {
        let a = FleetRegistry::synthesize(64, 7).snapshot();
        let b = FleetRegistry::synthesize(64, 7).snapshot();
        assert_eq!(a, b, "same (n, seed) must produce bit-identical registries");
        for kind in DeviceKind::ALL {
            assert!(a.healthy_of_kind(kind) > 0, "no {} node", kind.name());
        }
        // dense, ordered ids
        for (i, n) in a.nodes.iter().enumerate() {
            assert_eq!(n.id, NodeId(i as u32));
            assert!(n.capacity > 0);
            assert_eq!(n.health, NodeHealth::Healthy);
            assert!(n.headroom_mw > 0.0);
        }
        // a different seed reshuffles the tail mix
        let c = FleetRegistry::synthesize(64, 8).snapshot();
        assert_ne!(
            a.nodes.iter().map(|n| n.kind).collect::<Vec<_>>(),
            c.nodes.iter().map(|n| n.kind).collect::<Vec<_>>()
        );
    }

    #[test]
    fn scripted_node_fan_off_degrades_then_recovers() {
        let plan = FaultPlan {
            node_fan_off: vec![(1, 30.0, 90.0)],
            ..Default::default()
        };
        let inj = FaultInjector::new(plan);
        let mut reg = FleetRegistry::synthesize(4, 1);
        reg.heartbeat(30.0, Some(&inj)); // clock = 30 s: episode starts
        assert_eq!(reg.snapshot().nodes[1].health, NodeHealth::Degraded);
        assert_eq!(reg.snapshot().nodes[0].health, NodeHealth::Healthy);
        reg.heartbeat(30.0, Some(&inj)); // 60 s: still inside
        assert_eq!(reg.snapshot().nodes[1].health, NodeHealth::Degraded);
        reg.heartbeat(30.0, Some(&inj)); // 90 s: half-open end — recovered
        assert_eq!(reg.snapshot().nodes[1].health, NodeHealth::Healthy);
    }

    #[test]
    fn placements_warm_the_node_and_heartbeats_drain_load() {
        let mut reg = FleetRegistry::synthesize(3, 2);
        let wl = Workload::resnet();
        reg.note_placement(NodeId(0), wl);
        reg.note_placement(NodeId(0), wl);
        let snap = reg.snapshot();
        assert_eq!(snap.nodes[0].load, 2);
        assert!(snap.nodes[0].is_warm(&wl));
        assert_eq!(snap.nodes[0].warm.len(), 1, "warm set is deduplicated");
        reg.heartbeat(30.0, None);
        assert_eq!(reg.snapshot().nodes[0].load, 1);
    }

    #[test]
    fn observer_proxy_sees_registration_health_and_placements() {
        let obs = Arc::new(RecordingObserver::default());
        let plan = FaultPlan { node_fan_off: vec![(0, 0.0, 9999.0)], ..Default::default() };
        let inj = FaultInjector::new(plan);
        let mut reg =
            FleetRegistry::synthesize(2, 3).with_observer(Arc::clone(&obs) as Arc<dyn FleetObserver>);
        reg.note_placement(NodeId(1), Workload::bert());
        reg.heartbeat(10.0, Some(&inj));
        let events = obs.events();
        assert!(events.iter().any(|e| e.starts_with("register n000")), "{events:?}");
        assert!(events.iter().any(|e| e.starts_with("place n001")), "{events:?}");
        assert!(
            events.iter().any(|e| e == "health n000 healthy -> degraded"),
            "{events:?}"
        );
        assert!(events.iter().any(|e| e.starts_with("heartbeat")), "{events:?}");
        assert_eq!(obs.dropped(), 0, "a handful of events must not overflow the ring");
    }

    #[test]
    fn recording_observer_ring_caps_and_counts_drops() {
        let obs = RecordingObserver::with_capacity(3);
        for i in 0..8 {
            obs.push(format!("event {i}"));
        }
        let events = obs.events();
        assert_eq!(events, vec!["event 5", "event 6", "event 7"], "newest retained, oldest first");
        assert_eq!(obs.dropped(), 5);
        // a registration storm through the trait stays bounded too
        let obs = Arc::new(RecordingObserver::with_capacity(16));
        let reg =
            FleetRegistry::synthesize(200, 4).with_observer(Arc::clone(&obs) as Arc<dyn FleetObserver>);
        assert_eq!(reg.len(), 200);
        assert_eq!(obs.events().len(), 16);
        assert_eq!(obs.dropped(), 200 - 16);
    }

    #[test]
    fn incremental_index_tracks_every_mutation() {
        let mut reg = FleetRegistry::synthesize(24, 5);
        reg.indexed().check_invariants();
        let wl = Workload::yolo();
        reg.note_placement(NodeId(3), wl);
        reg.note_placement(NodeId(3), wl);
        reg.heartbeat(30.0, None);
        reg.note_placement(NodeId(7), Workload::bert());
        reg.indexed().check_invariants();
        // the incrementally maintained index and a from-scratch rebuild
        // of the legacy snapshot agree on every routing decision
        let rebuilt = IndexedSnapshot::from_registry_snapshot(&reg.snapshot());
        rebuilt.check_invariants();
        for affinity in [None, Some(DeviceKind::OrinAgx), Some(DeviceKind::OrinNano)] {
            for wl in Workload::default_five() {
                assert_eq!(
                    route_indexed(reg.indexed(), affinity, &wl),
                    route_indexed(&rebuilt, affinity, &wl),
                    "incremental index diverged from rebuild at {affinity:?}/{}",
                    wl.name()
                );
            }
        }
        // warmth agrees node-by-node
        for view in &reg.snapshot().nodes {
            for wl in Workload::default_five() {
                assert_eq!(reg.indexed().is_warm(view.id, &wl), view.is_warm(&wl));
            }
        }
    }

    #[test]
    fn publication_is_heartbeat_granular_and_dirty_gated() {
        let mut reg = FleetRegistry::synthesize(8, 6);
        let cell = reg.publication();
        // synthesize published the initial index
        assert_eq!(cell.load().len(), 8);
        // a placement updates the live index immediately but is not
        // published until the next heartbeat...
        reg.note_placement(NodeId(2), Workload::lstm());
        assert_eq!(reg.indexed().entry(NodeId(2)).unwrap().load, 1);
        assert_eq!(cell.load().entry(NodeId(2)).unwrap().load, 0, "publication lags to heartbeat");
        // ...which dirties entries (sensor/thermal advance) and republishes
        reg.heartbeat(30.0, None);
        assert!(reg.last_dirty() > 0);
        let published = cell.load();
        assert_eq!(published.clock_s, reg.clock_s());
        assert!(published.is_warm(NodeId(2), &Workload::lstm()));
        published.check_invariants();
    }
}
