//! The placement scoring contract, and the [`Placement`] type both
//! router implementations produce.
//!
//! Scoring order (first discriminator wins):
//!
//! 1. **Kind match** — only nodes of the requested [`DeviceKind`] are
//!    candidates; a cross-kind fallback fires only when no healthy
//!    in-kind capacity exists anywhere.
//! 2. **Warm-model locality** — a node that has already served this
//!    workload holds its transferred model pair hot.
//! 3. **Least-loaded** — fewest outstanding placements.
//! 4. **Thermal headroom** — largest sustainable-power margin
//!    (`f64::total_cmp` order).
//! 5. **Node id** — the final deterministic tie-break.
//!
//! Two implementations honor this contract **bit-identically**:
//!
//! * [`reference`](crate::fleet::reference) — the linear O(nodes) scan,
//!   kept as the executable oracle;
//! * [`index`](crate::fleet::index) — the production engine: per-kind
//!   `BTreeSet` candidate queues keyed by `(load, ¬headroom_bits, id)`
//!   plus an inverted warm-locality map, making a decision an O(1) peek
//!   and a placement update an O(log k) bucket move.
//!
//! Same seed ⇒ same placements on either path; the differential
//! property suite (`tests/property_fleet_router.rs`) enforces it.
//!
//! [`DeviceKind`]: crate::device::DeviceKind

use crate::device::DeviceKind;
use crate::fleet::registry::NodeId;

/// A routing decision.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Placement {
    /// The node chosen to serve the request.
    pub node: NodeId,
    /// Kind of the chosen node (== the affinity unless `cross_kind`).
    pub kind: DeviceKind,
    /// True when the health-blind first choice was unavailable and the
    /// request was placed elsewhere — the response is stamped
    /// `DegradedPlacement` so the reroute is visible in provenance.
    pub rerouted: bool,
    /// True when no healthy in-kind capacity existed and the request
    /// fell back to a different device kind entirely.
    pub cross_kind: bool,
}
