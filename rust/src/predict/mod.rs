//! Batched prediction over power-mode grids — the request-path hot loop.
//!
//! Given a trained checkpoint, predicts training time / power for every
//! mode of a grid (4,368–29,232 modes). Two backends:
//!
//! * [`predict_modes`] (feature `xla`) streams standardized feature chunks
//!   through the AOT `predict` artifact;
//! * [`GridPredictor`] / [`predict_modes_host`] run the batched,
//!   cache-blocked host engine (`nn::engine`) with the scalers
//!   affine-folded into the weights — the fallback when artifacts are
//!   unavailable, and the backend for baselines and the pure-host builds.
//!
//! Both feed the Pareto construction (paper section 5).

use crate::device::{FeatureMatrix, PowerMode};
use crate::nn::checkpoint::Checkpoint;
use crate::nn::engine::HostEngine;
use crate::profiler::StandardScaler;

#[cfg(feature = "xla")]
use crate::error::Result;
#[cfg(feature = "xla")]
use crate::runtime::{f32_literal, to_f32_vec, Runtime};

/// Predict raw-unit targets (ms or mW) for a slice of power modes using the
/// AOT artifact. Padding rows are zero-features; their outputs are dropped.
#[cfg(feature = "xla")]
pub fn predict_modes(
    rt: &Runtime,
    ckpt: &Checkpoint,
    modes: &[PowerMode],
) -> Result<Vec<f64>> {
    let bsz = rt.manifest.predict_batch;
    let dim = rt.manifest.input_dim;
    let mut out = Vec::with_capacity(modes.len());

    // invariant inputs (weights + target-scaler scalars) are materialized
    // once and re-submitted by reference for every chunk — the dominant
    // per-chunk cost would otherwise be copying ~166 KiB of weights
    // (see EXPERIMENTS.md section Perf)
    let mut const_lits: Vec<xla::Literal> = Vec::with_capacity(10);
    for (i, leaf) in ckpt.params.leaves.iter().enumerate() {
        const_lits.push(f32_literal(leaf, &crate::nn::leaf_shape(i))?);
    }
    let y_mean = f32_literal(&[ckpt.target_scaler.mean[0] as f32], &[])?;
    let y_std = f32_literal(&[ckpt.target_scaler.std[0] as f32], &[])?;

    // feature standardization hoisted out of the inner loop
    let f_mean = &ckpt.feature_scaler.mean;
    let f_std = &ckpt.feature_scaler.std;
    let mut x = vec![0.0f32; bsz * dim];

    for chunk in modes.chunks(bsz) {
        for (row, pm) in chunk.iter().enumerate() {
            let feats = pm.features();
            for d in 0..dim {
                x[row * dim + d] = ((feats[d] as f64 - f_mean[d]) / f_std[d]) as f32;
            }
        }
        // padding rows only exist in the final ragged chunk (every earlier
        // chunk fills the batch exactly); clear them just for that one
        if chunk.len() < bsz {
            for v in x[chunk.len() * dim..].iter_mut() {
                *v = 0.0;
            }
        }
        let x_lit = f32_literal(&x, &[bsz, dim])?;
        let mut inputs: Vec<&xla::Literal> = Vec::with_capacity(11);
        inputs.extend(const_lits.iter());
        inputs.push(&x_lit);
        inputs.push(&y_mean);
        inputs.push(&y_std);
        let outs = rt.execute_refs("predict", &inputs)?;
        let preds = to_f32_vec(&outs[0])?;
        out.extend(preds.iter().take(chunk.len()).map(|&p| p as f64));
    }
    Ok(out)
}

/// Host-engine predictor for one checkpoint, affine-folded: the feature
/// standardization and the inverse target transform are folded into the
/// engine's first/last layer weights at build time (see
/// [`HostEngine::folded`]), so prediction consumes *raw* mode features and
/// the two per-batch affine passes the seed paid disappear entirely.
/// Built once at checkpoint-load time and reused across grids; the
/// [`FeatureMatrix`] entry point shares one SoA feature build across the
/// time and power models and (via the coordinator's cache) across
/// requests.
#[derive(Debug, Clone)]
pub struct GridPredictor {
    engine: HostEngine,
}

impl GridPredictor {
    pub fn new(ckpt: &Checkpoint) -> GridPredictor {
        assert_eq!(ckpt.feature_scaler.dim(), 4, "feature scaler must be 4-wide");
        // σ is sanitized at fit/load time; clamp again so a hand-built
        // scaler with σ = 0 degrades like the transform convention
        // instead of folding an infinity into the weights
        let f_std: Vec<f64> = ckpt
            .feature_scaler
            .std
            .iter()
            .map(|&s| StandardScaler::clamp_std(s))
            .collect();
        GridPredictor {
            engine: HostEngine::folded(
                &ckpt.params,
                &ckpt.feature_scaler.mean,
                &f_std,
                ckpt.target_scaler.mean[0],
                ckpt.target_scaler.std[0],
            ),
        }
    }

    /// Predict raw-unit targets over a prebuilt SoA feature matrix,
    /// appending into `out` (cleared first). This is the grid-resident
    /// hot path: the matrix is built once per grid and shared by both
    /// models and every request that resolves to the same grid.
    pub fn predict_features_into(&self, features: &FeatureMatrix, out: &mut Vec<f64>) {
        let n = features.len();
        out.clear();
        if n == 0 {
            return;
        }
        let mut y = vec![0.0f32; n];
        self.engine.forward_cols_into(features.cols(), &mut y);
        out.reserve(n);
        out.extend(y.iter().map(|&v| v as f64));
    }

    /// Predict raw-unit targets for every mode, appending into `out`
    /// (cleared first). Builds a transient feature matrix; hold one via
    /// [`PowerModeGrid::feature_matrix`](crate::device::PowerModeGrid::feature_matrix)
    /// and use [`GridPredictor::predict_features_into`] to amortize it.
    pub fn predict_into(&self, modes: &[PowerMode], out: &mut Vec<f64>) {
        let features = FeatureMatrix::from_modes(modes);
        self.predict_features_into(&features, out);
    }

    pub fn predict(&self, modes: &[PowerMode]) -> Vec<f64> {
        let mut out = Vec::new();
        self.predict_into(modes, &mut out);
        out
    }

    pub fn predict_features(&self, features: &FeatureMatrix) -> Vec<f64> {
        let mut out = Vec::new();
        self.predict_features_into(features, &mut out);
        out
    }
}

/// Paired time+power predictor over one shared feature matrix — the
/// serve-plane build path of the coordinator pipeline. Keeps the two
/// folded engines together as a unit and shares one f32 output scratch
/// between them, so building a plane allocates a single staging buffer
/// instead of one per model.
#[derive(Debug, Clone)]
pub struct PlanePredictor {
    time: GridPredictor,
    power: GridPredictor,
}

impl PlanePredictor {
    pub fn new(time: &Checkpoint, power: &Checkpoint) -> PlanePredictor {
        PlanePredictor {
            time: GridPredictor::new(time),
            power: GridPredictor::new(power),
        }
    }

    /// Raw-unit (times, powers) parallel to the matrix rows — bitwise
    /// identical to running the two [`GridPredictor`]s independently
    /// (property-tested), just without the second scratch allocation.
    pub fn predict_features(&self, features: &FeatureMatrix) -> (Vec<f64>, Vec<f64>) {
        let n = features.len();
        let mut times = Vec::with_capacity(n);
        let mut powers = Vec::with_capacity(n);
        if n == 0 {
            return (times, powers);
        }
        let mut scratch = vec![0.0f32; n];
        self.time.engine.forward_cols_into(features.cols(), &mut scratch);
        times.extend(scratch.iter().map(|&v| v as f64));
        self.power.engine.forward_cols_into(features.cols(), &mut scratch);
        powers.extend(scratch.iter().map(|&v| v as f64));
        (times, powers)
    }
}

/// Pure-rust fallback prediction (no XLA) — used for verification, by
/// baselines that don't warrant an artifact round-trip, and by the
/// coordinator when artifacts are unavailable. One engine build per call;
/// hold a [`GridPredictor`] to amortize it across requests.
pub fn predict_modes_host(ckpt: &Checkpoint, modes: &[PowerMode]) -> Vec<f64> {
    GridPredictor::new(ckpt).predict(modes)
}

/// Host-path MAPE (%) of a checkpoint against a profiled corpus's
/// recorded targets — the holdout-evaluation step of the host training /
/// transfer loop (paper's headline accuracy metric), computed through
/// the same folded engine that serves predictions.
pub fn corpus_mape_host(
    ckpt: &Checkpoint,
    corpus: &crate::profiler::Corpus,
    target: crate::train::Target,
) -> f64 {
    let modes: Vec<PowerMode> = corpus.records().iter().map(|r| r.mode).collect();
    let preds = predict_modes_host(ckpt, &modes);
    crate::util::stats::mape(&preds, &target.values(corpus))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::{DeviceKind, PowerModeGrid};
    use crate::nn::{host_mlp, MlpParams};
    use crate::util::rng::Rng;

    fn demo_ckpt() -> Checkpoint {
        let mut rng = Rng::new(3);
        Checkpoint {
            params: MlpParams::init_he(&mut rng),
            feature_scaler: StandardScaler {
                mean: vec![6.0, 1200.0, 700.0, 1500.0],
                std: vec![3.0, 600.0, 350.0, 1000.0],
            },
            target_scaler: StandardScaler { mean: vec![100.0], std: vec![40.0] },
            target: "time".into(),
            provenance: "test".into(),
            val_loss: 0.0,
        }
    }

    #[test]
    fn host_prediction_is_deterministic_and_scaled() {
        let ckpt = demo_ckpt();
        let grid = PowerModeGrid::paper_subset(DeviceKind::OrinAgx);
        let modes = &grid.modes[..100];
        let a = predict_modes_host(&ckpt, modes);
        let b = predict_modes_host(&ckpt, modes);
        assert_eq!(a, b);
        assert_eq!(a.len(), 100);
        // outputs live in raw-unit space (mean 100, std 40): not all ~0
        let spread = a.iter().cloned().fold(f64::MIN, f64::max)
            - a.iter().cloned().fold(f64::MAX, f64::min);
        assert!(spread > 1.0, "degenerate predictions");
    }

    #[test]
    fn engine_path_matches_scalar_oracle() {
        // the batched, affine-folded engine must agree with the seed
        // scalar path (standardize -> forward_one -> inverse) within 1e-5
        // relative. The tolerance floor is the target scale σ_y: after the
        // output fold a raw prediction near zero is the difference of
        // σ_y-sized quantities, so agreement is meaningful relative to
        // that scale, not to an accidentally tiny value.
        let ckpt = demo_ckpt();
        let y_scale = ckpt.target_scaler.std[0];
        let grid = PowerModeGrid::paper_subset(DeviceKind::OrinAgx);
        let modes = &grid.modes[..517]; // ragged vs the 64-row tile
        let got = predict_modes_host(&ckpt, modes);
        for (i, pm) in modes.iter().enumerate() {
            let feats = pm.features();
            let raw: Vec<f64> = feats.iter().map(|&v| v as f64).collect();
            let z = ckpt.feature_scaler.transform_row(&raw);
            let zf = [z[0] as f32, z[1] as f32, z[2] as f32, z[3] as f32];
            let want = ckpt
                .target_scaler
                .inverse1(host_mlp::forward_one(&ckpt.params, &zf) as f64);
            assert!(
                (got[i] - want).abs() <= 1e-5 * want.abs().max(y_scale),
                "mode {i}: engine {} vs oracle {want}",
                got[i]
            );
        }
    }

    #[test]
    fn feature_matrix_path_matches_mode_path_exactly() {
        // the shared-SoA entry point is the same computation as the
        // per-call path — bitwise identical outputs
        let ckpt = demo_ckpt();
        let grid = PowerModeGrid::paper_subset(DeviceKind::OrinAgx);
        let p = GridPredictor::new(&ckpt);
        let fm = grid.feature_matrix();
        assert_eq!(p.predict(&grid.modes), p.predict_features(&fm));
    }

    #[test]
    fn corpus_mape_host_matches_manual_computation() {
        use crate::profiler::{Corpus, Record};
        use crate::train::Target;
        let ckpt = demo_ckpt();
        let grid = PowerModeGrid::paper_subset(DeviceKind::OrinAgx);
        let mut corpus = Corpus::new(DeviceKind::OrinAgx, crate::workload::Workload::resnet());
        for (i, pm) in grid.modes[..30].iter().enumerate() {
            corpus.push(Record {
                mode: *pm,
                time_ms: 100.0 + i as f64,
                power_mw: 20_000.0,
                cost_s: 0.0,
            });
        }
        let got = corpus_mape_host(&ckpt, &corpus, Target::Time);
        let preds = predict_modes_host(&ckpt, &grid.modes[..30]);
        let want = crate::util::stats::mape(&preds, &corpus.times_ms());
        assert_eq!(got, want);
        assert!(got.is_finite());
    }

    #[test]
    fn plane_predictor_matches_independent_grid_predictors_exactly() {
        // the paired path shares a scratch buffer but must stay bitwise
        // identical to two independent predictions
        let mut rng = Rng::new(9);
        let time_ckpt = demo_ckpt();
        let mut power_ckpt = demo_ckpt();
        power_ckpt.params = MlpParams::init_he(&mut rng);
        power_ckpt.target_scaler = StandardScaler { mean: vec![25_000.0], std: vec![9_000.0] };
        let grid = PowerModeGrid::paper_subset(DeviceKind::OrinAgx);
        let fm = grid.feature_matrix();
        let (times, powers) = PlanePredictor::new(&time_ckpt, &power_ckpt).predict_features(&fm);
        assert_eq!(times, GridPredictor::new(&time_ckpt).predict_features(&fm));
        assert_eq!(powers, GridPredictor::new(&power_ckpt).predict_features(&fm));
        // empty matrices degrade cleanly
        let empty = FeatureMatrix::from_modes(&[]);
        let (t, p) = PlanePredictor::new(&time_ckpt, &power_ckpt).predict_features(&empty);
        assert!(t.is_empty() && p.is_empty());
    }

    #[test]
    fn predict_into_reuses_output_buffer() {
        let ckpt = demo_ckpt();
        let grid = PowerModeGrid::paper_subset(DeviceKind::OrinAgx);
        let p = GridPredictor::new(&ckpt);
        let mut out = Vec::new();
        p.predict_into(&grid.modes[..80], &mut out);
        assert_eq!(out.len(), 80);
        let first = out.clone();
        p.predict_into(&grid.modes[..80], &mut out);
        assert_eq!(out, first);
        p.predict_into(&[], &mut out);
        assert!(out.is_empty());
    }
}
