//! Batched prediction over power-mode grids — the request-path hot loop.
//!
//! Given a trained checkpoint, predicts training time / power for every
//! mode of a grid (4,368–29,232 modes) by streaming standardized feature
//! chunks through the AOT `predict` artifact. This feeds the Pareto
//! construction (paper section 5).

use crate::device::PowerMode;
use crate::error::Result;
use crate::nn::checkpoint::Checkpoint;
use crate::nn::host_mlp;
use crate::runtime::{f32_literal, to_f32_vec, Runtime};

/// Predict raw-unit targets (ms or mW) for a slice of power modes using the
/// AOT artifact. Padding rows are zero-features; their outputs are dropped.
pub fn predict_modes(
    rt: &Runtime,
    ckpt: &Checkpoint,
    modes: &[PowerMode],
) -> Result<Vec<f64>> {
    let bsz = rt.manifest.predict_batch;
    let dim = rt.manifest.input_dim;
    let mut out = Vec::with_capacity(modes.len());

    // invariant inputs (weights + target-scaler scalars) are materialized
    // once and re-submitted by reference for every chunk — the dominant
    // per-chunk cost would otherwise be copying ~166 KiB of weights
    // (see EXPERIMENTS.md section Perf)
    let mut const_lits: Vec<xla::Literal> = Vec::with_capacity(10);
    for (i, leaf) in ckpt.params.leaves.iter().enumerate() {
        const_lits.push(f32_literal(leaf, &crate::nn::leaf_shape(i))?);
    }
    let y_mean = f32_literal(&[ckpt.target_scaler.mean[0] as f32], &[])?;
    let y_std = f32_literal(&[ckpt.target_scaler.std[0] as f32], &[])?;

    // feature standardization hoisted out of the inner loop
    let f_mean = &ckpt.feature_scaler.mean;
    let f_std = &ckpt.feature_scaler.std;
    let mut x = vec![0.0f32; bsz * dim];

    for chunk in modes.chunks(bsz) {
        for (row, pm) in chunk.iter().enumerate() {
            let feats = pm.features();
            for d in 0..dim {
                x[row * dim + d] = ((feats[d] as f64 - f_mean[d]) / f_std[d]) as f32;
            }
        }
        // zero any padding rows left over from a previous larger chunk
        for v in x[chunk.len() * dim..].iter_mut() {
            *v = 0.0;
        }
        let x_lit = f32_literal(&x, &[bsz, dim])?;
        let mut inputs: Vec<&xla::Literal> = Vec::with_capacity(11);
        inputs.extend(const_lits.iter());
        inputs.push(&x_lit);
        inputs.push(&y_mean);
        inputs.push(&y_std);
        let outs = rt.execute_refs("predict", &inputs)?;
        let preds = to_f32_vec(&outs[0])?;
        out.extend(preds.iter().take(chunk.len()).map(|&p| p as f64));
    }
    Ok(out)
}

/// Pure-rust fallback prediction (no XLA) — used for verification and by
/// baselines that don't warrant an artifact round-trip.
pub fn predict_modes_host(ckpt: &Checkpoint, modes: &[PowerMode]) -> Vec<f64> {
    modes
        .iter()
        .map(|pm| {
            let feats = pm.features();
            let raw: Vec<f64> = feats.iter().map(|&v| v as f64).collect();
            let z = ckpt.feature_scaler.transform_row(&raw);
            let zf = [z[0] as f32, z[1] as f32, z[2] as f32, z[3] as f32];
            let pred_std = host_mlp::forward_one(&ckpt.params, &zf) as f64;
            ckpt.target_scaler.inverse1(pred_std)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::{DeviceKind, PowerModeGrid};
    use crate::nn::MlpParams;
    use crate::profiler::StandardScaler;
    use crate::util::rng::Rng;

    fn demo_ckpt() -> Checkpoint {
        let mut rng = Rng::new(3);
        Checkpoint {
            params: MlpParams::init_he(&mut rng),
            feature_scaler: StandardScaler {
                mean: vec![6.0, 1200.0, 700.0, 1500.0],
                std: vec![3.0, 600.0, 350.0, 1000.0],
            },
            target_scaler: StandardScaler { mean: vec![100.0], std: vec![40.0] },
            target: "time".into(),
            provenance: "test".into(),
            val_loss: 0.0,
        }
    }

    #[test]
    fn host_prediction_is_deterministic_and_scaled() {
        let ckpt = demo_ckpt();
        let grid = PowerModeGrid::paper_subset(DeviceKind::OrinAgx);
        let modes = &grid.modes[..100];
        let a = predict_modes_host(&ckpt, modes);
        let b = predict_modes_host(&ckpt, modes);
        assert_eq!(a, b);
        assert_eq!(a.len(), 100);
        // outputs live in raw-unit space (mean 100, std 40): not all ~0
        let spread = a.iter().cloned().fold(f64::MIN, f64::max)
            - a.iter().cloned().fold(f64::MAX, f64::min);
        assert!(spread > 1.0, "degenerate predictions");
    }
}
