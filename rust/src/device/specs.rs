//! Device specifications for the three Jetson devkits the paper evaluates
//! (Table 2), plus the appendix reference machines (Table 5).
//!
//! Frequency tables follow the L4T/JetPack levels for each board. The paper
//! notes (section 2.5, footnote 7) that the exact frequency lists vary with
//! BSP version; what matters for the reproduction is the *cardinality*
//! (29/13/4 levels for Orin etc.) which Table 2 fixes, and which our grids
//! match exactly: Orin 18,096 modes, Xavier 29,232, Nano 1,800.

/// The devices modeled by the simulator.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DeviceKind {
    /// Jetson Orin AGX 32GB devkit (Ampere, 2048 CUDA cores) — the paper's
    /// primary device and the reference-model host.
    OrinAgx,
    /// Jetson Xavier AGX (Volta, 512 CUDA cores) — previous generation.
    XavierAgx,
    /// Jetson Orin Nano (Ampere, 1024 CUDA cores) — same generation,
    /// 6.9x less powerful.
    OrinNano,
}

impl DeviceKind {
    pub const ALL: [DeviceKind; 3] =
        [DeviceKind::OrinAgx, DeviceKind::XavierAgx, DeviceKind::OrinNano];

    pub fn name(&self) -> &'static str {
        match self {
            DeviceKind::OrinAgx => "orin-agx",
            DeviceKind::XavierAgx => "xavier-agx",
            DeviceKind::OrinNano => "orin-nano",
        }
    }

    pub fn parse(s: &str) -> Option<DeviceKind> {
        match s {
            "orin-agx" | "orin" => Some(DeviceKind::OrinAgx),
            "xavier-agx" | "xavier" => Some(DeviceKind::XavierAgx),
            "orin-nano" | "nano" => Some(DeviceKind::OrinNano),
            _ => None,
        }
    }

    pub fn spec(&self) -> &'static DeviceSpec {
        match self {
            DeviceKind::OrinAgx => &ORIN_AGX,
            DeviceKind::XavierAgx => &XAVIER_AGX,
            DeviceKind::OrinNano => &ORIN_NANO,
        }
    }
}

/// Full static description of a device: the power-mode parameter space plus
/// the simulator's performance/power coefficients (calibrated against the
/// paper's anchor measurements, see `sim/calibration.rs`).
#[derive(Debug, Clone)]
pub struct DeviceSpec {
    pub kind: DeviceKind,
    pub cpu_arch: &'static str,
    pub gpu_arch: &'static str,
    pub cuda_cores: u32,
    pub max_cores: u32,
    /// Available CPU frequencies in kHz, ascending.
    pub cpu_khz: &'static [u32],
    /// Available GPU frequencies in kHz, ascending.
    pub gpu_khz: &'static [u32],
    /// Available memory (EMC) frequencies in kHz, ascending.
    pub mem_khz: &'static [u32],

    // ---- simulator performance coefficients -------------------------------
    /// GPU throughput in Orin-relative units (Orin == 1.0); the time model
    /// divides workload GPU work by `gpu_tput * gpu_ghz`.
    pub gpu_tput: f64,
    /// CPU per-core IPC relative to the Orin A78AE.
    pub cpu_eff: f64,
    /// Memory bandwidth at max EMC frequency, Orin-relative.
    pub mem_bw: f64,

    // ---- simulator power coefficients (milliwatts) ------------------------
    /// Idle/base board power.
    pub p_base_mw: f64,
    /// Max dynamic power of one CPU core at top frequency, full load.
    pub p_core_max_mw: f64,
    /// Max dynamic GPU power at top frequency, full load.
    pub p_gpu_max_mw: f64,
    /// Max memory-subsystem power at top EMC frequency, full activity.
    pub p_mem_max_mw: f64,
    /// Advertised module peak power (W) — sanity ceiling.
    pub peak_power_w: f64,
}

impl DeviceSpec {
    pub fn max_cpu_khz(&self) -> u32 {
        *self.cpu_khz.last().unwrap()
    }
    pub fn max_gpu_khz(&self) -> u32 {
        *self.gpu_khz.last().unwrap()
    }
    pub fn max_mem_khz(&self) -> u32 {
        *self.mem_khz.last().unwrap()
    }

    /// Total size of the power-mode space (paper Table 2 bottom row).
    pub fn total_power_modes(&self) -> usize {
        self.max_cores as usize
            * self.cpu_khz.len()
            * self.gpu_khz.len()
            * self.mem_khz.len()
    }
}

/// Orin AGX CPU frequencies (kHz): 29 levels, 115.2 MHz – 2.2016 GHz.
static ORIN_CPU_KHZ: [u32; 29] = [
    115_200, 192_000, 268_800, 345_600, 422_400, 499_200, 576_000, 652_800,
    729_600, 806_400, 883_200, 960_000, 1_036_800, 1_113_600, 1_190_400,
    1_267_200, 1_344_000, 1_420_800, 1_497_600, 1_574_400, 1_651_200,
    1_728_000, 1_804_800, 1_881_600, 1_958_400, 2_035_200, 2_112_000,
    2_188_800, 2_201_600,
];

/// Orin AGX GPU frequencies (kHz): 13 levels up to 1.3005 GHz.
static ORIN_GPU_KHZ: [u32; 13] = [
    114_750, 216_750, 318_750, 420_750, 522_750, 624_750, 726_750, 828_750,
    930_750, 1_032_750, 1_134_750, 1_236_750, 1_300_500,
];

/// Orin AGX EMC frequencies (kHz): 4 levels up to 3.199 GHz.
static ORIN_MEM_KHZ: [u32; 4] = [204_000, 665_600, 2_133_000, 3_199_000];

pub static ORIN_AGX: DeviceSpec = DeviceSpec {
    kind: DeviceKind::OrinAgx,
    cpu_arch: "ARM A78AE",
    gpu_arch: "Ampere",
    cuda_cores: 2048,
    max_cores: 12,
    cpu_khz: &ORIN_CPU_KHZ,
    gpu_khz: &ORIN_GPU_KHZ,
    mem_khz: &ORIN_MEM_KHZ,
    gpu_tput: 1.0,
    cpu_eff: 1.0,
    mem_bw: 1.0,
    p_base_mw: 6_200.0,
    p_core_max_mw: 1_350.0,
    p_gpu_max_mw: 30_500.0,
    p_mem_max_mw: 11_000.0,
    peak_power_w: 60.0,
};

/// Xavier AGX CPU frequencies (kHz): 29 levels up to 2.2656 GHz (Carmel).
static XAVIER_CPU_KHZ: [u32; 29] = [
    115_200, 192_000, 268_800, 345_600, 422_400, 499_200, 576_000, 652_800,
    729_600, 806_400, 883_200, 960_000, 1_036_800, 1_113_600, 1_190_400,
    1_267_200, 1_344_000, 1_420_800, 1_497_600, 1_574_400, 1_651_200,
    1_728_000, 1_804_800, 1_881_600, 1_958_400, 2_035_200, 2_112_000,
    2_188_800, 2_265_600,
];

/// Xavier AGX GPU frequencies (kHz): 14 levels up to 1.377 GHz (Volta).
static XAVIER_GPU_KHZ: [u32; 14] = [
    114_750, 216_750, 318_750, 420_750, 522_750, 624_750, 675_750, 828_750,
    905_250, 1_032_750, 1_198_500, 1_236_750, 1_338_750, 1_377_000,
];

/// Xavier AGX EMC frequencies (kHz): 9 levels up to 2.133 GHz (LPDDR4).
static XAVIER_MEM_KHZ: [u32; 9] = [
    204_000, 408_000, 665_600, 800_000, 1_065_600, 1_331_200, 1_600_000,
    1_866_000, 2_133_000,
];

pub static XAVIER_AGX: DeviceSpec = DeviceSpec {
    kind: DeviceKind::XavierAgx,
    cpu_arch: "ARM Carmel",
    gpu_arch: "Volta",
    cuda_cores: 512,
    max_cores: 8,
    cpu_khz: &XAVIER_CPU_KHZ,
    gpu_khz: &XAVIER_GPU_KHZ,
    mem_khz: &XAVIER_MEM_KHZ,
    gpu_tput: 0.345,
    cpu_eff: 0.92,
    mem_bw: 0.55,
    p_base_mw: 5_500.0,
    p_core_max_mw: 1_750.0,
    p_gpu_max_mw: 21_500.0,
    p_mem_max_mw: 7_500.0,
    peak_power_w: 65.0,
};

/// Orin Nano CPU frequencies (kHz): 20 levels up to 1.5104 GHz.
static NANO_CPU_KHZ: [u32; 20] = [
    115_200, 192_000, 268_800, 345_600, 422_400, 499_200, 576_000, 652_800,
    729_600, 806_400, 883_200, 960_000, 1_036_800, 1_113_600, 1_190_400,
    1_267_200, 1_344_000, 1_420_800, 1_497_600, 1_510_400,
];

/// Orin Nano GPU frequencies (kHz): 5 levels up to 624.75 MHz.
static NANO_GPU_KHZ: [u32; 5] = [306_000, 408_000, 510_000, 612_000, 624_750];

/// Orin Nano EMC frequencies (kHz): 3 levels up to 2.133 GHz.
static NANO_MEM_KHZ: [u32; 3] = [665_600, 1_600_000, 2_133_000];

pub static ORIN_NANO: DeviceSpec = DeviceSpec {
    kind: DeviceKind::OrinNano,
    cpu_arch: "ARM A78AE",
    gpu_arch: "Ampere",
    cuda_cores: 1024,
    max_cores: 6,
    cpu_khz: &NANO_CPU_KHZ,
    gpu_khz: &NANO_GPU_KHZ,
    mem_khz: &NANO_MEM_KHZ,
    gpu_tput: 0.33,
    cpu_eff: 0.95,
    mem_bw: 0.4,
    p_base_mw: 1_900.0,
    p_core_max_mw: 520.0,
    p_gpu_max_mw: 6_800.0,
    p_mem_max_mw: 3_300.0,
    peak_power_w: 15.0,
};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_cardinalities_match_paper_table2() {
        assert_eq!(ORIN_AGX.total_power_modes(), 18_096);
        assert_eq!(XAVIER_AGX.total_power_modes(), 29_232);
        assert_eq!(ORIN_NANO.total_power_modes(), 1_800);
    }

    #[test]
    fn frequency_level_counts_match_paper() {
        assert_eq!(ORIN_AGX.cpu_khz.len(), 29);
        assert_eq!(ORIN_AGX.gpu_khz.len(), 13);
        assert_eq!(ORIN_AGX.mem_khz.len(), 4);
        assert_eq!(XAVIER_AGX.cpu_khz.len(), 29);
        assert_eq!(XAVIER_AGX.gpu_khz.len(), 14);
        assert_eq!(XAVIER_AGX.mem_khz.len(), 9);
        assert_eq!(ORIN_NANO.cpu_khz.len(), 20);
        assert_eq!(ORIN_NANO.gpu_khz.len(), 5);
        assert_eq!(ORIN_NANO.mem_khz.len(), 3);
    }

    #[test]
    fn max_frequencies_match_paper() {
        assert_eq!(ORIN_AGX.max_cpu_khz(), 2_201_600); // 2.2 GHz
        assert_eq!(ORIN_AGX.max_gpu_khz(), 1_300_500); // 1.3 GHz
        assert_eq!(ORIN_AGX.max_mem_khz(), 3_199_000); // 3.2 GHz
        assert_eq!(XAVIER_AGX.max_cpu_khz(), 2_265_600);
        assert_eq!(XAVIER_AGX.max_gpu_khz(), 1_377_000);
        assert_eq!(ORIN_NANO.max_gpu_khz(), 624_750);
    }

    #[test]
    fn frequency_tables_strictly_ascending() {
        for kind in DeviceKind::ALL {
            let s = kind.spec();
            for tbl in [s.cpu_khz, s.gpu_khz, s.mem_khz] {
                assert!(
                    tbl.windows(2).all(|w| w[0] < w[1]),
                    "non-ascending freq table on {:?}",
                    kind
                );
            }
        }
    }

    #[test]
    fn kind_name_round_trips() {
        for kind in DeviceKind::ALL {
            assert_eq!(DeviceKind::parse(kind.name()), Some(kind));
        }
        assert_eq!(DeviceKind::parse("rtx3090"), None);
    }
}
