//! Power modes, power-mode grids, sampling subsets and reboot-aware
//! profiling orderings (paper sections 1.1, 2.5).

use crate::device::specs::{DeviceKind, DeviceSpec};
use crate::error::{Error, Result};
use crate::util::rng::Rng;

/// One power-mode configuration: active CPU cores + CPU/GPU/EMC frequency.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct PowerMode {
    pub cores: u32,
    pub cpu_khz: u32,
    pub gpu_khz: u32,
    pub mem_khz: u32,
}

impl PowerMode {
    /// Validate this mode against a device's tables.
    pub fn validate(&self, spec: &DeviceSpec) -> Result<()> {
        if self.cores == 0 || self.cores > spec.max_cores {
            return Err(Error::Device(format!(
                "{} cores invalid for {} (max {})",
                self.cores,
                spec.kind.name(),
                spec.max_cores
            )));
        }
        for (val, tbl, what) in [
            (self.cpu_khz, spec.cpu_khz, "cpu"),
            (self.gpu_khz, spec.gpu_khz, "gpu"),
            (self.mem_khz, spec.mem_khz, "mem"),
        ] {
            if !tbl.contains(&val) {
                return Err(Error::Device(format!(
                    "{what} freq {val} kHz not available on {}",
                    spec.kind.name()
                )));
            }
        }
        Ok(())
    }

    /// The MAXN mode: everything at maximum (Nvidia's default).
    pub fn maxn(spec: &DeviceSpec) -> PowerMode {
        PowerMode {
            cores: spec.max_cores,
            cpu_khz: spec.max_cpu_khz(),
            gpu_khz: spec.max_gpu_khz(),
            mem_khz: spec.max_mem_khz(),
        }
    }

    /// Raw feature vector for the prediction models:
    /// `[cores, cpu_mhz, gpu_mhz, mem_mhz]` (standardized downstream).
    pub fn features(&self) -> [f32; 4] {
        [
            self.cores as f32,
            self.cpu_khz as f32 / 1000.0,
            self.gpu_khz as f32 / 1000.0,
            self.mem_khz as f32 / 1000.0,
        ]
    }

    /// Short display form matching the paper, e.g. `12c/2.20C/1.30G/3.20M`.
    pub fn label(&self) -> String {
        format!(
            "{}c/{:.2}C/{:.2}G/{:.2}M",
            self.cores,
            self.cpu_khz as f64 / 1e6,
            self.gpu_khz as f64 / 1e6,
            self.mem_khz as f64 / 1e6,
        )
    }
}

/// Nvidia's pre-defined power modes with power budgets (besides MAXN)
/// for every [`DeviceKind`] — the Fig 2c baseline on Orin AGX, and the
/// factory preset tables the fleet baselines use on Xavier AGX / Orin
/// Nano. Every mode draws its frequencies from the device's discrete
/// spec tables (validated by the preset tests), so presets are always
/// legal [`PowerMode`]s on their own device.
pub fn nvidia_preset_modes(kind: DeviceKind) -> Vec<(f64, PowerMode)> {
    match kind {
        DeviceKind::OrinAgx => vec![
            (
                15.0,
                PowerMode { cores: 4, cpu_khz: 1_113_600, gpu_khz: 420_750, mem_khz: 2_133_000 },
            ),
            (
                30.0,
                PowerMode { cores: 8, cpu_khz: 1_728_000, gpu_khz: 624_750, mem_khz: 3_199_000 },
            ),
            (
                50.0,
                PowerMode { cores: 12, cpu_khz: 1_497_600, gpu_khz: 828_750, mem_khz: 3_199_000 },
            ),
        ],
        DeviceKind::XavierAgx => vec![
            (
                10.0,
                PowerMode { cores: 2, cpu_khz: 1_190_400, gpu_khz: 522_750, mem_khz: 1_065_600 },
            ),
            (
                15.0,
                PowerMode { cores: 4, cpu_khz: 1_267_200, gpu_khz: 675_750, mem_khz: 1_331_200 },
            ),
            (
                30.0,
                PowerMode { cores: 8, cpu_khz: 1_497_600, gpu_khz: 905_250, mem_khz: 1_600_000 },
            ),
        ],
        DeviceKind::OrinNano => vec![
            (
                7.0,
                PowerMode { cores: 4, cpu_khz: 960_000, gpu_khz: 408_000, mem_khz: 665_600 },
            ),
            (
                15.0,
                PowerMode { cores: 6, cpu_khz: 1_510_400, gpu_khz: 624_750, mem_khz: 2_133_000 },
            ),
        ],
    }
}

/// SoA `f32` feature matrix for a set of power modes: four contiguous
/// columns (`cores`, `cpu MHz`, `gpu MHz`, `mem MHz`), the raw-feature
/// layout the affine-folded host engine streams through its first layer.
/// Built once per grid and shared by every model that predicts over it —
/// both the time and power predictors, and (via the coordinator's cache)
/// every request that resolves to the same grid.
#[derive(Debug, Clone, PartialEq)]
pub struct FeatureMatrix {
    n: usize,
    cols: [Vec<f32>; 4],
}

impl FeatureMatrix {
    pub fn from_modes(modes: &[PowerMode]) -> FeatureMatrix {
        let n = modes.len();
        let mut cols: [Vec<f32>; 4] = [
            Vec::with_capacity(n),
            Vec::with_capacity(n),
            Vec::with_capacity(n),
            Vec::with_capacity(n),
        ];
        for pm in modes {
            let f = pm.features();
            for d in 0..4 {
                cols[d].push(f[d]);
            }
        }
        FeatureMatrix { n, cols }
    }

    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// The four feature columns, each `len()` long.
    pub fn cols(&self) -> [&[f32]; 4] {
        [&self.cols[0], &self.cols[1], &self.cols[2], &self.cols[3]]
    }
}

/// A materialized set of power modes for one device.
#[derive(Debug, Clone)]
pub struct PowerModeGrid {
    pub kind: DeviceKind,
    pub modes: Vec<PowerMode>,
}

impl PowerModeGrid {
    /// The complete power-mode space of the device (Orin: 18,096).
    pub fn full(kind: DeviceKind) -> PowerModeGrid {
        let spec = kind.spec();
        let mut modes = Vec::with_capacity(spec.total_power_modes());
        for &mem in spec.mem_khz {
            for &gpu in spec.gpu_khz {
                for cores in 1..=spec.max_cores {
                    for &cpu in spec.cpu_khz {
                        modes.push(PowerMode { cores, cpu_khz: cpu, gpu_khz: gpu, mem_khz: mem });
                    }
                }
            }
        }
        PowerModeGrid { kind, modes }
    }

    /// The paper's uniformly-distributed Orin profiling subset (section 2.5):
    /// all GPU (13) x all mem (4) x even core counts (6) x every alternate
    /// CPU frequency excluding the two slowest (14) = 4,368 modes.
    pub fn paper_subset(kind: DeviceKind) -> PowerModeGrid {
        let spec = kind.spec();
        let cpu_sel: Vec<u32> = spec
            .cpu_khz
            .iter()
            .skip(2) // exclude the two slowest
            .enumerate()
            .filter(|(i, _)| i % 2 == 0)
            .map(|(_, &f)| f)
            .collect();
        let core_sel: Vec<u32> = (1..=spec.max_cores).filter(|c| c % 2 == 0).collect();
        let mut modes = Vec::new();
        for &mem in spec.mem_khz {
            for &gpu in spec.gpu_khz {
                for &cores in &core_sel {
                    for &cpu in &cpu_sel {
                        modes.push(PowerMode { cores, cpu_khz: cpu, gpu_khz: gpu, mem_khz: mem });
                    }
                }
            }
        }
        PowerModeGrid { kind, modes }
    }

    /// Random subset of the full space, as used for the Xavier (1,000 of
    /// 29,232) and Nano (180 of 1,800) corpora.
    pub fn random_subset(kind: DeviceKind, n: usize, rng: &mut Rng) -> PowerModeGrid {
        let full = PowerModeGrid::full(kind);
        let idx = rng.sample_indices(full.modes.len(), n.min(full.modes.len()));
        let modes = idx.into_iter().map(|i| full.modes[i]).collect();
        PowerModeGrid { kind, modes }
    }

    pub fn len(&self) -> usize {
        self.modes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.modes.is_empty()
    }

    /// The grid's SoA feature matrix (see [`FeatureMatrix`]).
    pub fn feature_matrix(&self) -> FeatureMatrix {
        FeatureMatrix::from_modes(&self.modes)
    }

    /// Sample `n` modes without replacement from this grid.
    pub fn sample(&self, n: usize, rng: &mut Rng) -> Vec<PowerMode> {
        rng.sample_indices(self.modes.len(), n.min(self.modes.len()))
            .into_iter()
            .map(|i| self.modes[i])
            .collect()
    }
}

/// One step of a profiling plan: configure this mode; `reboot` marks that
/// reaching it from the previous step requires a device reboot.
#[derive(Debug, Clone, Copy)]
pub struct ProfilingStep {
    pub mode: PowerMode,
    pub reboot: bool,
}

/// Reboot-aware profiling order (paper section 2.5, footnote 8): the Jetson
/// only supports *lowering* CPU/GPU frequencies at runtime; raising either
/// requires a reboot. The plan orders modes to minimize reboots: group by
/// descending CPU frequency, sweep GPU descending within each group, so a
/// reboot is only needed when a new CPU group begins (GPU must jump back up).
#[derive(Debug, Clone)]
pub struct ProfilingPlan {
    pub steps: Vec<ProfilingStep>,
}

impl ProfilingPlan {
    pub fn build(modes: &[PowerMode]) -> ProfilingPlan {
        let mut sorted: Vec<PowerMode> = modes.to_vec();
        // order: cpu desc, then gpu desc, then mem desc, then cores desc —
        // within a cpu group every transition only lowers gpu (or keeps it,
        // varying mem/cores which are freely settable).
        sorted.sort_by(|a, b| {
            b.cpu_khz
                .cmp(&a.cpu_khz)
                .then(b.gpu_khz.cmp(&a.gpu_khz))
                .then(b.mem_khz.cmp(&a.mem_khz))
                .then(b.cores.cmp(&a.cores))
        });
        let mut steps = Vec::with_capacity(sorted.len());
        let mut prev: Option<PowerMode> = None;
        for mode in sorted {
            let reboot = match prev {
                None => false, // assume freshly booted at max
                Some(p) => mode.cpu_khz > p.cpu_khz || mode.gpu_khz > p.gpu_khz,
            };
            steps.push(ProfilingStep { mode, reboot });
            prev = Some(mode);
        }
        ProfilingPlan { steps }
    }

    pub fn reboot_count(&self) -> usize {
        self.steps.iter().filter(|s| s.reboot).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_grid_sizes_match_paper() {
        assert_eq!(PowerModeGrid::full(DeviceKind::OrinAgx).len(), 18_096);
        assert_eq!(PowerModeGrid::full(DeviceKind::XavierAgx).len(), 29_232);
        assert_eq!(PowerModeGrid::full(DeviceKind::OrinNano).len(), 1_800);
    }

    #[test]
    fn paper_subset_is_4368_for_orin() {
        let g = PowerModeGrid::paper_subset(DeviceKind::OrinAgx);
        assert_eq!(g.len(), 4_368);
        // all even core counts only
        assert!(g.modes.iter().all(|m| m.cores % 2 == 0));
        // two slowest cpu freqs excluded
        assert!(g.modes.iter().all(|m| m.cpu_khz >= 268_800));
        // every mode is valid
        let spec = DeviceKind::OrinAgx.spec();
        assert!(g.modes.iter().all(|m| m.validate(spec).is_ok()));
    }

    #[test]
    fn subset_modes_are_unique() {
        let g = PowerModeGrid::paper_subset(DeviceKind::OrinAgx);
        let mut set = std::collections::HashSet::new();
        for m in &g.modes {
            assert!(set.insert(*m), "duplicate mode {m:?}");
        }
    }

    #[test]
    fn maxn_is_valid_and_maximal() {
        for kind in DeviceKind::ALL {
            let spec = kind.spec();
            let m = PowerMode::maxn(spec);
            m.validate(spec).unwrap();
            assert_eq!(m.cpu_khz, spec.max_cpu_khz());
            assert_eq!(m.gpu_khz, spec.max_gpu_khz());
        }
    }

    #[test]
    fn validate_rejects_bad_modes() {
        let spec = DeviceKind::OrinAgx.spec();
        let bad_cores = PowerMode { cores: 13, cpu_khz: 2_201_600, gpu_khz: 1_300_500, mem_khz: 3_199_000 };
        assert!(bad_cores.validate(spec).is_err());
        let bad_freq = PowerMode { cores: 4, cpu_khz: 123, gpu_khz: 1_300_500, mem_khz: 3_199_000 };
        assert!(bad_freq.validate(spec).is_err());
    }

    #[test]
    fn features_are_mhz_scaled() {
        let m = PowerMode { cores: 8, cpu_khz: 2_201_600, gpu_khz: 1_300_500, mem_khz: 3_199_000 };
        let f = m.features();
        assert_eq!(f[0], 8.0);
        assert!((f[1] - 2201.6).abs() < 0.01);
        assert!((f[2] - 1300.5).abs() < 0.01);
        assert!((f[3] - 3199.0).abs() < 0.01);
    }

    #[test]
    fn label_matches_paper_format() {
        let m = PowerMode { cores: 12, cpu_khz: 2_201_600, gpu_khz: 1_236_750, mem_khz: 3_199_000 };
        assert_eq!(m.label(), "12c/2.20C/1.24G/3.20M");
    }

    #[test]
    fn nvidia_presets_valid_on_orin() {
        let spec = DeviceKind::OrinAgx.spec();
        let presets = nvidia_preset_modes(DeviceKind::OrinAgx);
        assert_eq!(presets.len(), 3);
        for (budget, m) in presets {
            assert!(budget >= 15.0 && budget <= 50.0);
            m.validate(spec).unwrap();
        }
    }

    #[test]
    fn every_kind_has_spec_clamped_presets() {
        for kind in DeviceKind::ALL {
            let spec = kind.spec();
            let presets = nvidia_preset_modes(kind);
            assert!(!presets.is_empty(), "{} has no preset table", kind.name());
            for (budget, m) in presets {
                // validate() enforces table membership + the core bound,
                // so a preset can never name a frequency the device's
                // discrete ladders don't offer
                m.validate(spec)
                    .unwrap_or_else(|e| panic!("{} preset {budget} W invalid: {e}", kind.name()));
                assert!(
                    budget > 0.0 && budget <= spec.peak_power_w,
                    "{} preset budget {budget} W exceeds the {} W peak",
                    kind.name(),
                    spec.peak_power_w
                );
                // presets must be strictly below MAXN (they exist to cap
                // power), not merely legal
                let maxn = PowerMode::maxn(spec);
                assert!(
                    m.cpu_khz <= maxn.cpu_khz
                        && m.gpu_khz <= maxn.gpu_khz
                        && m.mem_khz <= maxn.mem_khz
                        && m.cores <= maxn.cores
                );
            }
        }
    }

    #[test]
    fn profiling_plan_never_raises_freq_without_reboot() {
        let g = PowerModeGrid::paper_subset(DeviceKind::OrinAgx);
        let plan = ProfilingPlan::build(&g.modes);
        assert_eq!(plan.steps.len(), g.len());
        for w in plan.steps.windows(2) {
            let (a, b) = (w[0].mode, w[1].mode);
            if !w[1].reboot {
                assert!(b.cpu_khz <= a.cpu_khz, "cpu raised without reboot");
                assert!(b.gpu_khz <= a.gpu_khz, "gpu raised without reboot");
            }
        }
    }

    #[test]
    fn profiling_plan_reboots_bounded_by_cpu_groups() {
        let g = PowerModeGrid::paper_subset(DeviceKind::OrinAgx);
        let plan = ProfilingPlan::build(&g.modes);
        // at most one reboot per distinct CPU frequency group
        let mut cpu_freqs: Vec<u32> = g.modes.iter().map(|m| m.cpu_khz).collect();
        cpu_freqs.sort_unstable();
        cpu_freqs.dedup();
        assert!(plan.reboot_count() <= cpu_freqs.len());
    }

    #[test]
    fn feature_matrix_is_column_transposed_features() {
        let g = PowerModeGrid::paper_subset(DeviceKind::OrinAgx);
        let fm = g.feature_matrix();
        assert_eq!(fm.len(), g.len());
        let cols = fm.cols();
        for (r, pm) in g.modes.iter().enumerate().step_by(97) {
            let f = pm.features();
            for d in 0..4 {
                assert_eq!(cols[d][r], f[d], "row {r} dim {d}");
            }
        }
        assert!(FeatureMatrix::from_modes(&[]).is_empty());
    }

    #[test]
    fn random_subset_has_requested_size_and_validity() {
        let mut rng = Rng::new(5);
        let g = PowerModeGrid::random_subset(DeviceKind::XavierAgx, 1000, &mut rng);
        assert_eq!(g.len(), 1000);
        let spec = DeviceKind::XavierAgx.spec();
        assert!(g.modes.iter().all(|m| m.validate(spec).is_ok()));
    }
}
