//! Jetson device models: specifications, power-mode grids and profiling
//! orderings (paper Table 2 and section 2.5).

pub mod power_mode;
pub mod specs;

pub use power_mode::{FeatureMatrix, PowerMode, PowerModeGrid, ProfilingPlan, ProfilingStep};
pub use specs::{DeviceKind, DeviceSpec};
