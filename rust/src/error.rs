//! Crate-wide error type.
//!
//! Hand-rolled `Display`/`Error` impls (no `thiserror`): the default
//! build is dependency-free so the pure-host paths compile offline.

use std::fmt;

/// Unified error type for all PowerTrain subsystems.
#[derive(Debug)]
pub enum Error {
    /// I/O failure (corpus files, checkpoints, artifacts).
    Io(std::io::Error),

    /// XLA / PJRT runtime failure.
    #[cfg(feature = "xla")]
    Xla(xla::Error),

    /// Malformed JSON (manifest, checkpoint, config).
    Json(String),

    /// Malformed CSV (profiling corpus).
    Csv(String),

    /// An artifact referenced by the manifest is missing or inconsistent.
    Artifact(String),

    /// Invalid power mode / device configuration.
    Device(String),

    /// Profiling pipeline failure (e.g. power never stabilized).
    Profiling(String),

    /// Training / transfer driver failure.
    Training(String),

    /// Optimization has no feasible solution (e.g. budget below idle power).
    Optimization(String),

    /// Coordinator / serving failure.
    Coordinator(String),

    /// A circuit breaker rejected the request without attempting the
    /// guarded operation (the underlying failure already happened K times).
    CircuitOpen(String),

    /// Invalid CLI usage.
    Usage(String),
}

pub type Result<T> = std::result::Result<T, Error>;

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Io(e) => write!(f, "io error: {e}"),
            #[cfg(feature = "xla")]
            Error::Xla(e) => write!(f, "xla error: {e}"),
            Error::Json(m) => write!(f, "json parse error: {m}"),
            Error::Csv(m) => write!(f, "csv parse error: {m}"),
            Error::Artifact(m) => write!(f, "artifact error: {m}"),
            Error::Device(m) => write!(f, "device error: {m}"),
            Error::Profiling(m) => write!(f, "profiling error: {m}"),
            Error::Training(m) => write!(f, "training error: {m}"),
            Error::Optimization(m) => write!(f, "optimization error: {m}"),
            Error::Coordinator(m) => write!(f, "coordinator error: {m}"),
            Error::CircuitOpen(m) => write!(f, "circuit breaker open: {m}"),
            Error::Usage(m) => write!(f, "usage error: {m}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            #[cfg(feature = "xla")]
            Error::Xla(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Error {
        Error::Io(e)
    }
}

#[cfg(feature = "xla")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Error {
        Error::Xla(e)
    }
}

impl Error {
    pub fn json(msg: impl Into<String>) -> Self {
        Error::Json(msg.into())
    }
    pub fn csv(msg: impl Into<String>) -> Self {
        Error::Csv(msg.into())
    }

    /// Whether retrying the failed operation could plausibly succeed.
    ///
    /// Transient: environmental hiccups (I/O, runtime, profiling telemetry,
    /// a fit that diverged on one attempt, another worker's in-flight build
    /// failing under us). Permanent: malformed inputs, inconsistent
    /// artifacts, infeasible optimizations, usage errors — retrying replays
    /// the same deterministic failure, so the resilience layer must degrade
    /// instead.
    pub fn is_transient(&self) -> bool {
        match self {
            Error::Io(_) => true,
            #[cfg(feature = "xla")]
            Error::Xla(_) => true,
            Error::Profiling(_) | Error::Training(_) | Error::Coordinator(_) => true,
            Error::Json(_)
            | Error::Csv(_)
            | Error::Artifact(_)
            | Error::Device(_)
            | Error::Optimization(_)
            | Error::CircuitOpen(_)
            | Error::Usage(_) => false,
        }
    }

    /// Variant name, for the failure ledger and chaos-run grepping.
    pub fn kind(&self) -> &'static str {
        match self {
            Error::Io(_) => "io",
            #[cfg(feature = "xla")]
            Error::Xla(_) => "xla",
            Error::Json(_) => "json",
            Error::Csv(_) => "csv",
            Error::Artifact(_) => "artifact",
            Error::Device(_) => "device",
            Error::Profiling(_) => "profiling",
            Error::Training(_) => "training",
            Error::Optimization(_) => "optimization",
            Error::Coordinator(_) => "coordinator",
            Error::CircuitOpen(_) => "circuit-open",
            Error::Usage(_) => "usage",
        }
    }

    /// `"transient"` / `"permanent"`, for ledger rendering.
    pub fn class(&self) -> &'static str {
        if self.is_transient() {
            "transient"
        } else {
            "permanent"
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transient_classification() {
        assert!(Error::Profiling("sensor hiccup".into()).is_transient());
        assert!(Error::Training("fit diverged".into()).is_transient());
        assert!(Error::Coordinator("worker panicked".into()).is_transient());
        assert!(Error::Io(std::io::Error::other("disk")).is_transient());

        assert!(!Error::Usage("bad flag".into()).is_transient());
        assert!(!Error::Optimization("no feasible mode".into()).is_transient());
        assert!(!Error::Artifact("fingerprint mismatch".into()).is_transient());
        assert!(!Error::CircuitOpen("model build".into()).is_transient());
        assert!(!Error::Json("truncated".into()).is_transient());
    }

    #[test]
    fn kind_and_class_names() {
        let e = Error::Profiling("x".into());
        assert_eq!(e.kind(), "profiling");
        assert_eq!(e.class(), "transient");
        let e = Error::CircuitOpen("x".into());
        assert_eq!(e.kind(), "circuit-open");
        assert_eq!(e.class(), "permanent");
    }
}
