//! Crate-wide error type.

/// Unified error type for all PowerTrain subsystems.
#[derive(Debug, thiserror::Error)]
pub enum Error {
    /// I/O failure (corpus files, checkpoints, artifacts).
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),

    /// XLA / PJRT runtime failure.
    #[error("xla error: {0}")]
    Xla(#[from] xla::Error),

    /// Malformed JSON (manifest, checkpoint, config).
    #[error("json parse error: {0}")]
    Json(String),

    /// Malformed CSV (profiling corpus).
    #[error("csv parse error: {0}")]
    Csv(String),

    /// An artifact referenced by the manifest is missing or inconsistent.
    #[error("artifact error: {0}")]
    Artifact(String),

    /// Invalid power mode / device configuration.
    #[error("device error: {0}")]
    Device(String),

    /// Profiling pipeline failure (e.g. power never stabilized).
    #[error("profiling error: {0}")]
    Profiling(String),

    /// Training / transfer driver failure.
    #[error("training error: {0}")]
    Training(String),

    /// Optimization has no feasible solution (e.g. budget below idle power).
    #[error("optimization error: {0}")]
    Optimization(String),

    /// Coordinator / serving failure.
    #[error("coordinator error: {0}")]
    Coordinator(String),

    /// Invalid CLI usage.
    #[error("usage error: {0}")]
    Usage(String),
}

pub type Result<T> = std::result::Result<T, Error>;

impl Error {
    pub fn json(msg: impl Into<String>) -> Self {
        Error::Json(msg.into())
    }
    pub fn csv(msg: impl Into<String>) -> Self {
        Error::Csv(msg.into())
    }
}
