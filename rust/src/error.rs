//! Crate-wide error type.
//!
//! Hand-rolled `Display`/`Error` impls (no `thiserror`): the default
//! build is dependency-free so the pure-host paths compile offline.

use std::fmt;

/// Unified error type for all PowerTrain subsystems.
#[derive(Debug)]
pub enum Error {
    /// I/O failure (corpus files, checkpoints, artifacts).
    Io(std::io::Error),

    /// XLA / PJRT runtime failure.
    #[cfg(feature = "xla")]
    Xla(xla::Error),

    /// Malformed JSON (manifest, checkpoint, config).
    Json(String),

    /// Malformed CSV (profiling corpus).
    Csv(String),

    /// An artifact referenced by the manifest is missing or inconsistent.
    Artifact(String),

    /// Invalid power mode / device configuration.
    Device(String),

    /// Profiling pipeline failure (e.g. power never stabilized).
    Profiling(String),

    /// Training / transfer driver failure.
    Training(String),

    /// Optimization has no feasible solution (e.g. budget below idle power).
    Optimization(String),

    /// Coordinator / serving failure.
    Coordinator(String),

    /// Invalid CLI usage.
    Usage(String),
}

pub type Result<T> = std::result::Result<T, Error>;

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Io(e) => write!(f, "io error: {e}"),
            #[cfg(feature = "xla")]
            Error::Xla(e) => write!(f, "xla error: {e}"),
            Error::Json(m) => write!(f, "json parse error: {m}"),
            Error::Csv(m) => write!(f, "csv parse error: {m}"),
            Error::Artifact(m) => write!(f, "artifact error: {m}"),
            Error::Device(m) => write!(f, "device error: {m}"),
            Error::Profiling(m) => write!(f, "profiling error: {m}"),
            Error::Training(m) => write!(f, "training error: {m}"),
            Error::Optimization(m) => write!(f, "optimization error: {m}"),
            Error::Coordinator(m) => write!(f, "coordinator error: {m}"),
            Error::Usage(m) => write!(f, "usage error: {m}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            #[cfg(feature = "xla")]
            Error::Xla(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Error {
        Error::Io(e)
    }
}

#[cfg(feature = "xla")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Error {
        Error::Xla(e)
    }
}

impl Error {
    pub fn json(msg: impl Into<String>) -> Self {
        Error::Json(msg.into())
    }
    pub fn csv(msg: impl Into<String>) -> Self {
        Error::Csv(msg.into())
    }
}
