//! Simulated PyTorch training session — generates the raw telemetry the
//! profiler consumes, with the artefacts the paper's pipeline must handle:
//!
//! * the first minibatch is several times slower (PyTorch kernel-selection
//!   warmup, paper section 2.5) and must be discarded;
//! * per-minibatch times carry small log-normal jitter;
//! * 1 Hz power samples ride the sensor's 2–3 s settling ramp after a mode
//!   change, so early samples are contaminated;
//! * optional fault injection: sensor dropouts and a thermal-throttle
//!   event, for failure-path tests.

use crate::device::{DeviceSpec, PowerMode};
use crate::sim::perf_model::minibatch_time_ms;
use crate::sim::power_model::steady_power_mw;
use crate::sim::sensor::PowerSensor;
use crate::util::rng::Rng;
use crate::workload::Workload;

/// Fault-injection knobs (all off by default).
#[derive(Debug, Clone, Copy)]
pub struct FaultConfig {
    /// Probability that a 1 Hz sensor sample is dropped (jtop hiccup).
    pub sensor_dropout_prob: f64,
    /// Multiplier on the sensor's read-noise sigma (noise burst when > 1).
    pub noise_factor: f64,
    /// If set, clocks throttle to this fraction after `throttle_after_s`.
    pub throttle_factor: Option<f64>,
    pub throttle_after_s: f64,
}

impl Default for FaultConfig {
    fn default() -> Self {
        FaultConfig {
            sensor_dropout_prob: 0.0,
            noise_factor: 1.0, // 1.0 = nominal noise, not silence
            throttle_factor: None,
            throttle_after_s: 0.0,
        }
    }
}

/// Raw telemetry from profiling one power mode.
#[derive(Debug, Clone)]
pub struct ProfilingRun {
    pub mode: PowerMode,
    /// Per-minibatch training times (ms), *including* the slow first one.
    pub minibatch_ms: Vec<f64>,
    /// 1 Hz power samples (mW), starting at the moment of the mode change.
    pub power_samples_mw: Vec<u32>,
    /// Wall-clock seconds the profiling of this mode took.
    pub wall_time_s: f64,
}

/// A simulated training session of one workload on one device. Owns the
/// sensor state so consecutive power modes see realistic settling ramps.
#[derive(Debug)]
pub struct TrainerSim {
    pub spec: &'static DeviceSpec,
    pub workload: Workload,
    sensor: PowerSensor,
    rng: Rng,
    faults: FaultConfig,
    /// log-space sigma of minibatch time jitter
    time_jitter_sigma: f64,
}

impl TrainerSim {
    pub fn new(spec: &'static DeviceSpec, workload: Workload, seed: u64) -> TrainerSim {
        let idle = spec.p_base_mw;
        TrainerSim {
            spec,
            workload,
            sensor: PowerSensor::new(idle),
            rng: Rng::new(seed),
            faults: FaultConfig::default(),
            time_jitter_sigma: 0.015,
        }
    }

    pub fn with_faults(mut self, faults: FaultConfig) -> TrainerSim {
        self.sensor.scale_noise(faults.noise_factor);
        self.faults = faults;
        self
    }

    /// Noise-free ground truth used by experiment harnesses for MAPE
    /// denominators (the paper's "actual observed" values are averaged
    /// telemetry; the difference is well under the models' error).
    pub fn true_minibatch_ms(&self, pm: &PowerMode) -> f64 {
        minibatch_time_ms(self.spec, &self.workload, pm).total_ms
    }

    pub fn true_power_mw(&self, pm: &PowerMode) -> f64 {
        steady_power_mw(self.spec, &self.workload, pm)
    }

    /// Run `n_minibatches` of training under `pm`, collecting telemetry.
    /// Mirrors the paper's per-mode profiling procedure (section 2.5).
    pub fn profile_mode(&mut self, pm: &PowerMode, n_minibatches: usize) -> ProfilingRun {
        let base = minibatch_time_ms(self.spec, &self.workload, pm);
        let steady_p = steady_power_mw(self.spec, &self.workload, pm);

        // switch power mode: sensor begins settling toward the new draw
        self.sensor.change_mode(steady_p);

        let mut minibatch_ms = Vec::with_capacity(n_minibatches);
        let mut power_samples = Vec::new();
        let mut clock_s = 0.0f64;
        let mut next_sample_s = 1.0f64; // 1 Hz sampling

        for i in 0..n_minibatches {
            let mut t_ms = base.total_ms * self.rng.lognormal_jitter(self.time_jitter_sigma);
            if i == 0 {
                // kernel-selection warmup: first minibatch is much slower
                t_ms *= self.rng.uniform_range(5.0, 9.0);
            }
            if let Some(factor) = self.faults.throttle_factor {
                if clock_s >= self.faults.throttle_after_s {
                    t_ms /= factor; // throttled clocks -> slower minibatch
                }
            }
            // advance wall clock through this minibatch, emitting 1 Hz
            // sensor samples at their scheduled instants
            let end_s = clock_s + t_ms / 1e3;
            while next_sample_s <= end_s {
                let dt = next_sample_s - clock_s;
                self.sensor.advance(dt);
                clock_s = next_sample_s;
                let throttled = self
                    .faults
                    .throttle_factor
                    .map(|f| clock_s >= self.faults.throttle_after_s && f < 1.0)
                    .unwrap_or(false);
                if !self.rng.bernoulli(self.faults.sensor_dropout_prob) {
                    let mut s = self.sensor.sample(&mut self.rng);
                    if throttled {
                        s = (s as f64 * 0.7) as u32;
                    }
                    power_samples.push(s);
                }
                next_sample_s += 1.0;
            }
            self.sensor.advance(end_s - clock_s);
            clock_s = end_s;
            minibatch_ms.push(t_ms);
        }

        ProfilingRun {
            mode: *pm,
            minibatch_ms,
            power_samples_mw: power_samples,
            wall_time_s: clock_s,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::DeviceKind;
    use crate::util::stats;

    fn sim() -> TrainerSim {
        TrainerSim::new(DeviceKind::OrinAgx.spec(), Workload::resnet(), 42)
    }

    fn maxn() -> PowerMode {
        PowerMode::maxn(DeviceKind::OrinAgx.spec())
    }

    #[test]
    fn first_minibatch_is_outlier() {
        let mut s = sim();
        let run = s.profile_mode(&maxn(), 41);
        let rest = &run.minibatch_ms[1..];
        let m = stats::mean(rest);
        assert!(run.minibatch_ms[0] > 3.0 * m, "first mb not slow");
        // the clean minibatches are tight around ground truth
        let truth = s.true_minibatch_ms(&maxn());
        assert!((m - truth).abs() / truth < 0.02);
    }

    #[test]
    fn per_minibatch_jitter_is_small() {
        let mut s = sim();
        let run = s.profile_mode(&maxn(), 41);
        let rest = &run.minibatch_ms[1..];
        let cv = stats::std_dev(rest) / stats::mean(rest);
        assert!(cv < 0.05, "cv={cv}");
    }

    #[test]
    fn power_sampling_covers_duration_at_1hz() {
        let mut s = sim();
        // a slow mode so profiling spans many seconds
        let spec = DeviceKind::OrinAgx.spec();
        let slow = PowerMode { cores: 2, cpu_khz: spec.cpu_khz[2], gpu_khz: spec.gpu_khz[0], mem_khz: spec.mem_khz[0] };
        let run = s.profile_mode(&slow, 40);
        let expected = run.wall_time_s.floor() as usize;
        assert!(run.power_samples_mw.len() >= expected.saturating_sub(1));
        assert!(run.power_samples_mw.len() <= expected + 1);
    }

    #[test]
    fn fast_modes_may_miss_power_telemetry() {
        // the paper's observation: at fast modes with few minibatches the
        // whole run finishes inside the 1 s sampling interval
        let mut s = TrainerSim::new(DeviceKind::OrinAgx.spec(), Workload::lstm(), 7);
        let run = s.profile_mode(&maxn(), 10);
        // 10 x ~10.7 ms plus warmup ~ 0.2 s << 1 s
        assert!(run.power_samples_mw.is_empty());
    }

    #[test]
    fn late_power_samples_near_steady_state() {
        let mut s = sim();
        let spec = DeviceKind::OrinAgx.spec();
        let slow = PowerMode { cores: 4, cpu_khz: spec.cpu_khz[4], gpu_khz: spec.gpu_khz[1], mem_khz: spec.mem_khz[1] };
        let run = s.profile_mode(&slow, 40);
        let truth = s.true_power_mw(&slow);
        assert!(run.power_samples_mw.len() > 8);
        let late: Vec<f64> = run.power_samples_mw[4..].iter().map(|&p| p as f64).collect();
        let m = stats::mean(&late);
        assert!((m - truth).abs() / truth < 0.03, "late mean {m} vs truth {truth}");
    }

    #[test]
    fn early_samples_ride_settling_ramp() {
        // start from idle; first sample after switching to a hot mode must
        // be well below steady state
        let mut s = sim();
        let run = s.profile_mode(&maxn(), 200);
        let truth = s.true_power_mw(&maxn());
        assert!(!run.power_samples_mw.is_empty());
        let first = run.power_samples_mw[0] as f64;
        assert!(first < 0.85 * truth, "first={first} truth={truth}");
    }

    #[test]
    fn sensor_dropout_reduces_sample_count() {
        let spec = DeviceKind::OrinAgx.spec();
        let slow = PowerMode { cores: 2, cpu_khz: spec.cpu_khz[2], gpu_khz: spec.gpu_khz[0], mem_khz: spec.mem_khz[0] };
        let full = TrainerSim::new(spec, Workload::resnet(), 3).profile_mode(&slow, 40);
        let dropped = TrainerSim::new(spec, Workload::resnet(), 3)
            .with_faults(FaultConfig { sensor_dropout_prob: 0.5, ..Default::default() })
            .profile_mode(&slow, 40);
        assert!(dropped.power_samples_mw.len() < full.power_samples_mw.len() * 3 / 4);
    }

    #[test]
    fn noise_burst_widens_power_samples() {
        let spec = DeviceKind::OrinAgx.spec();
        let slow = PowerMode { cores: 2, cpu_khz: spec.cpu_khz[2], gpu_khz: spec.gpu_khz[0], mem_khz: spec.mem_khz[0] };
        let clean = TrainerSim::new(spec, Workload::resnet(), 3).profile_mode(&slow, 40);
        let noisy = TrainerSim::new(spec, Workload::resnet(), 3)
            .with_faults(FaultConfig { noise_factor: 20.0, ..Default::default() })
            .profile_mode(&slow, 40);
        let late = |run: &ProfilingRun| -> Vec<f64> {
            run.power_samples_mw[4..].iter().map(|&p| p as f64).collect()
        };
        let (c, n) = (late(&clean), late(&noisy));
        assert!(stats::std_dev(&n) > 3.0 * stats::std_dev(&c));
    }

    #[test]
    fn deterministic_given_seed() {
        let a = TrainerSim::new(DeviceKind::OrinAgx.spec(), Workload::resnet(), 9)
            .profile_mode(&maxn(), 41);
        let b = TrainerSim::new(DeviceKind::OrinAgx.spec(), Workload::resnet(), 9)
            .profile_mode(&maxn(), 41);
        assert_eq!(a.minibatch_ms, b.minibatch_ms);
        assert_eq!(a.power_samples_mw, b.power_samples_mw);
    }
}
