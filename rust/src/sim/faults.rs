//! Deterministic fault-injection harness.
//!
//! A [`FaultPlan`] scripts failures for a chaos run — sensor dropouts and
//! noise bursts, transient profiling/fit failures, permanently-failing
//! models, corrupted checkpoints, worker panics and fan-off thermal
//! episodes — and a [`FaultInjector`] answers "does this operation fail
//! now?" queries from the serving stack.
//!
//! Every decision is a **pure function** of `(plan seed, fault domain,
//! operation key, attempt)`: the injector holds no mutable state and no
//! shared RNG stream, so worker scheduling order cannot change which
//! operations fail, and a chaos run replays bit-identically under the
//! same plan. Transient faults fail a bounded number of *consecutive*
//! attempts (`streak`) on an operation key and then succeed, which is
//! what lets the coordinator's retry layer recover deterministically.
//!
//! Plans serialize to JSON (`FaultPlan::load`/[`FaultPlan::save`]) so CI
//! chaos legs and `serve --faults <plan.json>` share committed scenarios.

use std::path::Path;

use crate::error::{Error, Result};
use crate::sim::trainer_sim::FaultConfig;
use crate::util::json::Value;
use crate::util::rng::Rng;

/// Format marker for serialized plans.
const PLAN_KIND: &str = "powertrain-fault-plan-v1";

/// Hash domains: every fault class rolls in its own stream so e.g. a
/// profiling fault on key K is independent of a fit fault on key K.
const DOMAIN_PROFILING: u64 = 0x70_72_6f_66_31; // "prof1"
const DOMAIN_FIT: u64 = 0x66_69_74_31; // "fit1"

/// A declarative chaos scenario. All knobs default to "off" —
/// [`FaultPlan::default`] is a no-op plan under which serving behaves
/// bit-identically to running without an injector at all.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultPlan {
    /// Seed of every hash-based fault decision.
    pub seed: u64,
    /// Fraction of profiling operations (by operation key) that fail
    /// transiently for their first `profiling_streak` attempts.
    pub profiling_fail_pct: f64,
    pub profiling_streak: usize,
    /// Fraction of model fits (by operation key) that fail transiently
    /// for their first `fit_streak` attempts.
    pub fit_fail_pct: f64,
    pub fit_streak: usize,
    /// Request seeds whose model build fails *permanently* (every
    /// attempt) — the scenario a circuit breaker exists for.
    pub permanent_fit_seeds: Vec<u64>,
    /// Request seeds whose freshly built checkpoints come back with
    /// corrupted fingerprints (caught by the integrity verify, never
    /// cached).
    pub corrupt_fit_seeds: Vec<u64>,
    /// Request ids whose first handling attempt panics inside the worker.
    pub panic_request_ids: Vec<u64>,
    /// Probability a 1 Hz sensor sample is dropped during profiling.
    pub sensor_dropout_prob: f64,
    /// Multiplier on the sensor's read-noise sigma (noise burst when > 1).
    pub noise_factor: f64,
    /// Fan-off thermal episodes as `[start_s, end_s)` intervals on the
    /// thermal guard's simulated clock (the IP-67 enclosure scenario).
    pub fan_off_s: Vec<(f64, f64)>,
    /// Per-node fan-off episodes for fleet chaos runs, as
    /// `(node, start_s, end_s)` triples on the fleet registry's heartbeat
    /// clock: node `node`'s cooling is scripted off for `[start_s,
    /// end_s)`, marking it `Degraded` so the router places around it.
    pub node_fan_off: Vec<(u32, f64, f64)>,
}

impl Default for FaultPlan {
    fn default() -> Self {
        FaultPlan {
            seed: 0,
            profiling_fail_pct: 0.0,
            profiling_streak: 1,
            fit_fail_pct: 0.0,
            fit_streak: 1,
            permanent_fit_seeds: Vec::new(),
            corrupt_fit_seeds: Vec::new(),
            panic_request_ids: Vec::new(),
            sensor_dropout_prob: 0.0,
            noise_factor: 1.0,
            fan_off_s: Vec::new(),
            node_fan_off: Vec::new(),
        }
    }
}

fn as_u64(v: &Value) -> Result<u64> {
    let f = v.as_f64()?;
    if f < 0.0 || f.fract() != 0.0 || f >= 9.0e15 {
        return Err(Error::json(format!("expected non-negative integer, got {f}")));
    }
    Ok(f as u64)
}

fn u64_list(plan: &Value, key: &str) -> Result<Vec<u64>> {
    match plan.get(key) {
        None => Ok(Vec::new()),
        Some(v) => v.as_arr()?.iter().map(as_u64).collect(),
    }
}

fn f64_or(plan: &Value, key: &str, default: f64) -> Result<f64> {
    match plan.get(key) {
        None => Ok(default),
        Some(v) => v.as_f64(),
    }
}

impl FaultPlan {
    /// True when this plan injects nothing: serving under it must be
    /// bit-identical to serving without an injector.
    pub fn is_noop(&self) -> bool {
        self.profiling_fail_pct == 0.0
            && self.fit_fail_pct == 0.0
            && self.permanent_fit_seeds.is_empty()
            && self.corrupt_fit_seeds.is_empty()
            && self.panic_request_ids.is_empty()
            && self.sensor_dropout_prob == 0.0
            && self.noise_factor == 1.0
            && self.fan_off_s.is_empty()
            && self.node_fan_off.is_empty()
    }

    pub fn from_json(v: &Value) -> Result<FaultPlan> {
        let kind = v.req("kind")?.as_str()?;
        if kind != PLAN_KIND {
            return Err(Error::json(format!(
                "unsupported fault plan kind '{kind}' (expected '{PLAN_KIND}')"
            )));
        }
        let d = FaultPlan::default();
        let mut fan_off_s = Vec::new();
        if let Some(episodes) = v.get("fan_off_s") {
            for ep in episodes.as_arr()? {
                let pair = ep.as_arr()?;
                if pair.len() != 2 {
                    return Err(Error::json("fan_off_s episodes must be [start_s, end_s] pairs"));
                }
                let (start, end) = (pair[0].as_f64()?, pair[1].as_f64()?);
                if !start.is_finite() || !end.is_finite() || start < 0.0 || end < start {
                    return Err(Error::json(format!(
                        "malformed fan_off_s episode [{start}, {end}]"
                    )));
                }
                fan_off_s.push((start, end));
            }
        }
        let mut node_fan_off = Vec::new();
        if let Some(episodes) = v.get("node_fan_off") {
            for ep in episodes.as_arr()? {
                let triple = ep.as_arr()?;
                if triple.len() != 3 {
                    return Err(Error::json(
                        "node_fan_off episodes must be [node, start_s, end_s] triples",
                    ));
                }
                let node = as_u64(&triple[0])?;
                if node > u32::MAX as u64 {
                    return Err(Error::json(format!("node id {node} out of range")));
                }
                let (start, end) = (triple[1].as_f64()?, triple[2].as_f64()?);
                if !start.is_finite() || !end.is_finite() || start < 0.0 || end < start {
                    return Err(Error::json(format!(
                        "malformed node_fan_off episode [{node}, {start}, {end}]"
                    )));
                }
                node_fan_off.push((node as u32, start, end));
            }
        }
        let plan = FaultPlan {
            seed: v.get("seed").map(as_u64).transpose()?.unwrap_or(d.seed),
            profiling_fail_pct: f64_or(v, "profiling_fail_pct", d.profiling_fail_pct)?,
            profiling_streak: v
                .get("profiling_streak")
                .map(|x| x.as_usize())
                .transpose()?
                .unwrap_or(d.profiling_streak),
            fit_fail_pct: f64_or(v, "fit_fail_pct", d.fit_fail_pct)?,
            fit_streak: v
                .get("fit_streak")
                .map(|x| x.as_usize())
                .transpose()?
                .unwrap_or(d.fit_streak),
            permanent_fit_seeds: u64_list(v, "permanent_fit_seeds")?,
            corrupt_fit_seeds: u64_list(v, "corrupt_fit_seeds")?,
            panic_request_ids: u64_list(v, "panic_request_ids")?,
            sensor_dropout_prob: f64_or(v, "sensor_dropout_prob", d.sensor_dropout_prob)?,
            noise_factor: f64_or(v, "noise_factor", d.noise_factor)?,
            fan_off_s,
            node_fan_off,
        };
        for (name, p) in [
            ("profiling_fail_pct", plan.profiling_fail_pct),
            ("fit_fail_pct", plan.fit_fail_pct),
            ("sensor_dropout_prob", plan.sensor_dropout_prob),
        ] {
            if !(0.0..=1.0).contains(&p) {
                return Err(Error::json(format!("{name} must be in [0, 1], got {p}")));
            }
        }
        if !plan.noise_factor.is_finite() || plan.noise_factor < 0.0 {
            return Err(Error::json(format!(
                "noise_factor must be finite and non-negative, got {}",
                plan.noise_factor
            )));
        }
        Ok(plan)
    }

    pub fn to_json(&self) -> Value {
        let nums = |xs: &[u64]| Value::Arr(xs.iter().map(|&x| Value::Num(x as f64)).collect());
        Value::obj(vec![
            ("kind", Value::Str(PLAN_KIND.into())),
            ("seed", Value::Num(self.seed as f64)),
            ("profiling_fail_pct", Value::Num(self.profiling_fail_pct)),
            ("profiling_streak", Value::Num(self.profiling_streak as f64)),
            ("fit_fail_pct", Value::Num(self.fit_fail_pct)),
            ("fit_streak", Value::Num(self.fit_streak as f64)),
            ("permanent_fit_seeds", nums(&self.permanent_fit_seeds)),
            ("corrupt_fit_seeds", nums(&self.corrupt_fit_seeds)),
            ("panic_request_ids", nums(&self.panic_request_ids)),
            ("sensor_dropout_prob", Value::Num(self.sensor_dropout_prob)),
            ("noise_factor", Value::Num(self.noise_factor)),
            (
                "fan_off_s",
                Value::Arr(
                    self.fan_off_s
                        .iter()
                        .map(|&(a, b)| Value::Arr(vec![Value::Num(a), Value::Num(b)]))
                        .collect(),
                ),
            ),
            (
                "node_fan_off",
                Value::Arr(
                    self.node_fan_off
                        .iter()
                        .map(|&(node, a, b)| {
                            Value::Arr(vec![
                                Value::Num(node as f64),
                                Value::Num(a),
                                Value::Num(b),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    pub fn load(path: &Path) -> Result<FaultPlan> {
        let text = std::fs::read_to_string(path)?;
        FaultPlan::from_json(&Value::parse(&text)?)
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_string())?;
        Ok(())
    }
}

/// Answers fault queries for one plan. Stateless and cheap to share
/// (`Arc`) across workers; every query hashes its inputs instead of
/// consuming from a stream, so decisions are independent of call order.
#[derive(Debug)]
pub struct FaultInjector {
    plan: FaultPlan,
}

impl FaultInjector {
    pub fn new(plan: FaultPlan) -> FaultInjector {
        FaultInjector { plan }
    }

    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }

    /// Uniform [0, 1) roll, a pure function of (plan seed, domain, key).
    fn roll(&self, domain: u64, key: u64) -> f64 {
        Rng::new(self.plan.seed ^ domain).split(key).uniform()
    }

    /// Does the profiling run for operation `key` fail on `attempt`?
    /// Selected keys fail attempts `0..profiling_streak`, then succeed.
    pub fn profiling_fails(&self, key: u64, attempt: u32) -> bool {
        (attempt as usize) < self.plan.profiling_streak
            && self.roll(DOMAIN_PROFILING, key) < self.plan.profiling_fail_pct
    }

    /// Does the model fit for operation `key` fail transiently on
    /// `attempt`?
    pub fn fit_fails(&self, key: u64, attempt: u32) -> bool {
        (attempt as usize) < self.plan.fit_streak
            && self.roll(DOMAIN_FIT, key) < self.plan.fit_fail_pct
    }

    /// Does the model fit for request seed `seed` fail on *every*
    /// attempt? (The circuit-breaker scenario.)
    pub fn fit_fails_permanently(&self, seed: u64) -> bool {
        self.plan.permanent_fit_seeds.contains(&seed)
    }

    /// Do the freshly built checkpoints for request seed `seed` come back
    /// with corrupted fingerprints?
    pub fn corrupts_checkpoint(&self, seed: u64) -> bool {
        self.plan.corrupt_fit_seeds.contains(&seed)
    }

    /// Does handling request `request_id` panic on this attempt? Only the
    /// first attempt panics, so a caught-and-retried request recovers.
    pub fn panics_on(&self, request_id: u64, attempt: u32) -> bool {
        attempt == 0 && self.plan.panic_request_ids.contains(&request_id)
    }

    /// Sensor-level faults ([`TrainerSim::with_faults`]) this plan
    /// scripts: sample dropout and noise bursts.
    ///
    /// [`TrainerSim::with_faults`]: crate::sim::TrainerSim::with_faults
    pub fn trainer_faults(&self) -> FaultConfig {
        FaultConfig {
            sensor_dropout_prob: self.plan.sensor_dropout_prob,
            noise_factor: self.plan.noise_factor,
            ..Default::default()
        }
    }

    /// Is the fan scripted off at simulated second `t_s`?
    pub fn fan_off_at(&self, t_s: f64) -> bool {
        self.plan.fan_off_s.iter().any(|&(a, b)| t_s >= a && t_s < b)
    }

    /// Is fleet node `node`'s fan scripted off at registry-heartbeat
    /// second `t_s`? Half-open like [`FaultInjector::fan_off_at`].
    pub fn node_fan_off_at(&self, node: u32, t_s: f64) -> bool {
        self.plan
            .node_fan_off
            .iter()
            .any(|&(n, a, b)| n == node && t_s >= a && t_s < b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_plan_is_noop() {
        let plan = FaultPlan::default();
        assert!(plan.is_noop());
        let inj = FaultInjector::new(plan);
        for key in 0..64 {
            assert!(!inj.profiling_fails(key, 0));
            assert!(!inj.fit_fails(key, 0));
            assert!(!inj.fit_fails_permanently(key));
            assert!(!inj.corrupts_checkpoint(key));
            assert!(!inj.panics_on(key, 0));
        }
        assert!(!inj.fan_off_at(0.0));
        let faults = inj.trainer_faults();
        assert_eq!(faults.sensor_dropout_prob, 0.0);
        assert_eq!(faults.noise_factor, 1.0);
    }

    #[test]
    fn decisions_are_deterministic_and_order_independent() {
        let plan = FaultPlan { seed: 42, profiling_fail_pct: 0.5, ..Default::default() };
        let a = FaultInjector::new(plan.clone());
        let b = FaultInjector::new(plan);
        // query b in reverse order: decisions must not depend on call order
        let from_a: Vec<bool> = (0..100).map(|k| a.profiling_fails(k, 0)).collect();
        let from_b: Vec<bool> = (0..100).rev().map(|k| b.profiling_fails(k, 0)).collect();
        let from_b: Vec<bool> = from_b.into_iter().rev().collect();
        assert_eq!(from_a, from_b);
        assert!(from_a.iter().any(|&f| f) && from_a.iter().any(|&f| !f));
    }

    #[test]
    fn transient_streak_bounds_consecutive_failures() {
        let plan = FaultPlan {
            seed: 7,
            fit_fail_pct: 1.0,
            fit_streak: 2,
            ..Default::default()
        };
        let inj = FaultInjector::new(plan);
        for key in 0..16 {
            assert!(inj.fit_fails(key, 0));
            assert!(inj.fit_fails(key, 1));
            // a retry past the streak deterministically succeeds
            assert!(!inj.fit_fails(key, 2));
        }
    }

    #[test]
    fn fail_fraction_tracks_the_configured_pct() {
        let plan = FaultPlan { seed: 3, profiling_fail_pct: 0.3, ..Default::default() };
        let inj = FaultInjector::new(plan);
        let n = 2000u64;
        let fails = (0..n).filter(|&k| inj.profiling_fails(k, 0)).count() as f64 / n as f64;
        assert!((fails - 0.3).abs() < 0.05, "fail fraction {fails}");
    }

    #[test]
    fn panics_only_on_first_attempt_of_listed_ids() {
        let plan = FaultPlan { panic_request_ids: vec![5], ..Default::default() };
        let inj = FaultInjector::new(plan);
        assert!(inj.panics_on(5, 0));
        assert!(!inj.panics_on(5, 1));
        assert!(!inj.panics_on(6, 0));
    }

    #[test]
    fn fan_episodes_are_half_open_intervals() {
        let plan = FaultPlan {
            fan_off_s: vec![(10.0, 20.0), (50.0, 60.0)],
            ..Default::default()
        };
        let inj = FaultInjector::new(plan);
        assert!(!inj.fan_off_at(9.9));
        assert!(inj.fan_off_at(10.0));
        assert!(inj.fan_off_at(19.9));
        assert!(!inj.fan_off_at(20.0));
        assert!(inj.fan_off_at(55.0));
        assert!(!inj.fan_off_at(100.0));
    }

    #[test]
    fn node_fan_episodes_hit_only_their_node_half_open() {
        let plan = FaultPlan {
            node_fan_off: vec![(2, 10.0, 20.0), (5, 15.0, 30.0)],
            ..Default::default()
        };
        assert!(!plan.is_noop());
        let inj = FaultInjector::new(plan);
        assert!(!inj.node_fan_off_at(2, 9.9));
        assert!(inj.node_fan_off_at(2, 10.0));
        assert!(inj.node_fan_off_at(2, 19.9));
        assert!(!inj.node_fan_off_at(2, 20.0));
        // other nodes are untouched by node 2's episode
        assert!(!inj.node_fan_off_at(3, 15.0));
        assert!(inj.node_fan_off_at(5, 15.0));
        // node episodes don't leak into the fleet-wide thermal guard
        assert!(!inj.fan_off_at(15.0));
    }

    #[test]
    fn json_round_trip() {
        let plan = FaultPlan {
            seed: 11,
            profiling_fail_pct: 0.1,
            profiling_streak: 2,
            fit_fail_pct: 0.05,
            fit_streak: 1,
            permanent_fit_seeds: vec![777],
            corrupt_fit_seeds: vec![888],
            panic_request_ids: vec![3, 9],
            sensor_dropout_prob: 0.05,
            noise_factor: 4.0,
            fan_off_s: vec![(0.0, 240.0)],
            node_fan_off: vec![(7, 30.0, 120.0)],
        };
        let back = FaultPlan::from_json(&Value::parse(&plan.to_json().to_string()).unwrap())
            .unwrap();
        assert_eq!(plan, back);
    }

    #[test]
    fn sparse_json_fills_defaults_and_bad_plans_are_rejected() {
        let v = Value::parse(r#"{"kind": "powertrain-fault-plan-v1", "seed": 9}"#).unwrap();
        let plan = FaultPlan::from_json(&v).unwrap();
        assert_eq!(plan.seed, 9);
        assert!(plan.is_noop());

        for bad in [
            r#"{"seed": 1}"#,                                                  // missing kind
            r#"{"kind": "other"}"#,                                            // wrong kind
            r#"{"kind": "powertrain-fault-plan-v1", "fit_fail_pct": 1.5}"#,    // pct out of range
            r#"{"kind": "powertrain-fault-plan-v1", "noise_factor": -1}"#,     // negative noise
            r#"{"kind": "powertrain-fault-plan-v1", "fan_off_s": [[5]]}"#,     // malformed pair
            r#"{"kind": "powertrain-fault-plan-v1", "fan_off_s": [[9, 2]]}"#,  // end < start
            r#"{"kind": "powertrain-fault-plan-v1", "panic_request_ids": [-1]}"#,
            r#"{"kind": "powertrain-fault-plan-v1", "node_fan_off": [[1, 5]]}"#,   // not a triple
            r#"{"kind": "powertrain-fault-plan-v1", "node_fan_off": [[1, 9, 2]]}"#, // end < start
        ] {
            assert!(
                FaultPlan::from_json(&Value::parse(bad).unwrap()).is_err(),
                "accepted bad plan: {bad}"
            );
        }
    }

    #[test]
    fn save_and_load() {
        let dir = std::env::temp_dir().join("pt_fault_plan_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("plan.json");
        let plan = FaultPlan { seed: 5, profiling_fail_pct: 0.1, ..Default::default() };
        plan.save(&path).unwrap();
        assert_eq!(FaultPlan::load(&path).unwrap(), plan);
        std::fs::remove_file(&path).ok();
    }
}
