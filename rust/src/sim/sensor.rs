//! INA3221-style power sensor simulation.
//!
//! The real devkit exposes module power through an INA3221 read at 1 Hz via
//! `jtop`/tegrastats (paper section 2.4). Two behaviours matter to the
//! profiling pipeline and are reproduced here:
//!
//! * after a power-mode change the reading takes 2–3 s to stabilize
//!   (first-order settling, paper section 2.5);
//! * readings carry sensor noise and are quantized to integer milliwatts.

use crate::util::rng::Rng;

/// Simulated power sensor for one device session.
#[derive(Debug, Clone)]
pub struct PowerSensor {
    /// Current steady-state power target (mW).
    steady_mw: f64,
    /// Power the board was drawing before the last mode change.
    prev_mw: f64,
    /// Seconds since the last mode change.
    since_change_s: f64,
    /// Settling time constant (s).
    tau_s: f64,
    /// Gaussian read-noise sigma (mW).
    noise_mw: f64,
}

impl PowerSensor {
    pub fn new(initial_mw: f64) -> PowerSensor {
        PowerSensor {
            steady_mw: initial_mw,
            prev_mw: initial_mw,
            since_change_s: f64::INFINITY,
            tau_s: 0.9,
            noise_mw: 120.0,
        }
    }

    /// Apply a power-mode change: the reading will settle from the current
    /// instantaneous value to `new_steady_mw` over the next ~2-3 s.
    pub fn change_mode(&mut self, new_steady_mw: f64) {
        self.prev_mw = self.instantaneous();
        self.steady_mw = new_steady_mw;
        self.since_change_s = 0.0;
    }

    /// Advance simulated time. Non-finite or negative `dt_s` (possible from
    /// a malformed fault plan) is clamped to 0 so the settling clock can
    /// never run backwards or go NaN.
    pub fn advance(&mut self, dt_s: f64) {
        let dt_s = if dt_s.is_finite() { dt_s.max(0.0) } else { 0.0 };
        self.since_change_s += dt_s;
        debug_assert!(
            !self.since_change_s.is_nan(),
            "sensor settling clock went NaN"
        );
    }

    /// Scale the Gaussian read-noise sigma — fault injection uses this for
    /// noise bursts. Non-finite or negative factors are ignored.
    pub fn scale_noise(&mut self, factor: f64) {
        if factor.is_finite() && factor >= 0.0 {
            self.noise_mw *= factor;
        }
    }

    /// Noise-free instantaneous power.
    pub fn instantaneous(&self) -> f64 {
        if self.since_change_s.is_infinite() {
            return self.steady_mw;
        }
        let k = (-self.since_change_s / self.tau_s).exp();
        self.steady_mw + (self.prev_mw - self.steady_mw) * k
    }

    /// One 1 Hz sensor sample: instantaneous + noise, quantized to mW.
    pub fn sample(&self, rng: &mut Rng) -> u32 {
        let v = self.instantaneous() + rng.normal_ms(0.0, self.noise_mw);
        v.max(0.0).round() as u32
    }

    /// True whether the reading has effectively settled (within 1% of
    /// steady state) — used by tests; the profiler must *detect* this from
    /// samples alone, like the paper's sliding-window logic.
    pub fn settled(&self) -> bool {
        (self.instantaneous() - self.steady_mw).abs() <= 0.01 * self.steady_mw.max(1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn settles_within_three_seconds() {
        let mut s = PowerSensor::new(10_000.0);
        s.change_mode(40_000.0);
        assert!(!s.settled());
        for _ in 0..3 {
            s.advance(1.0);
        }
        // after 3 tau-ish seconds the reading is close to steady
        assert!((s.instantaneous() - 40_000.0).abs() < 0.05 * 40_000.0);
    }

    #[test]
    fn approach_is_monotone() {
        let mut s = PowerSensor::new(50_000.0);
        s.change_mode(12_000.0);
        let mut last = s.instantaneous();
        for _ in 0..10 {
            s.advance(0.5);
            let v = s.instantaneous();
            assert!(v <= last + 1e-9, "non-monotone settle");
            last = v;
        }
    }

    #[test]
    fn samples_center_on_instantaneous() {
        let s = PowerSensor::new(30_000.0);
        let mut rng = Rng::new(1);
        let n = 5_000;
        let mean: f64 = (0..n).map(|_| s.sample(&mut rng) as f64).sum::<f64>() / n as f64;
        assert!((mean - 30_000.0).abs() < 50.0, "mean={mean}");
    }

    #[test]
    fn samples_never_negative() {
        let s = PowerSensor::new(10.0); // tiny power, noise could go negative
        let mut rng = Rng::new(2);
        for _ in 0..1000 {
            let _v: u32 = s.sample(&mut rng); // type guarantees >= 0
        }
    }

    #[test]
    fn advance_survives_hostile_inputs() {
        let mut s = PowerSensor::new(10_000.0);
        s.change_mode(40_000.0);
        s.advance(1.0);
        let before = s.instantaneous();
        for &dt in &[f64::NAN, f64::INFINITY, f64::NEG_INFINITY, -2.0] {
            s.advance(dt);
            assert!(s.instantaneous().is_finite(), "poisoned by dt={dt}");
        }
        // hostile dt values are no-ops (INFINITY snaps to steady is NOT
        // desired: it must be clamped to zero elapsed time)
        assert!((s.instantaneous() - before).abs() < 1e-9);
        s.advance(10.0);
        assert!(s.settled());
    }

    #[test]
    fn noise_scaling_widens_and_silences_samples() {
        let mut quiet = PowerSensor::new(30_000.0);
        quiet.scale_noise(0.0);
        let mut rng = Rng::new(5);
        for _ in 0..100 {
            assert_eq!(quiet.sample(&mut rng), 30_000);
        }
        let mut loud = PowerSensor::new(30_000.0);
        loud.scale_noise(10.0);
        let mut rng = Rng::new(5);
        let spread = (0..200)
            .map(|_| (loud.sample(&mut rng) as f64 - 30_000.0).abs())
            .fold(0.0f64, f64::max);
        assert!(spread > 1_000.0, "spread={spread}");
        // hostile factors are ignored
        let mut s = PowerSensor::new(30_000.0);
        s.scale_noise(f64::NAN);
        s.scale_noise(-3.0);
        let mut r1 = Rng::new(9);
        let mut r2 = Rng::new(9);
        let baseline = PowerSensor::new(30_000.0);
        for _ in 0..50 {
            assert_eq!(s.sample(&mut r1), baseline.sample(&mut r2));
        }
    }

    #[test]
    fn chained_mode_changes_start_from_current_value() {
        let mut s = PowerSensor::new(10_000.0);
        s.change_mode(50_000.0);
        s.advance(0.5); // mid-settle
        let mid = s.instantaneous();
        s.change_mode(20_000.0);
        // new settle starts from mid, not from 50k
        assert!((s.instantaneous() - mid).abs() < 1.0);
    }
}
