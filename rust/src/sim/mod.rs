//! Jetson hardware simulator — the substitute for the physical Orin AGX /
//! Xavier AGX / Orin Nano devkits (DESIGN.md section 3).
//!
//! The simulator is the *ground truth* of this reproduction: it maps
//! (device, workload, power mode) to per-minibatch training time and board
//! power the same way the real boards did for the paper's authors. The
//! prediction models never see its equations — only profiled telemetry —
//! so the learning problem (non-linear bottleneck switches across a 4-D
//! grid, workload- and device-specific constants) is preserved.

pub mod faults;
pub mod perf_model;
pub mod power_model;
pub mod sensor;
pub mod thermal;
pub mod trainer_sim;

pub use faults::{FaultInjector, FaultPlan};
pub use perf_model::{minibatch_time_ms, TimeBreakdown};
pub use power_model::steady_power_mw;
pub use sensor::PowerSensor;
pub use trainer_sim::{FaultConfig, ProfilingRun, TrainerSim};

#[cfg(test)]
mod calibration;
