//! Thermal model (fan + throttle guard).
//!
//! The paper runs the fan at maximum to avoid thermal throttling (section
//! 2.2), so throttling never triggers in the default configuration; the
//! model exists for failure-injection tests and for the coordinator's
//! safety check ("in the worst case, destroying the device due to
//! overheating", paper section 1.1).

/// Simple lumped thermal model: junction temperature follows power with a
/// first-order response; above `throttle_c` the device would clamp clocks.
#[derive(Debug, Clone)]
pub struct ThermalModel {
    /// Ambient temperature (C).
    pub ambient_c: f64,
    /// Thermal resistance (C per W) with fan at max.
    pub r_fan_max: f64,
    /// Thermal resistance with fan off (IP-67 enclosure scenario).
    pub r_fan_off: f64,
    /// Throttle trip point (C).
    pub throttle_c: f64,
    /// Current junction temperature (C).
    temp_c: f64,
    /// Time constant (s).
    tau_s: f64,
    pub fan_max: bool,
}

impl Default for ThermalModel {
    fn default() -> Self {
        ThermalModel {
            ambient_c: 25.0,
            r_fan_max: 0.55,
            r_fan_off: 1.9,
            throttle_c: 95.0,
            temp_c: 25.0,
            tau_s: 30.0,
            fan_max: true, // paper's configuration
        }
    }
}

impl ThermalModel {
    pub fn temp_c(&self) -> f64 {
        self.temp_c
    }

    fn resistance(&self) -> f64 {
        if self.fan_max {
            self.r_fan_max
        } else {
            self.r_fan_off
        }
    }

    /// Advance the thermal state by `dt_s` seconds at `power_mw` draw.
    ///
    /// Inputs come from fault plans and predicted power, either of which
    /// can be garbage; non-finite or negative values are clamped so a bad
    /// plan cannot NaN-poison `temp_c` (which never recovers: NaN steady
    /// state infects every later update).
    pub fn advance(&mut self, power_mw: f64, dt_s: f64) {
        let power_mw = if power_mw.is_finite() { power_mw.max(0.0) } else { 0.0 };
        let dt_s = if dt_s.is_finite() { dt_s.max(0.0) } else { 0.0 };
        let steady = self.ambient_c + self.resistance() * power_mw / 1000.0;
        let k = (-dt_s / self.tau_s).exp();
        self.temp_c = steady + (self.temp_c - steady) * k;
        debug_assert!(self.temp_c.is_finite(), "thermal state went non-finite");
    }

    /// Steady-state temperature at a sustained power draw.
    pub fn steady_c(&self, power_mw: f64) -> f64 {
        self.ambient_c + self.resistance() * power_mw / 1000.0
    }

    pub fn would_throttle(&self) -> bool {
        self.temp_c >= self.throttle_c
    }

    /// Max sustainable power (mW) before throttling in the current fan
    /// configuration — the coordinator's safety ceiling.
    pub fn max_sustainable_mw(&self) -> f64 {
        (self.throttle_c - self.ambient_c) / self.resistance() * 1000.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fan_max_never_throttles_at_module_peak() {
        // paper configuration: 60 W peak Orin with fan at max stays cool
        let mut t = ThermalModel::default();
        for _ in 0..100 {
            t.advance(60_000.0, 10.0);
        }
        assert!(!t.would_throttle(), "temp={}", t.temp_c());
        assert!(t.temp_c() < 70.0);
    }

    #[test]
    fn fan_off_throttles_at_high_power() {
        // the IP-67 enclosure scenario: sustained 50 W with no fan cooks it
        let mut t = ThermalModel { fan_max: false, ..Default::default() };
        for _ in 0..200 {
            t.advance(50_000.0, 10.0);
        }
        assert!(t.would_throttle());
    }

    #[test]
    fn sustainable_power_sane() {
        let fan = ThermalModel::default();
        let nofan = ThermalModel { fan_max: false, ..Default::default() };
        assert!(fan.max_sustainable_mw() > 60_000.0);
        assert!(nofan.max_sustainable_mw() < 60_000.0);
        assert!(nofan.max_sustainable_mw() > 10_000.0);
    }

    #[test]
    fn advance_survives_hostile_inputs() {
        let mut t = ThermalModel::default();
        t.advance(40_000.0, 10.0);
        let before = t.temp_c();
        for &(p, dt) in &[
            (f64::NAN, 1.0),
            (f64::INFINITY, 1.0),
            (40_000.0, f64::NAN),
            (40_000.0, f64::NEG_INFINITY),
            (-5_000.0, 1.0),
            (40_000.0, -3.0),
        ] {
            t.advance(p, dt);
            assert!(t.temp_c().is_finite(), "poisoned by ({p}, {dt})");
        }
        // a clamped negative/NaN dt is a no-op in time, so the state is
        // still in a sane band around where it started
        assert!((t.temp_c() - before).abs() < 30.0);
        // and the model keeps working normally afterwards
        t.advance(40_000.0, 1000.0);
        assert!((t.temp_c() - t.steady_c(40_000.0)).abs() < 0.5);
    }

    #[test]
    fn temperature_approaches_steady_monotonically() {
        let mut t = ThermalModel::default();
        let steady = t.steady_c(40_000.0);
        let mut last = t.temp_c();
        for _ in 0..50 {
            t.advance(40_000.0, 5.0);
            assert!(t.temp_c() >= last - 1e-9);
            last = t.temp_c();
        }
        assert!((t.temp_c() - steady).abs() < 0.5);
    }
}
