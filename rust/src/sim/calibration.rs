//! Calibration tests: the simulator must reproduce every anchor number the
//! paper reports (DESIGN.md section 4). Tolerances are deliberately loose
//! where the paper's mode is under-specified ("a low power mode") and tight
//! where it is exact (MAXN).

use crate::device::{DeviceKind, PowerMode, PowerModeGrid};
use crate::sim::perf_model::epoch_time_s;
use crate::sim::power_model::steady_power_mw;
use crate::workload::Workload;

fn epoch_min(kind: DeviceKind, wl: &Workload, pm: &PowerMode) -> f64 {
    epoch_time_s(kind.spec(), wl, pm) / 60.0
}

fn power_w(kind: DeviceKind, wl: &Workload, pm: &PowerMode) -> f64 {
    steady_power_mw(kind.spec(), wl, pm) / 1000.0
}

fn assert_close(got: f64, want: f64, tol_frac: f64, what: &str) {
    let err = (got - want).abs() / want;
    assert!(
        err <= tol_frac,
        "{what}: got {got:.2}, paper {want:.2} ({:.0}% off, tol {:.0}%)",
        err * 100.0,
        tol_frac * 100.0
    );
}

#[test]
fn orin_maxn_epoch_times_match_table3() {
    let maxn = PowerMode::maxn(DeviceKind::OrinAgx.spec());
    // paper Table 3: estimated epoch time at MAXN (minutes)
    assert_close(epoch_min(DeviceKind::OrinAgx, &Workload::resnet(), &maxn), 3.1, 0.05, "resnet epoch");
    assert_close(epoch_min(DeviceKind::OrinAgx, &Workload::mobilenet(), &maxn), 2.3, 0.08, "mobilenet epoch");
    assert_close(epoch_min(DeviceKind::OrinAgx, &Workload::yolo(), &maxn), 4.9, 0.05, "yolo epoch");
    assert_close(epoch_min(DeviceKind::OrinAgx, &Workload::bert(), &maxn), 68.6, 0.05, "bert epoch");
    assert_close(epoch_min(DeviceKind::OrinAgx, &Workload::lstm(), &maxn), 0.4, 0.08, "lstm epoch");
}

#[test]
fn orin_maxn_power_matches_paper() {
    let maxn = PowerMode::maxn(DeviceKind::OrinAgx.spec());
    // section 1.1: ResNet @ MAXN 51.1 W; BERT @ MAXN 57 W
    assert_close(power_w(DeviceKind::OrinAgx, &Workload::resnet(), &maxn), 51.1, 0.10, "resnet maxn power");
    assert_close(power_w(DeviceKind::OrinAgx, &Workload::bert(), &maxn), 57.0, 0.10, "bert maxn power");
}

#[test]
fn orin_low_mode_anchor_exists() {
    // section 1.1: "a low power mode ... 112 mins/epoch, ~11.8 W" for
    // ResNet. The exact mode is unspecified; assert that some full-grid
    // mode lands near that (time, power) point.
    let grid = PowerModeGrid::full(DeviceKind::OrinAgx);
    let wl = Workload::resnet();
    let found = grid.modes.iter().any(|pm| {
        let t = epoch_min(DeviceKind::OrinAgx, &wl, pm);
        let p = power_w(DeviceKind::OrinAgx, &wl, pm);
        (t - 112.0).abs() / 112.0 < 0.30 && (p - 11.8).abs() / 11.8 < 0.30
    });
    assert!(found, "no mode near (112 min, 11.8 W) for resnet");
}

#[test]
fn xavier_resnet_maxn_matches_paper() {
    // section 1.1: Xavier AGX ResNet MAXN: 8.47 min/epoch, 36.4 W
    let maxn = PowerMode::maxn(DeviceKind::XavierAgx.spec());
    assert_close(epoch_min(DeviceKind::XavierAgx, &Workload::resnet(), &maxn), 8.47, 0.10, "xavier resnet epoch");
    assert_close(power_w(DeviceKind::XavierAgx, &Workload::resnet(), &maxn), 36.4, 0.10, "xavier resnet power");
}

#[test]
fn nano_is_roughly_7x_slower_than_orin() {
    // section 4.3.4: Orin Nano is "6.9x less powerful" than Orin AGX
    let orin = epoch_min(DeviceKind::OrinAgx, &Workload::resnet(), &PowerMode::maxn(DeviceKind::OrinAgx.spec()));
    let nano = epoch_min(DeviceKind::OrinNano, &Workload::resnet(), &PowerMode::maxn(DeviceKind::OrinNano.spec()));
    let ratio = nano / orin;
    assert!((4.5..9.5).contains(&ratio), "nano/orin ratio={ratio:.2}");
}

#[test]
fn nano_stays_under_15w_peak() {
    let grid = PowerModeGrid::full(DeviceKind::OrinNano);
    for wl in Workload::default_five() {
        for pm in grid.modes.iter().step_by(37) {
            let p = power_w(DeviceKind::OrinNano, &wl, pm);
            assert!(p <= 15.0 * 1.05, "{} {} = {p:.1} W", wl.name(), pm.label());
        }
    }
}

#[test]
fn dynamic_ranges_match_paper_magnitudes() {
    // section 1.1: up to 36x time impact, 4.3x power impact
    let wl = Workload::resnet();
    let grid = PowerModeGrid::full(DeviceKind::OrinAgx);
    let spec = DeviceKind::OrinAgx.spec();
    let (mut tmin, mut tmax) = (f64::INFINITY, 0.0f64);
    let (mut pmin, mut pmax) = (f64::INFINITY, 0.0f64);
    for pm in &grid.modes {
        let t = crate::sim::perf_model::minibatch_time_ms(spec, &wl, pm).total_ms;
        let p = steady_power_mw(spec, &wl, pm);
        tmin = tmin.min(t);
        tmax = tmax.max(t);
        pmin = pmin.min(p);
        pmax = pmax.max(p);
    }
    let t_ratio = tmax / tmin;
    let p_ratio = pmax / pmin;
    assert!((15.0..80.0).contains(&t_ratio), "time ratio {t_ratio:.1}");
    assert!((3.0..10.0).contains(&p_ratio), "power ratio {p_ratio:.1}");
}

#[test]
fn nvidia_preset_budgets_roughly_respected() {
    // the three Orin presets should draw in the neighbourhood of their
    // nominal budgets for a heavy workload (NPE-style budgets are upper
    // bounds, so observed power should be at or under budget + slack)
    for (budget_w, pm) in crate::device::power_mode::nvidia_preset_modes(DeviceKind::OrinAgx) {
        let p = power_w(DeviceKind::OrinAgx, &Workload::resnet(), &pm);
        assert!(
            p < budget_w * 1.25 && p > budget_w * 0.4,
            "preset {budget_w} W draws {p:.1} W"
        );
    }
}
