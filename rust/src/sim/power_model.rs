//! Analytical board-power model.
//!
//! Power = base + CPU + GPU + memory, with each dynamic component the
//! product of a frequency curve (superlinear, approximating DVFS
//! voltage/frequency scaling) and a utilization term coupled to the time
//! model's busy fractions. The coupling is what makes power *workload-
//! dependent* (a CPU-bound MobileNet leaves the GPU idling at high
//! frequency — high clock, low draw) and gives the NPE-style "assume max
//! utilization" estimators their systematic overestimate (paper Fig 2a).

use crate::device::{DeviceSpec, PowerMode};
use crate::sim::perf_model::minibatch_time_ms;
use crate::workload::Workload;

/// CPU dynamic-power frequency curve (normalized freq -> [0, 1]).
fn cpu_freq_curve(f: f64) -> f64 {
    0.25 * f + 0.75 * f.powf(2.6)
}

/// GPU dynamic-power frequency curve.
fn gpu_freq_curve(f: f64) -> f64 {
    0.30 * f + 0.70 * f.powf(2.2)
}

/// Memory-subsystem frequency curve (has a floor: DRAM refresh etc.).
fn mem_freq_curve(f: f64) -> f64 {
    0.25 + 0.75 * f.powf(1.8)
}

/// Steady-state board power (mW) while training `wl` under `pm`.
pub fn steady_power_mw(spec: &DeviceSpec, wl: &Workload, pm: &PowerMode) -> f64 {
    let t = minibatch_time_ms(spec, wl, pm);
    let prof = wl.work_profile();

    let f_cpu = pm.cpu_khz as f64 / spec.max_cpu_khz() as f64;
    let f_gpu = pm.gpu_khz as f64 / spec.max_gpu_khz() as f64;
    let f_mem = pm.mem_khz as f64 / spec.max_mem_khz() as f64;

    // active cores draw idle power even when the loader is not saturating
    // them; busy fraction + workload activity drives the dynamic part
    let cpu_util = 0.18 + 0.82 * t.cpu_busy_frac * prof.cpu_act;
    let p_cpu = pm.cores as f64 * spec.p_core_max_mw * cpu_freq_curve(f_cpu) * cpu_util;

    let gpu_util = 0.10 + 0.90 * t.gpu_busy_frac * prof.gpu_act;
    let p_gpu = spec.p_gpu_max_mw * gpu_freq_curve(f_gpu) * gpu_util;

    let mem_activity = prof.mem_act * t.gpu_busy_frac.max(0.6 * t.cpu_busy_frac);
    let mem_util = 0.30 + 0.70 * mem_activity;
    let p_mem = spec.p_mem_max_mw * mem_freq_curve(f_mem) * mem_util;

    spec.p_base_mw + p_cpu + p_gpu + p_mem
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::{DeviceKind, PowerMode, PowerModeGrid};
    use crate::workload::Workload;

    #[test]
    fn power_positive_and_below_module_peak() {
        for kind in DeviceKind::ALL {
            let spec = kind.spec();
            let grid = PowerModeGrid::full(kind);
            for wl in Workload::default_five() {
                // sample the grid corners + a few interior points
                for pm in grid.modes.iter().step_by(grid.modes.len() / 50) {
                    let p = steady_power_mw(spec, &wl, pm);
                    assert!(p > 0.0);
                    assert!(
                        p <= spec.peak_power_w * 1000.0 * 1.05,
                        "{:?} {} exceeds peak: {} mW",
                        kind,
                        wl.name(),
                        p
                    );
                }
            }
        }
    }

    #[test]
    fn power_monotone_in_gpu_frequency_for_gpu_bound() {
        let spec = DeviceKind::OrinAgx.spec();
        let wl = Workload::bert();
        let mut last = 0.0;
        for &g in spec.gpu_khz {
            let pm = PowerMode { cores: 12, cpu_khz: spec.max_cpu_khz(), gpu_khz: g, mem_khz: spec.max_mem_khz() };
            let p = steady_power_mw(spec, &wl, &pm);
            assert!(p >= last - 1.0, "power decreased with gpu freq");
            last = p;
        }
    }

    #[test]
    fn workload_dependence_at_same_mode() {
        // BERT (GPU-saturating) must draw clearly more than LSTM (tiny) at
        // MAXN — the workload sensitivity NPE lacks
        let spec = DeviceKind::OrinAgx.spec();
        let pm = PowerMode::maxn(spec);
        let p_bert = steady_power_mw(spec, &Workload::bert(), &pm);
        let p_lstm = steady_power_mw(spec, &Workload::lstm(), &pm);
        assert!(p_bert > 1.3 * p_lstm, "bert={p_bert} lstm={p_lstm}");
    }

    #[test]
    fn power_range_is_several_x() {
        // paper: up to 4.3x impact of power modes on power
        let spec = DeviceKind::OrinAgx.spec();
        let wl = Workload::resnet();
        let grid = PowerModeGrid::paper_subset(DeviceKind::OrinAgx);
        let powers: Vec<f64> = grid.modes.iter().map(|pm| steady_power_mw(spec, &wl, pm)).collect();
        let max = powers.iter().cloned().fold(0.0, f64::max);
        let min = powers.iter().cloned().fold(f64::INFINITY, f64::min);
        let ratio = max / min;
        assert!(ratio > 2.5 && ratio < 12.0, "power ratio={ratio}");
    }
}
