//! Analytical per-minibatch training-time model.
//!
//! Three components, mirroring how a PyTorch training step spends time on
//! a Jetson:
//!
//! * **GPU compute** — roofline: max(compute-bound, memory-bound) time.
//!   Compute scales with GPU frequency and the device's throughput class;
//!   the memory-bound ceiling scales with EMC frequency and the device's
//!   bandwidth class.
//! * **CPU preprocessing** — DataLoader fetch+decode+augment; scales with
//!   CPU frequency, per-core IPC, and effective worker parallelism
//!   min(cores, num_workers + 1).
//! * **Framework overhead** — Python/launch overhead on the main process;
//!   scales inversely with CPU frequency only.
//!
//! With `num_workers >= 1` the DataLoader pipelines preprocessing against
//! GPU compute: total = max(gpu + overhead, cpu). With `num_workers == 0`
//! (YOLO, paper footnote 6) everything serializes: total = gpu + cpu +
//! overhead — exactly the "GPU stalls" behaviour the paper describes.

use crate::device::{DeviceSpec, PowerMode};
use crate::workload::Workload;

/// Orin AGX reference frequencies the workload coefficients are calibrated
/// against (work units are "ms x GHz" at these references).
pub const ORIN_GPU_MAX_GHZ: f64 = 1.3005;
pub const ORIN_MEM_MAX_KHZ: f64 = 3_199_000.0;

/// Decomposed minibatch time (milliseconds).
#[derive(Debug, Clone, Copy)]
pub struct TimeBreakdown {
    pub gpu_ms: f64,
    pub cpu_ms: f64,
    pub overhead_ms: f64,
    pub total_ms: f64,
    /// Fraction of wall time the GPU is busy (drives GPU power).
    pub gpu_busy_frac: f64,
    /// Fraction of wall time the CPU cluster is busy.
    pub cpu_busy_frac: f64,
}

/// Deterministic (noise-free) time model.
pub fn minibatch_time_ms(spec: &DeviceSpec, wl: &Workload, pm: &PowerMode) -> TimeBreakdown {
    let prof = wl.work_profile();
    let cpu_ghz = pm.cpu_khz as f64 / 1e6;
    let gpu_ghz = pm.gpu_khz as f64 / 1e6;

    // GPU roofline: compute-bound vs memory-bandwidth-bound.
    let compute_ms = prof.gpu_work / (spec.gpu_tput * gpu_ghz);
    let mem_scale = (ORIN_MEM_MAX_KHZ / pm.mem_khz as f64) / spec.mem_bw;
    let mem_ms = prof.gpu_mem_beta * (prof.gpu_work / ORIN_GPU_MAX_GHZ) * mem_scale;
    let gpu_ms = compute_ms.max(mem_ms);

    // CPU preprocessing with effective worker parallelism.
    let workers = if wl.num_workers == 0 {
        1.0
    } else {
        (wl.num_workers + 1).min(pm.cores) as f64
    };
    let cpu_ms = prof.cpu_work / (spec.cpu_eff * cpu_ghz * workers);

    // Fixed framework overhead on the main process.
    let overhead_ms = prof.overhead_work / (spec.cpu_eff * cpu_ghz);

    let total_ms = if wl.num_workers == 0 {
        gpu_ms + cpu_ms + overhead_ms
    } else {
        (gpu_ms + overhead_ms).max(cpu_ms)
    };

    TimeBreakdown {
        gpu_ms,
        cpu_ms,
        overhead_ms,
        total_ms,
        gpu_busy_frac: (gpu_ms / total_ms).min(1.0),
        cpu_busy_frac: ((cpu_ms + overhead_ms) / total_ms).min(1.0),
    }
}

/// Epoch time in seconds for a workload at a given mode.
pub fn epoch_time_s(spec: &DeviceSpec, wl: &Workload, pm: &PowerMode) -> f64 {
    minibatch_time_ms(spec, wl, pm).total_ms * wl.minibatches_per_epoch() as f64 / 1e3
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::{DeviceKind, PowerMode, PowerModeGrid};
    use crate::workload::Workload;

    fn orin() -> &'static DeviceSpec {
        DeviceKind::OrinAgx.spec()
    }

    #[test]
    fn maxn_components_positive_and_consistent() {
        let pm = PowerMode::maxn(orin());
        for wl in Workload::default_five() {
            let t = minibatch_time_ms(orin(), &wl, &pm);
            assert!(t.gpu_ms > 0.0 && t.cpu_ms > 0.0 && t.overhead_ms > 0.0);
            assert!(t.total_ms >= t.gpu_ms, "{wl:?}");
            assert!(t.gpu_busy_frac > 0.0 && t.gpu_busy_frac <= 1.0);
            assert!(t.cpu_busy_frac > 0.0 && t.cpu_busy_frac <= 1.0);
        }
    }

    #[test]
    fn yolo_serializes_components() {
        let pm = PowerMode::maxn(orin());
        let t = minibatch_time_ms(orin(), &Workload::yolo(), &pm);
        assert!((t.total_ms - (t.gpu_ms + t.cpu_ms + t.overhead_ms)).abs() < 1e-9);
    }

    #[test]
    fn pipelined_workloads_hide_cpu_when_gpu_bound() {
        let pm = PowerMode::maxn(orin());
        let t = minibatch_time_ms(orin(), &Workload::bert(), &pm);
        assert!((t.total_ms - (t.gpu_ms + t.overhead_ms)).abs() < 1e-9);
        assert!(t.cpu_ms < t.total_ms);
    }

    #[test]
    fn monotone_in_gpu_frequency() {
        let spec = orin();
        let wl = Workload::resnet();
        let mut last = f64::INFINITY;
        for &g in spec.gpu_khz {
            let pm = PowerMode { cores: 12, cpu_khz: spec.max_cpu_khz(), gpu_khz: g, mem_khz: spec.max_mem_khz() };
            let t = minibatch_time_ms(spec, &wl, &pm).total_ms;
            assert!(t <= last + 1e-9, "time increased with gpu freq");
            last = t;
        }
    }

    #[test]
    fn monotone_in_cores_for_cpu_bound() {
        let spec = orin();
        let wl = Workload::mobilenet(); // CPU-bound
        let mut last = f64::INFINITY;
        for cores in 1..=spec.max_cores {
            let pm = PowerMode { cores, cpu_khz: spec.max_cpu_khz(), gpu_khz: spec.max_gpu_khz(), mem_khz: spec.max_mem_khz() };
            let t = minibatch_time_ms(spec, &wl, &pm).total_ms;
            assert!(t <= last + 1e-9);
            last = t;
        }
    }

    #[test]
    fn bottleneck_switches_somewhere_in_grid() {
        // the non-linearity the NN must learn: some modes are CPU-bound,
        // others GPU-bound, for the same workload
        let spec = orin();
        let wl = Workload::resnet();
        let grid = PowerModeGrid::paper_subset(DeviceKind::OrinAgx);
        let mut cpu_bound = 0usize;
        let mut gpu_bound = 0usize;
        for pm in &grid.modes {
            let t = minibatch_time_ms(spec, &wl, pm);
            if t.cpu_ms > t.gpu_ms + t.overhead_ms {
                cpu_bound += 1;
            } else {
                gpu_bound += 1;
            }
        }
        assert!(cpu_bound > 100, "cpu_bound={cpu_bound}");
        assert!(gpu_bound > 100, "gpu_bound={gpu_bound}");
    }

    #[test]
    fn time_range_spans_order_of_magnitude() {
        let spec = orin();
        let wl = Workload::resnet();
        let grid = PowerModeGrid::full(DeviceKind::OrinAgx);
        let times: Vec<f64> = grid.modes.iter()
            .map(|pm| minibatch_time_ms(spec, &wl, pm).total_ms)
            .collect();
        let max = times.iter().cloned().fold(0.0, f64::max);
        let min = times.iter().cloned().fold(f64::INFINITY, f64::min);
        // paper reports up to ~36x impact of power modes on training time
        let ratio = max / min;
        assert!(ratio > 10.0 && ratio < 100.0, "ratio={ratio}");
    }
}
