//! StandardScaler — feature/target standardization matching sklearn's
//! behaviour (paper Table 4: "each input feature is normalized ... using
//! the sklearn library's StandardScaler").

use crate::error::{Error, Result};
use crate::util::json::Value;

/// Per-dimension (x - mean) / std transform.
#[derive(Debug, Clone, PartialEq)]
pub struct StandardScaler {
    pub mean: Vec<f64>,
    pub std: Vec<f64>,
}

impl StandardScaler {
    /// Fit on rows of equal width. Zero-variance columns get std = 1 so
    /// transform is the identity shift (sklearn's convention).
    pub fn fit(rows: &[Vec<f64>]) -> StandardScaler {
        assert!(!rows.is_empty(), "cannot fit scaler on empty data");
        let dim = rows[0].len();
        let n = rows.len() as f64;
        let mut mean = vec![0.0; dim];
        for r in rows {
            assert_eq!(r.len(), dim);
            for (m, v) in mean.iter_mut().zip(r) {
                *m += v;
            }
        }
        for m in &mut mean {
            *m /= n;
        }
        let mut var = vec![0.0; dim];
        for r in rows {
            for ((v, m), x) in var.iter_mut().zip(&mean).zip(r) {
                *v += (x - m) * (x - m);
            }
        }
        let std = var
            .into_iter()
            .map(|v| Self::clamp_std((v / n).sqrt()))
            .collect();
        StandardScaler { mean, std }
    }

    /// Guard a fitted/loaded σ against the degenerate cases that would
    /// otherwise divide straight through in `transform*` and poison every
    /// downstream feature with NaN/∞: zero or near-zero variance (a
    /// profiling corpus where a feature never moves), and non-finite
    /// values from a corrupt checkpoint. Clamped to 1.0, sklearn's
    /// convention (the transform degrades to a mean shift).
    pub fn clamp_std(s: f64) -> f64 {
        if s.is_finite() && s > 1e-12 {
            s
        } else {
            1.0
        }
    }

    /// Fit a 1-D scaler (for targets).
    pub fn fit1(xs: &[f64]) -> StandardScaler {
        Self::fit(&xs.iter().map(|&x| vec![x]).collect::<Vec<_>>())
    }

    pub fn dim(&self) -> usize {
        self.mean.len()
    }

    /// Standardize a 4-wide f32 feature row without allocating — the
    /// hot-path variant used by the batched engine and training feature
    /// prep. Single source of truth with [`StandardScaler::transform_row`]
    /// for the (x - mean) / std semantics (zero-variance columns already
    /// have std forced to 1.0 at fit time).
    pub fn transform4(&self, feats: &[f32; 4]) -> [f32; 4] {
        debug_assert_eq!(self.dim(), 4);
        let mut z = [0.0f32; 4];
        for d in 0..4 {
            z[d] = ((feats[d] as f64 - self.mean[d]) / self.std[d]) as f32;
        }
        z
    }

    pub fn transform_row(&self, row: &[f64]) -> Vec<f64> {
        assert_eq!(row.len(), self.dim());
        row.iter()
            .zip(&self.mean)
            .zip(&self.std)
            .map(|((x, m), s)| (x - m) / s)
            .collect()
    }

    pub fn inverse_row(&self, row: &[f64]) -> Vec<f64> {
        row.iter()
            .zip(&self.mean)
            .zip(&self.std)
            .map(|((z, m), s)| z * s + m)
            .collect()
    }

    /// Scalar helpers for 1-D target scalers.
    pub fn transform1(&self, x: f64) -> f64 {
        (x - self.mean[0]) / self.std[0]
    }

    pub fn inverse1(&self, z: f64) -> f64 {
        z * self.std[0] + self.mean[0]
    }

    // ---- persistence -------------------------------------------------------

    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("mean", Value::from_f64_slice(&self.mean)),
            ("std", Value::from_f64_slice(&self.std)),
        ])
    }

    pub fn from_json(v: &Value) -> Result<StandardScaler> {
        let mean = v.req("mean")?.as_f64_vec()?;
        let std: Vec<f64> = v
            .req("std")?
            .as_f64_vec()?
            .into_iter()
            .map(Self::clamp_std)
            .collect();
        if mean.len() != std.len() || mean.is_empty() {
            return Err(Error::json("scaler mean/std length mismatch"));
        }
        Ok(StandardScaler { mean, std })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn standardizes_to_zero_mean_unit_var() {
        let mut rng = Rng::new(1);
        let rows: Vec<Vec<f64>> = (0..1000)
            .map(|_| vec![rng.normal_ms(50.0, 10.0), rng.normal_ms(-3.0, 0.5)])
            .collect();
        let sc = StandardScaler::fit(&rows);
        let transformed: Vec<Vec<f64>> = rows.iter().map(|r| sc.transform_row(r)).collect();
        for d in 0..2 {
            let col: Vec<f64> = transformed.iter().map(|r| r[d]).collect();
            assert!(crate::util::stats::mean(&col).abs() < 1e-9);
            assert!((crate::util::stats::std_dev(&col) - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn inverse_round_trips() {
        let rows = vec![vec![1.0, 100.0], vec![2.0, 300.0], vec![3.0, -50.0]];
        let sc = StandardScaler::fit(&rows);
        for r in &rows {
            let back = sc.inverse_row(&sc.transform_row(r));
            for (a, b) in back.iter().zip(r) {
                assert!((a - b).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn constant_column_is_shift_only() {
        let rows = vec![vec![5.0], vec![5.0], vec![5.0]];
        let sc = StandardScaler::fit(&rows);
        assert_eq!(sc.std[0], 1.0);
        assert_eq!(sc.transform_row(&[5.0])[0], 0.0);
        assert_eq!(sc.transform_row(&[7.0])[0], 2.0);
    }

    #[test]
    fn transform4_matches_transform_row() {
        let sc = StandardScaler {
            mean: vec![6.0, 1200.0, 700.0, 1500.0],
            std: vec![3.0, 600.0, 350.0, 1000.0],
        };
        let feats = [8.0f32, 1651.2, 420.75, 2133.0];
        let z4 = sc.transform4(&feats);
        let row: Vec<f64> = feats.iter().map(|&x| x as f64).collect();
        let zr = sc.transform_row(&row);
        for d in 0..4 {
            assert_eq!(z4[d], zr[d] as f32, "dim {d}");
        }
    }

    #[test]
    fn degenerate_corpus_yields_finite_features() {
        // a profiling corpus where every feature is constant (e.g. a
        // single-mode corpus) must not produce NaN/inf features
        let rows = vec![vec![4.0, 1113.6, 420.75, 2133.0]; 5];
        let sc = StandardScaler::fit(&rows);
        assert!(sc.std.iter().all(|&s| s == 1.0));
        let z = sc.transform_row(&[8.0, 1113.6, 420.75, 2133.0]);
        assert!(z.iter().all(|v| v.is_finite()));
        assert_eq!(z[0], 4.0); // shift-only
        assert_eq!(z[1], 0.0);
    }

    #[test]
    fn clamp_std_guards_zero_and_nonfinite() {
        assert_eq!(StandardScaler::clamp_std(0.0), 1.0);
        assert_eq!(StandardScaler::clamp_std(1e-300), 1.0);
        assert_eq!(StandardScaler::clamp_std(-2.0), 1.0);
        assert_eq!(StandardScaler::clamp_std(f64::NAN), 1.0);
        assert_eq!(StandardScaler::clamp_std(f64::INFINITY), 1.0);
        assert_eq!(StandardScaler::clamp_std(3.5), 3.5);
    }

    #[test]
    fn from_json_clamps_degenerate_std() {
        // a checkpoint written with σ = 0 (degenerate corpus, older
        // builds) must load with the clamped convention, not divide
        // through to NaN at predict time
        let v = Value::parse(r#"{"mean":[5.0, 1.0],"std":[0.0, 2.0]}"#).unwrap();
        let sc = StandardScaler::from_json(&v).unwrap();
        assert_eq!(sc.std, vec![1.0, 2.0]);
        let z = sc.transform_row(&[7.0, 5.0]);
        assert!(z.iter().all(|x| x.is_finite()));
        assert_eq!(z[0], 2.0);
    }

    #[test]
    fn scalar_target_helpers() {
        let sc = StandardScaler::fit1(&[10.0, 20.0, 30.0]);
        assert!((sc.transform1(20.0)).abs() < 1e-12);
        assert!((sc.inverse1(sc.transform1(27.5)) - 27.5).abs() < 1e-12);
    }

    #[test]
    fn json_round_trip() {
        let sc = StandardScaler::fit(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        let back = StandardScaler::from_json(&Value::parse(&sc.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(sc, back);
    }

    #[test]
    fn from_json_rejects_mismatch() {
        let v = Value::parse(r#"{"mean":[1,2],"std":[1]}"#).unwrap();
        assert!(StandardScaler::from_json(&v).is_err());
    }
}
