//! Profiling pipeline (paper sections 2.4–2.5).
//!
//! Turns raw telemetry from a training session into clean per-power-mode
//! records: discard the slow first minibatch, detect power stabilization
//! with a sliding window, require 40 clean minibatches, and account the
//! wall-clock profiling cost (the overhead axis of Figs 7/8).

pub mod corpus;
pub mod scaler;

pub use corpus::{Corpus, Record, RollingCorpus};
pub use scaler::StandardScaler;

use crate::device::{PowerMode, ProfilingPlan};
use crate::error::{Error, Result};
use crate::sim::TrainerSim;
use crate::util::stats;

/// Number of clean minibatches collected per power mode (paper: 40, after
/// a sensitivity study over 10–40).
pub const CLEAN_MINIBATCHES: usize = 40;

/// Sliding-window stabilization detector parameters.
const STAB_WINDOW: usize = 3;
/// Relative spread within the window that counts as "stable".
const STAB_TOL: f64 = 0.04;

/// Profiling result for one power mode.
#[derive(Debug, Clone)]
pub struct ModeProfile {
    pub mode: PowerMode,
    /// Mean clean minibatch training time (ms).
    pub time_ms: f64,
    /// Mean stabilized power (mW). None if the run finished before any
    /// stable 1 Hz samples landed (fast modes, paper section 2.5).
    pub power_mw: Option<f64>,
    /// Wall-clock seconds this mode's profiling took (incl. re-runs).
    pub cost_s: f64,
    /// Device reboot needed to reach this mode in the plan.
    pub rebooted: bool,
}

/// Find the index after which the 1 Hz power readings have stabilized:
/// the first window of `STAB_WINDOW` consecutive samples whose relative
/// spread is below `STAB_TOL`. Returns the start of that window.
pub fn stabilization_index(samples: &[u32]) -> Option<usize> {
    if samples.len() < STAB_WINDOW {
        return None;
    }
    for start in 0..=(samples.len() - STAB_WINDOW) {
        let w = &samples[start..start + STAB_WINDOW];
        let lo = *w.iter().min().unwrap() as f64;
        let hi = *w.iter().max().unwrap() as f64;
        if hi <= 0.0 {
            continue;
        }
        if (hi - lo) / hi <= STAB_TOL {
            return Some(start);
        }
    }
    None
}

/// The profiler: owns a simulated training session and produces clean
/// [`ModeProfile`]s / a full [`Corpus`].
pub struct Profiler {
    pub sim: TrainerSim,
    /// Seconds charged per device reboot in cost accounting.
    pub reboot_cost_s: f64,
}

impl Profiler {
    pub fn new(sim: TrainerSim) -> Profiler {
        Profiler { sim, reboot_cost_s: 45.0 }
    }

    /// Profile a single power mode: run warmup + 40 clean minibatches,
    /// discard the first minibatch, and average power samples after the
    /// detected stabilization point.
    pub fn profile_mode(&mut self, mode: &PowerMode, rebooted: bool) -> Result<ModeProfile> {
        mode.validate(self.sim.spec)?;
        // +1 for the discarded warmup minibatch
        let mut run = self.sim.profile_mode(mode, CLEAN_MINIBATCHES + 1);
        let mut cost = run.wall_time_s;

        // fast modes can finish before any stable power sample exists
        // (paper section 2.5: "the training of all the minibatches
        // completes within this interval"); extend the run with enough
        // extra minibatches to span several sampling intervals — cheap,
        // since the workload trains productively during profiling anyway
        let mut extensions = 0;
        while stabilization_index(&run.power_samples_mw).is_none() && extensions < 4 {
            let mean_ms = stats::mean(&run.minibatch_ms[1..]).max(0.01);
            let needed_s = (STAB_WINDOW + 5) as f64;
            let n_more = ((needed_s * 1000.0 / mean_ms).ceil() as usize)
                .clamp(CLEAN_MINIBATCHES, 20_000);
            let more = self.sim.profile_mode(mode, n_more);
            cost += more.wall_time_s;
            run.power_samples_mw.extend(&more.power_samples_mw);
            extensions += 1;
        }

        let clean_times = &run.minibatch_ms[1..]; // discard first minibatch
        let time_ms = stats::mean(clean_times);

        let power_mw = stabilization_index(&run.power_samples_mw).map(|idx| {
            let stable: Vec<f64> = run.power_samples_mw[idx..]
                .iter()
                .map(|&p| p as f64)
                .collect();
            stats::mean(&stable)
        });

        if rebooted {
            cost += self.reboot_cost_s;
        }

        Ok(ModeProfile { mode: *mode, time_ms, power_mw, cost_s: cost, rebooted })
    }

    /// Profile a set of modes in reboot-aware order, assembling a corpus.
    pub fn profile_modes(&mut self, modes: &[PowerMode]) -> Result<Corpus> {
        let plan = ProfilingPlan::build(modes);
        let mut corpus = Corpus::new(self.sim.spec.kind, self.sim.workload);
        for step in &plan.steps {
            let prof = self.profile_mode(&step.mode, step.reboot)?;
            let power = prof.power_mw.ok_or_else(|| {
                Error::Profiling(format!(
                    "power never stabilized for {}",
                    step.mode.label()
                ))
            })?;
            corpus.push(Record {
                mode: prof.mode,
                time_ms: prof.time_ms,
                power_mw: power,
                cost_s: prof.cost_s,
            });
        }
        Ok(corpus)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::{DeviceKind, PowerModeGrid};
    use crate::sim::TrainerSim;
    use crate::workload::Workload;

    fn profiler(wl: Workload, seed: u64) -> Profiler {
        Profiler::new(TrainerSim::new(DeviceKind::OrinAgx.spec(), wl, seed))
    }

    #[test]
    fn stabilization_detects_ramp_end() {
        // ramp 10k -> 30k then stable
        let samples = vec![12_000u32, 21_000, 26_500, 29_000, 29_900, 30_100, 29_950];
        let idx = stabilization_index(&samples).unwrap();
        assert!(idx >= 3, "detected too early: {idx}");
    }

    #[test]
    fn stabilization_none_for_short_or_noisy() {
        assert_eq!(stabilization_index(&[10_000, 20_000]), None);
        let wild = vec![10_000u32, 20_000, 10_000, 20_000, 10_000, 20_000];
        assert_eq!(stabilization_index(&wild), None);
    }

    #[test]
    fn profile_mode_recovers_ground_truth() {
        let mut p = profiler(Workload::resnet(), 11);
        let spec = DeviceKind::OrinAgx.spec();
        let mode = PowerMode { cores: 8, cpu_khz: spec.cpu_khz[20], gpu_khz: spec.gpu_khz[6], mem_khz: spec.mem_khz[2] };
        let prof = p.profile_mode(&mode, false).unwrap();
        let t_truth = p.sim.true_minibatch_ms(&mode);
        let p_truth = p.sim.true_power_mw(&mode);
        assert!((prof.time_ms - t_truth).abs() / t_truth < 0.02);
        let pw = prof.power_mw.unwrap();
        assert!((pw - p_truth).abs() / p_truth < 0.05, "pw={pw} truth={p_truth}");
    }

    #[test]
    fn fast_modes_extend_until_power_stabilizes() {
        // LSTM at MAXN trains 41 minibatches in well under a second
        let mut p = profiler(Workload::lstm(), 13);
        let maxn = PowerMode::maxn(DeviceKind::OrinAgx.spec());
        let prof = p.profile_mode(&maxn, false).unwrap();
        assert!(prof.power_mw.is_some(), "extension policy failed");
    }

    #[test]
    fn reboot_cost_accounted() {
        let mut p = profiler(Workload::resnet(), 17);
        let maxn = PowerMode::maxn(DeviceKind::OrinAgx.spec());
        let without = p.profile_mode(&maxn, false).unwrap();
        let with = p.profile_mode(&maxn, true).unwrap();
        assert!(with.cost_s > without.cost_s + 40.0);
    }

    #[test]
    fn invalid_mode_rejected() {
        let mut p = profiler(Workload::resnet(), 19);
        let bad = PowerMode { cores: 99, cpu_khz: 1, gpu_khz: 1, mem_khz: 1 };
        assert!(p.profile_mode(&bad, false).is_err());
    }

    #[test]
    fn profile_modes_builds_full_corpus() {
        let mut p = profiler(Workload::resnet(), 23);
        let mut rng = crate::util::rng::Rng::new(1);
        let modes = PowerModeGrid::paper_subset(DeviceKind::OrinAgx).sample(25, &mut rng);
        let corpus = p.profile_modes(&modes).unwrap();
        assert_eq!(corpus.len(), 25);
        assert!(corpus.total_cost_s() > 0.0);
        // every record's time within a few % of ground truth
        for r in corpus.records() {
            let truth = p.sim.true_minibatch_ms(&r.mode);
            assert!((r.time_ms - truth).abs() / truth < 0.03);
        }
    }
}
