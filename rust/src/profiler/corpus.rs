//! Profiling corpus: the (power mode -> time, power) dataset the prediction
//! models train and validate on, with CSV persistence and the sampling
//! strategies the paper uses (all / uniform-N / random-N, 90:10 splits).
//!
//! [`RollingCorpus`] is the *online* variant: a bounded
//! recency-window-plus-reservoir store for serving-time feedback
//! observations, the ground-truth corpus the coordinator's model
//! lifecycle refits from.

use std::collections::VecDeque;
use std::path::Path;

use crate::device::{DeviceKind, PowerMode};
use crate::error::{Error, Result};
use crate::util::csv::Table;
use crate::util::rng::Rng;
use crate::workload::Workload;

/// One profiled power mode.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Record {
    pub mode: PowerMode,
    /// Mean clean minibatch time (ms).
    pub time_ms: f64,
    /// Mean stabilized power (mW).
    pub power_mw: f64,
    /// Profiling wall-clock cost (s).
    pub cost_s: f64,
}

/// A profiling corpus for one (device, workload) pair.
#[derive(Debug, Clone)]
pub struct Corpus {
    pub device: DeviceKind,
    pub workload: Workload,
    records: Vec<Record>,
}

impl Corpus {
    pub fn new(device: DeviceKind, workload: Workload) -> Corpus {
        Corpus { device, workload, records: Vec::new() }
    }

    pub fn push(&mut self, r: Record) {
        self.records.push(r);
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    pub fn records(&self) -> &[Record] {
        &self.records
    }

    /// Total profiling wall-clock cost (the overhead axis of Figs 7/8).
    pub fn total_cost_s(&self) -> f64 {
        self.records.iter().map(|r| r.cost_s).sum()
    }

    /// Feature matrix (raw, unstandardized).
    pub fn features(&self) -> Vec<[f32; 4]> {
        self.records.iter().map(|r| r.mode.features()).collect()
    }

    pub fn times_ms(&self) -> Vec<f64> {
        self.records.iter().map(|r| r.time_ms).collect()
    }

    pub fn powers_mw(&self) -> Vec<f64> {
        self.records.iter().map(|r| r.power_mw).collect()
    }

    /// Random subset of `n` records (sampling strategy for NN-small / PT).
    pub fn sample(&self, n: usize, rng: &mut Rng) -> Corpus {
        let idx = rng.sample_indices(self.len(), n.min(self.len()));
        Corpus {
            device: self.device,
            workload: self.workload,
            records: idx.into_iter().map(|i| self.records[i]).collect(),
        }
    }

    /// Deterministic uniformly-spaced subset of `n` records.
    pub fn uniform_subset(&self, n: usize) -> Corpus {
        let n = n.min(self.len());
        let mut records = Vec::with_capacity(n);
        if n > 0 {
            let step = self.len() as f64 / n as f64;
            for i in 0..n {
                records.push(self.records[(i as f64 * step) as usize]);
            }
        }
        Corpus { device: self.device, workload: self.workload, records }
    }

    /// 90:10 train/validation split (paper's protocol).
    ///
    /// For any `0 < train_frac < 1` on a corpus of at least 2 records,
    /// *both* splits are guaranteed non-empty: rounding alone would give
    /// e.g. `len=5, frac=0.9 → n_train=5` and an empty validation split,
    /// which made small transfer corpora silently validate on their own
    /// training data downstream.
    pub fn split(&self, train_frac: f64, rng: &mut Rng) -> (Corpus, Corpus) {
        assert!((0.0..=1.0).contains(&train_frac));
        let mut idx: Vec<usize> = (0..self.len()).collect();
        rng.shuffle(&mut idx);
        let mut n_train = ((self.len() as f64) * train_frac).round() as usize;
        if self.len() >= 2 {
            if train_frac < 1.0 {
                n_train = n_train.min(self.len() - 1);
            }
            if train_frac > 0.0 {
                n_train = n_train.max(1);
            }
        }
        let mk = |ids: &[usize]| Corpus {
            device: self.device,
            workload: self.workload,
            records: ids.iter().map(|&i| self.records[i]).collect(),
        };
        (mk(&idx[..n_train]), mk(&idx[n_train..]))
    }

    // ---- persistence -------------------------------------------------------

    pub fn to_table(&self) -> Table {
        let mut t = Table::new(&[
            "device", "workload", "cores", "cpu_khz", "gpu_khz", "mem_khz",
            "time_ms", "power_mw", "cost_s",
        ]);
        for r in &self.records {
            t.push_row(vec![
                self.device.name().to_string(),
                self.workload.name(),
                r.mode.cores.to_string(),
                r.mode.cpu_khz.to_string(),
                r.mode.gpu_khz.to_string(),
                r.mode.mem_khz.to_string(),
                format!("{:.4}", r.time_ms),
                format!("{:.1}", r.power_mw),
                format!("{:.3}", r.cost_s),
            ]);
        }
        t
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        self.to_table().save(path)
    }

    pub fn load(path: &Path) -> Result<Corpus> {
        let t = Table::load(path)?;
        Self::from_table(&t)
    }

    pub fn from_table(t: &Table) -> Result<Corpus> {
        if t.rows.is_empty() {
            return Err(Error::csv("empty corpus"));
        }
        let (c_device, c_workload) = (t.col("device")?, t.col("workload")?);
        let device = DeviceKind::parse(&t.rows[0][c_device])
            .ok_or_else(|| Error::csv("unknown device"))?;
        let workload = Workload::parse(&t.rows[0][c_workload])
            .ok_or_else(|| Error::csv("unknown workload"))?;
        let mut corpus = Corpus::new(device, workload);
        let (c_cores, c_cpu, c_gpu, c_mem) = (
            t.col("cores")?, t.col("cpu_khz")?, t.col("gpu_khz")?, t.col("mem_khz")?,
        );
        let (c_time, c_pow, c_cost) = (t.col("time_ms")?, t.col("power_mw")?, t.col("cost_s")?);
        for i in 0..t.rows.len() {
            // a corpus is one (device, workload) pair by construction;
            // a row disagreeing with the header means the file was
            // concatenated/edited and must not silently train a model
            // under the wrong identity
            if DeviceKind::parse(&t.rows[i][c_device]) != Some(device) {
                return Err(Error::csv(format!(
                    "corpus row {i}: device '{}' disagrees with header device '{}'",
                    t.rows[i][c_device],
                    device.name()
                )));
            }
            if Workload::parse(&t.rows[i][c_workload]) != Some(workload) {
                return Err(Error::csv(format!(
                    "corpus row {i}: workload '{}' disagrees with header workload '{}'",
                    t.rows[i][c_workload],
                    workload.name()
                )));
            }
            corpus.push(Record {
                mode: PowerMode {
                    cores: t.f64_at(i, c_cores)? as u32,
                    cpu_khz: t.f64_at(i, c_cpu)? as u32,
                    gpu_khz: t.f64_at(i, c_gpu)? as u32,
                    mem_khz: t.f64_at(i, c_mem)? as u32,
                },
                time_ms: t.f64_at(i, c_time)?,
                power_mw: t.f64_at(i, c_pow)?,
                cost_s: t.f64_at(i, c_cost)?,
            });
        }
        Ok(corpus)
    }
}

/// Bounded rolling observation store: the feedback lane's per-model
/// ground-truth corpus.
///
/// Serving-time observations arrive as an unbounded stream; a refit
/// wants (a) *what the workload does now* — so the newest
/// `recent` records are always kept verbatim — and (b) enough history to
/// not collapse onto the last few modes — so records aging out of the
/// recency window are offered to a uniform reservoir sample (capacity
/// `cap − recent`, classic algorithm R over the evicted stream). Memory
/// is therefore O(`cap`) regardless of stream length, deterministically
/// per seed.
///
/// Cost accounting: [`RollingCorpus::total_cost_s`] is **recomputed from
/// the resident records** on every call. An incrementally-decremented
/// running total drifts under eviction (subtract the wrong record once
/// and the error is permanent); recomputing over ≤ `cap` records is
/// cheap and self-healing, and the invariant `total_cost_s() ==
/// snapshot().total_cost_s()` is a tested property.
#[derive(Debug, Clone)]
pub struct RollingCorpus {
    device: DeviceKind,
    workload: Workload,
    recent: VecDeque<Record>,
    reservoir: Vec<Record>,
    recent_cap: usize,
    reservoir_cap: usize,
    /// Records ever offered to the reservoir (drives acceptance odds).
    evicted: u64,
    rng: Rng,
}

impl RollingCorpus {
    /// `cap` bounds the whole store; the newest `recent` records are kept
    /// exactly (clamped into `1..=cap`), the rest of the capacity holds
    /// the reservoir over older history.
    pub fn new(
        device: DeviceKind,
        workload: Workload,
        cap: usize,
        recent: usize,
        seed: u64,
    ) -> RollingCorpus {
        let cap = cap.max(1);
        let recent_cap = recent.clamp(1, cap);
        RollingCorpus {
            device,
            workload,
            recent: VecDeque::with_capacity(recent_cap + 1),
            reservoir: Vec::new(),
            recent_cap,
            reservoir_cap: cap - recent_cap,
            evicted: 0,
            rng: Rng::new(seed ^ 0x726f_6c6c), // "roll"
        }
    }

    pub fn device(&self) -> DeviceKind {
        self.device
    }

    pub fn workload(&self) -> Workload {
        self.workload
    }

    /// Resident records (recency window + reservoir).
    pub fn len(&self) -> usize {
        self.recent.len() + self.reservoir.len()
    }

    pub fn is_empty(&self) -> bool {
        self.recent.is_empty() && self.reservoir.is_empty()
    }

    /// Observations ever pushed (resident or not).
    pub fn seen(&self) -> u64 {
        self.evicted + self.recent.len() as u64
    }

    /// Record one observation. The newest `recent` records are always
    /// resident; the one aging out is offered to the reservoir.
    pub fn push(&mut self, r: Record) {
        self.recent.push_back(r);
        if self.recent.len() > self.recent_cap {
            let old = self.recent.pop_front().expect("recency window is non-empty");
            self.offer_to_reservoir(old);
        }
    }

    fn offer_to_reservoir(&mut self, r: Record) {
        self.evicted += 1;
        if self.reservoir_cap == 0 {
            return;
        }
        if self.reservoir.len() < self.reservoir_cap {
            self.reservoir.push(r);
            return;
        }
        // algorithm R: the i-th evicted record replaces a uniformly
        // random slot with probability cap/i, keeping the reservoir a
        // uniform sample of the whole evicted stream
        let j = self.rng.below(self.evicted as usize);
        if j < self.reservoir_cap {
            self.reservoir[j] = r;
        }
    }

    /// Materialize the resident window as a trainable [`Corpus`]
    /// (reservoir history first, then the recency window oldest→newest).
    pub fn snapshot(&self) -> Corpus {
        let mut c = Corpus::new(self.device, self.workload);
        for r in &self.reservoir {
            c.push(*r);
        }
        for r in &self.recent {
            c.push(*r);
        }
        c
    }

    /// Total profiling cost of the *resident* records, recomputed (see
    /// the type docs for why this is never an incrementally-updated
    /// counter).
    pub fn total_cost_s(&self) -> f64 {
        self.reservoir
            .iter()
            .chain(self.recent.iter())
            .map(|r| r.cost_s)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo_corpus(n: usize) -> Corpus {
        let mut c = Corpus::new(DeviceKind::OrinAgx, Workload::resnet());
        let spec = DeviceKind::OrinAgx.spec();
        for i in 0..n {
            c.push(Record {
                mode: PowerMode {
                    cores: 1 + (i % 12) as u32,
                    cpu_khz: spec.cpu_khz[i % spec.cpu_khz.len()],
                    gpu_khz: spec.gpu_khz[i % spec.gpu_khz.len()],
                    mem_khz: spec.mem_khz[i % spec.mem_khz.len()],
                },
                time_ms: 50.0 + i as f64,
                power_mw: 20_000.0 + 100.0 * i as f64,
                cost_s: 3.0,
            });
        }
        c
    }

    #[test]
    fn csv_round_trip() {
        let c = demo_corpus(20);
        let dir = std::env::temp_dir().join("pt_corpus_test");
        let path = dir.join("resnet.csv");
        c.save(&path).unwrap();
        let back = Corpus::load(&path).unwrap();
        assert_eq!(back.len(), 20);
        assert_eq!(back.device, c.device);
        assert_eq!(back.workload, c.workload);
        for (a, b) in back.records().iter().zip(c.records()) {
            assert_eq!(a.mode, b.mode);
            assert!((a.time_ms - b.time_ms).abs() < 1e-3);
            assert!((a.power_mw - b.power_mw).abs() < 0.5);
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn split_is_disjoint_and_complete() {
        let c = demo_corpus(100);
        let mut rng = Rng::new(3);
        let (train, val) = c.split(0.9, &mut rng);
        assert_eq!(train.len(), 90);
        assert_eq!(val.len(), 10);
        // disjoint by power mode (all modes unique in demo)
        for r in val.records() {
            assert!(!train.records().iter().any(|t| t.mode == r.mode));
        }
    }

    #[test]
    fn split_never_leaves_a_side_empty_on_small_corpora() {
        // regression: len=5 × 0.9 used to round to n_train=5 (empty val);
        // the ~50-mode transfer corpora this pipeline trains on live in
        // exactly this regime
        for n in 2..=12 {
            for &frac in &[0.1, 0.5, 0.9, 0.95] {
                let c = demo_corpus(n);
                let mut rng = Rng::new(n as u64);
                let (train, val) = c.split(frac, &mut rng);
                assert!(!train.is_empty(), "empty train at n={n} frac={frac}");
                assert!(!val.is_empty(), "empty val at n={n} frac={frac}");
                assert_eq!(train.len() + val.len(), n, "n={n} frac={frac}");
            }
        }
        // the motivating case, exactly
        let c = demo_corpus(5);
        let mut rng = Rng::new(3);
        let (train, val) = c.split(0.9, &mut rng);
        assert_eq!((train.len(), val.len()), (4, 1));
    }

    #[test]
    fn split_extremes_keep_whole_corpus_on_one_side() {
        let c = demo_corpus(10);
        let mut rng = Rng::new(1);
        let (train, val) = c.split(1.0, &mut rng);
        assert_eq!((train.len(), val.len()), (10, 0));
        let (train, val) = c.split(0.0, &mut rng);
        assert_eq!((train.len(), val.len()), (0, 10));
    }

    #[test]
    fn sample_without_replacement() {
        let c = demo_corpus(50);
        let mut rng = Rng::new(7);
        let s = c.sample(20, &mut rng);
        assert_eq!(s.len(), 20);
        let mut modes: Vec<_> = s.records().iter().map(|r| r.mode).collect();
        modes.sort_by_key(|m| (m.cores, m.cpu_khz, m.gpu_khz, m.mem_khz));
        modes.dedup();
        assert_eq!(modes.len(), 20);
    }

    #[test]
    fn uniform_subset_spans_range() {
        let c = demo_corpus(100);
        let s = c.uniform_subset(10);
        assert_eq!(s.len(), 10);
        assert_eq!(s.records()[0].time_ms, 50.0);
        assert!(s.records()[9].time_ms >= 135.0);
    }

    #[test]
    fn oversized_requests_clamp() {
        let c = demo_corpus(5);
        let mut rng = Rng::new(9);
        assert_eq!(c.sample(100, &mut rng).len(), 5);
        assert_eq!(c.uniform_subset(100).len(), 5);
    }

    #[test]
    fn cost_accumulates() {
        let c = demo_corpus(10);
        assert!((c.total_cost_s() - 30.0).abs() < 1e-9);
    }

    #[test]
    fn from_table_rejects_rows_disagreeing_with_header_device() {
        // regression: a concatenated/edited CSV whose later rows carry a
        // different device used to load silently under the header's
        // identity — the model then trained on another device's telemetry
        let c = demo_corpus(6);
        let mut t = c.to_table();
        let c_dev = t.col("device").unwrap();
        t.rows[3][c_dev] = "xavier".into();
        let err = Corpus::from_table(&t).unwrap_err();
        assert!(
            err.to_string().contains("row 3") && err.to_string().contains("device"),
            "{err}"
        );

        let mut t = c.to_table();
        let c_wl = t.col("workload").unwrap();
        t.rows[5][c_wl] = "bert/glue".into();
        let err = Corpus::from_table(&t).unwrap_err();
        assert!(err.to_string().contains("row 5"), "{err}");

        // an untampered table still round-trips
        let back = Corpus::from_table(&c.to_table()).unwrap();
        assert_eq!(back.device, c.device);
        assert_eq!(back.workload, c.workload);
        assert_eq!(back.len(), c.len());
    }

    fn obs(i: usize) -> Record {
        let spec = DeviceKind::OrinAgx.spec();
        Record {
            mode: PowerMode {
                cores: 1 + (i % 12) as u32,
                cpu_khz: spec.cpu_khz[i % spec.cpu_khz.len()],
                gpu_khz: spec.gpu_khz[i % spec.gpu_khz.len()],
                mem_khz: spec.mem_khz[i % spec.mem_khz.len()],
            },
            time_ms: 100.0 + i as f64,
            power_mw: 20_000.0,
            cost_s: 0.5 + (i % 7) as f64,
        }
    }

    #[test]
    fn rolling_corpus_stays_bounded_and_keeps_the_recency_window() {
        let mut rc =
            RollingCorpus::new(DeviceKind::OrinAgx, Workload::resnet(), 16, 8, 42);
        for i in 0..500 {
            rc.push(obs(i));
        }
        assert!(rc.len() <= 16, "{} resident", rc.len());
        assert_eq!(rc.seen(), 500);
        let snap = rc.snapshot();
        assert_eq!(snap.len(), rc.len());
        // the newest 8 observations are resident verbatim, newest last
        let tail: Vec<f64> = snap.records()[snap.len() - 8..]
            .iter()
            .map(|r| r.time_ms)
            .collect();
        let want: Vec<f64> = (492..500).map(|i| 100.0 + i as f64).collect();
        assert_eq!(tail, want);
        // the reservoir holds *older* history, not duplicates of the tail
        for r in &snap.records()[..snap.len() - 8] {
            assert!(r.time_ms < 100.0 + 492.0);
        }
    }

    #[test]
    fn rolling_corpus_cost_is_recomputed_not_drifted() {
        // regression guard for the satellite bug: eviction must not be
        // paired with an incremental cost decrement that can drift — the
        // resident total always equals the sum over the resident records
        let mut rc =
            RollingCorpus::new(DeviceKind::OrinAgx, Workload::resnet(), 12, 4, 7);
        for i in 0..300 {
            rc.push(obs(i));
            let direct: f64 = rc.snapshot().records().iter().map(|r| r.cost_s).sum();
            assert!(
                (rc.total_cost_s() - direct).abs() < 1e-9,
                "cost drifted at push {i}: {} vs {direct}",
                rc.total_cost_s()
            );
        }
    }

    #[test]
    fn rolling_corpus_is_deterministic_per_seed() {
        let run = |seed: u64| {
            let mut rc =
                RollingCorpus::new(DeviceKind::OrinAgx, Workload::resnet(), 10, 4, seed);
            for i in 0..200 {
                rc.push(obs(i));
            }
            rc.snapshot()
                .records()
                .iter()
                .map(|r| r.time_ms)
                .collect::<Vec<_>>()
        };
        assert_eq!(run(3), run(3));
        assert_ne!(run(3), run(4), "different seeds should sample differently");
    }

    #[test]
    fn rolling_corpus_degenerate_capacities_clamp() {
        // cap 0 → 1-record recency window, no reservoir; recent > cap →
        // recency clamped to cap
        let mut rc = RollingCorpus::new(DeviceKind::OrinAgx, Workload::resnet(), 0, 0, 1);
        for i in 0..10 {
            rc.push(obs(i));
        }
        assert_eq!(rc.len(), 1);
        assert_eq!(rc.snapshot().records()[0].time_ms, 109.0);
        let mut rc = RollingCorpus::new(DeviceKind::OrinAgx, Workload::resnet(), 4, 99, 1);
        for i in 0..10 {
            rc.push(obs(i));
        }
        assert_eq!(rc.len(), 4);
    }
}
