//! Profiling corpus: the (power mode -> time, power) dataset the prediction
//! models train and validate on, with CSV persistence and the sampling
//! strategies the paper uses (all / uniform-N / random-N, 90:10 splits).

use std::path::Path;

use crate::device::{DeviceKind, PowerMode};
use crate::error::{Error, Result};
use crate::util::csv::Table;
use crate::util::rng::Rng;
use crate::workload::Workload;

/// One profiled power mode.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Record {
    pub mode: PowerMode,
    /// Mean clean minibatch time (ms).
    pub time_ms: f64,
    /// Mean stabilized power (mW).
    pub power_mw: f64,
    /// Profiling wall-clock cost (s).
    pub cost_s: f64,
}

/// A profiling corpus for one (device, workload) pair.
#[derive(Debug, Clone)]
pub struct Corpus {
    pub device: DeviceKind,
    pub workload: Workload,
    records: Vec<Record>,
}

impl Corpus {
    pub fn new(device: DeviceKind, workload: Workload) -> Corpus {
        Corpus { device, workload, records: Vec::new() }
    }

    pub fn push(&mut self, r: Record) {
        self.records.push(r);
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    pub fn records(&self) -> &[Record] {
        &self.records
    }

    /// Total profiling wall-clock cost (the overhead axis of Figs 7/8).
    pub fn total_cost_s(&self) -> f64 {
        self.records.iter().map(|r| r.cost_s).sum()
    }

    /// Feature matrix (raw, unstandardized).
    pub fn features(&self) -> Vec<[f32; 4]> {
        self.records.iter().map(|r| r.mode.features()).collect()
    }

    pub fn times_ms(&self) -> Vec<f64> {
        self.records.iter().map(|r| r.time_ms).collect()
    }

    pub fn powers_mw(&self) -> Vec<f64> {
        self.records.iter().map(|r| r.power_mw).collect()
    }

    /// Random subset of `n` records (sampling strategy for NN-small / PT).
    pub fn sample(&self, n: usize, rng: &mut Rng) -> Corpus {
        let idx = rng.sample_indices(self.len(), n.min(self.len()));
        Corpus {
            device: self.device,
            workload: self.workload,
            records: idx.into_iter().map(|i| self.records[i]).collect(),
        }
    }

    /// Deterministic uniformly-spaced subset of `n` records.
    pub fn uniform_subset(&self, n: usize) -> Corpus {
        let n = n.min(self.len());
        let mut records = Vec::with_capacity(n);
        if n > 0 {
            let step = self.len() as f64 / n as f64;
            for i in 0..n {
                records.push(self.records[(i as f64 * step) as usize]);
            }
        }
        Corpus { device: self.device, workload: self.workload, records }
    }

    /// 90:10 train/validation split (paper's protocol).
    ///
    /// For any `0 < train_frac < 1` on a corpus of at least 2 records,
    /// *both* splits are guaranteed non-empty: rounding alone would give
    /// e.g. `len=5, frac=0.9 → n_train=5` and an empty validation split,
    /// which made small transfer corpora silently validate on their own
    /// training data downstream.
    pub fn split(&self, train_frac: f64, rng: &mut Rng) -> (Corpus, Corpus) {
        assert!((0.0..=1.0).contains(&train_frac));
        let mut idx: Vec<usize> = (0..self.len()).collect();
        rng.shuffle(&mut idx);
        let mut n_train = ((self.len() as f64) * train_frac).round() as usize;
        if self.len() >= 2 {
            if train_frac < 1.0 {
                n_train = n_train.min(self.len() - 1);
            }
            if train_frac > 0.0 {
                n_train = n_train.max(1);
            }
        }
        let mk = |ids: &[usize]| Corpus {
            device: self.device,
            workload: self.workload,
            records: ids.iter().map(|&i| self.records[i]).collect(),
        };
        (mk(&idx[..n_train]), mk(&idx[n_train..]))
    }

    // ---- persistence -------------------------------------------------------

    pub fn to_table(&self) -> Table {
        let mut t = Table::new(&[
            "device", "workload", "cores", "cpu_khz", "gpu_khz", "mem_khz",
            "time_ms", "power_mw", "cost_s",
        ]);
        for r in &self.records {
            t.push_row(vec![
                self.device.name().to_string(),
                self.workload.name(),
                r.mode.cores.to_string(),
                r.mode.cpu_khz.to_string(),
                r.mode.gpu_khz.to_string(),
                r.mode.mem_khz.to_string(),
                format!("{:.4}", r.time_ms),
                format!("{:.1}", r.power_mw),
                format!("{:.3}", r.cost_s),
            ]);
        }
        t
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        self.to_table().save(path)
    }

    pub fn load(path: &Path) -> Result<Corpus> {
        let t = Table::load(path)?;
        Self::from_table(&t)
    }

    pub fn from_table(t: &Table) -> Result<Corpus> {
        if t.rows.is_empty() {
            return Err(Error::csv("empty corpus"));
        }
        let device = DeviceKind::parse(&t.rows[0][t.col("device")?])
            .ok_or_else(|| Error::csv("unknown device"))?;
        let workload = Workload::parse(&t.rows[0][t.col("workload")?])
            .ok_or_else(|| Error::csv("unknown workload"))?;
        let mut corpus = Corpus::new(device, workload);
        let (c_cores, c_cpu, c_gpu, c_mem) = (
            t.col("cores")?, t.col("cpu_khz")?, t.col("gpu_khz")?, t.col("mem_khz")?,
        );
        let (c_time, c_pow, c_cost) = (t.col("time_ms")?, t.col("power_mw")?, t.col("cost_s")?);
        for i in 0..t.rows.len() {
            corpus.push(Record {
                mode: PowerMode {
                    cores: t.f64_at(i, c_cores)? as u32,
                    cpu_khz: t.f64_at(i, c_cpu)? as u32,
                    gpu_khz: t.f64_at(i, c_gpu)? as u32,
                    mem_khz: t.f64_at(i, c_mem)? as u32,
                },
                time_ms: t.f64_at(i, c_time)?,
                power_mw: t.f64_at(i, c_pow)?,
                cost_s: t.f64_at(i, c_cost)?,
            });
        }
        Ok(corpus)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo_corpus(n: usize) -> Corpus {
        let mut c = Corpus::new(DeviceKind::OrinAgx, Workload::resnet());
        let spec = DeviceKind::OrinAgx.spec();
        for i in 0..n {
            c.push(Record {
                mode: PowerMode {
                    cores: 1 + (i % 12) as u32,
                    cpu_khz: spec.cpu_khz[i % spec.cpu_khz.len()],
                    gpu_khz: spec.gpu_khz[i % spec.gpu_khz.len()],
                    mem_khz: spec.mem_khz[i % spec.mem_khz.len()],
                },
                time_ms: 50.0 + i as f64,
                power_mw: 20_000.0 + 100.0 * i as f64,
                cost_s: 3.0,
            });
        }
        c
    }

    #[test]
    fn csv_round_trip() {
        let c = demo_corpus(20);
        let dir = std::env::temp_dir().join("pt_corpus_test");
        let path = dir.join("resnet.csv");
        c.save(&path).unwrap();
        let back = Corpus::load(&path).unwrap();
        assert_eq!(back.len(), 20);
        assert_eq!(back.device, c.device);
        assert_eq!(back.workload, c.workload);
        for (a, b) in back.records().iter().zip(c.records()) {
            assert_eq!(a.mode, b.mode);
            assert!((a.time_ms - b.time_ms).abs() < 1e-3);
            assert!((a.power_mw - b.power_mw).abs() < 0.5);
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn split_is_disjoint_and_complete() {
        let c = demo_corpus(100);
        let mut rng = Rng::new(3);
        let (train, val) = c.split(0.9, &mut rng);
        assert_eq!(train.len(), 90);
        assert_eq!(val.len(), 10);
        // disjoint by power mode (all modes unique in demo)
        for r in val.records() {
            assert!(!train.records().iter().any(|t| t.mode == r.mode));
        }
    }

    #[test]
    fn split_never_leaves_a_side_empty_on_small_corpora() {
        // regression: len=5 × 0.9 used to round to n_train=5 (empty val);
        // the ~50-mode transfer corpora this pipeline trains on live in
        // exactly this regime
        for n in 2..=12 {
            for &frac in &[0.1, 0.5, 0.9, 0.95] {
                let c = demo_corpus(n);
                let mut rng = Rng::new(n as u64);
                let (train, val) = c.split(frac, &mut rng);
                assert!(!train.is_empty(), "empty train at n={n} frac={frac}");
                assert!(!val.is_empty(), "empty val at n={n} frac={frac}");
                assert_eq!(train.len() + val.len(), n, "n={n} frac={frac}");
            }
        }
        // the motivating case, exactly
        let c = demo_corpus(5);
        let mut rng = Rng::new(3);
        let (train, val) = c.split(0.9, &mut rng);
        assert_eq!((train.len(), val.len()), (4, 1));
    }

    #[test]
    fn split_extremes_keep_whole_corpus_on_one_side() {
        let c = demo_corpus(10);
        let mut rng = Rng::new(1);
        let (train, val) = c.split(1.0, &mut rng);
        assert_eq!((train.len(), val.len()), (10, 0));
        let (train, val) = c.split(0.0, &mut rng);
        assert_eq!((train.len(), val.len()), (0, 10));
    }

    #[test]
    fn sample_without_replacement() {
        let c = demo_corpus(50);
        let mut rng = Rng::new(7);
        let s = c.sample(20, &mut rng);
        assert_eq!(s.len(), 20);
        let mut modes: Vec<_> = s.records().iter().map(|r| r.mode).collect();
        modes.sort_by_key(|m| (m.cores, m.cpu_khz, m.gpu_khz, m.mem_khz));
        modes.dedup();
        assert_eq!(modes.len(), 20);
    }

    #[test]
    fn uniform_subset_spans_range() {
        let c = demo_corpus(100);
        let s = c.uniform_subset(10);
        assert_eq!(s.len(), 10);
        assert_eq!(s.records()[0].time_ms, 50.0);
        assert!(s.records()[9].time_ms >= 135.0);
    }

    #[test]
    fn oversized_requests_clamp() {
        let c = demo_corpus(5);
        let mut rng = Rng::new(9);
        assert_eq!(c.sample(100, &mut rng).len(), 5);
        assert_eq!(c.uniform_subset(100).len(), 5);
    }

    #[test]
    fn cost_accumulates() {
        let c = demo_corpus(10);
        assert!((c.total_cost_s() - 30.0).abs() < 1e-9);
    }
}
