//! # PowerTrain
//!
//! Production reproduction of *"PowerTrain: Fast, Generalizable Time and
//! Power Prediction Models to Optimize DNN Training on Accelerated Edges"*
//! (Prashanthi S.K. et al., FGCS 2024).
//!
//! The library is the Layer-3 rust coordinator of a three-layer stack:
//!
//! * **L1** — Pallas kernels (fused prediction-MLP forward/backward, fused
//!   Adam) authored in `python/compile/kernels/`, lowered once at build time.
//! * **L2** — the JAX model graph (`python/compile/model.py`) exported as
//!   HLO-text artifacts (`make artifacts`).
//! * **L3** — this crate: Jetson device models, the hardware simulator that
//!   substitutes for physical Orin/Xavier/Nano devkits, the profiling
//!   pipeline, the training/transfer/prediction drivers executing the AOT
//!   artifacts via PJRT, the Pareto optimizer, all paper baselines, the
//!   workload-arrival coordinator, and the experiment harness regenerating
//!   every table and figure of the paper.
//!
//! Python never runs on the request path: after `make artifacts` the
//! `powertrain` binary is self-contained.
//!
//! See `ARCHITECTURE.md` for the top-down subsystem map and the life of
//! one request, and `DESIGN.md` for the system inventory and the
//! per-experiment index.

pub mod baselines;
pub mod coordinator;
pub mod device;
pub mod error;
#[cfg(feature = "xla")]
pub mod experiments;
pub mod fleet;
pub mod loadgen;
pub mod nn;
pub mod pareto;
pub mod predict;
pub mod profiler;
pub mod runtime;
pub mod sim;
pub mod train;
pub mod util;
pub mod workload;

pub use error::{Error, Result};
