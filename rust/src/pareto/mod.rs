//! Time–power Pareto fronts and the power-budget optimization (paper
//! section 5).
//!
//! Given (predicted or observed) time and power for a set of power modes,
//! build the minimization Pareto front and answer the paper's optimization
//! query: *the mode minimizing epoch training time subject to
//! `power <= budget`*. Also computes the evaluation metrics of Figs 12–13:
//! time-penalty %, excess-power AUC, A/L and A/L+1.

use crate::device::PowerMode;
use crate::error::{Error, Result};

/// One candidate: a power mode with its (time, power) coordinates.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Point {
    pub mode: PowerMode,
    /// Training time (per minibatch ms or per epoch s — any consistent unit).
    pub time: f64,
    /// Power draw (mW).
    pub power_mw: f64,
}

/// A minimization Pareto front over (time, power), sorted by power
/// ascending (and therefore time strictly descending).
#[derive(Debug, Clone)]
pub struct ParetoFront {
    points: Vec<Point>,
}

impl ParetoFront {
    /// Build the front from arbitrary candidates.
    ///
    /// Allocation-lean: sorts a `u32` index permutation instead of cloning
    /// the full `Point` cloud (a `Point` is 32 bytes, so the sort moves
    /// 8× less memory). Candidates with
    /// non-finite time or power (NaN predictions from a diverged
    /// checkpoint, ±inf) are excluded up front; ordering uses
    /// `f64::total_cmp`, so the build can never panic.
    pub fn build(candidates: &[Point]) -> ParetoFront {
        debug_assert!(candidates.len() <= u32::MAX as usize);
        let mut idx: Vec<u32> = (0..candidates.len() as u32)
            .filter(|&i| {
                let p = &candidates[i as usize];
                p.time.is_finite() && p.power_mw.is_finite()
            })
            .collect();
        // sort by power asc, tie-break time asc
        idx.sort_unstable_by(|&a, &b| {
            let (pa, pb) = (&candidates[a as usize], &candidates[b as usize]);
            pa.power_mw
                .total_cmp(&pb.power_mw)
                .then(pa.time.total_cmp(&pb.time))
        });
        let mut front: Vec<Point> = Vec::new();
        let mut best_time = f64::INFINITY;
        for &i in &idx {
            let p = candidates[i as usize];
            if p.time < best_time {
                front.push(p);
                best_time = p.time;
            }
        }
        ParetoFront { points: front }
    }

    pub fn points(&self) -> &[Point] {
        &self.points
    }

    pub fn len(&self) -> usize {
        self.points.len()
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    /// The paper's optimization: the Pareto point with the largest power
    /// that is still within `budget_mw` (that point has the minimum time
    /// among feasible modes).
    ///
    /// O(log n): the front is sorted by power ascending and free of
    /// non-finite coordinates (both build invariants), so the feasible
    /// prefix `{p : power ≤ budget}` ends at a partition point and its
    /// last element is the answer. This is the entire steady-state cost
    /// of a budget-only request served from the coordinator's cached
    /// front. A NaN budget partitions at 0 and errors, like the seed's
    /// linear scan.
    pub fn optimize(&self, budget_mw: f64) -> Result<Point> {
        match self.optimize_idx(budget_mw) {
            Some(idx) => Ok(self.points[idx]),
            None => Err(Error::Optimization(format!(
                "no power mode fits within {:.1} W",
                budget_mw / 1000.0
            ))),
        }
    }

    /// Allocation-free form of [`optimize`](Self::optimize): the index of
    /// the winning front point, or `None` if no mode fits the budget.
    ///
    /// Budget sweeps (the coordinator's cache-hit path, Figs 12–13
    /// evaluation loops) call this in a tight loop; returning an index
    /// into the immutable front keeps the per-budget cost at one
    /// `partition_point` — no `Point` copy, and crucially no error
    /// `String` allocation on the infeasible branch.
    #[inline]
    pub fn optimize_idx(&self, budget_mw: f64) -> Option<usize> {
        let idx = self.points.partition_point(|p| p.power_mw <= budget_mw);
        idx.checked_sub(1)
    }

    /// True if no point in the front dominates another (invariant check).
    pub fn is_valid(&self) -> bool {
        self.points.windows(2).all(|w| {
            w[0].power_mw <= w[1].power_mw && w[0].time > w[1].time
        })
    }
}

/// Evaluation of one optimization strategy over a budget sweep, against
/// ground truth (Figs 12–13).
#[derive(Debug, Clone, Default)]
pub struct SweepMetrics {
    /// Excess training time vs the optimal mode, % per solved budget.
    pub time_penalty_pct: Vec<f64>,
    /// Observed power minus budget, clamped at 0, W per solved budget.
    pub excess_power_w: Vec<f64>,
    /// Count of budgets where observed power exceeded the budget.
    pub over_budget: usize,
    /// Count where it exceeded budget + 1 W.
    pub over_budget_1w: usize,
    /// Budgets with no feasible solution under the strategy.
    pub infeasible: usize,
    pub solved: usize,
}

impl SweepMetrics {
    /// Normalized excess-power area under the curve (W per solution) —
    /// the "Area" metric of Fig 13.
    pub fn area_w(&self) -> f64 {
        if self.solved == 0 {
            return 0.0;
        }
        self.excess_power_w.iter().sum::<f64>() / self.solved as f64
    }

    /// % of solutions exceeding the power limit (A/L in Fig 13).
    pub fn over_pct(&self) -> f64 {
        if self.solved == 0 {
            return 0.0;
        }
        100.0 * self.over_budget as f64 / self.solved as f64
    }

    /// % exceeding the limit by more than 1 W (A/L+1 in Fig 13).
    pub fn over1_pct(&self) -> f64 {
        if self.solved == 0 {
            return 0.0;
        }
        100.0 * self.over_budget_1w as f64 / self.solved as f64
    }

    /// Record one budget's outcome.
    pub fn record(
        &mut self,
        budget_mw: f64,
        observed: Point,
        optimal: Point,
    ) {
        self.solved += 1;
        self.time_penalty_pct
            .push(100.0 * (observed.time - optimal.time) / optimal.time);
        let excess = (observed.power_mw - budget_mw).max(0.0) / 1000.0;
        self.excess_power_w.push(excess);
        if observed.power_mw > budget_mw {
            self.over_budget += 1;
        }
        if observed.power_mw > budget_mw + 1000.0 {
            self.over_budget_1w += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::{DeviceKind, PowerMode};
    use crate::util::rng::Rng;

    fn pm() -> PowerMode {
        PowerMode::maxn(DeviceKind::OrinAgx.spec())
    }

    fn pt(time: f64, power_w: f64) -> Point {
        Point { mode: pm(), time, power_mw: power_w * 1000.0 }
    }

    #[test]
    fn front_excludes_dominated_points() {
        let pts = vec![
            pt(100.0, 10.0),
            pt(80.0, 20.0),
            pt(90.0, 25.0),  // dominated by (80, 20)
            pt(60.0, 30.0),
            pt(70.0, 35.0),  // dominated by (60, 30)
        ];
        let f = ParetoFront::build(&pts);
        assert_eq!(f.len(), 3);
        assert!(f.is_valid());
        let times: Vec<f64> = f.points().iter().map(|p| p.time).collect();
        assert_eq!(times, vec![100.0, 80.0, 60.0]);
    }

    #[test]
    fn optimize_picks_fastest_within_budget() {
        let f = ParetoFront::build(&[pt(100.0, 10.0), pt(80.0, 20.0), pt(60.0, 30.0)]);
        assert_eq!(f.optimize(25_000.0).unwrap().time, 80.0);
        assert_eq!(f.optimize(30_000.0).unwrap().time, 60.0);
        assert_eq!(f.optimize(1_000_000.0).unwrap().time, 60.0);
        assert!(f.optimize(5_000.0).is_err());
    }

    #[test]
    fn duplicate_and_tied_points_handled() {
        let pts = vec![pt(50.0, 10.0), pt(50.0, 10.0), pt(50.0, 12.0)];
        let f = ParetoFront::build(&pts);
        assert_eq!(f.len(), 1);
        assert_eq!(f.points()[0].power_mw, 10_000.0);
    }

    #[test]
    fn binary_search_optimize_matches_linear_scan() {
        // the O(log n) partition_point query must be indistinguishable
        // from the seed's linear reverse scan for every budget, including
        // exact boundaries and out-of-range budgets
        let mut rng = Rng::new(77);
        for _ in 0..50 {
            let pts: Vec<Point> = (0..200)
                .map(|_| pt(rng.uniform_range(10.0, 500.0), rng.uniform_range(8.0, 60.0)))
                .collect();
            let f = ParetoFront::build(&pts);
            let mut budgets: Vec<f64> = (0..40)
                .map(|_| rng.uniform_range(0.0, 70.0) * 1000.0)
                .collect();
            // exact front powers are the boundary cases
            budgets.extend(f.points().iter().map(|p| p.power_mw));
            budgets.push(f64::NAN);
            for &b in &budgets {
                let linear = f.points.iter().rev().find(|p| p.power_mw <= b).copied();
                match (f.optimize(b), linear) {
                    (Ok(got), Some(want)) => {
                        assert_eq!(got.power_mw, want.power_mw);
                        assert_eq!(got.time, want.time);
                    }
                    (Err(_), None) => {}
                    (got, want) => panic!("budget {b}: {got:?} vs linear {want:?}"),
                }
            }
        }
    }

    #[test]
    fn optimize_idx_agrees_with_optimize_everywhere() {
        // the allocation-free index query and the Point-returning wrapper
        // must agree for every budget, including boundaries and NaN
        let mut rng = Rng::new(41);
        let pts: Vec<Point> = (0..300)
            .map(|_| pt(rng.uniform_range(10.0, 500.0), rng.uniform_range(8.0, 60.0)))
            .collect();
        let f = ParetoFront::build(&pts);
        let mut budgets: Vec<f64> =
            (0..60).map(|_| rng.uniform_range(0.0, 70.0) * 1000.0).collect();
        budgets.extend(f.points().iter().map(|p| p.power_mw));
        budgets.push(f64::NAN);
        budgets.push(0.0);
        for &b in &budgets {
            match (f.optimize_idx(b), f.optimize(b)) {
                (Some(i), Ok(p)) => assert_eq!(f.points()[i], p),
                (None, Err(_)) => {}
                (i, p) => panic!("budget {b}: idx {i:?} vs point {p:?}"),
            }
        }
    }

    #[test]
    fn front_from_random_cloud_is_valid_and_minimal() {
        let mut rng = Rng::new(8);
        let pts: Vec<Point> = (0..500)
            .map(|_| pt(rng.uniform_range(10.0, 500.0), rng.uniform_range(8.0, 60.0)))
            .collect();
        let f = ParetoFront::build(&pts);
        assert!(f.is_valid());
        // no candidate strictly dominates any front point
        for fp in f.points() {
            assert!(!pts.iter().any(|c| c.time < fp.time && c.power_mw < fp.power_mw));
        }
    }

    #[test]
    fn non_finite_candidates_are_excluded_not_fatal() {
        // NaN predictions from a diverged checkpoint must not crash the
        // coordinator: they are filtered, the finite points still form
        // a valid front
        let pts = vec![
            pt(f64::NAN, 10.0),
            pt(100.0, f64::NAN),
            pt(f64::INFINITY, 15.0),
            pt(90.0, f64::NEG_INFINITY),
            pt(80.0, 20.0),
            pt(60.0, 30.0),
        ];
        let f = ParetoFront::build(&pts);
        assert_eq!(f.len(), 2);
        assert!(f.is_valid());
        assert_eq!(f.optimize(25_000.0).unwrap().time, 80.0);
    }

    #[test]
    fn all_nan_cloud_gives_empty_front() {
        let pts = vec![pt(f64::NAN, f64::NAN); 8];
        let f = ParetoFront::build(&pts);
        assert!(f.is_empty());
        assert!(f.optimize(1e9).is_err());
    }

    #[test]
    fn sweep_metrics_accounting() {
        let mut m = SweepMetrics::default();
        let optimal = pt(100.0, 20.0);
        // on budget, on time
        m.record(20_000.0, pt(100.0, 20.0), optimal);
        // 10% slower, 0.5 W over
        m.record(20_000.0, pt(110.0, 20.5), optimal);
        // 2 W over
        m.record(20_000.0, pt(95.0, 22.0), optimal);
        assert_eq!(m.solved, 3);
        assert_eq!(m.over_budget, 2);
        assert_eq!(m.over_budget_1w, 1);
        assert!((m.over_pct() - 66.666).abs() < 0.01);
        assert!((m.area_w() - (0.5 + 2.0) / 3.0).abs() < 1e-9);
        assert!((m.time_penalty_pct[1] - 10.0).abs() < 1e-9);
        // MAXN-style: faster than optimal -> negative penalty
        assert!(m.time_penalty_pct[2] < 0.0);
    }
}
