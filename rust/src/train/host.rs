//! Host-native training driver: the default-build counterpart of the
//! AOT-artifact `Trainer`.
//!
//! [`HostTrainer::train_from`] mirrors `Trainer::train_from` exactly in
//! its *semantics* — same corpus split, scalers fit on the training
//! split only, same per-epoch shuffle, per-epoch validation with
//! best-checkpoint selection on standardized-space MSE, same checkpoint
//! provenance format — while the compute runs through the hand-rolled
//! backward pass (`nn::grad`) instead of the PJRT artifacts. One fit
//! allocates its working set (transposed params, gradients, Adam
//! moments, tape, batch buffers) once; the epoch loop is allocation-free.
//!
//! [`HostTrainer::train_schedule`] generalizes the loop to a sequence of
//! (epochs, first-trainable-layer) phases so transfer learning can
//! freeze the pretrained body while the fresh head warms up
//! (`train::transfer::transfer_host`), with best-checkpoint tracking and
//! Adam state continuous across phases. The same `train_from` entry also
//! backs `train::transfer::refit_host`, the model-lifecycle warm refresh:
//! a deployed checkpoint's weights re-enter the loop as the starting
//! point and fine-tune on a small serving-time feedback corpus at a
//! short epoch budget — nothing here distinguishes a refit from any
//! other warm start, which is exactly why refits inherit the
//! determinism, divergence-rejection and best-checkpoint guarantees
//! below.
//!
//! Deliberate differences vs the artifact path, documented rather than
//! hidden: no dropout (transfer corpora are ~50 rows; determinism per
//! seed is a tested invariant) and no padding mask (the host passes the
//! true batch length). Gradients are property-tested against central
//! finite differences in `tests/property_host_training.rs`.

use crate::error::{Error, Result};
use crate::nn::checkpoint::Checkpoint;
use crate::nn::grad::{self, HostAdam, HostLoss, Tape, TransposedMlp, ADAM_LR};
use crate::nn::MlpParams;
use crate::profiler::{Corpus, StandardScaler};
use crate::train::{scale_features, LossKind, Target, TrainConfig, TrainingLog};
use crate::util::rng::Rng;

/// Training batch size, matching the AOT train artifact's batch
/// (`manifest.train_batch`) so host and artifact fits see the same
/// step/epoch structure.
pub const HOST_TRAIN_BATCH: usize = 64;

/// Pure-rust training driver. Construction is free; all state lives on
/// the stack of a fit.
#[derive(Debug, Clone, Copy)]
pub struct HostTrainer {
    /// Rows per optimizer step.
    pub batch: usize,
    /// Adam learning rate (paper Table 4: 1e-3).
    pub lr: f64,
}

impl Default for HostTrainer {
    fn default() -> Self {
        HostTrainer { batch: HOST_TRAIN_BATCH, lr: ADAM_LR }
    }
}

impl HostTrainer {
    pub fn new() -> HostTrainer {
        HostTrainer::default()
    }

    /// Train a prediction model from scratch (the paper's NN approach),
    /// host-native.
    pub fn train(
        &self,
        corpus: &Corpus,
        target: Target,
        cfg: &TrainConfig,
    ) -> Result<(Checkpoint, TrainingLog)> {
        let mut rng = Rng::new(cfg.seed);
        let params = MlpParams::init_he(&mut rng);
        self.train_from(params, corpus, target, cfg, &mut rng, "nn-scratch-host")
    }

    /// Core loop, shared with host transfer learning (which passes
    /// pre-trained params and its own provenance tag). Single phase, all
    /// layers trainable — the host mirror of `Trainer::train_from`.
    pub fn train_from(
        &self,
        params: MlpParams,
        corpus: &Corpus,
        target: Target,
        cfg: &TrainConfig,
        rng: &mut Rng,
        provenance: &str,
    ) -> Result<(Checkpoint, TrainingLog)> {
        self.train_schedule(params, corpus, target, cfg, rng, provenance, &[(cfg.epochs, 0)])
    }

    /// Phased training: each `(epochs, first_layer)` entry runs that many
    /// epochs with layers `first_layer..4` trainable (0 = all, 3 = head
    /// only). Split, scalers, shuffle stream, Adam state and
    /// best-checkpoint tracking are continuous across phases.
    #[allow(clippy::too_many_arguments)]
    pub fn train_schedule(
        &self,
        params: MlpParams,
        corpus: &Corpus,
        target: Target,
        cfg: &TrainConfig,
        rng: &mut Rng,
        provenance: &str,
        phases: &[(usize, usize)],
    ) -> Result<(Checkpoint, TrainingLog)> {
        if corpus.len() < 2 {
            return Err(Error::Training("corpus too small to train on".into()));
        }
        if phases.iter().map(|p| p.0).sum::<usize>() == 0 {
            // never hand back an untrained (or surgery-damaged) checkpoint
            // with val_loss = ∞ as if the fit succeeded
            return Err(Error::Training("zero training epochs requested".into()));
        }
        let (train, val) = corpus.split(cfg.train_frac, rng);
        let val = if val.is_empty() { train.clone() } else { val };

        // scalers fit on the training split only (paper protocol)
        let feat_rows: Vec<Vec<f64>> = train
            .features()
            .iter()
            .map(|f| f.iter().map(|&x| x as f64).collect())
            .collect();
        let feature_scaler = StandardScaler::fit(&feat_rows);
        let target_scaler = StandardScaler::fit1(&target.values(&train));

        let xs_train = scale_features(&train, &feature_scaler);
        let ys_train_raw = target.values(&train);
        let xs_val = scale_features(&val, &feature_scaler);
        let ys_val_raw = target.values(&val);

        // the loss decides the target space the step sees, mirroring the
        // artifact drivers: MSE trains standardized, MAPE trains raw
        let host_loss = match cfg.loss {
            LossKind::Mse => HostLoss::Mse,
            LossKind::Mape => HostLoss::Mape {
                y_mean: target_scaler.mean[0],
                y_std: target_scaler.std[0],
            },
        };
        let ys_step: Vec<f32> = match cfg.loss {
            LossKind::Mse => ys_train_raw
                .iter()
                .map(|&y| target_scaler.transform1(y) as f32)
                .collect(),
            LossKind::Mape => ys_train_raw.iter().map(|&y| y as f32).collect(),
        };

        // the fit's whole working set, allocated once
        let mut net = TransposedMlp::from_params(&params);
        let mut grads = TransposedMlp::zeros();
        let mut adam = HostAdam::new(self.lr);
        let mut tape = Tape::new(self.batch);
        let mut xbuf = vec![0.0f32; self.batch * 4];
        let mut ybuf = vec![0.0f32; self.batch];
        let mut order: Vec<usize> = (0..xs_train.len()).collect();

        let mut log = TrainingLog {
            train_loss: Vec::new(),
            val_mse: Vec::new(),
            val_mape: Vec::new(),
            best_epoch: 0,
            steps: 0,
        };
        let mut best_mse = f64::INFINITY;
        let mut best_params = params;
        let mut global_epoch = 0usize;

        for &(phase_epochs, first_layer) in phases {
            for _ in 0..phase_epochs {
                rng.shuffle(&mut order);
                let mut epoch_loss = 0.0f64;
                let mut batches = 0.0f64;
                for chunk in order.chunks(self.batch) {
                    for (row, &i) in chunk.iter().enumerate() {
                        xbuf[row * 4..(row + 1) * 4].copy_from_slice(&xs_train[i]);
                        ybuf[row] = ys_step[i];
                    }
                    let n = chunk.len();
                    let loss = grad::loss_and_grad(
                        &net, &xbuf[..n * 4], &ybuf, n, host_loss, &mut tape, &mut grads,
                    );
                    adam.step(&mut net, &grads, first_layer);
                    epoch_loss += loss;
                    batches += 1.0;
                    log.steps += 1;
                }
                log.train_loss.push(epoch_loss / batches.max(1.0));

                // validation reuses the step's batch buffer — the whole
                // epoch loop performs zero heap allocations
                let (mse, mape) = evaluate_into(
                    &net, &xs_val, &ys_val_raw, &target_scaler, &mut tape, &mut xbuf,
                );
                log.val_mse.push(mse);
                log.val_mape.push(mape);
                if mse < best_mse {
                    best_mse = mse;
                    net.write_params(&mut best_params);
                    log.best_epoch = global_epoch;
                }
                global_epoch += 1;
            }
        }

        if !best_params.is_finite() {
            return Err(Error::Training("training diverged to non-finite params".into()));
        }

        Ok((
            Checkpoint {
                params: best_params,
                feature_scaler,
                target_scaler,
                target: target.name().to_string(),
                provenance: format!(
                    "{provenance}: {} on {} ({} modes)",
                    target.name(),
                    corpus.workload.name(),
                    corpus.len()
                ),
                val_loss: best_mse,
            },
            log,
        ))
    }
}

/// Host validation pass: (MSE in standardized space, MAPE % in raw
/// units) over a feature/target set, chunked at the tape's capacity.
/// Mirrors the artifact `evaluate`'s semantics (zero-truth rows are
/// skipped from the MAPE like `stats::mape`).
pub fn evaluate_host(
    net: &TransposedMlp,
    xs: &[[f32; 4]],
    ys_raw: &[f64],
    tscaler: &StandardScaler,
    tape: &mut Tape,
) -> (f64, f64) {
    let mut flat = vec![0.0f32; tape.cap() * 4];
    evaluate_into(net, xs, ys_raw, tscaler, tape, &mut flat)
}

/// [`evaluate_host`] with a caller-owned `[cap * 4]` row buffer — the
/// trainer's per-epoch entry, so validation allocates nothing.
fn evaluate_into(
    net: &TransposedMlp,
    xs: &[[f32; 4]],
    ys_raw: &[f64],
    tscaler: &StandardScaler,
    tape: &mut Tape,
    flat: &mut [f32],
) -> (f64, f64) {
    debug_assert_eq!(xs.len(), ys_raw.len());
    let cap = tape.cap();
    debug_assert!(flat.len() >= cap * 4);
    let mut tot_mse = 0.0f64;
    let mut tot_ape = 0.0f64;
    let mut n_mse = 0usize;
    let mut n_ape = 0usize;
    for chunk_start in (0..xs.len()).step_by(cap) {
        let n = cap.min(xs.len() - chunk_start);
        for row in 0..n {
            flat[row * 4..(row + 1) * 4].copy_from_slice(&xs[chunk_start + row]);
        }
        grad::forward(net, &flat[..n * 4], n, tape);
        for row in 0..n {
            let y = ys_raw[chunk_start + row];
            let e = tape.yhat[row] as f64 - tscaler.transform1(y);
            tot_mse += e * e;
            n_mse += 1;
            if y.abs() > 1e-9 {
                let pred_raw = tscaler.inverse1(tape.yhat[row] as f64);
                tot_ape += ((pred_raw - y) / y).abs();
                n_ape += 1;
            }
        }
    }
    (
        tot_mse / (n_mse.max(1) as f64),
        100.0 * tot_ape / (n_ape.max(1) as f64),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::{DeviceKind, PowerModeGrid};
    use crate::profiler::Record;
    use crate::sim::TrainerSim;
    use crate::workload::Workload;

    /// Noise-free ground-truth corpus, mirroring the integration suites.
    fn truth_corpus(wl: Workload, n: usize, seed: u64) -> Corpus {
        let spec = DeviceKind::OrinAgx.spec();
        let sim = TrainerSim::new(spec, wl, seed);
        let mut rng = Rng::new(seed ^ 0xc0ffee);
        let modes = PowerModeGrid::paper_subset(DeviceKind::OrinAgx).sample(n, &mut rng);
        let mut c = Corpus::new(DeviceKind::OrinAgx, wl);
        for pm in modes {
            c.push(Record {
                mode: pm,
                time_ms: sim.true_minibatch_ms(&pm),
                power_mw: sim.true_power_mw(&pm),
                cost_s: 0.0,
            });
        }
        c
    }

    #[test]
    fn rejects_degenerate_corpus() {
        let tiny = truth_corpus(Workload::resnet(), 1, 1);
        let err = HostTrainer::new().train(&tiny, Target::Time, &TrainConfig::default());
        assert!(err.is_err());
    }

    #[test]
    fn checkpoint_metadata_mirrors_artifact_trainer() {
        let corpus = truth_corpus(Workload::resnet(), 40, 2);
        let cfg = TrainConfig { epochs: 4, seed: 3, ..Default::default() };
        let (ckpt, log) = HostTrainer::new().train(&corpus, Target::Power, &cfg).unwrap();
        assert_eq!(ckpt.target, "power");
        assert!(ckpt.provenance.starts_with("nn-scratch-host: power on resnet (40 modes)"));
        assert!(ckpt.params.is_finite());
        assert!(ckpt.val_loss.is_finite());
        assert_eq!(log.train_loss.len(), 4);
        assert_eq!(log.val_mse.len(), 4);
        // 40 rows · 0.9 split = 36 train rows → 1 step/epoch at batch 64
        assert_eq!(log.steps, 4);
        assert!(log.best_epoch < 4);
    }

    #[test]
    fn evaluate_host_matches_stats_mape() {
        let corpus = truth_corpus(Workload::mobilenet(), 60, 4);
        let cfg = TrainConfig { epochs: 6, seed: 5, ..Default::default() };
        let (ckpt, _) = HostTrainer::new().train(&corpus, Target::Time, &cfg).unwrap();
        let holdout = truth_corpus(Workload::mobilenet(), 50, 6);
        let xs = scale_features(&holdout, &ckpt.feature_scaler);
        let ys = Target::Time.values(&holdout);
        let net = TransposedMlp::from_params(&ckpt.params);
        let mut tape = Tape::new(HOST_TRAIN_BATCH);
        let (_, eval_mape) = evaluate_host(&net, &xs, &ys, &ckpt.target_scaler, &mut tape);
        let preds = crate::predict::predict_modes_host(
            &ckpt,
            &holdout.records().iter().map(|r| r.mode).collect::<Vec<_>>(),
        );
        let direct = crate::util::stats::mape(&preds, &ys);
        assert!(
            (eval_mape - direct).abs() < 0.5,
            "evaluate {eval_mape:.2}% vs predict-derived {direct:.2}%"
        );
    }
}
