//! PowerTrain transfer learning (paper section 3.2).
//!
//! Take the reference NN (trained once, offline, on the full 4.4k-mode
//! corpus of the reference workload), replace its final dense layer with a
//! fresh one, and fine-tune on ~50 profiled power modes of the new
//! workload / device. Both the time and the power model transfer the same
//! way; the Nano cross-device transfer switches the loss to MAPE.
//!
//! Two backends share the recipe:
//!
//! * [`transfer_host`] — the default build's path, driving the pure-rust
//!   backprop trainer (`train::HostTrainer`). It additionally warms the
//!   fresh head up with the pretrained body *frozen* for
//!   [`TransferConfig::freeze_epochs`] before unfreezing everything —
//!   the freeze-then-finetune schedule keeps the random head's large
//!   early gradients from scrambling the transferred features.
//! * [`transfer`] (feature `xla`) — the AOT-artifact path. The fused
//!   train-step executable updates every parameter, so it runs the
//!   paper's plain surgery + fine-tune without the freeze phase.

use crate::error::Result;
use crate::nn::checkpoint::Checkpoint;
use crate::profiler::Corpus;
use crate::train::{HostTrainer, Target, TrainConfig, TrainingLog};
use crate::util::rng::Rng;

#[cfg(feature = "xla")]
use crate::runtime::Runtime;
#[cfg(feature = "xla")]
use crate::train::Trainer;

/// Transfer configuration.
#[derive(Debug, Clone, Copy)]
pub struct TransferConfig {
    pub base: TrainConfig,
    /// Reinitialize the last dense layer before fine-tuning (the paper's
    /// surgery; disabling it is the ablation in `experiments`).
    pub reinit_last_layer: bool,
    /// Host path only: epochs the pretrained body stays frozen while the
    /// fresh head trains (0 disables the phase). Clamped to
    /// `base.epochs / 2` so the full-network fine-tune always gets at
    /// least half the budget — small epoch budgets must not degenerate
    /// to head-only training. Ignored by the artifact path, whose fused
    /// step always updates every parameter.
    pub freeze_epochs: usize,
}

impl Default for TransferConfig {
    fn default() -> Self {
        TransferConfig {
            base: TrainConfig::default(),
            reinit_last_layer: true,
            freeze_epochs: 10,
        }
    }
}

/// RNG domain tag so transfer draws an independent stream from scratch
/// training at the same seed ("transfer" in ASCII).
const TRANSFER_TAG: u64 = 0x7472_616e_7366_6572;

/// Fine-tune `reference` onto `corpus` (the new workload's ~50 modes)
/// with the pure-rust trainer — the default build's transfer path.
pub fn transfer_host(
    reference: &Checkpoint,
    corpus: &Corpus,
    target: Target,
    cfg: &TransferConfig,
) -> Result<(Checkpoint, TrainingLog)> {
    let mut rng = Rng::new(cfg.base.seed ^ TRANSFER_TAG);
    let mut params = reference.params.clone();
    if cfg.reinit_last_layer {
        params.reinit_last_layer(&mut rng);
    }
    let trainer = HostTrainer::new();
    let provenance = format!("powertrain-transfer-host(from {})", reference.provenance);
    // head-warmup gets at most half the epoch budget: the fine-tune of
    // the whole network is the paper's recipe and must never be starved
    // out by the freeze phase at small budgets
    let freeze = cfg.freeze_epochs.min(cfg.base.epochs / 2);
    let phases: &[(usize, usize)] = &[(freeze, 3), (cfg.base.epochs - freeze, 0)];
    trainer.train_schedule(params, corpus, target, &cfg.base, &mut rng, &provenance, phases)
}

/// Fine-tune `reference` onto `corpus` through the AOT train artifacts.
#[cfg(feature = "xla")]
pub fn transfer(
    rt: &Runtime,
    reference: &Checkpoint,
    corpus: &Corpus,
    target: Target,
    cfg: &TransferConfig,
) -> Result<(Checkpoint, TrainingLog)> {
    let mut rng = Rng::new(cfg.base.seed ^ TRANSFER_TAG);
    let mut params = reference.params.clone();
    if cfg.reinit_last_layer {
        params.reinit_last_layer(&mut rng);
    }
    let trainer = Trainer::new(rt);
    let provenance = format!("powertrain-transfer(from {})", reference.provenance);
    trainer.train_from(params, corpus, target, &cfg.base, &mut rng, &provenance)
}
