//! PowerTrain transfer learning (paper section 3.2).
//!
//! Take the reference NN (trained once, offline, on the full 4.4k-mode
//! corpus of the reference workload), replace its final dense layer with a
//! fresh one, and fine-tune on ~50 profiled power modes of the new
//! workload / device. Both the time and the power model transfer the same
//! way; the Nano cross-device transfer switches the loss to MAPE.
//!
//! Two backends share the recipe:
//!
//! * [`transfer_host`] — the default build's path, driving the pure-rust
//!   backprop trainer (`train::HostTrainer`). It additionally warms the
//!   fresh head up with the pretrained body *frozen* for
//!   [`TransferConfig::freeze_epochs`] before unfreezing everything —
//!   the freeze-then-finetune schedule keeps the random head's large
//!   early gradients from scrambling the transferred features.
//! * [`transfer`] (feature `xla`) — the AOT-artifact path. The fused
//!   train-step executable updates every parameter, so it runs the
//!   paper's plain surgery + fine-tune without the freeze phase.
//!
//! A third, *online* entry point rides on the same trainer:
//! [`refit_host`] warm-starts from an already-deployed checkpoint (no
//! surgery, no freeze) to absorb serving-time feedback — the background
//! refresh the coordinator's model lifecycle performs when a cached
//! model drifts.

use crate::error::Result;
use crate::nn::checkpoint::Checkpoint;
use crate::profiler::Corpus;
use crate::train::{HostTrainer, Target, TrainConfig, TrainingLog};
use crate::util::rng::Rng;

#[cfg(feature = "xla")]
use crate::runtime::Runtime;
#[cfg(feature = "xla")]
use crate::train::Trainer;

/// Transfer configuration.
#[derive(Debug, Clone, Copy)]
pub struct TransferConfig {
    pub base: TrainConfig,
    /// Reinitialize the last dense layer before fine-tuning (the paper's
    /// surgery; disabling it is the ablation in `experiments`).
    pub reinit_last_layer: bool,
    /// Host path only: epochs the pretrained body stays frozen while the
    /// fresh head trains (0 disables the phase). Clamped to
    /// `base.epochs / 2` so the full-network fine-tune always gets at
    /// least half the budget — small epoch budgets must not degenerate
    /// to head-only training. Ignored by the artifact path, whose fused
    /// step always updates every parameter.
    pub freeze_epochs: usize,
}

impl Default for TransferConfig {
    fn default() -> Self {
        TransferConfig {
            base: TrainConfig::default(),
            reinit_last_layer: true,
            freeze_epochs: 10,
        }
    }
}

/// RNG domain tag so transfer draws an independent stream from scratch
/// training at the same seed ("transfer" in ASCII).
const TRANSFER_TAG: u64 = 0x7472_616e_7366_6572;

/// RNG domain tag for warm refits ("refit" in ASCII), so a refit at the
/// same seed draws an independent shuffle/split stream from the original
/// transfer.
const REFIT_TAG: u64 = 0x72_6566_6974;

/// Warm-refit an already-deployed checkpoint on a fresh observation
/// corpus — the model-lifecycle refresh path
/// (`coordinator::lifecycle`).
///
/// Unlike [`transfer_host`], there is **no layer surgery and no freeze
/// phase**: the current weights (and their accumulated transfer) are the
/// starting point, and every layer fine-tunes from epoch 0. The caller
/// passes a *short* epoch budget (`TrainConfig::epochs`, typically a
/// fraction of the original transfer budget) because the fit starts a
/// few gradient steps from a good optimum. Scalers are refit on the new
/// corpus, so a refit tracks distribution shift in the features/targets
/// as well as in the mapping; a refit that diverges returns `Err`
/// instead of publishing non-finite weights.
pub fn refit_host(
    current: &Checkpoint,
    corpus: &Corpus,
    target: Target,
    cfg: &TrainConfig,
) -> Result<(Checkpoint, TrainingLog)> {
    let mut rng = Rng::new(cfg.seed ^ REFIT_TAG);
    let trainer = HostTrainer::new();
    trainer.train_from(
        current.params.clone(),
        corpus,
        target,
        cfg,
        &mut rng,
        "powertrain-refit-host",
    )
}

/// Fine-tune `reference` onto `corpus` (the new workload's ~50 modes)
/// with the pure-rust trainer — the default build's transfer path.
pub fn transfer_host(
    reference: &Checkpoint,
    corpus: &Corpus,
    target: Target,
    cfg: &TransferConfig,
) -> Result<(Checkpoint, TrainingLog)> {
    let mut rng = Rng::new(cfg.base.seed ^ TRANSFER_TAG);
    let mut params = reference.params.clone();
    if cfg.reinit_last_layer {
        params.reinit_last_layer(&mut rng);
    }
    let trainer = HostTrainer::new();
    let provenance = format!("powertrain-transfer-host(from {})", reference.provenance);
    // head-warmup gets at most half the epoch budget: the fine-tune of
    // the whole network is the paper's recipe and must never be starved
    // out by the freeze phase at small budgets
    let freeze = cfg.freeze_epochs.min(cfg.base.epochs / 2);
    let phases: &[(usize, usize)] = &[(freeze, 3), (cfg.base.epochs - freeze, 0)];
    trainer.train_schedule(params, corpus, target, &cfg.base, &mut rng, &provenance, phases)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::{DeviceKind, PowerModeGrid};
    use crate::profiler::Record;
    use crate::sim::TrainerSim;
    use crate::workload::Workload;

    /// Noise-free ground-truth corpus with an optional drift factor on
    /// the time channel (what a lifecycle refit sees after the workload
    /// shifted).
    fn truth_corpus(n: usize, seed: u64, time_factor: f64) -> Corpus {
        let spec = DeviceKind::OrinAgx.spec();
        let sim = TrainerSim::new(spec, Workload::mobilenet(), seed);
        let mut rng = Rng::new(seed ^ 0xfee1);
        let modes = PowerModeGrid::paper_subset(DeviceKind::OrinAgx).sample(n, &mut rng);
        let mut c = Corpus::new(DeviceKind::OrinAgx, Workload::mobilenet());
        for pm in modes {
            c.push(Record {
                mode: pm,
                time_ms: sim.true_minibatch_ms(&pm) * time_factor,
                power_mw: sim.true_power_mw(&pm),
                cost_s: 0.0,
            });
        }
        c
    }

    #[test]
    fn refit_tracks_drifted_targets_and_is_deterministic() {
        // deploy a model on the clean distribution...
        let clean = truth_corpus(40, 3, 1.0);
        let cfg = TrainConfig { epochs: 30, seed: 5, ..Default::default() };
        let (deployed, _) = HostTrainer::new().train(&clean, Target::Time, &cfg).unwrap();

        // ...then the workload drifts: observed times grow by 60%
        let drifted = truth_corpus(40, 3, 1.6);
        let short = TrainConfig { epochs: 25, seed: 5, ..Default::default() };
        let (refit, log) = refit_host(&deployed, &drifted, Target::Time, &short).unwrap();
        assert!(refit.provenance.starts_with("powertrain-refit-host"));
        assert!(log.best_val_mape().is_finite());

        // the refit must explain the drifted data better than the stale
        // deployed model does
        let holdout = truth_corpus(30, 9, 1.6);
        let stale_mape = crate::predict::corpus_mape_host(&deployed, &holdout, Target::Time);
        let fresh_mape = crate::predict::corpus_mape_host(&refit, &holdout, Target::Time);
        assert!(
            fresh_mape < stale_mape,
            "refit must track the drift: stale {stale_mape:.1}% vs refit {fresh_mape:.1}%"
        );

        // refits are bit-deterministic per seed (the lifecycle's cache
        // soundness rests on this)
        let (again, _) = refit_host(&deployed, &drifted, Target::Time, &short).unwrap();
        assert_eq!(refit.fingerprint(), again.fingerprint());
        // and a refit at a different seed draws an independent stream
        let other = TrainConfig { seed: 6, ..short };
        let (different, _) = refit_host(&deployed, &drifted, Target::Time, &other).unwrap();
        assert_ne!(refit.fingerprint(), different.fingerprint());
    }
}

/// Fine-tune `reference` onto `corpus` through the AOT train artifacts.
#[cfg(feature = "xla")]
pub fn transfer(
    rt: &Runtime,
    reference: &Checkpoint,
    corpus: &Corpus,
    target: Target,
    cfg: &TransferConfig,
) -> Result<(Checkpoint, TrainingLog)> {
    let mut rng = Rng::new(cfg.base.seed ^ TRANSFER_TAG);
    let mut params = reference.params.clone();
    if cfg.reinit_last_layer {
        params.reinit_last_layer(&mut rng);
    }
    let trainer = Trainer::new(rt);
    let provenance = format!("powertrain-transfer(from {})", reference.provenance);
    trainer.train_from(params, corpus, target, &cfg.base, &mut rng, &provenance)
}
