//! PowerTrain transfer learning (paper section 3.2).
//!
//! Take the reference NN (trained once, offline, on the full 4.4k-mode
//! corpus of the reference workload), replace its final dense layer with a
//! fresh one, and fine-tune on ~50 profiled power modes of the new
//! workload / device. Both the time and the power model transfer the same
//! way; the Nano cross-device transfer switches the loss to MAPE.

use crate::train::TrainConfig;

#[cfg(feature = "xla")]
use crate::error::Result;
#[cfg(feature = "xla")]
use crate::nn::checkpoint::Checkpoint;
#[cfg(feature = "xla")]
use crate::profiler::Corpus;
#[cfg(feature = "xla")]
use crate::runtime::Runtime;
#[cfg(feature = "xla")]
use crate::train::{Target, Trainer, TrainingLog};
#[cfg(feature = "xla")]
use crate::util::rng::Rng;

/// Transfer configuration.
#[derive(Debug, Clone, Copy)]
pub struct TransferConfig {
    pub base: TrainConfig,
    /// Reinitialize the last dense layer before fine-tuning (the paper's
    /// surgery; disabling it is the ablation in `experiments`).
    pub reinit_last_layer: bool,
}

impl Default for TransferConfig {
    fn default() -> Self {
        TransferConfig { base: TrainConfig::default(), reinit_last_layer: true }
    }
}

/// Fine-tune `reference` onto `corpus` (the new workload's ~50 modes).
#[cfg(feature = "xla")]
pub fn transfer(
    rt: &Runtime,
    reference: &Checkpoint,
    corpus: &Corpus,
    target: Target,
    cfg: &TransferConfig,
) -> Result<(Checkpoint, TrainingLog)> {
    let mut rng = Rng::new(cfg.base.seed ^ 0x7472_616e_7366_6572); // "transfer"
    let mut params = reference.params.clone();
    if cfg.reinit_last_layer {
        params.reinit_last_layer(&mut rng);
    }
    let trainer = Trainer::new(rt);
    let provenance = format!("powertrain-transfer(from {})", reference.provenance);
    trainer.train_from(params, corpus, target, &cfg.base, &mut rng, &provenance)
}
