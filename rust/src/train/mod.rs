//! Training drivers: reference-NN training and from-scratch NN baselines.
//!
//! The rust side owns all state (params, Adam moments, scalers, shuffling,
//! best-checkpoint logic). Two compute backends share those semantics:
//!
//! * [`host::HostTrainer`] — pure-rust backprop/Adam (`nn::grad`), the
//!   backend of the default, dependency-free build; and
//! * [`Trainer`] (feature `xla`) — one fused HLO executable per step
//!   through the AOT train/eval artifacts, Python never involved.

pub mod host;
pub mod transfer;

pub use host::HostTrainer;

use crate::profiler::{Corpus, StandardScaler};

#[cfg(feature = "xla")]
use crate::error::{Error, Result};
#[cfg(feature = "xla")]
use crate::nn::checkpoint::Checkpoint;
#[cfg(feature = "xla")]
use crate::nn::{leaf_shape, AdamState, MlpParams, N_LEAVES};
#[cfg(feature = "xla")]
use crate::runtime::{f32_literal, to_f32_scalar, to_f32_vec, u32_literal, Runtime};
#[cfg(feature = "xla")]
use crate::util::rng::Rng;

/// Which telemetry channel a model predicts.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Target {
    Time,
    Power,
}

impl Target {
    pub fn name(&self) -> &'static str {
        match self {
            Target::Time => "time",
            Target::Power => "power",
        }
    }

    pub fn values(&self, corpus: &Corpus) -> Vec<f64> {
        match self {
            Target::Time => corpus.times_ms(),
            Target::Power => corpus.powers_mw(),
        }
    }
}

/// Loss used by the train-step artifact.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LossKind {
    /// Masked MSE in standardized-target space (default, paper Table 4).
    Mse,
    /// Masked MAPE in raw units (cross-device transfer to Orin Nano,
    /// paper section 4.3.4).
    Mape,
}

/// Training configuration.
#[derive(Debug, Clone, Copy)]
pub struct TrainConfig {
    pub epochs: usize,
    pub loss: LossKind,
    pub seed: u64,
    /// Fraction of the corpus used for training (rest validates).
    pub train_frac: f64,
}

impl Default for TrainConfig {
    fn default() -> Self {
        // paper Table 4: 100 training epochs, 90:10 split
        TrainConfig { epochs: 100, loss: LossKind::Mse, seed: 0, train_frac: 0.9 }
    }
}

/// Loss curves and metadata from one training run.
#[derive(Debug, Clone)]
pub struct TrainingLog {
    pub train_loss: Vec<f64>,
    pub val_mse: Vec<f64>,
    pub val_mape: Vec<f64>,
    pub best_epoch: usize,
    pub steps: usize,
}

impl TrainingLog {
    /// Validation MAPE (%) at the best-checkpoint epoch — the raw-unit
    /// accuracy of the weights actually shipped, and the baseline the
    /// model-lifecycle drift monitor compares serving-time feedback
    /// against. `NaN` when the log is empty (callers treat an unknown
    /// baseline as "fall back to the absolute floor threshold").
    pub fn best_val_mape(&self) -> f64 {
        self.val_mape.get(self.best_epoch).copied().unwrap_or(f64::NAN)
    }
}

/// Builds per-step literals and drives the artifacts.
#[cfg(feature = "xla")]
pub struct Trainer<'rt> {
    pub rt: &'rt Runtime,
}

#[cfg(feature = "xla")]
impl<'rt> Trainer<'rt> {
    pub fn new(rt: &'rt Runtime) -> Trainer<'rt> {
        Trainer { rt }
    }

    /// Train a prediction model from scratch (the paper's NN approach).
    pub fn train(
        &self,
        corpus: &Corpus,
        target: Target,
        cfg: &TrainConfig,
    ) -> Result<(Checkpoint, TrainingLog)> {
        let mut rng = Rng::new(cfg.seed);
        let params = MlpParams::init_he(&mut rng);
        self.train_from(params, corpus, target, cfg, &mut rng, "nn-scratch")
    }

    /// Core loop, shared with transfer learning (which passes pre-trained
    /// params and its own provenance tag).
    pub fn train_from(
        &self,
        params: MlpParams,
        corpus: &Corpus,
        target: Target,
        cfg: &TrainConfig,
        rng: &mut Rng,
        provenance: &str,
    ) -> Result<(Checkpoint, TrainingLog)> {
        if corpus.len() < 2 {
            return Err(Error::Training("corpus too small to train on".into()));
        }
        let (train, val) = corpus.split(cfg.train_frac, rng);
        let val = if val.is_empty() { train.clone() } else { val };

        // scalers fit on the training split only
        let feat_rows: Vec<Vec<f64>> = train
            .features()
            .iter()
            .map(|f| f.iter().map(|&x| x as f64).collect())
            .collect();
        let feature_scaler = StandardScaler::fit(&feat_rows);
        let target_scaler = StandardScaler::fit1(&target.values(&train));

        let xs_train = scale_features(&train, &feature_scaler);
        let ys_train = target.values(&train);
        let xs_val = scale_features(&val, &feature_scaler);
        let ys_val = target.values(&val);

        let mut log = TrainingLog {
            train_loss: Vec::new(),
            val_mse: Vec::new(),
            val_mape: Vec::new(),
            best_epoch: 0,
            steps: 0,
        };

        // training state lives as XLA literals across steps: each step's
        // outputs feed the next step's inputs by reference, so the 3 x 42k
        // parameter/moment tensors never round-trip through host vectors
        // (EXPERIMENTS.md section Perf)
        let mut state = Vec::with_capacity(3 * N_LEAVES);
        push_leaves(&mut state, &params)?;
        let adam0 = AdamState::fresh();
        push_leaves(&mut state, &adam0.m)?;
        push_leaves(&mut state, &adam0.v)?;
        let mut step_count: u64 = 0;

        let mut best_mse = f64::INFINITY;
        let mut best_params = params.clone();

        let n = xs_train.len();
        let bsz = self.rt.manifest.train_batch;
        let mut order: Vec<usize> = (0..n).collect();

        for epoch in 0..cfg.epochs {
            rng.shuffle(&mut order);
            let mut epoch_loss = 0.0f64;
            let mut batches = 0.0f64;
            for chunk in order.chunks(bsz) {
                let loss = self.step_lits(
                    &mut state,
                    &mut step_count,
                    cfg.loss,
                    chunk,
                    &xs_train,
                    &ys_train,
                    &target_scaler,
                    rng,
                )?;
                epoch_loss += loss;
                batches += 1.0;
                log.steps += 1;
            }
            log.train_loss.push(epoch_loss / batches.max(1.0));

            let (mse, mape) =
                self.evaluate_refs(&state[0..N_LEAVES], &xs_val, &ys_val, &target_scaler)?;
            log.val_mse.push(mse);
            log.val_mape.push(mape);
            if mse < best_mse {
                best_mse = mse;
                pull_leaves(&state[0..N_LEAVES], &mut best_params)?;
                log.best_epoch = epoch;
            }
        }
        let best = (best_mse, best_params);

        if !best.1.is_finite() {
            return Err(Error::Training("training diverged to non-finite params".into()));
        }

        Ok((
            Checkpoint {
                params: best.1,
                feature_scaler,
                target_scaler,
                target: target.name().to_string(),
                provenance: format!(
                    "{provenance}: {} on {} ({} modes)",
                    target.name(),
                    corpus.workload.name(),
                    corpus.len()
                ),
                val_loss: best.0,
            },
            log,
        ))
    }

    /// One Adam step through the train artifact, keeping all model/optimizer
    /// state as literals (`state` = 24 tensors: params, m, v).
    #[allow(clippy::too_many_arguments)]
    fn step_lits(
        &self,
        state: &mut Vec<xla::Literal>,
        step_count: &mut u64,
        loss: LossKind,
        idx: &[usize],
        xs: &[[f32; 4]],
        ys_raw: &[f64],
        tscaler: &StandardScaler,
        rng: &mut Rng,
    ) -> Result<f64> {
        let bsz = self.rt.manifest.train_batch;
        let dim = self.rt.manifest.input_dim;
        let mut x = vec![0.0f32; bsz * dim];
        let mut y = vec![0.0f32; bsz];
        let mut mask = vec![0.0f32; bsz];
        for (row, &i) in idx.iter().enumerate().take(bsz) {
            x[row * dim..(row + 1) * dim].copy_from_slice(&xs[i]);
            y[row] = match loss {
                LossKind::Mse => tscaler.transform1(ys_raw[i]) as f32,
                LossKind::Mape => ys_raw[i] as f32,
            };
            mask[row] = 1.0;
        }

        let t_lit = f32_literal(&[(*step_count + 1) as f32], &[1])?;
        let key_lit = u32_literal(&rng.jax_key());
        let x_lit = f32_literal(&x, &[bsz, dim])?;
        let y_lit = f32_literal(&y, &[bsz, 1])?;
        let mask_lit = f32_literal(&mask, &[bsz])?;
        let (mean_lit, std_lit) = (
            f32_literal(&[tscaler.mean[0] as f32], &[])?,
            f32_literal(&[tscaler.std[0] as f32], &[])?,
        );

        let mut inputs: Vec<&xla::Literal> = Vec::with_capacity(31);
        inputs.extend(state.iter());
        inputs.push(&t_lit);
        inputs.push(&key_lit);
        inputs.push(&x_lit);
        inputs.push(&y_lit);
        inputs.push(&mask_lit);
        let artifact = match loss {
            LossKind::Mse => "train_mse",
            LossKind::Mape => {
                inputs.push(&mean_lit);
                inputs.push(&std_lit);
                "train_mape"
            }
        };

        let mut outs = self.rt.execute_refs(artifact, &inputs)?;
        let loss_lit = outs.pop().expect("loss output");
        outs.truncate(3 * N_LEAVES);
        *state = outs;
        *step_count += 1;
        Ok(to_f32_scalar(&loss_lit)? as f64)
    }

    /// Masked validation pass through the `evaluate` artifact.
    /// Returns (mse in standardized space, mape % in raw units).
    pub fn evaluate(
        &self,
        params: &MlpParams,
        xs: &[[f32; 4]],
        ys_raw: &[f64],
        tscaler: &StandardScaler,
    ) -> Result<(f64, f64)> {
        let mut lits = Vec::with_capacity(N_LEAVES);
        push_leaves(&mut lits, params)?;
        self.evaluate_refs(&lits, xs, ys_raw, tscaler)
    }

    /// As [`Trainer::evaluate`] but on parameter literals (no host copies).
    pub fn evaluate_refs(
        &self,
        param_lits: &[xla::Literal],
        xs: &[[f32; 4]],
        ys_raw: &[f64],
        tscaler: &StandardScaler,
    ) -> Result<(f64, f64)> {
        debug_assert_eq!(param_lits.len(), N_LEAVES);
        let bsz = self.rt.manifest.predict_batch;
        let dim = self.rt.manifest.input_dim;
        let mut tot_mse = 0.0;
        let mut tot_mape = 0.0;
        let mut tot_n = 0.0;
        let mean_lit = f32_literal(&[tscaler.mean[0] as f32], &[])?;
        let std_lit = f32_literal(&[tscaler.std[0] as f32], &[])?;
        // chunk buffers hoisted out of the loop (mirroring predict_modes);
        // ragged final chunks zero their padding tail below
        let mut x = vec![0.0f32; bsz * dim];
        let mut y_std = vec![0.0f32; bsz];
        let mut y_raw = vec![0.0f32; bsz];
        let mut mask = vec![0.0f32; bsz];
        for chunk_start in (0..xs.len()).step_by(bsz) {
            let chunk_end = (chunk_start + bsz).min(xs.len());
            let real = chunk_end - chunk_start;
            for row in 0..real {
                let i = chunk_start + row;
                x[row * dim..(row + 1) * dim].copy_from_slice(&xs[i]);
                y_std[row] = tscaler.transform1(ys_raw[i]) as f32;
                y_raw[row] = ys_raw[i] as f32;
                mask[row] = 1.0;
            }
            if real < bsz {
                x[real * dim..].fill(0.0);
                y_std[real..].fill(0.0);
                y_raw[real..].fill(0.0);
                mask[real..].fill(0.0);
            }
            let x_lit = f32_literal(&x, &[bsz, dim])?;
            let y_std_lit = f32_literal(&y_std, &[bsz, 1])?;
            let y_raw_lit = f32_literal(&y_raw, &[bsz, 1])?;
            let mask_lit = f32_literal(&mask, &[bsz])?;
            let mut inputs: Vec<&xla::Literal> = Vec::with_capacity(14);
            inputs.extend(param_lits.iter());
            inputs.push(&x_lit);
            inputs.push(&y_std_lit);
            inputs.push(&y_raw_lit);
            inputs.push(&mask_lit);
            inputs.push(&mean_lit);
            inputs.push(&std_lit);
            let outs = self.rt.execute_refs("evaluate", &inputs)?;
            let mse = to_f32_scalar(&outs[0])? as f64;
            let mape = to_f32_scalar(&outs[1])? as f64;
            tot_mse += mse * real as f64;
            tot_mape += mape * real as f64;
            tot_n += real as f64;
        }
        Ok((tot_mse / tot_n.max(1.0), tot_mape / tot_n.max(1.0)))
    }
}

/// Standardize a corpus's features with a fitted scaler, writing each row
/// straight into the output array — no per-row `Vec<f64>` round-trips.
pub fn scale_features(corpus: &Corpus, scaler: &StandardScaler) -> Vec<[f32; 4]> {
    assert_eq!(scaler.dim(), 4, "feature scaler must be 4-wide");
    corpus.features().iter().map(|f| scaler.transform4(f)).collect()
}

#[cfg(feature = "xla")]
fn push_leaves(inputs: &mut Vec<xla::Literal>, p: &MlpParams) -> Result<()> {
    for (i, leaf) in p.leaves.iter().enumerate() {
        inputs.push(f32_literal(leaf, &leaf_shape(i))?);
    }
    Ok(())
}

#[cfg(feature = "xla")]
fn pull_leaves(outs: &[xla::Literal], p: &mut MlpParams) -> Result<()> {
    for (i, lit) in outs.iter().enumerate() {
        p.leaves[i] = to_f32_vec(lit)?;
    }
    Ok(())
}
