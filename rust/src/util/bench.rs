//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! Measures wall-clock per iteration with warmup, reports median /
//! mean / p95 and throughput; used by `cargo bench` targets
//! (`harness = false`).

use std::hint::black_box;
use std::path::Path;
use std::time::Instant;

use super::json::Value;
use super::stats;
use crate::error::{Error, Result};

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub p95_ns: f64,
    /// Optional items-per-iteration for throughput reporting.
    pub items_per_iter: Option<f64>,
}

impl BenchResult {
    pub fn throughput_per_s(&self) -> Option<f64> {
        self.items_per_iter
            .map(|it| it / (self.median_ns / 1e9))
    }

    /// Median nanoseconds per logical item (per-iteration time when no
    /// item count was declared).
    pub fn ns_per_item(&self) -> f64 {
        match self.items_per_iter {
            Some(it) if it > 0.0 => self.median_ns / it,
            _ => self.median_ns,
        }
    }

    fn to_json(&self) -> Value {
        Value::obj(vec![
            ("name", Value::Str(self.name.clone())),
            ("iters", Value::Num(self.iters as f64)),
            ("median_ns", Value::Num(self.median_ns)),
            ("mean_ns", Value::Num(self.mean_ns)),
            ("p95_ns", Value::Num(self.p95_ns)),
            (
                "items_per_iter",
                self.items_per_iter.map_or(Value::Null, Value::Num),
            ),
            ("ns_per_item", Value::Num(self.ns_per_item())),
            (
                "throughput_per_s",
                self.throughput_per_s().map_or(Value::Null, Value::Num),
            ),
        ])
    }

    pub fn render(&self) -> String {
        let thr = match self.throughput_per_s() {
            Some(t) if t >= 1e6 => format!("  {:8.2} Mitem/s", t / 1e6),
            Some(t) if t >= 1e3 => format!("  {:8.2} Kitem/s", t / 1e3),
            Some(t) => format!("  {t:8.2} item/s"),
            None => String::new(),
        };
        format!(
            "{:<44} {:>12} median  {:>12} mean  {:>12} p95  ({} iters){}",
            self.name,
            fmt_ns(self.median_ns),
            fmt_ns(self.mean_ns),
            fmt_ns(self.p95_ns),
            self.iters,
            thr
        )
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0}ns")
    } else if ns < 1e6 {
        format!("{:.2}us", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.3}s", ns / 1e9)
    }
}

/// Benchmark runner with a global time budget per case.
pub struct Bencher {
    pub warmup_iters: usize,
    pub min_iters: usize,
    pub max_iters: usize,
    pub budget_s: f64,
    results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup_iters: 3,
            min_iters: 10,
            max_iters: 10_000,
            budget_s: 2.0,
            results: Vec::new(),
        }
    }
}

impl Bencher {
    pub fn quick() -> Self {
        Bencher {
            warmup_iters: 1,
            min_iters: 3,
            max_iters: 200,
            budget_s: 0.5,
            ..Default::default()
        }
    }

    /// Time `f`, which returns a value that is black-boxed to defeat DCE.
    pub fn bench<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &BenchResult {
        self.bench_with_items(name, None, &mut f)
    }

    /// Same, declaring how many logical items one iteration processes.
    pub fn bench_items<T>(
        &mut self,
        name: &str,
        items: f64,
        mut f: impl FnMut() -> T,
    ) -> &BenchResult {
        self.bench_with_items(name, Some(items), &mut f)
    }

    fn bench_with_items<T>(
        &mut self,
        name: &str,
        items: Option<f64>,
        f: &mut dyn FnMut() -> T,
    ) -> &BenchResult {
        for _ in 0..self.warmup_iters {
            black_box(f());
        }
        let mut samples_ns: Vec<f64> = Vec::new();
        let start = Instant::now();
        while samples_ns.len() < self.min_iters
            || (start.elapsed().as_secs_f64() < self.budget_s
                && samples_ns.len() < self.max_iters)
        {
            let t0 = Instant::now();
            black_box(f());
            samples_ns.push(t0.elapsed().as_nanos() as f64);
        }
        let res = BenchResult {
            name: name.to_string(),
            iters: samples_ns.len(),
            mean_ns: stats::mean(&samples_ns),
            median_ns: stats::median(&samples_ns),
            p95_ns: stats::quantile(&samples_ns, 0.95),
            items_per_iter: items,
        };
        println!("{}", res.render());
        self.results.push(res);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Write all recorded results as machine-readable JSON (one object per
    /// bench, keyed per-bench ns/item) so successive PRs can track the
    /// perf trajectory — e.g. `BENCH_hotpaths.json` from `bench_hotpaths`.
    pub fn save_json(&self, path: &Path) -> Result<()> {
        let doc = Value::obj(vec![
            ("kind", Value::Str("powertrain-bench-v1".into())),
            (
                "benches",
                Value::Arr(self.results.iter().map(|r| r.to_json()).collect()),
            ),
        ]);
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        std::fs::write(path, doc.to_string())?;
        Ok(())
    }
}

// ---------------------------------------------------------------------
// bench-regression gate

/// Default regression tolerance: a tracked hot path may be up to 30%
/// slower than its committed baseline before the gate fails.
pub const GATE_DEFAULT_TOLERANCE: f64 = 0.30;

/// Outcome of comparing one bench report against a committed baseline
/// (see [`gate`]).
#[derive(Debug, Clone)]
pub struct GateReport {
    /// Benches compared (present in both documents).
    pub checked: usize,
    /// Human-readable failure lines: regressions beyond tolerance and
    /// baseline benches missing from the current run.
    pub failures: Vec<String>,
    /// One status line per bench, for the CI log.
    pub lines: Vec<String>,
}

impl GateReport {
    pub fn passed(&self) -> bool {
        self.failures.is_empty()
    }
}

/// Collect `name -> ns_per_item` from a `powertrain-bench-v1` document.
fn bench_map(doc: &Value) -> Result<Vec<(String, f64)>> {
    let mut out = Vec::new();
    for b in doc.req("benches")?.as_arr()? {
        let name = b.req("name")?.as_str()?.to_string();
        let ns = b.req("ns_per_item")?.as_f64()?;
        out.push((name, ns));
    }
    Ok(out)
}

/// The CI bench-regression gate: compare a current `BENCH_hotpaths.json`
/// document against the committed `BENCH_baseline.json`.
///
/// Rules:
/// * every bench id in the **baseline** must appear in the current run —
///   a silently dropped bench would blind the gate, so missing ⇒ fail;
/// * a tracked bench **regresses** when its current ns/item exceeds
///   `baseline × (1 + tolerance)` — strictly, so exactly-at-tolerance
///   passes;
/// * benches only in the current run are reported but never fail (new
///   benches land one PR before their baseline refresh);
/// * non-finite or non-positive baselines are configuration errors
///   (`Err`), not pass/fail outcomes.
pub fn gate(baseline: &Value, current: &Value, tolerance: f64) -> Result<GateReport> {
    if !(tolerance.is_finite() && tolerance >= 0.0) {
        return Err(Error::Json(format!("invalid gate tolerance {tolerance}")));
    }
    let base = bench_map(baseline)?;
    let cur = bench_map(current)?;
    let mut report = GateReport { checked: 0, failures: Vec::new(), lines: Vec::new() };
    for (name, base_ns) in &base {
        if !(base_ns.is_finite() && *base_ns > 0.0) {
            return Err(Error::Json(format!(
                "baseline bench '{name}' has invalid ns_per_item {base_ns}"
            )));
        }
        let Some((_, cur_ns)) = cur.iter().find(|(n, _)| n == name) else {
            report.failures.push(format!(
                "MISSING   {name}: tracked in the baseline but absent from the current run \
                 (a dropped bench blinds the gate)"
            ));
            continue;
        };
        report.checked += 1;
        let ratio = cur_ns / base_ns;
        let line = format!(
            "{:<44} baseline {:>10}  current {:>10}  ({:+.1}%)",
            name,
            fmt_ns(*base_ns),
            fmt_ns(*cur_ns),
            (ratio - 1.0) * 100.0
        );
        if ratio > 1.0 + tolerance {
            report.failures.push(format!(
                "REGRESSED {name}: {} -> {} ({:+.1}%, tolerance +{:.0}%)",
                fmt_ns(*base_ns),
                fmt_ns(*cur_ns),
                (ratio - 1.0) * 100.0,
                tolerance * 100.0
            ));
            report.lines.push(format!("FAIL {line}"));
        } else {
            report.lines.push(format!("ok   {line}"));
        }
    }
    for (name, _) in &cur {
        if !base.iter().any(|(n, _)| n == name) {
            report.lines.push(format!(
                "new  {name:<44} (not in baseline; refresh to start tracking it)"
            ));
        }
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let mut b = Bencher::quick();
        let r = b.bench("spin", || {
            let mut acc = 0u64;
            for i in 0..1000u64 {
                acc = acc.wrapping_add(i * i);
            }
            acc
        });
        assert!(r.median_ns > 0.0);
        assert!(r.iters >= 3);
    }

    #[test]
    fn throughput_derived_from_median() {
        let mut b = Bencher::quick();
        let r = b.bench_items("noop-batch", 1000.0, || 42u8).clone();
        let thr = r.throughput_per_s().unwrap();
        assert!(thr > 0.0);
    }

    #[test]
    fn json_report_round_trips() {
        let mut b = Bencher::quick();
        b.bench_items("alpha", 100.0, || 1u8);
        b.bench("beta", || 2u8);
        let path = std::env::temp_dir().join("pt_bench_json").join("r.json");
        b.save_json(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let v = Value::parse(&text).unwrap();
        let benches = v.req("benches").unwrap().as_arr().unwrap();
        assert_eq!(benches.len(), 2);
        let first = &benches[0];
        assert_eq!(first.req("name").unwrap().as_str().unwrap(), "alpha");
        assert!(first.req("ns_per_item").unwrap().as_f64().unwrap() > 0.0);
        std::fs::remove_dir_all(path.parent().unwrap()).ok();
    }

    #[test]
    fn ns_formatting() {
        assert_eq!(fmt_ns(500.0), "500ns");
        assert_eq!(fmt_ns(1500.0), "1.50us");
        assert_eq!(fmt_ns(2.5e6), "2.50ms");
        assert_eq!(fmt_ns(3.0e9), "3.000s");
    }

    /// A `powertrain-bench-v1` document with the given (name, ns/item)
    /// entries — the shape both `BENCH_baseline.json` and the live
    /// `BENCH_hotpaths.json` share.
    fn bench_doc(entries: &[(&str, f64)]) -> Value {
        Value::obj(vec![
            ("kind", Value::Str("powertrain-bench-v1".into())),
            (
                "benches",
                Value::Arr(
                    entries
                        .iter()
                        .map(|(name, ns)| {
                            Value::obj(vec![
                                ("name", Value::Str((*name).to_string())),
                                ("ns_per_item", Value::Num(*ns)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    #[test]
    fn gate_passes_within_tolerance() {
        let base = bench_doc(&[("a/fast", 100.0), ("b/slow", 1e6)]);
        // +29% and -40%: both inside a 30% tolerance
        let cur = bench_doc(&[("a/fast", 129.0), ("b/slow", 0.6e6)]);
        let r = gate(&base, &cur, GATE_DEFAULT_TOLERANCE).unwrap();
        assert!(r.passed(), "{:?}", r.failures);
        assert_eq!(r.checked, 2);
        // exactly at tolerance passes (strictly-greater fails)
        let at = bench_doc(&[("a/fast", 130.0), ("b/slow", 1e6)]);
        assert!(gate(&base, &at, 0.30).unwrap().passed());
    }

    #[test]
    fn gate_fails_beyond_tolerance() {
        let base = bench_doc(&[("a/fast", 100.0), ("b/slow", 1e6)]);
        let cur = bench_doc(&[("a/fast", 150.0), ("b/slow", 1e6)]);
        let r = gate(&base, &cur, 0.30).unwrap();
        assert!(!r.passed());
        assert_eq!(r.failures.len(), 1);
        assert!(r.failures[0].contains("REGRESSED a/fast"), "{}", r.failures[0]);
        assert!(r.failures[0].contains("+50.0%"), "{}", r.failures[0]);
        // the healthy bench still reports ok
        assert!(r.lines.iter().any(|l| l.starts_with("ok   b/slow")), "{:?}", r.lines);
    }

    #[test]
    fn gate_fails_on_missing_tracked_bench() {
        let base = bench_doc(&[("a/fast", 100.0), ("b/gone", 200.0)]);
        let cur = bench_doc(&[("a/fast", 100.0)]);
        let r = gate(&base, &cur, 0.30).unwrap();
        assert!(!r.passed());
        assert!(r.failures[0].contains("MISSING   b/gone"), "{}", r.failures[0]);
        assert_eq!(r.checked, 1);
    }

    #[test]
    fn gate_tolerates_untracked_new_benches() {
        let base = bench_doc(&[("a/fast", 100.0)]);
        let cur = bench_doc(&[("a/fast", 90.0), ("c/new", 5.0)]);
        let r = gate(&base, &cur, 0.30).unwrap();
        assert!(r.passed());
        assert!(r.lines.iter().any(|l| l.contains("new  c/new")), "{:?}", r.lines);
    }

    #[test]
    fn gate_rejects_malformed_inputs() {
        let good = bench_doc(&[("a", 1.0)]);
        assert!(gate(&Value::obj(vec![]), &good, 0.3).is_err(), "no benches array");
        assert!(gate(&bench_doc(&[("a", 0.0)]), &good, 0.3).is_err(), "zero baseline");
        assert!(gate(&bench_doc(&[("a", f64::NAN)]), &good, 0.3).is_err(), "NaN baseline");
        assert!(gate(&good, &good, f64::NAN).is_err(), "NaN tolerance");
        assert!(gate(&good, &good, -0.1).is_err(), "negative tolerance");
    }

    #[test]
    fn gate_round_trips_through_saved_json() {
        // the live path: a Bencher-written file vs a baseline document
        let mut b = Bencher::quick();
        b.bench_items("alpha", 100.0, || 1u8);
        let path = std::env::temp_dir().join("pt_bench_gate").join("cur.json");
        b.save_json(&path).unwrap();
        let cur = Value::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        let alpha_ns = b.results()[0].ns_per_item();
        let base = bench_doc(&[("alpha", alpha_ns * 2.0)]); // generous baseline
        let r = gate(&base, &cur, GATE_DEFAULT_TOLERANCE).unwrap();
        assert!(r.passed(), "{:?}", r.failures);
        std::fs::remove_dir_all(path.parent().unwrap()).ok();
    }
}
