//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! Measures wall-clock per iteration with warmup, reports median /
//! mean / p95 and throughput; used by `cargo bench` targets
//! (`harness = false`).

use std::hint::black_box;
use std::path::Path;
use std::time::Instant;

use super::json::Value;
use super::stats;
use crate::error::Result;

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub p95_ns: f64,
    /// Optional items-per-iteration for throughput reporting.
    pub items_per_iter: Option<f64>,
}

impl BenchResult {
    pub fn throughput_per_s(&self) -> Option<f64> {
        self.items_per_iter
            .map(|it| it / (self.median_ns / 1e9))
    }

    /// Median nanoseconds per logical item (per-iteration time when no
    /// item count was declared).
    pub fn ns_per_item(&self) -> f64 {
        match self.items_per_iter {
            Some(it) if it > 0.0 => self.median_ns / it,
            _ => self.median_ns,
        }
    }

    fn to_json(&self) -> Value {
        Value::obj(vec![
            ("name", Value::Str(self.name.clone())),
            ("iters", Value::Num(self.iters as f64)),
            ("median_ns", Value::Num(self.median_ns)),
            ("mean_ns", Value::Num(self.mean_ns)),
            ("p95_ns", Value::Num(self.p95_ns)),
            (
                "items_per_iter",
                self.items_per_iter.map_or(Value::Null, Value::Num),
            ),
            ("ns_per_item", Value::Num(self.ns_per_item())),
            (
                "throughput_per_s",
                self.throughput_per_s().map_or(Value::Null, Value::Num),
            ),
        ])
    }

    pub fn render(&self) -> String {
        let thr = match self.throughput_per_s() {
            Some(t) if t >= 1e6 => format!("  {:8.2} Mitem/s", t / 1e6),
            Some(t) if t >= 1e3 => format!("  {:8.2} Kitem/s", t / 1e3),
            Some(t) => format!("  {t:8.2} item/s"),
            None => String::new(),
        };
        format!(
            "{:<44} {:>12} median  {:>12} mean  {:>12} p95  ({} iters){}",
            self.name,
            fmt_ns(self.median_ns),
            fmt_ns(self.mean_ns),
            fmt_ns(self.p95_ns),
            self.iters,
            thr
        )
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0}ns")
    } else if ns < 1e6 {
        format!("{:.2}us", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.3}s", ns / 1e9)
    }
}

/// Benchmark runner with a global time budget per case.
pub struct Bencher {
    pub warmup_iters: usize,
    pub min_iters: usize,
    pub max_iters: usize,
    pub budget_s: f64,
    results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup_iters: 3,
            min_iters: 10,
            max_iters: 10_000,
            budget_s: 2.0,
            results: Vec::new(),
        }
    }
}

impl Bencher {
    pub fn quick() -> Self {
        Bencher {
            warmup_iters: 1,
            min_iters: 3,
            max_iters: 200,
            budget_s: 0.5,
            ..Default::default()
        }
    }

    /// Time `f`, which returns a value that is black-boxed to defeat DCE.
    pub fn bench<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &BenchResult {
        self.bench_with_items(name, None, &mut f)
    }

    /// Same, declaring how many logical items one iteration processes.
    pub fn bench_items<T>(
        &mut self,
        name: &str,
        items: f64,
        mut f: impl FnMut() -> T,
    ) -> &BenchResult {
        self.bench_with_items(name, Some(items), &mut f)
    }

    fn bench_with_items<T>(
        &mut self,
        name: &str,
        items: Option<f64>,
        f: &mut dyn FnMut() -> T,
    ) -> &BenchResult {
        for _ in 0..self.warmup_iters {
            black_box(f());
        }
        let mut samples_ns: Vec<f64> = Vec::new();
        let start = Instant::now();
        while samples_ns.len() < self.min_iters
            || (start.elapsed().as_secs_f64() < self.budget_s
                && samples_ns.len() < self.max_iters)
        {
            let t0 = Instant::now();
            black_box(f());
            samples_ns.push(t0.elapsed().as_nanos() as f64);
        }
        let res = BenchResult {
            name: name.to_string(),
            iters: samples_ns.len(),
            mean_ns: stats::mean(&samples_ns),
            median_ns: stats::median(&samples_ns),
            p95_ns: stats::quantile(&samples_ns, 0.95),
            items_per_iter: items,
        };
        println!("{}", res.render());
        self.results.push(res);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Write all recorded results as machine-readable JSON (one object per
    /// bench, keyed per-bench ns/item) so successive PRs can track the
    /// perf trajectory — e.g. `BENCH_hotpaths.json` from `bench_hotpaths`.
    pub fn save_json(&self, path: &Path) -> Result<()> {
        let doc = Value::obj(vec![
            ("kind", Value::Str("powertrain-bench-v1".into())),
            (
                "benches",
                Value::Arr(self.results.iter().map(|r| r.to_json()).collect()),
            ),
        ]);
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        std::fs::write(path, doc.to_string())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let mut b = Bencher::quick();
        let r = b.bench("spin", || {
            let mut acc = 0u64;
            for i in 0..1000u64 {
                acc = acc.wrapping_add(i * i);
            }
            acc
        });
        assert!(r.median_ns > 0.0);
        assert!(r.iters >= 3);
    }

    #[test]
    fn throughput_derived_from_median() {
        let mut b = Bencher::quick();
        let r = b.bench_items("noop-batch", 1000.0, || 42u8).clone();
        let thr = r.throughput_per_s().unwrap();
        assert!(thr > 0.0);
    }

    #[test]
    fn json_report_round_trips() {
        let mut b = Bencher::quick();
        b.bench_items("alpha", 100.0, || 1u8);
        b.bench("beta", || 2u8);
        let path = std::env::temp_dir().join("pt_bench_json").join("r.json");
        b.save_json(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let v = Value::parse(&text).unwrap();
        let benches = v.req("benches").unwrap().as_arr().unwrap();
        assert_eq!(benches.len(), 2);
        let first = &benches[0];
        assert_eq!(first.req("name").unwrap().as_str().unwrap(), "alpha");
        assert!(first.req("ns_per_item").unwrap().as_f64().unwrap() > 0.0);
        std::fs::remove_dir_all(path.parent().unwrap()).ok();
    }

    #[test]
    fn ns_formatting() {
        assert_eq!(fmt_ns(500.0), "500ns");
        assert_eq!(fmt_ns(1500.0), "1.50us");
        assert_eq!(fmt_ns(2.5e6), "2.50ms");
        assert_eq!(fmt_ns(3.0e9), "3.000s");
    }
}
