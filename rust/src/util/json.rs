//! Minimal JSON codec (no serde in the offline build environment).
//!
//! Supports the full JSON grammar needed by the artifact manifest, NN
//! checkpoints and experiment reports: objects, arrays, strings with
//! escapes, f64 numbers, booleans, null.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::error::{Error, Result};

/// Parsed JSON value. Objects use a BTreeMap so serialization is
/// deterministic (stable diffs for checkpoints and reports).
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn parse(text: &str) -> Result<Value> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(Error::json(format!(
                "trailing content at byte {}",
                p.pos
            )));
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn req(&self, key: &str) -> Result<&Value> {
        self.get(key)
            .ok_or_else(|| Error::json(format!("missing key '{key}'")))
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Value::Num(n) => Ok(*n),
            _ => Err(Error::json("expected number")),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let f = self.as_f64()?;
        if f < 0.0 || f.fract() != 0.0 {
            return Err(Error::json(format!("expected non-negative integer, got {f}")));
        }
        Ok(f as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            _ => Err(Error::json("expected string")),
        }
    }

    pub fn as_arr(&self) -> Result<&[Value]> {
        match self {
            Value::Arr(v) => Ok(v),
            _ => Err(Error::json("expected array")),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(m) => Ok(m),
            _ => Err(Error::json("expected object")),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            _ => Err(Error::json("expected bool")),
        }
    }

    /// Array of f64 (checkpoint tensors).
    pub fn as_f64_vec(&self) -> Result<Vec<f64>> {
        self.as_arr()?.iter().map(|v| v.as_f64()).collect()
    }

    /// Array of f32 (checkpoint tensors).
    pub fn as_f32_vec(&self) -> Result<Vec<f32>> {
        Ok(self.as_f64_vec()?.into_iter().map(|x| x as f32).collect())
    }

    // -- builders ----------------------------------------------------------

    pub fn obj(entries: Vec<(&str, Value)>) -> Value {
        Value::Obj(
            entries
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }

    pub fn from_f32_slice(xs: &[f32]) -> Value {
        Value::Arr(xs.iter().map(|&x| Value::Num(x as f64)).collect())
    }

    pub fn from_f64_slice(xs: &[f64]) -> Value {
        Value::Arr(xs.iter().map(|&x| Value::Num(x)).collect())
    }

    // -- serialization -----------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(n) => write_num(*n, out),
            Value::Str(s) => write_str(s, out),
            Value::Arr(items) => {
                out.push('[');
                for (i, it) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    it.write(out);
                }
                out.push(']');
            }
            Value::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_num(n: f64, out: &mut String) {
    if n.is_finite() {
        if n.fract() == 0.0 && n.abs() < 1e15 {
            let _ = write!(out, "{}", n as i64);
        } else {
            // 17 significant digits round-trips every f64
            let _ = write!(out, "{n:?}");
        }
    } else {
        // JSON has no inf/nan; null is the conventional fallback
        out.push_str("null");
    }
}

fn write_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(Error::json(format!(
                "expected '{}' at byte {}",
                b as char, self.pos
            )))
        }
    }

    fn value(&mut self) -> Result<Value> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(Error::json(format!("unexpected byte at {}", self.pos))),
        }
    }

    fn literal(&mut self, word: &str, v: Value) -> Result<Value> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(Error::json(format!("bad literal at byte {}", self.pos)))
        }
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.pos;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit()
                || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E')
            {
                self.pos += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| Error::json("invalid utf8 in number"))?;
        s.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| Error::json(format!("bad number '{s}'")))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(Error::json("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{0008}'),
                        Some(b'f') => out.push('\u{000C}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(Error::json("truncated \\u escape"));
                            }
                            let hex = std::str::from_utf8(
                                &self.bytes[self.pos + 1..self.pos + 5],
                            )
                            .map_err(|_| Error::json("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| Error::json("bad \\u escape"))?;
                            out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.pos += 4;
                        }
                        _ => return Err(Error::json("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 code point
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| Error::json("invalid utf8 in string"))?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Value::Arr(items));
                }
                _ => return Err(Error::json(format!("bad array at byte {}", self.pos))),
            }
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Value::Obj(map));
                }
                _ => return Err(Error::json(format!("bad object at byte {}", self.pos))),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Value::parse("42").unwrap(), Value::Num(42.0));
        assert_eq!(Value::parse("-1.5e3").unwrap(), Value::Num(-1500.0));
        assert_eq!(Value::parse("true").unwrap(), Value::Bool(true));
        assert_eq!(Value::parse("null").unwrap(), Value::Null);
        assert_eq!(
            Value::parse("\"a\\nb\"").unwrap(),
            Value::Str("a\nb".into())
        );
    }

    #[test]
    fn parse_nested() {
        let v = Value::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.req("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.req("a").unwrap().as_arr().unwrap()[2]
                .req("b")
                .unwrap()
                .as_str()
                .unwrap(),
            "c"
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Value::parse("{").is_err());
        assert!(Value::parse("[1,]").is_err());
        assert!(Value::parse("1 2").is_err());
        assert!(Value::parse("\"unterminated").is_err());
        assert!(Value::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn round_trip() {
        let src = r#"{"adam":{"lr":0.001},"hidden":[256,128,64],"name":"pt \"x\"","ok":true,"z":null}"#;
        let v = Value::parse(src).unwrap();
        let re = Value::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn float_precision_round_trips() {
        let v = Value::Num(0.1 + 0.2);
        let re = Value::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn f32_slice_round_trip() {
        let xs = vec![1.5f32, -2.25, 0.0, 3.0e-7];
        let v = Value::from_f32_slice(&xs);
        let back = Value::parse(&v.to_string()).unwrap().as_f32_vec().unwrap();
        assert_eq!(xs, back);
    }

    #[test]
    fn unicode_strings() {
        let v = Value::parse("\"héllo \\u00e9\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo é");
    }

    #[test]
    fn as_usize_validation() {
        assert_eq!(Value::Num(5.0).as_usize().unwrap(), 5);
        assert!(Value::Num(5.5).as_usize().is_err());
        assert!(Value::Num(-1.0).as_usize().is_err());
    }
}
