//! A hand-rolled, std-only atomically swappable `Arc<T>` cell.
//!
//! `ArcCell<T>` is the publication primitive behind the coordinator's
//! lock-free snapshot reads (arc-swap style, but dependency-free): one
//! writer [`store`](ArcCell::store)s a freshly built immutable value while
//! any number of readers [`load`](ArcCell::load) the current one without
//! ever touching a mutex. Readers are wait-free in the common case (four
//! atomic ops) and never block writers for longer than the instant between
//! pinning a slot and cloning the `Arc` out of it.
//!
//! # Protocol
//!
//! The cell keeps **two slots**, each a `(pointer, reader-pin count)`
//! pair, plus a `current` index naming the live slot:
//!
//! - **Readers** load `current`, pin that slot by bumping its reader
//!   count, then *re-check* `current`. If it still names the pinned slot,
//!   the pointer is guaranteed live (see below) — clone the `Arc`, unpin,
//!   done. If the check fails (a writer flipped slots underneath), unpin
//!   and retry; no dereference happened, so the stale pointer is never
//!   touched.
//! - **Writers** (serialized by a private mutex) install the new value in
//!   the *spare* slot, flip `current` to it, then retire the old slot:
//!   spin until its reader count drains to zero, and only then drop the
//!   cell's reference to the old value.
//!
//! # Why readers can't tear or use-after-free
//!
//! All `current`/reader-count operations are `SeqCst`, so they form one
//! total order. A reader dereferences a slot pointer only after its pin
//! *and* a passing re-check of `current`. If the re-check observed
//! `current == i`, the pin precedes the re-check precedes any writer's
//! flip away from `i` in the total order — so when that writer later
//! spins on slot `i`'s reader count before dropping the value, it is
//! guaranteed to observe this reader's pin and wait for it. Conversely, a
//! reader that pins *after* the flip fails the re-check and never
//! dereferences. Either way no pointer is dropped while a dereferencing
//! reader holds it, and a load returns exactly the value from one
//! `store` — never a torn mixture.

use std::ptr;
use std::sync::atomic::{AtomicPtr, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::util::sync::lock_unpoisoned;

struct Slot<T> {
    /// Owning pointer (`Arc::into_raw`) to this slot's value; null while
    /// the slot is spare (between stores).
    ptr: AtomicPtr<T>,
    /// Number of readers currently pinning this slot.
    readers: AtomicUsize,
}

impl<T> Slot<T> {
    fn empty() -> Self {
        Slot { ptr: AtomicPtr::new(ptr::null_mut()), readers: AtomicUsize::new(0) }
    }
}

/// An atomically swappable `Arc<T>`: lock-free reads, serialized writes.
///
/// See the [module docs](self) for the two-slot pin/re-check protocol and
/// its safety argument.
pub struct ArcCell<T> {
    slots: [Slot<T>; 2],
    current: AtomicUsize,
    writer: Mutex<()>,
}

// The cell hands out `Arc<T>` clones across threads and drops T from the
// writer thread, so it needs exactly the bounds `Arc<T>` itself needs to
// be Send + Sync.
unsafe impl<T: Send + Sync> Send for ArcCell<T> {}
unsafe impl<T: Send + Sync> Sync for ArcCell<T> {}

impl<T> ArcCell<T> {
    /// Create a cell holding `value`. The cell is never empty: `load`
    /// always returns the most recently stored value.
    pub fn new(value: Arc<T>) -> Self {
        let cell = ArcCell {
            slots: [Slot::empty(), Slot::empty()],
            current: AtomicUsize::new(0),
            writer: Mutex::new(()),
        };
        cell.slots[0].ptr.store(Arc::into_raw(value) as *mut T, Ordering::Release);
        cell
    }

    /// Clone out the current value without taking any lock.
    ///
    /// Wait-free unless a concurrent `store` flips slots between the pin
    /// and the re-check, in which case the reader retries (at most once
    /// per concurrent store).
    pub fn load(&self) -> Arc<T> {
        loop {
            let idx = self.current.load(Ordering::SeqCst) & 1;
            let slot = &self.slots[idx];
            slot.readers.fetch_add(1, Ordering::SeqCst);
            if self.current.load(Ordering::SeqCst) & 1 == idx {
                // Pinned while current: the writer retires this slot only
                // after flipping `current` away and draining its pins, so
                // the pointer stays live until we unpin.
                let raw = slot.ptr.load(Ordering::Acquire);
                let arc = unsafe {
                    Arc::increment_strong_count(raw);
                    Arc::from_raw(raw)
                };
                slot.readers.fetch_sub(1, Ordering::SeqCst);
                return arc;
            }
            // A writer flipped underneath us before we could pin; back
            // off and retry against the new current slot.
            slot.readers.fetch_sub(1, Ordering::SeqCst);
            std::hint::spin_loop();
        }
    }

    /// Publish `value` as the new current value, dropping the cell's
    /// reference to the old one once all in-flight readers are done with
    /// it. Concurrent stores are serialized; readers never block.
    pub fn store(&self, value: Arc<T>) {
        let _writer = lock_unpoisoned(&self.writer);
        let cur = self.current.load(Ordering::SeqCst) & 1;
        let next = 1 - cur;
        // Wait out readers that pinned the spare slot with a stale index;
        // they fail their re-check and unpin without dereferencing.
        while self.slots[next].readers.load(Ordering::SeqCst) != 0 {
            std::thread::yield_now();
        }
        self.slots[next].ptr.store(Arc::into_raw(value) as *mut T, Ordering::Release);
        self.current.store(next, Ordering::SeqCst);
        // Retire the old current slot: once its pinned readers finish,
        // nothing can reach the pointer again (new pins re-check
        // `current`), so the cell's reference can be dropped.
        while self.slots[cur].readers.load(Ordering::SeqCst) != 0 {
            std::thread::yield_now();
        }
        let retired = self.slots[cur].ptr.swap(ptr::null_mut(), Ordering::AcqRel);
        debug_assert!(!retired.is_null(), "retired slot lost its value");
        if !retired.is_null() {
            unsafe { drop(Arc::from_raw(retired)) };
        }
    }
}

impl<T: Default> Default for ArcCell<T> {
    fn default() -> Self {
        ArcCell::new(Arc::new(T::default()))
    }
}

impl<T> Drop for ArcCell<T> {
    fn drop(&mut self) {
        for slot in &self.slots {
            let raw = slot.ptr.swap(ptr::null_mut(), Ordering::AcqRel);
            if !raw.is_null() {
                unsafe { drop(Arc::from_raw(raw)) };
            }
        }
    }
}

impl<T: std::fmt::Debug> std::fmt::Debug for ArcCell<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_tuple("ArcCell").field(&self.load()).finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_returns_the_stored_value() {
        let cell = ArcCell::new(Arc::new(41u64));
        assert_eq!(*cell.load(), 41);
        cell.store(Arc::new(42));
        assert_eq!(*cell.load(), 42);
    }

    #[test]
    fn default_wraps_the_default_value() {
        let cell: ArcCell<Vec<u32>> = ArcCell::default();
        assert!(cell.load().is_empty());
    }

    #[test]
    fn store_drops_exactly_the_superseded_value() {
        let first = Arc::new(1u32);
        let cell = ArcCell::new(Arc::clone(&first));
        assert_eq!(Arc::strong_count(&first), 2);
        cell.store(Arc::new(2));
        // the cell released its reference to `first` on supersession
        assert_eq!(Arc::strong_count(&first), 1);
        let second = cell.load();
        assert_eq!(*second, 2);
        drop(cell);
        // dropping the cell releases the current value too
        assert_eq!(Arc::strong_count(&second), 1);
    }

    /// The tearing/UAF gauntlet: readers hammer `load` while a writer
    /// storms `store`. Every observed value must be one the writer
    /// actually published, with its internal pair intact — the
    /// pin/re-check protocol forbids torn or freed snapshots.
    #[test]
    fn concurrent_readers_always_see_a_published_pair() {
        const WRITES: u64 = 2_000;
        const READERS: usize = 6;
        // the value is a pair that must always match; a use-after-free or
        // torn publication would break the invariant (or crash under
        // address sanitizers)
        let cell = ArcCell::new(Arc::new((0u64, 0u64)));
        std::thread::scope(|scope| {
            for _ in 0..READERS {
                scope.spawn(|| {
                    let mut last = 0u64;
                    loop {
                        let snap = cell.load();
                        assert_eq!(snap.0, snap.1, "torn snapshot");
                        // publications are observed in order, never rolled back
                        assert!(snap.0 >= last, "snapshot went backwards");
                        last = snap.0;
                        if snap.0 == WRITES {
                            return;
                        }
                    }
                });
            }
            scope.spawn(|| {
                for v in 1..=WRITES {
                    cell.store(Arc::new((v, v)));
                }
            });
        });
        assert_eq!(*cell.load(), (WRITES, WRITES));
    }
}
