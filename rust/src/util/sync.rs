//! Poison-recovering lock helpers for the serving stack.
//!
//! A worker thread that panics while holding a shared `Mutex` poisons it;
//! every later `.lock().unwrap()` then panics too, so one bad request
//! could cascade into killing every worker and wedging the whole serve
//! call. The coordinator's shared state (queues, caches, metric vectors)
//! is kept consistent at every await-free critical section — each guard
//! scope either completes its update or leaves the structure as it found
//! it — so recovering the guard from a `PoisonError` is sound: the data
//! is valid, only the "a panic happened" flag is set. These helpers make
//! that recovery the default and keep the intent greppable.

use std::sync::{Condvar, Mutex, MutexGuard, PoisonError, WaitTimeoutResult};
use std::time::Duration;

/// Lock `m`, recovering the guard if a previous holder panicked.
pub fn lock_unpoisoned<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// `Condvar::wait` that survives a poisoned mutex.
pub fn wait_unpoisoned<'a, T>(cv: &Condvar, guard: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
    cv.wait(guard).unwrap_or_else(PoisonError::into_inner)
}

/// `Condvar::wait_timeout` that survives a poisoned mutex.
pub fn wait_timeout_unpoisoned<'a, T>(
    cv: &Condvar,
    guard: MutexGuard<'a, T>,
    dur: Duration,
) -> (MutexGuard<'a, T>, WaitTimeoutResult) {
    cv.wait_timeout(guard, dur)
        .unwrap_or_else(PoisonError::into_inner)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::panic::{catch_unwind, AssertUnwindSafe};
    use std::sync::{Arc, Condvar, Mutex};

    #[test]
    fn lock_recovers_after_poisoning() {
        let m = Arc::new(Mutex::new(7u32));
        let m2 = Arc::clone(&m);
        let res = catch_unwind(AssertUnwindSafe(move || {
            let _g = m2.lock().unwrap();
            panic!("holder dies");
        }));
        assert!(res.is_err());
        assert!(m.lock().is_err(), "mutex must actually be poisoned");
        // the recovered guard still reads and writes coherent data
        let mut g = lock_unpoisoned(&m);
        assert_eq!(*g, 7);
        *g = 8;
        drop(g);
        assert_eq!(*lock_unpoisoned(&m), 8);
    }

    #[test]
    fn wait_timeout_returns_on_timeout() {
        let m = Mutex::new(());
        let cv = Condvar::new();
        let g = lock_unpoisoned(&m);
        let (_g, timeout) = wait_timeout_unpoisoned(&cv, g, Duration::from_millis(5));
        assert!(timeout.timed_out());
    }
}
