//! Minimal CSV codec for the profiling corpus and experiment result files.
//!
//! Supports quoted fields with embedded commas/quotes/newlines — enough for
//! robust round-tripping of our own files plus hand-edited ones.

use std::fs;
use std::path::Path;

use crate::error::{Error, Result};

/// A parsed CSV table: header + rows of equal width.
#[derive(Debug, Clone, PartialEq)]
pub struct Table {
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Table {
        Table {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn push_row(&mut self, row: Vec<String>) {
        debug_assert_eq!(row.len(), self.header.len(), "row width mismatch");
        self.rows.push(row);
    }

    /// Column index by name.
    pub fn col(&self, name: &str) -> Result<usize> {
        self.header
            .iter()
            .position(|h| h == name)
            .ok_or_else(|| Error::csv(format!("missing column '{name}'")))
    }

    /// Typed accessor.
    pub fn f64_at(&self, row: usize, col: usize) -> Result<f64> {
        self.rows[row][col]
            .parse::<f64>()
            .map_err(|_| Error::csv(format!("bad f64 '{}' at row {row}", self.rows[row][col])))
    }

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        write_record(&self.header, &mut out);
        for row in &self.rows {
            write_record(row, &mut out);
        }
        out
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            fs::create_dir_all(dir)?;
        }
        fs::write(path, self.to_string())?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Table> {
        Self::parse(&fs::read_to_string(path)?)
    }

    pub fn parse(text: &str) -> Result<Table> {
        let records = parse_records(text)?;
        let mut it = records.into_iter();
        let header = it
            .next()
            .ok_or_else(|| Error::csv("empty csv"))?;
        let rows: Vec<Vec<String>> = it.collect();
        for (i, r) in rows.iter().enumerate() {
            if r.len() != header.len() {
                return Err(Error::csv(format!(
                    "row {} has {} fields, header has {}",
                    i + 1,
                    r.len(),
                    header.len()
                )));
            }
        }
        Ok(Table { header, rows })
    }
}

fn needs_quoting(field: &str) -> bool {
    field.contains(',') || field.contains('"') || field.contains('\n') || field.contains('\r')
}

fn write_record(fields: &[String], out: &mut String) {
    for (i, f) in fields.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        if needs_quoting(f) {
            out.push('"');
            out.push_str(&f.replace('"', "\"\""));
            out.push('"');
        } else {
            out.push_str(f);
        }
    }
    out.push('\n');
}

fn parse_records(text: &str) -> Result<Vec<Vec<String>>> {
    let mut records = Vec::new();
    let mut record = Vec::new();
    let mut field = String::new();
    let mut chars = text.chars().peekable();
    let mut in_quotes = false;
    let mut any = false;

    while let Some(c) = chars.next() {
        any = true;
        if in_quotes {
            match c {
                '"' => {
                    if chars.peek() == Some(&'"') {
                        chars.next();
                        field.push('"');
                    } else {
                        in_quotes = false;
                    }
                }
                c => field.push(c),
            }
        } else {
            match c {
                '"' => in_quotes = true,
                ',' => {
                    record.push(std::mem::take(&mut field));
                }
                '\r' => {} // tolerate CRLF
                '\n' => {
                    record.push(std::mem::take(&mut field));
                    records.push(std::mem::take(&mut record));
                }
                c => field.push(c),
            }
        }
    }
    if in_quotes {
        return Err(Error::csv("unterminated quoted field"));
    }
    if any && (!field.is_empty() || !record.is_empty()) {
        record.push(field);
        records.push(record);
    }
    Ok(records)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn simple_round_trip() {
        let mut t = Table::new(&["a", "b"]);
        t.push_row(vec!["1".into(), "2".into()]);
        t.push_row(vec!["3".into(), "4".into()]);
        let parsed = Table::parse(&t.to_string()).unwrap();
        assert_eq!(parsed, t);
    }

    #[test]
    fn quoted_fields() {
        let mut t = Table::new(&["name", "note"]);
        t.push_row(vec!["a,b".into(), "say \"hi\"\nline2".into()]);
        let parsed = Table::parse(&t.to_string()).unwrap();
        assert_eq!(parsed, t);
    }

    #[test]
    fn missing_trailing_newline_ok() {
        let t = Table::parse("a,b\n1,2").unwrap();
        assert_eq!(t.rows, vec![vec!["1".to_string(), "2".to_string()]]);
    }

    #[test]
    fn ragged_rows_rejected() {
        assert!(Table::parse("a,b\n1\n").is_err());
    }

    #[test]
    fn unterminated_quote_rejected() {
        assert!(Table::parse("a\n\"oops\n").is_err());
    }

    #[test]
    fn empty_rejected() {
        assert!(Table::parse("").is_err());
    }

    #[test]
    fn col_lookup_and_typed_access() {
        let t = Table::parse("x,y\n1.5,hello\n").unwrap();
        assert_eq!(t.col("y").unwrap(), 1);
        assert!(t.col("z").is_err());
        assert_eq!(t.f64_at(0, 0).unwrap(), 1.5);
        assert!(t.f64_at(0, 1).is_err());
    }

    #[test]
    fn crlf_tolerated() {
        let t = Table::parse("a,b\r\n1,2\r\n").unwrap();
        assert_eq!(t.rows.len(), 1);
        assert_eq!(t.rows[0], vec!["1".to_string(), "2".to_string()]);
    }
}
