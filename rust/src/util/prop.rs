//! Mini property-based testing framework (proptest is unavailable offline).
//!
//! Provides seeded generators and a `forall` runner with counterexample
//! reporting and greedy shrinking for the common scalar/vec cases. Used by
//! the `property_suite` integration test to check coordinator/routing/
//! Pareto/grid invariants.

use super::rng::Rng;

/// A generator of random values of `T` driven by the shared [`Rng`].
pub struct Gen<T> {
    f: Box<dyn Fn(&mut Rng) -> T>,
}

impl<T: 'static> Gen<T> {
    pub fn new(f: impl Fn(&mut Rng) -> T + 'static) -> Self {
        Gen { f: Box::new(f) }
    }

    pub fn sample(&self, rng: &mut Rng) -> T {
        (self.f)(rng)
    }

    pub fn map<U: 'static>(self, g: impl Fn(T) -> U + 'static) -> Gen<U> {
        Gen::new(move |r| g(self.sample(r)))
    }
}

/// Uniform usize in [lo, hi].
pub fn usize_in(lo: usize, hi: usize) -> Gen<usize> {
    assert!(lo <= hi);
    Gen::new(move |r| lo + r.below(hi - lo + 1))
}

/// Uniform f64 in [lo, hi).
pub fn f64_in(lo: f64, hi: f64) -> Gen<f64> {
    Gen::new(move |r| r.uniform_range(lo, hi))
}

/// Vec of length in [min_len, max_len] of element gen.
pub fn vec_of<T: 'static>(elem: Gen<T>, min_len: usize, max_len: usize) -> Gen<Vec<T>> {
    Gen::new(move |r| {
        let n = min_len + r.below(max_len - min_len + 1);
        (0..n).map(|_| elem.sample(r)).collect()
    })
}

/// One of the given options, uniformly.
pub fn one_of<T: Clone + 'static>(options: Vec<T>) -> Gen<T> {
    assert!(!options.is_empty());
    Gen::new(move |r| options[r.below(options.len())].clone())
}

/// Outcome of a property check.
#[derive(Debug)]
pub enum PropResult {
    Ok { cases: usize },
    Failed { case: String, seed: u64 },
}

/// Run `prop` against `cases` random inputs from `gen`. Panics with the
/// (shrunk, where supported) counterexample on failure — the standard
/// property-testing contract for use inside `#[test]` fns.
pub fn forall<T: std::fmt::Debug + Clone + 'static>(
    seed: u64,
    cases: usize,
    gen: &Gen<T>,
    prop: impl Fn(&T) -> bool,
) {
    let mut rng = Rng::new(seed);
    for case_idx in 0..cases {
        let input = gen.sample(&mut rng);
        if !prop(&input) {
            panic!(
                "property failed on case {case_idx}/{cases} (seed {seed}):\n  input = {input:?}"
            );
        }
    }
}

/// forall for Vec<f64> with greedy shrinking: tries to remove elements and
/// zero them while the property still fails, reporting a minimal-ish case.
pub fn forall_vec_f64(
    seed: u64,
    cases: usize,
    gen: &Gen<Vec<f64>>,
    prop: impl Fn(&[f64]) -> bool,
) {
    let mut rng = Rng::new(seed);
    for case_idx in 0..cases {
        let input = gen.sample(&mut rng);
        if !prop(&input) {
            let shrunk = shrink_vec(&input, &prop);
            panic!(
                "property failed on case {case_idx}/{cases} (seed {seed}):\n  shrunk input = {shrunk:?}\n  original len = {}",
                input.len()
            );
        }
    }
}

fn shrink_vec(failing: &[f64], prop: &impl Fn(&[f64]) -> bool) -> Vec<f64> {
    let mut cur = failing.to_vec();
    loop {
        let mut improved = false;
        // try dropping each element
        let mut i = 0;
        while i < cur.len() {
            let mut cand = cur.clone();
            cand.remove(i);
            if !prop(&cand) {
                cur = cand;
                improved = true;
            } else {
                i += 1;
            }
        }
        // try zeroing / simplifying values
        for i in 0..cur.len() {
            for replacement in [0.0, 1.0, cur[i].trunc()] {
                if cur[i] != replacement {
                    let mut cand = cur.clone();
                    cand[i] = replacement;
                    if !prop(&cand) {
                        cur = cand;
                        improved = true;
                    }
                }
            }
        }
        if !improved {
            return cur;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let gen = vec_of(f64_in(-10.0, 10.0), 0, 32);
        forall(1, 200, &gen, |v| v.len() <= 32);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_case() {
        let gen = usize_in(0, 100);
        forall(2, 500, &gen, |&n| n < 90);
    }

    #[test]
    fn shrinking_finds_small_counterexample() {
        // property: sum < 25 — fails for vectors with large sums; shrinker
        // should reduce to something small
        let failing = vec![9.7, 8.2, 3.1, 7.9, 2.2];
        let shrunk = shrink_vec(&failing, &|v: &[f64]| v.iter().sum::<f64>() < 25.0);
        assert!(shrunk.len() <= failing.len());
        assert!(shrunk.iter().sum::<f64>() >= 25.0);
        // all elements simplified to integers where possible
        assert!(shrunk.iter().all(|x| x.fract() == 0.0 || failing.contains(x)));
    }

    #[test]
    fn one_of_stays_in_options() {
        let gen = one_of(vec!["a", "b", "c"]);
        forall(3, 100, &gen, |s| ["a", "b", "c"].contains(s));
    }
}
