//! Dependency-light utility substrates.
//!
//! The build environment is fully offline, so the usual ecosystem crates
//! (rand, serde, serde_json, csv, proptest, criterion) are replaced by
//! small, tested, purpose-built implementations (DESIGN.md section 3).

pub mod arc_cell;
pub mod bench;
pub mod csv;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod sync;
pub mod table;
