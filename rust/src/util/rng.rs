//! Deterministic, splittable PRNG (xoshiro256++ seeded via splitmix64).
//!
//! Every stochastic component (simulator noise, sampling strategies,
//! dropout keys, experiment repetitions) draws from a seeded [`Rng`] so
//! whole experiment suites replay bit-identically.

/// xoshiro256++ PRNG. Not cryptographic; excellent statistical quality for
/// simulation workloads.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed deterministically; any u64 works (including 0).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Derive an independent child stream (for per-task determinism that is
    /// robust to reordering of sibling tasks).
    pub fn split(&mut self, tag: u64) -> Rng {
        let mut sm = self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn uniform_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n). Rejection-free Lemire reduction.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0)");
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.uniform();
            if u1 > 1e-12 {
                let u2 = self.uniform();
                return (-2.0 * u1.ln()).sqrt()
                    * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Normal with given mean / standard deviation.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Log-normal multiplicative jitter with unit median and the given
    /// sigma of log-space (used for minibatch time noise).
    pub fn lognormal_jitter(&mut self, sigma: f64) -> f64 {
        (self.normal() * sigma).exp()
    }

    /// Bernoulli trial.
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (k <= n), in random order.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample_indices: k={k} > n={n}");
        let mut idx: Vec<usize> = (0..n).collect();
        // partial Fisher–Yates: only the first k positions need settling
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Raw 2-word key material for the jax threefry PRNG inputs.
    pub fn jax_key(&mut self) -> [u32; 2] {
        [self.next_u32(), self.next_u32()]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_replay() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn split_streams_are_independent_of_sibling_count() {
        let mut parent1 = Rng::new(3);
        let child_a = parent1.split(42);
        let mut parent2 = Rng::new(3);
        let child_b = parent2.split(42);
        let mut ca = child_a.clone();
        let mut cb = child_b.clone();
        for _ in 0..16 {
            assert_eq!(ca.next_u64(), cb.next_u64());
        }
    }

    #[test]
    fn uniform_in_unit_interval_and_roughly_uniform() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(13);
        let n = 200_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(17);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn sample_indices_distinct_and_in_range() {
        let mut r = Rng::new(19);
        for &(n, k) in &[(10usize, 10usize), (100, 5), (4386, 50), (1, 1)] {
            let s = r.sample_indices(n, k);
            assert_eq!(s.len(), k);
            let mut sorted = s.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), k, "duplicates for n={n} k={k}");
            assert!(s.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(23);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn lognormal_jitter_positive_and_centered() {
        let mut r = Rng::new(29);
        let n = 50_000;
        let mut sum_log = 0.0;
        for _ in 0..n {
            let j = r.lognormal_jitter(0.015);
            assert!(j > 0.0);
            sum_log += j.ln();
        }
        assert!((sum_log / n as f64).abs() < 1e-3);
    }
}
