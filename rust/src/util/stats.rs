//! Statistics helpers shared by the profiler, experiments and benches.

/// Arithmetic mean. Returns 0.0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Linear-interpolated quantile, q in [0, 1].
///
/// Non-finite inputs are handled explicitly instead of panicking: the
/// sort uses `f64::total_cmp` (a single NaN — e.g. the MAPE of a
/// diverged checkpoint in an experiments table — used to panic the
/// whole report through `partial_cmp().unwrap()`). NaN entries carry no
/// order information and are filtered out; ±inf entries are *kept* — a
/// diverged metric must stay visible in tail quantiles, so they take
/// their natural place in the order (interpolation against a non-finite
/// neighbor degrades to nearest-rank rather than manufacturing NaN).
/// An all-NaN input propagates NaN; an empty input stays 0.0 (the
/// historical convention callers rely on).
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    assert!((0.0..=1.0).contains(&q), "quantile q={q}");
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.iter().copied().filter(|x| !x.is_nan()).collect();
    if v.is_empty() {
        return f64::NAN; // every entry was NaN: propagate, don't invent
    }
    v.sort_unstable_by(f64::total_cmp);
    quantile_sorted(&v, q)
}

/// Explicitly *linear-interpolating* quantile.
///
/// Alias of [`quantile`], which has interpolated (position `q·(n−1)`,
/// the numpy `linear` / R type-7 convention) since the PR-3 host-training
/// work — the name exists so latency-reporting call sites can state the
/// tail-quantile semantics they rely on: p999 over a small sample is
/// interpolated between order statistics, not quantized to the nearest
/// observed value the way a nearest-rank estimator would.
pub fn quantile_linear(xs: &[f64], q: f64) -> f64 {
    quantile(xs, q)
}

/// The interpolation core of [`quantile`], for callers that take many
/// quantiles of one sample (latency p50/p95/p99/p999 reports): sort once
/// with `f64::total_cmp` (NaN filtered out), then call this per `q`.
///
/// `v` must be non-empty and sorted ascending with no NaN entries; ±inf
/// is allowed and handled as in [`quantile`] (nearest rank when an
/// interpolation neighbor is non-finite, so inf − inf never manufactures
/// NaN).
pub fn quantile_sorted(v: &[f64], q: f64) -> f64 {
    assert!((0.0..=1.0).contains(&q), "quantile q={q}");
    assert!(!v.is_empty(), "quantile_sorted needs a non-empty sample");
    debug_assert!(v.windows(2).all(|w| w[0].total_cmp(&w[1]).is_le()), "input not sorted");
    let pos = q * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi || v[lo] == v[hi] {
        v[lo]
    } else if !v[lo].is_finite() || !v[hi].is_finite() {
        // nearest rank: inf − inf interpolation would produce NaN
        if pos - lo as f64 >= 0.5 { v[hi] } else { v[lo] }
    } else {
        v[lo] + (pos - lo as f64) * (v[hi] - v[lo])
    }
}

pub fn median(xs: &[f64]) -> f64 {
    quantile(xs, 0.5)
}

/// Median with Q1/Q3 whiskers, as the paper reports across repetitions.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MedianIqr {
    pub median: f64,
    pub q1: f64,
    pub q3: f64,
}

pub fn median_iqr(xs: &[f64]) -> MedianIqr {
    MedianIqr {
        median: quantile(xs, 0.5),
        q1: quantile(xs, 0.25),
        q3: quantile(xs, 0.75),
    }
}

/// Mean Absolute Percentage Error (%) — the paper's headline metric.
/// Entries with |truth| < eps are skipped to avoid division blowups.
pub fn mape(pred: &[f64], truth: &[f64]) -> f64 {
    assert_eq!(pred.len(), truth.len(), "mape length mismatch");
    let eps = 1e-9;
    let mut total = 0.0;
    let mut n = 0usize;
    for (p, t) in pred.iter().zip(truth) {
        if t.abs() > eps {
            total += ((p - t) / t).abs();
            n += 1;
        }
    }
    if n == 0 {
        0.0
    } else {
        100.0 * total / n as f64
    }
}

/// Root mean squared error.
pub fn rmse(pred: &[f64], truth: &[f64]) -> f64 {
    assert_eq!(pred.len(), truth.len());
    if pred.is_empty() {
        return 0.0;
    }
    let s: f64 = pred
        .iter()
        .zip(truth)
        .map(|(p, t)| (p - t) * (p - t))
        .sum();
    (s / pred.len() as f64).sqrt()
}

/// Pearson correlation coefficient.
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len();
    if n < 2 {
        return 0.0;
    }
    let (mx, my) = (mean(xs), mean(ys));
    let mut num = 0.0;
    let mut dx = 0.0;
    let mut dy = 0.0;
    for i in 0..n {
        let (a, b) = (xs[i] - mx, ys[i] - my);
        num += a * b;
        dx += a * a;
        dy += b * b;
    }
    if dx == 0.0 || dy == 0.0 {
        0.0
    } else {
        num / (dx * dy).sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std_dev(&xs) - 2.0).abs() < 1e-12);
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std_dev(&[1.0]), 0.0);
    }

    #[test]
    fn quantiles() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((median(&xs) - 2.5).abs() < 1e-12);
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 1.0), 4.0);
        let unsorted = [4.0, 1.0, 3.0, 2.0];
        assert!((median(&unsorted) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn quantile_survives_non_finite_inputs() {
        // regression (mirrors PR 1's Pareto NaN fix): a NaN entry used to
        // panic via partial_cmp().unwrap(); now NaN is excluded from the
        // order statistics
        assert_eq!(median(&[1.0, f64::NAN, 3.0]), 2.0);
        let m = median_iqr(&[2.0, f64::NAN, 1.0, 3.0]);
        assert_eq!(m.median, 2.0);
        // ±inf stays visible in the order statistics (a diverged metric
        // must not be silently dropped from tail quantiles)
        assert_eq!(median(&[f64::INFINITY, 1.0, 3.0]), 3.0);
        assert_eq!(quantile(&[f64::INFINITY, 1.0, 3.0], 1.0), f64::INFINITY);
        assert_eq!(quantile(&[f64::INFINITY], 0.5), f64::INFINITY);
        assert_eq!(
            quantile(&[f64::INFINITY, f64::INFINITY, 1.0], 0.9),
            f64::INFINITY
        );
        // interpolation against a non-finite neighbor is nearest-rank,
        // never NaN
        assert_eq!(median(&[f64::NEG_INFINITY, 4.0]), 4.0);
        assert_eq!(quantile(&[f64::NEG_INFINITY, 4.0], 0.2), f64::NEG_INFINITY);
        // nothing orderable left: propagate NaN explicitly
        assert!(median(&[f64::NAN, f64::NAN]).is_nan());
        // empty input keeps the historical 0.0 convention
        assert_eq!(quantile(&[], 0.5), 0.0);
    }

    #[test]
    fn quantile_linear_interpolates_against_hand_computed_fixtures() {
        // numpy `linear` / R type-7 fixtures, computed by hand:
        // position q·(n−1), interpolate between the bracketing order
        // statistics
        let xs: Vec<f64> = (1..=10).map(f64::from).collect(); // 1..10
        // p50: pos 4.5 → (5 + 6)/2
        assert!((quantile_linear(&xs, 0.5) - 5.5).abs() < 1e-12);
        // p95: pos 8.55 → 9 + 0.55·(10−9)
        assert!((quantile_linear(&xs, 0.95) - 9.55).abs() < 1e-12);
        // p99: pos 8.91 → 9.91
        assert!((quantile_linear(&xs, 0.99) - 9.91).abs() < 1e-12);
        // p999 over a small sample is *not* quantized to an observed
        // value: pos 8.991 → 9.991 (nearest-rank would answer 10.0)
        assert!((quantile_linear(&xs, 0.999) - 9.991).abs() < 1e-12);
        // and stays in lockstep with `quantile` (same estimator)
        for q in [0.0, 0.25, 0.5, 0.9, 0.999, 1.0] {
            assert_eq!(quantile_linear(&xs, q), quantile(&xs, q));
        }
        // uneven spacing: [10, 20, 40], p75 at pos 1.5 → 30
        assert!((quantile_linear(&[40.0, 10.0, 20.0], 0.75) - 30.0).abs() < 1e-12);
    }

    #[test]
    fn quantile_sorted_matches_quantile_on_a_sorted_sample() {
        let mut v = vec![4.0, 1.0, 3.0, 2.0, 8.0, 6.0];
        let reference: Vec<f64> =
            [0.0, 0.1, 0.5, 0.9, 0.99, 1.0].iter().map(|&q| quantile(&v, q)).collect();
        v.sort_unstable_by(f64::total_cmp);
        for (&q, &want) in [0.0, 0.1, 0.5, 0.9, 0.99, 1.0].iter().zip(&reference) {
            assert_eq!(quantile_sorted(&v, q), want);
        }
        // the non-finite nearest-rank degradation carries over
        let inf = [1.0, f64::INFINITY];
        assert_eq!(quantile_sorted(&inf, 0.2), 1.0);
        assert_eq!(quantile_sorted(&inf, 0.9), f64::INFINITY);
    }

    #[test]
    fn median_iqr_ordering() {
        let xs: Vec<f64> = (1..=11).map(f64::from).collect();
        let m = median_iqr(&xs);
        assert_eq!(m.median, 6.0);
        assert!(m.q1 <= m.median && m.median <= m.q3);
    }

    #[test]
    fn mape_basics() {
        let truth = [100.0, 200.0];
        let pred = [110.0, 180.0];
        // (10% + 10%) / 2
        assert!((mape(&pred, &truth) - 10.0).abs() < 1e-9);
        assert_eq!(mape(&[], &[]), 0.0);
        // zero-truth entries skipped
        assert!((mape(&[5.0, 110.0], &[0.0, 100.0]) - 10.0).abs() < 1e-9);
    }

    #[test]
    fn rmse_basics() {
        assert!((rmse(&[3.0], &[0.0]) - 3.0).abs() < 1e-12);
        assert!((rmse(&[1.0, 1.0], &[0.0, 2.0]) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn pearson_perfect_and_inverse() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys = [2.0, 4.0, 6.0, 8.0];
        assert!((pearson(&xs, &ys) - 1.0).abs() < 1e-12);
        let inv = [8.0, 6.0, 4.0, 2.0];
        assert!((pearson(&xs, &inv) + 1.0).abs() < 1e-12);
    }
}
