//! Aligned plain-text table rendering for CLI / bench / experiment output.

/// Column-aligned text table builder.
#[derive(Debug, Default)]
pub struct TextTable {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl TextTable {
    pub fn new(header: &[&str]) -> Self {
        TextTable {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells);
        self
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], out: &mut String| {
            for (i, cell) in cells.iter().enumerate() {
                out.push_str(cell);
                if i + 1 < ncol {
                    for _ in 0..(widths[i] - cell.len() + 2) {
                        out.push(' ');
                    }
                }
            }
            out.push('\n');
        };
        fmt_row(&self.header, &mut out);
        let total: usize = widths.iter().sum::<usize>() + 2 * (ncol - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            fmt_row(row, &mut out);
        }
        out
    }
}

/// Format a float with fixed decimals, trimming to a compact width.
pub fn fmt_f(x: f64, decimals: usize) -> String {
    format!("{x:.decimals$}")
}

/// Format a duration in the most readable unit.
pub fn fmt_duration_s(secs: f64) -> String {
    if secs < 1e-3 {
        format!("{:.1}us", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2}ms", secs * 1e3)
    } else if secs < 120.0 {
        format!("{secs:.2}s")
    } else if secs < 7200.0 {
        format!("{:.1}min", secs / 60.0)
    } else {
        format!("{:.2}h", secs / 3600.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = TextTable::new(&["name", "value"]);
        t.row(vec!["short".into(), "1".into()]);
        t.row(vec!["a-much-longer-name".into(), "12345".into()]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        // each value column starts at the same offset
        let off1 = lines[2].find('1').unwrap();
        let off2 = lines[3].find('1').unwrap();
        assert_eq!(off1, off2);
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn rejects_ragged_rows() {
        let mut t = TextTable::new(&["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn duration_units() {
        assert_eq!(fmt_duration_s(0.0000005), "0.5us");
        assert_eq!(fmt_duration_s(0.5), "500.00ms");
        assert_eq!(fmt_duration_s(2.0), "2.00s");
        assert_eq!(fmt_duration_s(600.0), "10.0min");
        assert_eq!(fmt_duration_s(7200.0), "2.00h");
    }
}
