//! AOT runtime: the artifact manifest (always available) plus the PJRT
//! execution layer (behind the `xla` feature).
//!
//! With the feature on, HLO-text artifacts are compiled once per artifact
//! on the embedded PJRT CPU client and cached; the hot loop re-uses them
//! with fresh literals. Python is never involved at runtime. Without it,
//! the manifest types still parse (CLI `info`, tooling) and every
//! prediction path runs through the batched host engine (`nn::engine`).

pub mod artifacts;

#[cfg(feature = "xla")]
mod exec;

pub use artifacts::{AdamConfig, ArtifactSpec, DType, IoSpec, Manifest};

#[cfg(feature = "xla")]
pub use exec::{f32_literal, to_f32_scalar, to_f32_vec, u32_literal, Runtime};
