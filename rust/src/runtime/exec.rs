//! PJRT execution: compile HLO-text artifacts on the embedded CPU client
//! and run them. Only compiled with the `xla` feature; the pure-host
//! builds serve predictions through `nn::engine` instead.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;

use super::artifacts::{self, Manifest};
use crate::error::{Error, Result};

/// A loaded artifact runtime bound to one PJRT client.
///
/// Not `Send`: the underlying PJRT client is reference-counted without
/// atomics. Each coordinator worker owns its own `Runtime` (compilation is
/// cheap relative to profiling; see DESIGN.md section 9 for the measured
/// costs).
pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    executables: RefCell<HashMap<String, xla::PjRtLoadedExecutable>>,
}

impl Runtime {
    /// Create a CPU PJRT client and load the manifest from `dir`.
    pub fn new(dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime { client, manifest, executables: RefCell::new(HashMap::new()) })
    }

    /// Create from the default artifacts directory.
    pub fn from_default_dir() -> Result<Runtime> {
        Runtime::new(&artifacts::default_artifacts_dir())
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch the cached executable for) an artifact.
    fn executable(&self, name: &str) -> Result<()> {
        if self.executables.borrow().contains_key(name) {
            return Ok(());
        }
        let path = self.manifest.hlo_path(name)?;
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| Error::Artifact("non-utf8 artifact path".into()))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        self.executables.borrow_mut().insert(name.to_string(), exe);
        Ok(())
    }

    /// Number of compiled executables currently cached.
    pub fn cached_executables(&self) -> usize {
        self.executables.borrow().len()
    }

    /// Execute an artifact with positional inputs, validating shapes
    /// against the manifest, and return the flattened output tuple.
    pub fn execute(&self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        self.execute_any(name, inputs)
    }

    /// Like [`Runtime::execute`] but accepts borrowed literals, so hot
    /// paths can build invariant inputs (e.g. model weights) once and
    /// re-submit them across many calls without copying.
    pub fn execute_refs(&self, name: &str, inputs: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        self.execute_any(name, inputs)
    }

    fn execute_any<L: std::borrow::Borrow<xla::Literal>>(
        &self,
        name: &str,
        inputs: &[L],
    ) -> Result<Vec<xla::Literal>> {
        let spec = self.manifest.artifact(name)?.clone();
        if inputs.len() != spec.inputs.len() {
            return Err(Error::Artifact(format!(
                "{name}: expected {} inputs, got {}",
                spec.inputs.len(),
                inputs.len()
            )));
        }
        for (lit, io) in inputs.iter().zip(&spec.inputs) {
            let n = lit.borrow().element_count();
            if n != io.element_count() {
                return Err(Error::Artifact(format!(
                    "{name}: input '{}' has {} elements, manifest says {}",
                    io.name,
                    n,
                    io.element_count()
                )));
            }
        }
        self.executable(name)?;
        let exes = self.executables.borrow();
        let exe = exes.get(name).expect("just inserted");
        // NOTE: we deliberately avoid `PjRtLoadedExecutable::execute` here —
        // its C wrapper (xla_rs.cc `execute`) `release()`s every input
        // buffer and never frees it, leaking ~0.5 MB per train step. We
        // materialize the input buffers ourselves (freed on Drop) and go
        // through the leak-free `execute_b` path instead.
        let mut buffers = Vec::with_capacity(inputs.len());
        for lit in inputs {
            buffers.push(self.client.buffer_from_host_literal(None, lit.borrow())?);
        }
        let result = exe.execute_b::<xla::PjRtBuffer>(&buffers)?;
        // single device, single output buffer holding the result tuple
        // (aot.py lowers with return_tuple=True)
        let lit = result[0][0].to_literal_sync()?;
        let outs = lit.to_tuple()?;
        if outs.len() != spec.outputs.len() {
            return Err(Error::Artifact(format!(
                "{name}: got {} outputs, manifest says {}",
                outs.len(),
                spec.outputs.len()
            )));
        }
        Ok(outs)
    }
}

// ---------------------------------------------------------------------------
// Literal marshalling helpers
// ---------------------------------------------------------------------------

/// Build an f32 literal of the given shape from a flat slice.
pub fn f32_literal(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let n: usize = shape.iter().product::<usize>().max(1);
    if data.len() != n {
        return Err(Error::Artifact(format!(
            "literal data length {} != shape product {}",
            data.len(),
            n
        )));
    }
    if shape.is_empty() {
        return Ok(xla::Literal::scalar(data[0]));
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims)?)
}

/// Build a u32 literal (rank 1).
pub fn u32_literal(data: &[u32]) -> xla::Literal {
    xla::Literal::vec1(data)
}

/// Extract an f32 vector from a literal.
pub fn to_f32_vec(lit: &xla::Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

/// Extract a single f32 scalar.
pub fn to_f32_scalar(lit: &xla::Literal) -> Result<f32> {
    Ok(lit.get_first_element::<f32>()?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_literal_shapes() {
        let l = f32_literal(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0], &[2, 3]).unwrap();
        assert_eq!(l.element_count(), 6);
        let back = to_f32_vec(&l).unwrap();
        assert_eq!(back, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    fn f32_literal_scalar() {
        let l = f32_literal(&[7.5], &[]).unwrap();
        assert_eq!(to_f32_scalar(&l).unwrap(), 7.5);
    }

    #[test]
    fn f32_literal_rejects_mismatch() {
        assert!(f32_literal(&[1.0, 2.0], &[3]).is_err());
    }

    #[test]
    fn u32_literal_round_trip() {
        let l = u32_literal(&[0xdead_beef, 42]);
        assert_eq!(l.to_vec::<u32>().unwrap(), vec![0xdead_beef, 42]);
    }
}
