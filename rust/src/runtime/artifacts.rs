//! Artifact manifest: the typed contract between the AOT compiler
//! (`python/compile/aot.py`) and the rust runtime.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::util::json::Value;

/// Element type of an artifact input/output.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    U32,
}

impl DType {
    fn parse(s: &str) -> Result<DType> {
        match s {
            "f32" => Ok(DType::F32),
            "u32" => Ok(DType::U32),
            other => Err(Error::Artifact(format!("unknown dtype {other}"))),
        }
    }
}

/// One named input or output tensor.
#[derive(Debug, Clone)]
pub struct IoSpec {
    pub name: String,
    pub dtype: DType,
    pub shape: Vec<usize>,
}

impl IoSpec {
    pub fn element_count(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

/// One lowered HLO computation.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

/// Adam hyperparameters baked into the train-step artifacts.
#[derive(Debug, Clone, Copy)]
pub struct AdamConfig {
    pub lr: f64,
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
}

/// Parsed `artifacts/manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub predict_batch: usize,
    pub train_batch: usize,
    pub input_dim: usize,
    pub hidden: Vec<usize>,
    pub dropout_rate: f64,
    pub adam: AdamConfig,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

fn parse_io(v: &Value) -> Result<IoSpec> {
    let shape = v
        .req("shape")?
        .as_arr()?
        .iter()
        .map(|d| d.as_usize())
        .collect::<Result<Vec<_>>>()?;
    Ok(IoSpec {
        name: v.req("name")?.as_str()?.to_string(),
        dtype: DType::parse(v.req("dtype")?.as_str()?)?,
        shape,
    })
}

impl Manifest {
    /// Load and validate `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            Error::Artifact(format!(
                "cannot read {} — run `make artifacts` first ({e})",
                path.display()
            ))
        })?;
        let v = Value::parse(&text)?;
        if v.req("format")?.as_str()? != "hlo-text" {
            return Err(Error::Artifact("unsupported artifact format".into()));
        }
        let adam_v = v.req("adam")?;
        let adam = AdamConfig {
            lr: adam_v.req("lr")?.as_f64()?,
            beta1: adam_v.req("beta1")?.as_f64()?,
            beta2: adam_v.req("beta2")?.as_f64()?,
            eps: adam_v.req("eps")?.as_f64()?,
        };
        let mut artifacts = BTreeMap::new();
        for (name, av) in v.req("artifacts")?.as_obj()? {
            let spec = ArtifactSpec {
                name: name.clone(),
                file: av.req("file")?.as_str()?.to_string(),
                inputs: av
                    .req("inputs")?
                    .as_arr()?
                    .iter()
                    .map(parse_io)
                    .collect::<Result<Vec<_>>>()?,
                outputs: av
                    .req("outputs")?
                    .as_arr()?
                    .iter()
                    .map(parse_io)
                    .collect::<Result<Vec<_>>>()?,
            };
            let file = dir.join(&spec.file);
            if !file.exists() {
                return Err(Error::Artifact(format!(
                    "manifest references missing file {}",
                    file.display()
                )));
            }
            artifacts.insert(name.clone(), spec);
        }
        let hidden = v
            .req("hidden")?
            .as_arr()?
            .iter()
            .map(|h| h.as_usize())
            .collect::<Result<Vec<_>>>()?;
        Ok(Manifest {
            dir: dir.to_path_buf(),
            predict_batch: v.req("predict_batch")?.as_usize()?,
            train_batch: v.req("train_batch")?.as_usize()?,
            input_dim: v.req("input_dim")?.as_usize()?,
            hidden,
            dropout_rate: v.req("dropout_rate")?.as_f64()?,
            adam,
            artifacts,
        })
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .ok_or_else(|| Error::Artifact(format!("no artifact '{name}' in manifest")))
    }

    pub fn hlo_path(&self, name: &str) -> Result<PathBuf> {
        Ok(self.dir.join(&self.artifact(name)?.file))
    }
}

/// Default artifacts directory: `$POWERTRAIN_ARTIFACTS` or `./artifacts`.
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var_os("POWERTRAIN_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::fs;

    fn write_manifest(dir: &Path, body: &str) {
        fs::create_dir_all(dir).unwrap();
        fs::write(dir.join("manifest.json"), body).unwrap();
    }

    const MINIMAL: &str = r#"{
        "format": "hlo-text", "predict_batch": 512, "train_batch": 64,
        "input_dim": 4, "hidden": [256, 128, 64], "dropout_rate": 0.1,
        "adam": {"lr": 0.001, "beta1": 0.9, "beta2": 0.999, "eps": 1e-8},
        "artifacts": {
            "predict": {"file": "predict.hlo.txt",
                "inputs": [{"name": "x", "dtype": "f32", "shape": [512, 4]}],
                "outputs": [{"name": "y", "dtype": "f32", "shape": [512, 1]}]}
        }
    }"#;

    #[test]
    fn loads_valid_manifest() {
        let dir = std::env::temp_dir().join("pt_manifest_ok");
        write_manifest(&dir, MINIMAL);
        fs::write(dir.join("predict.hlo.txt"), "HloModule m\nENTRY e {}").unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.predict_batch, 512);
        assert_eq!(m.hidden, vec![256, 128, 64]);
        let a = m.artifact("predict").unwrap();
        assert_eq!(a.inputs[0].dtype, DType::F32);
        assert_eq!(a.inputs[0].element_count(), 2048);
        assert!(m.artifact("nope").is_err());
        fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_missing_hlo_file() {
        let dir = std::env::temp_dir().join("pt_manifest_missing");
        write_manifest(&dir, MINIMAL);
        // no predict.hlo.txt on disk
        let err = Manifest::load(&dir).unwrap_err();
        assert!(err.to_string().contains("missing file"));
        fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_absent_manifest_with_hint() {
        let dir = std::env::temp_dir().join("pt_manifest_absent");
        fs::create_dir_all(&dir).ok();
        fs::remove_file(dir.join("manifest.json")).ok();
        let err = Manifest::load(&dir).unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }

    #[test]
    fn rejects_bad_format() {
        let dir = std::env::temp_dir().join("pt_manifest_badfmt");
        write_manifest(&dir, &MINIMAL.replace("hlo-text", "proto"));
        assert!(Manifest::load(&dir).is_err());
        fs::remove_dir_all(&dir).ok();
    }
}
